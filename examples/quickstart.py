"""Quickstart: train a small decoder LM on the synthetic Markov task.

Demonstrates the public API end to end on one host:
  config -> Model -> optimizer -> jitted train step -> checkpoint.

Run:  PYTHONPATH=src python examples/quickstart.py [--steps 120]
The loss should fall from ~ln(V) toward the task's entropy floor.
"""
import argparse
import time

import jax

from repro.checkpoint import save_pytree
from repro.configs import get_config
from repro.data import make_markov_task, sample_batch
from repro.launch.train import make_train_step
from repro.models.model import Model
from repro.optim import adamw, cosine_schedule


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_quickstart.npz")
    args = ap.parse_args()

    cfg = get_config("paper_rwsgd")  # the paper's small payload LM
    model = Model(cfg)
    task = make_markov_task(cfg.vocab_size)
    opt = adamw(cosine_schedule(3e-3, warmup=10, total=args.steps))

    key = jax.random.key(0)
    params = model.init(key)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model={cfg.name} params={n_params:,} "
          f"entropy floor={task.entropy:.3f} nats/token")

    t0 = time.time()
    for i in range(args.steps):
        batch = sample_batch(task, jax.random.fold_in(key, i), args.batch, args.seq)
        params, opt_state, metrics = step(params, opt_state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(metrics['loss']):.4f}  "
                  f"({time.time() - t0:5.1f}s)")

    save_pytree(args.ckpt, params, metadata={"arch": cfg.name, "steps": args.steps})
    print(f"checkpoint saved to {args.ckpt}")
    final = float(metrics["loss"])
    print(f"final loss {final:.3f} vs floor {task.entropy:.3f} "
          f"(gap {final - task.entropy:+.3f})")


if __name__ == "__main__":
    main()
