"""Experiment service demo: many callers, coalesced compiled calls.

Three "users" each submit their own scenario list against one shared
Experiment. Scenarios that share static structure (and seeds/base key)
coalesce into ONE ``sweep_stacked`` call — the service stats show fewer
compiled batches than submissions — and results stream back per group.
With ``REPRO_RESULT_STORE`` set (or ``--store DIR``), a second run of
this script answers every submission from disk without compiling
anything.

Run:  PYTHONPATH=src python examples/experiment_service_demo.py [--store DIR]
"""
import argparse

import numpy as np

from repro.api import Experiment, ExperimentService
from repro.core.failures import FailureConfig
from repro.core.protocol import ProtocolConfig
from repro.sweep import Scenario


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--store", default="env",
                    help="result-store dir ('env': honor $REPRO_RESULT_STORE)")
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seeds", type=int, default=8)
    args = ap.parse_args()

    # one registered, config-driven study shared by every caller
    exp = Experiment.from_config({
        "experiment": "walks",
        "graph": "regular",
        "n": args.n,
        "steps": args.steps,
        "outputs": "scalars",
    })

    def scen(name, eps, bursts=()):
        return Scenario(
            name,
            ProtocolConfig(eps=eps),
            FailureConfig(burst_times=bursts, burst_sizes=(2,) * len(bursts)),
        )

    with ExperimentService(exp, store=args.store, autostart=False) as svc:
        # three callers, five scenarios, ONE static structure -> 1 batch
        f1 = svc.submit([scen("a/eps=1.8", 1.8), scen("a/eps=2.0", 2.0)],
                        seeds=args.seeds)
        f2 = svc.submit([scen("b/eps=2.2", 2.2)], seeds=args.seeds)
        f3 = svc.submit([scen("c/burst", 2.0, bursts=(100,)),
                         scen("c/calm", 2.0)], seeds=args.seeds)
        svc.flush()

        for fut in (f1, f2, f3):
            for name, outs, _ in fut.stream():
                z_final = float(np.mean(np.asarray(outs.z)[:, -1]))
                print(f"  {name:12s} mean final walk count = {z_final:.2f}")
        s = svc.stats
        print(
            f"{s['submissions']} submissions / {s['scenarios']} scenarios "
            f"ran as {s['batches']} compiled batch(es)"
        )
        if svc.store is not None:
            print(f"store: {svc.store!r}")


if __name__ == "__main__":
    main()
