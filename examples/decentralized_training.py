"""END-TO-END DRIVER: decentralized RW-SGD learning with DECAFORK(+).

This is the paper's full system in one *fused, compiled* call:

  * a graph of data-holding nodes (each owns a Markov-chain shard);
  * Z_0 random walks, each carrying a model replica + optimizer state;
  * every round, each live walk takes a local SGD step on the data of
    the node it sits on, then hops to a random neighbor (RW-SGD);
  * nodes run DECAFORK: estimate the live-walk count from return-time
    survival, fork the visiting walk (replica duplicated!) when the
    estimate drops, terminate when it overshoots (DECAFORK+);
  * a burst failure kills several walks mid-training — the system
    detects it, re-forks, and learning continues without losing the
    surviving replicas' progress.

The learning workload is an ``RwSgdPayload`` plugged into one declarative
``repro.api.Experiment``: model forks, local SGD steps and loss telemetry
all run inside the trajectory's single ``lax.scan`` — the whole training
run is ONE jitted device call, not a Python per-hop loop.

Run:  PYTHONPATH=src python examples/decentralized_training.py
      [--nodes 64 --z0 6 --steps 1400 --burst-at 900 --burst-size 3]
"""
import argparse
import time

import jax
import numpy as np

from repro.api import Experiment
from repro.configs import get_smoke_config
from repro.core.failures import FailureConfig
from repro.core.protocol import ProtocolConfig
from repro.data import make_markov_task
from repro.graphs import random_regular_graph
from repro.models.model import Model
from repro.optim import RwSgdPayload, adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=64)
    ap.add_argument("--degree", type=int, default=8)
    ap.add_argument("--z0", type=int, default=6)
    ap.add_argument("--max-walks", type=int, default=16)
    ap.add_argument("--steps", type=int, default=1400)
    ap.add_argument("--burst-at", type=int, default=900)
    ap.add_argument("--burst-size", type=int, default=3)
    ap.add_argument("--protocol-start", type=int, default=400)
    ap.add_argument("--eps", type=float, default=1.2)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--local-batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--train-every", type=int, default=1,
                    help="walk hops per local SGD step")
    args = ap.parse_args()

    # --- the decentralized system --------------------------------------
    g = random_regular_graph(args.nodes, args.degree, seed=0)
    pcfg = ProtocolConfig(
        algorithm="decafork", z0=args.z0, max_walks=args.max_walks,
        eps=args.eps, protocol_start=args.protocol_start, rt_bins=512,
    )
    fcfg = FailureConfig(burst_times=(args.burst_at,), burst_sizes=(args.burst_size,))

    # --- the learning payload ------------------------------------------
    cfg = get_smoke_config("paper_rwsgd")
    model = Model(cfg)
    task = make_markov_task(cfg.vocab_size)
    payload = RwSgdPayload(
        model, adamw(args.lr), task, max_walks=args.max_walks,
        local_batch=args.local_batch, seq_len=args.seq,
        train_every=args.train_every,
    )
    n_params = sum(
        x.size for x in jax.tree.leaves(model.init(jax.random.key(0)))
    )
    print(f"graph n={g.n} d={args.degree} | Z0={args.z0} walks | "
          f"payload {cfg.name} ({n_params:,} params/replica) | "
          f"entropy floor {task.entropy:.3f}")

    # --- the whole trajectory: ONE fused compiled call ------------------
    t0 = time.time()
    (final, replicas), (outs, learn) = Experiment(
        graph=g, protocol=pcfg, failures=fcfg, steps=args.steps,
        payload=payload,
    ).run(key=0)
    jax.block_until_ready(learn.mean_loss)
    wall = time.time() - t0

    z = np.asarray(outs.z)
    loss = np.asarray(learn.mean_loss)
    trained = np.asarray(learn.trained) > 0  # rounds where a step ran

    def loss_over(window: slice) -> float:
        """Mean loss over the window's *training* rounds only (with
        --train-every > 1 the off rounds report 0, not a loss)."""
        w = loss[window][trained[window]]
        return float(w.mean()) if w.size else float("nan")

    for t in range(0, args.steps, 100):
        marker = "  <-- BURST" if args.burst_at in range(t, t + 100) else ""
        print(f"t={t:5d}  Z={z[t]:2d}  "
              f"loss={loss_over(slice(t, t + 100)):.3f}{marker}")

    pre = slice(max(args.burst_at - 100, 0), args.burst_at)
    post = slice(args.steps - 100, args.steps)
    print("\n=== summary ===")
    print(f"wall: {wall:.1f}s for {args.steps} fused rounds "
          f"({wall * 1e3 / args.steps:.2f} ms/round incl. compile)")
    print(f"Z before burst: {z[pre].mean():.1f}   Z at end: {z[post].mean():.1f}")
    print(f"loss before burst: {loss_over(pre):.3f} -> end: {loss_over(post):.3f} "
          f"(floor {task.entropy:.3f})")
    print(f"replica local-step counters: {np.asarray(replicas.steps).tolist()}")
    survived = (z > 0).all()
    print(f"resilience: {'OK — at least one walk alive throughout' if survived else 'FAILED'}")


if __name__ == "__main__":
    main()
