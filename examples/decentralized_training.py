"""END-TO-END DRIVER: decentralized RW-SGD learning with DECAFORK(+).

This is the paper's full system in one script:

  * a graph of data-holding nodes (each owns a Markov-chain shard);
  * Z_0 random walks, each carrying a model replica + optimizer state;
  * every round, each live walk takes a local SGD step on the data of
    the node it sits on, then hops to a random neighbor (RW-SGD);
  * nodes run DECAFORK: estimate the live-walk count from return-time
    survival, fork the visiting walk (replica duplicated!) when the
    estimate drops, terminate when it overshoots (DECAFORK+);
  * a burst failure kills several walks mid-training — the system
    detects it, re-forks, and learning continues without losing the
    surviving replicas' progress.

Run:  PYTHONPATH=src python examples/decentralized_training.py
      [--nodes 64 --z0 6 --steps 1400 --burst-at 900 --burst-size 3]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.failures import FailureConfig
from repro.core.protocol import ProtocolConfig
from repro.core.simulator import init_state, protocol_step
from repro.data import make_markov_task, sample_batch
from repro.graphs import random_regular_graph
from repro.graphs.state import mirror_indices
from repro.models.model import Model
from repro.optim import adamw, fork_replica, init_replicas
from repro.optim.rw_sgd import replica_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=64)
    ap.add_argument("--degree", type=int, default=8)
    ap.add_argument("--z0", type=int, default=6)
    ap.add_argument("--max-walks", type=int, default=16)
    ap.add_argument("--steps", type=int, default=1400)
    ap.add_argument("--burst-at", type=int, default=900)
    ap.add_argument("--burst-size", type=int, default=3)
    ap.add_argument("--protocol-start", type=int, default=400)
    ap.add_argument("--eps", type=float, default=1.2)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--local-batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--train-every", type=int, default=1,
                    help="walk hops per local SGD step")
    args = ap.parse_args()

    # --- the decentralized system --------------------------------------
    g = random_regular_graph(args.nodes, args.degree, seed=0)
    pcfg = ProtocolConfig(
        algorithm="decafork", z0=args.z0, max_walks=args.max_walks,
        eps=args.eps, protocol_start=args.protocol_start, rt_bins=512,
    )
    fcfg = FailureConfig(burst_times=(args.burst_at,), burst_sizes=(args.burst_size,))
    neighbors = jnp.asarray(g.neighbors)
    degrees = jnp.asarray(g.degrees)

    # --- the learning payload ------------------------------------------
    cfg = get_smoke_config("paper_rwsgd")
    model = Model(cfg)
    task = make_markov_task(cfg.vocab_size)
    opt = adamw(args.lr)
    key = jax.random.key(0)
    rs = init_replicas(model.init, opt.init, key, max_walks=args.max_walks)
    train = jax.jit(replica_train_step(model.loss, opt))
    n_params = sum(x.size for x in jax.tree.leaves(model.init(key)))
    print(f"graph n={g.n} d={args.degree} | Z0={args.z0} walks | "
          f"payload {cfg.name} ({n_params:,} params/replica) | "
          f"entropy floor {task.entropy:.3f}")

    mirror = jnp.asarray(mirror_indices(g))
    step_fn = jax.jit(
        lambda s: protocol_step(s, pcfg, fcfg, neighbors, degrees, mirror, None)
    )

    @jax.jit
    def node_batches_for(pos, kb):
        return jax.vmap(
            lambda nid: sample_batch(task, kb, args.local_batch, args.seq, nid)
        )(pos)

    state = init_state(g.n, g.max_degree, pcfg, fcfg, key)
    slots = jnp.arange(args.max_walks)
    t0 = time.time()
    log = []
    for t in range(args.steps):
        state, out = step_fn(state)
        # replicate forked walks' models (DECAFORK's "identical copy")
        parents = out.fork_parent
        has_fork = np.asarray(parents >= 0).any()
        if has_fork:
            rs = fork_replica(rs, jnp.maximum(parents, 0), slots, parents >= 0)
        # local SGD at each visited node, on that node's data shard
        if t % args.train_every == 0:
            kb = jax.random.fold_in(key, 10_000 + t)
            batches = node_batches_for(state.walks.pos, kb)
            rs, losses = train(rs, batches, state.walks.active)
            z = int(out.z)
            mean_loss = float(losses.sum() / max(z, 1))
            log.append((t, z, mean_loss))
        if t % 100 == 0 or t == args.burst_at:
            z = int(out.z)
            marker = "  <-- BURST" if t == args.burst_at else ""
            print(f"t={t:5d}  Z={z:2d}  loss={log[-1][2]:.3f}  "
                  f"({time.time() - t0:5.1f}s){marker}")

    log = np.asarray(log)
    pre = log[(log[:, 0] > args.burst_at - 100) & (log[:, 0] < args.burst_at)]
    post = log[log[:, 0] > args.steps - 100]
    print("\n=== summary ===")
    print(f"Z before burst: {pre[:, 1].mean():.1f}   Z at end: {post[:, 1].mean():.1f}")
    print(f"loss before burst: {pre[:, 2].mean():.3f} -> end: {post[:, 2].mean():.3f} "
          f"(floor {task.entropy:.3f})")
    survived = (log[:, 1] > 0).all()
    print(f"resilience: {'OK — at least one walk alive throughout' if survived else 'FAILED'}")


if __name__ == "__main__":
    main()
