"""Serving demo: prefill a batch of prompts, then batched token decode.

The decode loop lives in ``repro.launch.serve.generate`` — this demo is
a thin driver over it (prefill + cache re-homing + EOS-aware decode with
early exit are the library's job, not the example's). Exercises the
inference path the decode_32k / long_500k dry-run shapes lower at
production scale — here with a smoke model on CPU.

Run:  PYTHONPATH=src python examples/serve_demo.py [--arch yi_6b]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.serve import generate
from repro.models.model import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--eos-id", type=int, default=None,
                    help="enable EOS tracking + early exit")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = Model(cfg)
    key = jax.random.key(0)
    params = model.init(key)
    B, P = args.batch, args.prompt_len

    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab_size, dtype=jnp.int32)
    batch = {"tokens": prompts}
    if cfg.arch_type == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1), (B, cfg.vision_tokens, 1024)
        )
    if cfg.num_codebooks:
        batch["tokens"] = jax.random.randint(
            key, (B, P, cfg.num_codebooks), 0, cfg.vocab_size, dtype=jnp.int32
        )

    gen, stats = generate(
        model,
        params,
        batch,
        max_new_tokens=args.new_tokens,
        temperature=args.temperature,
        eos_id=args.eos_id,
        key=jax.random.fold_in(key, 2),
    )
    print(
        f"arch={cfg.name}: prefill {B}x{P} tokens in "
        f"{stats['prefill_s']*1e3:.1f} ms (incl. compile)"
    )
    print(
        f"decoded {stats['decode_steps']} steps x {B} streams in "
        f"{stats['decode_s']*1e3:.0f} ms -> {stats['tokens_per_s']:.0f} tok/s "
        "(CPU, incl. compile)"
    )
    print("sample token ids (stream 0):", np.asarray(gen[0]).reshape(-1)[:16].tolist())


if __name__ == "__main__":
    main()
