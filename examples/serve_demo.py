"""Serving demo: prefill a batch of prompts, then batched token decode.

Exercises the inference path the decode_32k / long_500k dry-run shapes
lower at production scale — here with a smoke model on CPU.

Run:  PYTHONPATH=src python examples/serve_demo.py [--arch yi_6b]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models.model import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = Model(cfg)
    key = jax.random.key(0)
    params = model.init(key)
    B, P = args.batch, args.prompt_len

    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab_size, dtype=jnp.int32)
    batch = {"tokens": prompts}
    if cfg.arch_type == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1), (B, cfg.vision_tokens, 1024)
        )
    if cfg.num_codebooks:
        batch["tokens"] = jax.random.randint(
            key, (B, P, cfg.num_codebooks), 0, cfg.vocab_size, dtype=jnp.int32
        )

    # --- prefill ---------------------------------------------------------
    prefill = jax.jit(model.prefill)
    t0 = time.time()
    last_logits, cache = prefill(params, batch)
    jax.block_until_ready(last_logits)
    t_prefill = time.time() - t0
    print(f"arch={cfg.name}: prefill {B}x{P} tokens in {t_prefill*1e3:.1f} ms "
          f"(incl. compile)")

    # extend the ring so decode has room beyond the prompt
    decode_cache = model.init_cache(B, P + args.new_tokens)
    # copy prefilled keys/values/state into the larger cache
    def blit(dst, src):
        if dst.ndim >= 3 and dst.shape[:2] == src.shape[:2] and dst.ndim == src.ndim:
            sl = tuple([slice(None), slice(None), slice(0, src.shape[2])])
            return dst.at[sl].set(src) if dst.shape[2] >= src.shape[2] else dst
        return src if dst.shape == src.shape else dst
    decode_cache["layers"] = jax.tree.map(blit, decode_cache["layers"], cache["layers"])
    if "cache_positions" in cache:
        decode_cache["cache_positions"] = (
            decode_cache["cache_positions"].at[:, :P].set(cache["cache_positions"])
        )
    decode_cache["next_pos"] = cache["next_pos"]

    # --- decode loop -------------------------------------------------------
    decode = jax.jit(model.decode_step)
    tok = (
        jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
        if not cfg.num_codebooks
        else jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
    )
    if cfg.num_codebooks:
        tok = tok.reshape(B, 1, cfg.num_codebooks)
    else:
        tok = tok.reshape(B, 1)
    generated = [np.asarray(tok)]
    t0 = time.time()
    for i in range(args.new_tokens - 1):
        logits, decode_cache = decode(params, decode_cache, {"tokens": tok})
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        tok = tok.reshape(B, 1, cfg.num_codebooks) if cfg.num_codebooks else tok.reshape(B, 1)
        generated.append(np.asarray(tok))
    jax.block_until_ready(tok)
    dt = time.time() - t0
    tps = B * (args.new_tokens - 1) / dt
    print(f"decoded {args.new_tokens-1} tokens x {B} streams in {dt*1e3:.0f} ms "
          f"-> {tps:.0f} tok/s (CPU, incl. compile)")
    out = np.concatenate(generated, axis=1)
    print("sample token ids (stream 0):", out[0].reshape(-1)[:16].tolist())


if __name__ == "__main__":
    main()
