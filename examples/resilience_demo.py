"""Resilience demo: reproduce Fig. 1 as an ASCII time series.

Runs MISSINGPERSON / DECAFORK / DECAFORK+ through two burst failures and
plots Z_t in the terminal — the fastest way to *see* the paper's claim.

Run:  PYTHONPATH=src python examples/resilience_demo.py [--full]
"""
import argparse

import numpy as np

from repro.api import Experiment
from repro.core import FailureConfig, ProtocolConfig
from repro.graphs import random_regular_graph


def ascii_plot(z, z0, width=100, height=12, title=""):
    z = np.asarray(z, float)
    idx = np.linspace(0, len(z) - 1, width).astype(int)
    zz = z[idx]
    top = max(zz.max(), z0 * 2)
    rows = []
    for level in np.linspace(top, 0, height):
        line = "".join("#" if v >= level > v - top / height else
                       ("-" if abs(level - z0) < top / height / 2 else " ")
                       for v in zz)
        rows.append(f"{level:5.1f} |{line}")
    print(f"\n{title}  (- marks Z0={z0})")
    print("\n".join(rows))
    print("      +" + "-" * width)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale run")
    args = ap.parse_args()

    n, z0 = (100, 10) if args.full else (64, 8)
    steps = 9000 if args.full else 3000
    bursts = (2000, 6000) if args.full else (1000, 2000)
    proto_start = 1000 if args.full else 500

    g = random_regular_graph(n, 8, seed=0)
    fcfg = FailureConfig(burst_times=bursts, burst_sizes=(z0 // 2, z0 // 2 + 1))
    cases = [
        ("MISSINGPERSON (eps_mp=400)", "missingperson", dict(eps_mp=400.0)),
        ("DECAFORK (eps=2)", "decafork", dict(eps=2.0)),
        ("DECAFORK+ (eps=3, eps2=7.57)", "decafork+", dict(eps=3.0, eps2=7.57)),
    ]
    for title, alg, kw in cases:
        pcfg = ProtocolConfig(
            algorithm=alg, z0=z0, max_walks=64, protocol_start=proto_start, **kw
        )
        _, outs = Experiment(
            graph=g, protocol=pcfg, failures=fcfg, steps=steps
        ).run(key=0)
        z = np.asarray(outs.z)
        ascii_plot(z, z0, title=title)
        print(f"   forks={int(np.asarray(outs.forks).sum())} "
              f"terms={int(np.asarray(outs.terms).sum())} "
              f"maxZ={z.max()} survived={(z > 0).all()}")


if __name__ == "__main__":
    main()
