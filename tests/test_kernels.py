"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import attention_pallas, ssd_pallas, theta_sums_pallas
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.theta_survival import theta_sums

KEY = jax.random.key(42)


# ---------------------------------------------------------------------------
# theta_survival
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,W,B", [(8, 4, 16), (32, 16, 64), (64, 40, 128), (16, 7, 33)])
def test_theta_shapes(n, W, B):
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, n * W))
    ls = jax.random.randint(k1, (n, W), -1, 60, dtype=jnp.int32)
    hist = (jax.random.uniform(k2, (n, B)) * 3).astype(jnp.float32)
    # some nodes with zero samples
    hist = hist.at[0].set(0.0)
    total = hist.sum(1)
    t = jnp.int32(70)
    got = theta_sums_pallas(ls, hist, total, t)
    want = ref.theta_sums_ref(ls, hist, total, t)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n", [3, 13, 17, 101])
def test_theta_odd_n_pads_instead_of_raising(n):
    """Arbitrary graph sizes: n that is not a multiple of block_nodes is
    padded with masked rows and matches the compare oracle bitwise."""
    from repro.core.estimator import node_sums_compare

    k1, k2 = jax.random.split(jax.random.fold_in(KEY, n))
    ls = jax.random.randint(k1, (n, 6), -1, 40, dtype=jnp.int32)
    hist = jnp.floor(jax.random.uniform(k2, (n, 32)) * 3).astype(jnp.float32)
    total = hist.sum(1)
    t = jnp.int32(50)
    got = theta_sums(ls, hist, total, t, block_nodes=8, interpret=True)
    want = node_sums_compare(ls, hist, total, t)
    assert got.shape == (n,)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_theta_block_size_invariance():
    k1, k2 = jax.random.split(KEY)
    ls = jax.random.randint(k1, (16, 8), -1, 30, dtype=jnp.int32)
    hist = (jax.random.uniform(k2, (16, 32)) * 2).astype(jnp.float32)
    total = hist.sum(1)
    a = theta_sums(ls, hist, total, jnp.int32(40), block_nodes=4, interpret=True)
    b = theta_sums(ls, hist, total, jnp.int32(40), block_nodes=16, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_theta_all_never_seen():
    ls = jnp.full((8, 4), -1, jnp.int32)
    hist = jnp.ones((8, 16), jnp.float32)
    got = theta_sums_pallas(ls, hist, hist.sum(1), jnp.int32(10))
    np.testing.assert_allclose(np.asarray(got), 0.0)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("S,H,KV,D", [(128, 4, 4, 32), (256, 8, 2, 64), (256, 6, 1, 32)])
@pytest.mark.parametrize("window", [0, 96])
def test_flash_vs_ref(S, H, KV, D, window):
    k = jax.random.fold_in(KEY, S * H + window)
    q = jax.random.normal(jax.random.fold_in(k, 0), (2, S, H, D), jnp.float32)
    kk = jax.random.normal(jax.random.fold_in(k, 1), (2, S, KV, D), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(k, 2), (2, S, KV, D), jnp.float32)
    got = attention_pallas(q, kk, v, window=window)
    want = ref.mha_ref(q, kk, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_flash_bf16():
    k = jax.random.fold_in(KEY, 77)
    q = jax.random.normal(jax.random.fold_in(k, 0), (1, 128, 4, 32), jnp.bfloat16)
    kk = jax.random.normal(jax.random.fold_in(k, 1), (1, 128, 2, 32), jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(k, 2), (1, 128, 2, 32), jnp.bfloat16)
    got = attention_pallas(q, kk, v)
    want = ref.mha_ref(q, kk, v)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=3e-2, atol=3e-2
    )


@pytest.mark.slow
def test_flash_matches_model_blocked_attention():
    """Kernel == the jnp blocked attention the models actually run."""
    from repro.models.layers import blocked_causal_attention

    k = jax.random.fold_in(KEY, 99)
    q = jax.random.normal(jax.random.fold_in(k, 0), (2, 256, 8, 32), jnp.float32)
    kk = jax.random.normal(jax.random.fold_in(k, 1), (2, 256, 4, 32), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(k, 2), (2, 256, 4, 32), jnp.float32)
    for w in (0, 64):
        a = attention_pallas(q, kk, v, window=w)
        b = blocked_causal_attention(q, kk, v, window=w, q_block=128)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_flash_rejects_bad_shapes():
    q = jnp.zeros((1, 4, 128, 32))
    k = jnp.zeros((1, 3, 128, 32))
    with pytest.raises(ValueError):
        flash_attention(q, k, k)


# ---------------------------------------------------------------------------
# ssd intra-chunk
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("L,H,P,N,chunk", [(128, 2, 16, 8, 64), (256, 4, 32, 16, 128)])
def test_ssd_vs_chunked(L, H, P, N, chunk):
    from repro.models.ssm import ssd_chunked

    k = jax.random.fold_in(KEY, L * H)
    B = 2
    x = jax.random.normal(jax.random.fold_in(k, 0), (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1), (B, L, H)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 2), (H,)))
    b_in = jax.random.normal(jax.random.fold_in(k, 3), (B, L, N))
    c_in = jax.random.normal(jax.random.fold_in(k, 4), (B, L, N))
    y_ref, st_ref = ssd_chunked(x, dt, a, b_in, c_in, chunk=chunk, return_state=True)
    y_got, st_got = ssd_pallas(x, dt, a, b_in, c_in, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_ref), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(st_got), np.asarray(st_ref), rtol=3e-4, atol=3e-4)


@pytest.mark.slow
def test_ssd_vs_naive_recurrence():
    """Both chunked paths == the literal h_t = g h_{t-1} + dt B x recurrence."""
    from repro.models.ssm import ssd_chunked

    B, L, H, P, N = 1, 64, 2, 8, 4
    k = jax.random.fold_in(KEY, 1234)
    x = jax.random.normal(jax.random.fold_in(k, 0), (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1), (B, L, H)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 2), (H,)))
    b_in = jax.random.normal(jax.random.fold_in(k, 3), (B, L, N))
    c_in = jax.random.normal(jax.random.fold_in(k, 4), (B, L, N))

    h = np.zeros((B, H, P, N))
    ys = []
    for t in range(L):
        g = np.exp(np.asarray(dt[:, t]) * np.asarray(a))  # (B,H)
        xdt = np.asarray(x[:, t]) * np.asarray(dt[:, t])[..., None]
        h = h * g[..., None, None] + np.einsum("bhp,bn->bhpn", xdt, np.asarray(b_in[:, t]))
        ys.append(np.einsum("bhpn,bn->bhp", h, np.asarray(c_in[:, t])))
    want = np.stack(ys, axis=1)  # (B,L,H,P)

    got = np.asarray(ssd_chunked(x, dt, a, b_in, c_in, chunk=16))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    got_k, _ = ssd_pallas(x, dt, a, b_in, c_in, chunk=16)
    np.testing.assert_allclose(np.asarray(got_k), want, rtol=1e-4, atol=1e-4)


@pytest.mark.slow
def test_pallas_estimator_in_simulation():
    """estimator_impl='pallas' (interpret mode) drives the same protocol
    trajectory as the gather path inside a real simulation."""
    from repro.api import Experiment
    from repro.core.failures import FailureConfig
    from repro.core.protocol import ProtocolConfig
    from repro.graphs import random_regular_graph

    g = random_regular_graph(16, 4, seed=2)
    fcfg = FailureConfig(burst_times=(120,), burst_sizes=(2,))
    zs = {}
    for impl in ("gather", "pallas"):
        pcfg = ProtocolConfig(
            algorithm="decafork", z0=4, max_walks=8, eps=1.2,
            protocol_start=60, rt_bins=64, estimator_impl=impl,
        )
        _, outs = Experiment(graph=g, protocol=pcfg, failures=fcfg, steps=200).run(key=9)
        zs[impl] = np.asarray(outs.z)
    np.testing.assert_array_equal(zs["gather"], zs["pallas"])
