"""Walk-slot machinery edge cases: capacity overflow, slot reuse, identity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import walkers as wlk
from repro.core.estimator import NEVER


def _state(pos, active, track=None):
    pos = jnp.asarray(pos, jnp.int32)
    active = jnp.asarray(active, bool)
    if track is None:
        track = jnp.arange(pos.shape[0], dtype=jnp.int32)
    return wlk.WalkState(pos=pos, active=active, track=jnp.asarray(track, jnp.int32))


def test_fork_overflow_dropped_not_corrupted():
    """More fork events than free slots: extras drop, nothing is clobbered."""
    ws = _state([4, 5, 6, 7, 0, 0], [True, True, True, True, False, False])
    last_seen = jnp.full((8, 6), 3, jnp.int32)
    ev = jnp.asarray([True, True, True, True, False, False])  # 4 events, 2 free
    new_ws, new_ls, n, fp = wlk.execute_forks(ws, last_seen, ev, ws.pos, None, jnp.int32(9))
    assert int(n) == 2
    assert np.asarray(new_ws.active).all()  # exactly filled to capacity
    # free slots were matched to events in rank order: slot 4 <- walk 0, slot 5 <- walk 1
    assert int(new_ws.pos[4]) == 4 and int(new_ws.pos[5]) == 5
    np.testing.assert_array_equal(np.asarray(fp), [-1, -1, -1, -1, 0, 1])
    # surviving walks untouched
    np.testing.assert_array_equal(np.asarray(new_ws.pos[:4]), [4, 5, 6, 7])
    np.testing.assert_array_equal(np.asarray(new_ws.track[:4]), [0, 1, 2, 3])
    # dropped events (walks 2, 3) left no trace anywhere in last_seen
    ls = np.asarray(new_ls)
    assert (ls[:, :4] == 3).all()


def test_fork_with_zero_free_slots_is_noop():
    ws = _state([1, 2, 3], [True, True, True])
    last_seen = jnp.full((4, 3), 5, jnp.int32)
    ev = jnp.asarray([True, True, True])
    new_ws, new_ls, n, fp = wlk.execute_forks(ws, last_seen, ev, ws.pos, None, jnp.int32(7))
    assert int(n) == 0
    np.testing.assert_array_equal(np.asarray(new_ws.pos), np.asarray(ws.pos))
    np.testing.assert_array_equal(np.asarray(new_ws.active), np.asarray(ws.active))
    np.testing.assert_array_equal(np.asarray(new_ws.track), np.asarray(ws.track))
    assert (np.asarray(new_ls) == 5).all()
    assert (np.asarray(fp) == -1).all()


def test_decafork_slot_reuse_clears_stale_column():
    """Terminate a walk, fork into its slot: the stale last_seen column of
    the dead identity must not leak into the new walk's return stats."""
    ws = _state([2, 3, 1], [True, True, True])
    # slot 1's identity was seen everywhere at t=6 (stale once it dies)
    last_seen = jnp.asarray(
        [[0, 6, NEVER], [1, 6, NEVER], [2, 6, NEVER], [3, 6, NEVER]], jnp.int32
    )
    ws = wlk.execute_terminations(ws, jnp.asarray([False, True, False]))
    assert not bool(ws.active[1])
    ev = jnp.asarray([True, False, False])  # walk 0 (at node 2) forks
    new_ws, new_ls, n, fp = wlk.execute_forks(ws, last_seen, ev, ws.pos, None, jnp.int32(9))
    assert int(n) == 1 and bool(new_ws.active[1])
    assert int(new_ws.track[1]) == 1  # fresh identity = reused slot index
    ls = np.asarray(new_ls)
    # stale t=6 entries for the dead identity are gone ...
    assert ls[2, 1] == 9  # ... replaced by the fork origin's sighting at t
    np.testing.assert_array_equal(ls[[0, 1, 3], 1], [NEVER, NEVER, NEVER])
    # unrelated columns untouched
    np.testing.assert_array_equal(ls[:, 0], [0, 1, 2, 3])
    assert (ls[:, 2] == NEVER).all()


def test_missingperson_replacement_inherits_track():
    """MISSINGPERSON replacements carry the replaced walk's identity and
    keep its last_seen history (the whole point of the timeout rule)."""
    ws = _state([4, 0, 0], [True, False, False], track=[0, 1, 2])
    last_seen = jnp.asarray(
        [[7, 2, NEVER], [7, 2, NEVER], [7, 2, NEVER], [7, 2, NEVER], [7, 2, NEVER]],
        jnp.int32,
    )
    # walk 0 declares ids 1 and 2 missing -> two replacement forks from node 4
    ev = jnp.asarray([False, True, True])
    origins = jnp.asarray([4, 4, 4], jnp.int32)
    tracks = jnp.asarray([0, 1, 2], jnp.int32)
    parents = jnp.asarray([0, 0, 0], jnp.int32)
    new_ws, new_ls, n, fp = wlk.execute_forks(
        ws, last_seen, ev, origins, tracks, jnp.int32(12), parents
    )
    assert int(n) == 2
    np.testing.assert_array_equal(np.asarray(new_ws.active), [True, True, True])
    np.testing.assert_array_equal(np.asarray(new_ws.track), [0, 1, 2])
    np.testing.assert_array_equal(np.asarray(new_ws.pos), [4, 4, 4])
    np.testing.assert_array_equal(np.asarray(fp), [-1, 0, 0])
    # history untouched: replacements REUSE the replaced id's statistics
    np.testing.assert_array_equal(np.asarray(new_ls), np.asarray(last_seen))


def test_forks_execute_inside_jit_and_vmap():
    """The slot machinery stays shape-stable under jit+vmap (sweep path)."""

    def fork_once(key):
        pos = jax.random.randint(key, (6,), 0, 4, dtype=jnp.int32)
        ws = wlk.WalkState(
            pos=pos,
            active=jnp.asarray([True, True, True, False, False, False]),
            track=jnp.arange(6, dtype=jnp.int32),
        )
        ls = jnp.full((4, 6), 2, jnp.int32)
        ev = jnp.asarray([True, False, True, False, False, False])
        new_ws, new_ls, n, fp = wlk.execute_forks(ws, ls, ev, ws.pos, None, jnp.int32(5))
        return n, jnp.sum(new_ws.active)

    n, z = jax.jit(jax.vmap(fork_once))(jax.random.split(jax.random.key(0), 3))
    np.testing.assert_array_equal(np.asarray(n), [2, 2, 2])
    np.testing.assert_array_equal(np.asarray(z), [5, 5, 5])


# ---------------------------------------------------------------------------
# masked movement edge cases (the fused round's hop shares these paths)
# ---------------------------------------------------------------------------


def test_walk_holds_position_when_every_incident_edge_is_down():
    """A walk on a node whose incident edges are ALL down must hold
    position (not teleport, not die) — on both hop implementations."""
    # a triangle: every node has degree 2
    neighbors = jnp.asarray([[1, 2], [0, 2], [0, 1]], jnp.int32)
    degrees = jnp.asarray([2, 2, 2], jnp.int32)
    ws = _state([0, 1], [True, True])
    avail = jnp.asarray(
        [[False, False], [True, True], [True, True]]
    )  # node 0 isolated
    key = jax.random.key(3)
    moved = wlk.move_walks(ws, neighbors, degrees, key, avail)
    assert int(moved.pos[0]) == 0  # stranded walk held position
    assert int(moved.pos[1]) in (0, 2)  # free walk moved
    # row-restricted variant agrees bitwise (same uniforms)
    u = jax.random.uniform(key, (2,))
    got = wlk.move_walks_rows(
        ws, neighbors[ws.pos], u, avail[ws.pos], degrees.dtype
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(moved.pos))


def test_select_available_edge_zero_count_rank_select():
    """adeg == 0 rows: the returned count is 0 (callers hold position);
    the selected index stays in-bounds garbage, never out of range."""
    row_mask = jnp.asarray(
        [[False, False, False], [True, False, True], [False, True, False]]
    )
    u = jnp.asarray([0.99, 0.99, 0.0])
    adeg, sel = wlk.select_available_edge(row_mask, u, jnp.int32)
    np.testing.assert_array_equal(np.asarray(adeg), [0, 2, 1])
    assert 0 <= int(sel[0]) < 3  # garbage but in-bounds
    assert int(sel[1]) == 2  # u=0.99 over 2 available -> rank 1 -> slot 2
    assert int(sel[2]) == 1  # u=0.0 -> rank 0 -> the only available slot


def test_degree_one_node_under_link_churn():
    """A walk on a degree-1 node: moves over its single edge while the
    link is up, holds position while it is down, resumes after recovery
    — the fused and unfused hops agree at every phase."""
    # path graph 0 - 1 - 2; node 0 has degree 1 (padded slot at col 1)
    neighbors = jnp.asarray([[1, 0], [0, 2], [1, 0]], jnp.int32)
    degrees = jnp.asarray([1, 2, 1], jnp.int32)
    ws = _state([0], [True])
    key = jax.random.key(9)
    u = jax.random.uniform(key, (1,))
    for edge_up, want in [(True, 1), (False, 0), (True, 1)]:
        avail = jnp.asarray([[edge_up, False], [edge_up, True], [True, False]])
        moved = wlk.move_walks(ws, neighbors, degrees, key, avail)
        assert int(moved.pos[0]) == want, f"edge_up={edge_up}"
        got = wlk.move_walks_rows(
            ws, neighbors[ws.pos], u, avail[ws.pos], degrees.dtype
        )
        assert int(got[0]) == want, f"rows, edge_up={edge_up}"
