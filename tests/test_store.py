"""ResultStore + checkpoint atomicity (ISSUE 6 tentpole + satellite 1).

Contract under test:
  * a store-warm ``sweep_stacked`` in the SAME process returns the
    persisted pytree with zero new lowerings and zero new XLA compiles
    (the executable path is skipped entirely), bitwise equal to the
    cold run;
  * a store-warm re-run in a FRESH process (subprocess) is bitwise
    identical and compiles nothing;
  * keys are content hashes: changing the base key, seed count, a
    scenario leaf or the graph changes the key; identical inputs agree
    across Plan instances;
  * signature components without a stable encoding (a signature-less
    payload) refuse persistence with UnstableSignatureError;
  * corrupt / truncated entries degrade to misses, never errors;
  * checkpoint writes are atomic: a simulated crash mid-write never
    shadows the previous good snapshot (array file OR metadata).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.api import Experiment, ResultStore
from repro.api import plan as plan_mod
from repro.api.store import UnstableSignatureError, canonical_token
from repro.checkpoint import load_pytree, save_pytree
from repro.checkpoint import checkpoint as ckpt_mod
from repro.core import FailureConfig, ProtocolConfig
from repro.graphs import random_regular_graph
from repro.sweep import Scenario

N, W, Z0, STEPS, SEEDS, BASE_KEY = 24, 10, 5, 40, 2, 7


@pytest.fixture(scope="module")
def graph():
    return random_regular_graph(N, 4, seed=3)


def _pcfg(**kw):
    base = dict(algorithm="decafork", z0=Z0, max_walks=W, rt_bins=32,
                protocol_start=10, eps=1.8)
    base.update(kw)
    return ProtocolConfig(**base)


def _scenarios():
    return [
        Scenario("calm", _pcfg(), FailureConfig()),
        Scenario("burst", _pcfg(eps=2.1),
                 FailureConfig(burst_times=(15,), burst_sizes=(2,))),
    ]


def _exp(graph):
    return Experiment(graph=graph, steps=STEPS, outputs="scalars",
                      scenarios=_scenarios())


def _digest(tree) -> str:
    import hashlib

    h = hashlib.sha256()
    for leaf in jax.tree_util.tree_leaves(tree):
        a = np.ascontiguousarray(np.asarray(leaf))
        h.update(str(a.dtype).encode() + str(a.shape).encode() + a.tobytes())
    return h.hexdigest()


def _count_lowerings(monkeypatch):
    calls = []
    real = plan_mod._lower

    def counting(mode, signature):
        calls.append((mode, signature))
        return real(mode, signature)

    monkeypatch.setattr(plan_mod, "_lower", counting)
    return calls


# ---------------------------------------------------------------------------
# same-process warm hits
# ---------------------------------------------------------------------------


def test_store_warm_hit_skips_execution_and_matches(graph, tmp_path, monkeypatch):
    store = ResultStore(tmp_path / "store")
    plan = _exp(graph).plan()
    cold = plan.sweep_stacked(seeds=SEEDS, base_key=BASE_KEY, store=store)
    assert store.puts == 1 and store.misses == 1

    calls = _count_lowerings(monkeypatch)
    before = plan_mod.cache_stats()["xla_compiles"]
    warm = plan.sweep_stacked(seeds=SEEDS, base_key=BASE_KEY, store=store)
    assert store.hits == 1
    assert calls == []  # no new lowering...
    assert plan_mod.cache_stats()["xla_compiles"] == before  # ...no compile
    assert _digest(warm) == _digest(cold)  # bitwise round-trip

    # Plan.sweep threads the store through per-group stacked calls
    res = _exp(graph).plan().sweep(seeds=SEEDS, base_key=BASE_KEY, store=store)
    assert store.hits == 2
    assert res.names == ("calm", "burst")


def test_store_key_is_content_addressed(graph):
    plan = _exp(graph).plan()
    store = ResultStore("/tmp/unused-keys-only")
    from repro.sweep.scenario import stack_configs

    scen = _scenarios()
    stacked = stack_configs(scen)
    lens = (1, 0)
    sig = plan._signature("sweep", scen[0].pcfg, lens)
    key = lambda **kw: store.sweep_key(
        kw.get("sig", sig),
        kw.get("graph", graph),
        kw.get("cfg", stacked),
        kw.get("seeds", SEEDS),
        jax.random.key(kw.get("base_key", BASE_KEY)),
    )
    base = key()
    assert key() == base  # deterministic
    assert key(seeds=SEEDS + 1) != base
    assert key(base_key=BASE_KEY + 1) != base
    other = stack_configs([
        Scenario("calm", _pcfg(eps=1.81), scen[0].fcfg), scen[1]
    ])
    assert key(cfg=other) != base  # a single traced leaf changes the key
    g2 = random_regular_graph(N, 4, seed=4)
    assert key(graph=g2) != base


def test_unstable_payload_refuses_persistence(graph, tmp_path):
    from repro.core.payload import Payload

    class Anon(Payload):  # no signature(): identity-hashed
        pass

    with pytest.raises(UnstableSignatureError, match="Payload.signature"):
        canonical_token(plan_mod.payload_key(Anon()))
    exp = Experiment(graph=graph, steps=STEPS, scenarios=_scenarios(),
                     payload=Anon())
    with pytest.raises(UnstableSignatureError):
        exp.plan().sweep_stacked(seeds=SEEDS, store=ResultStore(tmp_path))


def test_corrupt_entries_degrade_to_misses(graph, tmp_path):
    store = ResultStore(tmp_path / "store")
    plan = _exp(graph).plan()
    plan.sweep_stacked(seeds=SEEDS, base_key=BASE_KEY, store=store)
    (key,) = [
        f[: -len(".meta.json")]
        for sub in os.listdir(store.root)
        for f in os.listdir(os.path.join(store.root, sub))
        if f.endswith(".meta.json")
    ]
    base, npz, meta = store._paths(key)
    assert key in store

    with open(npz, "wb") as f:
        f.write(b"not a zipfile")
    assert store.get(key) is None  # corrupt npz: miss, not error

    plan.sweep_stacked(seeds=SEEDS, base_key=BASE_KEY, store=store)  # re-put
    os.remove(meta)
    assert key not in store
    assert store.get(key) is None  # half-missing entry: miss


# ---------------------------------------------------------------------------
# fresh-process warm hit (the cross-process claim)
# ---------------------------------------------------------------------------

_CHILD = textwrap.dedent(
    """
    import json, sys
    import jax, numpy as np
    from repro.api import Experiment, ResultStore, cache_stats
    from repro.core import FailureConfig, ProtocolConfig
    from repro.graphs import random_regular_graph
    from repro.sweep import Scenario

    N, W, Z0, STEPS, SEEDS, BASE_KEY = 24, 10, 5, 40, 2, 7

    def _pcfg(**kw):
        base = dict(algorithm="decafork", z0=Z0, max_walks=W, rt_bins=32,
                    protocol_start=10, eps=1.8)
        base.update(kw)
        return ProtocolConfig(**base)

    scenarios = [
        Scenario("calm", _pcfg(), FailureConfig()),
        Scenario("burst", _pcfg(eps=2.1),
                 FailureConfig(burst_times=(15,), burst_sizes=(2,))),
    ]
    graph = random_regular_graph(N, 4, seed=3)
    plan = Experiment(graph=graph, steps=STEPS, outputs="scalars",
                      scenarios=scenarios).plan()
    store = ResultStore.from_env()
    result = plan.sweep_stacked(seeds=SEEDS, base_key=BASE_KEY, store=store)

    import hashlib
    h = hashlib.sha256()
    for leaf in jax.tree_util.tree_leaves(result):
        a = np.ascontiguousarray(np.asarray(leaf))
        h.update(str(a.dtype).encode() + str(a.shape).encode() + a.tobytes())
    print(json.dumps({
        "digest": h.hexdigest(),
        "hits": store.hits,
        "misses": store.misses,
        "xla_compiles": cache_stats()["xla_compiles"],
    }))
    """
)


@pytest.mark.slow
def test_fresh_process_store_hit_bitwise_zero_compiles(graph, tmp_path):
    """The headline persistence claim: a second PROCESS re-running the
    same study answers from disk — bitwise identical leaves, zero XLA
    compiles in the warm child."""
    store = ResultStore(tmp_path / "store")
    cold = _exp(graph).plan().sweep_stacked(
        seeds=SEEDS, base_key=BASE_KEY, store=store
    )
    env = dict(os.environ)
    env["REPRO_RESULT_STORE"] = store.root
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
    env["PYTHONPATH"] = src + os.pathsep * bool(env.get("PYTHONPATH")) + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", _CHILD], env=env, capture_output=True,
        text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr
    report = json.loads(out.stdout.strip().splitlines()[-1])
    assert report["hits"] == 1 and report["misses"] == 0
    assert report["xla_compiles"] == 0  # the child never compiled anything
    assert report["digest"] == _digest(cold)  # bitwise across processes


# ---------------------------------------------------------------------------
# checkpoint atomicity (satellite 1)
# ---------------------------------------------------------------------------


def _snap(path):
    with open(path, "rb") as f:
        return f.read()


def test_partial_write_never_shadows_previous_snapshot(tmp_path, monkeypatch):
    """A writer that dies mid-write (here: np.savez fails after emitting
    partial bytes) leaves the previous snapshot byte-identical and
    loadable, and leaves no temp debris behind."""
    path = str(tmp_path / "ckpt")
    tree = {"a": np.arange(6, dtype=np.float32), "b": np.ones((2, 3))}
    save_pytree(path, tree, metadata={"step": 1})
    good_npz = _snap(path + ".npz")
    good_meta = _snap(path + ".meta.json")

    def dying_savez(f, **arrays):
        f.write(b"PARTIAL GARBAGE")
        raise OSError("disk full")

    monkeypatch.setattr(ckpt_mod.np, "savez", dying_savez)
    with pytest.raises(OSError, match="disk full"):
        save_pytree(path, {"a": np.zeros(6, np.float32),
                           "b": np.zeros((2, 3))}, metadata={"step": 2})
    monkeypatch.undo()

    assert _snap(path + ".npz") == good_npz  # old snapshot intact...
    assert _snap(path + ".meta.json") == good_meta
    assert not [f for f in os.listdir(tmp_path) if ".tmp-" in f]  # no debris
    restored = load_pytree(path, tree)  # ...and still loadable
    np.testing.assert_array_equal(restored["a"], tree["a"])
    np.testing.assert_array_equal(restored["b"], tree["b"])


def test_partial_metadata_write_keeps_previous_meta(tmp_path, monkeypatch):
    """Array write succeeding but the metadata write dying must not
    leave a torn .meta.json either."""
    path = str(tmp_path / "ckpt")
    save_pytree(path, {"x": np.arange(3)}, metadata={"v": 1})
    good_meta = _snap(path + ".meta.json")

    real = ckpt_mod._atomic_write

    def dying_meta(p, write_fn):
        if p.endswith(".meta.json"):
            def torn(f):
                f.write(b'{"v":')
                raise OSError("crash")

            return real(p, torn)
        return real(p, write_fn)

    monkeypatch.setattr(ckpt_mod, "_atomic_write", dying_meta)
    with pytest.raises(OSError, match="crash"):
        save_pytree(path, {"x": np.arange(3)}, metadata={"v": 2})
    monkeypatch.undo()
    assert _snap(path + ".meta.json") == good_meta
    json.loads(_snap(path + ".meta.json"))  # parses


def test_atomic_write_replaces_only_on_success(tmp_path):
    from repro.checkpoint.checkpoint import _atomic_write

    path = str(tmp_path / "f.bin")
    _atomic_write(path, lambda f: f.write(b"v1"))
    assert _snap(path) == b"v1"
    _atomic_write(path, lambda f: f.write(b"v2-longer"))
    assert _snap(path) == b"v2-longer"
    with pytest.raises(RuntimeError):
        def die(f):
            f.write(b"half")
            raise RuntimeError("boom")

        _atomic_write(path, die)
    assert _snap(path) == b"v2-longer"
    assert os.listdir(tmp_path) == ["f.bin"]
