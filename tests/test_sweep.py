"""Sweep engine on the Plan surface: batched scenarios are bitwise the
per-scenario ensembles, grouping/stacking behave, placement dispatches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Experiment, Placement
from repro.api import plan as plan_mod
from repro.core import FailureConfig, ProtocolConfig
from repro.graphs import random_regular_graph
from repro.sweep import (
    Scenario,
    group_scenarios,
    stack_configs,
)

N, W, Z0, STEPS, SEEDS = 24, 10, 5, 60, 2


@pytest.fixture(scope="module")
def graph():
    return random_regular_graph(N, 4, seed=3)


def _pcfg(alg, impl, **kw):
    base = dict(
        algorithm=alg, z0=Z0, max_walks=W, rt_bins=32, protocol_start=10,
        estimator_impl=impl,
    )
    base.update(kw)
    return ProtocolConfig(**base)


def _fcfgs():
    return [
        FailureConfig(burst_times=(20,), burst_sizes=(2,)),
        FailureConfig(burst_times=(25,), burst_sizes=(1,), p_fail=0.002),
        FailureConfig(
            burst_times=(30,), burst_sizes=(2,),
            byzantine_node=1, p_byz=0.01, byz_start_time=15,
        ),
    ]


def _sweep_stacked(graph, scenarios, *, seeds=SEEDS, base_key=0, **kw):
    return Experiment(graph=graph, scenarios=scenarios, steps=STEPS,
                      **kw).plan().sweep_stacked(seeds=seeds, base_key=base_key)


def _ensemble(graph, pcfg, fcfg, *, seeds=SEEDS, base_key=0):
    return Experiment(graph=graph, protocol=pcfg, failures=fcfg,
                      steps=STEPS).ensemble(seeds, base_key=base_key)


def _assert_outputs_equal(ref, got, label):
    for name, a, b in zip(ref._fields, ref, got):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=f"{label}: field {name}"
        )


@pytest.mark.slow
@pytest.mark.parametrize("impl", ["gather", "compare"])
@pytest.mark.parametrize("alg", ["decafork", "decafork+", "missingperson", "none"])
def test_sweep_matches_ensemble(graph, alg, impl):
    """Plan.sweep_stacked over a scenario stack == per-scenario
    Plan.ensemble, bitwise."""
    eps_grid = (1.4, 1.8, 2.2)
    scenarios = [
        (_pcfg(alg, impl, eps=e, eps2=5.0 + e, eps_mp=15.0 + 10 * i), f)
        for i, (e, f) in enumerate(zip(eps_grid, _fcfgs()))
    ]
    out = _sweep_stacked(graph, scenarios, base_key=7)
    assert out.z.shape == (len(scenarios), SEEDS, STEPS)
    for i, (pc, fc) in enumerate(scenarios):
        ref = _ensemble(graph, pc, fc, base_key=7)
        got = jax.tree_util.tree_map(lambda x: x[i], out)
        _assert_outputs_equal(ref, got, f"{alg}/{impl}/scenario{i}")


def test_sweep_single_compilation(graph):
    """>= 8 scenarios x >= 4 seeds execute as ONE jit-compiled call, and
    numeric grid changes reuse the cached executable."""
    fcs = [
        FailureConfig(burst_times=(20,), burst_sizes=(2,)),
        FailureConfig(burst_times=(25,), burst_sizes=(2,), p_fail=0.001),
    ]
    scenarios = [
        (_pcfg("decafork", "gather", eps=e), fc)
        for e in (1.5, 1.8, 2.1, 2.4)
        for fc in fcs
    ]
    assert len(scenarios) >= 8
    sweep_compiles = lambda: plan_mod.cache_stats()["by_mode"].get("sweep", 0)
    before = sweep_compiles()
    out = _sweep_stacked(graph, scenarios, seeds=4, base_key=11)
    jax.block_until_ready(out.z)
    after_first = sweep_compiles()
    assert after_first <= before + 1  # one (possibly pre-cached) program
    assert out.z.shape == (8, 4, STEPS)
    # and that one program reproduces every per-scenario ensemble bitwise
    for i, (pc, fc) in enumerate(scenarios):
        ref = _ensemble(graph, pc, fc, seeds=4, base_key=11)
        got = jax.tree_util.tree_map(lambda x: x[i], out)
        _assert_outputs_equal(ref, got, f"scenario{i}")
    # numeric variations reuse the same program: a second grid, same shapes
    more = [
        (_pcfg("decafork", "gather", eps=e), fcs[0]) for e in np.linspace(1.2, 2.6, 8)
    ]
    _sweep_stacked(graph, more, seeds=4, base_key=13)
    assert sweep_compiles() == after_first


@pytest.mark.slow
def test_burst_padding_batches_unequal_schedules(graph):
    """Scenarios with different burst counts co-batch via pad_bursts."""
    scenarios = [
        (_pcfg("decafork", "gather", eps=1.8),
         FailureConfig(burst_times=(15, 35), burst_sizes=(2, 1))),
        (_pcfg("decafork", "gather", eps=2.0),
         FailureConfig(burst_times=(25,), burst_sizes=(2,))),
    ]
    out = _sweep_stacked(graph, scenarios, base_key=5)
    for i, (pc, fc) in enumerate(scenarios):
        ref = _ensemble(graph, pc, fc, base_key=5)
        np.testing.assert_array_equal(np.asarray(out.z[i]), np.asarray(ref.z))


def test_stack_rejects_mixed_static_structure():
    a = _pcfg("decafork", "gather")
    b = _pcfg("missingperson", "gather")
    fc = FailureConfig()
    with pytest.raises(ValueError, match="static structures"):
        stack_configs([(a, fc), (b, fc)])
    # fork_prob None vs value is a structure change, too
    c = _pcfg("decafork", "gather", fork_prob=0.2)
    with pytest.raises(ValueError, match="static structures"):
        stack_configs([(a, fc), (c, fc)])


def test_sweep_stacked_rejects_mixed_structures(graph):
    """Plan.sweep_stacked is the single-structure entry: mixed lists must
    go through Plan.sweep (which groups them)."""
    fc = FailureConfig()
    scenarios = [
        (_pcfg("decafork", "gather"), fc),
        (_pcfg("missingperson", "gather"), fc),
    ]
    with pytest.raises(ValueError, match="static structures"):
        _sweep_stacked(graph, scenarios)


@pytest.mark.slow
def test_sweep_mixes_groups(graph):
    """Mixed algorithms group into per-structure batches, order preserved
    — Plan.groups exposes the partition, Plan.sweep runs it."""
    fc = FailureConfig(burst_times=(20,), burst_sizes=(2,))
    scenarios = [
        Scenario("dfk/1.6", _pcfg("decafork", "gather", eps=1.6), fc),
        Scenario("mp", _pcfg("missingperson", "gather", eps_mp=25.0), fc),
        Scenario("dfk/2.0", _pcfg("decafork", "gather", eps=2.0), fc),
        Scenario("none", _pcfg("none", "gather"), FailureConfig()),
    ]
    exp = Experiment(graph=graph, scenarios=scenarios, steps=STEPS)
    plan = exp.plan()
    assert [idxs for _, idxs in plan.groups()] == [[0, 2], [1], [3]]
    assert plan.groups() == group_scenarios(scenarios)
    res = plan.sweep(seeds=SEEDS, base_key=3)
    assert res.names == ("dfk/1.6", "mp", "dfk/2.0", "none")
    for s, out in zip(scenarios, res.outputs):
        ref = _ensemble(graph, s.pcfg, s.fcfg, base_key=3)
        _assert_outputs_equal(ref, out, s.name)
    assert res["mp"] is res.outputs[1]


def test_placement_policies_agree_on_single_device(graph):
    """Explicit sharded placement is a correctness no-op on 1 device."""
    fc = FailureConfig(burst_times=(20,), burst_sizes=(2,))
    scenarios = [(_pcfg("decafork", "gather", eps=e), fc) for e in (1.6, 2.0)]
    a = _sweep_stacked(graph, scenarios, base_key=9, placement="sharded")
    b = _sweep_stacked(graph, scenarios, base_key=9, placement="local")
    np.testing.assert_array_equal(np.asarray(a.z), np.asarray(b.z))


def test_placement_dispatch(graph, monkeypatch):
    """The Plan consults exactly its Placement policy: 'local' never
    touches device placement, 'auto'/'sharded' go through place()."""
    import repro.api.placement as plc

    calls = []
    real = plc.Placement.place

    def spy(self, pcfgs, fcfgs, n_scenarios):
        calls.append(self.policy)
        return real(self, pcfgs, fcfgs, n_scenarios)

    monkeypatch.setattr(plc.Placement, "place", spy)
    fc = FailureConfig(burst_times=(20,), burst_sizes=(2,))
    scenarios = [(_pcfg("decafork", "gather", eps=e), fc) for e in (1.6, 2.0)]

    def run(placement):
        return Experiment(
            graph=graph, scenarios=scenarios, steps=5, placement=placement,
        ).plan().sweep_stacked(seeds=1)

    run("local")
    run(None)  # resolves to auto
    run(Placement.SHARDED)
    assert calls == ["local", "auto", "sharded"]
    with pytest.raises(ValueError, match="placement policy"):
        Placement("everywhere")
    with pytest.raises(TypeError, match="placement"):
        Experiment(graph=graph, scenarios=scenarios, steps=5, placement=7)


def test_placement_from_legacy_tristate():
    """Placement.from_sharded maps the legacy tri-state by identity:
    bool-equal ints must not silently alias into the wrong policy."""
    assert Placement.from_sharded(None) is Placement.AUTO
    assert Placement.from_sharded(True) is Placement.SHARDED
    assert Placement.from_sharded(False) is Placement.LOCAL
    for bad in (0, 1, "auto"):
        with pytest.raises(TypeError, match="sharded"):
            Placement.from_sharded(bad)


def test_traced_config_leaves_do_not_recompile(graph):
    """Numeric knobs are traced: one Plan executable serves a whole
    epsilon x failure-rate grid of ensembles (the pre-sweep per-curve
    compile storm is gone)."""
    first = None
    for e in (1.5, 1.9, 2.3):
        for pf in (0.0, 0.002):
            _ensemble(
                graph,
                _pcfg("decafork", "gather", eps=e),
                FailureConfig(burst_times=(20,), burst_sizes=(2,), p_fail=pf),
            )
            if first is None:
                first = plan_mod.cache_stats()["xla_compiles"]
    # every (eps, p_fail) combination after the first reused its program
    assert plan_mod.cache_stats()["xla_compiles"] == first
