"""Sweep engine: batched scenarios are bitwise the per-scenario ensembles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FailureConfig, ProtocolConfig, run_ensemble
from repro.core import simulator as sim
from repro.core.simulator import run_sweep
from repro.graphs import random_regular_graph
from repro.sweep import (
    Scenario,
    group_scenarios,
    run_scenarios,
    stack_configs,
)

N, W, Z0, STEPS, SEEDS = 24, 10, 5, 60, 2


@pytest.fixture(scope="module")
def graph():
    return random_regular_graph(N, 4, seed=3)


def _pcfg(alg, impl, **kw):
    base = dict(
        algorithm=alg, z0=Z0, max_walks=W, rt_bins=32, protocol_start=10,
        estimator_impl=impl,
    )
    base.update(kw)
    return ProtocolConfig(**base)


def _fcfgs():
    return [
        FailureConfig(burst_times=(20,), burst_sizes=(2,)),
        FailureConfig(burst_times=(25,), burst_sizes=(1,), p_fail=0.002),
        FailureConfig(
            burst_times=(30,), burst_sizes=(2,),
            byzantine_node=1, p_byz=0.01, byz_start_time=15,
        ),
    ]


def _assert_outputs_equal(ref, got, label):
    for name, a, b in zip(ref._fields, ref, got):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=f"{label}: field {name}"
        )


@pytest.mark.slow
@pytest.mark.parametrize("impl", ["gather", "compare"])
@pytest.mark.parametrize("alg", ["decafork", "decafork+", "missingperson", "none"])
def test_sweep_matches_ensemble(graph, alg, impl):
    """run_sweep over a scenario stack == per-scenario run_ensemble, bitwise."""
    eps_grid = (1.4, 1.8, 2.2)
    scenarios = [
        (_pcfg(alg, impl, eps=e, eps2=5.0 + e, eps_mp=15.0 + 10 * i), f)
        for i, (e, f) in enumerate(zip(eps_grid, _fcfgs()))
    ]
    out = run_sweep(graph, scenarios, steps=STEPS, seeds=SEEDS, base_key=7)
    assert out.z.shape == (len(scenarios), SEEDS, STEPS)
    for i, (pc, fc) in enumerate(scenarios):
        ref = run_ensemble(graph, pc, fc, steps=STEPS, seeds=SEEDS, base_key=7)
        got = jax.tree_util.tree_map(lambda x: x[i], out)
        _assert_outputs_equal(ref, got, f"{alg}/{impl}/scenario{i}")


def test_sweep_single_compilation(graph):
    """>= 8 scenarios x >= 4 seeds execute as ONE jit-compiled call."""
    fcs = [
        FailureConfig(burst_times=(20,), burst_sizes=(2,)),
        FailureConfig(burst_times=(25,), burst_sizes=(2,), p_fail=0.001),
    ]
    scenarios = [
        (_pcfg("decafork", "gather", eps=e), fc)
        for e in (1.5, 1.8, 2.1, 2.4)
        for fc in fcs
    ]
    assert len(scenarios) >= 8
    before = sim._run_sweep._cache_size()
    out = run_sweep(graph, scenarios, steps=STEPS, seeds=4, base_key=11)
    jax.block_until_ready(out.z)
    after_first = sim._run_sweep._cache_size()
    assert after_first == before + 1  # one compiled program for all 8x4
    assert out.z.shape == (8, 4, STEPS)
    # and that one program reproduces every per-scenario ensemble bitwise
    for i, (pc, fc) in enumerate(scenarios):
        ref = run_ensemble(graph, pc, fc, steps=STEPS, seeds=4, base_key=11)
        got = jax.tree_util.tree_map(lambda x: x[i], out)
        _assert_outputs_equal(ref, got, f"scenario{i}")
    # numeric variations reuse the same program: a second grid, same shapes
    more = [
        (_pcfg("decafork", "gather", eps=e), fcs[0]) for e in np.linspace(1.2, 2.6, 8)
    ]
    run_sweep(graph, more, steps=STEPS, seeds=4, base_key=13)
    assert sim._run_sweep._cache_size() == after_first


@pytest.mark.slow
def test_burst_padding_batches_unequal_schedules(graph):
    """Scenarios with different burst counts co-batch via pad_bursts."""
    scenarios = [
        (_pcfg("decafork", "gather", eps=1.8),
         FailureConfig(burst_times=(15, 35), burst_sizes=(2, 1))),
        (_pcfg("decafork", "gather", eps=2.0),
         FailureConfig(burst_times=(25,), burst_sizes=(2,))),
    ]
    out = run_sweep(graph, scenarios, steps=STEPS, seeds=SEEDS, base_key=5)
    for i, (pc, fc) in enumerate(scenarios):
        ref = run_ensemble(graph, pc, fc, steps=STEPS, seeds=SEEDS, base_key=5)
        np.testing.assert_array_equal(np.asarray(out.z[i]), np.asarray(ref.z))


def test_stack_rejects_mixed_static_structure():
    a = _pcfg("decafork", "gather")
    b = _pcfg("missingperson", "gather")
    fc = FailureConfig()
    with pytest.raises(ValueError, match="static structures"):
        stack_configs([(a, fc), (b, fc)])
    # fork_prob None vs value is a structure change, too
    c = _pcfg("decafork", "gather", fork_prob=0.2)
    with pytest.raises(ValueError, match="static structures"):
        stack_configs([(a, fc), (c, fc)])


@pytest.mark.slow
def test_run_scenarios_mixes_groups(graph):
    """Mixed algorithms group into per-structure batches, order preserved."""
    fc = FailureConfig(burst_times=(20,), burst_sizes=(2,))
    scenarios = [
        Scenario("dfk/1.6", _pcfg("decafork", "gather", eps=1.6), fc),
        Scenario("mp", _pcfg("missingperson", "gather", eps_mp=25.0), fc),
        Scenario("dfk/2.0", _pcfg("decafork", "gather", eps=2.0), fc),
        Scenario("none", _pcfg("none", "gather"), FailureConfig()),
    ]
    groups = group_scenarios(scenarios)
    assert [idxs for _, idxs in groups] == [[0, 2], [1], [3]]
    res = run_scenarios(graph, scenarios, steps=STEPS, seeds=SEEDS, base_key=3)
    assert res.names == ("dfk/1.6", "mp", "dfk/2.0", "none")
    for s, out in zip(scenarios, res.outputs):
        ref = run_ensemble(graph, s.pcfg, s.fcfg, steps=STEPS, seeds=SEEDS, base_key=3)
        _assert_outputs_equal(ref, out, s.name)
    assert res["mp"] is res.outputs[1]


def test_sharded_path_single_device(graph):
    """explicit sharding placement is a correctness no-op on 1 device."""
    fc = FailureConfig(burst_times=(20,), burst_sizes=(2,))
    scenarios = [(_pcfg("decafork", "gather", eps=e), fc) for e in (1.6, 2.0)]
    a = run_sweep(graph, scenarios, steps=STEPS, seeds=SEEDS, base_key=9, sharded=True)
    b = run_sweep(graph, scenarios, steps=STEPS, seeds=SEEDS, base_key=9, sharded=False)
    np.testing.assert_array_equal(np.asarray(a.z), np.asarray(b.z))


def test_sharded_tristate_dispatch(graph, monkeypatch):
    """The sharded knob is an explicit tri-state: None auto-places
    (explicit=False), True demands placement (explicit=True), False
    never touches device placement, and anything else is a TypeError."""
    import repro.sweep.engine as eng

    calls = []

    def spy(pcfgs, fcfgs, n_scenarios, *, explicit=False):
        calls.append(explicit)
        return pcfgs, fcfgs

    monkeypatch.setattr(eng, "maybe_shard_scenarios", spy)
    fc = FailureConfig(burst_times=(20,), burst_sizes=(2,))
    scenarios = [(_pcfg("decafork", "gather", eps=e), fc) for e in (1.6, 2.0)]

    run_sweep(graph, scenarios, steps=5, seeds=1, sharded=False)
    assert calls == []  # explicit opt-out: placement never consulted
    run_sweep(graph, scenarios, steps=5, seeds=1, sharded=None)
    assert calls == [False]  # auto mode
    run_sweep(graph, scenarios, steps=5, seeds=1, sharded=True)
    assert calls == [False, True]  # explicit demand
    with pytest.raises(TypeError, match="sharded"):
        run_sweep(graph, scenarios, steps=5, seeds=1, sharded="auto")
    # bool-equal ints must not silently alias into the wrong path
    for bad in (0, 1):
        with pytest.raises(TypeError, match="sharded"):
            run_sweep(graph, scenarios, steps=5, seeds=1, sharded=bad)
    assert calls == [False, True]  # nothing leaked through


def test_traced_config_leaves_do_not_recompile(graph):
    """Numeric knobs are traced: run_ensemble reuses one program across an
    epsilon grid and across failure rates (the pre-sweep per-curve compile
    storm is gone)."""
    fc = FailureConfig(burst_times=(20,), burst_sizes=(2,))
    first = None
    for e in (1.5, 1.9, 2.3):
        for pf in (0.0, 0.002):
            run_ensemble(
                graph,
                _pcfg("decafork", "gather", eps=e),
                FailureConfig(burst_times=(20,), burst_sizes=(2,), p_fail=pf),
                steps=STEPS,
                seeds=SEEDS,
            )
            if first is None:
                first = sim._run_ensemble._cache_size()
    # every (eps, p_fail) combination after the first reused its program
    assert sim._run_ensemble._cache_size() == first
