"""The zoo (PR 8): attacks x walk-variant defenses as registry scenarios.

Contract under test:
  * golden no-op parity — explicitly-neutral zoo knobs (uniform variant,
    zero jump probability, empty attack schedules) reproduce the PR-1
    golden trajectories bitwise: the zoo costs the default program
    nothing;
  * oracle parity — every zoo attack runs bitwise-identically under the
    fused round and the literal unfused stage sequence, over churny
    trajectories, on tile-multiple AND non-tile-multiple n;
  * attack semantics — multi-Pac-Man extinction, mobile Pac-Man hopping
    along live edges, scheduled edge cuts severing exactly the
    cross-partition edges and confining walks;
  * defense semantics — jump teleports across a partition, biased walks
    honor the p/q weights, Bloom walks avoid marked neighbors;
  * sweep integration — zoo rows group/pad correctly: a mixed sweep is
    bitwise each row's private ensemble, schedules pad with the
    never-fires fill;
  * compile-cache accounting — each variant's static tag opens exactly
    one cache slot, structurally-equal zoo configs share slots and hash
    to a stable ResultStore key;
  * observability — ``round_impl_decision`` / ``Plan.round_decisions``
    name the gate that sends a config to the stage sequence, decided on
    the group's PADDED schedule widths.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Experiment, ResultStore
from repro.api import plan as plan_mod
from repro.core import FailureConfig, ProtocolConfig
from repro.core import failures as flr
from repro.core import simulator as sim
from repro.core import walkers as wlk
from repro.graphs import (
    availability,
    community_graph,
    init_graph_state,
    mirror_indices,
    random_regular_graph,
    ring_graph,
)
from repro.sweep import Scenario
from repro.sweep.scenario import group_scenarios, stack_configs
from repro.zoo import attack, defense, zoo_scenarios
from repro.zoo.variants import _bloom_hashes

GOLDEN = os.path.join(
    os.path.dirname(__file__), "golden", "pr1_trajectories.json"
)

# must mirror tests/golden/capture_pr1.py
N, DEG, GRAPH_SEED = 24, 4, 3
W, Z0, STEPS, SEEDS, BASE_KEY = 10, 5, 60, 2, 7
HALF = N // 2


@pytest.fixture(scope="module")
def graph():
    return random_regular_graph(N, DEG, seed=GRAPH_SEED)


@pytest.fixture(scope="module")
def cgraph():
    return community_graph(N, k_bridges=2, seed=GRAPH_SEED)


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN) as f:
        return json.load(f)


def _pcfg(alg="decafork", **kw):
    base = dict(
        algorithm=alg, z0=Z0, max_walks=W, rt_bins=32, protocol_start=10
    )
    base.update(kw)
    return ProtocolConfig(**base)


def _bitwise(a, b, label):
    for name, x, y in zip(a._fields, a, b):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y), err_msg=f"{label}: field {name}"
        )


# ---------------------------------------------------------------------------
# golden no-op parity: neutral zoo knobs == the pre-zoo program, bitwise
# ---------------------------------------------------------------------------


def test_neutral_zoo_knobs_are_bitwise_pr1_golden(graph, golden):
    """Every zoo knob at its explicit neutral value — uniform variant,
    p_jump=0, unit biases, no extra Pac-Men, no cuts — reproduces the
    PR-1 golden ensemble bitwise (outputs='full': every recorded field)."""
    pcfg = _pcfg(
        "decafork", eps=1.8,
        walk_variant="uniform", p_jump=0.0, bias_p=1.0, bias_q=1.0,
        bloom_bits=64,
    )
    fcfg = FailureConfig(
        burst_times=(20,), burst_sizes=(2,),
        pacman_nodes=(), pacman_mobile=False, pacman_hop_prob=1.0,
        edge_cut_times=(), edge_cut_thresholds=(),
    )
    outs = Experiment(
        graph=graph, protocol=pcfg, failures=fcfg, steps=STEPS,
        outputs="full",
    ).ensemble(SEEDS, base_key=BASE_KEY)
    ref = golden["ensemble"]["decafork/burst"]
    for name, arr in zip(outs._fields, outs):
        got = np.asarray(arr)
        np.testing.assert_array_equal(
            got, np.asarray(ref[name], dtype=got.dtype),
            err_msg=f"neutral zoo: field {name}",
        )


def test_uniform_defense_preset_is_empty():
    """The 'uniform' defense overrides nothing: applying it to any base
    protocol is the identity (so the default program stays untouched)."""
    assert defense("uniform") == {}
    base = _pcfg("decafork+", eps=1.6, eps2=6.0)
    assert dataclasses.replace(base, **defense("uniform")) == base


# ---------------------------------------------------------------------------
# fused vs unfused oracle, per attack, on tile- and non-tile-multiple n
# ---------------------------------------------------------------------------

_CHURN = dict(
    burst_times=(30,), burst_sizes=(2,),
    p_node_fail=0.02, p_node_recover=0.3, node_fail_start=10,
    p_link_fail=0.05, p_link_recover=0.4, link_fail_start=10,
)


def _attack_under_churn(name, n):
    half = n // 2
    builders = {
        "mobile_pacman": lambda: attack(
            "mobile_pacman", node=0, hop_prob=0.7, start=5, **_CHURN
        ),
        "multi_pacman": lambda: attack(
            "multi_pacman", nodes=(0, half), start=5, **_CHURN
        ),
        "edge_cut": lambda: attack(
            "edge_cut", time=10, threshold=half, **_CHURN
        ),
    }
    return builders[name]()


@pytest.mark.parametrize("attack_name",
                         ["mobile_pacman", "multi_pacman", "edge_cut"])
@pytest.mark.parametrize("n", [19, N])
def test_zoo_attacks_fused_bitwise_unfused(attack_name, n, graph, cgraph):
    """Each zoo attack under heavy topology churn: the fused round must
    be bitwise the literal stage sequence on every recorded output —
    n=19 exercises the non-tile-multiple path, n=24 the community graph."""
    g = random_regular_graph(19, 4, seed=2) if n == 19 else cgraph
    fcfg = _attack_under_churn(attack_name, n)
    outs = {}
    for rimpl in ("fused", "unfused"):
        pcfg = _pcfg(
            "decafork+", eps=1.4, eps2=6.0, max_walks=8, z0=4,
            protocol_start=15, estimator_impl="gather", round_impl=rimpl,
        )
        _, outs[rimpl] = Experiment(
            graph=g, protocol=pcfg, failures=fcfg, steps=STEPS,
            outputs="full",
        ).run(key=5)
    _bitwise(outs["fused"], outs["unfused"], f"{attack_name}/n={n}")


@pytest.mark.parametrize("variant", ["jump", "biased", "bloom"])
def test_variant_fallback_is_bitwise_the_stage_sequence(variant):
    """A non-uniform variant with round_impl='fused' requested must take
    the validated fallback: bitwise the explicit unfused stage sequence
    (on a non-tile-multiple n, under churn + an attack)."""
    g = random_regular_graph(19, 4, seed=2)
    fcfg = _attack_under_churn("multi_pacman", 19)
    outs = {}
    for rimpl in ("fused", "unfused"):
        pcfg = _pcfg(
            "decafork", eps=1.8, z0=4, max_walks=8, protocol_start=15,
            round_impl=rimpl, **defense(variant),
        )
        assert not sim.round_impl_decision(pcfg, fcfg).fused
        _, outs[rimpl] = Experiment(
            graph=g, protocol=pcfg, failures=fcfg, steps=STEPS,
            outputs="full",
        ).run(key=5)
    _bitwise(outs["fused"], outs["unfused"], f"{variant} fallback")


# ---------------------------------------------------------------------------
# attack semantics
# ---------------------------------------------------------------------------


def test_multi_pacman_extinguishes_unregulated_walks(graph):
    """Several absorbing nodes at once: the unregulated population only
    shrinks, and dies out."""
    fcfg = attack("multi_pacman", nodes=(0, 5, 9), start=0)
    assert fcfg.n_pacman == 2  # ids beyond the first ride the schedule
    _, outs = Experiment(
        graph=graph, protocol=_pcfg("none"), failures=fcfg, steps=2000
    ).run(key=3)
    z = np.asarray(outs.z)
    assert z[-1] == 0
    assert (np.diff(z) <= 0).all()


def test_mobile_pacman_hops_along_live_edges(graph):
    """With hop_prob=1 the Pac-Man moves every armed round, always to a
    neighbor of its current node (the movement primitive's edge set)."""
    neighbors = jnp.asarray(graph.neighbors)
    degrees = jnp.asarray(graph.degrees)
    gs = init_graph_state(graph.n, graph.max_degree)
    avail = availability(gs, neighbors, degrees)
    fcfg = attack("mobile_pacman", node=0, hop_prob=1.0, start=0)
    assert fcfg.pacman_mobile
    pac = flr.initial_pacman_positions(fcfg)
    nbrs, degs = np.asarray(graph.neighbors), np.asarray(graph.degrees)
    for t in range(15):
        new = flr.step_mobile_pacman(
            pac, jnp.int32(t), fcfg, jax.random.key(t), neighbors, degrees,
            avail,
        )
        old_p, new_p = int(pac[0]), int(new[0])
        assert new_p != old_p  # hop_prob=1, degree>0: always moves
        assert new_p in nbrs[old_p, : degs[old_p]].tolist()
        pac = new


def test_mobile_pacman_hop_prob_zero_matches_static_pacman(graph):
    """hop_prob=0 never moves (the final carry proves it), and the whole
    trajectory is bitwise the classic static Pac-Man's — the mobile
    machinery only changes the program where it changes the physics."""
    pcfg = _pcfg("decafork", eps=1.8)
    frozen = attack("mobile_pacman", node=3, hop_prob=0.0, start=30)
    static = attack("pacman", node=3, start=30)
    final, mobile_outs = Experiment(
        graph=graph, protocol=pcfg, failures=frozen, steps=STEPS
    ).run(key=BASE_KEY)
    assert final.pacman_pos is not None
    np.testing.assert_array_equal(
        np.asarray(final.pacman_pos),
        np.asarray(flr.initial_pacman_positions(frozen)),
    )
    _, static_outs = Experiment(
        graph=graph, protocol=pcfg, failures=static, steps=STEPS
    ).run(key=BASE_KEY)
    _bitwise(mobile_outs, static_outs, "frozen mobile vs static pacman")


def test_edge_cut_mask_severs_exactly_the_cross_edges(cgraph):
    """At the scheduled time the mask covers precisely the edges whose
    endpoints straddle the id threshold — in both directed slots — and
    nothing at any other time."""
    neighbors = jnp.asarray(cgraph.neighbors)
    fcfg = attack("edge_cut", time=10, threshold=HALF)
    nbrs, degs = np.asarray(cgraph.neighbors), np.asarray(cgraph.degrees)
    want = np.zeros(nbrs.shape, bool)
    for i in range(cgraph.n):
        for k in range(degs[i]):
            want[i, k] = (i < HALF) != (nbrs[i, k] < HALF)
    got = np.asarray(flr.edge_cut_mask(neighbors, jnp.int32(10), fcfg))
    # padding slots beyond a node's degree are don't-cares: mask them off
    in_deg = np.arange(nbrs.shape[1])[None, :] < degs[:, None]
    np.testing.assert_array_equal(got & in_deg, want & in_deg)
    off = np.asarray(flr.edge_cut_mask(neighbors, jnp.int32(9), fcfg))
    assert not (off & in_deg).any()


def test_edge_cut_confines_walks_to_their_community(cgraph):
    """After the partition fires no walk ever changes sides: the side
    each (unregulated, deathless) walk holds at step 1 is the side it
    holds 40 steps later."""
    pcfg = _pcfg("none")
    fcfg = attack("edge_cut", time=0, threshold=HALF)
    side = {}
    for steps in (1, 41):
        final, _ = Experiment(
            graph=cgraph, protocol=pcfg, failures=fcfg, steps=steps
        ).run(key=BASE_KEY)
        pos = np.asarray(final.walks.pos)
        act = np.asarray(final.walks.active)
        side[steps] = np.where(pos < HALF, 0, 1)[act]
        assert act.sum() == Z0  # cuts strand, they don't kill
    np.testing.assert_array_equal(side[1], side[41])


# ---------------------------------------------------------------------------
# defense semantics
# ---------------------------------------------------------------------------


def _cut_state(cgraph):
    """GraphState + availability with every cross-community edge down."""
    neighbors = jnp.asarray(cgraph.neighbors)
    degrees = jnp.asarray(cgraph.degrees)
    mirror = jnp.asarray(mirror_indices(cgraph))
    gs = init_graph_state(cgraph.n, cgraph.max_degree)
    fcfg = attack("edge_cut", time=0, threshold=HALF)
    gs = flr.step_topology(
        gs, jnp.int32(0), fcfg, jax.random.key(0), neighbors, mirror
    )
    return gs, availability(gs, neighbors, degrees)


def test_jump_defense_crosses_a_partition(cgraph):
    """With the cut in force, uniform movement keeps every walk on its
    side; the jump variant's teleport reaches the other community."""
    neighbors = jnp.asarray(cgraph.neighbors)
    degrees = jnp.asarray(cgraph.degrees)
    gs, avail = _cut_state(cgraph)
    ws = wlk.WalkState(
        pos=jnp.zeros((W,), jnp.int32),  # all on side A
        active=jnp.ones((W,), bool),
        track=jnp.arange(W, dtype=jnp.int32),
    )
    from repro.zoo.variants import move_variant

    stuck = wlk.move_walks(ws, neighbors, degrees, jax.random.key(1), avail)
    assert (np.asarray(stuck.pos) < HALF).all()
    jumped = move_variant(
        ws, _pcfg(walk_variant="jump", p_jump=1.0), neighbors, degrees,
        jax.random.key(1), avail, gs.node_up,
    )
    assert (np.asarray(jumped.pos) >= HALF).any()


def test_biased_walk_honors_pq_weights():
    """On a ring with an overwhelming return penalty (bias_p huge) and
    outward pull (bias_q small) the walk must step forward, and ``prev``
    must follow it."""
    g = ring_graph(5)
    neighbors, degrees = jnp.asarray(g.neighbors), jnp.asarray(g.degrees)
    avail = availability(
        init_graph_state(g.n, g.max_degree), neighbors, degrees
    )
    ws = wlk.WalkState(
        pos=jnp.array([1], jnp.int32),
        active=jnp.array([True]),
        track=jnp.array([0], jnp.int32),
        prev=jnp.array([0], jnp.int32),
    )
    from repro.zoo.variants import move_variant

    pcfg = _pcfg(walk_variant="biased", bias_p=1e9, bias_q=1e-9,
                 z0=1, max_walks=1)
    out = move_variant(
        ws, pcfg, neighbors, degrees, jax.random.key(0), avail,
        jnp.ones((g.n,), bool),
    )
    assert int(out.pos[0]) == 2  # forward: the only non-vanishing weight
    assert int(out.prev[0]) == 1


def test_bloom_walk_avoids_marked_neighbor():
    """A walk at node 0 of a 4-ring whose filter already holds node 1
    must hop to node 3 — the only fresh available neighbor."""
    g = ring_graph(4)
    neighbors, degrees = jnp.asarray(g.neighbors), jnp.asarray(g.degrees)
    avail = availability(
        init_graph_state(g.n, g.max_degree), neighbors, degrees
    )
    B = 64
    bloom = np.zeros((1, B), bool)
    h1, h2 = _bloom_hashes(jnp.array([1], jnp.int32), B)
    bloom[0, int(h1[0])] = bloom[0, int(h2[0])] = True
    ws = wlk.WalkState(
        pos=jnp.array([0], jnp.int32),
        active=jnp.array([True]),
        track=jnp.array([0], jnp.int32),
        bloom=jnp.asarray(bloom),
    )
    from repro.zoo.variants import move_variant

    pcfg = _pcfg(walk_variant="bloom", bloom_bits=B, z0=1, max_walks=1)
    out = move_variant(
        ws, pcfg, neighbors, degrees, jax.random.key(0), avail,
        jnp.ones((g.n,), bool),
    )
    assert int(out.pos[0]) == 3
    # and the node it left is now marked
    g1, g2 = _bloom_hashes(jnp.array([0], jnp.int32), B)
    assert bool(out.bloom[0, int(g1[0])]) and bool(out.bloom[0, int(g2[0])])


def test_forks_duplicate_variant_memory():
    """execute_forks copies the parent's prev/bloom columns into the
    child slot — a forked biased/bloom walk inherits its history."""
    n, Wc = 6, 4
    ws = wlk.WalkState(
        pos=jnp.array([0, 1, 2, 3], jnp.int32),
        active=jnp.array([True, True, False, False]),
        track=jnp.array([0, 1, -1, -1], jnp.int32),
        prev=jnp.arange(Wc, dtype=jnp.int32) + 10,
        bloom=jnp.zeros((Wc, 8), bool).at[1, 3].set(True),
    )
    last_seen = jnp.zeros((n, Wc), jnp.int32)
    ev_mask = jnp.array([False, True, False, False])  # slot 1 forks
    out, _, n_forks, fork_parent = wlk.execute_forks(
        ws, last_seen, ev_mask, ws.pos, None, jnp.int32(5)
    )
    assert int(n_forks) == 1
    new_slot = int(np.nonzero(np.asarray(fork_parent) == 1)[0][0])
    assert bool(out.active[new_slot])
    assert int(out.prev[new_slot]) == 11  # parent slot 1's prev
    assert bool(out.bloom[new_slot, 3])  # parent slot 1's filter bit


# ---------------------------------------------------------------------------
# sweep integration: grouping, padding, bitwise-equal mixed sweeps
# ---------------------------------------------------------------------------


def _zoo_rows(base):
    return zoo_scenarios(
        defenses=["uniform", "jump"],
        attacks=[
            ("none", {}),
            ("multi_pacman", {"nodes": (0, HALF), "start": 20}),
            ("edge_cut", {"time": 20, "threshold": HALF}),
        ],
        base_protocol=base,
    ) + zoo_scenarios(
        defenses=["uniform"],
        attacks=[("mobile_pacman", {"node": 0, "start": 20})],
        base_protocol=base,
    )


def test_zoo_mixed_sweep_bitwise_matches_private_ensembles(cgraph):
    """The 7-row zoo grid groups into 3 compiled programs (schedule
    widths pad within a group) and every row stays bitwise what its own
    private ensemble computes."""
    rows = _zoo_rows(_pcfg("decafork", eps=1.8))
    groups = group_scenarios(rows)
    assert len(groups) == 3  # uniform / jump / uniform+mobile
    res = Experiment(
        graph=cgraph, scenarios=rows, steps=STEPS, outputs="full"
    ).plan().sweep(seeds=SEEDS, base_key=BASE_KEY)
    assert res.names == tuple(r.name for r in rows)
    for row, out in zip(rows, res.outputs):
        ref = Experiment(
            graph=cgraph, protocol=row.pcfg, failures=row.fcfg, steps=STEPS,
            outputs="full",
        ).ensemble(SEEDS, base_key=BASE_KEY)
        _bitwise(ref, out, row.name)


def test_pad_bursts_pads_zoo_schedules():
    """Pac-Man id and edge-cut schedules pad to the group's widest row
    with the never-fires fill (-1), like every other schedule family."""
    a = FailureConfig(pacman_node=0, pacman_nodes=(5, 9))
    b = FailureConfig(edge_cut_times=(7,), edge_cut_thresholds=(12,))
    pa, pb = flr.pad_bursts([a, b])
    assert pa.n_pacman == pb.n_pacman == 2
    assert pa.n_edge_cuts == pb.n_edge_cuts == 1
    assert np.asarray(pb.pacman_nodes).tolist() == [-1, -1]
    assert np.asarray(pa.edge_cut_times).tolist() == [-1]
    assert np.asarray(pa.edge_cut_thresholds).tolist() == [-1]
    # padded -1 ids never fire: same trajectory as the unpadded config
    g = random_regular_graph(N, DEG, seed=GRAPH_SEED)
    pcfg = _pcfg("decafork", eps=1.8)
    plain = Experiment(
        graph=g, protocol=pcfg, failures=b, steps=STEPS
    ).ensemble(SEEDS, base_key=BASE_KEY)
    padded = Experiment(
        graph=g, protocol=pcfg,
        failures=dataclasses.replace(pb, pacman_nodes=(-1, -1)),
        steps=STEPS,
    ).ensemble(SEEDS, base_key=BASE_KEY)
    _bitwise(plain, padded, "padded-schedule no-op")


# ---------------------------------------------------------------------------
# compile-cache accounting + stable store keys
# ---------------------------------------------------------------------------


def _count_lowerings(monkeypatch):
    calls = []
    real = plan_mod._lower

    def counting(mode, signature):
        calls.append((mode, signature))
        return real(mode, signature)

    monkeypatch.setattr(plan_mod, "_lower", counting)
    return calls


def test_each_variant_opens_exactly_one_cache_slot(graph, monkeypatch):
    """Four defenses -> four ensemble cache slots; structurally-equal
    rebuilds (fresh configs, new Experiment objects, different numeric
    knobs) re-lower nothing and recompile nothing."""
    calls = _count_lowerings(monkeypatch)
    fcfg = FailureConfig(burst_times=(20,), burst_sizes=(2,))

    def run_all(eps):
        for name in ("uniform", "jump", "biased", "bloom"):
            # rt_bins=48 is this test's own static: the process-wide
            # cache may already hold other suites' rt_bins=32 slots
            pcfg = dataclasses.replace(
                _pcfg("decafork", eps=eps, rt_bins=48), **defense(name)
            )
            Experiment(
                graph=graph, protocol=pcfg, failures=fcfg, steps=STEPS
            ).ensemble(SEEDS, base_key=BASE_KEY)

    run_all(1.8)
    first = len(calls)
    assert first == len(set(calls)) == 4  # one slot per variant tag
    compiles = plan_mod.cache_stats()["xla_compiles"]
    run_all(2.2)  # numeric change only: same four programs
    assert len(calls) == first
    assert plan_mod.cache_stats()["xla_compiles"] == compiles


def test_zoo_attack_statics_partition_the_cache(graph, monkeypatch):
    """pacman_mobile and the schedule widths are program structure: the
    mobile attack opens its own slot, while static multi-Pac-Man reuses
    the plain ensemble structure only when widths match."""
    calls = _count_lowerings(monkeypatch)
    pcfg = _pcfg("decafork", eps=1.8, rt_bins=48)  # own cache partition

    def run(fcfg):
        Experiment(
            graph=graph, protocol=pcfg, failures=fcfg, steps=STEPS
        ).ensemble(SEEDS, base_key=BASE_KEY)

    run(attack("multi_pacman", nodes=(0, 5), start=20))
    run(attack("mobile_pacman", node=0, start=20))
    assert len(calls) == len(set(calls)) == 2
    # structurally equal attacks (different ids — traced leaves) share
    run(attack("multi_pacman", nodes=(1, 7), start=25))
    run(attack("mobile_pacman", node=2, hop_prob=0.5, start=10))
    assert len(calls) == 2


def test_zoo_sweep_store_key_is_stable(cgraph):
    """Two independently built but structurally-equal zoo sweeps hash to
    the same ResultStore key; changing one traced defense knob changes
    it."""
    store = ResultStore("/tmp/unused-zoo-keys")

    def build(p_jump=0.3):
        rows = zoo_scenarios(
            defenses=[("jump", {"p_jump": p_jump})],
            attacks=[("edge_cut", {"time": 20, "threshold": HALF})],
            base_protocol=_pcfg("decafork", eps=1.8),
        )
        plan = Experiment(
            graph=cgraph, scenarios=rows, steps=STEPS
        ).plan()
        pcfgs, fcfgs = stack_configs(rows)
        lens = (
            int(jnp.shape(fcfgs.burst_times)[-1]),
            int(jnp.shape(fcfgs.node_crash_times)[-1]),
            int(jnp.shape(fcfgs.pacman_nodes)[-1]),
            int(jnp.shape(fcfgs.edge_cut_times)[-1]),
        )
        sig = plan._signature("sweep", rows[0].pcfg, lens, rows[0].fcfg)
        return store.sweep_key(
            sig, cgraph, (pcfgs, fcfgs), SEEDS, jax.random.key(BASE_KEY)
        )

    assert build() == build()  # content-addressed, not identity-addressed
    assert build(p_jump=0.31) != build()


# ---------------------------------------------------------------------------
# round decisions: the fallback is loud, and decided on padded widths
# ---------------------------------------------------------------------------


def test_round_impl_decision_names_the_gate():
    fused_ok = _pcfg(
        "decafork", eps=1.8, round_impl="fused", estimator_impl="gather"
    )
    dec = sim.round_impl_decision(fused_ok, FailureConfig())
    assert dec.fused and dec.backend == "ref"
    for name in ("jump", "biased", "bloom"):
        pcfg = dataclasses.replace(fused_ok, **defense(name))
        dec = sim.round_impl_decision(pcfg, FailureConfig())
        assert not dec.fused
        assert f"walk_variant {name!r}" in dec.reason
    dec = sim.round_impl_decision(dataclasses.replace(fused_ok,
                                                      round_impl="unfused"))
    assert not dec.fused and "round_impl" in dec.reason


def test_ref_backend_fuses_zoo_attacks_pallas_does_not(monkeypatch):
    """The ref fused round shares the jnp failure helpers, so zoo attack
    statics stay fused on it; the Pallas whole-round kernel falls back,
    and the reason says which attack tripped it."""
    attacks = {
        "mobile Pac-Man": attack("mobile_pacman", node=0),
        "multiple Pac-Man": attack("multi_pacman", nodes=(0, 1)),
        "edge cuts": attack("edge_cut", time=5, threshold=HALF),
    }
    ref_pcfg = _pcfg(
        "decafork", eps=1.8, round_impl="fused", estimator_impl="gather"
    )
    for fcfg in attacks.values():
        assert sim.round_impl_decision(ref_pcfg, fcfg).fused
    monkeypatch.setattr(sim, "_fused_round_backend", lambda: "pallas")
    pallas_pcfg = dataclasses.replace(ref_pcfg, estimator_impl="compare")
    assert sim.round_impl_decision(pallas_pcfg, FailureConfig()).fused
    for phrase, fcfg in attacks.items():
        dec = sim.round_impl_decision(pallas_pcfg, fcfg)
        assert not dec.fused
        assert phrase in dec.reason


def test_plan_round_decisions_use_padded_group_widths(graph, monkeypatch):
    """Plan.round_decisions reports per compile group, on the PADDED
    schedule widths the compiled program actually sees: a cut-free row
    co-batched with an edge-cut row shares the group's fallback."""
    monkeypatch.setattr(sim, "_fused_round_backend", lambda: "pallas")
    pcfg = _pcfg(
        "decafork", eps=1.8, round_impl="fused", estimator_impl="compare"
    )
    rows = [
        Scenario("calm", pcfg, FailureConfig()),
        Scenario("cut", pcfg, attack("edge_cut", time=20, threshold=HALF)),
        Scenario("jump", dataclasses.replace(pcfg, **defense("jump")),
                 FailureConfig()),
    ]
    plan = Experiment(graph=graph, scenarios=rows, steps=STEPS).plan()
    decisions = plan.round_decisions()
    assert len(decisions) == 2  # {calm, cut} co-batch; jump is its own
    by_rows = {tuple(idxs): dec for _sig, idxs, dec in decisions}
    group_dec = by_rows[(0, 1)]
    assert not group_dec.fused
    assert "edge cuts" in group_dec.reason  # calm row shares the fallback
    assert "walk_variant 'jump'" in by_rows[(2,)].reason
    # alone, the calm row fuses — the padding is what demotes it
    assert sim.round_impl_decision(pcfg, FailureConfig()).fused


def test_plan_round_decisions_base_plan(graph):
    plan = Experiment(
        graph=graph, protocol=_pcfg("decafork", eps=1.8),
        failures=attack("mobile_pacman", node=0), steps=STEPS,
    ).plan()
    [(sig, idxs, dec)] = plan.round_decisions()
    assert sig is None and idxs == [0]
    assert isinstance(dec, sim.RoundDecision) and dec.reason


# ---------------------------------------------------------------------------
# registry integration
# ---------------------------------------------------------------------------


def test_registry_resolves_zoo_experiment(cgraph):
    """Experiment.from_config({'experiment': 'zoo'}) builds the grid with
    the graph-aware attack defaults (the registry lazy-imports repro.zoo
    on first lookup, so config-driven callers need no import)."""
    exp = Experiment.from_config({
        "experiment": "zoo",
        "n": N, "graph_seed": GRAPH_SEED, "steps": STEPS,
        "protocol": dict(algorithm="decafork", z0=Z0, max_walks=W,
                         rt_bins=32, protocol_start=10, eps=1.8),
        "defenses": ["uniform", "jump"],
        "attacks": ["edge_cut", "multi_pacman"],
    })
    assert [s.name for s in exp.scenarios] == [
        "uniform|edge_cut", "uniform|multi_pacman",
        "jump|edge_cut", "jump|multi_pacman",
    ]
    assert exp.scenarios[0].fcfg.n_edge_cuts == 1
    assert exp.scenarios[1].fcfg.n_pacman == 1  # one per community
    assert exp.scenarios[2].pcfg.walk_variant == "jump"


def test_unknown_names_raise():
    with pytest.raises(KeyError, match="unknown attack"):
        attack("meteor")
    with pytest.raises(KeyError, match="unknown defense"):
        defense("prayer")
    with pytest.raises(ValueError, match="walk_variant"):
        ProtocolConfig(walk_variant="quantum")
