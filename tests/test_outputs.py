"""OutputSpec / RecordedOutputs: the thinned trajectory-recording path.

Contract under test:
  * a thinned run's recorded fields are bitwise the corresponding fields
    of a full run (the spec only selects what is STACKED, never what is
    computed) — payload-free and with a real training payload attached;
  * the default payload-free spec is scalars-only: no (.., steps, W)
    per-walk stacks anywhere in the output pytree;
  * attaching a payload auto-records the full set;
  * requesting a dropped field raises immediately with the fix;
  * bad specs fail fast with clear errors.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Experiment
from repro.core import (
    FULL,
    SCALARS,
    FailureConfig,
    OutputSpec,
    ProtocolConfig,
    RecordedOutputs,
)
from repro.core.outputs import ALL_FIELDS, SCALAR_FIELDS, resolve_spec
from repro.graphs import random_regular_graph

N, W, Z0, STEPS, SEEDS = 24, 10, 5, 40, 2


@pytest.fixture(scope="module")
def graph():
    return random_regular_graph(N, 4, seed=3)


def _pcfg(**kw):
    base = dict(
        algorithm="decafork", z0=Z0, max_walks=W, rt_bins=32,
        protocol_start=10, eps=1.8,
    )
    base.update(kw)
    return ProtocolConfig(**base)


FCFG = FailureConfig(burst_times=(15,), burst_sizes=(2,))


# ---------------------------------------------------------------------------
# spec construction / resolution
# ---------------------------------------------------------------------------


def test_spec_canonicalizes_and_validates():
    assert OutputSpec(("terminated", "z")).fields == ("z", "terminated")
    assert OutputSpec(("z", "z")).fields == ("z",)
    assert FULL.fields == ALL_FIELDS
    assert SCALARS.fields == SCALAR_FIELDS
    with pytest.raises(ValueError, match="unknown StepOutputs field"):
        OutputSpec(("z", "bogus"))
    with pytest.raises(ValueError, match="at least one"):
        OutputSpec(())


def test_resolve_spec_modes():
    assert resolve_spec(None, None) is SCALARS
    assert resolve_spec(None, object()) is FULL
    assert resolve_spec("full", None) == FULL
    assert resolve_spec("scalars", object()) == SCALARS
    assert resolve_spec(("z",), None) == OutputSpec(("z",))
    assert resolve_spec(FULL, None) is FULL
    with pytest.raises(ValueError, match="shorthand"):
        resolve_spec("everything", None)
    with pytest.raises(TypeError, match="outputs must be"):
        resolve_spec(7, None)


def test_dropped_field_access_raises(graph):
    outs = Experiment(graph=graph, protocol=_pcfg(), failures=FCFG,
                      steps=10).ensemble(seeds=1)
    with pytest.raises(AttributeError, match="not recorded.*outputs='full'"):
        outs.fork_parent
    with pytest.raises(AttributeError):
        outs.definitely_not_a_field


# ---------------------------------------------------------------------------
# thinned == slices of full, bitwise
# ---------------------------------------------------------------------------


def test_thinned_equals_full_slices_payload_free(graph):
    full = Experiment(graph=graph, protocol=_pcfg(), failures=FCFG, steps=STEPS,
                      outputs="full").ensemble(SEEDS, base_key=7)
    assert full._fields == ALL_FIELDS
    for spec in (None, "scalars", ("z", "terminated"), OutputSpec(("forks",))):
        thin = Experiment(graph=graph, protocol=_pcfg(), failures=FCFG,
                          steps=STEPS, outputs=spec).ensemble(SEEDS, base_key=7)
        for name in thin._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(thin, name)),
                np.asarray(getattr(full, name)),
                err_msg=f"outputs={spec!r}: field {name}",
            )


@pytest.mark.slow
def test_thinned_equals_full_slices_with_payload(graph):
    from repro.data import make_markov_task
    from repro.models.config import ModelConfig
    from repro.models.model import Model
    from repro.optim import RwSgdPayload, adamw

    cfg = ModelConfig(
        name="tiny", arch_type="dense", num_layers=1, d_model=32, d_ff=64,
        vocab_size=64, num_heads=2, num_kv_heads=2, head_dim=16,
        dtype="float32",
    )
    payload = RwSgdPayload(
        Model(cfg), adamw(1e-2), make_markov_task(cfg.vocab_size, rank=4),
        max_walks=W, local_batch=1, seq_len=8,
    )
    T = 12
    full, learn_full = Experiment(
        graph=graph, protocol=_pcfg(), failures=FCFG, steps=T,
        payload=payload,
    ).ensemble(SEEDS, base_key=3)
    assert full._fields == ALL_FIELDS  # payload auto-records everything
    thin, learn_thin = Experiment(
        graph=graph, protocol=_pcfg(), failures=FCFG, steps=T,
        payload=payload, outputs=("z",),
    ).ensemble(SEEDS, base_key=3)
    assert thin._fields == ("z",)
    np.testing.assert_array_equal(np.asarray(thin.z), np.asarray(full.z))
    # the payload outputs are untouched by the spec (hooks see everything)
    np.testing.assert_array_equal(
        np.asarray(learn_thin.loss), np.asarray(learn_full.loss)
    )


# ---------------------------------------------------------------------------
# pytree structure: the dropped stacks are never materialized
# ---------------------------------------------------------------------------


def test_payload_free_sweep_has_no_per_walk_stacks(graph):
    scenarios = [(_pcfg(eps=e), FCFG) for e in (1.6, 2.0, 2.4)]
    out = Experiment(graph=graph, scenarios=scenarios,
                     steps=STEPS).plan().sweep_stacked(seeds=SEEDS, base_key=5)
    assert isinstance(out, RecordedOutputs)
    assert out._fields == SCALAR_FIELDS
    leaves = jax.tree_util.tree_leaves(out)
    assert len(leaves) == len(SCALAR_FIELDS)
    for leaf in leaves:
        assert leaf.shape == (len(scenarios), SEEDS, STEPS), leaf.shape
    # nothing in the output pytree carries a (.., W) trailing axis
    assert not any(leaf.ndim == 4 for leaf in leaves)


def test_sweep_thinned_matches_ensemble(graph):
    """The spec composes with the sweep/ensemble bitwise contract."""
    scenarios = [(_pcfg(eps=e), FCFG) for e in (1.6, 2.2)]
    out = Experiment(graph=graph, scenarios=scenarios, steps=STEPS,
                     outputs=("z", "fork_parent")).plan().sweep_stacked(
        seeds=SEEDS, base_key=9)
    assert out._fields == ("z", "fork_parent")
    assert out.fork_parent.shape == (2, SEEDS, STEPS, W)
    for i, (pc, fc) in enumerate(scenarios):
        ref = Experiment(graph=graph, protocol=pc, failures=fc, steps=STEPS,
                         outputs=("z", "fork_parent")).ensemble(SEEDS, base_key=9)
        for name in ref._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(ref, name)),
                np.asarray(getattr(out, name)[i]),
                err_msg=f"scenario{i}: {name}",
            )


def test_run_scenarios_threads_outputs(graph):
    from repro.sweep import Scenario

    scenarios = [
        Scenario("a", _pcfg(eps=1.6), FCFG),
        Scenario("mp", _pcfg(algorithm="missingperson", eps_mp=20.0), FCFG),
    ]
    res = Experiment(graph=graph, scenarios=scenarios, steps=10,
                     outputs=("z", "terminated")).sweep(seeds=1)
    for name in res.names:
        assert res[name]._fields == ("z", "terminated")
        assert res[name].terminated.shape == (1, 10, W)


# ---------------------------------------------------------------------------
# container behavior
# ---------------------------------------------------------------------------


def test_recorded_outputs_container_protocol():
    ro = RecordedOutputs(("z", "forks"), (jnp.arange(3), jnp.zeros(3)))
    assert len(ro) == 2
    assert list(ro._fields) == ["z", "forks"]
    np.testing.assert_array_equal(np.asarray(ro[0]), np.asarray(ro.z))
    np.testing.assert_array_equal(np.asarray(ro["forks"]), np.zeros(3))
    assert set(ro._asdict()) == {"z", "forks"}
    with pytest.raises(AttributeError, match="immutable"):
        ro.z = jnp.ones(3)
    # pytree round-trip preserves fields
    mapped = jax.tree_util.tree_map(lambda x: x * 2, ro)
    assert mapped._fields == ro._fields
    np.testing.assert_array_equal(np.asarray(mapped.z), 2 * np.arange(3))
    # results are persistable: pickle and deepcopy round-trip
    import copy
    import pickle

    for clone in (pickle.loads(pickle.dumps(ro)), copy.deepcopy(ro)):
        assert clone._fields == ro._fields
        np.testing.assert_array_equal(np.asarray(clone.z), np.asarray(ro.z))
