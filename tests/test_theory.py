import math

import numpy as np
import pytest

from repro.core.theory import (
    PopulationHistory,
    Rates,
    fork_estimate_cdf,
    fork_estimate_mean_closed,
    fork_estimate_moments,
    fork_probability_bound,
    fork_rate_upper,
    growth_bound_delta,
    multi_fork_reaction_bound,
    overshoot_recursion,
    reaction_time_bound,
    termination_probability_bound,
    theta_mean,
    theta_variance,
    time_until_growth,
)

RATES = Rates(lambda_r=0.02, lambda_a=0.01)  # n=50-ish graph


def test_lemma1_cdf_is_a_cdf():
    t, tf, td = 100.0, 20.0, 60.0
    xs = np.linspace(0, 1, 400)
    F = fork_estimate_cdf(xs, t, tf, td, RATES)
    assert (np.diff(F) >= -1e-9).all()
    assert F[0] >= 0 and abs(F[-1] - 1) < 1e-9


def test_corollary1_matches_numerical_integration():
    for (t, tf, td) in [(100.0, 20.0, 60.0), (50.0, 10.0, 50.0), (200.0, 0.0, 120.0)]:
        closed = fork_estimate_mean_closed(t, tf, td, RATES)
        numeric, var = fork_estimate_moments(t, tf, td, RATES)
        assert abs(closed - numeric) < 2e-3, (t, tf, td, closed, numeric)
        assert var >= 0


def test_theorem1_asymptotics():
    """E[theta] -> K as t - T_last -> infinity (Thm. 1)."""
    hist = PopulationHistory(
        n_active=7,
        terminations=((100.0, 3),),
        forks=((120.0, 2),),
    )
    # long after the last event: K = 7 + 2 live walks tracked
    m = theta_mean(5000.0, hist, RATES)
    assert abs(2 * m - 2 * (7 + 2) / 2) < 0.05  # theta ~ K/2 => 2E = K
    # right after a termination the dead walks still look half-alive
    import dataclasses

    m_soon = theta_mean(101.0, dataclasses.replace(hist, forks=()), RATES)
    assert m_soon > 7 / 2 + 1.0


def test_variance_components():
    hist = PopulationHistory(n_active=5)
    assert abs(theta_variance(1000.0, hist, RATES) - 4 / 12) < 1e-9
    hist2 = PopulationHistory(n_active=5, terminations=((990.0, 2),))
    assert theta_variance(1000.0, hist2, RATES) > 4 / 12


def test_bennett_bounds_behave():
    p = 0.1
    hist = PopulationHistory(n_active=10)
    # mean 5, far above eps=2 -> tiny forking probability
    b_low = fork_probability_bound(1000.0, hist, RATES, eps=2.0, p=p)
    b_close = fork_probability_bound(1000.0, hist, RATES, eps=4.4, p=p)
    assert b_low < b_close <= p
    assert b_low < 0.01  # Bennett with tau=3, sigma^2=0.75 -> ~4.8e-3
    # termination mirror
    t_low = termination_probability_bound(1000.0, hist, RATES, eps2=8.0, p=p)
    t_close = termination_probability_bound(1000.0, hist, RATES, eps2=5.6, p=p)
    assert t_low < t_close <= p


@pytest.mark.slow
def test_reaction_time_bound_monotonic():
    common = dict(r_forked=0, k_remaining=5, t_d=0.0, p=0.2, rates=RATES, delta=0.1)
    t_eps_small = reaction_time_bound(d_failed=5, eps=1.5, **common)
    t_eps_large = reaction_time_bound(d_failed=5, eps=3.0, **common)
    assert t_eps_large <= t_eps_small  # larger eps -> faster reaction
    assert 0 < t_eps_large < 1e5
    total = multi_fork_reaction_bound(5, 5, 3, 0.0, 3.0, 0.2, RATES, 0.1)
    assert total >= t_eps_large


def test_growth_bound_and_inversion():
    args = dict(z0=10, n_nodes=100, eps=2.0, p=0.1, rates=Rates(0.02, 0.01))
    d_short = growth_bound_delta(z_max=20, horizon=10.0, **args)
    d_long = growth_bound_delta(z_max=20, horizon=1e5, **args)
    assert 0 <= d_short <= d_long <= 1.0
    t = time_until_growth(z_max=20, delta=0.5, **args)
    assert t > 0
    # consistency: bound at that horizon stays near delta
    assert growth_bound_delta(z_max=20, horizon=t, **args) <= 0.55


def test_fork_rate_upper_decreases_eventually():
    rates = [fork_rate_upper(nu, eps=2.0, p=0.1) for nu in range(10, 30)]
    assert rates[-1] < rates[0]
    assert all(r >= 0 for r in rates)


def test_overshoot_recursion_bounded_growth():
    """Cor. 3 is explicitly non-convergent (the paper notes the ceiling
    forces >= +1 per step in the long run); the useful content is the
    EARLY-horizon overshoot bound after a failure."""
    ceiled = overshoot_recursion(
        z_after_failure=5, d_failed=5, t_d=0.0, steps=60,
        eps=2.0, p=0.1, rates=RATES,
    )
    assert (np.diff(ceiled) >= -1e-9).all()  # non-decreasing (submartingale)
    # paper's own caveat: the ceiling forces ~ +1/step
    assert ceiled[-1] <= 5 + 60 + 5 * (1 + 0.1) ** 60
    smooth = overshoot_recursion(
        z_after_failure=5, d_failed=5, t_d=0.0, steps=60,
        eps=2.0, p=0.1, rates=RATES, use_ceiling=False,
    )
    assert (np.diff(smooth) >= -1e-9).all()
    # informative bound: sub-compounding growth (fork feedback raises the
    # estimator mean, damping the Bennett-bounded fork rate)
    assert smooth[-1] < 5 * (1 + 0.1) ** 60 / 10
    assert np.diff(smooth)[-1] < 0.35  # decelerating, not exploding
