"""Node-sharded shard_map protocol step on the local 1-device mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distributed import make_sharded_step
from repro.utils.compat import AxisType, make_mesh
from repro.core.protocol import ProtocolConfig
from repro.graphs import random_regular_graph


@pytest.fixture(scope="module")
def setup():
    g = random_regular_graph(64, 8, seed=1)
    pcfg = ProtocolConfig(
        algorithm="decafork+", z0=6, max_walks=24, eps=1.8, eps2=6.5,
        protocol_start=200, rt_bins=256,
    )
    mesh = make_mesh((1,), ("data",), axis_types=(AxisType.Auto,))
    step = jax.jit(make_sharded_step(mesh, ("data",), g.n, pcfg))
    return g, pcfg, mesh, step


def _init(g, pcfg, key):
    W = pcfg.max_walks
    pos = jax.random.randint(key, (W,), 0, g.n, dtype=jnp.int32)
    active = jnp.arange(W) < pcfg.z0
    track = jnp.arange(W, dtype=jnp.int32)
    last_seen = jnp.full((g.n, W), -1, jnp.int32)
    hist = jnp.zeros((g.n, pcfg.rt_bins), jnp.float32)
    total = jnp.zeros((g.n,), jnp.float32)
    return pos, active, track, last_seen, hist, total


@pytest.mark.slow
def test_distributed_step_runs_and_self_regulates(setup):
    g, pcfg, mesh, step = setup
    key = jax.random.key(0)
    pos, active, track, last_seen, hist, total = _init(g, pcfg, key)
    nbrs, degs = jnp.asarray(g.neighbors), jnp.asarray(g.degrees)
    t = jnp.int32(0)
    zs = []
    with mesh:
        for _ in range(600):
            t, pos, active, track, last_seen, hist, total, key, z = step(
                t, pos, active, track, last_seen, hist, total, key, nbrs, degs
            )
            zs.append(int(z))
    zs = np.asarray(zs)
    assert zs.min() >= 1  # resilience objective
    assert zs.max() <= pcfg.max_walks
    assert float(total.sum()) > 0  # return-time samples accumulated
    # movement stays on the graph
    assert (np.asarray(pos) >= 0).all() and (np.asarray(pos) < g.n).all()


def test_distributed_movement_follows_edges(setup):
    g, pcfg, mesh, step = setup
    key = jax.random.key(1)
    pos, active, track, last_seen, hist, total = _init(g, pcfg, key)
    nbrs, degs = jnp.asarray(g.neighbors), jnp.asarray(g.degrees)
    adj = g.adjacency()
    t = jnp.int32(0)
    with mesh:
        for _ in range(25):
            old_pos = np.asarray(pos)
            old_active = np.asarray(pos * 0 + 1)
            t, pos, active, track, last_seen, hist, total, key, z = step(
                t, pos, active, track, last_seen, hist, total, key, nbrs, degs
            )
            new_pos = np.asarray(pos)
            act = np.asarray(active)
            for w in range(pcfg.max_walks):
                if act[w] and old_pos[w] != new_pos[w]:
                    assert adj[old_pos[w], new_pos[w]], (old_pos[w], new_pos[w])
