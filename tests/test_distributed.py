"""Node-sharded shard_map protocol step on the local 1-device mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import walkers as wlk
from repro.core.distributed import make_sharded_step
from repro.core.protocol import ProtocolConfig
from repro.graphs import GraphState, availability, random_regular_graph
from repro.utils.compat import AxisType, make_mesh
from repro.utils.prng import fold_in_time


@pytest.fixture(scope="module")
def setup():
    g = random_regular_graph(64, 8, seed=1)
    pcfg = ProtocolConfig(
        algorithm="decafork+", z0=6, max_walks=24, eps=1.8, eps2=6.5,
        protocol_start=200, rt_bins=256,
    )
    mesh = make_mesh((1,), ("data",), axis_types=(AxisType.Auto,))
    step = jax.jit(make_sharded_step(mesh, ("data",), g.n, pcfg))
    return g, pcfg, mesh, step


def _init(g, pcfg, key):
    W = pcfg.max_walks
    pos = jax.random.randint(key, (W,), 0, g.n, dtype=jnp.int32)
    active = jnp.arange(W) < pcfg.z0
    track = jnp.arange(W, dtype=jnp.int32)
    last_seen = jnp.full((g.n, W), -1, jnp.int32)
    hist = jnp.zeros((g.n, pcfg.rt_bins), jnp.float32)
    total = jnp.zeros((g.n,), jnp.float32)
    return pos, active, track, last_seen, hist, total


def _full_masks(g):
    return jnp.ones((g.n,), bool), jnp.ones((g.n, g.max_degree), bool)


@pytest.mark.slow
def test_distributed_step_runs_and_self_regulates(setup):
    g, pcfg, mesh, step = setup
    key = jax.random.key(0)
    pos, active, track, last_seen, hist, total = _init(g, pcfg, key)
    nbrs, degs = jnp.asarray(g.neighbors), jnp.asarray(g.degrees)
    node_up, edge_up = _full_masks(g)
    t = jnp.int32(0)
    zs = []
    with mesh:
        for _ in range(600):
            t, pos, active, track, last_seen, hist, total, key, z = step(
                t, pos, active, track, last_seen, hist, total, key, nbrs, degs,
                node_up, edge_up,
            )
            zs.append(int(z))
    zs = np.asarray(zs)
    assert zs.min() >= 1  # resilience objective
    assert zs.max() <= pcfg.max_walks
    assert float(total.sum()) > 0  # return-time samples accumulated
    # movement stays on the graph
    assert (np.asarray(pos) >= 0).all() and (np.asarray(pos) < g.n).all()


def test_distributed_movement_follows_edges(setup):
    g, pcfg, mesh, step = setup
    key = jax.random.key(1)
    pos, active, track, last_seen, hist, total = _init(g, pcfg, key)
    nbrs, degs = jnp.asarray(g.neighbors), jnp.asarray(g.degrees)
    node_up, edge_up = _full_masks(g)
    adj = g.adjacency()
    t = jnp.int32(0)
    with mesh:
        for _ in range(25):
            old_pos = np.asarray(pos)
            t, pos, active, track, last_seen, hist, total, key, z = step(
                t, pos, active, track, last_seen, hist, total, key, nbrs, degs,
                node_up, edge_up,
            )
            new_pos = np.asarray(pos)
            act = np.asarray(active)
            for w in range(pcfg.max_walks):
                if act[w] and old_pos[w] != new_pos[w]:
                    assert adj[old_pos[w], new_pos[w]], (old_pos[w], new_pos[w])


def test_distributed_masked_movement_parity_with_single_device(setup):
    """GraphState masks through the shard_map'd step: resident-walk kills
    and masked movement match the single-device path (kill_resident_walks
    + walkers.move_walks over the same availability) bit-for-bit."""
    g, pcfg, mesh, step = setup
    nbrs, degs = jnp.asarray(g.neighbors), jnp.asarray(g.degrees)
    rng = np.random.default_rng(7)
    node_up = jnp.asarray(rng.random(g.n) > 0.15)
    edge_np = rng.random((g.n, g.max_degree)) > 0.2
    # keep the mask symmetric like step_topology does (not required for
    # parity, but it is the state space the simulator actually produces)
    for i in range(g.n):
        for k in range(int(g.degrees[i])):
            j = int(g.neighbors[i, k])
            if j > i:
                kk = int(np.nonzero(np.asarray(g.neighbors[j]) == i)[0][0])
                edge_np[j, kk] = edge_np[i, k]
    edge_up = jnp.asarray(edge_np)
    gs = GraphState(node_up=node_up, edge_up=edge_up)
    avail = availability(gs, nbrs, degs)

    key = jax.random.key(3)
    pos, active, track, last_seen, hist, total = _init(g, pcfg, key)
    t = jnp.int32(0)
    with mesh:
        for _ in range(8):
            # single-device reference for this round, same key stream
            ref_active = active & node_up[pos]
            ws = wlk.WalkState(pos=pos, active=ref_active, track=track)
            ref = wlk.move_walks(
                ws, nbrs, degs, fold_in_time(key, t, 0), avail
            )
            t, pos, active, track, last_seen, hist, total, key, z = step(
                t, pos, active, track, last_seen, hist, total, key, nbrs, degs,
                node_up, edge_up,
            )
            # protocol_start=200 >> t: no forks/terminations interfere
            np.testing.assert_array_equal(np.asarray(active), np.asarray(ref.active))
            np.testing.assert_array_equal(np.asarray(pos), np.asarray(ref.pos))


def test_distributed_full_masks_bitwise_equal_unmasked(setup):
    """All-True masks reproduce the pre-mask step exactly: positions equal
    the unmasked uniform-neighbor hop under the same key."""
    g, pcfg, mesh, step = setup
    nbrs, degs = jnp.asarray(g.neighbors), jnp.asarray(g.degrees)
    node_up, edge_up = _full_masks(g)
    key = jax.random.key(5)
    pos, active, track, last_seen, hist, total = _init(g, pcfg, key)
    t = jnp.int32(0)
    with mesh:
        for _ in range(5):
            ws = wlk.WalkState(pos=pos, active=active, track=track)
            ref = wlk.move_walks(ws, nbrs, degs, fold_in_time(key, t, 0))
            t, pos, active, track, last_seen, hist, total, key, z = step(
                t, pos, active, track, last_seen, hist, total, key, nbrs, degs,
                node_up, edge_up,
            )
            np.testing.assert_array_equal(np.asarray(pos), np.asarray(ref.pos))
