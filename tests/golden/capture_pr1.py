"""Capture golden PR-1 trajectories for the topology no-op equivalence tests.

Run at the pre-GraphState commit to (re)generate
``tests/golden/pr1_trajectories.json``; ``tests/test_topology.py`` then
asserts that the refactored simulator with every topology-failure knob
disabled reproduces these outputs bitwise.

    PYTHONPATH=src python tests/golden/capture_pr1.py
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core import FailureConfig, ProtocolConfig, run_ensemble
from repro.core.simulator import run_sweep
from repro.graphs import random_regular_graph

OUT = os.path.join(os.path.dirname(__file__), "pr1_trajectories.json")

# mirror tests/test_topology.py: keep these literals in sync
N, DEG, GRAPH_SEED = 24, 4, 3
W, Z0, STEPS, SEEDS, BASE_KEY = 10, 5, 60, 2, 7


def _pcfg(alg, **kw):
    base = dict(algorithm=alg, z0=Z0, max_walks=W, rt_bins=32, protocol_start=10)
    base.update(kw)
    return ProtocolConfig(**base)


def cases():
    burst = FailureConfig(burst_times=(20,), burst_sizes=(2,))
    byz = FailureConfig(
        burst_times=(25,), burst_sizes=(1,), p_fail=0.002,
        byzantine_node=1, p_byz=0.01, byz_start_time=15,
    )
    return [
        ("decafork/burst", _pcfg("decafork", eps=1.8), burst),
        ("decafork+/byz", _pcfg("decafork+", eps=1.6, eps2=6.0), byz),
        ("missingperson/burst", _pcfg("missingperson", eps_mp=20.0), burst),
        ("none/pfail", _pcfg("none"), FailureConfig(p_fail=0.004)),
    ]


def _outputs_to_dict(outs) -> dict:
    # float32 -> python float is exact (float64 widening), so the JSON
    # round-trip preserves bitwise equality for every field
    return {
        name: np.asarray(arr).tolist() for name, arr in zip(outs._fields, outs)
    }


def main() -> None:
    graph = random_regular_graph(N, DEG, seed=GRAPH_SEED)
    payload = {"ensemble": {}, "sweep": {}}
    for name, pcfg, fcfg in cases():
        outs = run_ensemble(graph, pcfg, fcfg, steps=STEPS, seeds=SEEDS,
                            base_key=BASE_KEY, outputs="full")
        payload["ensemble"][name] = _outputs_to_dict(outs)

    sweep_cases = [
        (_pcfg("decafork", eps=e), f)
        for e, f in zip((1.4, 2.2), (FailureConfig(burst_times=(20,), burst_sizes=(2,)),
                                     FailureConfig(burst_times=(30,), burst_sizes=(1,), p_fail=0.002)))
    ]
    outs = run_sweep(graph, sweep_cases, steps=STEPS, seeds=SEEDS,
                     base_key=BASE_KEY, outputs="full")
    payload["sweep"]["decafork/eps-grid"] = _outputs_to_dict(outs)

    with open(OUT, "w") as f:
        json.dump(payload, f)
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
