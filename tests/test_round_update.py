"""Fused round kernel (``estimator_impl="fused"``): bitwise oracle tests.

The contract is *bitwise* (not allclose): the fused pass must be freely
interchangeable with the unfused sequence — ``record_returns`` ->
``last_seen`` scatter-max -> ``node_sums_compare`` — in the middle of a
compiled trajectory, so every output (updated observation state AND node
theta sums) must match the reference exactly, on arbitrary shapes
including node counts that are not a multiple of the Pallas tile.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import estimator as est
from repro.kernels.round_update import (
    random_round_inputs as _random_round,  # the shared round fixture
    round_update,
    round_update_pallas,
    round_update_ref,
)

KEY = jax.random.key(123)

FIELDS = ("last_seen", "hist", "total", "sums")


def _unfused_reference(ls, hist, total, pos, track, r, valid, upd, t):
    rts = est.record_returns(est.ReturnTimeState(hist, total), pos, r, valid)
    ls2 = ls.at[pos, track].max(upd, mode="drop")
    sums = est.node_sums_compare(ls2, rts.hist, rts.total, t)
    return ls2, rts.hist, rts.total, sums


def _assert_bitwise(got, want, label):
    for name, a, b in zip(FIELDS, got, want):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=f"{label}: {name}"
        )


# shapes deliberately include n that are NOT multiples of the node tile
SHAPES = [(8, 4, 16, 4), (30, 12, 64, 12), (13, 7, 33, 7), (17, 5, 16, 5),
          (64, 40, 128, 40), (100, 64, 256, 64)]


@pytest.mark.parametrize("n,C,B,W", SHAPES)
def test_ref_is_the_unfused_sequence(n, C, B, W):
    args = _random_round(jax.random.fold_in(KEY, n * B + W), n, C, B, W)
    _assert_bitwise(
        round_update_ref(*args), _unfused_reference(*args), f"ref n={n}"
    )


@pytest.mark.parametrize("n,C,B,W", SHAPES)
def test_pallas_bitwise_vs_oracle(n, C, B, W):
    """The node-tiled Pallas kernel (interpret mode) == the unfused
    reference, bitwise, including padded (non-tile-multiple) n."""
    args = _random_round(jax.random.fold_in(KEY, 7 * n + B), n, C, B, W)
    got = round_update_pallas(*args, interpret=True)
    _assert_bitwise(got, _unfused_reference(*args), f"pallas n={n}")


@pytest.mark.parametrize("block_nodes", [3, 8, 16, 100])
def test_pallas_block_size_invariance(block_nodes):
    args = _random_round(jax.random.fold_in(KEY, block_nodes), 22, 6, 32, 6)
    got = round_update_pallas(*args, block_nodes=block_nodes, interpret=True)
    _assert_bitwise(got, _unfused_reference(*args), f"bn={block_nodes}")


def test_round_update_dispatch():
    args = _random_round(jax.random.fold_in(KEY, 999), 16, 5, 24, 5)
    want = _unfused_reference(*args)
    _assert_bitwise(round_update(*args, impl="ref"), want, "impl=ref")
    # default dispatch resolves per backend and stays on the contract
    _assert_bitwise(round_update(*args), want, "impl=auto")
    with pytest.raises(ValueError, match="round impl"):
        round_update(*args, impl="bogus")


def test_no_observations_round():
    """A round where no walk records anything (all inactive) must leave
    the state untouched and still produce the oracle sums."""
    ls, hist, total, pos, track, r, valid, upd, t = _random_round(
        jax.random.fold_in(KEY, 5), 14, 4, 16, 4
    )
    valid = jnp.zeros_like(valid)
    upd = jnp.full_like(upd, est.NEVER)
    args = (ls, hist, total, pos, track, r, valid, upd, t)
    got = round_update_pallas(*args, interpret=True)
    _assert_bitwise(got, _unfused_reference(*args), "silent round")
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(hist))
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(ls))


# ---------------------------------------------------------------------------
# in-simulator equivalence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("alg", ["decafork", "decafork+"])
def test_fused_impl_matches_compare_trajectory(alg):
    """estimator_impl='fused' drives the exact same protocol trajectory
    as 'compare' (its oracle) inside a real multi-round simulation."""
    from repro.api import Experiment
    from repro.core import FailureConfig, ProtocolConfig
    from repro.graphs import random_regular_graph

    g = random_regular_graph(19, 4, seed=2)  # n=19: not a tile multiple
    fcfg = FailureConfig(burst_times=(40,), burst_sizes=(2,))
    outs = {}
    for impl in ("compare", "fused"):
        pcfg = ProtocolConfig(
            algorithm=alg, z0=4, max_walks=8, eps=1.4, eps2=6.0,
            protocol_start=20, rt_bins=64, estimator_impl=impl,
        )
        _, o = Experiment(graph=g, protocol=pcfg, failures=fcfg, steps=120,
                          outputs="full").run(key=11)
        outs[impl] = o
    for name in outs["compare"]._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(outs["fused"], name)),
            np.asarray(getattr(outs["compare"], name)),
            err_msg=f"{alg}: field {name}",
        )


def test_auto_impl_resolves_per_backend():
    """estimator_impl='auto' picks the backend's best implementation and
    (on CPU) is bitwise the gather path."""
    from repro.api import Experiment
    from repro.core import FailureConfig, ProtocolConfig
    from repro.graphs import random_regular_graph
    from repro.kernels.platform import best_estimator_impl

    g = random_regular_graph(16, 4, seed=4)
    want_impl = best_estimator_impl()
    assert want_impl in ("gather", "fused")
    ref_z = {}
    for impl in ("auto", want_impl):
        pcfg = ProtocolConfig(
            algorithm="decafork", z0=4, max_walks=8, eps=1.4,
            protocol_start=20, rt_bins=32, estimator_impl=impl,
        )
        _, o = Experiment(graph=g, protocol=pcfg, steps=80).run(key=3)
        ref_z[impl] = np.asarray(o.z)
    np.testing.assert_array_equal(ref_z["auto"], ref_z[want_impl])
