"""Assignment deliverable (f): per-architecture reduced smoke tests.

For each of the 10 assigned architectures: instantiate the REDUCED
same-family variant (<= 2 layers, d_model <= 512, <= 4 experts), run one
forward/train step and one cached decode step on CPU, assert output
shapes and absence of NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.data import random_batch_like
from repro.models.model import Model, batch_spec

B, S = 2, 64


@pytest.fixture(scope="module")
def key():
    return jax.random.key(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_constraints(arch):
    cfg = get_smoke_config(arch)
    full = get_config(arch)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    assert cfg.moe_num_experts <= 4
    assert cfg.arch_type == full.arch_type  # same family


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_shapes_and_finite(arch, key):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(key)
    batch = random_batch_like(batch_spec(cfg, B, S, "train"), key)
    # clip synthetic tokens into the smoke vocab
    batch["tokens"] = batch["tokens"] % cfg.vocab_size
    batch["labels"] = batch["labels"] % cfg.vocab_size

    from repro.launch.train import make_train_step
    from repro.optim import sgd

    opt = sgd(1e-3)
    step = jax.jit(make_train_step(model, opt))
    new_params, _, metrics = step(params, opt.init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    # params changed and stayed finite
    moved = jax.tree.map(
        lambda a, b: not np.allclose(np.asarray(a), np.asarray(b)),
        params, new_params,
    )
    assert any(jax.tree.leaves(moved))
    for leaf in jax.tree.leaves(new_params):
        assert np.isfinite(np.asarray(leaf, dtype=np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_shapes(arch, key):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(key)
    cache = model.init_cache(B, 128)
    batch = random_batch_like(batch_spec(cfg, B, S, "decode"), key)
    batch["tokens"] = batch["tokens"] % cfg.vocab_size
    logits, new_cache = jax.jit(model.decode_step)(params, cache, batch)
    if cfg.num_codebooks:
        assert logits.shape == (B, 1, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert int(new_cache["next_pos"][0]) == 1


@pytest.mark.parametrize("arch", ["yi_6b", "mamba2_1_3b", "hymba_1_5b", "dbrx_132b"])
def test_decode_matches_full_forward(arch, key):
    """Replay a sequence token-by-token through the cache and compare
    against the full-sequence forward pass — exercises KV ring buffers,
    SSD-vs-recurrent equivalence, and MoE decode routing."""
    import dataclasses

    cfg = get_smoke_config(arch)
    if cfg.arch_type == "moe":
        # dropless capacity: the full-sequence pass must not drop tokens,
        # or it can't match the per-token decode path
        cfg = dataclasses.replace(cfg, capacity_factor=4.0)
    model = Model(cfg)
    params = model.init(key)
    T = 24
    toks = jax.random.randint(jax.random.fold_in(key, 1), (B, T), 0, cfg.vocab_size)
    full = model.forward_logits(params, {"tokens": toks})  # (B, T, V)
    cache = model.init_cache(B, T + 4)
    dec = jax.jit(model.decode_step)
    outs = []
    for i in range(T):
        logits, cache = dec(params, cache, {"tokens": toks[:, i : i + 1]})
        outs.append(np.asarray(logits[:, 0], np.float32))
    got = np.stack(outs, axis=1)
    want = np.asarray(full, np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["granite_8b", "mamba2_1_3b", "deepseek_v2_236b"])
def test_prefill_matches_forward(arch, key):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(key)
    batch = random_batch_like(batch_spec(cfg, B, 32, "prefill"), key)
    batch["tokens"] = batch["tokens"] % cfg.vocab_size
    last, cache = jax.jit(model.prefill)(params, batch)
    full = model.forward_logits(params, batch)
    np.testing.assert_allclose(
        np.asarray(last[:, 0], np.float32),
        np.asarray(full[:, -1], np.float32),
        rtol=2e-3, atol=2e-3,
    )
    assert int(cache["next_pos"][0]) == (
        32 if cfg.arch_type != "vlm" else 32
    )


def test_sliding_window_variant_lowers_flops():
    """The long_500k adjustment must actually change the attention mask."""
    import dataclasses

    from repro.configs.shapes import SHAPES, adjust_config

    cfg = get_config("yi_6b")
    adj = adjust_config(cfg, SHAPES["long_500k"])
    assert adj.sliding_window == 8192
    assert adjust_config(cfg, SHAPES["train_4k"]).sliding_window == 0


def test_param_count_matches_init():
    for arch in ["yi_6b", "mamba2_1_3b", "dbrx_132b", "qwen2_vl_2b", "musicgen_large"]:
        cfg = get_smoke_config(arch)
        model = Model(cfg)
        shapes = model.init_shapes()
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
        predicted = cfg.param_count()
        assert abs(actual - predicted) / actual < 0.05, (arch, actual, predicted)
