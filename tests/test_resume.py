"""Durable segmented execution (ISSUE 9 tentpole): bitwise resume.

Contract under test:
  * a run/ensemble/sweep executed in ``segment_steps`` chunks is BITWISE
    the monolithic call — recorded outputs, payload outputs, and the
    final carried state — for every algorithm and under the churniest
    zoo scenario (node/link churn + bursts + mobile Pac-Men + cuts);
  * interrupt-at-any-segment-boundary-then-resume (a SimulatedKill, the
    snapshot already on disk) reproduces the uninterrupted trajectory
    bitwise, and resume is chunking-independent;
  * segmented and monolithic sweeps share one result-store content key
    (warm hits interchange), and completed runs clear their snapshots;
  * segment snapshots survive torn writes (fall back to the previous
    snapshot) — and the checkpoint layer round-trips the full modern
    SimState (int16 histograms, cumulative estimator carry, zoo
    prev/bloom/pacman_pos columns, typed PRNG keys, payload carry)
    exactly, rejecting shape/dtype drift with a named error.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Experiment
from repro.api.store import ResultStore
from repro.checkpoint import (
    CheckpointMismatchError,
    load_pytree,
    save_pytree,
)
from repro.core import FailureConfig, ProtocolConfig
from repro.core import simulator as sim
from repro.graphs import random_regular_graph
from repro.sweep import Scenario
from repro.utils.faults import FaultPlan, Kill, SimulatedKill, Torn

N, DEG, W, Z0, STEPS, SEEDS, BASE_KEY = 24, 4, 10, 5, 36, 2, 7


@pytest.fixture(scope="module")
def graph():
    return random_regular_graph(N, DEG, seed=3)


def _pcfg(alg="decafork", **kw):
    base = dict(algorithm=alg, z0=Z0, max_walks=W, rt_bins=32,
                protocol_start=8, eps=1.8)
    base.update(kw)
    return ProtocolConfig(**base)


def _churny_fcfg(**kw):
    """The kitchen-sink zoo scenario: bursts + i.i.d. node/link churn +
    mobile Pac-Men (scan-carried positions) + a scheduled partition cut."""
    base = dict(
        burst_times=(9, 23), burst_sizes=(3, 2),
        p_node_fail=0.02, p_node_recover=0.3,
        p_link_fail=0.03, p_link_recover=0.4,
        pacman_nodes=(2, 11), pacman_mobile=True, pacman_hop_prob=0.5,
        edge_cut_times=(15,), edge_cut_thresholds=(12,),
    )
    base.update(kw)
    return FailureConfig(**base)


def _plan(graph, pcfg, fcfg, **kw):
    return Experiment(
        graph=graph, protocol=pcfg, failures=fcfg, steps=STEPS, **kw
    ).plan()


def _leaves(tree):
    out = []
    for leaf in jax.tree_util.tree_leaves(tree):
        if jax.dtypes.issubdtype(
            getattr(leaf, "dtype", np.dtype("f4")), jax.dtypes.prng_key
        ):
            leaf = jax.random.key_data(leaf)
        out.append(np.asarray(leaf))
    return out


def _assert_tree_equal(ref, got, label):
    rl, gl = _leaves(ref), _leaves(got)
    assert len(rl) == len(gl), f"{label}: leaf count {len(rl)} != {len(gl)}"
    for i, (a, b) in enumerate(zip(rl, gl)):
        np.testing.assert_array_equal(a, b, err_msg=f"{label}: leaf {i}")


def _assert_tree_close(ref, got, label, rtol=1e-6, atol=1e-6):
    """Integer leaves exact; float leaves to the last ulp.

    For payload floats compared ACROSS compiled programs (segmented vs
    monolithic), XLA may re-fuse reductions — the documented PR-5
    caveat. Default tolerances fit the per-step telemetry (last-ulp);
    optimizer state after many training steps amplifies that ulp noise
    chaotically per parameter (adamw divides by near-zero second
    moments), so carry comparisons pass looser bounds explicitly.
    Same-chunking comparisons stay on _assert_tree_equal.
    """
    rl, gl = _leaves(ref), _leaves(got)
    assert len(rl) == len(gl), f"{label}: leaf count {len(rl)} != {len(gl)}"
    for i, (a, b) in enumerate(zip(rl, gl)):
        if np.issubdtype(a.dtype, np.floating):
            np.testing.assert_allclose(
                a, b, rtol=rtol, atol=atol, err_msg=f"{label}: leaf {i}"
            )
        else:
            np.testing.assert_array_equal(a, b, err_msg=f"{label}: leaf {i}")


# ---------------------------------------------------------------------------
# golden: segmented == monolithic, bitwise, per algorithm x churny zoo
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("alg", ["none", "missingperson", "decafork",
                                 "decafork+"])
def test_segmented_run_bitwise_per_algorithm(graph, alg):
    """run_segmented is bitwise run() — final state AND every recorded
    field — for every algorithm under the churny zoo scenario (an uneven
    final chunk included: 13 does not divide 36)."""
    plan = _plan(graph, _pcfg(alg), _churny_fcfg())
    s_mono, r_mono = plan.run(BASE_KEY)
    s_seg, r_seg = plan.run_segmented(BASE_KEY, segment_steps=13)
    _assert_tree_equal(r_mono, r_seg, f"{alg}: recorded")
    _assert_tree_equal(s_mono, s_seg, f"{alg}: final state")


def test_segmented_bloom_variant_bitwise(graph):
    """The bloom walk variant carries prev/bloom columns through the
    scan — they must round-trip segment boundaries bitwise too."""
    plan = _plan(graph, _pcfg(walk_variant="bloom", bloom_bits=64),
                 _churny_fcfg())
    s_mono, r_mono = plan.run(BASE_KEY)
    s_seg, r_seg = plan.run_segmented(BASE_KEY, segment_steps=10)
    _assert_tree_equal(r_mono, r_seg, "bloom: recorded")
    _assert_tree_equal(s_mono, s_seg, "bloom: final state")


def test_segmented_ensemble_bitwise(graph):
    plan = _plan(graph, _pcfg(), _churny_fcfg())
    ref = plan.ensemble(SEEDS, BASE_KEY)
    got = plan.ensemble_segmented(SEEDS, BASE_KEY, segment_steps=17)
    _assert_tree_equal(ref, got, "ensemble")


def test_segmented_sweep_bitwise_and_store_interchange(graph, tmp_path):
    """Segmented sweeps land under the SAME content key as monolithic
    ones (warm hits interchange both ways) and clear their snapshots on
    completion."""
    pcfg, fcfg = _pcfg(), _churny_fcfg()
    plan = _plan(graph, pcfg, fcfg)
    scens = [Scenario(f"e{e}", dataclasses.replace(pcfg, eps=e), fcfg)
             for e in (0.9, 1.8)]
    ref = plan.sweep_stacked(scens, seeds=SEEDS, base_key=1)
    store = ResultStore(tmp_path / "store")
    got = plan.sweep_stacked(scens, seeds=SEEDS, base_key=1, store=store,
                             segment_steps=15)
    _assert_tree_equal(ref, got, "segmented sweep")
    # the monolithic call must now be a warm hit on the segmented result
    before = store.hits
    warm = plan.sweep_stacked(scens, seeds=SEEDS, base_key=1, store=store)
    _assert_tree_equal(ref, warm, "warm interchange")
    assert store.hits == before + 1
    # completed runs own their key via the final result, not snapshots
    seg_root = os.path.join(store.root, "segments")
    leftover = [
        f for _, _, files in os.walk(seg_root) for f in files
    ] if os.path.isdir(seg_root) else []
    assert leftover == []


# ---------------------------------------------------------------------------
# kill-and-resume: the durable-execution invariant
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("boundary", [0, 1, 2])
def test_kill_at_any_boundary_then_resume_is_bitwise(graph, tmp_path,
                                                     boundary):
    """A SimulatedKill at the k-th segment boundary, then a fresh call:
    the resumed sweep picks up from the boundary snapshot and finishes
    bitwise identical to the never-interrupted run."""
    pcfg, fcfg = _pcfg(), _churny_fcfg()
    plan = _plan(graph, pcfg, fcfg)
    scens = [Scenario(f"e{e}", dataclasses.replace(pcfg, eps=e), fcfg)
             for e in (0.9, 1.8)]
    ref = plan.sweep_stacked(scens, seeds=SEEDS, base_key=1)
    store = ResultStore(tmp_path / "store")
    fp = FaultPlan().skip("segment.boundary", boundary).at(
        "segment.boundary", Kill()
    )
    with pytest.raises(SimulatedKill), fp.active():
        plan.sweep_stacked(scens, seeds=SEEDS, base_key=1, store=store,
                           segment_steps=10)
    assert fp.fired, "the kill must actually have fired"
    resumed = plan.sweep_stacked(scens, seeds=SEEDS, base_key=1, store=store,
                                 segment_steps=10)
    _assert_tree_equal(ref, resumed, f"kill@boundary{boundary} + resume")


def test_resume_is_chunking_independent(graph, tmp_path):
    """Snapshots are keyed by steps-done, not by segment length: a run
    killed under segment_steps=9 resumes bitwise under segment_steps=15."""
    pcfg, fcfg = _pcfg(), _churny_fcfg()
    plan = _plan(graph, pcfg, fcfg)
    scens = [Scenario("base", pcfg, fcfg)]
    ref = plan.sweep_stacked(scens, seeds=SEEDS, base_key=2)
    store = ResultStore(tmp_path / "store")
    fp = FaultPlan().skip("segment.boundary", 1).at(
        "segment.boundary", Kill()
    )
    with pytest.raises(SimulatedKill), fp.active():
        plan.sweep_stacked(scens, seeds=SEEDS, base_key=2, store=store,
                           segment_steps=9)
    resumed = plan.sweep_stacked(scens, seeds=SEEDS, base_key=2, store=store,
                                 segment_steps=15)
    _assert_tree_equal(ref, resumed, "cross-chunking resume")


def test_run_segmented_kill_resume(graph, tmp_path):
    """The single-trajectory surface resumes bitwise too (final state
    included — the obs-pad strip happens once, after the last segment)."""
    pcfg, fcfg = _pcfg(), _churny_fcfg()
    plan = _plan(graph, pcfg, fcfg)
    s_ref, r_ref = plan.run(BASE_KEY)
    store = ResultStore(tmp_path / "store")
    fp = FaultPlan().skip("segment.boundary", 1).at(
        "segment.boundary", Kill()
    )
    with pytest.raises(SimulatedKill), fp.active():
        plan.run_segmented(BASE_KEY, segment_steps=10, store=store)
    s_got, r_got = plan.run_segmented(BASE_KEY, segment_steps=10, store=store)
    _assert_tree_equal(r_ref, r_got, "run resume: recorded")
    _assert_tree_equal(s_ref, s_got, "run resume: final state")


# ---------------------------------------------------------------------------
# payload trajectories: RwSGD training rides the same invariant
# ---------------------------------------------------------------------------


def _tiny_payload():
    from repro.data import make_markov_task
    from repro.models.config import ModelConfig
    from repro.models.model import Model
    from repro.optim import RwSgdPayload, adamw

    cfg = ModelConfig(
        name="tiny", arch_type="dense", num_layers=1, d_model=32, d_ff=64,
        vocab_size=64, num_heads=2, num_kv_heads=2, head_dim=16,
        dtype="float32",
    )
    return RwSgdPayload(
        Model(cfg), adamw(1e-2), make_markov_task(cfg.vocab_size, rank=4),
        max_walks=W, local_batch=1, seq_len=8, train_every=2,
    )


@pytest.mark.slow
def test_payload_segmented_bitwise(graph):
    """Segmented payload runs reproduce the control plane bitwise; the
    payload's float telemetry/carry is compared across two DIFFERENT
    compiled programs (chunked vs monolithic scan), where XLA may
    re-fuse the loss/grad reductions at the last ulp — so floats get
    the PR-5 allclose treatment, integers stay exact."""
    plan = _plan(graph, _pcfg(), _churny_fcfg(), payload=_tiny_payload())
    (s_ref, pc_ref), (r_ref, p_ref) = plan.run(BASE_KEY)
    (s_got, pc_got), (r_got, p_got) = plan.run_segmented(
        BASE_KEY, segment_steps=13
    )
    _assert_tree_equal(r_ref, r_got, "payload: recorded")
    _assert_tree_equal(s_ref, s_got, "payload: final state")
    _assert_tree_close(p_ref, p_got, "payload: payload outputs")
    _assert_tree_close(pc_ref, pc_got, "payload: payload carry",
                       rtol=1e-2, atol=1e-4)


@pytest.mark.slow
def test_payload_kill_resume_bitwise(graph, tmp_path):
    """Kill-and-resume holds bitwise for training runs: the payload
    carry (replica params + optimizer state) round-trips the snapshot.

    The reference is the UNINTERRUPTED segmented run — the durability
    invariant is interrupt-then-resume == uninterrupted, and with the
    same segment_steps both arms run the same compiled chunk programs,
    so even the payload floats must match exactly. (Monolithic-vs-
    segmented float drift is covered, allclose, above.)"""
    pcfg, fcfg = _pcfg(), _churny_fcfg()
    plan = _plan(graph, pcfg, fcfg, payload=_tiny_payload())
    r_ref, p_ref = plan.ensemble_segmented(SEEDS, BASE_KEY, segment_steps=12)
    store = ResultStore(tmp_path / "store")
    fp = FaultPlan().skip("segment.boundary", 1).at(
        "segment.boundary", Kill()
    )
    with pytest.raises(SimulatedKill), fp.active():
        plan.ensemble_segmented(SEEDS, BASE_KEY, segment_steps=12,
                                store=store)
    r_got, p_got = plan.ensemble_segmented(SEEDS, BASE_KEY, segment_steps=12,
                                           store=store)
    _assert_tree_equal(r_ref, r_got, "payload resume: recorded")
    _assert_tree_equal(p_ref, p_got, "payload resume: payload outputs")


# ---------------------------------------------------------------------------
# snapshot torn-write recovery
# ---------------------------------------------------------------------------


def test_torn_snapshot_falls_back_to_previous(graph, tmp_path):
    """A torn latest snapshot (killed mid-write, pre-atomic file at the
    final path) must fall back to the previous boundary's snapshot —
    and the resumed run still finishes bitwise."""
    pcfg, fcfg = _pcfg(), _churny_fcfg()
    plan = _plan(graph, pcfg, fcfg)
    scens = [Scenario("base", pcfg, fcfg)]
    ref = plan.sweep_stacked(scens, seeds=SEEDS, base_key=3)
    store = ResultStore(tmp_path / "store")
    # let the first snapshot land, tear the second mid-write
    fp = FaultPlan().skip("checkpoint.write", 2).at(
        "checkpoint.write", Torn(keep_bytes=40)
    )
    with pytest.raises(SimulatedKill), fp.active():
        plan.sweep_stacked(scens, seeds=SEEDS, base_key=3, store=store,
                           segment_steps=9)
    resumed = plan.sweep_stacked(scens, seeds=SEEDS, base_key=3, store=store,
                                 segment_steps=9)
    _assert_tree_equal(ref, resumed, "torn snapshot + resume")


def test_latest_segment_skips_torn_and_deeper_snapshots(graph, tmp_path):
    """latest_segment: a torn newest file falls back to the next-older
    loadable snapshot; snapshots deeper than max_steps are ignored."""
    store = ResultStore(tmp_path / "store")
    snap = {"carry": jnp.arange(4, dtype=jnp.int32), "recorded": None}
    store.put_segment("k" * 64, 10, snap)
    store.put_segment("k" * 64, 20, snap)
    fp = FaultPlan().at("checkpoint.write", Torn(keep_bytes=16))
    with pytest.raises(SimulatedKill), fp.active():
        store.put_segment("k" * 64, 30, snap)
    steps_done, got = store.latest_segment("k" * 64)
    assert steps_done == 20
    np.testing.assert_array_equal(np.asarray(got["carry"]), np.arange(4))
    # a stale deeper run must not leak into a shorter one
    assert store.latest_segment("k" * 64, max_steps=15)[0] == 10
    store.clear_segments("k" * 64)
    assert store.latest_segment("k" * 64) is None


# ---------------------------------------------------------------------------
# checkpoint round-trip of the full modern carry (satellite)
# ---------------------------------------------------------------------------


def test_full_simstate_checkpoint_roundtrip(graph, tmp_path):
    """The complete segmented carry — SimState with int16 histogram /
    cumulative estimator carry, zoo prev/bloom columns, mobile Pac-Man
    positions, GraphState churn masks, typed PRNG key — survives
    save_pytree/load_pytree bitwise."""
    pcfg = _pcfg(walk_variant="bloom", bloom_bits=64)
    fcfg = _churny_fcfg()
    plan = _plan(graph, pcfg, fcfg)
    state, _ = plan.run_segmented(BASE_KEY, segment_steps=STEPS)
    path = str(tmp_path / "state")
    save_pytree(path, state)
    restored = load_pytree(path, state)
    _assert_tree_equal(state, restored, "SimState round-trip")
    # the restored key is a working typed key, not just equal bytes
    assert jax.dtypes.issubdtype(restored.key.dtype, jax.dtypes.prng_key)
    jax.random.fold_in(restored.key, 1)


@pytest.mark.slow
def test_payload_carry_checkpoint_roundtrip(graph, tmp_path):
    """Replica params + optimizer state round-trip exactly (the payload
    carry is what makes a killed training run resumable)."""
    plan = _plan(graph, _pcfg(), _churny_fcfg(), payload=_tiny_payload())
    (state, pcarry), _ = plan.run(BASE_KEY)
    path = str(tmp_path / "carry")
    save_pytree(path, pcarry)
    restored = load_pytree(path, pcarry)
    _assert_tree_equal(pcarry, restored, "payload carry round-trip")


def test_load_pytree_rejects_shape_and_dtype_drift(tmp_path):
    """CheckpointMismatchError names EVERY mismatching leaf — a drifted
    schema must never silently reinterpret arrays."""
    path = str(tmp_path / "ck")
    save_pytree(path, {"a": jnp.zeros((3,), jnp.float32),
                       "b": jnp.zeros((2, 2), jnp.int32),
                       "c": jnp.zeros((4,), jnp.float32)})
    like = {"a": jnp.zeros((4,), jnp.float32),     # shape drift
            "b": jnp.zeros((2, 2), jnp.int16),     # dtype drift
            "c": jnp.zeros((4,), jnp.float32)}     # fine
    with pytest.raises(CheckpointMismatchError) as ei:
        load_pytree(path, like)
    msg = str(ei.value)
    assert "a" in msg and "shape" in msg
    assert "b" in msg and "dtype" in msg
    assert len(ei.value.mismatches) == 2
    # missing leaves still raise the established KeyError
    with pytest.raises(KeyError):
        load_pytree(path, {"zz": jnp.zeros((1,))})


def test_load_pytree_bf16_exemption_still_exact(tmp_path):
    """bf16 leaves store as f32 (exact) and cast back (exact) — the one
    sanctioned dtype mismatch; anything else still raises."""
    path = str(tmp_path / "bf")
    save_pytree(path, {"w": jnp.arange(8, dtype=jnp.bfloat16) / 3})
    out = load_pytree(path, {"w": jnp.zeros((8,), jnp.bfloat16)})
    assert out["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(out["w"].astype(jnp.float32)),
        np.asarray((jnp.arange(8, dtype=jnp.bfloat16) / 3).astype(jnp.float32)),
    )
    with pytest.raises(CheckpointMismatchError):
        load_pytree(path, {"w": jnp.zeros((8,), jnp.float16)})
