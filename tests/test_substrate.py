"""Optimizers, RW-SGD replicas, data pipeline, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import make_markov_task, node_batches, sample_batch
from repro.optim import adamw, cosine_schedule, fork_replica, init_replicas, sgd
from repro.optim.rw_sgd import replica_train_step


def _quadratic(params, batch):
    loss = jnp.sum((params["w"] - 3.0) ** 2) + jnp.sum((params["b"] + 1.0) ** 2)
    return loss, {}


@pytest.mark.parametrize("opt", [sgd(0.1), sgd(0.05, momentum=0.9), adamw(0.3)])
def test_optimizers_converge(opt):
    params = {"w": jnp.zeros((4,)), "b": jnp.zeros((2,))}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: _quadratic(p, None)[0])(params)
        params, state = opt.update(grads, state, params)
    np.testing.assert_allclose(np.asarray(params["w"]), 3.0, atol=3e-2)
    np.testing.assert_allclose(np.asarray(params["b"]), -1.0, atol=3e-2)


def test_cosine_schedule():
    s = cosine_schedule(1.0, warmup=10, total=100)
    assert float(s(jnp.int32(0))) == 0.0
    np.testing.assert_allclose(float(s(jnp.int32(10))), 1.0, rtol=1e-5)
    assert float(s(jnp.int32(100))) <= 0.11


def test_adamw_bf16_params():
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    opt = adamw(0.01)
    state = opt.init(params)
    assert state.mu["w"].dtype == jnp.float32  # moments in f32
    grads = {"w": jnp.ones((4,), jnp.bfloat16)}
    new_params, _ = opt.update(grads, state, params)
    assert new_params["w"].dtype == jnp.bfloat16
    assert float(new_params["w"][0]) < 1.0


def test_replica_fork_and_step():
    init_fn = lambda key: {"w": jax.random.normal(key, (3,))}
    opt = sgd(0.1)
    rs = init_replicas(init_fn, opt.init, jax.random.key(0), max_walks=4)
    assert rs.params["w"].shape == (4, 3)

    def loss_fn(params, batch):
        return jnp.sum((params["w"] - batch) ** 2), {}

    step = replica_train_step(loss_fn, opt)
    batches = jnp.stack([jnp.full((3,), float(i)) for i in range(4)])
    active = jnp.array([True, True, False, False])
    rs2, losses = step(rs, batches, active)
    # active replicas moved toward their targets, inactive untouched
    assert not np.allclose(rs2.params["w"][0], rs.params["w"][0])
    np.testing.assert_array_equal(rs2.params["w"][2], rs.params["w"][2])
    assert float(losses[2]) == 0.0
    np.testing.assert_array_equal(np.asarray(rs2.steps), [1, 1, 0, 0])

    # fork slot 0 -> slot 3 (DECAFORK duplicate semantics)
    rs3 = fork_replica(rs2, jnp.int32(0), jnp.int32(3), jnp.asarray(True))
    np.testing.assert_array_equal(rs3.params["w"][3], rs2.params["w"][0])
    # no-op fork when do=False
    rs4 = fork_replica(rs2, jnp.int32(0), jnp.int32(3), jnp.asarray(False))
    np.testing.assert_array_equal(rs4.params["w"][3], rs2.params["w"][3])


def test_markov_task_learnable_floor():
    task = make_markov_task(64)
    assert 0.0 < task.entropy < np.log(64)
    b = sample_batch(task, jax.random.key(0), batch=8, seq=32)
    assert b["tokens"].shape == (8, 32)
    np.testing.assert_array_equal(
        np.asarray(b["tokens"][:, 1:]), np.asarray(b["labels"][:, :-1])
    )
    # deterministic per (key, node)
    b2 = sample_batch(task, jax.random.key(0), batch=8, seq=32)
    np.testing.assert_array_equal(np.asarray(b["tokens"]), np.asarray(b2["tokens"]))
    b3 = sample_batch(task, jax.random.key(0), batch=8, seq=32, node_id=5)
    assert not (np.asarray(b["tokens"]) == np.asarray(b3["tokens"])).all()


def test_node_batches_shapes():
    task = make_markov_task(32)
    nb = node_batches(task, jax.random.key(1), n_nodes=6, batch=2, seq=16)
    assert nb["tokens"].shape == (6, 2, 16)


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import load_pytree, save_pytree

    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
    }
    path = os.path.join(tmp_path, "ckpt.npz")
    save_pytree(path, tree, metadata={"step": 7})
    out = load_pytree(path, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert out["nested"]["b"].dtype == jnp.bfloat16
    assert os.path.exists(path + ".meta.json")
    # structure mismatch raises
    with pytest.raises(KeyError):
        load_pytree(path, {"missing": tree["a"]})


def test_walk_snapshot(tmp_path):
    from repro.checkpoint import save_walk_snapshot, load_pytree

    stack = {"w": jnp.arange(12, dtype=jnp.float32).reshape(4, 3)}
    p = os.path.join(tmp_path, "walk.npz")
    save_walk_snapshot(p, stack, walk_slot=2, step=5)
    out = load_pytree(p, {"w": jnp.zeros((3,))})
    np.testing.assert_array_equal(np.asarray(out["w"]), [6.0, 7.0, 8.0])
