"""MoE dispatch semantics and SSM details beyond the smoke tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import moe as moe_lib


@pytest.fixture()
def cfg():
    return get_smoke_config("dbrx_132b")  # 4 experts top-2, no shared


def test_moe_matches_dense_reference(cfg):
    """With generous capacity, sort-based dispatch == per-token dense mix."""
    key = jax.random.key(0)
    params = moe_lib.moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model))
    y, aux = moe_lib.moe_apply(params, x, cfg)

    # dense reference: every token through its top-k experts explicitly
    xf = np.asarray(x).reshape(-1, cfg.d_model)
    logits = xf @ np.asarray(params["router"])
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    top = np.argsort(-probs, axis=-1)[:, : cfg.moe_top_k]
    ref = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        g = probs[t, top[t]]
        g = g / g.sum()
        for j, e in enumerate(top[t]):
            gate = np.asarray(params["gate"][e])
            up = np.asarray(params["up"][e])
            down = np.asarray(params["down"][e])
            h = (xf[t] @ gate)
            h = h / (1 + np.exp(-h)) * (xf[t] @ up)
            ref[t] += g[j] * (h @ down)
    np.testing.assert_allclose(
        np.asarray(y).reshape(-1, cfg.d_model), ref, rtol=2e-3, atol=2e-3
    )
    assert float(aux) > 0


def test_moe_capacity_drops_tokens(cfg):
    """With capacity_factor ~ 0, most tokens drop -> near-zero output."""
    import dataclasses

    tight = dataclasses.replace(cfg, capacity_factor=1e-6)
    key = jax.random.key(0)
    params = moe_lib.moe_init(key, tight, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 64, tight.d_model))
    y, _ = moe_lib.moe_apply(params, x, tight)
    y_full, _ = moe_lib.moe_apply(params, x, cfg)
    # tight capacity must produce strictly smaller output energy
    assert float(jnp.sum(y**2)) < float(jnp.sum(y_full**2))


def test_moe_capacity_rounding():
    cfg = get_smoke_config("dbrx_132b")
    assert moe_lib.moe_capacity(1024, cfg) % 8 == 0
    assert moe_lib.moe_capacity(1, cfg) == 8  # floor


def test_mla_shapes():
    cfg = get_smoke_config("deepseek_v2_236b")
    key = jax.random.key(0)
    p = moe_lib.mla_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model))
    qn, qr = moe_lib.mla_project_q(p, x, cfg)
    assert qn.shape == (2, 8, cfg.num_heads, cfg.head_dim)
    assert qr.shape == (2, 8, cfg.num_heads, cfg.rope_head_dim)
    ckv, kr = moe_lib.mla_compress_kv(p, x, cfg)
    assert ckv.shape == (2, 8, cfg.kv_lora_rank)
    assert kr.shape == (2, 8, cfg.rope_head_dim)
    k, v = moe_lib.mla_decompress(p, ckv)
    assert k.shape == v.shape == (2, 8, cfg.num_heads, cfg.head_dim)


def test_ssm_decode_state_evolution():
    """Decode state must change with inputs and decay without them."""
    from repro.models import ssm as ssm_lib

    cfg = get_smoke_config("mamba2_1_3b")
    key = jax.random.key(0)
    p = ssm_lib.ssm_init(key, cfg, jnp.float32)
    B = 2
    state = jnp.zeros((B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state))
    conv = jnp.zeros((B, cfg.ssm_conv - 1, cfg.ssm_d_inner + 2 * cfg.ssm_state))
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, 1, cfg.d_model))
    y1, st1, cv1 = ssm_lib.ssm_decode_step(p, x, state, conv, cfg)
    assert float(jnp.abs(st1).sum()) > 0
    y2, st2, _ = ssm_lib.ssm_decode_step(p, jnp.zeros_like(x), st1, cv1, cfg)
    # zero input: state decays toward zero (|g| < 1)
    assert float(jnp.abs(st2).sum()) < float(jnp.abs(st1).sum()) * 1.5


def test_mrope_sections_sum():
    from repro.models.layers import apply_mrope

    cfg = get_smoke_config("qwen2_vl_2b")
    assert sum(cfg.mrope_sections) == cfg.head_dim // 2
    x = jnp.ones((1, 4, 2, cfg.head_dim))
    p3 = jnp.zeros((3, 1, 4), jnp.int32)
    out = apply_mrope(x, p3, 10000.0, cfg.mrope_sections)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), atol=1e-6)  # pos 0 = identity


@pytest.mark.slow
def test_grouped_moe_matches_global():
    """§Perf-2 path: shard-local grouped dispatch == global dispatch
    (dropless capacity)."""
    import dataclasses

    cfg = dataclasses.replace(
        get_smoke_config("dbrx_132b"), capacity_factor=4.0
    )
    key = jax.random.key(0)
    p = moe_lib.moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 16, cfg.d_model))
    y0, _ = moe_lib.moe_apply(p, x, cfg)
    y1, _ = moe_lib.moe_apply(p, x, dataclasses.replace(cfg, moe_groups=4))
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=2e-4, atol=2e-4)


@pytest.mark.slow
def test_absorbed_mla_matches_naive_decode():
    """§Perf-3 path: absorbed-matmul MLA decode == naive decompression."""
    import dataclasses

    from repro.models.model import Model

    cfg = get_smoke_config("deepseek_v2_236b")
    m = Model(cfg)
    key = jax.random.key(0)
    params = m.init(key)
    toks = jax.random.randint(jax.random.fold_in(key, 2), (2, 8), 0, cfg.vocab_size)

    def replay(cfgx):
        mm = Model(cfgx)
        c = mm.init_cache(2, 12)
        dec = jax.jit(mm.decode_step)
        outs = []
        for i in range(8):
            lg, c = dec(params, c, {"tokens": toks[:, i : i + 1]})
            outs.append(np.asarray(lg[:, 0], np.float32))
        return np.stack(outs, 1)

    naive = replay(cfg)
    absorbed = replay(dataclasses.replace(cfg, mla_absorb=True))
    np.testing.assert_allclose(absorbed, naive, rtol=3e-3, atol=3e-3)


@pytest.mark.slow
def test_ssd_chunk_override_equivalent():
    """§Perf ssd_chunk knob changes tiling, not math."""
    import dataclasses

    from repro.models import ssm as ssm_lib

    cfg = get_smoke_config("mamba2_1_3b")
    key = jax.random.key(0)
    p = ssm_lib.ssm_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 128, cfg.d_model))
    y0 = ssm_lib.ssm_forward_train(p, x, cfg)
    y1 = ssm_lib.ssm_forward_train(
        p, x, dataclasses.replace(cfg, ssd_chunk=32)
    )
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=5e-4, atol=5e-4)
