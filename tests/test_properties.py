"""Hypothesis property-based tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dep: property tests")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import estimator as est
from repro.core import walkers as wlk
from repro.core.irwin_hall import irwin_hall_cdf, scaled_irwin_hall_cdf

SETTINGS = dict(max_examples=25, deadline=None)


@given(
    k=st.integers(1, 30),
    x=st.floats(-1.0, 31.0, allow_nan=False),
)
@settings(**SETTINGS)
def test_irwin_hall_is_cdf(k, x):
    v = float(irwin_hall_cdf(x, k))
    assert 0.0 <= v <= 1.0
    assert float(irwin_hall_cdf(x - 0.25, k)) <= v + 1e-9  # monotone
    if x <= 0:
        assert v == 0.0
    if x >= k:
        assert v > 1.0 - 1e-6  # grid path (k > 25) interpolates near 1


@given(
    k=st.integers(1, 10),
    support=st.floats(1e-3, 1.0),
    x=st.floats(0.0, 10.0),
)
@settings(**SETTINGS)
def test_scaled_irwin_hall_support(k, support, x):
    v = float(scaled_irwin_hall_cdf(x, k, support))
    assert 0.0 <= v <= 1.0
    if x >= k * support:
        assert v > 1.0 - 1e-9


@given(data=st.data())
@settings(**SETTINGS)
def test_survival_bounds_and_monotonicity(data):
    n = data.draw(st.integers(1, 8))
    bins = data.draw(st.integers(2, 32))
    seed = data.draw(st.integers(0, 2**30))
    key = jax.random.key(seed)
    hist = (jax.random.uniform(key, (n, bins)) * 4).astype(jnp.float32)
    state = est.ReturnTimeState(hist=hist, total=hist.sum(1))
    cum = est.survival_cumulative(state)
    rs = jnp.arange(bins + 4, dtype=jnp.int32)
    for i in range(n):
        v = np.asarray(est.survival_eval(cum, state.total, jnp.full_like(rs, i), rs))
        assert (v >= -1e-6).all() and (v <= 1 + 1e-6).all()
        assert (np.diff(v) <= 1e-6).all()


@given(data=st.data())
@settings(**SETTINGS)
def test_fork_allocation_invariants(data):
    """Never exceeds capacity, never double-assigns a slot, preserves
    existing walks."""
    W = data.draw(st.integers(2, 16))
    seed = data.draw(st.integers(0, 2**30))
    key = jax.random.key(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    active = jax.random.uniform(k1, (W,)) < 0.5
    ev = jax.random.uniform(k2, (W,)) < 0.5
    pos = jax.random.randint(k3, (W,), 0, 5, dtype=jnp.int32)
    ws = wlk.WalkState(pos=pos, active=active, track=jnp.arange(W, dtype=jnp.int32))
    ls = jnp.zeros((5, W), jnp.int32)
    new_ws, _, n_forks, _fp = wlk.execute_forks(ws, ls, ev, pos, None, jnp.int32(3))
    n_free = int((~active).sum())
    n_ev = int(ev.sum())
    assert int(n_forks) == min(n_free, n_ev)
    # old actives survive
    assert bool(jnp.all(new_ws.active | ~active | ~active))
    assert int(new_ws.active.sum()) == int(active.sum()) + int(n_forks)


@given(data=st.data())
@settings(**SETTINGS)
def test_theta_identity_between_impls(data):
    """gather- and compare-based node estimators agree on random states."""
    seed = data.draw(st.integers(0, 2**30))
    n = data.draw(st.sampled_from([4, 8]))
    W = data.draw(st.integers(1, 6))
    bins = data.draw(st.sampled_from([8, 16]))
    t = data.draw(st.integers(0, 50))
    key = jax.random.key(seed)
    k1, k2 = jax.random.split(key)
    ls = jax.random.randint(k1, (n, W), -1, max(t, 1), dtype=jnp.int32)
    hist = jnp.round(jax.random.uniform(k2, (n, bins)) * 3)
    total = hist.sum(1)
    a = est.node_sums_compare(ls, hist, total, jnp.int32(t))
    from repro.kernels.ref import theta_sums_ref

    b = theta_sums_ref(ls, hist, total, jnp.int32(t))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


@given(
    n=st.integers(6, 40).filter(lambda v: v % 2 == 0),
    d=st.integers(3, 5),
    seed=st.integers(0, 100),
)
@settings(max_examples=10, deadline=None)
def test_regular_graph_properties(n, d, seed):
    from repro.graphs import random_regular_graph

    if d >= n:
        return
    g = random_regular_graph(n, d, seed=seed)
    assert (g.degrees == d).all()
    a = g.adjacency()
    assert (a == a.T).all() and not a.diagonal().any()
