"""Chaos suite for the host-level fault-injection harness (ISSUE 9).

Every named fault site in ``repro.utils.faults.SITES`` is exercised at
least once, and every injected failure must yield either a correct retry
or a clean per-future error — never a hang (every wait below carries a
timeout) and never a silently wrong result (recovered paths are compared
bitwise against an undisturbed reference).

Site coverage map:
  ``service.run_group``   retry/exhaustion/split tests below;
  ``store.get``           read-fault degradation test below;
  ``store.put``           snapshot write-behind degradation test below;
  ``segment.boundary``    kill-and-resume tests (here and test_resume);
  ``checkpoint.write``    torn-write tests (here via the matrix, and
                          test_resume's fallback tests).
"""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Experiment
from repro.api.service import (
    DeadlineExceededError,
    ExperimentService,
    ServiceClosedError,
    default_retryable,
)
from repro.api.store import ResultStore
from repro.core import FailureConfig, ProtocolConfig
from repro.graphs import random_regular_graph
from repro.sweep import Scenario
from repro.utils import faults
from repro.utils.faults import (
    Delay,
    FaultPlan,
    Kill,
    PermanentFault,
    Raise,
    SimulatedKill,
    Torn,
    TransientFault,
    fault_point,
)

N, W, Z0, STEPS, SEEDS, BASE_KEY = 24, 10, 5, 30, 2, 7
WAIT = 120.0  # every blocking call below is bounded: a hang is a failure


@pytest.fixture(scope="module")
def graph():
    return random_regular_graph(N, 4, seed=3)


def _pcfg(**kw):
    base = dict(algorithm="decafork", z0=Z0, max_walks=W, rt_bins=32,
                protocol_start=8, eps=1.8)
    base.update(kw)
    return ProtocolConfig(**base)


def _scen(name, **kw):
    fcfg = kw.pop("fcfg", FailureConfig())
    return Scenario(name, _pcfg(**kw), fcfg)


def _service(graph, **kw):
    kw.setdefault("store", None)
    kw.setdefault("autostart", False)
    kw.setdefault("backoff", 0.0)
    exp = Experiment(graph=graph, steps=STEPS, outputs="scalars",
                     scenarios=[_scen("base")])
    return ExperimentService(exp, **kw)


def _assert_tree_equal(ref, got, label):
    import jax

    rl = jax.tree_util.tree_leaves(ref)
    gl = jax.tree_util.tree_leaves(got)
    assert len(rl) == len(gl), label
    for a, b in zip(rl, gl):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=label)


# ---------------------------------------------------------------------------
# FaultPlan mechanics
# ---------------------------------------------------------------------------


def test_fault_point_is_noop_without_active_plan():
    assert fault_point("store.get") is None
    assert fault_point("checkpoint.write", tearable=True) is None


def test_plan_fifo_targets_kth_invocation_and_counts_hits():
    plan = FaultPlan().skip("store.get", 2).at(
        "store.get", Raise(TransientFault("boom"))
    )
    with plan.active():
        fault_point("store.get")
        fault_point("store.get")
        with pytest.raises(TransientFault, match="boom"):
            fault_point("store.get")
        fault_point("store.get")  # queue drained: back to no-op
    assert plan.hits["store.get"] == 4
    assert plan.pending("store.get") == 0
    assert [s for s, _ in plan.fired] == ["store.get"]


def test_plan_deactivates_on_exit_and_nests():
    outer, inner = FaultPlan(), FaultPlan()
    with outer.active():
        with inner.active():
            fault_point("store.put")
        fault_point("store.put")
    fault_point("store.put")
    assert inner.hits == {"store.put": 1}
    assert outer.hits == {"store.put": 1}


def test_torn_at_non_tearable_site_raises():
    plan = FaultPlan().at("store.get", Torn())
    with plan.active(), pytest.raises(RuntimeError, match="non-tearable"):
        fault_point("store.get")


def test_kill_is_a_base_exception():
    with pytest.raises(SimulatedKill):
        try:
            Kill().fire("segment.boundary")
        except Exception:  # a best-effort handler must NOT swallow a kill
            pytest.fail("SimulatedKill was caught by `except Exception`")


def test_delay_just_sleeps():
    plan = FaultPlan().at("store.put", Delay(0.01))
    t0 = time.monotonic()
    with plan.active():
        assert fault_point("store.put") is None
    assert time.monotonic() - t0 >= 0.01


def test_default_retryable_classification():
    assert default_retryable(TransientFault("x"))
    assert default_retryable(OSError("disk"))
    assert default_retryable(TimeoutError("slow"))
    assert not default_retryable(PermanentFault("x"))
    assert not default_retryable(ValueError("bad config"))


# ---------------------------------------------------------------------------
# service retry / degradation / deadline
# ---------------------------------------------------------------------------


def test_transient_fault_retries_then_succeeds_bitwise(graph):
    svc = _service(graph, retries=2)
    ref = svc.plan.sweep([_scen("a"), _scen("b", eps=0.9)], seeds=SEEDS,
                         base_key=BASE_KEY)
    plan = FaultPlan().at("service.run_group", Raise(TransientFault("blip")))
    with plan.active():
        fut = svc.submit([_scen("a"), _scen("b", eps=0.9)], seeds=SEEDS,
                         base_key=BASE_KEY)
        svc.flush(timeout=WAIT)
    got = fut.result(timeout=WAIT)
    assert svc.stats["retries"] == 1
    assert svc.stats["splits"] == 0
    for name in ("a", "b"):
        _assert_tree_equal(ref[name], got[name], f"retried result {name}")
    svc.close()


def test_retries_exhausted_fails_cleanly_service_survives(graph):
    svc = _service(graph, retries=1)
    # retries=1 -> two attempts, both transient-faulted; the group has a
    # single member, so there is nothing to split: clean failure
    plan = FaultPlan().at(
        "service.run_group",
        Raise(TransientFault("1")), Raise(TransientFault("2")),
    )
    with plan.active():
        fut = svc.submit([_scen("a")], seeds=SEEDS, base_key=BASE_KEY)
        svc.flush(timeout=WAIT)
        with pytest.raises(TransientFault):
            fut.result(timeout=WAIT)
    # the service is still healthy: the next submission succeeds
    ok = svc.submit([_scen("a")], seeds=SEEDS, base_key=BASE_KEY)
    svc.flush(timeout=WAIT)
    ref = svc.plan.sweep([_scen("a")], seeds=SEEDS, base_key=BASE_KEY)
    _assert_tree_equal(ref["a"], ok.result(timeout=WAIT)["a"],
                       "post-failure submission")
    svc.close()


def test_permanent_fault_never_retries(graph):
    svc = _service(graph, retries=3)
    plan = FaultPlan().at("service.run_group", Raise(PermanentFault("no")))
    with plan.active():
        fut = svc.submit([_scen("a")], seeds=SEEDS, base_key=BASE_KEY)
        svc.flush(timeout=WAIT)
        with pytest.raises(PermanentFault):
            fut.result(timeout=WAIT)
    assert svc.stats["retries"] == 0
    svc.close()


def test_injected_group_fault_splits_and_members_recover(graph):
    """A non-retryable fault on a 2-member group triggers the split;
    both members then succeed individually — bitwise."""
    svc = _service(graph, retries=0)
    scens = [_scen("a"), _scen("b", eps=0.9)]
    ref = svc.plan.sweep(scens, seeds=SEEDS, base_key=BASE_KEY)
    plan = FaultPlan().at("service.run_group", Raise(PermanentFault("grp")))
    with plan.active():
        fut = svc.submit(scens, seeds=SEEDS, base_key=BASE_KEY)
        svc.flush(timeout=WAIT)
        got = fut.result(timeout=WAIT)
    assert svc.stats["splits"] == 1
    for name in ("a", "b"):
        _assert_tree_equal(ref[name], got[name], f"split recovery {name}")
    svc.close()


def test_poisoned_scenario_fails_only_its_own_future(graph):
    """The natural poison: a z0 > max_walks scenario coalesces (z0 is a
    traced leaf, so the static group key matches) but fails validation at
    stack time. The co-batched innocent submission must still succeed,
    bitwise; only the poisoned future errors."""
    svc = _service(graph)
    good = _scen("good")
    poisoned = Scenario("bad", _pcfg(z0=jnp.asarray(W + 5, jnp.int32)),
                        FailureConfig())
    ref = svc.plan.sweep([good], seeds=SEEDS, base_key=BASE_KEY)
    fut_good = svc.submit([good], seeds=SEEDS, base_key=BASE_KEY)
    fut_bad = svc.submit([poisoned], seeds=SEEDS, base_key=BASE_KEY)
    svc.flush(timeout=WAIT)
    assert svc.stats["splits"] == 1
    _assert_tree_equal(ref["good"], fut_good.result(timeout=WAIT)["good"],
                       "innocent co-batched submission")
    with pytest.raises(ValueError, match="max_walks"):
        fut_bad.result(timeout=WAIT)
    svc.close()


def test_submission_deadline_exceeded(graph):
    svc = _service(graph)
    fut = svc.submit([_scen("a")], seeds=SEEDS, base_key=BASE_KEY,
                     timeout=0.0)
    time.sleep(0.005)
    svc.flush(timeout=WAIT)
    with pytest.raises(DeadlineExceededError):
        fut.result(timeout=WAIT)
    svc.close()


# ---------------------------------------------------------------------------
# store faults: degrade, never take the caller down
# ---------------------------------------------------------------------------


def test_store_get_fault_degrades_to_recompute_bitwise(graph, tmp_path):
    store = ResultStore(tmp_path / "store")
    exp = Experiment(graph=graph, steps=STEPS, outputs="scalars",
                     scenarios=[_scen("base")])
    plan_ = exp.plan()
    scens = [_scen("a")]
    ref = plan_.sweep_stacked(scens, seeds=SEEDS, base_key=1, store=store)
    misses = store.misses
    fp = FaultPlan().at("store.get", Raise(OSError("flaky disk")))
    with fp.active():
        got = plan_.sweep_stacked(scens, seeds=SEEDS, base_key=1, store=store)
    assert store.misses == misses + 1  # the read fault counted as a miss
    _assert_tree_equal(ref, got, "recompute under store.get fault")


def test_snapshot_writebehind_fault_degrades_with_warning(graph, tmp_path):
    """A failing snapshot write must cost only durability (a warning),
    never correctness or the run itself."""
    store = ResultStore(tmp_path / "store")
    exp = Experiment(graph=graph, steps=STEPS, outputs="scalars",
                     scenarios=[_scen("base")])
    plan_ = exp.plan()
    scens = [_scen("a")]
    ref = plan_.sweep_stacked(scens, seeds=SEEDS, base_key=1)
    # first store.put hit is the first boundary snapshot (get comes first
    # and has its own site); fail it
    fp = FaultPlan().at("store.put", Raise(OSError("disk full")))
    with fp.active(), pytest.warns(UserWarning, match="write-behind"):
        got = plan_.sweep_stacked(scens, seeds=SEEDS, base_key=1,
                                  store=store, segment_steps=10)
    _assert_tree_equal(ref, got, "segmented run under store.put fault")


# ---------------------------------------------------------------------------
# kills: worker death and close() determinism — never a hang
# ---------------------------------------------------------------------------


def test_worker_kill_fails_futures_and_service_drains_inline(graph):
    """A kill inside the background worker's group run: the touching
    future errors (no hang), and the service keeps working — flush and
    later submissions drain inline past the dead thread."""
    svc = _service(graph, autostart=True, linger=0.0)
    fp = FaultPlan().at("service.run_group", Kill())
    with fp.active():
        fut = svc.submit([_scen("a")], seeds=SEEDS, base_key=BASE_KEY)
        with pytest.raises(SimulatedKill):
            fut.result(timeout=WAIT)
    # wait for the worker thread to actually die
    deadline = time.monotonic() + WAIT
    while svc._worker_alive() is not None:
        assert time.monotonic() < deadline, "worker did not die"
        time.sleep(0.005)
    ok = svc.submit([_scen("a")], seeds=SEEDS, base_key=BASE_KEY)
    svc.flush(timeout=WAIT)
    ref = svc.plan.sweep([_scen("a")], seeds=SEEDS, base_key=BASE_KEY)
    _assert_tree_equal(ref["a"], ok.result(timeout=WAIT)["a"],
                       "submission after worker death")
    svc.close(timeout=WAIT)


def test_close_resolves_pending_and_post_close_submit_raises(graph):
    svc = _service(graph)
    fut = svc.submit([_scen("a")], seeds=SEEDS, base_key=BASE_KEY)
    svc.close(timeout=WAIT)
    # the pending future resolved deterministically (final drain ran it)
    assert fut.done()
    fut.result(timeout=WAIT)
    with pytest.raises(ServiceClosedError, match="closed"):
        svc.submit([_scen("a")], seeds=SEEDS, base_key=BASE_KEY)
    svc.close(timeout=WAIT)  # idempotent


def test_close_is_deterministic_with_live_worker(graph):
    svc = _service(graph, autostart=True)
    futs = [svc.submit([_scen("a")], seeds=SEEDS, base_key=BASE_KEY)
            for _ in range(3)]
    svc.close(timeout=WAIT)
    for fut in futs:
        assert fut.done()
        fut.result(timeout=WAIT)
    with pytest.raises(ServiceClosedError):
        svc.submit([_scen("a")], seeds=SEEDS, base_key=BASE_KEY)


def test_concurrent_submitters_with_transient_faults(graph):
    """Chaos under concurrency: several submitter threads race a worker
    that takes transient hits; every future must resolve correctly."""
    svc = _service(graph, autostart=True, retries=3, linger=0.005)
    scens = [_scen("a"), _scen("b", eps=0.9)]
    ref = svc.plan.sweep(scens, seeds=SEEDS, base_key=BASE_KEY)
    fp = FaultPlan().at(
        "service.run_group",
        Raise(TransientFault("x")), Delay(0.002), Raise(TransientFault("y")),
    )
    results, errors = {}, []

    def submitter(i):
        try:
            fut = svc.submit(scens, seeds=SEEDS, base_key=BASE_KEY)
            results[i] = fut.result(timeout=WAIT)
        except BaseException as exc:  # noqa: BLE001 - recorded for assert
            errors.append(exc)

    with fp.active():
        threads = [threading.Thread(target=submitter, args=(i,))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(WAIT)
            assert not t.is_alive(), "submitter hung"
    assert not errors, f"submitters failed: {errors!r}"
    for i, got in results.items():
        for name in ("a", "b"):
            _assert_tree_equal(ref[name], got[name],
                               f"concurrent submitter {i}/{name}")
    svc.close(timeout=WAIT)


# ---------------------------------------------------------------------------
# the chaos matrix: every documented site is real and exercised
# ---------------------------------------------------------------------------


def test_every_documented_site_is_hit_by_one_durable_service_run(graph,
                                                                tmp_path):
    """One durable service run (segmented + store + a retried transient)
    passes through EVERY fault site in ``faults.SITES`` — the harness
    instruments the whole host stack, not a subset."""
    store = ResultStore(tmp_path / "store")
    svc = _service(graph, store=store, segment_steps=10, retries=1)
    fp = FaultPlan().at("service.run_group", Raise(TransientFault("once")))
    with fp.active():
        fut = svc.submit([_scen("a")], seeds=SEEDS, base_key=BASE_KEY)
        svc.flush(timeout=WAIT)
        fut.result(timeout=WAIT)
    assert set(faults.SITES) <= set(fp.hits), (
        f"unhit sites: {set(faults.SITES) - set(fp.hits)}"
    )
    svc.close(timeout=WAIT)


def test_sites_tuple_matches_module_doc():
    assert faults.SITES == (
        "checkpoint.write", "store.get", "store.put",
        "service.run_group", "segment.boundary",
    )
