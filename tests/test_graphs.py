import numpy as np
import pytest

from repro.graphs import (
    GRAPH_FAMILIES,
    community_graph,
    complete_graph,
    erdos_renyi_graph,
    expected_return_times,
    make_graph,
    power_law_graph,
    random_regular_graph,
    ring_graph,
    spectral_gap,
    stationary_distribution,
    torus_graph,
)
from repro.graphs.generators import is_connected_adj


@pytest.mark.parametrize("n,d", [(20, 3), (50, 4), (100, 8)])
def test_random_regular(n, d):
    g = random_regular_graph(n, d, seed=1)
    g.validate()
    assert (g.degrees == d).all()
    assert g.num_edges == n * d // 2


def test_regular_rejects_bad_args():
    with pytest.raises(ValueError):
        random_regular_graph(9, 3)  # odd n*d
    with pytest.raises(ValueError):
        random_regular_graph(4, 5)  # d >= n


@pytest.mark.parametrize(
    "maker",
    [
        lambda: erdos_renyi_graph(60, seed=2),
        lambda: complete_graph(12),
        lambda: power_law_graph(80, m=3, seed=3),
        lambda: ring_graph(17),
        lambda: torus_graph(4, 5),
    ],
)
def test_families_valid(maker):
    g = maker()
    g.validate()


def test_make_graph_dispatch():
    for fam in ("regular", "erdos_renyi", "complete", "power_law", "ring"):
        g = make_graph(fam, 24, seed=0, degree=4, m=2)
        assert g.n == 24
        assert is_connected_adj(g.adjacency())
    with pytest.raises(KeyError):
        make_graph("nope", 10)


@pytest.mark.parametrize("n,k", [(24, 1), (24, 2), (33, 3), (64, 2)])
def test_community_graph_structure(n, k):
    """Two connected halves, exactly k bridges across the id boundary,
    connected overall (also for odd n, where the halves differ by one)."""
    g = community_graph(n, k_bridges=k, seed=3)
    g.validate()
    assert g.family == "community"
    a = g.adjacency()
    h = n // 2
    assert a[:h, h:].sum() == k  # exactly k cross edges
    assert is_connected_adj(a[:h, :h])  # each half connected on its own
    assert is_connected_adj(a[h:, h:])
    # severing the bridges disconnects the graph — the edge_cut attack's
    # partition premise
    cut = a.copy()
    cut[:h, h:] = cut[h:, :h] = False
    assert not is_connected_adj(cut)


def test_community_graph_deterministic_and_guarded():
    g1 = community_graph(40, k_bridges=2, seed=7)
    g2 = community_graph(40, k_bridges=2, seed=7)
    np.testing.assert_array_equal(g1.neighbors, g2.neighbors)
    np.testing.assert_array_equal(g1.degrees, g2.degrees)
    assert community_graph(40, k_bridges=2, seed=8).num_edges != 0
    with pytest.raises(ValueError):
        community_graph(3)  # too small
    with pytest.raises(ValueError):
        community_graph(24, k_bridges=0)  # would disconnect
    m = make_graph("community", 24, seed=3, k_bridges=2)
    np.testing.assert_array_equal(
        m.neighbors, community_graph(24, 2, seed=3).neighbors
    )


def test_stationary_and_kac():
    g = random_regular_graph(40, 4, seed=5)
    pi = stationary_distribution(g)
    np.testing.assert_allclose(pi.sum(), 1.0)
    # regular graph: uniform stationary, E[R] = n
    np.testing.assert_allclose(pi, 1.0 / 40)
    np.testing.assert_allclose(expected_return_times(g), 40.0)


def test_spectral_gap_positive():
    g = random_regular_graph(60, 6, seed=6)
    gap = spectral_gap(g)
    assert 0.0 < gap <= 2.0
    # complete graph has the largest gap
    assert spectral_gap(complete_graph(20)) > spectral_gap(ring_graph(20))


def test_empirical_return_time_matches_kac():
    """Simulate a single walk and check mean return time ~ n (Kac)."""
    import jax
    import jax.numpy as jnp

    g = random_regular_graph(30, 4, seed=7)
    nbrs = jnp.asarray(g.neighbors)
    degs = jnp.asarray(g.degrees)

    def step(carry, k):
        posn, = carry
        u = jax.random.uniform(k, ())
        idx = jnp.minimum((u * degs[posn]).astype(jnp.int32), degs[posn] - 1)
        nxt = nbrs[posn, idx]
        return (nxt,), nxt

    keys = jax.random.split(jax.random.key(0), 30000)
    _, path = jax.lax.scan(step, (jnp.int32(0),), keys)
    visits = np.nonzero(np.asarray(path) == 0)[0]
    mean_rt = np.diff(visits).mean()
    assert abs(mean_rt - 30.0) / 30.0 < 0.15
