"""The declarative Experiment API (ISSUE 5): spec -> Plan -> results.

Contract under test:
  * one compiled program per static signature, process-wide: repeated
    ``.run`` / ``.ensemble`` / ``.sweep`` calls with the same structure
    never re-lower and never recompile (monkeypatched-lower counts +
    XLA cache counts), across re-planned Experiments; a static-field
    change opens exactly one new cache slot;
  * the four legacy runners are deprecation shims that stay bitwise
    equal to the new path (and warn with APIDeprecationWarning, which
    the test lanes otherwise promote to an error);
  * ``outputs=`` thins payload outputs too: selected fields only, the
    dropped ``(.., steps, W)`` stacks never allocated, values matching
    the full run (integer fields exactly; float fields to the ulp-level
    re-fusion caveat documented in ``core.outputs``);
  * the fused estimator path carries pre-padded observation state
    (``observation_rows``) and returns a final state sliced back to n.
"""
import warnings

import jax
import numpy as np
import pytest

from repro.api import Experiment, Placement, Plan, cache_stats
from repro.api import plan as plan_mod
from repro.core import FailureConfig, ProtocolConfig
from repro.core.outputs import PayloadOutputSpec, split_outputs
from repro.core.simulator import observation_rows
from repro.graphs import random_regular_graph
from repro.sweep import Scenario
from repro.utils.deprecation import APIDeprecationWarning

N, W, Z0, STEPS, SEEDS, BASE_KEY = 24, 10, 5, 40, 2, 7


@pytest.fixture(scope="module")
def graph():
    return random_regular_graph(N, 4, seed=3)


def _pcfg(alg="decafork", **kw):
    base = dict(algorithm=alg, z0=Z0, max_walks=W, rt_bins=32,
                protocol_start=10, eps=1.8)
    base.update(kw)
    return ProtocolConfig(**base)


FCFG = FailureConfig(burst_times=(15,), burst_sizes=(2,))


def _tiny_payload(max_walks=W, **kw):
    from repro.data import make_markov_task
    from repro.models.config import ModelConfig
    from repro.models.model import Model
    from repro.optim import RwSgdPayload, adamw

    cfg = ModelConfig(
        name="tiny", arch_type="dense", num_layers=1, d_model=32, d_ff=64,
        vocab_size=64, num_heads=2, num_kv_heads=2, head_dim=16,
        dtype="float32",
    )
    return RwSgdPayload(
        Model(cfg), adamw(1e-2), make_markov_task(cfg.vocab_size, rank=4),
        max_walks=max_walks, local_batch=1, seq_len=8, **kw,
    )


def _assert_outputs_equal(ref, got, label):
    for name, a, b in zip(ref._fields, ref, got):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=f"{label}: field {name}"
        )


# ---------------------------------------------------------------------------
# spec validation
# ---------------------------------------------------------------------------


def test_experiment_spec_validation(graph):
    with pytest.raises(TypeError, match="steps"):
        Experiment(graph=graph, protocol=_pcfg())
    with pytest.raises(TypeError, match="base scenario"):
        Experiment(graph=graph, steps=5)
    with pytest.raises(TypeError, match="without protocol"):
        Experiment(graph=graph, failures=FCFG, steps=5)
    # a protocol-only spec defaults to the failure-free config
    exp = Experiment(graph=graph, protocol=_pcfg(), steps=5)
    assert exp.failures == FailureConfig()
    assert exp.placement is Placement.AUTO
    # scenario-only specs plan but refuse run/ensemble with a clear error
    sexp = Experiment(graph=graph, scenarios=[(_pcfg(), FCFG)], steps=5)
    with pytest.raises(ValueError, match="base scenario"):
        sexp.run()
    with pytest.raises(ValueError, match="base scenario"):
        sexp.ensemble(1)
    # ...and a base-only plan refuses sweeps without scenario rows
    with pytest.raises(ValueError, match="scenarios"):
        exp.sweep(seeds=1)


def test_plan_repr_and_experiment_repr(graph):
    exp = Experiment(graph=graph, protocol=_pcfg(), steps=5, name="demo")
    assert "demo" in repr(exp) and "decafork" in repr(exp)
    assert "steps=5" in repr(exp.plan())


# ---------------------------------------------------------------------------
# compile cache: one lowering + one XLA program per static signature
# ---------------------------------------------------------------------------


def _count_lowerings(monkeypatch):
    calls = []
    real = plan_mod._lower

    def counting(mode, signature):
        calls.append((mode, signature))
        return real(mode, signature)

    monkeypatch.setattr(plan_mod, "_lower", counting)
    return calls


def test_plan_reuse_never_relowers_or_recompiles(graph, monkeypatch):
    """Repeated .run/.ensemble/.sweep with the same structure: zero new
    lowerings, zero new XLA compiles — across calls AND re-planned
    Experiments AND numeric config changes."""
    calls = _count_lowerings(monkeypatch)
    exp = Experiment(graph=graph, protocol=_pcfg(), failures=FCFG, steps=STEPS)
    plan = exp.plan()
    scenarios = [(_pcfg(eps=e), FCFG) for e in (1.6, 2.0, 2.4)]

    plan.run(key=0)
    plan.ensemble(SEEDS, base_key=0)
    plan.sweep_stacked(scenarios, seeds=SEEDS, base_key=0)
    lowered = len(calls)
    assert lowered <= 3  # at most one per mode (fewer if pre-cached)
    compiles = cache_stats()["xla_compiles"]

    # same structure, different keys / numeric knobs / fresh plans
    plan.run(key=1)
    plan.ensemble(SEEDS, base_key=2)
    exp.plan().run(key=3)
    Experiment(
        graph=graph, protocol=_pcfg(eps=2.2),
        failures=FailureConfig(burst_times=(12,), burst_sizes=(1,)),
        steps=STEPS,
    ).ensemble(SEEDS, base_key=4)
    plan.sweep_stacked(
        [(_pcfg(eps=e), FCFG) for e in (1.5, 1.9, 2.3)],
        seeds=SEEDS, base_key=5,
    )
    assert len(calls) == lowered  # no new lowerings
    assert cache_stats()["xla_compiles"] == compiles  # no new XLA programs


def test_static_field_change_opens_one_new_slot(graph, monkeypatch):
    """Changing a static field (rt_bins) re-lowers exactly once; changing
    back hits the original slot (the cache is keyed, not invalidated)."""
    calls = _count_lowerings(monkeypatch)
    base = Experiment(graph=graph, protocol=_pcfg(), failures=FCFG, steps=STEPS)
    base.ensemble(SEEDS)
    n0 = len(calls)

    changed = Experiment(
        graph=graph, protocol=_pcfg(rt_bins=64), failures=FCFG, steps=STEPS
    )
    changed.ensemble(SEEDS)
    assert len(calls) == n0 + 1  # exactly one new signature
    sig_new = calls[-1][1] if calls else None

    base.ensemble(SEEDS, base_key=9)  # back to the old structure: cached
    changed.ensemble(SEEDS, base_key=9)  # new structure: also cached now
    assert len(calls) == n0 + 1
    if sig_new is not None:
        assert ("ensemble", sig_new) in plan_mod._EXECUTABLES


def test_mixed_groups_one_slot_each(graph, monkeypatch):
    """A mixed sweep lowers once per static group; re-running it (or
    permuting the rows) adds nothing."""
    calls = _count_lowerings(monkeypatch)
    fc = FailureConfig(burst_times=(20,), burst_sizes=(2,))
    scenarios = [
        Scenario("dfk/1.6", _pcfg(eps=1.6), fc),
        Scenario("mp", _pcfg("missingperson", eps_mp=25.0), fc),
        Scenario("dfk/2.0", _pcfg(eps=2.0), fc),
    ]
    exp = Experiment(graph=graph, scenarios=scenarios, steps=STEPS)
    exp.sweep(seeds=SEEDS)
    n0 = len(calls)
    assert n0 <= 2  # two static groups (decafork, missingperson)
    compiles = cache_stats()["xla_compiles"]
    exp.sweep(seeds=SEEDS, base_key=1)
    exp.plan().sweep(list(reversed(scenarios)), seeds=SEEDS)
    assert len(calls) == n0
    assert cache_stats()["xla_compiles"] == compiles


def test_cache_stats_shape():
    st = cache_stats()
    assert set(st) == {"entries", "xla_compiles", "by_mode"}
    assert st["entries"] >= 0 and st["xla_compiles"] >= 0
    assert st["xla_compiles"] == sum(st["by_mode"].values())
    assert set(st["by_mode"]) <= {"run", "ensemble", "sweep"}


# ---------------------------------------------------------------------------
# new-path == single-trajectory core, across modes
# ---------------------------------------------------------------------------


def test_modes_are_bitwise_consistent(graph):
    """sweep_stacked[i] == ensemble on scenario i; ensemble[s] == the
    seed-s trajectory of run under the split keys."""
    scenarios = [(_pcfg(eps=e), FCFG) for e in (1.6, 2.2)]
    exp = Experiment(graph=graph, scenarios=scenarios, steps=STEPS,
                     protocol=scenarios[0][0], failures=FCFG)
    plan = exp.plan()
    stacked = plan.sweep_stacked(seeds=SEEDS, base_key=BASE_KEY)
    for i, (pc, fc) in enumerate(scenarios):
        ref = Experiment(graph=graph, protocol=pc, failures=fc,
                         steps=STEPS).ensemble(SEEDS, base_key=BASE_KEY)
        got = jax.tree_util.tree_map(lambda x: x[i], stacked)
        _assert_outputs_equal(ref, got, f"scenario{i}")
    # per-seed equality against single runs
    ens = plan.ensemble(SEEDS, base_key=BASE_KEY)
    keys = jax.random.split(jax.random.key(BASE_KEY), SEEDS)
    for s in range(SEEDS):
        _, one = plan.run(key=keys[s])
        got = jax.tree_util.tree_map(lambda x: x[s], ens)
        _assert_outputs_equal(one, got, f"seed{s}")


# ---------------------------------------------------------------------------
# legacy shims: bitwise-equal, and they warn
# ---------------------------------------------------------------------------


def test_run_simulation_shim_bitwise_and_warns(graph):
    from repro.core import run_simulation

    exp = Experiment(graph=graph, protocol=_pcfg(), failures=FCFG, steps=STEPS)
    final_new, outs_new = exp.run(key=3)
    with pytest.warns(APIDeprecationWarning, match="run_simulation"):
        final_old, outs_old = run_simulation(graph, _pcfg(), FCFG,
                                             steps=STEPS, key=3)
    _assert_outputs_equal(outs_new, outs_old, "run_simulation")
    np.testing.assert_array_equal(
        np.asarray(final_new.last_seen), np.asarray(final_old.last_seen)
    )


def test_run_ensemble_shim_bitwise_and_warns(graph):
    from repro.core import run_ensemble

    new = Experiment(graph=graph, protocol=_pcfg(), failures=FCFG,
                     steps=STEPS, outputs="full").ensemble(SEEDS, base_key=BASE_KEY)
    with pytest.warns(APIDeprecationWarning, match="run_ensemble"):
        old = run_ensemble(graph, _pcfg(), FCFG, steps=STEPS, seeds=SEEDS,
                           base_key=BASE_KEY, outputs="full")
    _assert_outputs_equal(new, old, "run_ensemble")


def test_run_sweep_shim_bitwise_and_warns(graph):
    from repro.core.simulator import run_sweep

    scenarios = [(_pcfg(eps=e), FCFG) for e in (1.6, 2.2)]
    new = Experiment(graph=graph, scenarios=scenarios,
                     steps=STEPS).plan().sweep_stacked(
        seeds=SEEDS, base_key=BASE_KEY)
    with pytest.warns(APIDeprecationWarning, match="run_sweep"):
        old = run_sweep(graph, scenarios, steps=STEPS, seeds=SEEDS,
                        base_key=BASE_KEY)
    _assert_outputs_equal(new, old, "run_sweep")
    # the legacy sharded tri-state still validates by identity
    with pytest.warns(APIDeprecationWarning):
        with pytest.raises(TypeError, match="sharded"):
            run_sweep(graph, scenarios, steps=5, seeds=1, sharded=0)


def test_legacy_shim_warning_is_promoted_to_error(graph):
    """The tier-1 lane must FAIL on unshielded in-repo shim calls: with
    no pytest.warns shield, the APIDeprecationWarning surfaces as an
    error (conftest promotes it)."""
    from repro.core import run_simulation

    with pytest.raises(APIDeprecationWarning):
        run_simulation(graph, _pcfg(), FCFG, steps=2, key=0)


def test_run_scenarios_shim_bitwise_and_warns(graph):
    from repro.sweep import run_scenarios

    fc = FailureConfig(burst_times=(20,), burst_sizes=(2,))
    scenarios = [
        Scenario("dfk", _pcfg(eps=1.6), fc),
        Scenario("mp", _pcfg("missingperson", eps_mp=25.0), fc),
    ]
    new = Experiment(graph=graph, scenarios=scenarios,
                     steps=STEPS).sweep(seeds=SEEDS, base_key=3)
    with pytest.warns(APIDeprecationWarning, match="run_scenarios"):
        old = run_scenarios(graph, scenarios, steps=STEPS, seeds=SEEDS,
                            base_key=3)
    assert old.names == new.names
    for name in new.names:
        _assert_outputs_equal(new[name], old[name], name)


# ---------------------------------------------------------------------------
# payload-output thinning (outputs= selects payload fields too)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def payload():
    return _tiny_payload()


def test_split_outputs_resolution(payload):
    from repro.core.outputs import FULL, SCALARS, OutputSpec

    assert split_outputs(None, None) == (SCALARS, None)
    assert split_outputs(None, payload) == (FULL, None)
    assert split_outputs(("z",), payload) == (OutputSpec(("z",)), None)
    spec, pspec = split_outputs(("z", "mean_loss"), payload)
    assert spec == OutputSpec(("z",)) and pspec == PayloadOutputSpec(("mean_loss",))
    # payload-only names: explicitly thinned -> scalars on the sim side
    spec, pspec = split_outputs(("mean_loss", "trained"), payload)
    assert spec == SCALARS
    assert pspec == PayloadOutputSpec(("mean_loss", "trained"))
    with pytest.raises(ValueError, match="unknown output field"):
        split_outputs(("z", "bogus"), payload)
    with pytest.raises(ValueError, match="unknown output field"):
        split_outputs(("mean_loss",), None)  # no payload to resolve against
    with pytest.raises(ValueError, match="no payload"):
        split_outputs(PayloadOutputSpec(("mean_loss",)), None)


def test_payload_output_thinning_drops_stacks(graph, payload):
    """Thinned payload outputs: only the selected fields are stacked (no
    (seeds, steps, W) loss buffer), values match the full run."""
    T = 12
    mk = lambda **kw: Experiment(
        graph=graph, protocol=_pcfg(), failures=FCFG, steps=T,
        payload=payload, **kw,
    ).ensemble(SEEDS, base_key=3)
    full, learn_full = mk()
    assert learn_full._fields == ("loss", "mean_loss", "trained")
    thin, learn_thin = mk(outputs=("z", "mean_loss", "trained"))
    assert thin._fields == ("z",)
    assert learn_thin._fields == ("mean_loss", "trained")
    leaves = jax.tree_util.tree_leaves(learn_thin)
    assert all(leaf.shape == (SEEDS, T) for leaf in leaves)  # no (.., W)
    # integer telemetry is exact; float reductions may re-fuse (see
    # core.outputs.PayloadOutputSpec) so the loss curve is allclose
    np.testing.assert_array_equal(
        np.asarray(learn_thin.trained), np.asarray(learn_full.trained)
    )
    np.testing.assert_allclose(
        np.asarray(learn_thin.mean_loss), np.asarray(learn_full.mean_loss),
        rtol=1e-5, atol=1e-6,
    )
    np.testing.assert_array_equal(np.asarray(thin.z), np.asarray(full.z))
    with pytest.raises(AttributeError):
        learn_thin.loss


def test_payload_thinning_through_sweep(graph, payload):
    """The payload spec rides the sweep path: thinned stacks per scenario,
    sweep rows == the thinned ensembles."""
    T = 10
    scenarios = [(_pcfg(eps=1.5), FCFG), (_pcfg(eps=2.1), FCFG)]
    outs, learn = Experiment(
        graph=graph, scenarios=scenarios, steps=T, payload=payload,
        outputs=("z", "mean_loss"),
    ).plan().sweep_stacked(seeds=SEEDS, base_key=BASE_KEY)
    assert learn._fields == ("mean_loss",)
    assert learn.mean_loss.shape == (2, SEEDS, T)
    for i, (pc, fc) in enumerate(scenarios):
        _, ref = Experiment(
            graph=graph, protocol=pc, failures=fc, steps=T, payload=payload,
            outputs=("z", "mean_loss"),
        ).ensemble(SEEDS, base_key=BASE_KEY)
        np.testing.assert_array_equal(
            np.asarray(ref.mean_loss), np.asarray(learn.mean_loss[i])
        )


def test_payload_signature_structural_identity(graph, monkeypatch):
    """Satellite 4 (ISSUE 6): two structurally equal payload instances
    are ONE program — equal/hash-equal statics, one compile-cache slot,
    zero extra lowerings and zero extra XLA compiles — and changing one
    static knob (train_every) opens exactly one more slot + program."""
    calls = _count_lowerings(monkeypatch)
    T = 8
    p1, p2 = _tiny_payload(), _tiny_payload()
    assert p1 is not p2
    assert p1 == p2 and hash(p1) == hash(p2)  # structural identity
    assert p1.signature() is not None

    mk = lambda p: Experiment(
        graph=graph, protocol=_pcfg(), failures=FCFG, steps=T, payload=p,
        outputs=("z", "mean_loss"),
    )
    out1, learn1 = mk(p1).ensemble(SEEDS, base_key=BASE_KEY)
    base_entries = cache_stats()["entries"]
    base_compiles = cache_stats()["xla_compiles"]
    n_lower = len(calls)

    out2, learn2 = mk(p2).ensemble(SEEDS, base_key=BASE_KEY)
    assert len(calls) == n_lower  # fresh instance, same slot
    assert cache_stats()["entries"] == base_entries
    assert cache_stats()["xla_compiles"] == base_compiles  # shared program
    np.testing.assert_array_equal(np.asarray(out1.z), np.asarray(out2.z))
    np.testing.assert_array_equal(
        np.asarray(learn1.mean_loss), np.asarray(learn2.mean_loss)
    )

    p3 = _tiny_payload(train_every=2)  # one static knob changed
    assert p3 != p1 and p3.signature() != p1.signature()
    mk(p3).ensemble(SEEDS, base_key=BASE_KEY)
    assert len(calls) == n_lower + 1  # exactly one new slot...
    assert cache_stats()["entries"] == base_entries + 1
    assert cache_stats()["xla_compiles"] == base_compiles + 1  # ...one program


def test_payload_spec_requires_addressable_outputs(graph):
    """A payload that emits a non-namedtuple outputs pytree cannot be
    thinned by field name — the error says so at spec time."""
    from repro.core import Payload

    with pytest.raises(ValueError, match="unknown output field"):
        Experiment(graph=graph, protocol=_pcfg(), steps=3,
                   payload=Payload(), outputs=("mean_loss",))


# ---------------------------------------------------------------------------
# fused path: pre-padded observation state
# ---------------------------------------------------------------------------


def test_observation_rows_pads_only_fused():
    fused = _pcfg(estimator_impl="fused")
    assert observation_rows(19, fused) == 24  # tile 8
    assert observation_rows(16, fused) == 16  # already aligned
    assert observation_rows(5, fused) == 5  # bn = min(8, n)
    assert observation_rows(19, _pcfg(estimator_impl="gather")) == 19
    assert observation_rows(19, _pcfg("missingperson")) == 19
    assert observation_rows(
        19, _pcfg(estimator_impl="fused", analytic_survival=True)
    ) == 19  # pi path never fuses


def test_fused_prepadded_state_matches_compare_and_slices_back(graph):
    """The pre-padded fused trajectory equals the unfused oracle bitwise
    on a non-tile-multiple n, and the returned final state is sliced back
    to (n, ...)."""
    g = random_regular_graph(19, 4, seed=2)
    fcfg = FailureConfig(burst_times=(25,), burst_sizes=(2,))
    finals, outs = {}, {}
    for impl in ("compare", "fused"):
        pcfg = ProtocolConfig(
            algorithm="decafork", z0=4, max_walks=8, eps=1.4,
            protocol_start=15, rt_bins=32, estimator_impl=impl,
        )
        finals[impl], outs[impl] = Experiment(
            graph=g, protocol=pcfg, failures=fcfg, steps=60, outputs="full"
        ).run(key=5)
    _assert_outputs_equal(outs["compare"], outs["fused"], "fused vs compare")
    assert finals["fused"].last_seen.shape == (19, 8)
    assert finals["fused"].rts.hist.shape[0] == 19
    np.testing.assert_array_equal(
        np.asarray(finals["fused"].last_seen),
        np.asarray(finals["compare"].last_seen),
    )
    np.testing.assert_array_equal(
        np.asarray(finals["fused"].rts.hist),
        np.asarray(finals["compare"].rts.hist),
    )
