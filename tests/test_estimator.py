import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import estimator as est


def _random_state(key, n=16, bins=64):
    k1, k2 = jax.random.split(key)
    hist = jax.random.uniform(k1, (n, bins)) * (jax.random.uniform(k2, (n, bins)) > 0.4)
    return est.ReturnTimeState(hist=hist.astype(jnp.float32), total=hist.sum(1))


def test_record_returns_counts():
    s = est.init_return_time_state(4, 16)
    nodes = jnp.array([0, 1, 1, 3], jnp.int32)
    r = jnp.array([1, 5, 200, 3], jnp.int32)  # 200 clamps to last bin
    valid = jnp.array([True, True, True, False])
    s = est.record_returns(s, nodes, r, valid)
    assert float(s.total[0]) == 1.0
    assert float(s.total[1]) == 2.0
    assert float(s.total[3]) == 0.0  # invalid dropped
    assert float(s.hist[0, 0]) == 1.0  # r=1 -> bin 0
    assert float(s.hist[1, 15]) == 1.0  # clamped tail


def test_survival_monotone_and_bounded():
    s = _random_state(jax.random.key(0))
    cum = est.survival_cumulative(s)
    nodes = jnp.zeros((50,), jnp.int32)
    rs = jnp.arange(50, dtype=jnp.int32)
    vals = est.survival_eval(cum, s.total, nodes, rs)
    v = np.asarray(vals)
    assert (v <= 1.0 + 1e-6).all() and (v >= -1e-6).all()
    assert (np.diff(v) <= 1e-6).all()  # non-increasing in r
    assert v[0] == 1.0  # S(0) = 1


def test_survival_no_samples_defaults_alive():
    s = est.init_return_time_state(2, 8)
    cum = est.survival_cumulative(s)
    v = est.survival_eval(cum, s.total, jnp.array([0]), jnp.array([5]))
    assert float(v[0]) == 1.0


def test_theta_hat_excludes_own_column():
    n, W, bins = 4, 3, 16
    s = est.init_return_time_state(n, bins)
    # node 0 saw walks 0,1,2 all at t=10; with no samples S=1 each
    last_seen = jnp.full((n, W), est.NEVER, jnp.int32).at[0].set(10)
    cum = est.survival_cumulative(s)
    pos = jnp.array([0], jnp.int32)
    track = jnp.array([0], jnp.int32)
    theta = est.theta_hat(last_seen, cum, s.total, jnp.int32(10), pos, track)
    # 1/2 + S(0)*2 others = 2.5
    np.testing.assert_allclose(np.asarray(theta), [2.5])


def test_probability_integral_transform_prop1():
    """Prop. 1 (with a measured correction): 2 E[theta] tracks Z.

    The paper argues E[S(age)] = 1/2 by treating the inspected age as a
    fresh sample of R_i. In vivo the age is the *stationary age* of a
    renewal process (inspection paradox), and R_i on a regular graph is
    only approximately geometric, giving E[S(age)] ~ 0.42 rather than
    0.50 (EXPERIMENTS.md "Estimator bias"). The estimator therefore
    tracks ~0.42 Z + 1/2 - protocol thresholds absorb the offset. We pin
    the measured band so regressions in the estimator are caught.
    """
    from repro.graphs import random_regular_graph
    from repro.core.protocol import ProtocolConfig
    from repro.core.failures import FailureConfig
    from repro.api import Experiment

    g = random_regular_graph(50, 6, seed=2)
    pcfg = ProtocolConfig(
        algorithm="decafork", z0=8, max_walks=16, eps=0.0,  # eps=0: never fork
        protocol_start=10**9, rt_bins=512,
    )
    _, outs = Experiment(graph=g, protocol=pcfg, steps=4000).run(key=1)
    theta = np.asarray(outs.theta_mean)[2000:]  # steady state
    # idealized value 4.0; measured inspection-paradox band:
    assert 3.0 < theta.mean() < 4.3, theta.mean()


def test_inspection_paradox_bias_quantified():
    """E[S(age)] < 1/2: the documented deviation from Prop. 1's
    idealization (ages are stationary-age distributed, not ~ R_i)."""
    import jax

    from repro.graphs import random_regular_graph
    from repro.core.protocol import ProtocolConfig
    from repro.core.failures import FailureConfig
    from repro.api import Experiment

    g = random_regular_graph(50, 6, seed=2)
    pcfg = ProtocolConfig(
        algorithm="decafork", z0=8, max_walks=16, eps=0.0,
        protocol_start=10**9, rt_bins=512,
    )
    final, _ = Experiment(graph=g, protocol=pcfg, steps=4000).run(key=1)
    cum = est.survival_cumulative(final.rts)
    t = final.t
    ls = final.last_seen[:, :8]
    nodes = jnp.repeat(jnp.arange(50), 8)
    ages = (t - ls).reshape(-1)
    s = est.survival_eval(cum, final.rts.total, nodes, ages)
    m = float(jnp.mean(s))
    assert 0.35 < m < 0.48, m  # strictly below the idealized 0.5


def test_node_sums_compare_matches_gather():
    key = jax.random.key(3)
    s = _random_state(key, n=12, bins=32)
    last_seen = jax.random.randint(key, (12, 8), -1, 30, dtype=jnp.int32)
    t = jnp.int32(40)
    got = est.node_sums_compare(last_seen, s.hist, s.total, t)
    # gather-based reference via theta_hat identity
    from repro.kernels.ref import theta_sums_ref

    want = theta_sums_ref(last_seen, s.hist, s.total, t)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_analytic_survival_geometric():
    pi = jnp.array([0.1, 0.5])
    v = est.analytic_survival_eval(pi, jnp.array([0, 1]), jnp.array([3, 3]))
    np.testing.assert_allclose(np.asarray(v), [0.9**3, 0.5**3], rtol=1e-6)
