"""End-to-end behaviour tests: the paper's claims, reproduced small.

These are the integration-level assertions the benchmarks measure at full
scale (Figs. 1-3): self-regulation keeps Z_t near Z_0 through failures,
the unregulated system collapses, and decentralized RW-SGD training
survives a burst failure with learning progress intact.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.api import Experiment
from repro.core import (
    FailureConfig,
    ProtocolConfig,
    survived,
    reaction_time,
)
from repro.graphs import random_regular_graph


@pytest.fixture(scope="module")
def graph():
    return random_regular_graph(64, 8, seed=0)


def test_fig1_claims_small(graph):
    """Burst failures: DECAFORK recovers to ~Z0; no-protocol collapses
    after enough failures; MISSINGPERSON over-forks past Z0."""
    z0 = 8
    fcfg = FailureConfig(burst_times=(700, 1400), burst_sizes=(4, 5))
    runs = {}
    for alg, kw in [
        ("none", {}),
        ("decafork", dict(eps=2.0)),
        ("missingperson", dict(eps_mp=250.0)),
    ]:
        pcfg = ProtocolConfig(
            algorithm=alg, z0=z0, max_walks=48, protocol_start=400,
            rt_bins=256, **kw,
        )
        _, outs = Experiment(graph=graph, protocol=pcfg, failures=fcfg, steps=2600).run(key=1)
        runs[alg] = np.asarray(outs.z)

    assert runs["none"][-1] <= 1  # two bursts of 4+5 kill at most all 8
    assert survived(runs["decafork"])
    # decafork: back to >= z0 after each burst, bounded overshoot
    assert reaction_time(runs["decafork"], z0, 700) >= 0
    assert runs["decafork"][2000:].mean() >= z0 * 0.75
    assert runs["decafork"].max() <= z0 * 2.5
    # missingperson: over-forks well beyond z0 (paper's Fig. 1 criticism)
    assert runs["missingperson"].max() > runs["decafork"].max()


def test_decaforkplus_faster_reaction(graph):
    z0 = 8
    fcfg = FailureConfig(burst_times=(800,), burst_sizes=(5,))
    rts = {}
    for alg, kw in [
        ("decafork", dict(eps=2.0)),
        ("decafork+", dict(eps=2.9, eps2=6.8)),
    ]:
        pcfg = ProtocolConfig(
            algorithm=alg, z0=z0, max_walks=48, protocol_start=400,
            rt_bins=256, **kw,
        )
        zs = []
        for seed in range(3):
            _, outs = Experiment(graph=graph, protocol=pcfg, failures=fcfg, steps=2000).run(key=seed)
            zs.append(reaction_time(np.asarray(outs.z), z0, 800))
        rts[alg] = np.median(zs)
    # the aggressive fork threshold (enabled by terminations) reacts faster
    assert rts["decafork+"] <= rts["decafork"]


def test_estimator_tracks_population(graph):
    """Theorem 1 in vivo: 2*theta_hat tracks Z_t before/after a burst."""
    pcfg = ProtocolConfig(
        algorithm="decafork", z0=10, max_walks=32, eps=0.0,  # estimate only
        protocol_start=10**9, rt_bins=256,
    )
    fcfg = FailureConfig(burst_times=(1500,), burst_sizes=(5,))
    _, outs = Experiment(graph=graph, protocol=pcfg, failures=fcfg, steps=3000).run(key=2)
    theta = np.asarray(outs.theta_mean)
    # steady state before failure: 2*theta ~ 10
    assert abs(2 * theta[1200:1500].mean() - 10) < 1.5
    # long after the failure: 2*theta ~ 5 (dead walks aged out)
    assert abs(2 * theta[2700:].mean() - 5) < 1.5


def test_e2e_decentralized_training_with_failures(graph):
    """RW-SGD + DECAFORK: walks train replicas on node-local data, a burst
    kills some replicas, forked duplicates carry on — loss keeps falling."""
    from repro.configs import get_smoke_config
    from repro.data import make_markov_task, sample_batch
    from repro.models.model import Model
    from repro.optim import init_replicas, fork_replica, sgd
    from repro.optim.rw_sgd import replica_train_step

    from repro.optim import adamw

    cfg = get_smoke_config("paper_rwsgd")
    model = Model(cfg)
    # rank-4 chain: learnable within the test's tiny token budget
    task = make_markov_task(cfg.vocab_size, rank=4, temperature=2.5)
    opt = adamw(1e-2)
    W = 8
    z0 = 4
    key = jax.random.key(0)
    rs = init_replicas(model.init, opt.init, key, max_walks=W)
    loss_fn = model.loss
    step = jax.jit(replica_train_step(loss_fn, opt))

    active = jnp.arange(W) < z0
    losses = []
    T = 60
    for t in range(T):
        kb = jax.random.fold_in(key, 1000 + t)
        batches = jax.vmap(
            lambda nid: sample_batch(task, kb, batch=2, seq=32, node_id=nid)
        )(jnp.arange(W))
        rs, step_losses = step(rs, batches, active)
        losses.append(float(step_losses.sum() / active.sum()))
        if t == 30:  # burst: kill walks 0,1 -> fork 2,3 into slots 4,5
            active = active.at[jnp.array([0, 1])].set(False)
            rs = fork_replica(
                rs, jnp.array([2, 3]), jnp.array([4, 5]), jnp.array([True, True])
            )
            active = active.at[jnp.array([4, 5])].set(True)

    # learning progressed toward the entropy floor despite the failure
    early = np.mean(losses[:5])
    late = np.mean(losses[-5:])
    assert late < early - 0.3, (early, late)
    assert late > 0.5  # sanity: no degenerate loss collapse


def test_auto_eps_self_calibration():
    """Beyond-paper: per-node quantile thresholds (auto_eps) keep the
    system resilient across graph families with ZERO per-graph tuning —
    the paper hand-tunes eps per n (Fig. 4)."""
    from repro.graphs import make_graph

    for fam, n, kw in [("regular", 64, dict(degree=8)), ("power_law", 64, dict(m=4))]:
        g = make_graph(fam, n, seed=0, **kw)
        pcfg = ProtocolConfig(
            algorithm="decafork+", z0=8, max_walks=48,
            eps=2.0, eps2=6.8,  # fallback only (auto thresholds take over)
            auto_eps=True, protocol_start=800, rt_bins=512,
        )
        fcfg = FailureConfig(burst_times=(1400,), burst_sizes=(4,))
        _, outs = Experiment(graph=g, protocol=pcfg, failures=fcfg, steps=3000).run(key=3)
        z = np.asarray(outs.z)
        assert survived(z), fam
        assert z[2400:].mean() > 5.0, (fam, z[2400:].mean())
        assert z.max() <= 30, fam
