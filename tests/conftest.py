import os
import sys

# src-layout import path (mirrors PYTHONPATH=src)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

# keep smoke tests on the single real device; dryrun.py sets its own flags
jax.config.update("jax_platforms", "cpu")


def pytest_configure(config):
    # tier-1 lane guard: calling a legacy runner shim (run_simulation /
    # run_ensemble / run_sweep / run_scenarios) from in-repo code fails
    # the suite — only pytest.warns(APIDeprecationWarning)-shielded shim
    # tests may touch them. Registered here (not pytest.ini) because the
    # ini filters are parsed before this conftest puts src/ on sys.path.
    config.addinivalue_line(
        "filterwarnings",
        "error::repro.utils.deprecation.APIDeprecationWarning",
    )
