import os
import sys

# src-layout import path (mirrors PYTHONPATH=src)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

# keep smoke tests on the single real device; dryrun.py sets its own flags
jax.config.update("jax_platforms", "cpu")
