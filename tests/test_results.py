"""SweepResult error semantics (ISSUE 6 satellite 2).

Three sharp edges, unified:
  * unknown scenario-name lookup: ``KeyError`` listing the available
    names (not ``tuple.index``'s bare ValueError);
  * duplicate names: rejected at construction (a first-match duplicate
    lookup silently returns the wrong scenario);
  * ``payload()`` on a payload-free sweep: the same ``KeyError`` family
    with an actionable message.
"""
import pytest

from repro.api import SweepResult


def _res(names=("a", "b"), payloads=None):
    outputs = [f"out-{n}" for n in names]
    return SweepResult(names=names, outputs=outputs, payloads=payloads)


def test_lookup_by_name_and_position():
    res = _res()
    assert res["a"] == "out-a" == res[0]
    assert res["b"] == "out-b" == res[1]
    assert len(res) == 2
    assert res.items() == [("a", "out-a"), ("b", "out-b")]


def test_unknown_name_raises_keyerror_listing_available():
    res = _res()
    with pytest.raises(KeyError, match=r"unknown scenario name 'zz'.*'a', 'b'"):
        res["zz"]
    # name lookup on payloads goes through the same path
    pres = _res(payloads=["pa", "pb"])
    with pytest.raises(KeyError, match="available scenarios"):
        pres.payload("zz")
    assert pres.payload("b") == "pb"


def test_duplicate_names_rejected_at_construction():
    with pytest.raises(ValueError, match=r"duplicate scenario name\(s\) \['x'\]"):
        _res(names=("x", "y", "x"))
    with pytest.raises(ValueError, match="names but"):
        SweepResult(names=("a",), outputs=["o1", "o2"])


def test_payload_lookup_without_payload_is_keyerror():
    res = _res()
    with pytest.raises(KeyError, match="ran without a payload"):
        res.payload("a")
    with pytest.raises(KeyError, match="attach payload="):
        res.payload(0)
