"""``fork_replica`` slot-copy edge cases (ISSUE 3 satellite).

The payload layer leans on three properties of the slot-to-slot copy:
dropped events (out-of-range destination) are exact no-ops, all source
reads happen against the pre-copy state (chained forks in one round), and
a re-fork into a previously terminated slot overwrites every leaf of the
stale state (params, both optimizer moments, step counter).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import walkers as wlk
from repro.optim import adamw, fork_replica, init_replicas
from repro.optim.rw_sgd import replica_train_step


def _replicas(n_slots=4, distinct=True):
    """ReplicaSet with per-slot-distinct params and non-trivial moments."""
    init_fn = lambda key: {"w": jax.random.normal(key, (3,))}
    opt = adamw(1e-1)
    rs = init_replicas(init_fn, opt.init, jax.random.key(0), max_walks=n_slots)
    if distinct:
        # one masked train step per slot against slot-specific targets
        # makes params, mu and nu all slot-distinct
        loss_fn = lambda p, b: (jnp.sum((p["w"] - b) ** 2), {})
        step = replica_train_step(loss_fn, opt)
        targets = jnp.arange(n_slots, dtype=jnp.float32)[:, None] * jnp.ones((3,))
        for _ in range(2):
            rs, _ = step(rs, targets, jnp.ones((n_slots,), bool))
    return rs


def _leaves(rs):
    return jax.tree.leaves((rs.params, rs.opt_state, rs.steps))


def _assert_slot_equal(rs_a, slot_a, rs_b, slot_b):
    for x, y in zip(_leaves(rs_a), _leaves(rs_b)):
        np.testing.assert_array_equal(np.asarray(x[slot_a]), np.asarray(y[slot_b]))


def test_fork_into_out_of_range_slot_is_noop():
    """A dropped fork event (destination == W, the allocate_fork_slots
    overflow encoding) must leave every slot untouched."""
    rs = _replicas(4)
    W = rs.steps.shape[0]
    out = fork_replica(rs, jnp.int32(0), jnp.int32(W), jnp.asarray(True))
    for x, y in zip(_leaves(out), _leaves(rs)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # and a masked-off event with an in-range destination is equally inert
    out2 = fork_replica(rs, jnp.int32(0), jnp.int32(2), jnp.asarray(False))
    for x, y in zip(_leaves(out2), _leaves(rs)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_chained_fork_reads_pre_round_state():
    """Two events in one round where one destination is another event's
    source: all copies read the PRE-copy state (gather-then-scatter), so
    a parent that is itself overwritten this round still hands its
    original replica to its child."""
    rs = _replicas(4)
    src = jnp.asarray([1, 0], jnp.int32)
    dst = jnp.asarray([0, 3], jnp.int32)  # slot 0 is overwritten AND read
    out = fork_replica(rs, src, dst, jnp.asarray([True, True]))
    _assert_slot_equal(out, 0, rs, 1)  # dst 0 <- old slot 1
    _assert_slot_equal(out, 3, rs, 0)  # dst 3 <- old slot 0 (pre-overwrite)
    _assert_slot_equal(out, 1, rs, 1)  # sources themselves untouched
    _assert_slot_equal(out, 2, rs, 2)


def test_parent_forked_then_parent_fails_child_keeps_copy():
    """Fork chained with the parent's death in the same round: the child
    slot keeps the copied replica after the parent slot is deactivated
    and even after the parent's replica is later clobbered."""
    rs = _replicas(4)
    ws = wlk.WalkState(
        pos=jnp.asarray([0, 1, 2, 3], jnp.int32),
        active=jnp.asarray([True, True, False, False]),
        track=jnp.arange(4, dtype=jnp.int32),
    )
    ls = jnp.full((5, 4), -1, jnp.int32)
    ev = jnp.asarray([True, False, False, False])  # walk 0 forks
    new_ws, _, n, fork_parent = wlk.execute_forks(ws, ls, ev, ws.pos, None, jnp.int32(3))
    assert int(n) == 1
    child = int(np.nonzero(np.asarray(fork_parent) >= 0)[0][0])
    out = fork_replica(
        rs, jnp.maximum(fork_parent, 0), jnp.arange(4, dtype=jnp.int32),
        fork_parent >= 0,
    )
    _assert_slot_equal(out, child, rs, 0)
    # parent dies (burst) right after: the child's copy is unaffected
    dead = new_ws.active.at[0].set(False)
    assert bool(dead[child])
    _assert_slot_equal(out, child, rs, 0)


def test_terminate_then_refork_overwrites_stale_payload_state():
    """Slot reuse: a replica left behind by a terminated walk must be
    fully replaced on re-fork — params, BOTH adamw moments, and the local
    step counter (no stale-state leakage into the new walk)."""
    rs = _replicas(4)  # every slot has nonzero moments + steps == 2
    # the doomed walk takes one extra local step before terminating, so
    # every leaf of its slot (params, moments, counters) is distinguishable
    loss_fn = lambda p, b: (jnp.sum((p["w"] - b) ** 2), {})
    step = replica_train_step(loss_fn, adamw(1e-1))
    only2 = jnp.asarray([False, False, True, False])
    rs, _ = step(rs, jnp.full((4, 3), 9.0), only2)
    # walk 2 terminates; later walk 1 forks into the freed slot 2
    out = fork_replica(rs, jnp.int32(1), jnp.int32(2), jnp.asarray(True))
    _assert_slot_equal(out, 2, rs, 1)
    # explicitly: nothing of the stale slot-2 state survives anywhere
    stale = _leaves(rs)
    fresh = _leaves(out)
    for x, y in zip(fresh, stale):
        assert not np.array_equal(np.asarray(x[2]), np.asarray(y[2])), (
            "stale leaf survived slot reuse"
        )
