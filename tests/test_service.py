"""ExperimentService (ISSUE 6 tentpole): coalescing submission queue.

Contract under test:
  * K submissions spanning G static structures execute as exactly G
    compiled programs (the ``_lower`` seam + ``cache_stats`` both
    agree), however many callers contributed;
  * coalescing is bitwise-invisible: every caller's results equal a
    private ``Plan.sweep`` of just their scenarios under the same
    seeds/base key;
  * differing seeds or base keys must NOT coalesce (they change the
    per-seed key derivation);
  * futures stream per-group results incrementally and in completion
    order; errors in a group propagate to exactly the touching futures;
  * the background-worker mode delivers the same results under
    concurrent submitters.
"""
import threading

import numpy as np
import pytest

from repro.api import Experiment, ExperimentService
from repro.api import plan as plan_mod
from repro.core import FailureConfig, ProtocolConfig
from repro.graphs import random_regular_graph
from repro.sweep import Scenario

N, W, Z0, STEPS, SEEDS, BASE_KEY = 24, 10, 5, 40, 2, 7


@pytest.fixture(scope="module")
def graph():
    return random_regular_graph(N, 4, seed=3)


def _pcfg(**kw):
    base = dict(algorithm="decafork", z0=Z0, max_walks=W, rt_bins=32,
                protocol_start=10, eps=1.8)
    base.update(kw)
    return ProtocolConfig(**base)


def _scen(name, **kw):
    fcfg = kw.pop("fcfg", FailureConfig())
    return Scenario(name, _pcfg(**kw), fcfg)


def _exp(graph, **kw):
    return Experiment(graph=graph, steps=STEPS, outputs="scalars",
                      scenarios=[_scen("base")], **kw)


def _count_lowerings(monkeypatch):
    calls = []
    real = plan_mod._lower

    def counting(mode, signature):
        calls.append((mode, signature))
        return real(mode, signature)

    monkeypatch.setattr(plan_mod, "_lower", counting)
    return calls


def _assert_tree_equal(ref, got, label):
    import jax

    rl = jax.tree_util.tree_leaves(ref)
    gl = jax.tree_util.tree_leaves(got)
    assert len(rl) == len(gl), label
    for a, b in zip(rl, gl):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=label)


# ---------------------------------------------------------------------------
# coalescing: K submissions, G static structures, G compiled programs
# ---------------------------------------------------------------------------


def test_submissions_coalesce_into_one_program_per_structure(
    graph, monkeypatch
):
    """Five scenario rows from three callers spanning TWO static
    structures (rt_bins 48 vs 64) run as exactly two compiled calls —
    counted at the _lower seam AND in jax's own compile cache."""
    calls = _count_lowerings(monkeypatch)
    svc = ExperimentService(_exp(graph), store=None, autostart=False)

    f1 = svc.submit(
        [_scen("a1", rt_bins=48, eps=1.6), _scen("a2", rt_bins=48, eps=2.0)],
        seeds=SEEDS, base_key=BASE_KEY,
    )
    f2 = svc.submit([_scen("b1", rt_bins=48, eps=2.4)],
                    seeds=SEEDS, base_key=BASE_KEY)
    f3 = svc.submit(
        [_scen("c1", rt_bins=64), _scen("c2", rt_bins=48, eps=1.9)],
        seeds=SEEDS, base_key=BASE_KEY,
    )
    before = plan_mod.cache_stats()["xla_compiles"]
    svc.flush()
    assert [c[0] for c in calls] == ["sweep", "sweep"]  # exactly G=2
    assert svc.stats["batches"] == 2
    assert svc.stats["coalesced"] == 4  # the four rt_bins=48 rows shared
    assert plan_mod.cache_stats()["xla_compiles"] - before <= 2
    for f in (f1, f2, f3):
        assert f.done()
    assert list(f1.result().names) == ["a1", "a2"]
    svc.close()


def test_coalesced_results_bitwise_equal_private_sweep(graph):
    """A caller's coalesced results are bitwise what a private
    Plan.sweep of ONLY their scenarios returns — strangers sharing the
    batch are invisible (the PR-1 stacking invariant, end to end)."""
    mine = [_scen("mine1", eps=1.7), _scen("mine2", eps=2.1)]
    stranger = [_scen("other1", eps=2.5), _scen("other2", eps=1.9),
                _scen("other3", fcfg=FailureConfig(burst_times=(15,),
                                                   burst_sizes=(2,)))]
    exp = _exp(graph)
    svc = ExperimentService(exp, store=None, autostart=False)
    f_mine = svc.submit(mine, seeds=SEEDS, base_key=BASE_KEY)
    f_other = svc.submit(stranger, seeds=SEEDS, base_key=BASE_KEY)
    svc.flush()
    res = f_mine.result()
    ref = exp.plan().sweep(mine, seeds=SEEDS, base_key=BASE_KEY)
    for name in ("mine1", "mine2"):
        _assert_tree_equal(ref[name], res[name], f"coalesced vs private: {name}")
    assert f_other.result().names == ("other1", "other2", "other3")
    svc.close()


def test_differing_seeds_or_base_key_never_coalesce(graph, monkeypatch):
    """seeds/base_key are part of the coalescing key: same structure but
    different batching axes must run as separate stacked calls."""
    svc = ExperimentService(_exp(graph), store=None, autostart=False)
    svc.submit([_scen("s1")], seeds=SEEDS, base_key=BASE_KEY)
    svc.submit([_scen("s2")], seeds=SEEDS + 1, base_key=BASE_KEY)
    svc.submit([_scen("s3")], seeds=SEEDS, base_key=BASE_KEY + 1)
    svc.flush()
    assert svc.stats["batches"] == 3
    assert svc.stats["coalesced"] == 0
    svc.close()


# ---------------------------------------------------------------------------
# futures: streaming, ordering, errors
# ---------------------------------------------------------------------------


def test_future_streams_per_group_results(graph):
    """A mixed submission yields scenarios per coalesced group as each
    group's compiled call finishes (first-seen group order), while
    ``result()`` restores submission order."""
    svc = ExperimentService(_exp(graph), store=None, autostart=False)
    fut = svc.submit(
        [_scen("slow", rt_bins=64), _scen("fast1", rt_bins=48),
         _scen("fast2", rt_bins=48, eps=2.2)],
        seeds=SEEDS,
    )
    svc.flush()
    order = [name for name, outs, pay in fut.stream()]
    # groups run in first-seen order: rt_bins=64 first, then the 48s
    assert order == ["slow", "fast1", "fast2"]
    res = fut.result()
    assert res.names == ("slow", "fast1", "fast2")  # input order restored
    svc.close()


def test_submit_validates_eagerly(graph):
    svc = ExperimentService(_exp(graph), store=None, autostart=False)
    with pytest.raises(ValueError, match="at least one scenario"):
        svc.submit([], seeds=SEEDS)
    with pytest.raises(ValueError, match="duplicate scenario names"):
        svc.submit([_scen("dup"), _scen("dup")], seeds=SEEDS)
    svc.close()
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit([_scen("late")], seeds=SEEDS)


def test_group_error_propagates_to_touching_futures_only(graph):
    """An invalid scenario poisons exactly the futures that share its
    batch; disjoint groups still deliver. (A concrete-array z0 defers
    the capacity check from config construction to stacking time, so
    the error fires inside the service's compiled-group run.)"""
    import jax.numpy as jnp

    bad = Scenario(
        "bad", _pcfg(z0=jnp.asarray(W + 5)), FailureConfig()
    )
    svc = ExperimentService(_exp(graph), store=None, autostart=False)
    f_bad = svc.submit([bad], seeds=SEEDS)
    f_ok = svc.submit([_scen("ok", rt_bins=64)], seeds=SEEDS)
    svc.flush()
    with pytest.raises(ValueError, match="max_walks"):
        f_bad.result()
    with pytest.raises(ValueError, match="max_walks"):
        list(f_bad.stream())
    assert f_ok.result().names == ("ok",)
    svc.close()


def test_result_timeout_reports_progress(graph, monkeypatch):
    """result(timeout=) raises while the batch is still in flight, and
    resolves normally once it lands."""
    svc = ExperimentService(_exp(graph), store=None, autostart=True,
                            linger=0.0)
    release = threading.Event()
    real = svc.plan.sweep_stacked

    def slow(*a, **kw):
        release.wait(60)
        return real(*a, **kw)

    monkeypatch.setattr(svc.plan, "sweep_stacked", slow)
    fut = svc.submit([_scen("s")], seeds=SEEDS)
    with pytest.raises(TimeoutError, match="0/1 scenarios"):
        fut.result(timeout=0.1)
    release.set()
    assert fut.result(timeout=120).names == ("s",)
    svc.close()


# ---------------------------------------------------------------------------
# background-worker mode
# ---------------------------------------------------------------------------


def test_threaded_submitters_coalesce_and_match(graph):
    """Concurrent submitters against the live worker: every caller gets
    their own bitwise-correct rows, and the batch count stays below the
    submission count (some coalescing happened across the linger)."""
    exp = _exp(graph)
    ref = exp.plan().sweep(
        [_scen(f"t{i}", eps=1.5 + 0.1 * i) for i in range(6)],
        seeds=SEEDS, base_key=BASE_KEY,
    )
    svc = ExperimentService(exp, store=None, autostart=True, linger=0.25)
    futures = [None] * 6
    start = threading.Barrier(6)

    def caller(i):
        start.wait()
        futures[i] = svc.submit(
            [_scen(f"t{i}", eps=1.5 + 0.1 * i)], seeds=SEEDS, base_key=BASE_KEY
        )

    threads = [threading.Thread(target=caller, args=(i,)) for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for i, fut in enumerate(futures):
        res = fut.result(timeout=120)
        _assert_tree_equal(ref[f"t{i}"], res[f"t{i}"], f"threaded t{i}")
    assert svc.stats["submissions"] == 6
    assert svc.stats["batches"] < 6  # the linger window coalesced some
    svc.close()


def test_service_run_convenience_and_context_manager(graph):
    with ExperimentService(_exp(graph), store=None, autostart=False) as svc:
        res = svc.run([_scen("one")], seeds=SEEDS, base_key=BASE_KEY)
        assert res.names == ("one",)


# ---------------------------------------------------------------------------
# named-experiment registry
# ---------------------------------------------------------------------------


def test_experiment_from_config_builds_registered_study():
    from repro.api import registry

    exp = Experiment.from_config({
        "experiment": "walks",
        "graph": "regular",
        "n": N,
        "graph_seed": 3,
        "steps": STEPS,
        "scenarios": [
            {"name": "calm", "protocol": {"z0": Z0, "max_walks": W}},
            {"name": "burst", "protocol": {"z0": Z0, "max_walks": W},
             "failures": {"burst_times": [15], "burst_sizes": [2]}},
        ],
        "outputs": "scalars",
    })
    assert exp.graph.n == N and exp.steps == STEPS
    assert [s.name for s in exp.scenarios] == ["calm", "burst"]
    assert "walks" in registry.names()
    with pytest.raises(KeyError, match="registered experiments"):
        Experiment.from_config({"experiment": "nope"})
    with pytest.raises(ValueError, match="'experiment' key"):
        Experiment.from_config({"n": 8})


def test_registry_rejects_bad_builders_and_rows():
    from repro.api import registry

    @registry.register("tmp-bad")
    def _bad(**kw):
        return "not an experiment"

    try:
        with pytest.raises(TypeError, match="expected an Experiment"):
            registry.build("tmp-bad")
    finally:
        registry._REGISTRY.pop("tmp-bad", None)
    with pytest.raises(TypeError, match="unknown keys"):
        Experiment.from_config({
            "experiment": "walks", "n": 12, "steps": 5,
            "scenarios": [{"name": "x", "bogus": 1}],
        })
