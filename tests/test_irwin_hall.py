import numpy as np
import pytest

from repro.core.irwin_hall import (
    _irwin_hall_cdf_closed,
    _irwin_hall_cdf_grid,
    design_eps,
    design_eps2,
    false_fork_probability,
    false_termination_probability,
    irwin_hall_cdf,
    scaled_irwin_hall_cdf,
)


def test_k1_is_uniform():
    xs = np.linspace(-0.5, 1.5, 21)
    np.testing.assert_allclose(irwin_hall_cdf(xs, 1), np.clip(xs, 0, 1), atol=1e-12)


def test_symmetry_at_mean():
    for k in (2, 5, 9):
        np.testing.assert_allclose(irwin_hall_cdf(k / 2, k), 0.5, atol=1e-9)
    np.testing.assert_allclose(irwin_hall_cdf(10.0, 20), 0.5, atol=1e-6)  # grid path


def test_closed_vs_grid():
    xs = np.linspace(0.1, 8.9, 40)
    a = _irwin_hall_cdf_closed(xs, 9)
    b = _irwin_hall_cdf_grid(xs, 9)
    np.testing.assert_allclose(a, b, atol=5e-3)  # grid discretization


def test_monte_carlo_agreement():
    rng = np.random.default_rng(0)
    k = 7
    samples = rng.random((200000, k)).sum(1)
    for x in (2.0, 3.5, 4.5):
        emp = (samples <= x).mean()
        assert abs(emp - irwin_hall_cdf(x, k)) < 5e-3


def test_scaled_irwin_hall():
    # sum of k U(0, 0.5): CDF at x = F_IH(2x)
    np.testing.assert_allclose(
        scaled_irwin_hall_cdf(1.0, 4, 0.5), irwin_hall_cdf(2.0, 4), atol=1e-12
    )
    assert scaled_irwin_hall_cdf(0.1, 3, 0.0) == 1.0


def test_design_rules_consistent():
    z0 = 10
    eps = design_eps(z0, 1e-3)
    eps2 = design_eps2(z0, 1e-3)
    assert eps < z0 / 2 + 0.5 < eps2
    np.testing.assert_allclose(false_fork_probability(z0, eps), 1e-3 / z0, rtol=0.02)
    np.testing.assert_allclose(
        false_termination_probability(z0, eps2), 1e-3 / z0, rtol=0.02
    )


def test_paper_threshold_diagnosis():
    """The paper quotes eps2=5.75 for Z0=10; under its own Prop.-3 design
    rule that is a 19.6% false-termination tail (documented discrepancy —
    EXPERIMENTS.md; our benchmarks use the design rule)."""
    tail = 1.0 - irwin_hall_cdf(5.75 - 0.5, 9)
    assert 0.15 < tail < 0.25


def test_cdf_monotone_in_k():
    # more uniforms -> stochastically larger -> smaller CDF at fixed x
    for x in (1.0, 2.0, 3.0):
        vals = [irwin_hall_cdf(x, k) for k in range(1, 12)]
        assert all(a >= b - 1e-12 for a, b in zip(vals, vals[1:]))
