"""Whole-round fusion (``round_impl="fused"``): bitwise oracle tests.

The contract is *bitwise* (not allclose): one fused round — topology
step, resident kills, masked rank-select hop, walk-level failures,
observation update, theta and the fork/terminate decisions — must be
freely interchangeable with the literal unfused sequence in
``protocol_step`` (``round_impl="unfused"``, THE oracle), over whole
multi-round trajectories, on shapes including node counts that are not
a multiple of the Pallas tile, under partial GraphState masks (node and
link churn), and on both execution backends of the fused round (the
pure-jnp incremental-CDF reference and the whole-round Pallas kernel,
exercised in interpret mode by pinning the backend).
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import estimator as est
from repro.core import failures as flr
from repro.core import protocol as prt
from repro.core import simulator as sim
from repro.core.simulator import _graph_arrays, _run_core
from repro.graphs.generators import erdos_renyi_graph, random_regular_graph
from repro.kernels import platform

KEY = jax.random.key(20)

# every threat model at once: bursts, probabilistic kills, a Byzantine
# chain, node/link churn (partial GraphState masks), a scheduled crash
# and a Pac-Man node — the fused round must track the oracle through all
CHURN = flr.FailureConfig(
    burst_times=(10, 25), burst_sizes=(3, 2), p_fail=0.01,
    byzantine_node=2, p_byz=0.05, byz_start_time=8,
    p_node_fail=0.02, p_node_recover=0.3, node_fail_start=5,
    p_link_fail=0.05, p_link_recover=0.4, link_fail_start=5,
    pacman_node=4, pacman_start_time=20,
    node_crash_times=(12,), node_crash_ids=(3,),
)
QUIET = flr.FailureConfig()  # full masks: the hop must equal the unmasked hop


def _pcfg(alg, impl, round_impl, **kw):
    return prt.ProtocolConfig(
        algorithm=alg, z0=6, max_walks=16, rt_bins=64,
        estimator_impl=impl, round_impl=round_impl, **kw
    )


def _trajectory(graph, pcfg, fcfg, steps=40, key=KEY):
    nbr, deg, mir, pi = _graph_arrays(graph, pcfg)
    return _run_core(key, nbr, deg, mir, pi, pcfg, fcfg, steps, graph.n)


def _assert_trajectories_equal(got, want, label):
    sf, tf = got
    su, tu = want
    for fld in tf._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(tf, fld)), np.asarray(getattr(tu, fld)),
            err_msg=f"{label}: out.{fld}",
        )
    np.testing.assert_array_equal(
        np.asarray(sf.last_seen), np.asarray(su.last_seen),
        err_msg=f"{label}: last_seen",
    )
    for fld in ("hist", "total"):
        np.testing.assert_array_equal(
            np.asarray(getattr(sf.rts, fld)), np.asarray(getattr(su.rts, fld)),
            err_msg=f"{label}: rts.{fld}",
        )
    for fld in ("pos", "active", "track"):
        np.testing.assert_array_equal(
            np.asarray(getattr(sf.walks, fld)),
            np.asarray(getattr(su.walks, fld)),
            err_msg=f"{label}: walks.{fld}",
        )
    for fld in ("node_up", "edge_up"):
        np.testing.assert_array_equal(
            np.asarray(getattr(sf.graph, fld)),
            np.asarray(getattr(su.graph, fld)),
            err_msg=f"{label}: graph.{fld}",
        )


# deliberately include n that are NOT multiples of the node tile (8).
# The fast lane keeps the most adversarial graph (n=19, non-tile-multiple);
# the remaining shapes ride the nightly full lane (each arm re-traces a
# whole 40-round scan, ~25s apiece on CPU).
GRAPHS = [
    pytest.param(
        "regular16", random_regular_graph(16, 4, seed=3),
        marks=pytest.mark.slow,
    ),
    pytest.param("er19", erdos_renyi_graph(19, p=0.3, seed=7)),
    pytest.param(
        "er13", erdos_renyi_graph(13, p=0.4, seed=5),
        marks=pytest.mark.slow,
    ),
]


@pytest.mark.parametrize("alg", ["decafork", "decafork+"])
@pytest.mark.parametrize("gname,graph", GRAPHS)
def test_fused_ref_matches_unfused_trajectory(alg, gname, graph):
    """The fused-ref round (incremental cumulative carry, row-restricted
    hop, pairwise choose) == the unfused oracle, bitwise, through a full
    churny trajectory."""
    pcfg_f = _pcfg(alg, "gather", "fused")
    pcfg_u = dataclasses.replace(pcfg_f, round_impl="unfused")
    assert sim._will_fuse_round(pcfg_f)
    assert not sim._will_fuse_round(pcfg_u)
    key = jax.random.fold_in(KEY, graph.n)
    _assert_trajectories_equal(
        _trajectory(graph, pcfg_f, CHURN, key=key),
        _trajectory(graph, pcfg_u, CHURN, key=key),
        f"{alg}/{gname}",
    )
    # the public carry representation is identical too (int16 counts)
    sf, _ = _trajectory(graph, pcfg_f, CHURN, key=key)
    assert sf.rts.hist.dtype == jnp.int16
    assert sf.rts.total.dtype == jnp.int32


@pytest.mark.parametrize("alg", ["decafork", "decafork+"])
def test_fused_ref_full_mask_noop_parity(alg):
    """With every failure knob off the masks stay full and the fused hop
    must be bitwise the unmasked hop — same walks, same observations."""
    g = random_regular_graph(19, 4, seed=2)
    pcfg_f = _pcfg(alg, "gather", "fused")
    pcfg_u = dataclasses.replace(pcfg_f, round_impl="unfused")
    got = _trajectory(g, pcfg_f, QUIET, steps=60)
    want = _trajectory(g, pcfg_u, QUIET, steps=60)
    _assert_trajectories_equal(got, want, f"{alg}/quiet")
    # sanity: nothing ever went down
    assert bool(jnp.all(got[0].graph.node_up))
    assert bool(jnp.all(got[0].graph.edge_up))


@pytest.mark.parametrize("alg", ["decafork", "decafork+"])
@pytest.mark.parametrize("gname,graph", GRAPHS)
def test_whole_round_pallas_matches_unfused_trajectory(
    alg, gname, graph, monkeypatch
):
    """The whole-round Pallas kernel (interpret mode on CPU, pinned via
    the backend hook) == the unfused oracle, bitwise, through a churny
    trajectory — including non-tile-multiple n and partial masks."""
    pcfg_f = _pcfg(alg, "compare", "fused")
    pcfg_u = dataclasses.replace(pcfg_f, round_impl="unfused")
    key = jax.random.fold_in(KEY, 100 + graph.n)
    want = _trajectory(graph, pcfg_u, CHURN, key=key)
    monkeypatch.setattr(platform, "fused_round_backend", lambda: "pallas")
    assert sim._will_fuse_round(pcfg_f)
    got = _trajectory(graph, pcfg_f, CHURN, key=key)
    _assert_trajectories_equal(got, want, f"pallas/{alg}/{gname}")


def test_whole_round_pallas_block_size_invariance():
    """Tile size must not change a single bit of any kernel output
    (padding rows are inert), on an n that no tested tile divides."""
    from repro.kernels.round_update import whole_round_pallas

    g = random_regular_graph(19, 4, seed=3)
    n, D, W, C, B, K = 19, 4, 12, 12, 16, 2
    ks = jax.random.split(jax.random.fold_in(KEY, 77), 20)
    pos = jax.random.randint(ks[0], (W,), 0, n, dtype=jnp.int32)
    neighbors = jnp.asarray(g.neighbors)
    args = (
        jax.random.randint(ks[1], (n, C), -1, 20, dtype=jnp.int32),  # ls
        jax.random.randint(ks[2], (n, B), 0, 5, dtype=jnp.int16),  # hist
        jax.random.randint(ks[3], (n,), 0, 50, dtype=jnp.int32),  # total
        jax.random.bernoulli(ks[4], 0.9, (n,)),  # node_up
        jax.random.bernoulli(ks[5], 0.9, (n, D)),  # edge_up
        pos,
        jnp.arange(W, dtype=jnp.int32),  # track
        jax.random.bernoulli(ks[6], 0.8, (W,)),  # active
        neighbors[pos],
        jnp.asarray(g.degrees)[pos],
        jax.random.bernoulli(ks[7], 0.9, (W, D)),  # edge_up_rows
        jax.random.uniform(ks[8], (W, D)),  # e_fail_rows
        jax.random.uniform(ks[9], (W, D)),  # e_rec_rows
        jax.random.uniform(ks[10], (W,)),  # u_move
        jax.random.uniform(ks[11], (W,)),  # u_pfail
        jax.random.uniform(ks[12], (W,)),  # u_fork
        jax.random.uniform(ks[13], (W,)),  # u_term
        jax.random.uniform(ks[14], (K, W)),  # u_burst
        jnp.asarray([2, 0], jnp.int32),  # burst_sizes_eff
        jax.random.uniform(ks[15], (n,)),  # u_nfail
        jax.random.uniform(ks[16], (n,)),  # u_nrec
        jnp.zeros((n,), bool).at[3].set(True),  # sched_down
        jax.random.uniform(ks[17], (n, D)),  # e_fail
        jax.random.uniform(ks[18], (n, D)),  # e_rec
    )
    kw = dict(
        params_f=jnp.asarray(
            [[0.02, 0.03, 0.05, 0.3, 0.4, 2.0, 5.75, 0.2]], jnp.float32
        ),
        params_i=jnp.asarray([[17, 2, -1, 1]], jnp.int32),
        decafork_plus=True,
        interpret=True,
    )
    want = whole_round_pallas(*args, block_nodes=8, **kw)
    for bn in (3, 19, 100):
        got = whole_round_pallas(*args, block_nodes=bn, **kw)
        for j, (a, b) in enumerate(zip(got, want)):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b), err_msg=f"bn={bn}, out[{j}]"
            )


def test_choose_walks_pairwise_matches_scatter():
    """The (W, W) pairwise choose == the (n,)-scatter choose, bitwise,
    over randomized occupancy patterns (shared nodes, inactive slots)."""
    for i in range(20):
        k1, k2 = jax.random.split(jax.random.fold_in(KEY, i))
        n, W = 11, 24
        pos = jax.random.randint(k1, (W,), 0, n, dtype=jnp.int32)
        active = jax.random.bernoulli(k2, 0.6, (W,))
        np.testing.assert_array_equal(
            np.asarray(prt.choose_walks_pairwise(pos, active)),
            np.asarray(prt.choose_walks(pos, active, n)),
            err_msg=f"case {i}",
        )


# ---------------------------------------------------------------------------
# the incremental cumulative carry (the fused-ref round's estimator)
# ---------------------------------------------------------------------------


def test_cumulative_carry_matches_histogram_carry():
    """record_returns_cumulative + cumulative_to_return_time == the
    histogram-carry record_returns, and theta_hat_cumulative == the
    gather path, bitwise, over random observation streams."""
    n, B, W, C = 13, 24, 10, 10
    rts = est.init_return_time_state(n, B)
    cum = est.init_cumulative_state(n, B)
    key = KEY
    for step in range(30):
        key, k1, k2, k3 = jax.random.split(key, 4)
        nodes = jax.random.randint(k1, (W,), 0, n, dtype=jnp.int32)
        r = jax.random.randint(k2, (W,), 1, B + 5, dtype=jnp.int32)
        valid = jax.random.bernoulli(k3, 0.7, (W,))
        rts = est.record_returns(rts, nodes, r, valid)
        cum = est.record_returns_cumulative(cum, nodes, r, valid, B)
    back = est.cumulative_to_return_time(cum, B)
    np.testing.assert_array_equal(np.asarray(back.hist), np.asarray(rts.hist))
    np.testing.assert_array_equal(
        np.asarray(back.total), np.asarray(rts.total)
    )
    assert back.hist.dtype == rts.hist.dtype == jnp.int16
    # theta agrees bitwise on random walk placements
    key, k1, k2, k3 = jax.random.split(key, 4)
    ls = jax.random.randint(k1, (n, C), -1, 25, dtype=jnp.int32)
    pos = jax.random.randint(k2, (W,), 0, n, dtype=jnp.int32)
    track = jax.random.randint(k3, (W,), 0, C, dtype=jnp.int32)
    t = jnp.int32(25)
    np.testing.assert_array_equal(
        np.asarray(est.theta_hat_cumulative(ls, cum, t, pos, track)),
        np.asarray(
            est.theta_hat_rows(ls, rts.hist, rts.total, t, pos, track)
        ),
    )


def test_cumulative_bin_trim_is_bitwise_neutral():
    """Trimming the cumulative table to the step budget (init_state's
    ``steps``) changes nothing: elapsed times never exceed t."""
    g = random_regular_graph(16, 4, seed=3)
    pcfg = _pcfg("decafork", "gather", "fused", protocol_start=5)
    assert sim._will_fuse_round(pcfg)
    nbr, deg, mir, pi = _graph_arrays(g, pcfg)
    # steps=30 < rt_bins=64 -> the carry is trimmed to 30 bins
    st, _ = _run_core(KEY, nbr, deg, mir, pi, pcfg, QUIET, 30, g.n)
    st_u, _ = _run_core(
        KEY, nbr, deg, mir, pi,
        dataclasses.replace(pcfg, round_impl="unfused"), QUIET, 30, g.n,
    )
    assert st.rts.hist.shape == st_u.rts.hist.shape  # padded back to rt_bins
    np.testing.assert_array_equal(
        np.asarray(st.rts.hist), np.asarray(st_u.rts.hist)
    )
    np.testing.assert_array_equal(
        np.asarray(st.rts.total), np.asarray(st_u.rts.total)
    )


# ---------------------------------------------------------------------------
# resolution layering: explicit config > auto > env override > default
# ---------------------------------------------------------------------------


def test_env_override_round_impl(monkeypatch):
    monkeypatch.setenv("REPRO_ROUND_IMPL", "unfused")
    assert platform.best_round_impl() == "unfused"
    assert not sim._will_fuse_round(_pcfg("decafork", "gather", "auto"))
    monkeypatch.setenv("REPRO_ROUND_IMPL", "fused")
    assert platform.best_round_impl() == "fused"
    assert sim._will_fuse_round(_pcfg("decafork", "gather", "auto"))
    # explicit config wins over the env override
    monkeypatch.setenv("REPRO_ROUND_IMPL", "fused")
    assert sim.resolved_round_impl(
        _pcfg("decafork", "gather", "unfused")
    ) == "unfused"
    monkeypatch.delenv("REPRO_ROUND_IMPL")
    assert platform.best_round_impl() == "fused"  # backend default


def test_env_override_estimator_impl(monkeypatch):
    monkeypatch.setenv("REPRO_ESTIMATOR_IMPL", "compare")
    assert platform.best_estimator_impl() == "compare"
    assert sim.resolved_estimator_impl(
        _pcfg("decafork", "auto", "unfused")
    ) == "compare"
    # explicit config wins
    assert sim.resolved_estimator_impl(
        _pcfg("decafork", "gather", "unfused")
    ) == "gather"
    monkeypatch.delenv("REPRO_ESTIMATOR_IMPL")
    assert platform.best_estimator_impl() in ("gather", "fused")


@pytest.mark.parametrize(
    "var,val",
    [("REPRO_ROUND_IMPL", "bogus"), ("REPRO_ESTIMATOR_IMPL", "bogus"),
     ("REPRO_ROUND_IMPL", "auto")],  # 'auto' is a config value, not an env one
)
def test_env_override_invalid_values_raise(monkeypatch, var, val):
    monkeypatch.setenv(var, val)
    fn = (
        platform.best_round_impl
        if var == "REPRO_ROUND_IMPL"
        else platform.best_estimator_impl
    )
    with pytest.raises(ValueError, match=var):
        fn()


def test_empty_env_override_is_unset(monkeypatch):
    monkeypatch.setenv("REPRO_ROUND_IMPL", "")
    assert platform.best_round_impl() == "fused"


def test_round_impl_validated_in_config():
    with pytest.raises(ValueError, match="round_impl"):
        prt.ProtocolConfig(round_impl="bogus")


def test_fuse_gate_excludes_unsupported_configs():
    """Configurations outside the fused round's bitwise envelope keep the
    literal unfused sequence."""
    assert not sim._will_fuse_round(_pcfg("missingperson", "gather", "fused"))
    assert not sim._will_fuse_round(_pcfg("none", "gather", "fused"))
    assert not sim._will_fuse_round(
        _pcfg("decafork", "gather", "fused", auto_eps=True)
    )
    assert not sim._will_fuse_round(
        _pcfg("decafork", "gather", "fused", analytic_survival=True)
    )
    # ref backend fuses the gather family only
    if platform.fused_round_backend() == "ref":
        assert not sim._will_fuse_round(_pcfg("decafork", "compare", "fused"))
        assert sim._will_fuse_round(_pcfg("decafork", "gather", "fused"))


def test_fused_path_rejects_analytic_pi():
    g = random_regular_graph(16, 4, seed=3)
    pcfg = _pcfg("decafork", "gather", "fused")
    nbr, deg, mir, _ = _graph_arrays(g, pcfg)
    state = sim.init_state(g.n, nbr.shape[1], pcfg, QUIET, KEY)
    with pytest.raises(ValueError, match="analytic"):
        sim.protocol_step(
            state, pcfg, QUIET, nbr, deg, mir, jnp.ones((g.n,)) / g.n
        )
