"""Pluggable walk-payload API (ISSUE 3).

Contract under test:
  * ``payload=None`` is the exact pre-payload engine — bitwise against
    the PR-2 golden trajectories;
  * attaching a payload (even the hook-free base class) leaves every
    simulator stream and ``StepOutputs`` trajectory bitwise unchanged;
  * the fused in-scan hook sequence equals a hand-rolled per-round hook
    loop (the old example's structure);
  * payload outputs batch under ensemble/sweep exactly like StepOutputs
    (``run_sweep[i]`` bitwise ``run_ensemble`` — losses included);
  * ``run_scenarios`` threads payload outputs through mixed groups.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Experiment
from repro.core import FailureConfig, Payload, ProtocolConfig
from repro.core.payload import PAYLOAD_STREAM, payload_init_key
from repro.core.simulator import init_state, protocol_step
from repro.data import make_markov_task
from repro.graphs import random_regular_graph
from repro.graphs.state import mirror_indices
from repro.models.config import ModelConfig
from repro.models.model import Model
from repro.optim import RwSgdPayload, adamw
from repro.sweep import Scenario
from repro.utils.prng import fold_in_time

GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "pr1_trajectories.json")

# must mirror tests/golden/capture_pr1.py
N, DEG, GRAPH_SEED = 24, 4, 3
W, Z0, STEPS, SEEDS, BASE_KEY = 10, 5, 60, 2, 7


@pytest.fixture(scope="module")
def graph():
    return random_regular_graph(N, DEG, seed=GRAPH_SEED)


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN) as f:
        return json.load(f)


def _pcfg(alg="decafork", **kw):
    base = dict(algorithm=alg, z0=Z0, max_walks=W, rt_bins=32, protocol_start=10)
    base.update(kw)
    return ProtocolConfig(**base)


def _tiny_payload(max_walks=W, train_every=1):
    cfg = ModelConfig(
        name="tiny", arch_type="dense", num_layers=1, d_model=32, d_ff=64,
        vocab_size=64, num_heads=2, num_kv_heads=2, head_dim=16,
        dtype="float32",
    )
    model = Model(cfg)
    task = make_markov_task(cfg.vocab_size, rank=4)
    return RwSgdPayload(
        model, adamw(1e-2), task, max_walks=max_walks, local_batch=1,
        seq_len=8, train_every=train_every,
    )


def _assert_outputs_equal(ref, got, label):
    for name, a, b in zip(ref._fields, ref, got):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=f"{label}: field {name}"
        )


# ---------------------------------------------------------------------------
# payload invariance of the control plane
# ---------------------------------------------------------------------------


def test_payload_none_is_bitwise_pr2_golden(graph, golden):
    """The payload-capable engine with payload=None reproduces the PR-2
    golden ensemble trajectories exactly."""
    pcfg = _pcfg("decafork", eps=1.8)
    fcfg = FailureConfig(burst_times=(20,), burst_sizes=(2,))
    outs = Experiment(graph=graph, protocol=pcfg, failures=fcfg, steps=STEPS,
                      payload=None, outputs="full").ensemble(
        SEEDS, base_key=BASE_KEY)
    ref = golden["ensemble"]["decafork/burst"]
    for name, arr in zip(outs._fields, outs):
        got = np.asarray(arr)
        np.testing.assert_array_equal(
            got, np.asarray(ref[name], dtype=got.dtype), err_msg=name
        )


def test_null_payload_leaves_golden_trajectories_bitwise(graph, golden):
    """Attaching the hook-free base Payload must not perturb a single
    simulator stream: StepOutputs stay bitwise the PR-2 goldens."""
    pcfg = _pcfg("decafork", eps=1.8)
    fcfg = FailureConfig(burst_times=(20,), burst_sizes=(2,))
    outs, pouts = Experiment(graph=graph, protocol=pcfg, failures=fcfg,
                             steps=STEPS, payload=Payload()).ensemble(
        SEEDS, base_key=BASE_KEY)
    assert pouts == ()
    ref = golden["ensemble"]["decafork/burst"]
    for name, arr in zip(outs._fields, outs):
        got = np.asarray(arr)
        np.testing.assert_array_equal(
            got, np.asarray(ref[name], dtype=got.dtype), err_msg=name
        )


@pytest.mark.slow
def test_rw_sgd_payload_leaves_sim_outputs_bitwise(graph):
    """Even a real training payload is invisible to the control plane."""
    pcfg = _pcfg("decafork+", eps=1.6, eps2=6.0)
    fcfg = FailureConfig(burst_times=(15,), burst_sizes=(2,))
    ref = Experiment(graph=graph, protocol=pcfg, failures=fcfg,
                     steps=25).ensemble(SEEDS, base_key=3)
    outs, learn = Experiment(graph=graph, protocol=pcfg, failures=fcfg,
                             steps=25, payload=_tiny_payload()).ensemble(
        SEEDS, base_key=3)
    _assert_outputs_equal(ref, outs, "rw-sgd attached")
    assert learn.loss.shape == (SEEDS, 25, W)
    assert np.isfinite(np.asarray(learn.loss)).all()


def test_run_simulation_return_shapes(graph):
    pcfg = _pcfg()
    fcfg = FailureConfig()
    final, outs = Experiment(graph=graph, protocol=pcfg, failures=fcfg,
                             steps=10).run(key=1)
    assert outs.z.shape == (10,)
    (final2, carry), (outs2, learn) = Experiment(
        graph=graph, protocol=pcfg, failures=fcfg, steps=10,
        payload=_tiny_payload(),
    ).run(key=1)
    _assert_outputs_equal(outs, outs2, "payload run")
    assert carry.steps.shape == (W,)
    assert learn.mean_loss.shape == (10,)


# ---------------------------------------------------------------------------
# fused hooks == hand-rolled per-round loop
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_fused_scan_matches_per_round_hook_loop(graph):
    """The in-scan hook sequence (on_terminate -> on_fork -> on_visit)
    reproduces a hand-rolled per-round loop, per-slot losses included."""
    payload = _tiny_payload()
    pcfg = _pcfg("decafork", eps=1.8)
    fcfg = FailureConfig(burst_times=(8,), burst_sizes=(2,))
    T = 15
    (_, rs_fused), (outs, learn) = Experiment(
        graph=graph, protocol=pcfg, failures=fcfg, steps=T, payload=payload
    ).run(key=0)

    key = jax.random.key(0)
    neighbors = jnp.asarray(graph.neighbors)
    degrees = jnp.asarray(graph.degrees)
    mirror = jnp.asarray(mirror_indices(graph))
    state = init_state(graph.n, graph.max_degree, pcfg, fcfg, key)
    rs = payload.init(payload_init_key(key))
    step = jax.jit(
        lambda s: protocol_step(s, pcfg, fcfg, neighbors, degrees, mirror, None)
    )
    losses = []
    for _ in range(T):
        k_visit = fold_in_time(state.key, state.t, PAYLOAD_STREAM)
        state, out = step(state)
        rs = payload.on_terminate(rs, out.terminated)
        rs = payload.on_fork(rs, out.fork_parent)
        rs, pout = payload.on_visit(rs, state.walks, state.t - 1, k_visit)
        losses.append(np.asarray(pout.loss))
    np.testing.assert_allclose(
        np.asarray(learn.loss), np.stack(losses), rtol=2e-5, atol=1e-6
    )
    np.testing.assert_array_equal(
        np.asarray(rs_fused.steps), np.asarray(rs.steps)
    )


def test_hook_order_is_terminate_fork_visit(graph):
    """The protocol frees slots (execute_terminations) BEFORE it
    reallocates them (execute_forks), so a slot can be terminated and
    re-forked in one round; the hooks must run in that order or a
    clearing payload would clobber the fresh copy. The scan body is
    traced once, so trace-time recording observes the per-round order."""
    calls = []

    class Recorder(Payload):
        def on_terminate(self, carry, terminated):
            calls.append("terminate")
            return carry

        def on_fork(self, carry, fork_parent):
            calls.append("fork")
            return carry

        def on_visit(self, carry, walks, t, key):
            calls.append("visit")
            return carry, ()

    Experiment(graph=graph, protocol=_pcfg(), steps=3,
               payload=Recorder()).run(key=0)
    assert calls == ["terminate", "fork", "visit"]


# ---------------------------------------------------------------------------
# RwSgdPayload hook semantics
# ---------------------------------------------------------------------------


def test_rw_sgd_on_fork_duplicates_parent_replica():
    payload = _tiny_payload(max_walks=4)
    rs = payload.init(jax.random.key(0))
    # make slot 0 distinct: one train step with only slot 0 active
    walks = type("WS", (), {})()
    walks.pos = jnp.zeros((4,), jnp.int32)
    walks.active = jnp.asarray([True, False, False, False])
    rs, _ = payload.on_visit(rs, walks, jnp.int32(0), jax.random.key(1))
    fork_parent = jnp.asarray([-1, -1, 0, -1], jnp.int32)
    rs2 = payload.on_fork(rs, fork_parent)
    for a, b in zip(jax.tree.leaves(rs2.params), jax.tree.leaves(rs.params)):
        np.testing.assert_array_equal(np.asarray(a[2]), np.asarray(b[0]))
        np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))
    assert int(rs2.steps[2]) == int(rs.steps[0]) == 1
    # no-fork round: fork_parent all -1 is a no-op
    rs3 = payload.on_fork(rs2, jnp.full((4,), -1, jnp.int32))
    for a, b in zip(jax.tree.leaves(rs3.params), jax.tree.leaves(rs2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_rw_sgd_on_visit_trains_only_active_slots():
    payload = _tiny_payload(max_walks=3)
    rs = payload.init(jax.random.key(0))
    walks = type("WS", (), {})()
    walks.pos = jnp.asarray([0, 1, 2], jnp.int32)
    walks.active = jnp.asarray([True, True, False])
    rs2, out = payload.on_visit(rs, walks, jnp.int32(0), jax.random.key(1))
    assert int(out.trained) == 2
    losses = np.asarray(out.loss)
    assert losses[0] > 0 and losses[1] > 0 and losses[2] == 0.0
    for a, b in zip(jax.tree.leaves(rs2.params), jax.tree.leaves(rs.params)):
        assert not np.array_equal(np.asarray(a[0]), np.asarray(b[0]))
        np.testing.assert_array_equal(np.asarray(a[2]), np.asarray(b[2]))
    np.testing.assert_array_equal(np.asarray(rs2.steps), [1, 1, 0])


def test_rw_sgd_train_every_thins_updates():
    payload = _tiny_payload(max_walks=2, train_every=2)
    rs = payload.init(jax.random.key(0))
    walks = type("WS", (), {})()
    walks.pos = jnp.asarray([0, 1], jnp.int32)
    walks.active = jnp.asarray([True, True])
    _, out_odd = payload.on_visit(rs, walks, jnp.int32(1), jax.random.key(1))
    assert int(out_odd.trained) == 0 and float(out_odd.mean_loss) == 0.0
    _, out_even = payload.on_visit(rs, walks, jnp.int32(2), jax.random.key(1))
    assert int(out_even.trained) == 2


def test_payload_validate_capacity_mismatch(graph):
    payload = _tiny_payload(max_walks=W + 1)
    with pytest.raises(ValueError, match="max_walks"):
        Experiment(graph=graph, protocol=_pcfg(), steps=5,
                   payload=payload).run()


# ---------------------------------------------------------------------------
# batching: payload outputs are ordinary sweep axes
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_payload():
    return _tiny_payload()


@pytest.mark.slow
def test_sweep_payload_matches_ensemble_bitwise(graph, small_payload):
    """run_sweep with a payload == per-scenario run_ensemble, bitwise —
    StepOutputs AND learning telemetry."""
    scenarios = [
        (_pcfg("decafork", eps=1.4),
         FailureConfig(burst_times=(8,), burst_sizes=(2,))),
        (_pcfg("decafork", eps=2.2), FailureConfig(p_fail=0.002)),
    ]
    T = 12
    outs, learn = Experiment(
        graph=graph, scenarios=scenarios, steps=T, payload=small_payload,
    ).plan().sweep_stacked(seeds=SEEDS, base_key=BASE_KEY)
    assert outs.z.shape == (2, SEEDS, T)
    assert learn.loss.shape == (2, SEEDS, T, W)
    for i, (pc, fc) in enumerate(scenarios):
        ref, ref_learn = Experiment(
            graph=graph, protocol=pc, failures=fc, steps=T,
            payload=small_payload,
        ).ensemble(SEEDS, base_key=BASE_KEY)
        got = jax.tree_util.tree_map(lambda x: x[i], outs)
        _assert_outputs_equal(ref, got, f"scenario{i}")
        for name, a, b in zip(ref_learn._fields, ref_learn, learn):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b[i]),
                err_msg=f"scenario{i}: payload field {name}",
            )


@pytest.mark.slow
def test_run_scenarios_threads_payloads_through_groups(graph, small_payload):
    """Mixed static groups each carry the payload; per-scenario payload
    outputs come back in input order, name-addressable."""
    fc = FailureConfig(burst_times=(8,), burst_sizes=(2,))
    scenarios = [
        Scenario("dfk", _pcfg("decafork", eps=1.6), fc),
        Scenario("none", _pcfg("none"), fc),
        Scenario("dfk2", _pcfg("decafork", eps=2.0), fc),
    ]
    T = 12
    res = Experiment(graph=graph, scenarios=scenarios, steps=T,
                     payload=small_payload).sweep(seeds=SEEDS, base_key=3)
    assert res.names == ("dfk", "none", "dfk2")
    assert res.payloads is not None and len(res.payloads) == 3
    for s in scenarios:
        ref, ref_learn = Experiment(
            graph=graph, protocol=s.pcfg, failures=s.fcfg, steps=T,
            payload=small_payload,
        ).ensemble(SEEDS, base_key=3)
        _assert_outputs_equal(ref, res[s.name], s.name)
        np.testing.assert_array_equal(
            np.asarray(ref_learn.loss), np.asarray(res.payload(s.name).loss),
            err_msg=s.name,
        )


def test_run_scenarios_without_payload_has_no_payloads(graph):
    fc = FailureConfig()
    res = Experiment(graph=graph, scenarios=[Scenario("a", _pcfg(), fc)],
                     steps=5).sweep(seeds=1)
    assert res.payloads is None
    with pytest.raises(KeyError):
        res.payload("a")
