import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import protocol as prt
from repro.core import walkers as wlk
from repro.core.estimator import NEVER


def test_config_validation():
    with pytest.raises(ValueError):
        prt.ProtocolConfig(algorithm="bogus")
    with pytest.raises(ValueError):
        prt.ProtocolConfig(z0=10, max_walks=5)
    cfg = prt.ProtocolConfig(z0=10)
    assert cfg.p == 0.1


def test_choose_walks_dedup():
    pos = jnp.array([3, 3, 5, 3, 7], jnp.int32)
    active = jnp.array([False, True, True, True, True])
    chosen = prt.choose_walks(pos, active, 10)
    # node 3: slots 1,3 active -> slot 1 chosen; node 5: slot 2; node 7: slot 4
    np.testing.assert_array_equal(
        np.asarray(chosen), [False, True, True, False, True]
    )


def test_decafork_decisions_threshold():
    cfg = prt.ProtocolConfig(algorithm="decafork+", z0=4, max_walks=8,
                             eps=2.0, eps2=5.0, fork_prob=1.0)
    theta = jnp.array([1.0, 3.0, 6.0, 1.0])
    chosen = jnp.array([True, True, True, False])
    fork, term = prt.decafork_decisions(
        theta, chosen, jax.random.key(0), cfg, jnp.asarray(True)
    )
    np.testing.assert_array_equal(np.asarray(fork), [True, False, False, False])
    np.testing.assert_array_equal(np.asarray(term), [False, False, True, False])
    # disabled -> nothing fires
    fork, term = prt.decafork_decisions(
        theta, chosen, jax.random.key(0), cfg, jnp.asarray(False)
    )
    assert not np.asarray(fork).any() and not np.asarray(term).any()


def test_decafork_probability_scaling():
    cfg = prt.ProtocolConfig(algorithm="decafork", z0=10, max_walks=16, eps=5.0)
    theta = jnp.zeros((2000,))
    chosen = jnp.ones((2000,), bool)
    fork, _ = prt.decafork_decisions(
        theta, chosen, jax.random.key(1), cfg, jnp.asarray(True)
    )
    rate = float(jnp.mean(fork))
    assert abs(rate - 0.1) < 0.03  # p = 1/Z0


def test_missingperson_flags():
    cfg = prt.ProtocolConfig(
        algorithm="missingperson", z0=3, max_walks=6, eps_mp=10.0, fork_prob=1.0
    )
    n, W = 4, 6
    last_seen = jnp.zeros((n, W), jnp.int32)
    # walk 0 at node 2; id 1 last seen at t=0 (stale), id 2 seen at t=15
    last_seen = last_seen.at[2, 2].set(15)
    pos = jnp.array([2, 0, 0, 0, 0, 0], jnp.int32)
    track = jnp.arange(W, dtype=jnp.int32)
    chosen = jnp.array([True] + [False] * 5)
    ev = prt.missingperson_decisions(
        last_seen, pos, track, chosen, jnp.int32(20), jax.random.key(0), cfg,
        jnp.asarray(True),
    )
    ev = np.asarray(ev)
    # events span the full track space; columns >= z0 are masked off so
    # that z0 can stay a traced (sweep-batchable) value
    assert ev.shape == (W, W)
    assert ev[0, 1]  # id 1 stale -> replacement fork
    assert not ev[0, 0]  # own id excluded
    assert not ev[0, 2]  # id 2 fresh (20-15 <= 10)
    assert not ev[:, 3:].any()  # non-initial ids (>= z0) never fire
    assert not ev[1:].any()  # only the chosen walk's node acts


def test_execute_forks_capacity_and_tracks():
    ws = wlk.WalkState(
        pos=jnp.array([1, 2, 3, 0], jnp.int32),
        active=jnp.array([True, True, True, False]),
        track=jnp.arange(4, dtype=jnp.int32),
    )
    last_seen = jnp.full((5, 4), 7, jnp.int32)
    # two fork events but only one free slot -> one executes
    ev = jnp.array([True, True, False, False])
    new_ws, new_ls, n, fp = wlk.execute_forks(ws, last_seen, ev, ws.pos, None, jnp.int32(9))
    assert int(n) == 1
    assert bool(new_ws.active[3])
    assert int(new_ws.pos[3]) == 1  # forked from walk 0's node
    assert int(new_ws.track[3]) == 3  # fresh identity = slot
    ls = np.asarray(new_ls)
    assert ls[1, 3] == 9  # origin node recorded the new walk
    assert (ls[[0, 2, 3, 4], 3] == NEVER).all()  # rest of column cleared


def test_execute_forks_missingperson_identity():
    ws = wlk.WalkState(
        pos=jnp.array([4, 0, 0], jnp.int32),
        active=jnp.array([True, False, False]),
        track=jnp.array([0, 1, 2], jnp.int32),
    )
    last_seen = jnp.full((5, 3), 11, jnp.int32)
    ev = jnp.array([True, False, False])
    tracks = jnp.array([2, 0, 0], jnp.int32)  # replacement carries id 2
    new_ws, new_ls, n, fp = wlk.execute_forks(ws, last_seen, ev, ws.pos, tracks, jnp.int32(12))
    assert int(n) == 1
    assert int(new_ws.track[1]) == 2
    # MISSINGPERSON does NOT clear the identity column
    assert (np.asarray(new_ls) == 11).all()


def test_terminations():
    ws = wlk.WalkState(
        pos=jnp.zeros(3, jnp.int32),
        active=jnp.array([True, True, True]),
        track=jnp.arange(3, dtype=jnp.int32),
    )
    out = wlk.execute_terminations(ws, jnp.array([False, True, False]))
    np.testing.assert_array_equal(np.asarray(out.active), [True, False, True])
