import jax
import numpy as np
import pytest

from repro.core.failures import FailureConfig
from repro.core.protocol import ProtocolConfig
from repro.api import Experiment
from repro.core.simulator import max_overshoot, reaction_time, survived
from repro.graphs import random_regular_graph


@pytest.fixture(scope="module")
def graph():
    return random_regular_graph(64, 6, seed=11)


def test_reproducible(graph):
    pcfg = ProtocolConfig(algorithm="decafork", z0=6, max_walks=24, eps=1.8,
                          protocol_start=300, rt_bins=256)
    fcfg = FailureConfig(burst_times=(600,), burst_sizes=(3,))
    _, a = Experiment(graph=graph, protocol=pcfg, failures=fcfg, steps=1000).run(key=5)
    _, b = Experiment(graph=graph, protocol=pcfg, failures=fcfg, steps=1000).run(key=5)
    np.testing.assert_array_equal(np.asarray(a.z), np.asarray(b.z))


def test_no_protocol_collapses(graph):
    pcfg = ProtocolConfig(algorithm="none", z0=6, max_walks=24)
    fcfg = FailureConfig(p_fail=0.01)
    _, outs = Experiment(graph=graph, protocol=pcfg, failures=fcfg, steps=2000).run(key=0)
    z = np.asarray(outs.z)
    assert z[-1] == 0  # catastrophic failure without self-regulation
    assert not survived(z)


def test_burst_kills_exact_count(graph):
    pcfg = ProtocolConfig(algorithm="none", z0=8, max_walks=16)
    fcfg = FailureConfig(burst_times=(100,), burst_sizes=(5,))
    _, outs = Experiment(graph=graph, protocol=pcfg, failures=fcfg, steps=200).run(key=1)
    z = np.asarray(outs.z)
    assert z[99] == 8 and z[100] == 3
    assert int(np.asarray(outs.failures).sum()) == 5


def test_decafork_recovers(graph):
    pcfg = ProtocolConfig(algorithm="decafork", z0=6, max_walks=24, eps=1.2,
                          protocol_start=400, rt_bins=256)
    fcfg = FailureConfig(burst_times=(800,), burst_sizes=(3,))
    _, outs = Experiment(graph=graph, protocol=pcfg, failures=fcfg, steps=2500).run(key=3)
    z = np.asarray(outs.z)
    z_pre = int(z[799])
    assert z_pre >= 6  # held (or exceeded) the target before the burst
    assert int(z[800]) == z_pre - 3  # burst kills exactly 3
    rt = reaction_time(z, 6, 800)
    assert 0 <= rt < 1200
    assert survived(z)
    assert max_overshoot(z, 6) <= 10


def test_walk_count_bounded_by_capacity(graph):
    pcfg = ProtocolConfig(algorithm="missingperson", z0=6, max_walks=12,
                          eps_mp=20.0, protocol_start=0)
    fcfg = FailureConfig()
    _, outs = Experiment(graph=graph, protocol=pcfg, failures=fcfg, steps=500).run(key=4)
    assert np.asarray(outs.z).max() <= 12


def test_ensemble_shape_and_variation(graph):
    pcfg = ProtocolConfig(algorithm="decafork", z0=6, max_walks=16, eps=1.8,
                          protocol_start=300, rt_bins=256)
    fcfg = FailureConfig(burst_times=(600,), burst_sizes=(3,))
    outs = Experiment(graph=graph, protocol=pcfg, failures=fcfg, steps=900).ensemble(seeds=4)
    z = np.asarray(outs.z)
    assert z.shape == (4, 900)
    # different seeds -> different trajectories
    assert not (z[0] == z[1]).all()


def test_byzantine_gating(graph):
    pcfg = ProtocolConfig(algorithm="none", z0=6, max_walks=8)
    fcfg = FailureConfig(byzantine_node=0, p_byz=0.0, byz_start=True,
                         byz_start_time=300)
    _, outs = Experiment(graph=graph, protocol=pcfg, failures=fcfg, steps=600).run(key=6)
    z = np.asarray(outs.z)
    assert (z[:299] == 6).all()  # honest before onset
    assert z[-1] < 6  # kills once armed


def test_metrics_helpers():
    z = np.array([5, 5, 2, 3, 4, 5, 6])
    assert reaction_time(z, 5, 2) == 3
    assert reaction_time(np.array([5, 1, 1]), 5, 1) == -1
    assert max_overshoot(z, 5) == 1
    assert survived(z) and not survived(np.array([1, 0, 2]))
