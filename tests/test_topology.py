"""Dynamic-topology failure layer: no-op equivalence + failure semantics.

The refactor's contract (ISSUE 2): with every topology knob disabled the
simulator is *bitwise* the PR-1 simulator — verified against golden
trajectories captured at the pre-GraphState commit
(``tests/golden/capture_pr1.py``) — and with knobs armed the new failure
modes (node crashes, link failures, Pac-Man absorption) behave per spec.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Experiment
from repro.core import FailureConfig, ProtocolConfig
from repro.core import failures as flr
from repro.core import walkers as wlk
from repro.graphs import (
    GraphState,
    availability,
    init_graph_state,
    mirror_indices,
    random_regular_graph,
    ring_graph,
)

GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "pr1_trajectories.json")

# must mirror tests/golden/capture_pr1.py
N, DEG, GRAPH_SEED = 24, 4, 3
W, Z0, STEPS, SEEDS, BASE_KEY = 10, 5, 60, 2, 7


@pytest.fixture(scope="module")
def graph():
    return random_regular_graph(N, DEG, seed=GRAPH_SEED)


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN) as f:
        return json.load(f)


def _pcfg(alg, **kw):
    base = dict(algorithm=alg, z0=Z0, max_walks=W, rt_bins=32, protocol_start=10)
    base.update(kw)
    return ProtocolConfig(**base)


def _golden_cases():
    burst = FailureConfig(burst_times=(20,), burst_sizes=(2,))
    byz = FailureConfig(
        burst_times=(25,), burst_sizes=(1,), p_fail=0.002,
        byzantine_node=1, p_byz=0.01, byz_start_time=15,
    )
    return [
        ("decafork/burst", _pcfg("decafork", eps=1.8), burst),
        ("decafork+/byz", _pcfg("decafork+", eps=1.6, eps2=6.0), byz),
        ("missingperson/burst", _pcfg("missingperson", eps_mp=20.0), burst),
        ("none/pfail", _pcfg("none"), FailureConfig(p_fail=0.004)),
    ]


def _assert_matches_golden(outs, ref: dict, label: str):
    for name, arr in zip(outs._fields, outs):
        got = np.asarray(arr)
        want = np.asarray(ref[name], dtype=got.dtype)
        np.testing.assert_array_equal(got, want, err_msg=f"{label}: field {name}")


# ---------------------------------------------------------------------------
# bitwise no-op equivalence vs PR-1 golden trajectories
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("case", range(4))
def test_disabled_topology_is_bitwise_pr1_ensemble(graph, golden, case):
    """All topology knobs at their defaults == the pre-refactor engine."""
    name, pcfg, fcfg = _golden_cases()[case]
    # outputs="full": keep the per-walk fork/terminate streams under
    # golden coverage too, not just the default scalar diagnostics
    outs = Experiment(graph=graph, protocol=pcfg, failures=fcfg, steps=STEPS,
                      outputs="full").ensemble(SEEDS, base_key=BASE_KEY)
    _assert_matches_golden(outs, golden["ensemble"][name], name)


def test_disabled_topology_is_bitwise_pr1_sweep(graph, golden):
    scenarios = [
        (_pcfg("decafork", eps=1.4),
         FailureConfig(burst_times=(20,), burst_sizes=(2,))),
        (_pcfg("decafork", eps=2.2),
         FailureConfig(burst_times=(30,), burst_sizes=(1,), p_fail=0.002)),
    ]
    outs = Experiment(graph=graph, scenarios=scenarios, steps=STEPS,
                      outputs="full").plan().sweep_stacked(
        seeds=SEEDS, base_key=BASE_KEY)
    _assert_matches_golden(outs, golden["sweep"]["decafork/eps-grid"], "sweep")


def test_explicit_zero_knobs_match_defaults(graph):
    """Explicitly-zero topology knobs are the same numeric no-op as the
    default config (rates 0, ids -1, empty schedules share the program)."""
    pcfg = _pcfg("decafork", eps=1.8)
    base = FailureConfig(burst_times=(20,), burst_sizes=(2,))
    zeroed = FailureConfig(
        burst_times=(20,), burst_sizes=(2,),
        p_node_fail=0.0, p_node_recover=0.0, p_link_fail=0.0,
        p_link_recover=0.0, pacman_node=-1, node_crash_times=(-1,),
        node_crash_ids=(-1,),
    )
    a = Experiment(graph=graph, protocol=pcfg, failures=base,
                   steps=STEPS).ensemble(SEEDS, base_key=BASE_KEY)
    b = Experiment(graph=graph, protocol=pcfg, failures=zeroed,
                   steps=STEPS).ensemble(SEEDS, base_key=BASE_KEY)
    for name, x, y in zip(a._fields, a, b):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y), err_msg=f"field {name}"
        )


# ---------------------------------------------------------------------------
# masked movement
# ---------------------------------------------------------------------------


def test_move_walks_full_mask_bitwise_equal(graph):
    """Masked sampling over a fully-up GraphState == unmasked sampling."""
    neighbors = jnp.asarray(graph.neighbors)
    degrees = jnp.asarray(graph.degrees)
    gs = init_graph_state(graph.n, graph.max_degree)
    key = jax.random.key(42)
    ws = wlk.init_walks(Z0, W, graph.n, jax.random.key(1))
    for i in range(5):
        k = jax.random.fold_in(key, i)
        plain = wlk.move_walks(ws, neighbors, degrees, k)
        masked = wlk.move_walks(
            ws, neighbors, degrees, k, availability(gs, neighbors, degrees)
        )
        np.testing.assert_array_equal(np.asarray(plain.pos), np.asarray(masked.pos))
        ws = masked


def test_stranded_walk_holds_position():
    """A walk on a node with no available incident edge stays put."""
    g = ring_graph(6)
    neighbors = jnp.asarray(g.neighbors)
    degrees = jnp.asarray(g.degrees)
    # sever both edges incident to node 2 (both directed slots each)
    edge_up = np.ones((g.n, g.max_degree), bool)
    for k in range(int(g.degrees[2])):
        j = int(g.neighbors[2, k])
        edge_up[2, k] = False
        edge_up[j, np.nonzero(g.neighbors[j] == 2)[0][0]] = False
    gs = GraphState(node_up=jnp.ones((g.n,), bool), edge_up=jnp.asarray(edge_up))
    ws = wlk.WalkState(
        pos=jnp.array([2, 0], jnp.int32),
        active=jnp.array([True, True]),
        track=jnp.arange(2, dtype=jnp.int32),
    )
    out = wlk.move_walks(
        ws, neighbors, degrees, jax.random.key(0),
        availability(gs, neighbors, degrees),
    )
    assert int(out.pos[0]) == 2  # stranded: held position
    assert int(out.pos[1]) != 0  # the free walk moved
    assert bool(out.active[0])  # stranding is not death


def test_availability_respects_down_nodes():
    g = ring_graph(5)
    gs = init_graph_state(g.n, g.max_degree)
    gs = gs._replace(node_up=gs.node_up.at[3].set(False))
    av = np.asarray(availability(gs, jnp.asarray(g.neighbors), jnp.asarray(g.degrees)))
    nbrs = np.asarray(g.neighbors)
    # no edge into node 3, and nothing out of it
    assert not av[3].any()
    for i in range(g.n):
        for k in range(int(g.degrees[i])):
            if nbrs[i, k] == 3:
                assert not av[i, k]


def test_mirror_indices_involution(graph):
    m = mirror_indices(graph)
    nbrs = np.asarray(graph.neighbors)
    degs = np.asarray(graph.degrees)
    for i in range(graph.n):
        for k in range(degs[i]):
            j = nbrs[i, k]
            assert nbrs[j, m[i, k]] == i
            assert m[j, m[i, k]] == k  # involution


# ---------------------------------------------------------------------------
# topology failure semantics
# ---------------------------------------------------------------------------


def test_scheduled_crash_kills_resident_walks(graph):
    """Crashing every start node at t=0 kills the whole population."""
    pcfg = _pcfg("none")
    # i.i.d. crash with p=1 downs every node at t=0: all walks die at once
    fcfg = FailureConfig(p_node_fail=1.0)
    _, outs = Experiment(graph=graph, protocol=pcfg, failures=fcfg, steps=5).run(key=0)
    z = np.asarray(outs.z)
    assert (z == 0).all()
    assert int(np.asarray(outs.failures)[0]) == Z0


def test_scheduled_crash_and_recovery(graph):
    """A scheduled crash downs one node; resident walks die, others
    survive, and with p_node_recover=1 the node is back next step."""
    pcfg = _pcfg("none")
    fcfg = FailureConfig(
        node_crash_times=(3,), node_crash_ids=(0,), p_node_recover=1.0
    )
    final, outs = Experiment(graph=graph, protocol=pcfg, failures=fcfg, steps=10).run(key=2)
    z = np.asarray(outs.z)
    lost = int(np.asarray(outs.failures).sum())
    assert (z[3:] == Z0 - lost).all()  # only the resident kills at t=3
    assert bool(np.asarray(final.graph.node_up).all())  # recovered


def test_permanent_link_failures_strand_walks():
    """p_link_fail=1 with no recovery severs every edge: all walks freeze
    in place but stay alive (link loss is not walk death)."""
    g = ring_graph(8)
    pcfg = ProtocolConfig(algorithm="none", z0=4, max_walks=8)
    fcfg = FailureConfig(p_link_fail=1.0)
    final, outs = Experiment(graph=g, protocol=pcfg, failures=fcfg, steps=6).run(key=1)
    assert (np.asarray(outs.z) == 4).all()
    assert not bool(np.asarray(final.graph.edge_up).any())
    # frozen: every edge is down before the first hop, so positions are
    # identical after 6 and after 12 steps (same key -> same initial spots)
    pos0 = np.asarray(final.walks.pos)
    final2, outs2 = Experiment(graph=g, protocol=pcfg, failures=fcfg, steps=12).run(key=1)
    assert (np.asarray(outs2.z) == 4).all()
    np.testing.assert_array_equal(pos0, np.asarray(final2.walks.pos))


def test_link_failure_symmetry(graph):
    """step_topology keeps the two directed slots of an edge in lockstep."""
    neighbors = jnp.asarray(graph.neighbors)
    degrees = jnp.asarray(graph.degrees)
    mirror = jnp.asarray(mirror_indices(graph))
    gs = init_graph_state(graph.n, graph.max_degree)
    fcfg = FailureConfig(p_link_fail=0.4, p_link_recover=0.3)
    for t in range(6):
        gs = flr.step_topology(
            gs, jnp.int32(t), fcfg, jax.random.key(t), neighbors, mirror
        )
        eu = np.asarray(gs.edge_up)
        nbrs = np.asarray(graph.neighbors)
        m = np.asarray(mirror)
        for i in range(graph.n):
            for k in range(int(graph.degrees[i])):
                j = nbrs[i, k]
                assert eu[i, k] == eu[j, m[i, k]], (t, i, k)


def test_pacman_absorbs_all_walks(graph):
    """An armed Pac-Man eventually eats the whole (unregulated) walk
    population — every walk that steps onto it disappears silently."""
    pcfg = _pcfg("none")
    fcfg = FailureConfig(pacman_node=0, pacman_start_time=0)
    _, outs = Experiment(graph=graph, protocol=pcfg, failures=fcfg, steps=2000).run(key=3)
    z = np.asarray(outs.z)
    assert z[-1] == 0
    assert (np.diff(z) <= 0).all()  # absorption only, never regrowth


def test_pacman_start_time_gates_absorption(graph):
    pcfg = _pcfg("none")
    fcfg = FailureConfig(pacman_node=0, pacman_start_time=50)
    _, outs = Experiment(graph=graph, protocol=pcfg, failures=fcfg, steps=100).run(key=3)
    z = np.asarray(outs.z)
    assert (z[:49] == Z0).all()  # honest before onset


def test_crashed_byzantine_node_is_harmless(graph):
    """Edge case from the issue: crash the Byzantine node. Its resident
    walks die with the crash, but afterwards no walk can step onto it, so
    the Byzantine kill mechanism never fires again."""
    pcfg = _pcfg("none")
    byz_only = FailureConfig(byzantine_node=1, p_byz=0.0, byz_start=True,
                             byz_start_time=0)
    both = FailureConfig(byzantine_node=1, p_byz=0.0, byz_start=True,
                         byz_start_time=0,
                         node_crash_times=(0,), node_crash_ids=(1,))
    _, outs_byz = Experiment(graph=graph, protocol=pcfg, failures=byz_only, steps=400).run(key=5)
    _, outs_both = Experiment(graph=graph, protocol=pcfg, failures=both, steps=400).run(key=5)
    z_byz = np.asarray(outs_byz.z)
    z_both = np.asarray(outs_both.z)
    # byz node alone keeps killing visitors over time
    assert z_byz[-1] < Z0
    # crashed byz node: at most the t=0 resident kills, then a plateau
    assert (z_both == z_both[-1]).all() or (np.diff(z_both) <= 0).all()
    assert (np.diff(z_both[1:]) == 0).all()


# ---------------------------------------------------------------------------
# sweep integration: topology knobs as scenario rows
# ---------------------------------------------------------------------------


def test_topology_scenarios_batch_and_match_ensemble(graph):
    """Node-crash / link-failure / Pac-Man rows co-batch in one sweep and
    stay bitwise equal to their per-scenario ensembles."""
    pcfg = _pcfg("decafork", eps=1.8)
    scenarios = [
        (pcfg, FailureConfig(node_crash_times=(20,), node_crash_ids=(2,),
                             p_node_recover=0.05)),
        (pcfg, FailureConfig(p_link_fail=0.01, p_link_recover=0.2)),
        (pcfg, FailureConfig(pacman_node=0, pacman_start_time=30)),
        (pcfg, FailureConfig(p_node_fail=0.002, p_node_recover=0.1)),
    ]
    out = Experiment(graph=graph, scenarios=scenarios,
                     steps=STEPS).plan().sweep_stacked(
        seeds=SEEDS, base_key=BASE_KEY)
    assert out.z.shape == (4, SEEDS, STEPS)
    for i, (pc, fc) in enumerate(scenarios):
        ref = Experiment(graph=graph, protocol=pc, failures=fc,
                         steps=STEPS).ensemble(SEEDS, base_key=BASE_KEY)
        for name, a, b in zip(ref._fields, ref, out):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b[i]),
                err_msg=f"scenario{i}: field {name}",
            )


def test_pad_bursts_pads_node_crash_schedules():
    a = FailureConfig(node_crash_times=(5, 9), node_crash_ids=(1, 2))
    b = FailureConfig(burst_times=(7,), burst_sizes=(2,))
    pa, pb = flr.pad_bursts([a, b])
    assert pa.n_bursts == pb.n_bursts == 1
    assert pa.n_node_crashes == pb.n_node_crashes == 2
    assert np.asarray(pb.node_crash_times).tolist() == [-1, -1]
    assert np.asarray(pb.node_crash_ids).tolist() == [-1, -1]
    assert np.asarray(pa.burst_times).tolist() == [-1]
