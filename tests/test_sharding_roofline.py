"""Sharding policy + roofline parsing (no 512-device mesh needed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.utils.compat import AxisType, abstract_mesh  # noqa: F401  (compat-gated)

from repro.launch import roofline as rl
from repro.launch import sharding as shd


@pytest.fixture(scope="module")
def mesh():
    # abstract mesh: sharding specs only need axis sizes, so build a
    # 1-device-backed mesh with logical sizes via AbstractMesh semantics.
    return abstract_mesh((16, 16), ("data", "model"))


def test_spec_divisibility_fallback(mesh):
    # llama3 embed vocab 128256 divides 16 -> sharded
    assert shd.spec_for_param("embed", (128256, 16384), mesh) == P("model", None)
    # mamba2 vocab 50280 does not -> replicated
    assert shd.spec_for_param("embed", (50280, 2048), mesh) == P(None, None)
    # hymba 25 heads don't divide -> replicated head dim
    assert shd.spec_for_param("layers/attn/wq", (32, 1600, 25, 64), mesh) == P(
        None, None, None, None
    )
    # llama 128 heads divide (stacked layer dim unsharded)
    assert shd.spec_for_param("layers/attn/wq", (126, 16384, 128, 128), mesh) == P(
        None, None, "model", None
    )
    # moe experts shard expert-parallel
    assert shd.spec_for_param("layers/moe/gate", (60, 160, 5120, 1536), mesh) == P(
        None, "model", None, None
    )
    # swiglu 2-D gate shards d_ff
    assert shd.spec_for_param("layers/mlp/gate", (32, 4096, 11008), mesh) == P(
        None, None, "model"
    )
    # norms replicate
    assert shd.spec_for_param("layers/attn_norm", (32, 4096), mesh) == P()


def test_batch_sharding_batch1_replicates(mesh):
    spec = {"tokens": jax.ShapeDtypeStruct((1, 1), jnp.int32)}
    sh = shd.batch_shardings(spec, mesh)
    assert sh["tokens"].spec == P()
    spec = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    sh = shd.batch_shardings(spec, mesh)
    assert sh["tokens"].spec == P(("data",), None)


def test_cache_sharding_long_context(mesh):
    from repro.configs import get_config

    cfg = get_config("llama3_405b")
    shapes = {
        "layers": {
            "k": jax.ShapeDtypeStruct((126, 1, 8192, 8, 128), jnp.bfloat16),
            "v": jax.ShapeDtypeStruct((126, 1, 8192, 8, 128), jnp.bfloat16),
        },
        "cache_positions": jax.ShapeDtypeStruct((1, 8192), jnp.int32),
        "next_pos": jax.ShapeDtypeStruct((1,), jnp.int32),
    }
    sh = shd.cache_shardings(shapes, mesh, cfg)
    # batch=1: the KV ring shards its window over data instead
    assert sh["layers"]["k"].spec == P(None, None, ("data",), None, None)
    assert sh["cache_positions"].spec == P(None, ("data",))
    assert sh["next_pos"].spec == P(None)


def test_collective_parser():
    hlo = """
  %all-reduce.1 = f32[128,256]{1,0} all-reduce(%dot.1), channel_id=1
  %ag = bf16[16,1024]{1,0} all-gather(%p0), dimensions={0}
  %rs.5 = f32[4,8]{1,0} reduce-scatter(%x), dimensions={0}
  %cp = u32[8]{0} collective-permute(%y), source_target_pairs={{0,1}}
  %a2a.2 = bf16[64,64]{1,0} all-to-all(%z), dimensions={1}
  %ars = f32[2,2]{1,0} all-reduce-start(%q)
  %ard = f32[2,2]{1,0} all-reduce-done(%ars)
  %not_a_collective = f32[9]{0} add(%a, %b)
"""
    out = rl.collective_bytes(hlo)
    assert out["all-reduce"] == 2 * (128 * 256 * 4) + 2 * (2 * 2 * 4)
    assert out["all-gather"] == 16 * 1024 * 2
    assert out["reduce-scatter"] == 4 * 8 * 4
    assert out["collective-permute"] == 8 * 4
    assert out["all-to-all"] == 64 * 64 * 2
    assert out["ops"] == 6  # -done not counted


def test_combine_scan_math():
    full = {"flops": 100.0, "bytes accessed": 10.0}
    block = {"flops": 30.0, "bytes accessed": 2.0}
    out = rl.combine_scan_costs(full, block, num_layers=5)
    assert out["flops"] == 100.0 + 4 * 30.0
    assert rl.combine_scan_collectives({"total": 7.0}, {"total": 3.0}, 5) == 7.0 + 12.0
    assert rl.combine_scan_costs(full, None, 5) == full


def test_roofline_terms_and_bottleneck():
    rep = rl.analyze(
        {"flops": 197e12, "bytes accessed": 819e9 * 2},
        coll_total=50e9 * 3,
        n_chips=256,
        model_flops=197e12 * 256 * 0.5,
    )
    np.testing.assert_allclose(rep.compute_s, 1.0)
    np.testing.assert_allclose(rep.memory_s, 2.0)
    np.testing.assert_allclose(rep.collective_s, 3.0)
    assert rep.bottleneck == "collective"
    np.testing.assert_allclose(rep.useful_ratio, 0.5)


def test_active_params_moe():
    from repro.configs import get_config

    cfg = get_config("deepseek_v2_236b")
    total = cfg.param_count()
    active = rl.active_param_count(cfg)
    assert active < total
    # deepseek-v2: ~236B total, ~21B active (order-of-magnitude check)
    assert 100e9 < total < 400e9
    assert 10e9 < active < 40e9


def test_model_flops_modes():
    from repro.configs import get_config

    cfg = get_config("yi_6b")
    t = rl.analytic_model_flops(cfg, 256, 4096, "train")
    p = rl.analytic_model_flops(cfg, 32, 32768, "prefill")
    d = rl.analytic_model_flops(cfg, 128, 32768, "decode")
    assert t == 6.0 * cfg.param_count() * 256 * 4096
    assert p == 2.0 * cfg.param_count() * 32 * 32768
    assert d == 2.0 * cfg.param_count() * 128
