"""Serving driver, Theorem-4 exact bound, microbatch equivalence, and the
remaining per-family decode/prefill consistency cases."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import random_batch_like
from repro.models.model import Model, batch_spec


# ---------------------------------------------------------------------------
# launch/serve.py
# ---------------------------------------------------------------------------


def _prefill_batch(cfg, B, S, key):
    batch = random_batch_like(batch_spec(cfg, B, S, "prefill"), key)
    batch["tokens"] = batch["tokens"] % cfg.vocab_size
    return batch


@pytest.mark.parametrize("arch", ["yi_6b", "mamba2_1_3b", "musicgen_large"])
def test_generate_shapes(arch):
    from repro.launch.serve import generate

    cfg = get_smoke_config(arch)
    model = Model(cfg)
    key = jax.random.key(0)
    params = model.init(key)
    batch = _prefill_batch(cfg, 2, 16, key)
    gen, stats = generate(model, params, batch, max_new_tokens=6)
    if cfg.num_codebooks:
        assert gen.shape == (2, 6, cfg.num_codebooks)
    else:
        assert gen.shape == (2, 6)
    assert (np.asarray(gen) >= 0).all() and (np.asarray(gen) < cfg.vocab_size).all()
    assert stats["tokens_per_s"] > 0


def test_generate_greedy_matches_forward():
    """Greedy generation's first token == argmax of the full forward."""
    from repro.launch.serve import generate

    cfg = get_smoke_config("granite_8b")
    model = Model(cfg)
    key = jax.random.key(1)
    params = model.init(key)
    batch = _prefill_batch(cfg, 2, 12, key)
    gen, _ = generate(model, params, batch, max_new_tokens=3)
    full = model.forward_logits(params, {"tokens": batch["tokens"]})
    want0 = np.argmax(np.asarray(full[:, -1], np.float32), axis=-1)
    np.testing.assert_array_equal(np.asarray(gen[:, 0]), want0)


def test_generate_eos_freezes_stream():
    from repro.launch.serve import generate

    cfg = get_smoke_config("yi_6b")
    model = Model(cfg)
    key = jax.random.key(2)
    params = model.init(key)
    batch = _prefill_batch(cfg, 2, 8, key)
    gen, _ = generate(model, params, batch, max_new_tokens=8, eos_id=0)
    g = np.asarray(gen)
    for b in range(2):
        hits = np.nonzero(g[b] == 0)[0]
        if hits.size:
            assert (g[b, hits[0]:] == 0).all()  # frozen after EOS


def test_generate_eos_early_exit_bitwise_and_step_count():
    """ISSUE 6 satellite 3: once every stream is finished the decode
    loop actually exits (periodic host check), the eos-padded tail is
    bitwise what the full loop would have emitted, and decode_steps /
    tokens_per_s count only the steps actually executed."""
    from repro.launch.serve import generate

    cfg = get_smoke_config("yi_6b")
    model = Model(cfg)
    key = jax.random.key(4)
    params = model.init(key)
    batch = _prefill_batch(cfg, 1, 8, key)
    T = 10
    ref, _ = generate(model, params, batch, max_new_tokens=T)
    eos = int(np.asarray(ref)[0, 1])  # the greedy stream emits this early

    full, fstats = generate(model, params, batch, max_new_tokens=T,
                            eos_id=eos, eos_check_every=0)  # exit disabled
    early, estats = generate(model, params, batch, max_new_tokens=T,
                             eos_id=eos, eos_check_every=1)
    assert fstats["decode_steps"] == T - 1  # full loop ran to the end
    assert 1 <= estats["decode_steps"] < T - 1  # early exit fired
    assert early.shape == (1, T)
    np.testing.assert_array_equal(np.asarray(early), np.asarray(full))
    assert estats["tokens_per_s"] > 0


# ---------------------------------------------------------------------------
# remaining decode/prefill consistency families (audio, vlm, absorbed MLA)
# ---------------------------------------------------------------------------


def test_musicgen_decode_matches_forward():
    cfg = get_smoke_config("musicgen_large")
    model = Model(cfg)
    key = jax.random.key(3)
    params = model.init(key)
    B, T = 2, 16
    toks = jax.random.randint(key, (B, T, cfg.num_codebooks), 0, cfg.vocab_size)
    full = model.forward_logits(params, {"tokens": toks})
    cache = model.init_cache(B, T + 2)
    dec = jax.jit(model.decode_step)
    outs = []
    for i in range(T):
        lg, cache = dec(params, cache, {"tokens": toks[:, i : i + 1]})
        outs.append(np.asarray(lg[:, 0], np.float32))
    np.testing.assert_allclose(
        np.stack(outs, 1), np.asarray(full, np.float32), rtol=2e-3, atol=2e-3
    )


@pytest.mark.slow
def test_vlm_prefill_then_decode_consistent():
    """Vision prefix + text prefill, then decode one more text token ==
    full forward over the extended text."""
    cfg = get_smoke_config("qwen2_vl_2b")
    model = Model(cfg)
    key = jax.random.key(4)
    params = model.init(key)
    B, S_text = 2, 12
    toks = jax.random.randint(key, (B, S_text + 1), 0, cfg.vocab_size)
    vis = jax.random.normal(jax.random.fold_in(key, 1), (B, cfg.vision_tokens, 1024))
    full = model.forward_logits(
        params, {"tokens": toks, "vision_embeds": vis}
    )  # (B, S_text+1, V)
    from repro.launch.serve import expand_cache

    last, cache = jax.jit(model.prefill)(
        params, {"tokens": toks[:, :S_text], "vision_embeds": vis}
    )
    cache = expand_cache(model, cache, cfg.vision_tokens + S_text + 4)
    np.testing.assert_allclose(
        np.asarray(last[:, 0], np.float32),
        np.asarray(full[:, S_text - 1], np.float32),
        rtol=2e-3, atol=2e-3,
    )
    lg, _ = jax.jit(model.decode_step)(
        params, cache, {"tokens": toks[:, S_text : S_text + 1]}
    )
    np.testing.assert_allclose(
        np.asarray(lg[:, 0], np.float32),
        np.asarray(full[:, S_text], np.float32),
        rtol=2e-3, atol=2e-3,
    )


def test_absorbed_mla_generate_matches_naive():
    from repro.launch.serve import generate

    cfg = get_smoke_config("deepseek_v2_236b")
    key = jax.random.key(5)
    params = Model(cfg).init(key)
    batch = _prefill_batch(cfg, 2, 10, key)
    g1, _ = generate(Model(cfg), params, batch, max_new_tokens=5)
    g2, _ = generate(
        Model(dataclasses.replace(cfg, mla_absorb=True)), params, batch, 5
    )
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))


# ---------------------------------------------------------------------------
# microbatch gradient-accumulation equivalence
# ---------------------------------------------------------------------------


def test_microbatch_equivalence():
    from repro.launch.train import make_train_step
    from repro.optim import sgd

    cfg = get_smoke_config("granite_8b")
    model = Model(cfg)
    key = jax.random.key(6)
    params = model.init(key)
    opt = sgd(0.1)
    batch = random_batch_like(batch_spec(cfg, 4, 32, "train"), key)
    batch["tokens"] = batch["tokens"] % cfg.vocab_size
    batch["labels"] = batch["labels"] % cfg.vocab_size
    p1, _, m1 = jax.jit(make_train_step(model, opt))(params, opt.init(params), batch)
    p2, _, m2 = jax.jit(make_train_step(model, opt, microbatches=2))(
        params, opt.init(params), batch
    )
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=2e-4, atol=2e-5,
        )


# ---------------------------------------------------------------------------
# Theorem 4 exact bound
# ---------------------------------------------------------------------------


def test_theorem4_exact_bound():
    from repro.core.theory import Rates, overshoot_exact_bound, overshoot_recursion

    rates = Rates(lambda_r=0.02, lambda_a=0.01)
    args = dict(z_after_failure=5, d_failed=5, t_d=0.0, eps=2.0, p=0.1, rates=rates)
    e4 = overshoot_exact_bound(horizon=6, **args)
    assert 5.0 <= e4 < 50.0  # finite, sane (kappa pinning is conservative)
    # monotone in horizon
    assert overshoot_exact_bound(horizon=8, **args) >= e4 - 1e-9
    # the paper: thresholds "can be optimized to minimize the bound"
    e4_opt = min(
        overshoot_exact_bound(horizon=6, kappa_factor=f, **args)
        for f in (1.1, 1.25, 1.5, 2.0)
    )
    assert e4_opt <= e4 + 1e-9
    assert e4_opt < 15.0  # optimized thresholds give a tight bound
    # upper-bounds the smooth Cor.-3 estimate at the same horizon
    smooth = overshoot_recursion(steps=6, use_ceiling=False, **args)
    assert e4_opt >= smooth[-1] - 1e-6
    with pytest.raises(ValueError):
        overshoot_exact_bound(horizon=20, **args)
    with pytest.raises(ValueError):
        overshoot_exact_bound(horizon=4, kappa_factor=3.0, **args)


def test_analytic_survival_mode_runs():
    """Footnote-5 option: protocol with the analytic geometric survival."""
    from repro.api import Experiment
    from repro.core import FailureConfig, ProtocolConfig, survived
    from repro.graphs import random_regular_graph

    g = random_regular_graph(48, 6, seed=4)
    pcfg = ProtocolConfig(
        algorithm="decafork", z0=6, max_walks=24, eps=1.2,
        protocol_start=300, rt_bins=256, analytic_survival=True,
    )
    fcfg = FailureConfig(burst_times=(600,), burst_sizes=(3,))
    _, outs = Experiment(graph=g, protocol=pcfg, failures=fcfg, steps=1500).run(key=0)
    z = np.asarray(outs.z)
    assert survived(z)
    assert z[600] == z[599] - 3
    assert z[-300:].mean() > 4.0
