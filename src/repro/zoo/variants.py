"""Walk-variant strategies — the zoo's *defense* axis.

Each variant is a movement rule layered over the slot machinery in
``core/walkers.py``; the simulator dispatches here whenever
``ProtocolConfig.walk_variant != "uniform"`` (a static field, so each
variant is its own compiled program and the default program is
bitwise-untouched). Variants and the literature motivating them:

  * ``uniform`` — the paper's walk: a uniform available neighbor
    (literally ``walkers.move_walks``; listed so the registry is total);
  * ``jump``    — random walks with jumps (Liu et al.): after the normal
    hop, teleport w.p. ``p_jump`` to a uniformly random *up* node —
    escapes slow mixing and, crucially, scheduled partition cuts;
  * ``biased``  — node2vec-style second-order p/q walk: relative to the
    previous node (``WalkState.prev``), returning weighs ``1/bias_p``,
    staying at distance 1 weighs ``1``, exploring outward weighs
    ``1/bias_q`` — ``bias_q < 1`` pushes exploration;
  * ``bloom``   — self-avoiding walk with a fixed-size Bloom-filter
    history per walk (``WalkState.bloom``, ``bloom_bits`` wide, forked
    with the slot): the walk marks every node it leaves and prefers
    unvisited available neighbors, falling back to uniform when all are
    marked — jit-compatible walk memory after h-ohsaki's SRW variants.

All rules are branch-free on traced values (``p_jump``/``bias_p``/
``bias_q`` are ordinary vmap-batchable leaves), hold position when no
eligible edge exists (exactly like ``move_walks``), and keep inactive
slots frozen.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import walkers as wlk

__all__ = [
    "DEFENSES",
    "defense",
    "init_variant_state",
    "move_variant",
]

# named defense presets: ProtocolConfig field overrides. ``defense()``
# merges caller overrides on top, so a preset is a starting point, not a
# straitjacket.
DEFENSES: dict = {
    "uniform": {},
    "jump": {"walk_variant": "jump", "p_jump": 0.05},
    "biased": {"walk_variant": "biased", "bias_p": 4.0, "bias_q": 0.5},
    "bloom": {"walk_variant": "bloom", "bloom_bits": 64},
}


def defense(name: str, **overrides) -> dict:
    """The named defense's ``ProtocolConfig`` overrides (+ caller's)."""
    try:
        base = DEFENSES[name]
    except KeyError:
        raise KeyError(
            f"unknown defense {name!r}; known: {sorted(DEFENSES)}"
        ) from None
    return {**base, **overrides}


def init_variant_state(ws: wlk.WalkState, pcfg) -> wlk.WalkState:
    """Attach the variant's per-walk memory columns to a fresh WalkState.

    ``biased`` seeds ``prev`` with the walk's own starting node — every
    neighbor is then at distance 1 from "prev", so the first hop is
    uniform, the standard second-order-walk initialization. ``bloom``
    starts with an empty filter.
    """
    W = ws.pos.shape[0]
    if pcfg.walk_variant == "biased":
        return ws._replace(prev=ws.pos)
    if pcfg.walk_variant == "bloom":
        return ws._replace(bloom=jnp.zeros((W, pcfg.bloom_bits), bool))
    return ws


def move_variant(
    ws: wlk.WalkState,
    pcfg,
    neighbors: jax.Array,
    degrees: jax.Array,
    key: jax.Array,
    avail: jax.Array,
    node_up: jax.Array,
) -> wlk.WalkState:
    """One movement round under ``pcfg.walk_variant`` (see module doc).

    Same contract as ``walkers.move_walks``: consumes the round's
    movement key (splitting it internally — each variant is a distinct
    static program, so stream layout only matters within a variant) and
    the live availability mask; returns the moved WalkState.
    """
    variant = pcfg.walk_variant
    if variant == "uniform":
        return wlk.move_walks(ws, neighbors, degrees, key, avail)
    if variant == "jump":
        return _move_jump(ws, pcfg, neighbors, degrees, key, avail, node_up)
    if variant == "biased":
        return _move_biased(ws, pcfg, neighbors, degrees, key, avail)
    if variant == "bloom":
        return _move_bloom(ws, pcfg, neighbors, degrees, key, avail)
    raise ValueError(f"unknown walk_variant {variant!r}")


def _move_jump(ws, pcfg, neighbors, degrees, key, avail, node_up):
    """Normal hop, then w.p. ``p_jump`` teleport to a uniform up-node.

    The teleport target is rank-selected over the live ``node_up`` mask
    (same primitive shape as edge selection): with every node up it is
    exactly ``floor(u * n)``; with nodes down only up nodes are
    reachable, so a jump can never land a walk on a crashed node. With
    zero up-nodes (fully crashed graph) the walk keeps its hop result.
    """
    W = ws.pos.shape[0]
    n = node_up.shape[0]
    k_hop, k_gate, k_dest = jax.random.split(key, 3)
    ws = wlk.move_walks(ws, neighbors, degrees, k_hop, avail)
    do_jump = jax.random.uniform(k_gate, (W,)) < pcfg.p_jump
    u = jax.random.uniform(k_dest, (W,))
    n_up = jnp.sum(node_up, dtype=jnp.int32)
    idx = jnp.minimum((u * n_up).astype(jnp.int32), n_up - 1)
    rank = jnp.cumsum(node_up, dtype=jnp.int32) - 1  # rank among up nodes
    ids = jnp.arange(n, dtype=jnp.int32)
    rank_to_node = (
        jnp.zeros((n,), jnp.int32)
        .at[jnp.where(node_up, rank, n)]
        .set(ids, mode="drop")
    )
    dest = rank_to_node[jnp.clip(idx, 0, n - 1)]
    teleport = ws.active & do_jump & (n_up > 0)
    return ws._replace(pos=jnp.where(teleport, dest, ws.pos))


def _move_biased(ws, pcfg, neighbors, degrees, key, avail):
    """node2vec-style p/q walk: weight each available incident edge by
    the destination's relation to the previous node, then sample the
    categorical with one uniform against the row's weight CDF."""
    W = ws.pos.shape[0]
    D = neighbors.shape[1]
    rows = neighbors[ws.pos]  # (W, D) candidate destinations
    row_mask = avail[ws.pos]
    prev = ws.prev
    prev_rows = neighbors[prev]  # (W, D) the previous node's neighbors
    prev_deg = (
        jnp.arange(D, dtype=degrees.dtype)[None, :] < degrees[prev, None]
    )
    is_prev = rows == prev[:, None]
    dist1 = (
        (rows[:, :, None] == prev_rows[:, None, :]) & prev_deg[:, None, :]
    ).any(axis=-1)
    w = jnp.where(
        is_prev,
        1.0 / pcfg.bias_p,
        jnp.where(dist1, 1.0, 1.0 / pcfg.bias_q),
    )
    w = jnp.where(row_mask, w, 0.0)
    tot = jnp.sum(w, axis=1)
    u = jax.random.uniform(key, (W,)) * tot
    cdf = jnp.cumsum(w, axis=1)
    # first slot whose cdf exceeds u — a zero-weight slot shares its
    # predecessor's cdf, so it can never be first
    sel = jnp.argmax(cdf > u[:, None], axis=1)
    nxt = jnp.take_along_axis(rows, sel[:, None], axis=1)[:, 0]
    can_move = ws.active & (tot > 0)
    return ws._replace(
        pos=jnp.where(can_move, nxt, ws.pos),
        prev=jnp.where(can_move, ws.pos, prev),
    )


def _bloom_hashes(node: jax.Array, bits: int):
    """Two independent multiplicative hashes into [0, bits)."""
    x = node.astype(jnp.uint32)
    h1 = (x * jnp.uint32(2654435761)) % jnp.uint32(bits)
    h2 = (x * jnp.uint32(40503) + jnp.uint32(2699)) % jnp.uint32(bits)
    return h1.astype(jnp.int32), h2.astype(jnp.int32)


def _move_bloom(ws, pcfg, neighbors, degrees, key, avail):
    """Self-avoiding hop: mark the node being left in the walk's Bloom
    filter, then hop uniformly among available neighbors NOT in the
    filter — falling back to plain uniform-available when every
    candidate is marked (or a false positive says so). The filter is
    per-walk state, duplicated on fork with the slot."""
    W = ws.pos.shape[0]
    B = ws.bloom.shape[1]
    slots = jnp.arange(W, dtype=jnp.int32)
    h1, h2 = _bloom_hashes(ws.pos, B)
    mark = ws.active
    bloom = ws.bloom
    bloom = bloom.at[slots, h1].set(bloom[slots, h1] | mark)
    bloom = bloom.at[slots, h2].set(bloom[slots, h2] | mark)
    rows = neighbors[ws.pos]  # (W, D)
    g1, g2 = _bloom_hashes(rows, B)
    visited = jnp.take_along_axis(bloom, g1, axis=1) & jnp.take_along_axis(
        bloom, g2, axis=1
    )
    row_mask = avail[ws.pos]
    fresh = row_mask & ~visited
    mask = jnp.where(fresh.any(axis=1)[:, None], fresh, row_mask)
    u = jax.random.uniform(key, (W,))
    adeg, sel = wlk.select_available_edge(mask, u, degrees.dtype)
    nxt = jnp.take_along_axis(rows, sel[:, None], axis=1)[:, 0]
    can_move = ws.active & (adeg > 0)
    return ws._replace(
        pos=jnp.where(can_move, nxt, ws.pos), bloom=bloom
    )
