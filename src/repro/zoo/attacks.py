"""Attack registry — the zoo's *adversary* axis.

Every attack is a named builder returning an ordinary
:class:`~repro.core.failures.FailureConfig`, so attacks compose with the
sweep engine exactly like the paper's failure regimes: numeric knobs are
traced leaves (vmap-batchable), shape-bearing schedules pad via
``pad_bursts``, and the one program-structure field (``pacman_mobile``)
keys the compile group. Attacks and the literature motivating them:

  * ``pacman``        — the classic single static absorbing node
    (arXiv:2508.05663);
  * ``multi_pacman``  — several simultaneous absorbing nodes (Chen et
    al.'s multi-adversary regime): ids beyond the first ride the
    shape-bearing ``pacman_nodes`` array;
  * ``mobile_pacman`` — the absorbing node hops to a random available
    neighbor w.p. ``hop_prob`` each round (positions are traced scan
    state, see ``failures.step_mobile_pacman``);
  * ``edge_cut``      — a scheduled partition: at ``time`` every edge
    crossing the node-id ``threshold`` goes down at once, splitting the
    graph (the correlated-failure regime the jump defense targets);
  * ``burst`` / ``probabilistic`` / ``byzantine`` — the paper's walk-level
    threat models, wrapped so the cross-product helper can name them.
"""
from __future__ import annotations

from typing import Callable, Dict

from repro.core.failures import FailureConfig

__all__ = ["ATTACKS", "attack", "register_attack"]

ATTACKS: Dict[str, Callable[..., FailureConfig]] = {}


def register_attack(name: str, builder: Callable | None = None):
    """Register an attack builder under ``name``; usable as a decorator.
    Last registration wins (notebook-iteration friendly)."""

    def _register(fn: Callable):
        if not callable(fn):
            raise TypeError(f"attack builder for {name!r} must be callable")
        ATTACKS[str(name)] = fn
        return fn

    return _register(builder) if builder is not None else _register


def attack(name: str, **kwargs) -> FailureConfig:
    """Build the named attack's :class:`FailureConfig`."""
    try:
        builder = ATTACKS[name]
    except KeyError:
        raise KeyError(
            f"unknown attack {name!r}; known: {sorted(ATTACKS)}"
        ) from None
    return builder(**kwargs)


@register_attack("none")
def _none(**kw) -> FailureConfig:
    """The calm regime (any FailureConfig fields pass through)."""
    return FailureConfig(**kw)


@register_attack("pacman")
def _pacman(node: int = 0, start: int = 0, **kw) -> FailureConfig:
    return FailureConfig(
        pacman_node=node, pacman_start_time=start, **kw
    )


@register_attack("multi_pacman")
def _multi_pacman(nodes=(0, 1), start: int = 0, **kw) -> FailureConfig:
    """Several static absorbing nodes at once (``nodes``: their ids)."""
    nodes = tuple(int(x) for x in nodes)
    if not nodes:
        raise ValueError("multi_pacman needs at least one node id")
    return FailureConfig(
        pacman_node=nodes[0],
        pacman_nodes=nodes[1:],
        pacman_start_time=start,
        **kw,
    )


@register_attack("mobile_pacman")
def _mobile_pacman(
    node: int = 0, hop_prob: float = 1.0, start: int = 0, nodes=(), **kw
) -> FailureConfig:
    """An absorbing node that hops each round (``nodes``: extra mobile
    Pac-Men beyond the first)."""
    return FailureConfig(
        pacman_node=node,
        pacman_nodes=tuple(int(x) for x in nodes),
        pacman_mobile=True,
        pacman_hop_prob=hop_prob,
        pacman_start_time=start,
        **kw,
    )


@register_attack("edge_cut")
def _edge_cut(time: int = 0, threshold: int = 1, **kw) -> FailureConfig:
    """One scheduled partition cut at ``time`` along id ``threshold``."""
    return FailureConfig(
        edge_cut_times=(int(time),),
        edge_cut_thresholds=(int(threshold),),
        **kw,
    )


@register_attack("burst")
def _burst(times=(), sizes=(), **kw) -> FailureConfig:
    return FailureConfig(
        burst_times=tuple(times), burst_sizes=tuple(sizes), **kw
    )


@register_attack("probabilistic")
def _probabilistic(p: float = 0.01, start: int = 0, **kw) -> FailureConfig:
    return FailureConfig(p_fail=p, p_fail_start=start, **kw)


@register_attack("byzantine")
def _byzantine(
    node: int = 0, p: float = 0.05, start: int = 0, **kw
) -> FailureConfig:
    return FailureConfig(
        byzantine_node=node, p_byz=p, byz_start_time=start, **kw
    )
