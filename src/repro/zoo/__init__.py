"""repro.zoo — the adversary & walk-variant zoo (ROADMAP item 4).

Attacks (``repro.zoo.attacks``) and walk-variant defenses
(``repro.zoo.variants``) are registry-named builders over the ordinary
config pytrees, so the whole defense x attack cross-product is just a
list of :class:`~repro.sweep.scenario.Scenario` rows — the sweep engine
runs it with ONE compiled program per static group (walk variant,
``pacman_mobile``, schedule widths), and every numeric knob batches under
vmap inside its group.

    from repro.zoo import zoo_scenarios
    rows = zoo_scenarios(
        defenses=["uniform", "jump", "bloom"],
        attacks=[("mobile_pacman", {"node": 0}),
                 ("edge_cut", {"time": 50, "threshold": 32})],
    )
    Experiment(graph=g, scenarios=rows, steps=500).plan().sweep(seeds=8)

The registered ``"zoo"`` experiment builder packages the common study —
a community graph under the default 3-defense x 3-attack grid — for
config-driven callers (``Experiment.from_config({"experiment": "zoo"})``,
the service, ``benchmarks/fig9_zoo.py``).
"""
from __future__ import annotations

import dataclasses

from repro.zoo.attacks import ATTACKS, attack, register_attack
from repro.zoo.variants import (
    DEFENSES,
    defense,
    init_variant_state,
    move_variant,
)

__all__ = [
    "ATTACKS",
    "DEFENSES",
    "attack",
    "defense",
    "init_variant_state",
    "move_variant",
    "zoo_scenarios",
    "register_attack",
]


def _named(entry, kind):
    """Normalize ``"name"`` | ``("name", {kwargs})`` entries."""
    if isinstance(entry, str):
        return entry, {}
    name, kwargs = entry
    if not isinstance(kwargs, dict):
        raise TypeError(
            f"{kind} entry {entry!r} must be 'name' or ('name', dict)"
        )
    return name, dict(kwargs)


def zoo_scenarios(defenses, attacks, base_protocol=None):
    """The defense x attack cross-product as named Scenario rows.

    ``defenses``/``attacks`` entries are names or ``(name, kwargs)``
    pairs — defense kwargs override the preset's ProtocolConfig fields,
    attack kwargs go to the attack builder. Rows are named
    ``"<defense>|<attack>"`` and ordered defense-major. The returned
    list drops straight into ``Experiment(scenarios=...)``; grouping,
    schedule padding and compile caching are the sweep engine's job.
    """
    from repro.core.protocol import ProtocolConfig
    from repro.sweep.scenario import Scenario

    base = base_protocol if base_protocol is not None else ProtocolConfig()
    rows = []
    for d_entry in defenses:
        d_name, d_kw = _named(d_entry, "defense")
        pcfg = dataclasses.replace(base, **defense(d_name, **d_kw))
        for a_entry in attacks:
            a_name, a_kw = _named(a_entry, "attack")
            rows.append(
                Scenario(
                    name=f"{d_name}|{a_name}",
                    pcfg=pcfg,
                    fcfg=attack(a_name, **a_kw),
                )
            )
    return rows


def _register_experiment():
    from repro.api import registry

    @registry.register("zoo")
    def _zoo(
        *,
        graph: str = "community",
        n: int = 64,
        graph_seed: int = 0,
        graph_kwargs: dict | None = None,
        steps: int = 500,
        protocol: dict | None = None,
        defenses=("uniform", "jump", "bloom"),
        attacks=("mobile_pacman", "multi_pacman", "edge_cut"),
        outputs="scalars",
        placement="auto",
        name: str | None = None,
    ):
        """The zoo study: a (default: community) graph under the defense
        x attack grid. Plain attack names get graph-aware defaults —
        ``edge_cut`` severs the id boundary ``n//2`` at ``steps//3``,
        ``multi_pacman`` posts one Pac-Man per community."""
        from repro.api.experiment import Experiment
        from repro.core.protocol import ProtocolConfig
        from repro.graphs.generators import make_graph

        g = make_graph(graph, int(n), int(graph_seed), **(graph_kwargs or {}))
        half = int(n) // 2
        auto_kw = {
            "edge_cut": {"time": int(steps) // 3, "threshold": half},
            "multi_pacman": {"nodes": (0, half)},
            "mobile_pacman": {"node": 0},
            "pacman": {"node": 0},
        }
        rows = [
            (a, auto_kw.get(a, {})) if isinstance(a, str) else a
            for a in attacks
        ]
        return Experiment(
            graph=g,
            scenarios=zoo_scenarios(
                defenses, rows,
                base_protocol=ProtocolConfig(**(protocol or {})),
            ),
            steps=int(steps),
            outputs=outputs,
            placement=placement,
            name=name,
        )


_register_experiment()
