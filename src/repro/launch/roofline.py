"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds (TPU v5e constants):

  compute    = HLO_FLOPs / (chips * 197e12)           [bf16 MXU peak]
  memory     = HLO_bytes / (chips * 819e9)            [HBM bandwidth]
  collective = collective_bytes / (chips * 50e9)      [per-link ICI]

``cost_analysis()`` supplies per-device FLOPs / bytes-accessed, but XLA
counts a while-loop body ONCE, so for layer-scanned models the dry-run
also lowers a single-block step and this module combines
    total = full_graph + (L - 1) * block .
Collective bytes are not in cost_analysis at all: we parse the
post-SPMD HLO text and sum result sizes of every collective op
(all-reduce counted twice — ring reduce-scatter + all-gather).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

# ---- TPU v5e hardware constants (per chip) --------------------------------
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "fp8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(tok: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(tok):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device ICI bytes by collective kind, parsed from compiled HLO.

    Counts the *result* size of each collective op (start/done pairs are
    deduplicated by only counting `-start` when both forms appear);
    all-reduce is weighted 2x for the ring reduce-scatter + all-gather.
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    op_re = re.compile(
        r"^((?:\([^)]*\))|(?:\w+\[[^\]]*\](?:\{[^}]*\})?))\s+%?([\w-]+)\("
    )
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        rhs = s.split("=", 1)[1].lstrip()
        m = op_re.match(rhs)
        if not m:
            continue
        opname = m.group(2)
        base = opname[:-6] if opname.endswith("-start") else opname
        if base not in _COLLECTIVES:
            continue
        if opname.endswith("-done"):
            continue
        nbytes = _shape_bytes(m.group(1))
        w = 2.0 if base == "all-reduce" else 1.0
        out[base] += w * nbytes
        counts[base] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["ops"] = float(sum(counts.values()))
    return out


@dataclasses.dataclass
class RooflineReport:
    flops: float  # per-device
    hbm_bytes: float  # per-device
    coll_bytes: float  # per-device
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float  # analytic 6*N*D (global)
    useful_ratio: float  # model_flops / (flops * chips)

    def to_dict(self):
        return dataclasses.asdict(self)


def combine_scan_costs(full: dict, block: dict | None, num_layers: int) -> dict:
    """total = full + (L-1) * block (cost_analysis counts scan bodies once)."""
    if block is None:
        return dict(full)
    out = {}
    for k in ("flops", "bytes accessed"):
        out[k] = full.get(k, 0.0) + (num_layers - 1) * block.get(k, 0.0)
    return out


def combine_scan_collectives(full_coll: dict, block_coll: dict | None, num_layers: int) -> float:
    total = full_coll.get("total", 0.0)
    if block_coll is not None:
        total += (num_layers - 1) * block_coll.get("total", 0.0)
    return total


def analyze(
    costs: dict,
    coll_total: float,
    n_chips: int,
    model_flops: float,
) -> RooflineReport:
    flops = float(costs.get("flops", 0.0))
    hbm = float(costs.get("bytes accessed", 0.0))
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    coll_s = coll_total / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    total_flops = flops * n_chips
    return RooflineReport(
        flops=flops,
        hbm_bytes=hbm,
        coll_bytes=coll_total,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=coll_s,
        bottleneck=bottleneck,
        model_flops=model_flops,
        useful_ratio=(model_flops / total_flops) if total_flops else 0.0,
    )


def analytic_model_flops(cfg, batch: int, seq: int, mode: str) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (forward), N = active params."""
    n_active = active_param_count(cfg)
    tokens = batch * seq if mode in ("train", "prefill") else batch * 1
    mult = 6.0 if mode == "train" else 2.0
    return mult * n_active * tokens


def active_param_count(cfg) -> int:
    """Active (per-token) parameter count: MoE counts top-k + shared only."""
    n = cfg.param_count()
    if cfg.arch_type != "moe":
        return n
    d, e, fe, L = cfg.d_model, cfg.moe_num_experts, cfg.moe_d_ff, cfg.num_layers
    all_routed = L * e * 3 * d * fe
    active_routed = L * cfg.moe_top_k * 3 * d * fe
    return n - all_routed + active_routed
