"""Production mesh definitions (TPU v5e target).

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run must set XLA_FLAGS before the first jax init.

Mesh construction goes through ``repro.utils.compat.make_mesh`` so the
``axis_types`` kwarg (jax >= 0.5) degrades gracefully on the installed
jax 0.4.x (see the compat module for the version policy).
"""
from __future__ import annotations

import jax

from repro.utils.compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips for multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_local_mesh(model_axis: int = 1):
    """Degenerate mesh over the locally available devices (CPU tests)."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return make_mesh(
        (n // model_axis, model_axis),
        ("data", "model"),
        axis_types=(AxisType.Auto, AxisType.Auto),
    )


def data_axes(mesh) -> tuple:
    """Axes that shard the batch: ('pod','data') on multi-pod meshes."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_size(mesh) -> int:
    return mesh.shape["model"] if "model" in mesh.axis_names else 1


def data_axis_size(mesh) -> int:
    out = 1
    for a in data_axes(mesh):
        out *= mesh.shape[a]
    return out
