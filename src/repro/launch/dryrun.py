import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

For each combination this produces, with ZERO device allocation
(ShapeDtypeStruct AOT lowering):

  - proof that the sharding config is coherent (compile succeeds on the
    16x16 single-pod mesh AND the 2x16x16 multi-pod mesh);
  - ``memory_analysis()``  -> per-device bytes (does it fit HBM?);
  - ``cost_analysis()``    -> per-device FLOPs / bytes for the roofline;
  - compiled HLO text      -> collective bytes (parsed, see roofline.py);
  - a single-block lowering -> corrects XLA's count-scan-body-once
    accounting (total = full + (L-1) * block).

Results are written as JSON under experiments/dryrun/ and aggregated into
EXPERIMENTS.md by benchmarks/report_roofline.py.

Usage:
  python -m repro.launch.dryrun --arch llama3-405b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--force]
  python -m repro.launch.dryrun --protocol           # paper-technique step
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, canonical
from repro.configs.shapes import SHAPES, adjust_config
from repro.launch import sharding as shd
from repro.launch.mesh import make_production_mesh, data_axes
from repro.launch.roofline import (
    analytic_model_flops,
    analyze,
    collective_bytes,
    combine_scan_collectives,
    combine_scan_costs,
)
from repro.launch.train import make_train_step
from repro.models.model import Model, batch_spec
from repro.models.transformer import block_apply_decode, block_apply_full, make_pos_info
from repro.optim import adamw

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def _mem_dict(compiled):
    m = compiled.memory_analysis()
    return {
        "argument_bytes": int(m.argument_size_in_bytes),
        "output_bytes": int(m.output_size_in_bytes),
        "temp_bytes": int(m.temp_size_in_bytes),
        "alias_bytes": int(m.alias_size_in_bytes),
        "total_bytes": int(
            m.argument_size_in_bytes + m.output_size_in_bytes + m.temp_size_in_bytes
            - m.alias_size_in_bytes
        ),
    }


def _lower_and_compile(jitted, args, mesh):
    with mesh:
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    return lowered, compiled


# ---------------------------------------------------------------------------
# Full-step lowering
# ---------------------------------------------------------------------------


def build_full(
    arch: str,
    shape_name: str,
    mesh,
    microbatches: int = 1,
    fsdp: bool = False,
    overrides: dict | None = None,
):
    """Returns (jitted_fn, arg_structs, cfg, model)."""
    shape = SHAPES[shape_name]
    cfg_overrides = {k: v for k, v in (overrides or {}).items() if not k.startswith("_")}
    cfg = adjust_config(get_config(arch, **cfg_overrides), shape)
    model = Model(cfg)
    p_shapes = model.init_shapes()
    p_sh = shd.params_shardings(p_shapes, mesh, fsdp=fsdp)
    p_args = shd.with_shardings(p_shapes, p_sh)

    if shape.mode == "train":
        moment_dtype = jnp.bfloat16 if (overrides or {}).get("_bf16_moments") else jnp.float32
        opt = adamw(1e-4, moment_dtype=moment_dtype)
        o_shapes = jax.eval_shape(opt.init, p_shapes)
        o_sh = shd.opt_shardings(o_shapes, mesh, p_sh, fsdp=fsdp)
        b_spec = batch_spec(cfg, shape.global_batch, shape.seq_len, "train")
        b_sh = shd.batch_shardings(b_spec, mesh)
        fn = make_train_step(model, opt, microbatches=microbatches)
        jitted = jax.jit(fn, out_shardings=(p_sh, o_sh, None), donate_argnums=(0, 1))
        args = (p_args, shd.with_shardings(o_shapes, o_sh), shd.with_shardings(b_spec, b_sh))
    elif shape.mode == "prefill":
        b_spec = batch_spec(cfg, shape.global_batch, shape.seq_len, "prefill")
        b_sh = shd.batch_shardings(b_spec, mesh)
        jitted = jax.jit(model.prefill)
        args = (p_args, shd.with_shardings(b_spec, b_sh))
    else:  # decode
        c_shapes = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len)
        )
        c_sh = shd.cache_shardings(c_shapes, mesh, cfg)
        b_spec = batch_spec(cfg, shape.global_batch, 1, "decode")
        b_sh = shd.batch_shardings(b_spec, mesh)
        jitted = jax.jit(model.decode_step, out_shardings=(None, c_sh), donate_argnums=(1,))
        args = (
            p_args,
            shd.with_shardings(c_shapes, c_sh),
            shd.with_shardings(b_spec, b_sh),
        )
    return jitted, args, cfg, model


# ---------------------------------------------------------------------------
# Single-block lowering (scan cost correction)
# ---------------------------------------------------------------------------


def build_block(
    arch: str, shape_name: str, mesh, fsdp: bool = False, overrides: dict | None = None
):
    shape = SHAPES[shape_name]
    cfg_overrides = {k: v for k, v in (overrides or {}).items() if not k.startswith("_")}
    cfg = adjust_config(get_config(arch, **cfg_overrides), shape)
    model = Model(cfg)
    p_shapes = model.init_shapes()
    lp_shapes = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype), p_shapes["layers"]
    )
    lp_sh = shd.params_shardings(lp_shapes, mesh, fsdp=fsdp)
    lp_args = shd.with_shardings(lp_shapes, lp_sh)
    dp = data_axes(mesh)
    dsize = 1
    for a in dp:
        dsize *= mesh.shape[a]
    B = shape.global_batch
    S = shape.seq_len if shape.mode != "decode" else 1
    from jax.sharding import NamedSharding, PartitionSpec as P

    b_ax = dp if B % dsize == 0 else None
    x_sh = NamedSharding(mesh, P(b_ax, None, None))
    dt = jnp.dtype(cfg.dtype)
    x_arg = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt, sharding=x_sh)

    if shape.mode == "train":

        def block_loss(lp, x):
            pos_info = make_pos_info(cfg, B, S)
            out, aux, _ = block_apply_full(lp, x, cfg, pos_info, False)
            return jnp.sum(out.astype(jnp.float32)) + aux

        if cfg.remat:
            block_loss_fn = jax.checkpoint(block_loss)
        else:
            block_loss_fn = block_loss
        fn = jax.grad(block_loss_fn, argnums=(0, 1))
        jitted = jax.jit(fn, out_shardings=(lp_sh, x_sh))
        args = (lp_args, x_arg)
    elif shape.mode == "prefill":

        def block_fwd(lp, x):
            pos_info = make_pos_info(cfg, B, S)
            out, _, cache = block_apply_full(lp, x, cfg, pos_info, True)
            return out, cache

        jitted = jax.jit(block_fwd, out_shardings=(x_sh, None))
        args = (lp_args, x_arg)
    else:  # decode
        c_shapes = jax.eval_shape(lambda: model.init_cache(B, shape.seq_len))
        c_sh_full = shd.cache_shardings(c_shapes, mesh, cfg)
        cl_shapes = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype), c_shapes["layers"]
        )
        cl_sh = jax.tree.map(
            lambda sh: NamedSharding(mesh, P(*sh.spec[1:])), c_sh_full["layers"]
        )
        cl_args = shd.with_shardings(cl_shapes, cl_sh)
        pos_arg = jax.ShapeDtypeStruct(
            (B,), jnp.int32, sharding=NamedSharding(mesh, P(b_ax))
        )
        extra = {}
        if cfg.arch_type != "ssm":
            cp = c_shapes["cache_positions"]
            cp_sh = shd.cache_shardings(c_shapes, mesh, cfg)["cache_positions"]
            extra["cache_positions"] = jax.ShapeDtypeStruct(
                cp.shape, cp.dtype, sharding=cp_sh
            )

        def block_dec(lp, x, cache_l, pos, cache_positions=None):
            pos_info = {"pos": pos}
            if cache_positions is not None:
                pos_info["cache_positions"] = cache_positions
            return block_apply_decode(lp, x, cfg, cache_l, pos_info)

        jitted = jax.jit(block_dec, out_shardings=(x_sh, cl_sh))
        args = (lp_args, x_arg, cl_args, pos_arg) + (
            (extra["cache_positions"],) if extra else ()
        )
    return jitted, args, cfg


# ---------------------------------------------------------------------------
# Protocol (paper technique) distributed-step lowering
# ---------------------------------------------------------------------------


def build_protocol(mesh, n_nodes: int = 131072, max_walks: int = 64, bins: int = 512):
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.distributed import make_sharded_step
    from repro.core.protocol import ProtocolConfig

    pcfg = ProtocolConfig(
        algorithm="decafork+", z0=16, max_walks=max_walks, eps=4.0, eps2=11.0,
        rt_bins=bins,
    )
    axes = data_axes(mesh)
    step = make_sharded_step(mesh, axes, n_nodes, pcfg)
    node_spec = P(axes)
    rep = NamedSharding(mesh, P())
    node_sh2 = NamedSharding(mesh, node_spec)
    i32, f32 = jnp.int32, jnp.float32
    W = max_walks
    max_deg = 16
    args = (
        jax.ShapeDtypeStruct((), i32, sharding=rep),  # t
        jax.ShapeDtypeStruct((W,), i32, sharding=rep),  # pos
        jax.ShapeDtypeStruct((W,), jnp.bool_, sharding=rep),  # active
        jax.ShapeDtypeStruct((W,), i32, sharding=rep),  # track
        jax.ShapeDtypeStruct((n_nodes, W), i32, sharding=node_sh2),  # last_seen
        jax.ShapeDtypeStruct((n_nodes, bins), f32, sharding=node_sh2),  # hist
        jax.ShapeDtypeStruct((n_nodes,), f32, sharding=node_sh2),  # total
        jax.ShapeDtypeStruct((), jnp.uint32, sharding=rep),  # key (raw)
        jax.ShapeDtypeStruct((n_nodes, max_deg), i32, sharding=node_sh2),  # neighbors
        jax.ShapeDtypeStruct((n_nodes,), i32, sharding=node_sh2),  # degrees
        jax.ShapeDtypeStruct((n_nodes,), jnp.bool_, sharding=rep),  # node_up
        jax.ShapeDtypeStruct((n_nodes, max_deg), jnp.bool_, sharding=node_sh2),  # edge_up
    )
    # the key must be a typed PRNG key struct
    key_struct = jax.eval_shape(lambda: jax.random.key(0))
    args = args[:7] + (
        jax.ShapeDtypeStruct(key_struct.shape, key_struct.dtype, sharding=rep),
    ) + args[8:]
    return jax.jit(step), args, pcfg


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------


def run_one(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    out_dir: str,
    force: bool = False,
    with_block: bool = True,
    microbatches: int = 1,
    tag: str = "",
    fsdp: bool = False,
    overrides: dict | None = None,
):
    mesh_name = "pod512" if multi_pod else "pod256"
    slug = f"{canonical(arch)}__{shape_name}__{mesh_name}{tag}"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, slug + ".json")
    if os.path.exists(path) and not force:
        print(f"[skip] {slug} (exists)")
        with open(path) as f:
            return json.load(f)
    t0 = time.time()
    rec = {
        "arch": canonical(arch),
        "shape": shape_name,
        "mesh": mesh_name,
        "microbatches": microbatches,
        "fsdp": fsdp,
        "overrides": overrides or {},
        "ok": False,
    }
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_chips = mesh.size
        jitted, args, cfg, model = build_full(
            arch, shape_name, mesh, microbatches, fsdp=fsdp, overrides=overrides
        )
        lowered, compiled = _lower_and_compile(jitted, args, mesh)
        rec["memory"] = _mem_dict(compiled)
        full_cost = dict(compiled.cost_analysis())
        full_coll = collective_bytes(compiled.as_text())
        rec["cost_full"] = {
            "flops": full_cost.get("flops", 0.0),
            "bytes accessed": full_cost.get("bytes accessed", 0.0),
        }
        rec["coll_full"] = {k: v for k, v in full_coll.items()}

        block_cost = None
        block_coll = None
        if with_block:
            bj, bargs, _ = build_block(
                arch, shape_name, mesh, fsdp=fsdp, overrides=overrides
            )
            _, bcompiled = _lower_and_compile(bj, bargs, mesh)
            bc = dict(bcompiled.cost_analysis())
            block_cost = {
                "flops": bc.get("flops", 0.0),
                "bytes accessed": bc.get("bytes accessed", 0.0),
            }
            block_coll = collective_bytes(bcompiled.as_text())
            rec["cost_block"] = block_cost
            rec["coll_block"] = {k: v for k, v in block_coll.items()}

        costs = combine_scan_costs(rec["cost_full"], block_cost, cfg.num_layers)
        coll_total = combine_scan_collectives(full_coll, block_coll, cfg.num_layers)
        shape = SHAPES[shape_name]
        mf = analytic_model_flops(cfg, shape.global_batch, shape.seq_len, shape.mode)
        report = analyze(costs, coll_total, n_chips, mf)
        rec["roofline"] = report.to_dict()
        rec["params"] = cfg.param_count()
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record the failure, don't die
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["compile_seconds"] = round(time.time() - t0, 1)
    with open(path, "w") as f:
        json.dump(rec, f, indent=2, default=float)
    status = "ok" if rec["ok"] else "FAIL"
    rl = rec.get("roofline", {})
    print(
        f"[{status}] {slug} {rec['compile_seconds']}s "
        f"bottleneck={rl.get('bottleneck','-')} "
        f"mem={rec.get('memory',{}).get('total_bytes',0)/2**30:.1f}GiB"
    )
    return rec


def run_protocol(multi_pod: bool, out_dir: str, force: bool = False):
    mesh_name = "pod512" if multi_pod else "pod256"
    slug = f"protocol_decafork__{mesh_name}"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, slug + ".json")
    if os.path.exists(path) and not force:
        print(f"[skip] {slug}")
        with open(path) as f:
            return json.load(f)
    t0 = time.time()
    rec = {"arch": "protocol_decafork", "mesh": mesh_name, "ok": False}
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        jitted, args, pcfg = build_protocol(mesh)
        lowered, compiled = _lower_and_compile(jitted, args, mesh)
        rec["memory"] = _mem_dict(compiled)
        c = dict(compiled.cost_analysis())
        rec["cost_full"] = {
            "flops": c.get("flops", 0.0),
            "bytes accessed": c.get("bytes accessed", 0.0),
        }
        rec["coll_full"] = collective_bytes(compiled.as_text())
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["compile_seconds"] = round(time.time() - t0, 1)
    with open(path, "w") as f:
        json.dump(rec, f, indent=2, default=float)
    print(f"[{'ok' if rec['ok'] else 'FAIL'}] {slug} {rec['compile_seconds']}s")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--protocol", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-block", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--fsdp", action="store_true",
                    help="ZeRO-style param/opt sharding over the data axes")
    ap.add_argument("--set", action="append", default=[], metavar="KEY=VALUE",
                    help="ModelConfig overrides, e.g. --set mla_absorb=True")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=os.path.abspath(OUT_DIR))
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        if v in ("True", "False"):
            overrides[k] = v == "True"
        else:
            try:
                overrides[k] = int(v)
            except ValueError:
                try:
                    overrides[k] = float(v)
                except ValueError:
                    overrides[k] = v

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    if args.protocol:
        for mp in meshes:
            run_protocol(mp, args.out, force=args.force)
        return

    combos = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                combos.append((a, s))
    else:
        combos.append((args.arch, args.shape))

    n_fail = 0
    for a, s in combos:
        for mp in meshes:
            rec = run_one(
                a, s, mp, args.out,
                force=args.force,
                with_block=not args.no_block and not mp,
                microbatches=args.microbatches,
                tag=args.tag,
                fsdp=args.fsdp,
                overrides=overrides,
            )
            n_fail += 0 if rec["ok"] else 1
    print(f"done; failures={n_fail}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
