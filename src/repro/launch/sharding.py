"""Divisibility-aware sharding policy (DESIGN.md §5).

One declarative rule table maps parameter names to PartitionSpec
templates; every templated dimension is checked for divisibility against
the mesh and falls back to replication when it doesn't divide (hymba's 25
heads, mamba2's 50280 vocab, ...). Parameters under the stacked
``layers/`` prefix get a leading unsharded layer dimension automatically.

Conventions (MaxText-style):
  vocab, heads, d_ff, experts  -> 'model'
  batch                        -> ('pod','data')   [replicated if B=1]
  sequence                     -> unsharded, except the decode KV ring of
                                  batch-1 long-context, which shards its
                                  window over the data axes instead.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes
from repro.utils.tree import tree_flatten_with_paths

M = "model"

# name -> {ndim: spec template}
PARAM_RULES: Dict[str, Dict[int, tuple]] = {
    "embed": {2: (M, None), 3: (None, M, None)},
    "unembed": {2: (None, M), 3: (None, None, M)},
    "vision_proj": {2: (None, M)},
    # attention
    "wq": {3: (None, M, None)},
    "wk": {3: (None, M, None)},
    "wv": {3: (None, M, None)},
    "wo": {3: (M, None, None)},
    # MLA
    "wdq": {2: (None, M)},
    "wuq": {3: (None, M, None)},
    "wdkv": {2: (None, None)},
    "wkr": {2: (None, None)},
    "wuk": {3: (None, M, None)},
    "wuv": {3: (None, M, None)},
    # swiglu (2-D) and moe experts (3-D, expert-parallel)
    "gate": {2: (None, M), 3: (M, None, None)},
    "up": {2: (None, M), 3: (M, None, None)},
    "down": {2: (M, None), 3: (M, None, None)},
    "router": {2: (None, None)},
    # ssm
    "in_proj": {2: (None, M)},
    "conv_w": {2: (None, M)},
    "out_proj": {2: (M, None)},
}


def _check_divisible(spec: tuple, shape: tuple, mesh) -> P:
    fixed = []
    for dim, ax in enumerate(spec):
        if ax is None:
            fixed.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if shape[dim] % size == 0 and shape[dim] >= size:
            fixed.append(ax)
        else:
            fixed.append(None)
    return P(*fixed)


def spec_for_param(path: str, shape: tuple, mesh) -> P:
    name = path.split("/")[-1]
    rule = PARAM_RULES.get(name)
    in_stack = "/layers/" in f"/{path}/"
    nd = len(shape) - (1 if in_stack else 0)
    if rule is None or nd not in rule:
        return P()  # replicate (norm scales, small vectors, A_log, ...)
    template = rule[nd]
    if in_stack:
        template = (None,) + tuple(template)
    return _check_divisible(tuple(template), shape, mesh)


def _add_fsdp(spec: P, path: str, shape: tuple, mesh) -> P:
    """ZeRO/FSDP extension (EXPERIMENTS.md §Perf-1): additionally shard
    the largest still-replicated dim of every >=2-D parameter over the
    data axes, so parameter/optimizer state divides by the full chip
    count instead of the model axis alone. GSPMD turns this into
    per-layer weight all-gathers + gradient reduce-scatters."""
    dp = data_axes(mesh)
    size = 1
    for a in dp:
        size *= mesh.shape[a]
    nd = len(shape)
    full = tuple(spec) + (None,) * (nd - len(tuple(spec)))
    in_stack = "/layers/" in f"/{path}/"
    start = 1 if in_stack else 0
    if nd - start < 2:
        return P(*full)  # skip 1-D (norms, biases): negligible bytes
    best = None
    for i in range(start, nd):
        if full[i] is None and shape[i] % size == 0 and shape[i] >= size:
            if best is None or shape[i] > shape[best]:
                best = i
    if best is None:
        return P(*full)
    new = list(full)
    new[best] = dp if len(dp) > 1 else dp[0]
    return P(*new)


def params_shardings(param_shapes: Any, mesh, fsdp: bool = False) -> Any:
    flat = tree_flatten_with_paths(param_shapes)
    specs = []
    for p, l in flat:
        spec = spec_for_param(p, tuple(l.shape), mesh)
        if fsdp:
            spec = _add_fsdp(spec, p, tuple(l.shape), mesh)
        specs.append(NamedSharding(mesh, spec))
    treedef = jax.tree.structure(param_shapes)
    return jax.tree.unflatten(treedef, specs)


def opt_shardings(opt_shapes: Any, mesh, params_sh: Any, fsdp: bool = False) -> Any:
    """Moments mirror parameter shardings; scalars replicate."""

    def one(path, leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        # path like 'mu/<param path>' or 'nu/...'
        sub = path.split("/", 1)[1] if "/" in path else path
        spec = spec_for_param(sub, tuple(leaf.shape), mesh)
        if fsdp:
            spec = _add_fsdp(spec, sub, tuple(leaf.shape), mesh)
        return NamedSharding(mesh, spec)

    flat = tree_flatten_with_paths(opt_shapes)
    specs = [one(p, l) for p, l in flat]
    return jax.tree.unflatten(jax.tree.structure(opt_shapes), specs)


def batch_shardings(batch_spec_tree: Any, mesh) -> Any:
    """Shard the leading batch dim over (pod, data) where divisible."""
    dp = data_axes(mesh)
    size = 1
    for a in dp:
        size *= mesh.shape[a]

    def one(leaf):
        if leaf.ndim >= 1 and leaf.shape[0] % size == 0 and leaf.shape[0] >= size:
            return NamedSharding(mesh, P(dp, *([None] * (leaf.ndim - 1))))
        return NamedSharding(mesh, P())

    return jax.tree.map(one, batch_spec_tree)


def cache_shardings(cache_shapes: Any, mesh, cfg) -> Any:
    """Decode-cache shardings.

    Layer-stacked leaves are (L, B, ...). Batch shards over (pod,data)
    when divisible; for batch-1 long-context the KV ring/time dimension
    shards over the data axes instead; KV heads / compressed dims shard
    over 'model' when divisible.
    """
    dp = data_axes(mesh)
    dsize = 1
    for a in dp:
        dsize *= mesh.shape[a]
    msize = mesh.shape["model"]

    def one(path: str, leaf):
        name = path.split("/")[-1]
        shp = tuple(leaf.shape)
        if name in ("k", "v"):  # (L, B, T, KV, hd)
            b_ok = shp[1] % dsize == 0
            kv_ax = M if shp[3] % msize == 0 else None
            if b_ok:
                return NamedSharding(mesh, P(None, dp, None, kv_ax, None))
            t_ax = dp if shp[2] % dsize == 0 else None
            return NamedSharding(mesh, P(None, None, t_ax, kv_ax, None))
        if name in ("ckv", "krope"):  # (L, B, T, r)
            b_ok = shp[1] % dsize == 0
            if b_ok:
                return NamedSharding(mesh, P(None, dp, None, None))
            t_ax = dp if shp[2] % dsize == 0 else None
            return NamedSharding(mesh, P(None, None, t_ax, None))
        if name == "state":  # (L, B, H, P, N)
            b_ok = shp[1] % dsize == 0
            h_ax = M if shp[2] % msize == 0 else None
            return NamedSharding(mesh, P(None, dp if b_ok else None, h_ax, None, None))
        if name == "conv":  # (L, B, K-1, conv_dim)
            b_ok = shp[1] % dsize == 0
            c_ax = M if shp[3] % msize == 0 else None
            return NamedSharding(mesh, P(None, dp if b_ok else None, None, c_ax))
        if name == "cache_positions":  # (B, T)
            if shp[0] % dsize == 0:
                return NamedSharding(mesh, P(dp, None))
            t_ax = dp if shp[1] % dsize == 0 else None
            return NamedSharding(mesh, P(None, t_ax))
        if name == "next_pos":  # (B,)
            ax = dp if shp[0] % dsize == 0 else None
            return NamedSharding(mesh, P(ax))
        return NamedSharding(mesh, P())

    flat = tree_flatten_with_paths(cache_shapes)
    specs = [one(p, l) for p, l in flat]
    return jax.tree.unflatten(jax.tree.structure(cache_shapes), specs)


def with_shardings(shapes: Any, shardings: Any) -> Any:
    """Attach shardings to ShapeDtypeStructs (for AOT .lower())."""
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes,
        shardings,
    )
