"""Datacenter training driver: pjit train step (+ microbatch accumulation).

``make_train_step`` builds the jittable step; the ``__main__`` driver runs
a small real training loop on the local device(s) — see
``examples/quickstart.py`` for the guided version.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.models.model import Model, batch_spec
from repro.optim import adamw, cosine_schedule


def make_train_step(model: Model, optimizer, microbatches: int = 1):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    With microbatches > 1 the global batch is split on its leading axis
    and gradients are accumulated under a lax.scan — this divides live
    activation memory by the microbatch count (the memory-roofline lever
    for the 405B hillclimb) at identical math.
    """

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
        else:
            def split(leaf):
                b = leaf.shape[0]
                assert b % microbatches == 0, "batch must divide microbatches"
                return leaf.reshape(microbatches, b // microbatches, *leaf.shape[1:])

            mb = jax.tree.map(split, batch)

            def acc(carry, micro):
                (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, micro
                )
                acc_loss, acc_grads = carry
                acc_grads = jax.tree.map(jnp.add, acc_grads, grads)
                return (acc_loss + loss, acc_grads), metrics

            zero_grads = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss_sum, grads), metrics = jax.lax.scan(
                acc, (jnp.float32(0.0), zero_grads), mb
            )
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics)

        new_params, new_opt = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return step


def main():
    ap = argparse.ArgumentParser(description="local training driver")
    ap.add_argument("--arch", default="paper_rwsgd")
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke_config
    from repro.data import make_markov_task, sample_batch

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    model = Model(cfg)
    opt = adamw(cosine_schedule(args.lr, warmup=10, total=args.steps))
    key = jax.random.key(0)
    params = model.init(key)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))

    task = make_markov_task(cfg.vocab_size)
    print(f"arch={cfg.name} params={sum(x.size for x in jax.tree.leaves(params)):,} "
          f"entropy_floor={task.entropy:.3f}")
    t0 = time.time()
    for i in range(args.steps):
        batch = sample_batch(task, jax.random.fold_in(key, i), args.batch, args.seq)
        params, opt_state, metrics = step(params, opt_state, batch)
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(metrics['loss']):.4f} "
                  f"({time.time()-t0:.1f}s)")


if __name__ == "__main__":
    main()
