"""Serving driver: prefill + batched autoregressive generation.

This is the datacenter-mode inference loop the decode_32k / long_500k
dry-run shapes lower at production scale: one jitted ``decode_step`` per
token over a batch of streams, greedy or temperature sampling, ring-buffer
KV caches (sliding-window archs), EOS-aware early exit mask.

  from repro.launch.serve import generate
  tokens = generate(model, params, prompts, max_new_tokens=64)

CLI demo:  PYTHONPATH=src python -m repro.launch.serve --arch yi_6b
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


def _sample(logits: jax.Array, key, temperature: float) -> jax.Array:
    """logits: (B, 1, V[, nq]) -> token ids of the batch shape."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(
        jnp.int32
    )


def expand_cache(model: Model, cache, total_len: int):
    """Re-home a prefill cache into a decode cache with headroom."""
    B = cache["next_pos"].shape[0]
    out = model.init_cache(B, total_len)

    def blit(dst, src):
        if dst.shape == src.shape:
            return src
        if (
            dst.ndim == src.ndim
            and dst.shape[:2] == src.shape[:2]
            and dst.shape[2] >= src.shape[2]
        ):
            return dst.at[:, :, : src.shape[2]].set(src)
        return dst

    out["layers"] = jax.tree.map(blit, out["layers"], cache["layers"])
    if "cache_positions" in cache:
        P = cache["cache_positions"].shape[1]
        if out["cache_positions"].shape[1] >= P:
            out["cache_positions"] = (
                out["cache_positions"].at[:, :P].set(cache["cache_positions"])
            )
        else:
            out["cache_positions"] = cache["cache_positions"][
                :, : out["cache_positions"].shape[1]
            ]
    out["next_pos"] = cache["next_pos"]
    return out


def generate(
    model: Model,
    params,
    batch: dict,
    max_new_tokens: int,
    temperature: float = 0.0,
    eos_id: Optional[int] = None,
    key=None,
    eos_check_every: int = 8,
):
    """Prefill `batch` then decode up to `max_new_tokens` greedily/sampled.

    Returns (generated (B, max_new_tokens[, nq]) int32, stats dict).
    Streams that hit `eos_id` keep emitting eos (finished mask), and the
    decode loop exits early once EVERY stream is finished: the finished
    mask is checked on the host every `eos_check_every` steps (periodic,
    so the check does not force a device sync per token), the remaining
    positions are padded with `eos_id` — bitwise what the full loop would
    have emitted — and `stats["decode_steps"]` / `tokens_per_s` count
    only the decode steps actually executed.
    """
    cfg = model.cfg
    if key is None:
        key = jax.random.key(0)
    prompt_len = batch["tokens"].shape[1]
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    last_logits, cache = prefill(params, batch)
    cache = expand_cache(model, cache, prompt_len + max_new_tokens + 1)
    t_prefill = time.time() - t0

    B = batch["tokens"].shape[0]
    tok = _sample(last_logits, key, temperature)
    if cfg.num_codebooks:
        tok = tok.reshape(B, 1, cfg.num_codebooks)
    else:
        tok = tok.reshape(B, 1)
    finished = jnp.zeros((B,), bool)
    track_eos = eos_id is not None and not cfg.num_codebooks
    outs = [tok]
    decode_steps = 0
    t0 = time.time()
    for i in range(max_new_tokens - 1):
        if (
            track_eos
            and eos_check_every > 0
            and i % eos_check_every == 0
            and bool(jax.device_get(jnp.all(finished)))
        ):
            break  # every stream frozen: the rest would all be eos
        logits, cache = decode(params, cache, {"tokens": tok})
        decode_steps += 1
        key = jax.random.fold_in(key, i)
        nxt = _sample(logits, key, temperature)
        nxt = (
            nxt.reshape(B, 1, cfg.num_codebooks)
            if cfg.num_codebooks
            else nxt.reshape(B, 1)
        )
        if track_eos:
            finished = finished | (tok[:, 0] == eos_id)
            nxt = jnp.where(finished[:, None], eos_id, nxt)
        tok = nxt
        outs.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = jnp.concatenate(outs, axis=1)
    if gen.shape[1] < max_new_tokens:  # early exit: pad the frozen tail
        pad = jnp.full(
            (B, max_new_tokens - gen.shape[1]) + gen.shape[2:],
            eos_id,
            gen.dtype,
        )
        gen = jnp.concatenate([gen, pad], axis=1)
    stats = {
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_steps": decode_steps,
        "tokens_per_s": B * max(decode_steps, 1) / max(t_decode, 1e-9),
    }
    return gen, stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    from repro.configs import get_smoke_config
    from repro.data import random_batch_like
    from repro.models.model import batch_spec

    cfg = get_smoke_config(args.arch)
    model = Model(cfg)
    key = jax.random.key(0)
    params = model.init(key)
    batch = random_batch_like(batch_spec(cfg, args.batch, args.prompt_len, "prefill"))
    batch["tokens"] = batch["tokens"] % cfg.vocab_size
    gen, stats = generate(
        model, params, batch, args.max_new, temperature=args.temperature
    )
    print(
        f"arch={cfg.name}: prefill {stats['prefill_s']*1e3:.0f} ms, "
        f"decode {stats['tokens_per_s']:.0f} tok/s"
    )
    print("stream 0:", np.asarray(gen[0]).reshape(-1)[:16].tolist())


if __name__ == "__main__":
    main()
