"""Scenario descriptions for the batched sweep engine.

A *scenario* is one (ProtocolConfig, FailureConfig) pair — one curve of a
paper figure. Scenarios whose configs share static structure (algorithm,
estimator, slot capacity, histogram resolution, burst/node-crash schedule
lengths, fork_prob presence) batch into a single compiled program —
topology-failure regimes (crash schedules, churn and link rates, Pac-Man
node) are ordinary traced leaves and need no grouping at all;
``stack_configs`` builds
the stacked config pytrees (every numeric leaf gains a leading scenario
axis) and ``group_scenarios`` partitions an arbitrary scenario list into
batchable groups.
"""
from __future__ import annotations

import numbers
from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.failures import FailureConfig, pad_bursts
from repro.core.protocol import ProtocolConfig


class Scenario(NamedTuple):
    """A named (protocol, failure) regime — one curve of a figure."""

    name: str
    pcfg: ProtocolConfig
    fcfg: FailureConfig


def as_pair(scenario) -> Tuple[ProtocolConfig, FailureConfig]:
    """Accept a Scenario, an (pcfg, fcfg) tuple, or any .pcfg/.fcfg object."""
    if hasattr(scenario, "pcfg"):
        return scenario.pcfg, scenario.fcfg
    pcfg, fcfg = scenario
    return pcfg, fcfg


def static_signature(scenario) -> tuple:
    """Hashable program-shape key: scenarios batch iff signatures match.

    The final element collects the shape-bearing schedule lengths (walk
    bursts, scheduled node crashes, extra Pac-Man ids, edge cuts);
    ``group_scenarios`` strips it because ``pad_bursts`` reconciles those
    at stacking time. The failure config's own static aux fields
    (``pacman_mobile``) are part of the key proper — a mobile-Pac-Man
    scenario carries different scan state and cannot share a program.
    """
    pcfg, fcfg = as_pair(scenario)
    return (
        pcfg.static_fields,
        pcfg.fork_prob is None,  # None vs value changes the pytree structure
        fcfg.static_fields,
        (fcfg.n_bursts, fcfg.n_node_crashes, fcfg.n_pacman, fcfg.n_edge_cuts),
    )


def group_key(scenario) -> tuple:
    """The batching key: :func:`static_signature` minus the schedule
    lengths (``pad_bursts`` reconciles those at stacking time). Scenarios
    with equal group keys share one compiled program — this is the key
    ``group_scenarios`` partitions on and the coalescing key the
    ``api.service.ExperimentService`` batches concurrent submissions by.
    """
    return static_signature(scenario)[:-1]


def group_scenarios(scenarios: Sequence) -> list:
    """Partition into batchable groups: list of (signature, [indices]).

    Schedule-length differences (bursts, node crashes) are reconciled
    later by ``pad_bursts``, so the grouping key (:func:`group_key`)
    ignores them; everything else must match exactly.
    """
    groups: dict = {}
    order = []
    for i, s in enumerate(scenarios):
        sig = group_key(s)
        if sig not in groups:
            groups[sig] = []
            order.append(sig)
        groups[sig].append(i)
    return [(sig, groups[sig]) for sig in order]


def stack_configs(scenarios: Sequence):
    """Stack scenario configs into (pcfg_batch, fcfg_batch) pytrees whose
    numeric leaves carry a leading (S,) scenario axis.

    Raises ValueError when the scenarios cannot share one compiled
    program (mismatched static fields); burst schedules of different
    lengths are padded to the widest scenario.
    """
    if not scenarios:
        raise ValueError("need at least one scenario")
    pairs = [as_pair(s) for s in scenarios]
    sigs = {group_key(p) for p in pairs}
    if len(sigs) > 1:
        raise ValueError(
            "scenarios mix static structures (algorithm / estimator_impl / "
            "max_walks / rt_bins / fork_prob presence); group them with "
            f"repro.sweep.group_scenarios first: {sorted(map(str, sigs))}"
        )
    pcfgs = [p for p, _ in pairs]
    fcfgs = pad_bursts([f for _, f in pairs])
    for p in pcfgs:
        z0 = p.z0
        if (
            isinstance(z0, (jax.Array, np.ndarray))
            and not isinstance(z0, jax.core.Tracer)
            and z0.ndim == 0
        ):
            z0 = int(z0)  # concrete scalar arrays validate like ints
        if isinstance(z0, numbers.Integral) and p.max_walks < z0:
            raise ValueError("max_walks must be >= z0 in every scenario")

    def _stack(*leaves):
        # round-trip through numpy: python-scalar leaves would otherwise
        # stack into weak-typed arrays, and weak-vs-strong avals needlessly
        # split the jit cache between (say) tuple- and ndarray-built grids
        return jnp.stack([jnp.asarray(np.asarray(leaf)) for leaf in leaves])

    pcfg_batch = jax.tree_util.tree_map(_stack, *pcfgs)
    fcfg_batch = jax.tree_util.tree_map(_stack, *fcfgs)
    return pcfg_batch, fcfg_batch
