"""Batched scenario-sweep engine (see ``engine.py`` for the design).

Quick use::

    from repro.sweep import Scenario, run_scenarios

    scenarios = [
        Scenario(f"eps={e}", ProtocolConfig(eps=e), FailureConfig(...))
        for e in (1.8, 2.0, 2.25, 2.5)
    ]
    result = run_scenarios(graph, scenarios, steps=4500, seeds=8)
    z = result["eps=2.0"].z  # (seeds, steps)
"""
from repro.core.simulator import run_sweep
from repro.sweep.engine import SweepResult, maybe_shard_scenarios, run_scenarios
from repro.sweep.scenario import (
    Scenario,
    as_pair,
    group_scenarios,
    stack_configs,
    static_signature,
)

__all__ = [
    "Scenario",
    "SweepResult",
    "as_pair",
    "group_scenarios",
    "maybe_shard_scenarios",
    "run_scenarios",
    "run_sweep",
    "stack_configs",
    "static_signature",
]
