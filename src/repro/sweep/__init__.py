"""Scenario descriptions + legacy sweep surface.

:class:`Scenario` and the stacking/grouping primitives
(``stack_configs`` / ``group_scenarios`` / ``static_signature``) remain
first-class — the new API consumes them. The *runner* moved: use
``repro.api.Experiment`` ::

    from repro.api import Experiment
    from repro.sweep import Scenario

    scenarios = [
        Scenario(f"eps={e}", ProtocolConfig(eps=e), FailureConfig(...))
        for e in (1.8, 2.0, 2.25, 2.5)
    ]
    result = Experiment(graph=graph, scenarios=scenarios,
                        steps=4500).sweep(seeds=8)
    z = result["eps=2.0"].z  # (seeds, steps)

``run_scenarios`` / ``run_sweep`` survive as deprecation shims.
"""
from repro.core.simulator import run_sweep
from repro.sweep.engine import SweepResult, maybe_shard_scenarios, run_scenarios
from repro.sweep.scenario import (
    Scenario,
    as_pair,
    group_key,
    group_scenarios,
    stack_configs,
    static_signature,
)

__all__ = [
    "Scenario",
    "SweepResult",
    "as_pair",
    "group_key",
    "group_scenarios",
    "maybe_shard_scenarios",
    "run_scenarios",
    "run_sweep",
    "stack_configs",
    "static_signature",
]
