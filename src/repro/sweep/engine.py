"""Legacy sweep-engine surface (deprecated shims over ``repro.api``).

The batched scenario engine this module used to implement — grouping by
static signature, one compiled call per group, scenario-axis placement —
now lives in :class:`repro.api.Plan` (grouping + compile cache) and
:class:`repro.api.Placement` (the placement decision). What remains here:

  * :func:`run_scenarios` — a deprecation shim building the equivalent
    ``Experiment(...).sweep(...)`` (bitwise-equal by construction);
  * :func:`maybe_shard_scenarios` — a thin delegate to ``Placement``,
    kept for callers of the old helper;
  * ``SweepResult`` — re-exported from ``repro.api.results`` (its new
    home) so existing imports keep resolving.
"""
from __future__ import annotations

from typing import Sequence

import jax

from repro.api.results import SweepResult

__all__ = ["SweepResult", "run_scenarios", "maybe_shard_scenarios"]


def maybe_shard_scenarios(pcfgs, fcfgs, n_scenarios: int, *, explicit: bool = False):
    """Place stacked config leaves across the 'data' mesh axis.

    Thin delegate to :meth:`repro.api.Placement.place` (where the logic
    moved): ``explicit=False`` is ``Placement.AUTO``, ``explicit=True``
    is ``Placement.SHARDED``.
    """
    from repro.api import Placement

    policy = Placement.SHARDED if explicit else Placement.AUTO
    return policy.place(pcfgs, fcfgs, n_scenarios)


def run_scenarios(
    graph,
    scenarios: Sequence,
    steps: int,
    seeds: int,
    base_key: jax.Array | int = 0,
    *,
    sharded: bool | None = None,
    payload=None,
    outputs=None,
) -> SweepResult:
    """DEPRECATED shim: run a mixed scenario list, one compiled call per
    static group, per-scenario results in input order.

    Use ``repro.api.Experiment(graph=..., scenarios=..., steps=...,
    placement=...).sweep(seeds=...)`` — same grouping, same compile
    caching, same bits.
    """
    from repro.api import Experiment, Placement
    from repro.utils.deprecation import warn_legacy_runner

    warn_legacy_runner(
        "repro.sweep.run_scenarios", "Experiment(...).sweep(seeds=...)"
    )
    return Experiment(
        graph=graph, scenarios=scenarios, steps=steps, payload=payload,
        outputs=outputs, placement=Placement.from_sharded(sharded),
    ).sweep(seeds=seeds, base_key=base_key)
