"""Batched scenario-sweep engine.

The paper's evaluation is a sweep: figures x failure regimes x epsilon
grids x seed ensembles. ``run_scenarios`` executes an arbitrary mixed
scenario list with ONE jit-compiled call per static-structure group
(``core.simulator.run_sweep`` under the hood: vmap over scenario configs
x seeds), instead of one compile + one device round-trip per curve.

Multi-device: when more than one jax device is visible, the scenario axis
is placed across the 'data' axis of the local mesh (``launch/mesh.py``),
so groups split across devices; on a single device everything stays
local with zero overhead.

Adding a new regime (node-crash schedules, link-failure churn, Pac-Man
adversarial removals, multi-stream variants, ...) is appending a Scenario
row — no new compilation units. A walk payload (``core.payload``) rides
every group's compiled call unchanged, which turns workload metrics
(RW-SGD loss curves) into ordinary batched sweep outputs.
"""
from __future__ import annotations

from typing import Sequence

import jax

from repro.core import simulator as sim
from repro.sweep.scenario import as_pair, group_scenarios

__all__ = ["SweepResult", "run_scenarios", "maybe_shard_scenarios"]


class SweepResult:
    """Per-scenario outputs, input order preserved.

    Behaves as a container of scenarios: ``len`` is the scenario count,
    iteration yields per-scenario StepOutputs (leading ``(seeds,)`` axis),
    and indexing accepts either a position or a scenario name. When the
    sweep carried a payload, ``payloads`` is the parallel list of
    per-scenario payload outputs (``payload(name_or_index)`` to look one
    up); otherwise it is ``None``.
    """

    def __init__(self, names: tuple, outputs: list, payloads: list | None = None):
        self.names = tuple(names)
        self.outputs = list(outputs)
        self.payloads = list(payloads) if payloads is not None else None

    def _index(self, i) -> int:
        return self.names.index(i) if isinstance(i, str) else i

    def __getitem__(self, i):
        return self.outputs[self._index(i)]

    def payload(self, i):
        """Per-scenario payload outputs by position or scenario name."""
        if self.payloads is None:
            raise KeyError("sweep ran without a payload")
        return self.payloads[self._index(i)]

    def __len__(self):
        return len(self.outputs)

    def __iter__(self):
        return iter(self.outputs)

    def items(self):
        return list(zip(self.names, self.outputs))

    def __repr__(self):
        return f"SweepResult({len(self.outputs)} scenarios: {list(self.names)!r})"


def maybe_shard_scenarios(pcfgs, fcfgs, n_scenarios: int, *, explicit: bool = False):
    """Place stacked config leaves across the 'data' mesh axis.

    Auto mode (``explicit=False``) silently skips placement on a single
    device or when the scenario count does not divide the data axis —
    correctness never depends on placement. An ``explicit`` request that
    cannot be honored raises instead of silently running replicated.
    """
    if jax.device_count() == 1 and not explicit:
        return pcfgs, fcfgs
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import data_axis_size, make_local_mesh

    mesh = make_local_mesh()
    if n_scenarios % data_axis_size(mesh) != 0:
        if explicit:
            raise ValueError(
                f"sharded=True but {n_scenarios} scenarios do not divide the "
                f"data axis ({data_axis_size(mesh)} devices); pad the "
                "scenario list or drop the explicit request"
            )
        return pcfgs, fcfgs
    sharding = NamedSharding(mesh, P("data"))

    def put(x):
        return jax.device_put(x, sharding)

    return (
        jax.tree_util.tree_map(put, pcfgs),
        jax.tree_util.tree_map(put, fcfgs),
    )


def run_scenarios(
    graph,
    scenarios: Sequence,
    steps: int,
    seeds: int,
    base_key: jax.Array | int = 0,
    *,
    sharded: bool | None = None,
    payload=None,
    outputs=None,
) -> SweepResult:
    """Run a mixed scenario list; one compiled call per static group.

    ``scenarios`` may freely mix algorithms/estimators: entries are
    grouped by static signature (``group_scenarios``), each group runs as
    one batched ``run_sweep`` call, and results come back per scenario in
    the input order. Each scenario's (seeds,)-leading outputs are bitwise
    what ``run_ensemble`` would produce for it under the same ``base_key``.

    ``outputs`` selects the recorded ``StepOutputs`` fields per group
    (``core.outputs``): the default records scalars only — no
    ``(seeds, steps, W)`` per-walk stacks — unless a payload is attached.

    A ``payload`` (``core.payload.Payload``) rides every group's compiled
    call; per-scenario payload outputs land in ``SweepResult.payloads``
    (workload-under-failure — e.g. loss curves — as ordinary sweep rows).
    """
    scenarios = list(scenarios)
    names = tuple(
        getattr(s, "name", f"scenario{i}") for i, s in enumerate(scenarios)
    )
    results = [None] * len(scenarios)
    payloads = [None] * len(scenarios) if payload is not None else None
    for _sig, idxs in group_scenarios(scenarios):
        group = [(as_pair(scenarios[i])) for i in idxs]
        stacked = sim.run_sweep(
            graph, group, steps, seeds, base_key, sharded=sharded,
            payload=payload, outputs=outputs,
        )
        if payload is not None:
            stacked, stacked_payload = stacked
        for j, i in enumerate(idxs):
            results[i] = jax.tree_util.tree_map(lambda x: x[j], stacked)
            if payload is not None:
                payloads[i] = jax.tree_util.tree_map(
                    lambda x: x[j], stacked_payload
                )
    return SweepResult(names=names, outputs=results, payloads=payloads)
