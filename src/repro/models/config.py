"""Model configuration covering all six assigned architecture families.

One frozen dataclass describes every family; the block/stack builders in
``transformer.py`` dispatch on ``arch_type``:

  dense  — pre-norm decoder, GQA attention, SwiGLU MLP (llama lineage)
  moe    — dense skeleton with the MLP swapped for a routed expert layer
           (optionally MLA attention for deepseek-v2)
  ssm    — attention-free Mamba-2 (SSD) blocks
  hybrid — Hymba-style parallel attention + SSM heads in every block
  audio  — dense decoder over EnCodec tokens: K codebooks in, K heads out
  vlm    — dense decoder with M-RoPE and a precomputed-vision-embedding
           prefix (frontend is a stub per the assignment carve-out)
"""
from __future__ import annotations

import dataclasses

ARCH_TYPES = ("dense", "moe", "ssm", "hybrid", "audio", "vlm")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    # attention (num_heads = 0 -> attention-free)
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    rope_theta: float = 10000.0
    sliding_window: int = 0  # 0 = full causal; >0 = window size
    # MoE
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_num_shared: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # MLA (deepseek-v2)
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 0
    # SSM (mamba2 / hymba)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    # audio
    num_codebooks: int = 0
    # vlm
    mrope: bool = False
    mrope_sections: tuple = (16, 24, 24)  # (t, h, w) per half-head-dim
    vision_tokens: int = 0
    # numerics / execution
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    remat: bool = False
    use_pallas: bool = False
    tie_embeddings: bool = False
    # ---- beyond-paper perf options (EXPERIMENTS.md §Perf) ----
    mla_absorb: bool = False  # absorbed-matmul MLA decode (no K/V remat)
    moe_groups: int = 0  # >0: shard-local MoE dispatch groups (no global sort)
    ssd_chunk: int = 0  # override SSD chunk length (0 -> default 256)
    seq_sharded_residual: bool = False  # Megatron-SP: shard the residual
    # stream's sequence dim over 'model' between blocks (remat-carry /16)

    def __post_init__(self):
        if self.arch_type not in ARCH_TYPES:
            raise ValueError(f"unknown arch_type {self.arch_type!r}")
        if self.arch_type != "ssm" and self.num_heads == 0:
            raise ValueError("attention archs need num_heads")
        if self.num_heads and self.num_kv_heads and self.num_heads % self.num_kv_heads:
            raise ValueError("num_heads must be a multiple of num_kv_heads")

    # ---- derived dims ----------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def attn_out_dim(self) -> int:
        return self.num_heads * self.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (matches init; used in reports)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.num_codebooks:
            emb = self.num_codebooks * v * d * 2
        per_layer = 2 * d  # two norms
        if self.arch_type == "ssm":
            per_layer = d  # single pre-norm per mamba block
        # attention
        if self.arch_type != "ssm":
            if self.use_mla:
                r, rr = self.kv_lora_rank, self.rope_head_dim
                qr = self.q_lora_rank or d
                per_layer += d * self.q_lora_rank if self.q_lora_rank else 0
                q_in = self.q_lora_rank if self.q_lora_rank else d
                per_layer += q_in * self.num_heads * (self.head_dim + rr)
                per_layer += d * (r + rr)  # kv down + shared rope key
                per_layer += r * self.num_kv_heads * 2 * self.head_dim
                per_layer += self.num_heads * self.head_dim * d  # o_proj
            elif self.num_heads:
                per_layer += d * self.num_heads * self.head_dim  # q
                per_layer += 2 * d * self.num_kv_heads * self.head_dim  # k,v
                per_layer += self.num_heads * self.head_dim * d  # o
        # mixer: ssm / hybrid extra
        if self.arch_type in ("ssm", "hybrid"):
            di, n, hds = self.ssm_d_inner, self.ssm_state, self.ssm_heads
            conv_dim = di + 2 * n
            per_layer += d * (2 * di + 2 * n + hds)  # in_proj (z,x,B,C,dt)
            per_layer += conv_dim * self.ssm_conv  # conv
            per_layer += 2 * hds + hds  # A_log, D, dt_bias
            per_layer += di * d  # out_proj
        # mlp
        if self.arch_type == "moe":
            e, fe = self.moe_num_experts, self.moe_d_ff
            per_layer += d * e  # router
            per_layer += e * 3 * d * fe
            per_layer += self.moe_num_shared * 3 * d * fe
        elif self.arch_type != "ssm":
            per_layer += 3 * d * f  # swiglu
        total = emb + L * per_layer + d  # final norm
        if self.arch_type == "vlm":
            total += 1024 * d  # vision projector (stub frontend width 1024)
        return int(total)
