"""Mixture-of-Experts layer (capacity-based, GShard semantics) and MLA.

Dispatch is sort-based rather than one-hot-einsum based: a (tokens, experts,
capacity) dispatch tensor at 1M tokens x 160 experts would be ~10^13
elements, so we instead argsort the (token, expert) assignment pairs,
compute each pair's rank within its expert, and scatter into per-expert
capacity buffers — O(T k d) memory, and the expert FFN runs as one batched
(E, C, d) x (E, d, f) einsum whose FLOPs are exactly the *active* compute
(what the MoE roofline should count). Tokens over capacity are dropped
(standard GShard behavior, capacity_factor controls slack).

Sharding: expert-major weights shard the E axis over the 'model' mesh axis
(expert parallelism); GSPMD inserts the token all-to-all around the
scatter/gather. deepseek-v2's MLA is implemented alongside: low-rank
compressed KV (cached as c_kv + shared rope key), naive decompression on
the forward path — the absorbed-matmul variant is a perf option exercised
in the hillclimb.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Routed experts
# ---------------------------------------------------------------------------


def moe_init(key, cfg, dtype):
    d, e, fe = cfg.d_model, cfg.moe_num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(fe)
    params = {
        "router": (jax.random.normal(ks[0], (d, e)) * s_in).astype(jnp.float32),
        "gate": (jax.random.normal(ks[1], (e, d, fe)) * s_in).astype(dtype),
        "up": (jax.random.normal(ks[2], (e, d, fe)) * s_in).astype(dtype),
        "down": (jax.random.normal(ks[3], (e, fe, d)) * s_out).astype(dtype),
    }
    if cfg.moe_num_shared:
        from repro.models.layers import swiglu_init

        params["shared"] = swiglu_init(
            ks[4], d, cfg.moe_num_shared * fe, dtype
        )
    return params


def moe_capacity(tokens: int, cfg) -> int:
    cap = int(
        math.ceil(tokens * cfg.moe_top_k / cfg.moe_num_experts * cfg.capacity_factor)
    )
    return max(8, -(-cap // 8) * 8)  # round up to a multiple of 8


def moe_apply(params, x: jax.Array, cfg):
    """x: (B, S, d) -> (y: (B, S, d), aux_loss: scalar).

    With ``cfg.moe_groups > 1`` dispatch runs independently per token
    group (EXPERIMENTS.md §Perf-2): groups align with the batch shards,
    so the argsort/scatter stay device-local and the only cross-device
    traffic left is the unavoidable token<->expert all-to-all around the
    expert einsum. ``moe_groups = 0`` is the global-sort baseline.
    """
    B, S, d = x.shape
    T = B * S
    if cfg.moe_groups > 1 and T % cfg.moe_groups == 0:
        G = cfg.moe_groups
        tg = T // G
        xg = x.reshape(G, tg, d)
        cg = moe_capacity(tg, cfg)
        y, aux = jax.vmap(lambda xx: _moe_tokens(params, xx, cfg, cg))(xg)
        y = y.reshape(B, S, d)
        aux_total = jnp.mean(aux)
    else:
        y, aux_total = _moe_tokens(
            params, x.reshape(T, d), cfg, moe_capacity(T, cfg)
        )
        y = y.reshape(B, S, d)
    if cfg.moe_num_shared:
        from repro.models.layers import swiglu

        y = y + swiglu(params["shared"], x)
    return y, aux_total


def _moe_tokens(params, xf: jax.Array, cfg, C: int):
    """Sort-based dispatch + expert FFN + combine for flat tokens (T, d)."""
    T, d = xf.shape
    k = cfg.moe_top_k
    E = cfg.moe_num_experts

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # ---- load-balance auxiliary loss (Switch-style) -----------------------
    me = jnp.mean(probs, axis=0)  # (E,)
    onehot_top1 = jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(onehot_top1, axis=0)
    aux = E * jnp.sum(me * ce) * cfg.router_aux_weight

    # ---- sort-based dispatch ----------------------------------------------
    flat_e = expert_idx.reshape(T * k)
    flat_tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    flat_gate = gate_vals.reshape(T * k)
    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    tok_sorted = flat_tok[order]
    gate_sorted = flat_gate[order]
    counts = jnp.bincount(flat_e, length=E)  # (E,)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(T * k, dtype=jnp.int32) - starts[e_sorted]
    keep = rank < C
    slot = jnp.where(keep, rank, C)  # C = out-of-range -> dropped

    buf = jnp.zeros((E, C, d), xf.dtype)
    buf = buf.at[e_sorted, slot].set(xf[tok_sorted], mode="drop")

    # ---- expert FFN: batched over experts (active FLOPs only) -------------
    g = jnp.einsum("ecd,edf->ecf", buf, params["gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["up"])
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["down"])

    # ---- combine ------------------------------------------------------------
    gathered = out_buf[e_sorted, jnp.minimum(slot, C - 1)]  # (T*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    y = jnp.zeros((T, d), xf.dtype).at[tok_sorted].add(
        gathered * gate_sorted[:, None].astype(xf.dtype)
    )
    return y, aux


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, deepseek-v2)
# ---------------------------------------------------------------------------


def mla_init(key, cfg, dtype):
    d, H, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    r, rr, qr = cfg.kv_lora_rank, cfg.rope_head_dim, cfg.q_lora_rank
    ks = jax.random.split(key, 8)
    s = 1.0 / math.sqrt(d)
    params = {
        "wdkv": (jax.random.normal(ks[0], (d, r)) * s).astype(dtype),
        "wkr": (jax.random.normal(ks[1], (d, rr)) * s).astype(dtype),
        "kv_norm": jnp.ones((r,), dtype),
        "wuk": (jax.random.normal(ks[2], (r, H, hd)) / math.sqrt(r)).astype(dtype),
        "wuv": (jax.random.normal(ks[3], (r, H, hd)) / math.sqrt(r)).astype(dtype),
        "wo": (
            jax.random.normal(ks[4], (H, hd, d)) / math.sqrt(H * hd)
        ).astype(dtype),
    }
    if qr:
        params["wdq"] = (jax.random.normal(ks[5], (d, qr)) * s).astype(dtype)
        params["q_norm"] = jnp.ones((qr,), dtype)
        params["wuq"] = (
            jax.random.normal(ks[6], (qr, H, hd + rr)) / math.sqrt(qr)
        ).astype(dtype)
    else:
        params["wq"] = (
            jax.random.normal(ks[7], (d, H, hd + rr)) * s
        ).astype(dtype)
    return params


def mla_project_q(params, x, cfg):
    """-> q_nope (B,S,H,hd), q_rope (B,S,H,rr)."""
    from repro.models.layers import rmsnorm

    hd, rr = cfg.head_dim, cfg.rope_head_dim
    if cfg.q_lora_rank:
        cq = jnp.einsum("bsd,dr->bsr", x, params["wdq"])
        cq = rmsnorm(cq, params["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhe->bshe", cq, params["wuq"])
    else:
        q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    return q[..., :hd], q[..., hd:]


def mla_compress_kv(params, x, cfg):
    """-> c_kv (B,S,r) normalized, k_rope (B,S,rr)."""
    from repro.models.layers import rmsnorm

    ckv = jnp.einsum("bsd,dr->bsr", x, params["wdkv"])
    ckv = rmsnorm(ckv, params["kv_norm"], cfg.norm_eps)
    kr = jnp.einsum("bsd,dr->bsr", x, params["wkr"])
    return ckv, kr


def mla_decompress(params, ckv):
    """Naive path: -> k_nope (B,T,H,hd), v (B,T,H,hd)."""
    k = jnp.einsum("btr,rhe->bthe", ckv, params["wuk"])
    v = jnp.einsum("btr,rhe->bthe", ckv, params["wuv"])
    return k, v


def mla_decode_absorbed(
    params,
    q_nope,  # (B, 1, H, hd)
    q_rope,  # (B, 1, H, rr) — rope already applied
    ckv_cache,  # (B, T, r)
    kr_cache,  # (B, T, rr) — rope already applied at insert time
    valid,  # (B, T) bool cache-slot mask
    cfg,
):
    """Absorbed-matmul MLA decode (EXPERIMENTS.md §Perf-3).

    Instead of rematerializing K/V = W_uk c, W_uv c over the whole cache
    (H x hd = 16384 floats per cached token), fold W_uk into the query
    and W_uv into the output:

        score_h(t) = (W_uk_h^T q_h) . c_t + q_rope_h . k_rope_t
        out_h      = W_uv_h^T (sum_t p_h(t) c_t)

    HBM per token drops from O(T * H * hd) to O(T * (r + rr)) — a
    (H*hd)/(r+rr) = 28x reduction for deepseek-v2 — at lower FLOPs too.
    """
    import math as _math

    hd, rr = cfg.head_dim, cfg.rope_head_dim
    scale = 1.0 / _math.sqrt(hd + rr)
    # q~ = W_uk^T q : (B, H, r)
    q_abs = jnp.einsum("bhe,rhe->bhr", q_nope[:, 0], params["wuk"])
    scores = jnp.einsum(
        "bhr,btr->bht", q_abs.astype(jnp.float32), ckv_cache.astype(jnp.float32)
    )
    scores += jnp.einsum(
        "bhe,bte->bht",
        q_rope[:, 0].astype(jnp.float32),
        kr_cache.astype(jnp.float32),
    )
    scores = jnp.where(valid[:, None, :], scores * scale, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bht,btr->bhr", p, ckv_cache.astype(jnp.float32))  # (B,H,r)
    out = jnp.einsum("bhr,rhe->bhe", ctx, params["wuv"].astype(jnp.float32))
    return out[:, None].astype(ckv_cache.dtype)  # (B, 1, H, hd)
