"""Decoder stacks for all six architecture families.

Layer parameters are stacked on a leading (L, ...) axis and the stack runs
under ``lax.scan`` (small HLO, fast multi-pod compiles; the roofline
pipeline corrects for XLA's count-the-body-once cost analysis by lowering
``block_fn`` separately and scaling by L — see launch/roofline.py).

Three entry points per model, matching the assigned input shapes:
  train:   full-sequence forward + CE loss           (train_4k)
  prefill: full-sequence forward, returns KV/SSM cache (prefill_32k)
  decode:  one token against the cache               (decode_32k, long_500k)
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.config import ModelConfig
from repro.models.layers import (
    apply_mrope,
    apply_rope,
    attention_init,
    blocked_causal_attention,
    decode_attention,
    rmsnorm,
    rmsnorm_init,
    swiglu,
    swiglu_init,
)

VISION_EMBED_DIM = 1024  # stub ViT output width (assignment carve-out)


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Block init
# ---------------------------------------------------------------------------


def block_init(key, cfg: ModelConfig):
    dt = _dtype(cfg)
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {}
    if cfg.arch_type == "ssm":
        p["norm"] = rmsnorm_init(cfg.d_model, dt)
        p["ssm"] = ssm_lib.ssm_init(ks[0], cfg, dt)
        return p
    p["attn_norm"] = rmsnorm_init(cfg.d_model, dt)
    p["mlp_norm"] = rmsnorm_init(cfg.d_model, dt)
    if cfg.use_mla:
        p["attn"] = moe_lib.mla_init(ks[0], cfg, dt)
    else:
        p["attn"] = attention_init(ks[0], cfg, dt)
    if cfg.arch_type == "hybrid":
        p["ssm"] = ssm_lib.ssm_init(ks[1], cfg, dt)
        p["attn_branch_norm"] = rmsnorm_init(cfg.d_model, dt)
        p["ssm_branch_norm"] = rmsnorm_init(cfg.d_model, dt)
    if cfg.arch_type == "moe":
        p["moe"] = moe_lib.moe_init(ks[2], cfg, dt)
    else:
        p["mlp"] = swiglu_init(ks[3], cfg.d_model, cfg.d_ff, dt)
    return p


# ---------------------------------------------------------------------------
# Attention paths (full-sequence and decode)
# ---------------------------------------------------------------------------


def _rope_q_k(cfg, q, k, pos_info):
    if cfg.mrope:
        q = apply_mrope(q, pos_info["positions3"], cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, pos_info["positions3"], cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, pos_info["positions"], cfg.rope_theta)
        k = apply_rope(k, pos_info["positions"], cfg.rope_theta)
    return q, k


def attn_full(lp, x, cfg: ModelConfig, pos_info, window: int):
    """Full-sequence GQA attention; returns (out, (k, v)) for cache fill."""
    q = jnp.einsum("bsd,dhk->bshk", x, lp["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, lp["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, lp["wv"])
    q, k = _rope_q_k(cfg, q, k, pos_info)
    if cfg.use_pallas:
        from repro.kernels import attention_pallas

        out = attention_pallas(q, k, v, window=window)
    else:
        out = blocked_causal_attention(q, k, v, window=window)
    return jnp.einsum("bshk,hkd->bsd", out, lp["wo"]), (k, v)


def attn_decode(lp, x, cfg: ModelConfig, cache, pos_info):
    """x: (B,1,d). cache: {'k','v'} ring buffers + shared positions."""
    pos = pos_info["pos"]  # (B,)
    q = jnp.einsum("bsd,dhk->bshk", x, lp["wq"])
    k_new = jnp.einsum("bsd,dhk->bshk", x, lp["wk"])
    v_new = jnp.einsum("bsd,dhk->bshk", x, lp["wv"])
    if cfg.mrope:
        # decode happens in the text region: all three coordinate streams
        # advance together as i - vision_tokens + grid (see make_pos_info)
        g = max(int(math.ceil(math.sqrt(max(cfg.vision_tokens, 1)))), 1)
        pos_txt = pos - cfg.vision_tokens + g
        p3 = jnp.broadcast_to(pos_txt[None, :, None], (3, pos.shape[0], 1))
        q = apply_mrope(q, p3, cfg.rope_theta, cfg.mrope_sections)
        k_new = apply_mrope(k_new, p3, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k_new = apply_rope(k_new, pos[:, None], cfg.rope_theta)
    T = cache["k"].shape[1]
    slot = pos % T  # ring-buffer insert
    bidx = jnp.arange(pos.shape[0])
    k_cache = cache["k"].at[bidx, slot].set(k_new[:, 0])
    v_cache = cache["v"].at[bidx, slot].set(v_new[:, 0])
    # cache_positions are shared across layers and updated once per step
    cache_pos = pos_info["cache_positions"]
    out = decode_attention(
        q, k_cache, v_cache, cache_pos, pos, window=cfg.sliding_window
    )
    out = jnp.einsum("bshk,hkd->bsd", out, lp["wo"])
    return out, {"k": k_cache, "v": v_cache}


def mla_full(lp, x, cfg: ModelConfig, pos_info):
    q_nope, q_rope = moe_lib.mla_project_q(lp, x, cfg)
    ckv, kr = moe_lib.mla_compress_kv(lp, x, cfg)
    k_nope, v = moe_lib.mla_decompress(lp, ckv)
    pos = pos_info["positions"]
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    kr = apply_rope(kr[:, :, None, :], pos, cfg.rope_theta)  # (B,S,1,rr)
    kr_b = jnp.broadcast_to(kr, (*k_nope.shape[:3], kr.shape[-1]))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, kr_b], axis=-1)
    out = blocked_causal_attention(q, k, v, window=0)
    return jnp.einsum("bshk,hkd->bsd", out, lp["wo"]), (ckv, kr[:, :, 0, :])


def mla_decode(lp, x, cfg: ModelConfig, cache, pos_info):
    pos = pos_info["pos"]
    q_nope, q_rope = moe_lib.mla_project_q(lp, x, cfg)
    ckv_new, kr_new = moe_lib.mla_compress_kv(lp, x, cfg)
    q_rope = apply_rope(q_rope, pos[:, None], cfg.rope_theta)
    kr_new = apply_rope(kr_new[:, :, None, :], pos[:, None], cfg.rope_theta)[:, :, 0]
    T = cache["ckv"].shape[1]
    slot = pos % T
    bidx = jnp.arange(pos.shape[0])
    ckv = cache["ckv"].at[bidx, slot].set(ckv_new[:, 0])
    kr = cache["krope"].at[bidx, slot].set(kr_new[:, 0])
    cache_pos = pos_info["cache_positions"]
    if cfg.mla_absorb:
        # absorbed-matmul path (EXPERIMENTS.md §Perf-3)
        valid = (cache_pos >= 0) & (cache_pos <= pos[:, None])
        if cfg.sliding_window > 0:
            valid &= cache_pos > (pos[:, None] - cfg.sliding_window)
        out = moe_lib.mla_decode_absorbed(lp, q_nope, q_rope, ckv, kr, valid, cfg)
    else:
        # naive decompression of the whole compressed cache (§Perf-3 baseline)
        k_nope, v = moe_lib.mla_decompress(lp, ckv)
        kr_b = jnp.broadcast_to(kr[:, :, None, :], (*k_nope.shape[:3], kr.shape[-1]))
        k = jnp.concatenate([k_nope, kr_b], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = decode_attention(q, k, v, cache_pos, pos, window=0)
    return jnp.einsum("bshk,hkd->bsd", out, lp["wo"]), {"ckv": ckv, "krope": kr}


# ---------------------------------------------------------------------------
# Block apply — full sequence (train / prefill)
# ---------------------------------------------------------------------------


def block_apply_full(lp, x, cfg: ModelConfig, pos_info, collect_cache: bool):
    """Returns (x', aux_loss, cache_entry_or_None)."""
    aux = jnp.float32(0.0)
    cache_entry = {} if collect_cache else None
    if cfg.arch_type == "ssm":
        h = rmsnorm(x, lp["norm"], cfg.norm_eps)
        if collect_cache:
            y, sc = ssm_lib.ssm_forward_train(lp["ssm"], h, cfg, return_cache=True)
            cache_entry.update(sc)
        else:
            y = ssm_lib.ssm_forward_train(lp["ssm"], h, cfg)
        return x + y, aux, cache_entry

    h = rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
    if cfg.use_mla:
        attn_out, (ckv, kr) = mla_full(lp["attn"], h, cfg, pos_info)
        if collect_cache:
            cache_entry.update({"ckv": ckv, "krope": kr})
    else:
        attn_out, (k, v) = attn_full(lp["attn"], h, cfg, pos_info, cfg.sliding_window)
        if collect_cache:
            cache_entry.update({"k": k, "v": v})
    if cfg.arch_type == "hybrid":
        if collect_cache:
            ssm_out, sc = ssm_lib.ssm_forward_train(lp["ssm"], h, cfg, return_cache=True)
            cache_entry.update(sc)
        else:
            ssm_out = ssm_lib.ssm_forward_train(lp["ssm"], h, cfg)
        mixed = 0.5 * (
            rmsnorm(attn_out, lp["attn_branch_norm"], cfg.norm_eps)
            + rmsnorm(ssm_out, lp["ssm_branch_norm"], cfg.norm_eps)
        )
        x = x + mixed
    else:
        x = x + attn_out

    h2 = rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
    if cfg.arch_type == "moe":
        y, aux = moe_lib.moe_apply(lp["moe"], h2, cfg)
        x = x + y
    else:
        x = x + swiglu(lp["mlp"], h2)
    return x, aux, cache_entry


# ---------------------------------------------------------------------------
# Block apply — decode (one token, cached)
# ---------------------------------------------------------------------------


def block_apply_decode(lp, x, cfg: ModelConfig, cache_l, pos_info):
    """Returns (x', new_cache_l)."""
    new_cache = dict(cache_l)
    if cfg.arch_type == "ssm":
        h = rmsnorm(x, lp["norm"], cfg.norm_eps)
        y, st, cc = ssm_lib.ssm_decode_step(
            lp["ssm"], h, cache_l["state"], cache_l["conv"], cfg
        )
        new_cache["state"], new_cache["conv"] = st, cc
        return x + y, new_cache

    h = rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
    if cfg.use_mla:
        attn_out, kv_cache = mla_decode(lp["attn"], h, cfg, cache_l, pos_info)
    else:
        attn_out, kv_cache = attn_decode(lp["attn"], h, cfg, cache_l, pos_info)
    new_cache.update(kv_cache)
    if cfg.arch_type == "hybrid":
        y, st, cc = ssm_lib.ssm_decode_step(
            lp["ssm"], h, cache_l["state"], cache_l["conv"], cfg
        )
        new_cache["state"], new_cache["conv"] = st, cc
        mixed = 0.5 * (
            rmsnorm(attn_out, lp["attn_branch_norm"], cfg.norm_eps)
            + rmsnorm(y, lp["ssm_branch_norm"], cfg.norm_eps)
        )
        x = x + mixed
    else:
        x = x + attn_out

    h2 = rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
    if cfg.arch_type == "moe":
        y2, _ = moe_lib.moe_apply(lp["moe"], h2, cfg)
        x = x + y2
    else:
        x = x + swiglu(lp["mlp"], h2)
    return x, new_cache


# ---------------------------------------------------------------------------
# Embeddings / heads
# ---------------------------------------------------------------------------


def embed_init(key, cfg: ModelConfig):
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    s = 0.02
    p: Dict[str, Any] = {"final_norm": rmsnorm_init(cfg.d_model, dt)}
    if cfg.num_codebooks:
        p["embed"] = (
            jax.random.normal(ks[0], (cfg.num_codebooks, cfg.vocab_size, cfg.d_model))
            * s
        ).astype(dt)
        p["unembed"] = (
            jax.random.normal(ks[1], (cfg.num_codebooks, cfg.d_model, cfg.vocab_size))
            * s
        ).astype(dt)
    else:
        p["embed"] = (
            jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model)) * s
        ).astype(dt)
        if not cfg.tie_embeddings:
            p["unembed"] = (
                jax.random.normal(ks[1], (cfg.d_model, cfg.vocab_size)) * s
            ).astype(dt)
    if cfg.arch_type == "vlm":
        p["vision_proj"] = (
            jax.random.normal(ks[2], (VISION_EMBED_DIM, cfg.d_model))
            / math.sqrt(VISION_EMBED_DIM)
        ).astype(dt)
    return p


def embed_tokens(p, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    if cfg.num_codebooks:
        # tokens: (B, S, nq) — sum codebook embeddings (MusicGen)
        parts = [p["embed"][q][tokens[..., q]] for q in range(cfg.num_codebooks)]
        return sum(parts)
    return p["embed"][tokens]


def logits_from_h(p, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    h = rmsnorm(h, p["final_norm"], cfg.norm_eps)
    if cfg.num_codebooks:
        return jnp.einsum("bsd,qdv->bsqv", h, p["unembed"])
    w = p["embed"].T if cfg.tie_embeddings else p["unembed"]
    return jnp.einsum("bsd,dv->bsv", h, w)


# ---------------------------------------------------------------------------
# Position streams
# ---------------------------------------------------------------------------


def make_pos_info(cfg: ModelConfig, batch_size: int, seq_len: int):
    pos = jnp.broadcast_to(jnp.arange(seq_len, dtype=jnp.int32), (batch_size, seq_len))
    info = {"positions": pos}
    if cfg.mrope:
        tv = cfg.vision_tokens
        g = max(int(math.ceil(math.sqrt(max(tv, 1)))), 1)
        i = jnp.arange(seq_len, dtype=jnp.int32)
        is_vis = i < tv
        t = jnp.where(is_vis, 0, i - tv + g)
        hh = jnp.where(is_vis, i // g, i - tv + g)
        ww = jnp.where(is_vis, i % g, i - tv + g)
        p3 = jnp.stack([t, hh, ww])  # (3, S)
        info["positions3"] = jnp.broadcast_to(p3[:, None, :], (3, batch_size, seq_len))
    return info
