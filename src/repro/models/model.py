"""Public model API: init / loss / train forward / prefill / decode.

A ``Model`` wraps a ``ModelConfig`` and exposes pure functions suitable for
``jax.jit`` + pjit sharding:

  init(key)                          -> params
  loss(params, batch)                -> (scalar, metrics)     [train_4k]
  prefill(params, batch)             -> (last_logits, cache)  [prefill_32k]
  decode_step(params, cache, batch)  -> (logits, cache)       [decode_*]

Batches are dicts of arrays (see ``batch_spec``); the decoder stack runs
under ``lax.scan`` over stacked layer params, optionally rematerialized.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import (
    VISION_EMBED_DIM,
    block_apply_decode,
    block_apply_full,
    block_init,
    embed_init,
    embed_tokens,
    logits_from_h,
    make_pos_info,
)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ init
    def init(self, key: jax.Array):
        cfg = self.cfg
        k_emb, k_layers = jax.random.split(key)
        layer_keys = jax.random.split(k_layers, cfg.num_layers)
        layers = jax.vmap(lambda k: block_init(k, cfg))(layer_keys)
        params = embed_init(k_emb, cfg)
        params["layers"] = layers
        return params

    def init_shapes(self):
        """ShapeDtypeStruct pytree of params without allocating (dry-run)."""
        return jax.eval_shape(lambda: self.init(jax.random.key(0)))

    # --------------------------------------------------------------- helpers
    def _embed_batch(self, params, batch) -> jax.Array:
        cfg = self.cfg
        h = embed_tokens(params, cfg, batch["tokens"])
        if cfg.arch_type == "vlm":
            vis = jnp.einsum(
                "btv,vd->btd", batch["vision_embeds"].astype(h.dtype), params["vision_proj"]
            )
            h = jnp.concatenate([vis, h], axis=1)
        return h

    def _stack_full(self, params, h, pos_info, collect_cache: bool):
        cfg = self.cfg

        def _sp(x):
            if not cfg.seq_sharded_residual:
                return x
            # Megatron-SP: the saved inter-layer residual is sequence-
            # sharded over 'model'; GSPMD inserts AG/RS around the block.
            from jax.sharding import PartitionSpec as P

            U = P.UNCONSTRAINED
            return jax.lax.with_sharding_constraint(x, P(U, "model", U))

        def body(carry, lp):
            x, aux = carry
            x = _sp(x)
            x, a, cache_entry = block_apply_full(lp, x, cfg, pos_info, collect_cache)
            x = _sp(x)
            return (x, aux + a), cache_entry

        if cfg.remat:
            body = jax.checkpoint(body)
        (h, aux), caches = jax.lax.scan(body, (h, jnp.float32(0.0)), params["layers"])
        return h, aux, caches

    # ------------------------------------------------------------------ train
    def forward_logits(self, params, batch) -> jax.Array:
        h = self._embed_batch(params, batch)
        pos_info = make_pos_info(self.cfg, h.shape[0], h.shape[1])
        h, _, _ = self._stack_full(params, h, pos_info, collect_cache=False)
        if self.cfg.arch_type == "vlm":
            h = h[:, self.cfg.vision_tokens :]
        return logits_from_h(params, self.cfg, h)

    def loss(self, params, batch):
        cfg = self.cfg
        h = self._embed_batch(params, batch)
        pos_info = make_pos_info(cfg, h.shape[0], h.shape[1])
        h, aux, _ = self._stack_full(params, h, pos_info, collect_cache=False)
        if cfg.arch_type == "vlm":
            h = h[:, cfg.vision_tokens :]
        logits = logits_from_h(params, cfg, h)
        labels = batch["labels"]
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(
            logits.astype(jnp.float32), labels[..., None], axis=-1
        )[..., 0]
        ce = jnp.mean(lse - gold)
        total = ce + aux
        return total, {"ce": ce, "aux": aux}

    # ---------------------------------------------------------------- prefill
    def prefill(self, params, batch):
        """Full-sequence forward; returns (last-position logits, cache)."""
        cfg = self.cfg
        h = self._embed_batch(params, batch)
        B, S = h.shape[0], h.shape[1]
        pos_info = make_pos_info(cfg, B, S)
        h, _, caches = self._stack_full(params, h, pos_info, collect_cache=True)
        last = logits_from_h(params, cfg, h[:, -1:])
        cache = {"layers": caches}
        if cfg.arch_type not in ("ssm",):
            cache["cache_positions"] = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32), (B, S)
            )
        cache["next_pos"] = jnp.full((B,), S, jnp.int32)
        return last, cache

    # ----------------------------------------------------------------- decode
    def cache_len(self, seq_len: int) -> int:
        w = self.cfg.sliding_window
        return min(seq_len, w) if w > 0 else seq_len

    def init_cache(self, batch_size: int, seq_len: int):
        """Zeroed decode cache sized for a context of `seq_len` tokens."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        T = self.cache_len(seq_len)
        L, B = cfg.num_layers, batch_size
        layers: Dict[str, Any] = {}
        if cfg.arch_type != "ssm":
            if cfg.use_mla:
                layers["ckv"] = jnp.zeros((L, B, T, cfg.kv_lora_rank), dt)
                layers["krope"] = jnp.zeros((L, B, T, cfg.rope_head_dim), dt)
            else:
                kv, hd = cfg.num_kv_heads, cfg.head_dim
                layers["k"] = jnp.zeros((L, B, T, kv, hd), dt)
                layers["v"] = jnp.zeros((L, B, T, kv, hd), dt)
        if cfg.arch_type in ("ssm", "hybrid"):
            hs, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
            conv_dim = cfg.ssm_d_inner + 2 * n
            layers["state"] = jnp.zeros((L, B, hs, p, n), jnp.float32)
            layers["conv"] = jnp.zeros((L, B, cfg.ssm_conv - 1, conv_dim), dt)
        cache: Dict[str, Any] = {"layers": layers, "next_pos": jnp.zeros((B,), jnp.int32)}
        if cfg.arch_type != "ssm":
            cache["cache_positions"] = jnp.full((B, T), -1, jnp.int32)
        return cache

    def decode_step(self, params, cache, batch):
        """One-token decode. batch: {'tokens': (B,1[,nq])}; returns
        (logits (B,1,V[,nq]), updated cache)."""
        cfg = self.cfg
        pos = cache["next_pos"]  # (B,)
        h = embed_tokens(params, cfg, batch["tokens"])
        pos_info: Dict[str, Any] = {"pos": pos}
        new_cache = dict(cache)
        if cfg.arch_type != "ssm":
            T = cache["cache_positions"].shape[1]
            slot = pos % T
            bidx = jnp.arange(pos.shape[0])
            cache_positions = cache["cache_positions"].at[bidx, slot].set(pos)
            pos_info["cache_positions"] = cache_positions
            new_cache["cache_positions"] = cache_positions

        def body(x, xs):
            lp, cache_l = xs
            x, new_cache_l = block_apply_decode(lp, x, cfg, cache_l, pos_info)
            return x, new_cache_l

        h, new_layer_caches = jax.lax.scan(
            body, h, (params["layers"], cache["layers"])
        )
        new_cache["layers"] = new_layer_caches
        new_cache["next_pos"] = pos + 1
        logits = logits_from_h(params, cfg, h)
        return logits, new_cache


@functools.lru_cache(maxsize=64)
def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


# ---------------------------------------------------------------------------
# Batch specs (ShapeDtypeStructs for jit lowering / synthetic data shapes)
# ---------------------------------------------------------------------------


def batch_spec(cfg: ModelConfig, batch_size: int, seq_len: int, mode: str):
    """ShapeDtypeStruct dict for `mode` in {'train','prefill','decode'}."""
    i32 = jnp.int32
    f32 = jnp.float32
    if mode in ("train", "prefill"):
        if cfg.num_codebooks:
            toks = jax.ShapeDtypeStruct((batch_size, seq_len, cfg.num_codebooks), i32)
            labels = jax.ShapeDtypeStruct((batch_size, seq_len, cfg.num_codebooks), i32)
        elif cfg.arch_type == "vlm":
            text = seq_len - cfg.vision_tokens
            toks = jax.ShapeDtypeStruct((batch_size, text), i32)
            labels = jax.ShapeDtypeStruct((batch_size, text), i32)
        else:
            toks = jax.ShapeDtypeStruct((batch_size, seq_len), i32)
            labels = jax.ShapeDtypeStruct((batch_size, seq_len), i32)
        batch = {"tokens": toks}
        if mode == "train":
            batch["labels"] = labels
        if cfg.arch_type == "vlm":
            batch["vision_embeds"] = jax.ShapeDtypeStruct(
                (batch_size, cfg.vision_tokens, VISION_EMBED_DIM), f32
            )
        return batch
    if mode == "decode":
        if cfg.num_codebooks:
            toks = jax.ShapeDtypeStruct((batch_size, 1, cfg.num_codebooks), i32)
        else:
            toks = jax.ShapeDtypeStruct((batch_size, 1), i32)
        return {"tokens": toks}
    raise ValueError(mode)
