"""Shared neural layers: RMSNorm, RoPE / M-RoPE, SwiGLU, GQA attention.

Attention is implemented as *statically* unrolled q-block attention with
static causal KV slicing. Two reasons:
  1. exact FLOP accounting — XLA's ``cost_analysis`` counts a while-loop
     body once, so ``lax.scan``-based flash attention would corrupt the
     roofline terms (we verified this empirically);
  2. bounded transients — a q-block of 512 keeps the score buffer at
     (B, H, 512, kv_len) instead of (B, H, S, S), which is what makes the
     405B × 4k train step fit in HBM without a Pallas dependency.
The Pallas flash kernel in ``repro/kernels`` is the TPU-optimized version
of exactly this computation (same oracle), switchable via cfg.use_pallas.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

Q_BLOCK = 512  # static query block for blocked attention


# ---------------------------------------------------------------------------
# Norm / MLP
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype) -> jax.Array:
    return jnp.ones((d,), dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def swiglu_init(key, d: int, f: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f)
    return {
        "gate": (jax.random.normal(k1, (d, f)) * s_in).astype(dtype),
        "up": (jax.random.normal(k2, (d, f)) * s_in).astype(dtype),
        "down": (jax.random.normal(k3, (f, d)) * s_out).astype(dtype),
    }


def swiglu(params, x: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, params["gate"])
    u = jnp.einsum("...d,df->...f", x, params["up"])
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, params["down"])


# ---------------------------------------------------------------------------
# RoPE (standard + M-RoPE)
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """(head_dim/2,) inverse frequencies."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    half = x.shape[-1] // 2
    freqs = rope_frequencies(x.shape[-1], theta)  # (half,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions3: jax.Array, theta: float, sections: tuple
) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    positions3: (3, B, S) — temporal / height / width coordinate streams.
    The half-head-dim frequency bands are split into ``sections`` chunks;
    band j uses the coordinate stream assigned to its chunk.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, "mrope sections must sum to head_dim/2"
    freqs = rope_frequencies(x.shape[-1], theta)  # (half,)
    # select the position stream per frequency band
    sel = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=half
    )  # (half,) in {0,1,2}
    pos = positions3.astype(jnp.float32)  # (3, B, S)
    pos_per_band = jnp.take(pos, sel, axis=0)  # (half, B, S)
    ang = jnp.moveaxis(pos_per_band, 0, -1) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention (train/prefill: blocked; decode: cached single query)
# ---------------------------------------------------------------------------


def attention_init(key, cfg, dtype):
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    so = 1.0 / math.sqrt(H * hd)
    return {
        "wq": (jax.random.normal(k1, (d, H, hd)) * s).astype(dtype),
        "wk": (jax.random.normal(k2, (d, KV, hd)) * s).astype(dtype),
        "wv": (jax.random.normal(k3, (d, KV, hd)) * s).astype(dtype),
        "wo": (jax.random.normal(k4, (H, hd, d)) * so).astype(dtype),
    }


def _block_attend(q, k, v, q_offset: int, kv_offset: int, window: int):
    """Attend one q block against a kv slice with causal (+window) mask.

    q: (B, Tq, KV, G, hd); k/v: (B, Tk, KV, hd). Offsets are the absolute
    positions of element 0 of each slice. Returns (out, row_max, row_sum)
    for online-softmax combination — callers that pass the full causal kv
    range can use the softmaxed output directly.
    """
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum(
        "bqkgd,btkd->bkgqt", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale  # (B, KV, G, Tq, Tk)
    qpos = q_offset + jnp.arange(q.shape[1])[:, None]
    kpos = kv_offset + jnp.arange(k.shape[1])[None, :]
    mask = kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqt,btkd->bqkgd", probs.astype(v.dtype), v)
    return out


def blocked_causal_attention(
    q: jax.Array,  # (B, S, H, hd)
    k: jax.Array,  # (B, S, KV, hd)
    v: jax.Array,  # (B, S, KV, hd)
    window: int = 0,
    q_block: int = Q_BLOCK,
) -> jax.Array:
    """Statically-unrolled q-block causal attention with exact KV slicing.

    For q block i, only kv[0 : (i+1)*q_block] (or the sliding window slice)
    is touched — static slices, so compiled FLOPs match the causal cost.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    vd = v.shape[-1]  # may differ from hd (MLA: q/k carry extra rope dims)
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    if S <= q_block:
        out = _block_attend(qg, k, v, 0, 0, window)
        return out.reshape(B, S, H, vd)
    assert S % q_block == 0, "sequence must be a multiple of the q block"
    outs = []
    for i in range(S // q_block):
        q_i = qg[:, i * q_block : (i + 1) * q_block]
        end = (i + 1) * q_block
        start = 0 if window <= 0 else max(0, end - window - q_block)
        o = _block_attend(
            q_i, k[:, start:end], v[:, start:end], i * q_block, start, window
        )
        outs.append(o)
    return jnp.concatenate(outs, axis=1).reshape(B, S, H, vd)


def decode_attention(
    q: jax.Array,  # (B, 1, H, hd)
    k_cache: jax.Array,  # (B, T, KV, hd)  (ring buffer if windowed)
    v_cache: jax.Array,  # (B, T, KV, hd)
    cache_positions: jax.Array,  # (B, T) int32 absolute positions, -1 = empty
    pos: jax.Array,  # (B,) current absolute position
    window: int = 0,
) -> jax.Array:
    """Single-token attention against a (possibly ring-buffer) KV cache."""
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    vd = v_cache.shape[-1]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, KV, G, hd)
    scores = jnp.einsum(
        "bkgd,btkd->bkgt", qg.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    valid = (cache_positions >= 0) & (cache_positions <= pos[:, None])
    if window > 0:
        valid &= cache_positions > (pos[:, None] - window)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", probs.astype(v_cache.dtype), v_cache)
    return out.reshape(B, 1, H, vd)
