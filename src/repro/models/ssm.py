"""Mamba-2 (SSD — state-space duality) blocks, train scan + decode step.

The selective state-space recurrence
    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t,    y_t = C_t h_t + D x_t
is computed chunkwise (SSD): quadratic attention-like compute inside
chunks of length Q, a cross-chunk state recurrence between them. The
cross-chunk recurrence uses ``jax.lax.associative_scan`` (statically
unrolled log-depth tree) rather than ``lax.scan`` so the compiled HLO
carries the true FLOP count for the roofline (XLA cost analysis counts a
while-loop body once — verified empirically).

Single-group (G=1) B/C as in mamba2-1.3b; Hymba reuses these functions
with its own (smaller) state size. The Pallas ``ssd_scan`` kernel mirrors
the intra-chunk computation; ``use_pallas`` switches it in on TPU.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

SSD_CHUNK = 256


def ssm_init(key, cfg, dtype):
    d = cfg.d_model
    di = cfg.ssm_d_inner
    n = cfg.ssm_state
    hs = cfg.ssm_heads
    conv_dim = di + 2 * n
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    return {
        "in_proj": (
            jax.random.normal(ks[0], (d, 2 * di + 2 * n + hs)) * s
        ).astype(dtype),
        "conv_w": (
            jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim)) / math.sqrt(cfg.ssm_conv)
        ).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(
            jnp.linspace(1.0, 16.0, hs, dtype=jnp.float32)
        ),  # A = -exp(a_log)
        "d_skip": jnp.ones((hs,), jnp.float32),
        "dt_bias": jnp.full((hs,), math.log(math.e - 1), jnp.float32),
        "gate_norm": jnp.ones((di,), dtype),
        "out_proj": (
            jax.random.normal(ks[2], (di, d)) / math.sqrt(di)
        ).astype(dtype),
    }


def _split_in_proj(cfg, zxbcdt):
    di, n, hs = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + di + 2 * n]
    dt = zxbcdt[..., di + di + 2 * n :]
    return z, xbc, dt


def causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal 1-D conv; xbc: (B, L, Cd), w: (K, Cd)."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(K):  # K = 4: static unroll, exact FLOPs
        out = out + pad[:, i : i + xbc.shape[1]] * w[K - 1 - i]
    return jax.nn.silu(out + b)


def ssd_chunked(
    x: jax.Array,  # (B, L, H, P) head inputs
    dt: jax.Array,  # (B, L, H) softplus'd step sizes
    a: jax.Array,  # (H,) negative continuous-time decay
    b_in: jax.Array,  # (B, L, N) input projections (G=1)
    c_in: jax.Array,  # (B, L, N) output projections (G=1)
    chunk: int = SSD_CHUNK,
    return_state: bool = False,
):
    """Chunkwise SSD; returns y (B, L, H, P) (without D skip / gating).

    With ``return_state`` also returns the final SSM state (B, H, P, N)
    so prefill can seed the decode cache.
    """
    B, L, H, P = x.shape
    N = b_in.shape[-1]
    if L % chunk:
        raise ValueError(f"L={L} must be a multiple of chunk={chunk}")
    nc = L // chunk
    xc = x.reshape(B, nc, chunk, H, P)
    dtc = dt.reshape(B, nc, chunk, H)
    bc = b_in.reshape(B, nc, chunk, N)
    cc = c_in.reshape(B, nc, chunk, N)

    da = dtc * a  # (B, nc, Q, H) log-decay increments (negative)
    da_cs = jnp.cumsum(da, axis=2)  # inclusive cumsum within chunk
    da_total = da_cs[:, :, -1]  # (B, nc, H)

    xdt = xc * dtc[..., None]  # dt-weighted inputs

    # ---- intra-chunk (quadratic) -----------------------------------------
    # L_mat[q, t] = exp(da_cs[q] - da_cs[t]) for q >= t
    diff = da_cs[:, :, :, None, :] - da_cs[:, :, None, :, :]  # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask BEFORE exp: masked entries have diff > 0 -> exp overflows and
    # the where backward would emit 0 * inf = NaN
    decay = jnp.exp(jnp.where(tri[None, None, :, :, None], diff, -1e30))
    scores = jnp.einsum("bcqn,bctn->bcqt", cc, bc)  # (B,nc,Q,Q)
    y_intra = jnp.einsum(
        "bcqt,bcqth,bcthp->bcqhp", scores, decay, xdt.astype(jnp.float32)
    )

    # ---- chunk states -------------------------------------------------------
    decay_out = jnp.exp(da_total[:, :, None, :] - da_cs)  # (B,nc,Q,H)
    states = jnp.einsum(
        "bctn,bcth,bcthp->bchpn", bc, decay_out, xdt.astype(jnp.float32)
    )  # (B,nc,H,P,N)

    # ---- inter-chunk recurrence via associative scan ------------------------
    # pairs (g, s): s_running = s_prev * g + s
    gs = jnp.exp(da_total)  # (B,nc,H)

    def combine(left, right):
        g1, s1 = left
        g2, s2 = right
        return g1 * g2, s1 * g2[..., None, None] + s2

    g_run, s_run = jax.lax.associative_scan(combine, (gs, states), axis=1)
    # state entering chunk c = running state after chunk c-1
    s_prev = jnp.concatenate(
        [jnp.zeros_like(s_run[:, :1]), s_run[:, :-1]], axis=1
    )  # (B,nc,H,P,N)

    in_decay = jnp.exp(da_cs)  # decay from chunk start to position q
    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", cc, in_decay, s_prev)

    y = (y_intra + y_inter).astype(x.dtype)
    y = y.reshape(B, L, H, P)
    if return_state:
        return y, s_run[:, -1]  # (B, H, P, N)
    return y


def ssm_forward_train(params, x: jax.Array, cfg, return_cache: bool = False):
    """Full mamba2 mixer for a training/prefill sequence; x: (B, L, d).

    With ``return_cache`` also returns {'state', 'conv'} for decoding.
    """
    di, n, hs, p = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    from repro.models.layers import rmsnorm

    zxbcdt = jnp.einsum("bld,de->ble", x, params["in_proj"])
    z, xbc_raw, dt = _split_in_proj(cfg, zxbcdt)
    xbc = causal_conv(xbc_raw, params["conv_w"], params["conv_b"])
    xs = xbc[..., :di].reshape(*x.shape[:2], hs, p)
    b_in = xbc[..., di : di + n]
    c_in = xbc[..., di + n :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])
    chunk = min(getattr(cfg, "ssd_chunk", 0) or SSD_CHUNK, x.shape[1])
    if getattr(cfg, "use_pallas", False):
        from repro.kernels import ssd_pallas

        y, state = ssd_pallas(xs, dt, a, b_in, c_in, chunk=chunk)
    else:
        y, state = ssd_chunked(xs, dt, a, b_in, c_in, chunk=chunk, return_state=True)
    y = y + (params["d_skip"][None, None, :, None] * xs.astype(jnp.float32)).astype(
        y.dtype
    )
    y = y.reshape(*x.shape[:2], di)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, params["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, params["out_proj"])
    if return_cache:
        k = params["conv_w"].shape[0]
        conv_cache = xbc_raw[:, -(k - 1) :]  # raw pre-conv window
        return out, {"state": state.astype(jnp.float32), "conv": conv_cache}
    return out


def ssm_decode_step(params, x: jax.Array, state, conv_cache, cfg):
    """Single-token recurrent update.

    x: (B, 1, d); state: (B, H, P, N); conv_cache: (B, K-1, conv_dim).
    Returns (y (B,1,d), new_state, new_conv_cache).
    """
    di, n, hs, p = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    from repro.models.layers import rmsnorm

    zxbcdt = jnp.einsum("bld,de->ble", x, params["in_proj"])
    z, xbc, dt = _split_in_proj(cfg, zxbcdt)
    xbc_t = xbc[:, 0]  # (B, conv_dim)
    # conv over the cached window: window[k] holds x_{t-K+1+k}, while
    # conv_w[j] multiplies lag j — flip to align (matches causal_conv)
    window = jnp.concatenate([conv_cache, xbc_t[:, None]], axis=1)  # (B,K,Cd)
    conv_out = (
        jnp.einsum("bkc,kc->bc", window, params["conv_w"][::-1]) + params["conv_b"]
    )
    conv_out = jax.nn.silu(conv_out)
    new_conv_cache = window[:, 1:]

    xs = conv_out[:, :di].reshape(-1, hs, p)  # (B,H,P)
    b_in = conv_out[:, di : di + n]  # (B,N)
    c_in = conv_out[:, di + n :]  # (B,N)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a = -jnp.exp(params["a_log"])  # (H,)
    g = jnp.exp(dtv * a)  # (B,H)
    xdt = xs.astype(jnp.float32) * dtv[..., None]
    new_state = state * g[..., None, None] + jnp.einsum("bhp,bn->bhpn", xdt, b_in)
    y = jnp.einsum("bhpn,bn->bhp", new_state, c_in)
    y = y + params["d_skip"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(-1, 1, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, params["gate_norm"], cfg.norm_eps)
    return jnp.einsum("ble,ed->bld", y, params["out_proj"]), new_state, new_conv_cache
