"""Random-walk SGD: the paper's learning algorithm (Section I).

Each live walk carries a model replica; the currently visited node takes a
local (mini-batch) SGD step on *its own* data shard and forwards the
replica. Replicas live in a fixed-capacity stack with a leading walk-slot
axis — forking a walk is a slot-to-slot copy of (params, opt moments),
which is exactly DECAFORK's "identical duplicate" semantics, and
termination simply deactivates the slot.

``replica_train_step`` vectorizes the per-walk local step with ``vmap``
so one jitted call advances every live replica simultaneously (the
synchronous-round semantics of the simulator). :class:`RwSgdPayload`
packages the whole thing as a ``core.payload.Payload``, fusing RW-SGD
into the simulator's ``lax.scan`` — learning runs *inside* the compiled
trajectory, batches under ``Experiment.ensemble``/``.sweep``
(``repro.api``), and accuracy-under-failure becomes an ordinary scenario
axis.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.payload import Payload


class ReplicaSet(NamedTuple):
    params: Any  # pytree, leaves (W, ...)
    opt_state: Any  # pytree, leaves (W, ...)
    steps: jax.Array  # (W,) int32 local step counters


def init_replicas(init_fn: Callable, opt_init: Callable, key, max_walks: int) -> ReplicaSet:
    """All slots start from the same initialization (footnote 4: one node
    creates the Z_0 walks — they share the initial model)."""
    params = init_fn(key)
    opt_state = opt_init(params)
    stack = lambda t: jax.tree.map(
        lambda x: jnp.broadcast_to(x, (max_walks,) + x.shape), t
    )
    return ReplicaSet(
        params=stack(params),
        opt_state=stack(opt_state),
        steps=jnp.zeros((max_walks,), jnp.int32),
    )


def fork_replica(rs: ReplicaSet, src: jax.Array, dst: jax.Array, do: jax.Array) -> ReplicaSet:
    """Copy slot src -> dst where `do` (bool scalar or (E,) events) holds."""
    src = jnp.atleast_1d(src)
    dst = jnp.atleast_1d(dst)
    do = jnp.atleast_1d(do)
    safe_dst = jnp.where(do, dst, rs.steps.shape[0])  # out-of-range -> drop

    def copy(leaf):
        return leaf.at[safe_dst].set(leaf[src], mode="drop")

    return ReplicaSet(
        params=jax.tree.map(copy, rs.params),
        opt_state=jax.tree.map(copy, rs.opt_state),
        steps=rs.steps.at[safe_dst].set(rs.steps[src], mode="drop"),
    )


def local_sgd_step(loss_fn: Callable, optimizer, params, opt_state, batch):
    """One node-local update: plain SGD/Adam on the node's mini-batch."""
    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
    new_params, new_opt = optimizer.update(grads, opt_state, params)
    return new_params, new_opt, loss, metrics


def replica_train_step(loss_fn: Callable, optimizer):
    """vmapped per-walk local step over the slot axis.

    Returns f(rs, batches, active) -> (new rs, (W,) losses); inactive
    slots pass through unchanged.
    """

    def one(params, opt_state, batch, active):
        new_p, new_o, loss, _ = local_sgd_step(loss_fn, optimizer, params, opt_state, batch)
        sel = lambda a, b: jax.tree.map(
            lambda x, y: jnp.where(
                jnp.reshape(active, (1,) * x.ndim), x, y
            ),
            a,
            b,
        )
        return sel(new_p, params), sel(new_o, opt_state), jnp.where(active, loss, 0.0)

    vone = jax.vmap(one, in_axes=(0, 0, 0, 0))

    def step(rs: ReplicaSet, batches, active):
        new_params, new_opt, losses = vone(rs.params, rs.opt_state, batches, active)
        return (
            ReplicaSet(
                params=new_params,
                opt_state=new_opt,
                steps=rs.steps + active.astype(jnp.int32),
            ),
            losses,
        )

    return step


class RwSgdOutputs(NamedTuple):
    """Per-round learning telemetry stacked over the trajectory."""

    loss: jax.Array  # (W,) per-slot local loss (0 where no step ran)
    mean_loss: jax.Array  # scalar mean over slots that trained this round
    trained: jax.Array  # scalar int32: slots that took a local step


class RwSgdPayload(Payload):
    """The paper's workload as a pluggable payload: per-walk model
    replicas + optimizer state, advanced by batched local SGD.

    carry = :class:`ReplicaSet` (leaves with a leading ``max_walks``
    slot axis). Per round:

      * ``on_fork`` duplicates the parent's (params, opt moments, step
        counter) into the freshly allocated slot via ``fork_replica`` —
        DECAFORK's "identical copy", and the overwrite that recycles any
        stale state left by a terminated predecessor in that slot;
      * ``on_visit`` samples each live walk's mini-batch from the data
        shard of the node it just hopped to (``data.synthetic``'s
        node-keyed Markov task) and applies the vmapped local step;
        ``train_every`` > 1 thins updates to every k-th round (mask-based,
        same compiled program);
      * ``on_terminate`` is the default no-op: a dead slot's replica is
        simply never trained again and is overwritten on re-fork.

    The object is static under jit — model/optimizer/task/capacity are
    structure, the ReplicaSet is the traced state. Reuse one instance
    across runs to reuse the compiled program.
    """

    def __init__(
        self,
        model,
        optimizer,
        task,
        max_walks: int,
        local_batch: int = 2,
        seq_len: int = 32,
        train_every: int = 1,
    ):
        self.model = model
        self.optimizer = optimizer
        self.task = task
        self.max_walks = int(max_walks)
        self.local_batch = int(local_batch)
        self.seq_len = int(seq_len)
        self.train_every = int(train_every)
        self._train = replica_train_step(model.loss, optimizer)
        self._signature_cache = False  # lazily computed (task content hash)

    def signature(self):
        """Stable static-config tuple (see ``Payload.signature``): model
        config dataclass, optimizer hyperparameter signature, a content
        hash of the task's transition logits, and the capacity knobs.
        Returns None — identity semantics, no cross-process store keys —
        when the optimizer or task cannot be fingerprinted.
        """
        if self._signature_cache is not False:
            return self._signature_cache
        opt_sig = getattr(self.optimizer, "signature", None)
        model_cfg = getattr(self.model, "cfg", None)
        task_logits = getattr(self.task, "logits", None)
        if opt_sig is None or model_cfg is None or task_logits is None:
            sig = None
        else:
            import hashlib

            import numpy as np

            digest = hashlib.sha256(
                np.ascontiguousarray(np.asarray(task_logits, np.float32))
                .tobytes()
            ).hexdigest()
            sig = (
                model_cfg,
                opt_sig,
                ("task", digest),
                self.max_walks,
                self.local_batch,
                self.seq_len,
                self.train_every,
            )
        self._signature_cache = sig
        return sig

    def output_fields(self):
        return RwSgdOutputs._fields

    def validate(self, pcfg) -> None:
        if pcfg.max_walks != self.max_walks:
            raise ValueError(
                f"payload capacity max_walks={self.max_walks} does not match "
                f"ProtocolConfig.max_walks={pcfg.max_walks}"
            )

    def init(self, key: jax.Array) -> ReplicaSet:
        return init_replicas(
            self.model.init, self.optimizer.init, key, self.max_walks
        )

    def on_fork(self, rs: ReplicaSet, fork_parent: jax.Array) -> ReplicaSet:
        slots = jnp.arange(fork_parent.shape[0], dtype=jnp.int32)
        return fork_replica(
            rs, jnp.maximum(fork_parent, 0), slots, fork_parent >= 0
        )

    def on_visit(self, rs: ReplicaSet, walks, t, key):
        from repro.data.synthetic import sample_batch

        batches = jax.vmap(
            lambda nid: sample_batch(
                self.task, key, self.local_batch, self.seq_len, nid
            )
        )(walks.pos)
        do = walks.active & (t % self.train_every == 0)
        rs, losses = self._train(rs, batches, do)
        n_trained = jnp.sum(do)
        mean = jnp.sum(losses) / jnp.maximum(n_trained, 1)
        return rs, RwSgdOutputs(
            loss=losses, mean_loss=mean, trained=n_trained.astype(jnp.int32)
        )
