"""Random-walk SGD: the paper's learning algorithm (Section I).

Each live walk carries a model replica; the currently visited node takes a
local (mini-batch) SGD step on *its own* data shard and forwards the
replica. Replicas live in a fixed-capacity stack with a leading walk-slot
axis — forking a walk is a slot-to-slot copy of (params, opt moments),
which is exactly DECAFORK's "identical duplicate" semantics, and
termination simply deactivates the slot.

``replica_train_step`` vectorizes the per-walk local step with ``vmap``
so one jitted call advances every live replica simultaneously (the
synchronous-round semantics of the simulator).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class ReplicaSet(NamedTuple):
    params: Any  # pytree, leaves (W, ...)
    opt_state: Any  # pytree, leaves (W, ...)
    steps: jax.Array  # (W,) int32 local step counters


def init_replicas(init_fn: Callable, opt_init: Callable, key, max_walks: int) -> ReplicaSet:
    """All slots start from the same initialization (footnote 4: one node
    creates the Z_0 walks — they share the initial model)."""
    params = init_fn(key)
    opt_state = opt_init(params)
    stack = lambda t: jax.tree.map(
        lambda x: jnp.broadcast_to(x, (max_walks,) + x.shape), t
    )
    return ReplicaSet(
        params=stack(params),
        opt_state=stack(opt_state),
        steps=jnp.zeros((max_walks,), jnp.int32),
    )


def fork_replica(rs: ReplicaSet, src: jax.Array, dst: jax.Array, do: jax.Array) -> ReplicaSet:
    """Copy slot src -> dst where `do` (bool scalar or (E,) events) holds."""
    src = jnp.atleast_1d(src)
    dst = jnp.atleast_1d(dst)
    do = jnp.atleast_1d(do)
    safe_dst = jnp.where(do, dst, rs.steps.shape[0])  # out-of-range -> drop

    def copy(leaf):
        return leaf.at[safe_dst].set(leaf[src], mode="drop")

    return ReplicaSet(
        params=jax.tree.map(copy, rs.params),
        opt_state=jax.tree.map(copy, rs.opt_state),
        steps=rs.steps.at[safe_dst].set(rs.steps[src], mode="drop"),
    )


def local_sgd_step(loss_fn: Callable, optimizer, params, opt_state, batch):
    """One node-local update: plain SGD/Adam on the node's mini-batch."""
    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
    new_params, new_opt = optimizer.update(grads, opt_state, params)
    return new_params, new_opt, loss, metrics


def replica_train_step(loss_fn: Callable, optimizer):
    """vmapped per-walk local step over the slot axis.

    Returns f(rs, batches, active) -> (new rs, (W,) losses); inactive
    slots pass through unchanged.
    """

    def one(params, opt_state, batch, active):
        new_p, new_o, loss, _ = local_sgd_step(loss_fn, optimizer, params, opt_state, batch)
        sel = lambda a, b: jax.tree.map(
            lambda x, y: jnp.where(
                jnp.reshape(active, (1,) * x.ndim), x, y
            ),
            a,
            b,
        )
        return sel(new_p, params), sel(new_o, opt_state), jnp.where(active, loss, 0.0)

    vone = jax.vmap(one, in_axes=(0, 0, 0, 0))

    def step(rs: ReplicaSet, batches, active):
        new_params, new_opt, losses = vone(rs.params, rs.opt_state, batches, active)
        return (
            ReplicaSet(
                params=new_params,
                opt_state=new_opt,
                steps=rs.steps + active.astype(jnp.int32),
            ),
            losses,
        )

    return step
