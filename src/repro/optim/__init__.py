from repro.optim.optimizers import (
    Optimizer,
    OptState,
    adamw,
    sgd,
    cosine_schedule,
    constant_schedule,
)
from repro.optim.rw_sgd import (
    ReplicaSet,
    RwSgdOutputs,
    RwSgdPayload,
    init_replicas,
    fork_replica,
    local_sgd_step,
    replica_train_step,
)

__all__ = [
    "Optimizer",
    "OptState",
    "adamw",
    "sgd",
    "cosine_schedule",
    "constant_schedule",
    "ReplicaSet",
    "RwSgdOutputs",
    "RwSgdPayload",
    "init_replicas",
    "fork_replica",
    "local_sgd_step",
    "replica_train_step",
]
