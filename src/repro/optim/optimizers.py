"""Functional optimizers (no external deps): SGD(+momentum), AdamW.

State mirrors the parameter pytree leaf-for-leaf, so the sharding policy
applied to params applies verbatim to optimizer slots — which is exactly
what the dry-run does.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    mu: object  # pytree like params (or () for plain SGD)
    nu: object  # pytree like params (or () for SGD)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params) -> (new_params, new_state)
    # stable hyperparameter tuple (name, lr token, ...) — None when the
    # optimizer closes over something we cannot fingerprint (an unlabeled
    # schedule callable). Consumed by Payload.signature implementations
    # (repro.core.payload) to build cross-process compile/store keys.
    signature: Optional[tuple] = None


def _lr_token(lr):
    """Stable token for a learning rate: the float itself, a schedule's
    declared ``.signature``, or None (unfingerprintable callable)."""
    if callable(lr):
        return getattr(lr, "signature", None)
    return float(lr)


def constant_schedule(lr: float) -> Callable:
    fn = lambda step: jnp.float32(lr)
    fn.signature = ("const", float(lr))
    return fn


def cosine_schedule(lr: float, warmup: int, total: int, min_ratio: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / max(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.float32(lr) * warm * (min_ratio + (1 - min_ratio) * cos)

    fn.signature = ("cosine", float(lr), int(warmup), int(total), float(min_ratio))
    return fn


def _cast_like(x, ref):
    return x.astype(ref.dtype)


def sgd(lr, momentum: float = 0.0) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        mu = jax.tree.map(jnp.zeros_like, params) if momentum else ()
        return OptState(step=jnp.zeros((), jnp.int32), mu=mu, nu=())

    def update(grads, state, params):
        lr_t = sched(state.step)
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state.mu, grads)
            upd = mu
        else:
            mu = ()
            upd = grads
        new_params = jax.tree.map(
            lambda p, u: p - _cast_like(lr_t * u, p), params, upd
        )
        return new_params, OptState(step=state.step + 1, mu=mu, nu=())

    tok = _lr_token(lr)
    sig = None if tok is None else ("sgd", tok, float(momentum))
    return Optimizer(init=init, update=update, signature=sig)


def adamw(
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    moment_dtype=jnp.float32,
) -> Optimizer:
    """AdamW. ``moment_dtype=bfloat16`` halves optimizer HBM (the ZeRO-2
    companion used by the llama3-405b fit hillclimb, EXPERIMENTS.md
    §Perf-1); accumulation still happens in float32."""
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
        return OptState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def update(grads, state, params):
        step = state.step + 1
        lr_t = sched(state.step)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd_leaf(g, m, v, p):
            g = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
            mhat = m32 / c1
            vhat = v32 / c2
            delta = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                delta = delta + weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype)
            return m32.astype(moment_dtype), v32.astype(moment_dtype), new_p

        flat_g, tdef = jax.tree.flatten(grads)
        flat_m = tdef.flatten_up_to(state.mu)
        flat_v = tdef.flatten_up_to(state.nu)
        flat_p = tdef.flatten_up_to(params)
        out = [upd_leaf(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        mu = tdef.unflatten([o[0] for o in out])
        nu = tdef.unflatten([o[1] for o in out])
        new_params = tdef.unflatten([o[2] for o in out])
        return new_params, OptState(step=step, mu=mu, nu=nu)

    tok = _lr_token(lr)
    sig = None if tok is None else (
        "adamw", tok, float(b1), float(b2), float(eps), float(weight_decay),
        jnp.dtype(moment_dtype).name,
    )
    return Optimizer(init=init, update=update, signature=sig)
