"""Spectral / Markov-chain quantities of the simple random walk.

Used for (i) the analytic-survival option of the estimator (paper
footnote 5), (ii) the theory module's (lambda_r, lambda_a) rates
(Assumption 1), and (iii) sizing the initialization phase (cover time).
"""
from __future__ import annotations

import numpy as np

from repro.graphs.generators import Graph


def transition_matrix(g: Graph) -> np.ndarray:
    """Row-stochastic simple-RW transition matrix (analysis use)."""
    a = g.adjacency().astype(np.float64)
    return a / a.sum(1, keepdims=True)


def stationary_distribution(g: Graph) -> np.ndarray:
    """pi_i = deg(i) / 2|E| for a simple RW on an undirected graph."""
    d = g.degrees.astype(np.float64)
    return d / d.sum()


def expected_return_times(g: Graph) -> np.ndarray:
    """E[R_i] = 1 / pi_i (Kac's formula)."""
    return 1.0 / stationary_distribution(g)


def return_rate_estimate(g: Graph) -> np.ndarray:
    """Per-node exponential return rate lambda_r (Assumption 1 proxy).

    The paper approximates R_i by a geometric with mean 1/pi_i; the
    continuous analog is exp(lambda_r) with lambda_r = pi_i.
    """
    return stationary_distribution(g)


def spectral_gap(g: Graph) -> float:
    """1 - lambda_2 of the lazy symmetrized walk (mixing rate)."""
    p = transition_matrix(g)
    d = g.degrees.astype(np.float64)
    # Symmetrize: S = D^{1/2} P D^{-1/2} has the same spectrum as P.
    s = np.sqrt(d)[:, None] * p / np.sqrt(d)[None, :]
    ev = np.linalg.eigvalsh((s + s.T) / 2.0)
    lam2 = ev[-2]
    return float(1.0 - lam2)


def mixing_time_bound(g: Graph, eps: float = 0.25) -> float:
    """t_mix <= log(1/(eps*pi_min)) / gap (standard bound)."""
    gap = spectral_gap(g)
    pi_min = stationary_distribution(g).min()
    return float(np.log(1.0 / (eps * pi_min)) / max(gap, 1e-12))


def arrival_rate_estimate(g: Graph) -> float:
    """Global first-hitting rate lambda_a for a freshly forked walk.

    Hitting times to a random target from a random source concentrate
    around n for regular expanders; we use lambda_a = 1 / mean_i E[H_i]
    with E[H_i] ~ E[R_i] * (1 - pi_i) / pi_i ... approximated by 1/n
    scaled by the spectral gap correction (Tishby et al. 2022 show
    exponential tails with rate ~ pi_i for random regular graphs).
    """
    pi = stationary_distribution(g)
    return float(pi.mean())


def cover_time_estimate(g: Graph) -> float:
    """~ n log n for regular expanders; used to size the init phase."""
    n = g.n
    return float(2.0 * n * np.log(max(n, 2)))
