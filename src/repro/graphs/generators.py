"""Graph generators for the decentralized system.

Self-contained (no networkx). Every generator returns a `Graph` — a padded
neighbor-list representation that is directly consumable by jitted JAX code:

  neighbors : (n, max_deg) int32, padded with 0 (mask via degrees)
  degrees   : (n,)         int32

The paper evaluates on random d-regular graphs (Figs. 1-5) plus complete,
Erdos-Renyi and power-law graphs (Fig. 6); we implement all of those plus
ring and 2-D torus for tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Callable

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """Immutable padded-adjacency graph."""

    n: int
    neighbors: np.ndarray  # (n, max_deg) int32, row i padded with i itself
    degrees: np.ndarray  # (n,) int32
    family: str = "custom"

    @property
    def max_degree(self) -> int:
        return int(self.neighbors.shape[1])

    @property
    def num_edges(self) -> int:
        return int(self.degrees.sum()) // 2

    def adjacency(self) -> np.ndarray:
        """Dense boolean adjacency (test/analysis use only)."""
        a = np.zeros((self.n, self.n), dtype=bool)
        for i in range(self.n):
            for j in self.neighbors[i, : self.degrees[i]]:
                a[i, j] = True
        return a

    def validate(self) -> None:
        a = self.adjacency()
        assert (a == a.T).all(), "graph must be undirected"
        assert not a.diagonal().any(), "no self loops"
        assert is_connected_adj(a), "graph must be connected"


def _adj_to_graph(a: np.ndarray, family: str) -> Graph:
    n = a.shape[0]
    degs = a.sum(1).astype(np.int32)
    max_deg = int(degs.max())
    # Pad each row with the node's own index: sampling code never reads
    # beyond `degrees[i]`, padding value only needs to be a valid index.
    nbrs = np.tile(np.arange(n, dtype=np.int32)[:, None], (1, max_deg))
    for i in range(n):
        js = np.nonzero(a[i])[0].astype(np.int32)
        nbrs[i, : len(js)] = js
    return Graph(n=n, neighbors=nbrs, degrees=degs, family=family)


def is_connected_adj(a: np.ndarray) -> bool:
    """BFS connectivity check on a dense adjacency matrix."""
    n = a.shape[0]
    seen = np.zeros(n, dtype=bool)
    frontier = np.zeros(n, dtype=bool)
    frontier[0] = True
    seen[0] = True
    while frontier.any():
        nxt = (a[frontier].any(0)) & ~seen
        seen |= nxt
        frontier = nxt
    return bool(seen.all())


def random_regular_graph(n: int, d: int, seed: int = 0) -> Graph:
    """Random d-regular graph via greedy stub matching with restarts.

    Plain configuration-model rejection has acceptance ~ e^{-(d^2-1)/4}
    (hopeless for d = 8), so we instead match stubs greedily, rejecting
    self-loops/multi-edges locally, and restart on dead ends — the same
    strategy networkx uses. Connectivity is checked at the end (a random
    d >= 3 regular graph is connected w.h.p.).
    """
    if n * d % 2 != 0:
        raise ValueError("n*d must be even")
    if d >= n:
        raise ValueError("d must be < n")
    rng = np.random.default_rng(seed)
    for _attempt in range(200):
        a = _greedy_regular_pairing(n, d, rng)
        if a is None:
            continue
        if is_connected_adj(a):
            return _adj_to_graph(a, "regular")
    raise RuntimeError("failed to sample a simple connected regular graph")


def _greedy_regular_pairing(n: int, d: int, rng) -> np.ndarray | None:
    stubs = np.repeat(np.arange(n), d)
    rng.shuffle(stubs)
    stubs = stubs.tolist()
    a = np.zeros((n, n), dtype=bool)
    while stubs:
        u = stubs.pop()
        # try a bounded number of random partners for u
        found = False
        for _ in range(60):
            j = int(rng.integers(len(stubs))) if stubs else -1
            if j < 0:
                break
            v = stubs[j]
            if v != u and not a[u, v]:
                stubs[j] = stubs[-1]
                stubs.pop()
                a[u, v] = a[v, u] = True
                found = True
                break
        if not found:
            return None  # dead end: restart with a fresh shuffle
    return a


def erdos_renyi_graph(n: int, p: float | None = None, seed: int = 0) -> Graph:
    """Connected Erdos-Renyi G(n, p); defaults to p = 2 ln n / n."""
    if p is None:
        p = min(1.0, 2.0 * np.log(n) / n)
    rng = np.random.default_rng(seed)
    for _attempt in range(1000):
        a = rng.random((n, n)) < p
        a = np.triu(a, 1)
        a = a | a.T
        if is_connected_adj(a):
            return _adj_to_graph(a, "erdos_renyi")
    raise RuntimeError("failed to sample connected ER graph; increase p")


def complete_graph(n: int) -> Graph:
    a = ~np.eye(n, dtype=bool)
    return _adj_to_graph(a, "complete")


def power_law_graph(n: int, m: int = 3, seed: int = 0) -> Graph:
    """Barabasi-Albert preferential attachment (power-law degrees)."""
    if m < 1 or m >= n:
        raise ValueError("need 1 <= m < n")
    rng = np.random.default_rng(seed)
    a = np.zeros((n, n), dtype=bool)
    # seed clique of m+1 nodes
    for i in range(m + 1):
        for j in range(i + 1, m + 1):
            a[i, j] = a[j, i] = True
    targets_pool = list(range(m + 1)) * m
    for v in range(m + 1, n):
        chosen: set[int] = set()
        while len(chosen) < m:
            chosen.add(int(targets_pool[rng.integers(len(targets_pool))]))
        for u in chosen:
            a[u, v] = a[v, u] = True
            targets_pool.append(u)
        targets_pool.extend([v] * m)
    assert is_connected_adj(a)
    return _adj_to_graph(a, "power_law")


def community_graph(
    n: int,
    k_bridges: int = 2,
    p_in: float | None = None,
    seed: int = 0,
) -> Graph:
    """Two ER communities joined by ``k_bridges`` random bridge edges.

    Nodes ``[0, n//2)`` form one community, ``[n//2, n)`` the other —
    the id boundary ``n//2`` is exactly the threshold the zoo's
    ``edge_cut`` attack severs, so cutting there isolates the halves.
    Each half is a connected G(n/2, p_in) (default ``p_in = 3 ln(n/2) /
    (n/2)``); bridges pair uniformly random endpoints across the halves
    (deduplicated, so the bridge count is exactly ``k_bridges``).
    """
    if n < 4:
        raise ValueError("community graph needs n >= 4")
    if k_bridges < 1:
        raise ValueError("need k_bridges >= 1 (else the graph disconnects)")
    h = n // 2
    sizes = (h, n - h)
    if p_in is None:
        p_in = min(1.0, 3.0 * np.log(max(sizes)) / min(sizes))
    rng = np.random.default_rng(seed)
    a = np.zeros((n, n), dtype=bool)
    for lo, size in ((0, sizes[0]), (h, sizes[1])):
        for _attempt in range(1000):
            block = rng.random((size, size)) < p_in
            block = np.triu(block, 1)
            block = block | block.T
            if is_connected_adj(block):
                a[lo : lo + size, lo : lo + size] = block
                break
        else:
            raise RuntimeError(
                "failed to sample a connected community; increase p_in"
            )
    bridges: set = set()
    while len(bridges) < k_bridges:
        u = int(rng.integers(0, h))
        v = int(rng.integers(h, n))
        bridges.add((u, v))
    for u, v in sorted(bridges):
        a[u, v] = a[v, u] = True
    return _adj_to_graph(a, "community")


def ring_graph(n: int) -> Graph:
    a = np.zeros((n, n), dtype=bool)
    idx = np.arange(n)
    a[idx, (idx + 1) % n] = True
    a[(idx + 1) % n, idx] = True
    return _adj_to_graph(a, "ring")


def torus_graph(rows: int, cols: int) -> Graph:
    n = rows * cols
    a = np.zeros((n, n), dtype=bool)
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            for dr, dc in ((1, 0), (0, 1)):
                j = ((r + dr) % rows) * cols + (c + dc) % cols
                a[i, j] = a[j, i] = True
    return _adj_to_graph(a, "torus")


GRAPH_FAMILIES: Dict[str, Callable[..., Graph]] = {
    "regular": random_regular_graph,
    "erdos_renyi": erdos_renyi_graph,
    "complete": complete_graph,
    "power_law": power_law_graph,
    "community": community_graph,
    "ring": ring_graph,
    "torus": torus_graph,
}


def make_graph(family: str, n: int, seed: int = 0, **kwargs) -> Graph:
    """Uniform constructor used by configs/benchmarks."""
    if family == "regular":
        return random_regular_graph(n, kwargs.get("degree", 8), seed)
    if family == "erdos_renyi":
        return erdos_renyi_graph(n, kwargs.get("p"), seed)
    if family == "complete":
        return complete_graph(n)
    if family == "power_law":
        return power_law_graph(n, kwargs.get("m", 3), seed)
    if family == "community":
        return community_graph(
            n, kwargs.get("k_bridges", 2), kwargs.get("p_in"), seed
        )
    if family == "ring":
        return ring_graph(n)
    if family == "torus":
        return torus_graph(kwargs.get("rows", 8), kwargs.get("cols", max(1, n // 8)))
    raise KeyError(f"unknown graph family {family!r}")
