"""Dynamic topology as traced runtime state.

The paper's opening premise is that random walks "can fail due to node or
link failures" — which requires the *graph itself* to be mutable at run
time, not a constant frozen into the compiled program. ``GraphState``
carries the live topology as two boolean masks over the static padded
adjacency of a :class:`repro.graphs.generators.Graph`:

  node_up : (n,) bool        — node i is operational
  edge_up : (n, max_deg) bool — directed slot (i, k), i.e. the edge from i
                                to ``neighbors[i, k]``, is operational

Both leaves are jax arrays threaded through the simulator's ``lax.scan``
carry, so crashes persist across steps, recoveries are stochastic events,
and every knob that drives them lives in ``FailureConfig`` as a traced
(vmap-batchable) leaf. The static ``Graph`` remains the superset topology:
dynamic state can only *mask* edges, never add them.

Undirected edges appear in two slots — (i, k) and its mirror (j, k') with
``neighbors[j, k'] == i``. ``mirror_indices`` precomputes that involution
(numpy, trace-time) so link-failure sampling can draw one uniform per
undirected edge and keep the two directed slots in lockstep.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.generators import Graph


class GraphState(NamedTuple):
    """Live topology masks; all-True == the static graph (the no-op state)."""

    node_up: jax.Array  # (n,) bool
    edge_up: jax.Array  # (n, max_deg) bool, aligned with Graph.neighbors


def init_graph_state(n: int, max_deg: int) -> GraphState:
    """Fully-operational topology (every mask True)."""
    return GraphState(
        node_up=jnp.ones((n,), bool),
        edge_up=jnp.ones((n, max_deg), bool),
    )


def mirror_indices(graph: Graph) -> np.ndarray:
    """(n, max_deg) int32 M with ``neighbors[neighbors[i,k], M[i,k]] == i``.

    Padded slots (k >= degrees[i]) map to themselves — harmless because
    availability masks them out before any sampling. O(n * max_deg) via a
    sort over directed-edge keys; memoized on the (immutable) graph since
    every run_* call needs it.
    """
    cached = getattr(graph, "_mirror_cache", None)
    if cached is not None:
        return cached
    nbrs = np.asarray(graph.neighbors)
    degs = np.asarray(graph.degrees)
    n, D = nbrs.shape
    src = np.repeat(np.arange(n, dtype=np.int64), D).reshape(n, D)
    # directed-edge keys are unique (simple graph, no self loops except
    # padding, and padding keys i*n+i are overwritten below anyway)
    fwd = src * n + nbrs  # key of slot (i, k): edge i -> j
    rev = nbrs.astype(np.int64) * n + src  # key of the mirrored slot j -> i
    order = np.argsort(fwd.ravel(), kind="stable")
    pos = np.searchsorted(fwd.ravel()[order], rev.ravel())
    mirror = (order[np.clip(pos, 0, n * D - 1)] % D).astype(np.int32).reshape(n, D)
    pad = np.arange(D, dtype=np.int32)[None, :] >= degs[:, None]
    mirror[pad] = np.broadcast_to(np.arange(D, dtype=np.int32), (n, D))[pad]
    object.__setattr__(graph, "_mirror_cache", mirror)  # frozen dataclass
    return mirror


def availability_rows(
    edge_up_rows: jax.Array,  # (rows, max_deg) edge masks for these rows
    node_up_rows: jax.Array,  # (rows,) liveness of the rows' own nodes
    node_up_full: jax.Array,  # (n,) global liveness (neighbor lookup)
    neighbors_rows: jax.Array,  # (rows, max_deg)
    degrees_rows: jax.Array,  # (rows,)
) -> jax.Array:
    """The traversability invariant on an arbitrary row slice: slot
    (r, k) is available iff it exists in the static graph (k < degree),
    the edge is up, and both endpoints are up. Rows and the global node
    vector are passed separately so a node-sharded caller (the shard_map
    step in ``core.distributed``, whose neighbor ids cross shards) shares
    this single definition with the full-graph ``availability``.
    """
    D = neighbors_rows.shape[1]
    within = (
        jnp.arange(D, dtype=degrees_rows.dtype)[None, :]
        < degrees_rows[:, None]
    )
    return (
        within
        & edge_up_rows
        & node_up_rows[:, None]
        & node_up_full[neighbors_rows]
    )


def availability(
    gs: GraphState, neighbors: jax.Array, degrees: jax.Array
) -> jax.Array:
    """(n, max_deg) bool: slot (i, k) is traversable right now. With a
    fully-up ``GraphState`` this is exactly the static within-degree mask.
    """
    return availability_rows(
        gs.edge_up, gs.node_up, gs.node_up, neighbors, degrees
    )
