from repro.graphs.generators import (
    Graph,
    complete_graph,
    erdos_renyi_graph,
    power_law_graph,
    random_regular_graph,
    ring_graph,
    torus_graph,
    make_graph,
    GRAPH_FAMILIES,
)
from repro.graphs.state import (
    GraphState,
    availability,
    init_graph_state,
    mirror_indices,
)
from repro.graphs.spectral import (
    stationary_distribution,
    expected_return_times,
    return_rate_estimate,
    arrival_rate_estimate,
    spectral_gap,
    mixing_time_bound,
    cover_time_estimate,
)

__all__ = [
    "Graph",
    "GraphState",
    "availability",
    "init_graph_state",
    "mirror_indices",
    "complete_graph",
    "erdos_renyi_graph",
    "power_law_graph",
    "random_regular_graph",
    "ring_graph",
    "torus_graph",
    "make_graph",
    "GRAPH_FAMILIES",
    "stationary_distribution",
    "expected_return_times",
    "return_rate_estimate",
    "arrival_rate_estimate",
    "spectral_gap",
    "mixing_time_bound",
    "cover_time_estimate",
]
