"""Deterministic PRNG helpers.

Every stochastic component (walk movement, failure injection, fork coin
flips) folds the global step counter into its key so that simulations are
bit-reproducible regardless of how the step loop is structured.
"""
from __future__ import annotations

import jax


def fold_in_time(key: jax.Array, t, tag: int = 0) -> jax.Array:
    """Fold step counter (and a component tag) into a key."""
    key = jax.random.fold_in(key, tag)
    return jax.random.fold_in(key, t)


def split_like(key: jax.Array, n: int):
    return jax.random.split(key, n)
