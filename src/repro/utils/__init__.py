from repro.utils.tree import (
    tree_bytes,
    tree_count,
    tree_flatten_with_paths,
    tree_zeros_like,
)
from repro.utils.prng import fold_in_time, split_like

__all__ = [
    "tree_bytes",
    "tree_count",
    "tree_flatten_with_paths",
    "tree_zeros_like",
    "fold_in_time",
    "split_like",
]
