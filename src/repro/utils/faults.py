"""Deterministic host-level fault injection (the chaos harness).

The simulator's *walks* already survive arbitrary node/link failures by
construction; this module gives the *host* stack — result store IO,
service worker loop, segment checkpoints — the same systematically
exercised failure surface. A :class:`FaultPlan` scripts exactly which
named **site** fails on which invocation and how, so every chaos test is
deterministic and replayable:

    plan = FaultPlan().at("service.run_group", Raise(TransientFault("x")))
    with plan.active():
        ...   # the first _run_group attempt raises; the retry proceeds

Sites are plain strings compiled into the host code via
:func:`fault_point` calls — a no-op (one dict lookup on an inactive
module global) outside chaos tests. The instrumented sites:

  ``checkpoint.write``   inside ``checkpoint._atomic_write``, before the
                         temp file is published (tearable: a :class:`Torn`
                         action leaves a truncated file at the FINAL path,
                         simulating a pre-atomic torn write, then kills);
  ``store.get``          entry of ``ResultStore.get``;
  ``store.put``          entry of ``ResultStore.put``;
  ``service.run_group``  entry of every ``ExperimentService`` group
                         attempt (initial, retry, and per-member split
                         re-runs all pass through it);
  ``segment.boundary``   after each completed segment of a segmented run
                         (snapshot already written — a :class:`Kill` here
                         is "the process died between segments").

Failure vocabulary:

  :class:`TransientFault`   an injected error the service's default
                            retry predicate classifies as retryable;
  :class:`PermanentFault`   never retried — exercises clean per-future
                            error delivery and group splitting;
  :class:`SimulatedKill`    "the process died HERE". Deliberately a
                            ``BaseException`` so no best-effort
                            ``except Exception`` recovery path can
                            swallow it — exactly like a real SIGKILL.

Actions: :class:`Raise`, :class:`Delay`, :class:`Kill`, :class:`Torn`.
Each site holds a FIFO of actions; every :func:`fault_point` hit pops
one (``None`` entries are explicit no-ops, for targeting the k-th
invocation). ``plan.hits`` counts every site hit and ``plan.fired``
records what actually fired, so tests can assert coverage.

Activation is a module-level global (NOT thread-local): the
ExperimentService worker runs on its own thread and must see the plan
the test activated.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from contextlib import contextmanager

__all__ = [
    "FaultPlan",
    "fault_point",
    "Raise",
    "Delay",
    "Kill",
    "Torn",
    "FaultError",
    "TransientFault",
    "PermanentFault",
    "SimulatedKill",
    "SITES",
]

SITES = (
    "checkpoint.write",
    "store.get",
    "store.put",
    "service.run_group",
    "segment.boundary",
)


class FaultError(Exception):
    """Base class of injected exceptions."""


class TransientFault(FaultError):
    """An injected error the default service retry predicate retries."""


class PermanentFault(FaultError):
    """An injected error that must fail cleanly, never retry."""


class SimulatedKill(BaseException):
    """The process 'died' at a kill point.

    A ``BaseException`` on purpose: recovery code is allowed to swallow
    ``Exception`` (best-effort IO, retries) but a kill must unwind the
    whole host stack, exactly like the real thing.
    """

    def __init__(self, site: str):
        super().__init__(f"simulated process kill at fault site {site!r}")
        self.site = site


class Raise:
    """Raise ``exc`` (an instance, or a zero-arg factory/class)."""

    def __init__(self, exc):
        self.exc = exc

    def fire(self, site: str):
        exc = self.exc() if callable(self.exc) else self.exc
        raise exc

    def __repr__(self):
        return f"Raise({self.exc!r})"


class Delay:
    """Sleep ``seconds`` (slow IO / scheduler stall), then continue."""

    def __init__(self, seconds: float):
        self.seconds = float(seconds)

    def fire(self, site: str):
        time.sleep(self.seconds)

    def __repr__(self):
        return f"Delay({self.seconds})"


class Kill:
    """Raise :class:`SimulatedKill` — the process dies at this site."""

    def fire(self, site: str):
        raise SimulatedKill(site)

    def __repr__(self):
        return "Kill()"


class Torn:
    """Tear the write at a tearable site, then die.

    Only honored where :func:`fault_point` is called with
    ``tearable=True`` (``checkpoint.write``): the writer publishes the
    first ``keep_bytes`` of the payload at the FINAL path — the
    half-written file a pre-atomic writer leaves behind — and then
    raises :class:`SimulatedKill`. Recovery code must treat the torn
    file as absent/corrupt, never as data.
    """

    def __init__(self, keep_bytes: int = 24):
        self.keep_bytes = int(keep_bytes)

    def __repr__(self):
        return f"Torn(keep_bytes={self.keep_bytes})"


class FaultPlan:
    """A deterministic per-site schedule of fault actions (module docstring).

    ``at(site, *actions)`` appends actions to the site's FIFO; each
    :func:`fault_point` hit pops one (missing/None == no-op). Use
    ``plan.skip(site, k)`` to let the first k invocations through.
    """

    def __init__(self):
        self._sites: dict = {}
        self._lock = threading.Lock()
        self.hits: dict = {}
        self.fired: list = []

    def at(self, site: str, *actions) -> "FaultPlan":
        self._sites.setdefault(site, deque()).extend(actions)
        return self

    def skip(self, site: str, k: int = 1) -> "FaultPlan":
        """Append k explicit no-ops (target a later invocation)."""
        return self.at(site, *([None] * k))

    def pending(self, site: str) -> int:
        """Actions not yet consumed at ``site`` (0 == site is drained)."""
        return len(self._sites.get(site, ()))

    # -- firing (called from fault_point) ---------------------------------

    def _fire(self, site: str, tearable: bool):
        with self._lock:
            self.hits[site] = self.hits.get(site, 0) + 1
            queue = self._sites.get(site)
            action = queue.popleft() if queue else None
            if action is not None:
                self.fired.append((site, action))
        if action is None:
            return None
        if isinstance(action, Torn):
            if not tearable:
                raise RuntimeError(
                    f"Torn action scheduled at non-tearable site {site!r}"
                )
            return action  # the writer implements the tear + kill
        action.fire(site)
        return None

    @contextmanager
    def active(self):
        """Activate this plan process-wide for the duration of the block."""
        global _ACTIVE
        prev = _ACTIVE
        _ACTIVE = self
        try:
            yield self
        finally:
            _ACTIVE = prev

    def __repr__(self):
        sched = {s: list(q) for s, q in self._sites.items() if q}
        return f"FaultPlan(pending={sched}, hits={self.hits})"


_ACTIVE: FaultPlan | None = None


def fault_point(site: str, *, tearable: bool = False):
    """The instrumentation hook host code compiles in at a named site.

    No-op (returns None) unless a :class:`FaultPlan` is active. With an
    active plan: counts the hit, pops the site's next action and performs
    it — raising for :class:`Raise`/:class:`Kill`, sleeping for
    :class:`Delay`. A :class:`Torn` action is *returned* to the caller
    (only at ``tearable=True`` sites), which must tear its own write and
    raise :class:`SimulatedKill`.
    """
    plan = _ACTIVE
    if plan is None:
        return None
    return plan._fire(site, tearable)
