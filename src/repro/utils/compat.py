"""jax version compatibility shims.

Policy (ROADMAP "Open items" / this PR): the repo targets the newest jax
API surface but must run on the baked-in toolchain (jax 0.4.37 today).
Anything newer-than-installed is adapted here — import the symbol from
``repro.utils.compat`` instead of sprinkling try/excepts per module:

  - ``AxisType``        : ``jax.sharding.AxisType`` (added ~0.5); stubbed
                          with the same member names on older jax.
  - ``make_mesh``       : ``jax.make_mesh`` accepting ``axis_types``; the
                          kwarg is dropped when the installed jax predates
                          it (mesh semantics are equivalent for Auto axes).
  - ``shard_map``       : ``jax.shard_map`` (top-level export added ~0.6),
                          falling back to ``jax.experimental.shard_map``;
                          accepts ``check_vma`` and translates it to the
                          legacy ``check_rep`` kwarg when needed.
"""
from __future__ import annotations

import enum
import inspect
from typing import Any

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType  # type: ignore[attr-defined]

    HAS_AXIS_TYPE = True
except ImportError:  # jax 0.4.x
    class AxisType(enum.Enum):  # type: ignore[no-redef]
        """Stand-in for jax.sharding.AxisType (all meshes behave as Auto)."""

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    HAS_AXIS_TYPE = False


_MAKE_MESH_TAKES_AXIS_TYPES = (
    "axis_types" in inspect.signature(jax.make_mesh).parameters
)


def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kwargs):
    """``jax.make_mesh`` that tolerates ``axis_types`` on every jax version."""
    if axis_types is not None and _MAKE_MESH_TAKES_AXIS_TYPES:
        kwargs["axis_types"] = axis_types
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


def abstract_mesh(axis_shapes, axis_names):
    """``jax.sharding.AbstractMesh`` across signature generations.

    Newer jax takes ``(axis_shapes, axis_names)`` like ``make_mesh``; jax
    0.4.x takes one ``((name, size), ...)`` tuple.
    """
    AbstractMesh = jax.sharding.AbstractMesh
    try:
        return AbstractMesh(tuple(axis_shapes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_shapes)))


if hasattr(jax, "shard_map"):  # jax >= 0.6
    _shard_map_impl = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_SHARD_MAP_TAKES_CHECK_VMA = (
    "check_vma" in inspect.signature(_shard_map_impl).parameters
)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None, **kwargs: Any):
    """``shard_map`` accepting the modern ``check_vma`` kwarg everywhere.

    Older jax calls the same knob ``check_rep``; semantics are identical
    for our usage (disable replication/vma checking).
    """
    if check_vma is not None:
        if _SHARD_MAP_TAKES_CHECK_VMA:
            kwargs["check_vma"] = check_vma
        else:
            kwargs["check_rep"] = check_vma
    return _shard_map_impl(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
    )
