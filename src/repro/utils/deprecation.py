"""Deprecation machinery for the legacy run_* surface (PR 5).

The four historical runners (``run_simulation`` / ``run_ensemble`` /
``run_sweep`` / ``repro.sweep.run_scenarios``) survive as thin shims over
the declarative ``repro.api`` surface. They warn with
:class:`APIDeprecationWarning` — a *distinct* class so the test suite can
promote exactly our own deprecations to errors (registered in
``tests/conftest.py``) without tripping on third-party warnings. It
derives from ``FutureWarning``, not ``DeprecationWarning``: Python's
default filters show DeprecationWarning only in ``__main__``, which would
silence the migration notice for exactly the audience it exists for —
downstream *library* callers. In-repo code (library, tests, benchmarks,
examples) must not call the shims; external callers get one visible
warning per call site per session.
"""
from __future__ import annotations

import warnings

__all__ = ["APIDeprecationWarning", "warn_legacy_runner"]


class APIDeprecationWarning(FutureWarning):
    """A repro-owned deprecation: legacy runner called instead of
    ``repro.api.Experiment``. Promoted to an error in the repo's own
    test lanes; a visible-by-default warning for external callers
    (FutureWarning base — see module docstring)."""


def warn_legacy_runner(old: str, new: str) -> None:
    """Warn that ``old`` is a deprecation shim; point at the ``repro.api``
    replacement. ``stacklevel=3`` lands the warning on the caller of the
    shim, not the shim itself."""
    warnings.warn(
        f"{old} is deprecated; use {new} — see the migration table in "
        "README.md (repro.api: spec -> compiled Plan -> results)",
        APIDeprecationWarning,
        stacklevel=3,
    )
