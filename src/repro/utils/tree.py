"""Small pytree utilities used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_count(tree) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes of a pytree (dtype-aware)."""
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_flatten_with_paths(tree):
    """Return [(path_string, leaf)] for a pytree, '/'-joined key paths."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        keys = []
        for p in path:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
            elif hasattr(p, "name"):
                keys.append(str(p.name))
            else:
                keys.append(str(p))
        out.append(("/".join(keys), leaf))
    return out
