"""musicgen-large [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284]. 4 codebooks, 2048 entries each; the EnCodec conv
frontend is a stub (token ids in, per-codebook heads out)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", arch_type="audio",
    num_layers=48, d_model=2048, d_ff=8192, vocab_size=2048,
    num_heads=32, num_kv_heads=32, head_dim=64,
    num_codebooks=4,
)

SMOKE = ModelConfig(
    name="musicgen-large-smoke", arch_type="audio",
    num_layers=2, d_model=256, d_ff=512, vocab_size=128,
    num_heads=8, num_kv_heads=8, head_dim=32,
    num_codebooks=4,
    dtype="float32",
)
