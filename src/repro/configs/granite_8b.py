"""granite-8b [dense] — llama-arch, code [arXiv:2405.04324]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b", arch_type="dense",
    num_layers=36, d_model=4096, d_ff=14336, vocab_size=49152,
    num_heads=32, num_kv_heads=8, head_dim=128, rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="granite-8b-smoke", arch_type="dense",
    num_layers=2, d_model=192, d_ff=384, vocab_size=384,
    num_heads=6, num_kv_heads=2, head_dim=32,
    dtype="float32",
)
