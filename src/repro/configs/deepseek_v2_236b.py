"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed
top-6 experts [arXiv:2405.04434]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", arch_type="moe",
    num_layers=60, d_model=5120, d_ff=12288, vocab_size=102400,
    num_heads=128, num_kv_heads=128, head_dim=128,
    moe_num_experts=160, moe_top_k=6, moe_num_shared=2, moe_d_ff=1536,
    use_mla=True, kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
)

SMOKE = ModelConfig(
    name="deepseek-v2-236b-smoke", arch_type="moe",
    num_layers=2, d_model=256, d_ff=512, vocab_size=512,
    num_heads=4, num_kv_heads=4, head_dim=64,
    moe_num_experts=4, moe_top_k=2, moe_num_shared=1, moe_d_ff=128,
    use_mla=True, kv_lora_rank=64, q_lora_rank=96, rope_head_dim=32,
    dtype="float32",
)
