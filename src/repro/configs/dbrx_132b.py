"""dbrx-132b [moe] — 16 experts top-4, fine-grained
[hf:databricks/dbrx-base]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", arch_type="moe",
    num_layers=40, d_model=6144, d_ff=10752, vocab_size=100352,
    num_heads=48, num_kv_heads=8, head_dim=128, rope_theta=500000.0,
    moe_num_experts=16, moe_top_k=4, moe_d_ff=10752,
)

SMOKE = ModelConfig(
    name="dbrx-132b-smoke", arch_type="moe",
    num_layers=2, d_model=256, d_ff=512, vocab_size=512,
    num_heads=8, num_kv_heads=2, head_dim=32,
    moe_num_experts=4, moe_top_k=2, moe_d_ff=128,
    dtype="float32",
)
