"""Architecture registry: the 10 assigned architectures + the paper's own
decentralized-learning payload config.

Every entry cites its source; ``get_config(name)`` returns the full-size
ModelConfig, ``get_smoke_config(name)`` a reduced same-family variant
(<= 2 layers, d_model <= 512, <= 4 experts) for CPU smoke tests.
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCH_IDS = (
    "llama3_405b",
    "yi_6b",
    "granite_8b",
    "deepseek_67b",
    "hymba_1_5b",
    "musicgen_large",
    "qwen2_vl_2b",
    "mamba2_1_3b",
    "deepseek_v2_236b",
    "dbrx_132b",
)

_ALIASES = {
    "llama3-405b": "llama3_405b",
    "yi-6b": "yi_6b",
    "granite-8b": "granite_8b",
    "deepseek-67b": "deepseek_67b",
    "hymba-1.5b": "hymba_1_5b",
    "musicgen-large": "musicgen_large",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "mamba2-1.3b": "mamba2_1_3b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "dbrx-132b": "dbrx_132b",
}


def canonical(name: str) -> str:
    return _ALIASES.get(name, name)


def get_config(name: str, **overrides) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    cfg: ModelConfig = mod.CONFIG
    if overrides:
        import dataclasses

        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def get_smoke_config(name: str, **overrides) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    cfg: ModelConfig = mod.SMOKE
    if overrides:
        import dataclasses

        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
