"""hymba-1.5b [hybrid] — parallel attn+mamba heads [arXiv:2411.13676]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", arch_type="hybrid",
    num_layers=32, d_model=1600, d_ff=5504, vocab_size=32001,
    num_heads=25, num_kv_heads=5, head_dim=64,
    ssm_state=16, ssm_conv=4, ssm_expand=2, ssm_head_dim=64,
)

SMOKE = ModelConfig(
    name="hymba-1.5b-smoke", arch_type="hybrid",
    num_layers=2, d_model=256, d_ff=512, vocab_size=512,
    num_heads=4, num_kv_heads=2, head_dim=64,
    ssm_state=16, ssm_conv=4, ssm_expand=2, ssm_head_dim=64,
    dtype="float32",
)
