"""The paper's own payload: a small decoder LM trained by RW-SGD on a
graph of data-holding nodes (Section I motivating example). Sized so ten
model replicas (walks) fit a single host for the end-to-end example."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paper-rwsgd", arch_type="dense",
    num_layers=4, d_model=256, d_ff=1024, vocab_size=4096,
    num_heads=8, num_kv_heads=4, head_dim=32,
    dtype="float32",
)

SMOKE = ModelConfig(
    name="paper-rwsgd-smoke", arch_type="dense",
    num_layers=2, d_model=128, d_ff=256, vocab_size=512,
    num_heads=4, num_kv_heads=2, head_dim=32,
    dtype="float32",
)
