"""yi-6b [dense] — llama-arch GQA [arXiv:2403.04652]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b", arch_type="dense",
    num_layers=32, d_model=4096, d_ff=11008, vocab_size=64000,
    num_heads=32, num_kv_heads=4, head_dim=128, rope_theta=5000000.0,
)

SMOKE = ModelConfig(
    name="yi-6b-smoke", arch_type="dense",
    num_layers=2, d_model=256, d_ff=512, vocab_size=512,
    num_heads=8, num_kv_heads=1, head_dim=32, rope_theta=5000000.0,
    dtype="float32",
)
