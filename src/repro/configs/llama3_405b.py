"""llama3-405b [dense] — GQA, 128k vocab [arXiv:2407.21783]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", arch_type="dense",
    num_layers=126, d_model=16384, d_ff=53248, vocab_size=128256,
    num_heads=128, num_kv_heads=8, head_dim=128, rope_theta=500000.0,
)

SMOKE = ModelConfig(
    name="llama3-405b-smoke", arch_type="dense",
    num_layers=2, d_model=256, d_ff=512, vocab_size=512,
    num_heads=8, num_kv_heads=2, head_dim=32, rope_theta=500000.0,
    dtype="float32",
)
