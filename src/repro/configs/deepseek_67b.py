"""deepseek-67b [dense] — llama-arch [arXiv:2401.02954]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b", arch_type="dense",
    num_layers=95, d_model=8192, d_ff=22016, vocab_size=102400,
    num_heads=64, num_kv_heads=8, head_dim=128, rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="deepseek-67b-smoke", arch_type="dense",
    num_layers=2, d_model=256, d_ff=640, vocab_size=512,
    num_heads=8, num_kv_heads=2, head_dim=32,
    dtype="float32",
)
