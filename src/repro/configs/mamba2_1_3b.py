"""mamba2-1.3b [ssm] — SSD (state-space duality) [arXiv:2405.21060].
Attention-free: 48 mamba2 blocks, d_state=128, headdim=64."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", arch_type="ssm",
    num_layers=48, d_model=2048, d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_conv=4, ssm_expand=2, ssm_head_dim=64,
)

SMOKE = ModelConfig(
    name="mamba2-1.3b-smoke", arch_type="ssm",
    num_layers=2, d_model=256, d_ff=0, vocab_size=512,
    ssm_state=32, ssm_conv=4, ssm_expand=2, ssm_head_dim=64,
    dtype="float32",
)
