"""Assigned input shapes and per-(arch, shape) adjustments.

  train_4k     seq_len=4096    global_batch=256   -> train_step
  prefill_32k  seq_len=32768   global_batch=32    -> prefill
  decode_32k   seq_len=32768   global_batch=128   -> serve_step (1 token,
                                                     KV cache of seq_len)
  long_500k    seq_len=524288  global_batch=1     -> serve_step; requires
               sub-quadratic attention: SSM/hybrid run natively, all other
               archs switch to the sliding-window KV-ring variant
               (window 8192) implemented for exactly this shape.
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

LONG_CONTEXT_WINDOW = 8192


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def adjust_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Per-shape config adjustments (documented in DESIGN.md §4)."""
    updates = {}
    if shape.name == "long_500k":
        # sub-quadratic requirement: bounded attention state.
        # SSM is already O(1); hybrid + all attention archs get the
        # sliding-window ring-buffer cache.
        if cfg.arch_type != "ssm" and cfg.sliding_window == 0:
            updates["sliding_window"] = LONG_CONTEXT_WINDOW
        if cfg.use_mla:
            # MLA's compressed cache is small but decompression cost is
            # O(T); the ring buffer bounds T as for vanilla attention.
            updates["sliding_window"] = LONG_CONTEXT_WINDOW
    if shape.mode == "train":
        updates["remat"] = True
    return dataclasses.replace(cfg, **updates) if updates else cfg
