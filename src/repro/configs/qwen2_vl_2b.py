"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191].
ViT frontend is a stub: input_specs() provides precomputed patch
embeddings; the decoder applies M-RoPE over (t, h, w) streams."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", arch_type="vlm",
    num_layers=28, d_model=1536, d_ff=8960, vocab_size=151936,
    num_heads=12, num_kv_heads=2, head_dim=128, rope_theta=1000000.0,
    mrope=True, mrope_sections=(16, 24, 24), vision_tokens=1024,
)

SMOKE = ModelConfig(
    name="qwen2-vl-2b-smoke", arch_type="vlm",
    num_layers=2, d_model=256, d_ff=512, vocab_size=512,
    num_heads=4, num_kv_heads=2, head_dim=64,
    mrope=True, mrope_sections=(8, 12, 12), vision_tokens=16,
    dtype="float32",
)
