"""Pallas TPU kernel for the Mamba-2 SSD intra-chunk block.

The SSD algorithm splits the selective-scan into (i) a quadratic
intra-chunk part — two small matmuls plus an elementwise decay mask, MXU
food — and (ii) a tiny inter-chunk linear recurrence. This kernel
computes (i) plus each chunk's outgoing state; the recurrence and the
cross-chunk correction stay in jnp (log-depth associative scan over
(B, nc, H, P, N) states — bandwidth-trivial).

Grid: (batch, chunks). VMEM per program holds one chunk:
  x (Q, H, P) dt-weighted inputs, da_cs (Q, H), B/C (Q, N), plus the
  (Q, Q, H) decay tensor — Q=128, H<=8-per-shard, P=64, N=128 keeps the
  footprint ~1.5 MiB. Heads beyond the VMEM budget split over the grid in
  ops.py by folding H into the batch axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dacs_ref, b_ref, c_ref, y_ref, st_ref):
    x = x_ref[0].astype(jnp.float32)  # (Q, H, P)
    da = dacs_ref[0].astype(jnp.float32)  # (Q, H)
    b_in = b_ref[0].astype(jnp.float32)  # (Q, N)
    c_in = c_ref[0].astype(jnp.float32)  # (Q, N)
    Q, H, P = x.shape

    diff = da[:, None, :] - da[None, :, :]  # (Q, Q, H)
    qi = jax.lax.broadcasted_iota(jnp.int32, (Q, Q, H), 0)
    ti = jax.lax.broadcasted_iota(jnp.int32, (Q, Q, H), 1)
    decay = jnp.exp(jnp.where(ti <= qi, diff, -1e30))  # (Q, Q, H)

    scores = c_in @ b_in.T  # (Q, Q) MXU
    w = scores[:, :, None] * decay  # (Q, Q, H)
    # y[q,h,p] = sum_t w[q,t,h] x[t,h,p]
    y = jnp.einsum("qth,thp->qhp", w, x)

    da_total = da[-1:, :]  # (1, H)
    decay_out = jnp.exp(da_total - da)  # (Q, H)
    xw = x * decay_out[:, :, None]  # (Q, H, P)
    # state[h,p,n] = sum_t b[t,n] xw[t,h,p]
    st = jnp.einsum("tn,thp->hpn", b_in, xw)

    y_ref[0] = y.astype(y_ref.dtype)
    st_ref[0] = st.astype(st_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk(
    x: jax.Array,  # (B, nc, Q, H, P) dt-weighted inputs
    da_cs: jax.Array,  # (B, nc, Q, H) in-chunk cumulative log-decay
    b_in: jax.Array,  # (B, nc, Q, N)
    c_in: jax.Array,  # (B, nc, Q, N)
    *,
    interpret: bool = True,
):
    """Returns (y_intra (B,nc,Q,H,P) f32, states (B,nc,H,P,N) f32)."""
    B, nc, Q, H, P = x.shape
    N = b_in.shape[-1]
    grid = (B, nc)
    y, st = pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, None, Q, H, P), lambda b, c: (b, c, 0, 0, 0)),
            pl.BlockSpec((1, None, Q, H), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, None, Q, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, None, Q, N), lambda b, c: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, None, Q, H, P), lambda b, c: (b, c, 0, 0, 0)),
            pl.BlockSpec((1, None, H, P, N), lambda b, c: (b, c, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nc, Q, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, nc, H, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(x, da_cs, b_in, c_in)
    return y, st
