"""Platform detection shared by the Pallas kernels and their wrappers.

Leaf module (no intra-package imports) so both ``kernels/ops.py`` and the
kernel modules themselves can use it without an import cycle.
"""
from __future__ import annotations

import jax


def default_interpret() -> bool:
    """Pallas ``interpret`` default: emulate on CPU, compile on TPU."""
    return jax.default_backend() != "tpu"
