"""Platform detection + small shared helpers for the Pallas kernels.

Leaf module (no intra-package imports) so both ``kernels/ops.py`` and the
kernel modules themselves can use it without an import cycle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def default_interpret() -> bool:
    """Pallas ``interpret`` default: emulate on CPU, compile on TPU."""
    return jax.default_backend() != "tpu"


def best_estimator_impl() -> str:
    """Best DECAFORK ``estimator_impl`` for the current backend.

    TPU: the fused round kernel (``kernels/round_update.py``) — one
    VMEM pass over node tiles, no full cumulative table, no gathers.
    CPU/GPU: the row-restricted gather path (``estimator.theta_hat_rows``)
    — gathers are cheap there and the per-round work is O(W*B), not
    O(n*W*B). ``ProtocolConfig(estimator_impl="auto")`` resolves through
    this at trace time.
    """
    return "fused" if jax.default_backend() == "tpu" else "gather"


def pad_node_axis(bn: int, last_seen, hist, total):
    """Pad the node axis up to a multiple of the tile ``bn`` with masked
    "no data" rows — ``last_seen = NEVER`` (-1), empty histograms, zero
    totals — that no walk can hit and whose theta sums are exactly 0.

    Shared by every node-tiled kernel so arbitrary graph sizes work;
    callers slice ``[:n]`` off the outputs. Returns the (possibly
    unchanged) arrays plus the pad count.
    """
    n = last_seen.shape[0]
    pad = (-n) % bn
    if pad:
        last_seen = jnp.concatenate(
            [last_seen, jnp.full((pad,) + last_seen.shape[1:], -1, last_seen.dtype)]
        )
        hist = jnp.concatenate(
            [hist, jnp.zeros((pad,) + hist.shape[1:], hist.dtype)]
        )
        total = jnp.concatenate([total, jnp.zeros((pad,), total.dtype)])
    return last_seen, hist, total, pad


def best_round_impl() -> str:
    """Implementation backing ``estimator_impl='fused'``: the Pallas
    kernel on TPU, the fused pure-jnp reference elsewhere (interpret-mode
    Pallas inside a long scan would be pure overhead on CPU)."""
    return "pallas" if jax.default_backend() == "tpu" else "ref"
