"""Platform detection + small shared helpers for the Pallas kernels.

Leaf module (no intra-package imports) so both ``kernels/ops.py`` and the
kernel modules themselves can use it without an import cycle.

Implementation resolution is layered: an explicit config value always
wins; ``"auto"`` resolves through the ``best_*`` helpers here, which
honor the ``REPRO_ESTIMATOR_IMPL`` / ``REPRO_ROUND_IMPL`` environment
variables (validated — an unknown value raises) before falling back to
the per-backend default. The env hooks let benchmarks, CI lanes, and bug
reproductions force an implementation without editing configs.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

ESTIMATOR_IMPLS = ("gather", "compare", "pallas", "fused")
ROUND_IMPLS = ("fused", "unfused")


def _env_impl(var: str, allowed: tuple) -> str | None:
    """Validated environment override: the value of ``var`` if set (must
    be one of ``allowed`` — anything else raises so typos can't silently
    run the wrong arm), else None."""
    val = os.environ.get(var)
    if val is None or val == "":
        return None
    if val not in allowed:
        raise ValueError(
            f"{var}={val!r} is not a valid override; expected one of {allowed}"
        )
    return val


def default_interpret() -> bool:
    """Pallas ``interpret`` default: emulate on CPU, compile on TPU."""
    return jax.default_backend() != "tpu"


def best_estimator_impl() -> str:
    """Best DECAFORK ``estimator_impl`` for the current backend.

    ``REPRO_ESTIMATOR_IMPL`` (if set, validated) wins. Otherwise — TPU:
    the fused observation kernel (``kernels/round_update.py``) — one
    VMEM pass over node tiles, no full cumulative table, no gathers.
    CPU/GPU: the row-restricted gather path (``estimator.theta_hat_rows``)
    — gathers are cheap there and the per-round work is O(W*B), not
    O(n*W*B). ``ProtocolConfig(estimator_impl="auto")`` resolves through
    this at trace time.
    """
    env = _env_impl("REPRO_ESTIMATOR_IMPL", ESTIMATOR_IMPLS)
    if env is not None:
        return env
    return "fused" if jax.default_backend() == "tpu" else "gather"


def best_round_impl() -> str:
    """Best whole-round implementation for the current backend.

    ``REPRO_ROUND_IMPL`` (if set, validated) wins. Otherwise ``"fused"``
    everywhere: the fused round is bitwise the unfused sequence by
    construction (golden tests enforce it) and strictly cheaper — on
    CPU it carries the cumulative return-time table incrementally
    (no per-round cumsum), on TPU it is the whole-round Pallas kernel.
    ``ProtocolConfig(round_impl="auto")`` resolves through this.
    """
    env = _env_impl("REPRO_ROUND_IMPL", ROUND_IMPLS)
    if env is not None:
        return env
    return "fused"


def fused_round_backend() -> str:
    """How ``round_impl='fused'`` executes: the whole-round Pallas kernel
    on TPU, the fused pure-jnp reference elsewhere (interpret-mode Pallas
    inside a long scan would be pure overhead on CPU)."""
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def pad_node_axis(bn: int, last_seen, hist, total):
    """Pad the node axis up to a multiple of the tile ``bn`` with masked
    "no data" rows — ``last_seen = NEVER`` (-1), empty histograms, zero
    totals — that no walk can hit and whose theta sums are exactly 0.

    Shared by every node-tiled kernel so arbitrary graph sizes work;
    callers slice ``[:n]`` off the outputs. Returns the (possibly
    unchanged) arrays plus the pad count.
    """
    n = last_seen.shape[0]
    pad = (-n) % bn
    if pad:
        last_seen = jnp.concatenate(
            [last_seen, jnp.full((pad,) + last_seen.shape[1:], -1, last_seen.dtype)]
        )
        hist = jnp.concatenate(
            [hist, jnp.zeros((pad,) + hist.shape[1:], hist.dtype)]
        )
        total = jnp.concatenate([total, jnp.zeros((pad,), total.dtype)])
    return last_seen, hist, total, pad


def best_round_update_impl() -> str:
    """Implementation backing ``estimator_impl='fused'`` (the PR-4
    observation-pipeline kernel): the Pallas kernel on TPU, the fused
    pure-jnp reference elsewhere (interpret-mode Pallas inside a long
    scan would be pure overhead on CPU)."""
    return "pallas" if jax.default_backend() == "tpu" else "ref"
