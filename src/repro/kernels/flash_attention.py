"""Pallas TPU flash attention (causal + sliding window, GQA).

Grid: (batch, q_head, q_blocks). Each program streams KV blocks for its
query tile with the online-softmax recurrence (running max m, normalizer
l, accumulator acc in f32), so the (S, S) score matrix never exists. KV
blocks strictly above the causal diagonal (or outside the sliding window)
contribute nothing; their contribution is masked. GQA is expressed in the
BlockSpec index maps: q head h reads kv head h // (H // KV) — no K/V
duplication in HBM or VMEM.

VMEM per program: q (qb, d) + k/v tiles (kb, d) + acc (qb, d) f32;
qb = kb = 128, d <= 256 -> well under 1 MiB.

Validated in interpret mode against ``ref.mha_ref`` over shape/dtype
sweeps (tests/test_kernels_attention.py); ``cfg.use_pallas`` switches the
model's attention to this kernel on real TPUs.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.platform import default_interpret

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, block_k, window, causal):
    qi = pl.program_id(2)
    q = q_ref[0, :, :].astype(jnp.float32) * scale  # (qb, d)
    qb, d = q.shape
    S = k_ref.shape[1]
    nk = S // block_k

    q_offset = qi * qb
    qpos = q_offset + jax.lax.broadcasted_iota(jnp.int32, (qb, block_k), 0)

    m = jnp.full((qb, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((qb, 1), jnp.float32)
    acc = jnp.zeros((qb, d), jnp.float32)

    for j in range(nk):
        k_blk = k_ref[0, j * block_k : (j + 1) * block_k, :].astype(jnp.float32)
        v_blk = v_ref[0, j * block_k : (j + 1) * block_k, :].astype(jnp.float32)
        s = q @ k_blk.T  # (qb, kb)
        kpos = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (qb, block_k), 1
        )
        mask = jnp.ones((qb, block_k), bool)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc = acc * alpha + p @ v_blk
        m = m_new

    o_ref[0, :, :] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "causal", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,  # (B, KV, S, D)
    v: jax.Array,  # (B, KV, S, D)
    *,
    window: int = 0,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = default_interpret()
    B, H, S, D = q.shape
    KV = k.shape[1]
    if H % KV:
        raise ValueError("H must be a multiple of KV")
    g = H // KV
    bq = min(block_q, S)
    bk = min(block_k, S)
    if S % bq or S % bk:
        raise ValueError("S must be a multiple of the block sizes")
    grid = (B, H, S // bq)
    scale = 1.0 / math.sqrt(D)
    kernel = functools.partial(
        _flash_kernel, scale=scale, block_k=bk, window=window, causal=causal
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, None, bq, D), lambda b, h, i: (b, h, i, 0)),
            # GQA: q head h reads kv head h // g; full-S KV stripe in VMEM
            pl.BlockSpec((1, None, S, D), lambda b, h, i: (b, h // g, 0, 0)),
            pl.BlockSpec((1, None, S, D), lambda b, h, i: (b, h // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, None, bq, D), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
