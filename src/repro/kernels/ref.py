"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def theta_sums_ref(
    last_seen: jax.Array,  # (n, W) int32, -1 = never seen
    hist: jax.Array,  # (n, B) f32 return-time histogram
    total: jax.Array,  # (n,) f32
    t: jax.Array,  # scalar int32
) -> jax.Array:
    """sum_c S_i(t - last_seen[i,c]) over seen columns, for every node.

    S_i(r) = 1 - cum_i(r)/total_i with cum_i(r) = #samples <= r;
    total_i = 0 -> S = 1 (optimistic prior), matching
    repro.core.estimator.survival_eval.
    """
    n, W = last_seen.shape
    B = hist.shape[1]
    valid = last_seen >= 0
    r = jnp.where(valid, t - last_seen, 0)  # (n, W)
    cum = jnp.concatenate(
        [jnp.zeros((n, 1), hist.dtype), jnp.cumsum(hist, axis=1)], axis=1
    )
    rc = jnp.clip(r, 0, B)
    mass = jnp.take_along_axis(cum, rc, axis=1)  # (n, W)
    tot = jnp.maximum(total, 1.0)[:, None]
    s = 1.0 - mass / tot
    s = jnp.where(total[:, None] > 0, s, 1.0)
    s = jnp.where(r <= 0, 1.0, s)
    return jnp.sum(jnp.where(valid, s, 0.0), axis=1)


def mha_ref(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, KV, D)
    v: jax.Array,  # (B, S, KV, D)
    window: int = 0,
    causal: bool = True,
) -> jax.Array:
    """Naive full-materialization GQA attention."""
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, D)
    scores = jnp.einsum(
        "bqkgd,btkd->bkgqt", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) / jnp.sqrt(jnp.float32(D))
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqt,btkd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, S, H, D).astype(q.dtype)


def ssd_chunk_ref(
    x: jax.Array,  # (B, Q, H, P) one chunk of dt-weighted inputs (x*dt)
    da_cs: jax.Array,  # (B, Q, H) in-chunk cumulative log-decay (negative)
    b_in: jax.Array,  # (B, Q, N)
    c_in: jax.Array,  # (B, Q, N)
):
    """Intra-chunk SSD: (y_intra (B,Q,H,P), state (B,H,P,N)).

    y_intra[q] = sum_{t<=q} (C_q . B_t) exp(da_cs[q]-da_cs[t]) x_t
    state     = sum_t B_t exp(da_total - da_cs[t]) x_t
    """
    Q = x.shape[1]
    diff = da_cs[:, :, None, :] - da_cs[:, None, :, :]  # (B,Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.exp(jnp.where(tri[None, :, :, None], diff, -1e30))
    scores = jnp.einsum("bqn,btn->bqt", c_in, b_in)
    y = jnp.einsum("bqt,bqth,bthp->bqhp", scores, decay, x.astype(jnp.float32))
    da_total = da_cs[:, -1]  # (B,H)
    decay_out = jnp.exp(da_total[:, None, :] - da_cs)  # (B,Q,H)
    state = jnp.einsum("btn,bth,bthp->bhpn", b_in, decay_out, x.astype(jnp.float32))
    return y, state
