"""Fused per-round observation kernel: scatter + max-update + theta sums.

Every simulator round runs the observation pipeline at the visited nodes
(``core/simulator.py`` step 4-5):

  1. ``record_returns``   — scatter observed return times into the
                            per-node histograms ``hist (n, B)`` / ``total``;
  2. ``last_seen`` update — scatter-max the visit times into ``(n, C)``;
  3. node theta sums      — sum_c S_i(t - last_seen[i, c]) per node
                            (Eq. 1's node-side reduction).

Unfused, 3. alone either re-builds the full ``(n, B+1)`` cumulative table
every round (gather path) or re-materializes an ``(n, C, B)`` compare
intermediate from HBM (compare path), and 1.-2. are separate scatter
dispatches touching the same rows again. This module fuses all three into
ONE node-tiled Pallas pass: each grid program holds a ``(bn, ...)`` tile
of ``last_seen`` / ``hist`` / ``total`` in VMEM, applies the round's walk
events to its tile (one-hot contractions — no scatter, no gather), and
reduces the theta sums for its rows while they are still resident. The
``(bn, C, B)`` compare intermediate never leaves VMEM, and per-round HBM
traffic drops to one read + one write of the observation state.

Exactness contract: ``hist``/``total`` hold event *counts* (integer-valued
f32, as ``record_returns`` maintains) and the walk weights are 0/1, so the
one-hot matmul accumulates exactly the same floats as the reference
scatter-adds; the max-updates are integer ops. The kernel is therefore
*bitwise* equal to the unfused reference sequence — ``round_update_ref``
(which literally IS that sequence, with ``estimator.node_sums_compare``
as the sums oracle) — and is golden-tested as such, including node counts
that are not a multiple of the tile (padded with masked "no data" rows).

``round_update`` dispatches per backend (``kernels.platform``): the
Pallas kernel on TPU, the fused-at-the-jnp-level reference elsewhere.
The simulator selects this whole path with ``estimator_impl="fused"``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import estimator as est
from repro.kernels.platform import (
    best_round_impl,
    default_interpret,
    pad_node_axis,
)

DEFAULT_BLOCK_NODES = 8
NEVER = est.NEVER


def random_round_inputs(key, n, C, B, W, t=70, p_active=0.8):
    """A plausible mid-trajectory observation round honoring the input
    contract (integer-valued count histograms, ``r``/``valid``/``upd``
    derived exactly as the simulator derives them) — the shared fixture
    for the bitwise oracle tests, the benchmark grid and the CI smoke
    tripwire. Returns ``(last_seen, hist, total, pos, track, r, valid,
    upd, t)``, i.e. ``round_update``'s argument tuple."""
    ks = jax.random.split(key, 5)
    ls = jax.random.randint(ks[0], (n, C), -1, t, dtype=jnp.int32)
    hist = jnp.floor(jax.random.uniform(ks[1], (n, B)) * 3).astype(jnp.float32)
    total = hist.sum(1)
    pos = jax.random.randint(ks[2], (W,), 0, n, dtype=jnp.int32)
    track = jax.random.randint(ks[3], (W,), 0, C, dtype=jnp.int32)
    active = jax.random.uniform(ks[4], (W,)) < p_active
    t = jnp.int32(t)
    prev = ls[pos, track]
    r = t - prev
    valid = active & (prev != NEVER) & (r >= 1)
    upd = jnp.where(active, t, NEVER)
    return ls, hist, total, pos, track, r, valid, upd, t


def round_update_ref(last_seen, hist, total, pos, track, r, valid, upd, t):
    """The unfused reference sequence (and the Pallas kernel's bitwise
    oracle): ``record_returns`` -> ``last_seen`` scatter-max ->
    ``node_sums_compare``. Returns ``(last_seen, hist, total, sums)``."""
    rts = est.record_returns(est.ReturnTimeState(hist, total), pos, r, valid)
    ls = last_seen.at[pos, track].max(upd, mode="drop")
    sums = est.node_sums_compare(ls, rts.hist, rts.total, t)
    return ls, rts.hist, rts.total, sums


def _round_kernel(
    t_ref, pos_ref, track_ref, rbin_ref, w_ref, upd_ref,
    ls_ref, hist_ref, tot_ref,
    ls_out, hist_out, tot_out, sums_out,
):
    t = t_ref[0, 0]
    pos = pos_ref[0, :]  # (W,) node visited by each walk slot
    track = track_ref[0, :]  # (W,) column each walk writes
    rbin = rbin_ref[0, :]  # (W,) histogram bin of the observed return
    w = w_ref[0, :]  # (W,) 0/1 observation weight
    upd = upd_ref[0, :]  # (W,) last-seen update value (NEVER if inactive)
    ls = ls_ref[...]  # (bn, C) int32
    hist = hist_ref[...]  # (bn, B) f32
    tot = tot_ref[...]  # (bn, 1) f32
    bn, C = ls.shape
    B = hist.shape[1]
    W = pos.shape[0]

    base = pl.program_id(0) * bn
    rows = jax.lax.broadcasted_iota(jnp.int32, (bn, W), 0) + base
    hit = rows == pos[None, :]  # (bn, W): walk j visits row i of this tile

    # 1. return-time scatter as a one-hot contraction: counts are exact
    #    integer-valued f32, so the matmul accumulates bitwise what the
    #    reference scatter-adds would
    bin_iota = jax.lax.broadcasted_iota(jnp.int32, (W, B), 1)
    ev = jnp.where(bin_iota == rbin[:, None], w[:, None], 0.0)  # (W, B)
    hist = hist + jnp.dot(hit.astype(jnp.float32), ev)
    tot = tot + jnp.sum(jnp.where(hit, w[None, :], 0.0), axis=1, keepdims=True)

    # 2. last-seen scatter-max at (pos[j], track[j]) <- upd[j]
    col_iota = jax.lax.broadcasted_iota(jnp.int32, (W, C), 1)
    m = jnp.where(col_iota == track[:, None], upd[:, None], NEVER)  # (W, C)
    upd_rows = jnp.max(
        jnp.where(hit[:, :, None], m[None, :, :], NEVER), axis=1
    )  # (bn, C)
    ls = jnp.maximum(ls, upd_rows)

    # 3. theta sums on the updated tile: the shared compare-accumulate
    #    core (estimator.survival_node_sums_rows), VMEM-resident
    ls_out[...] = ls
    hist_out[...] = hist
    tot_out[...] = tot
    sums_out[...] = est.survival_node_sums_rows(ls, hist, tot[:, 0], t)[:, None]


@functools.partial(jax.jit, static_argnames=("block_nodes", "interpret"))
def round_update_pallas(
    last_seen: jax.Array,  # (n, C) int32
    hist: jax.Array,  # (n, B) f32 counts
    total: jax.Array,  # (n,) f32 counts
    pos: jax.Array,  # (W,) int32
    track: jax.Array,  # (W,) int32
    r: jax.Array,  # (W,) int32 observed return times (t - prev)
    valid: jax.Array,  # (W,) bool
    upd: jax.Array,  # (W,) int32 last-seen update (NEVER if inactive)
    t: jax.Array,  # scalar int32
    *,
    block_nodes: int = DEFAULT_BLOCK_NODES,
    interpret: bool | None = None,
):
    """One fused observation round over node tiles; see module docstring.

    Returns ``(last_seen, hist, total, sums)`` with the round's walk
    events applied and ``sums[i] = sum_c S_i(t - last_seen[i, c])``.
    ``n`` need not divide the tile: the node axis is padded with masked
    "no data" rows (sliced off again) that no walk can hit. NB the
    pad+slice happens per call, so a non-tile-multiple ``n`` inside a
    scanned trajectory pays one extra copy of the observation state per
    round — pick ``n`` (or ``block_nodes``) tile-aligned on the hot
    path, or carry pre-padded state (ROADMAP follow-up).
    """
    n, C = last_seen.shape
    B = hist.shape[1]
    W = pos.shape[0]
    if interpret is None:
        interpret = default_interpret()
    bn = min(block_nodes, n)
    last_seen, hist, total, pad = pad_node_axis(bn, last_seen, hist, total)
    npad = n + pad
    rbin = jnp.clip(r, 1, B) - 1  # record_returns' bin rule
    w = valid.astype(jnp.float32)
    t_arr = jnp.asarray(t, jnp.int32).reshape(1, 1)
    walk_spec = pl.BlockSpec((1, W), lambda i: (0, 0))  # broadcast to tiles
    ls_o, hist_o, tot_o, sums_o = pl.pallas_call(
        _round_kernel,
        grid=(npad // bn,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),  # t (broadcast)
            walk_spec,  # pos
            walk_spec,  # track
            walk_spec,  # rbin
            walk_spec,  # w
            walk_spec,  # upd
            pl.BlockSpec((bn, C), lambda i: (i, 0)),  # last_seen tile
            pl.BlockSpec((bn, B), lambda i: (i, 0)),  # hist tile
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),  # total tile
        ],
        out_specs=[
            pl.BlockSpec((bn, C), lambda i: (i, 0)),
            pl.BlockSpec((bn, B), lambda i: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad, C), last_seen.dtype),
            jax.ShapeDtypeStruct((npad, B), jnp.float32),
            jax.ShapeDtypeStruct((npad, 1), jnp.float32),
            jax.ShapeDtypeStruct((npad, 1), jnp.float32),
        ],
        interpret=interpret,
    )(
        t_arr,
        pos[None, :],
        track[None, :],
        rbin[None, :],
        w[None, :],
        upd[None, :],
        last_seen,
        hist,
        total[:, None],
    )
    return ls_o[:n], hist_o[:n], tot_o[:n, 0], sums_o[:n, 0]


def round_update(
    last_seen, hist, total, pos, track, r, valid, upd, t,
    *, impl: str | None = None,
):
    """Backend-dispatched fused round: ``impl=None`` resolves through
    ``kernels.platform.best_round_impl`` ('pallas' on TPU, 'ref' on
    CPU/GPU). Both implementations are bitwise-interchangeable."""
    if impl is None:
        impl = best_round_impl()
    if impl == "pallas":
        return round_update_pallas(
            last_seen, hist, total, pos, track, r, valid, upd, t
        )
    if impl == "ref":
        return round_update_ref(
            last_seen, hist, total, pos, track, r, valid, upd, t
        )
    raise ValueError(f"unknown round impl {impl!r}; use 'pallas' or 'ref'")
