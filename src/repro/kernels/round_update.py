"""Fused per-round observation kernel: scatter + max-update + theta sums.

Every simulator round runs the observation pipeline at the visited nodes
(``core/simulator.py`` step 4-5):

  1. ``record_returns``   — scatter observed return times into the
                            per-node histograms ``hist (n, B)`` / ``total``;
  2. ``last_seen`` update — scatter-max the visit times into ``(n, C)``;
  3. node theta sums      — sum_c S_i(t - last_seen[i, c]) per node
                            (Eq. 1's node-side reduction).

Unfused, 3. alone either re-builds the full ``(n, B+1)`` cumulative table
every round (gather path) or re-materializes an ``(n, C, B)`` compare
intermediate from HBM (compare path), and 1.-2. are separate scatter
dispatches touching the same rows again. This module fuses all three into
ONE node-tiled Pallas pass: each grid program holds a ``(bn, ...)`` tile
of ``last_seen`` / ``hist`` / ``total`` in VMEM, applies the round's walk
events to its tile (one-hot contractions — no scatter, no gather), and
reduces the theta sums for its rows while they are still resident. The
``(bn, C, B)`` compare intermediate never leaves VMEM, and per-round HBM
traffic drops to one read + one write of the observation state.

Exactness contract: ``hist``/``total`` hold event *counts* (int16/int32
as ``record_returns`` maintains — per-bin counts are step-bounded, far
below 32767; the f32 one-hot matmul accumulates exact small integers that
cast back losslessly) and the walk weights are 0/1, so the kernel updates
bitwise what the reference scatter-adds would; the max-updates are
integer ops. The kernel is therefore *bitwise* equal to the unfused
reference sequence — ``round_update_ref`` (which literally IS that
sequence, with ``estimator.node_sums_compare`` as the sums oracle) — and
is golden-tested as such, including node counts that are not a multiple
of the tile (padded with masked "no data" rows). Both kernels are
dtype-polymorphic (outputs follow the input carry), so the benchmark
grid can still measure a float32 arm.

``round_update`` dispatches per backend (``kernels.platform``): the
Pallas kernel on TPU, the fused-at-the-jnp-level reference elsewhere.
The simulator selects this whole path with ``estimator_impl="fused"``.

``whole_round_pallas`` extends the fusion to the ENTIRE round: one
node-tiled two-phase pass performing the topology step, resident-walk
kills, the masked rank-select hop, walk-level failures (probabilistic /
burst / Byzantine / Pac-Man), the observation update above, AND the
fork/terminate decision masks — everything between two scan carries
except the walk-slot fork/terminate execution, which stays outside. All
uniforms are pre-drawn by the caller from the exact PRNG streams the
unfused sequence consumes (``core.simulator._protocol_step_fused``), so
the kernel is deterministic data flow and bitwise-testable against the
literal unfused round.

Zoo coverage (``repro.zoo``): the kernel handles the classic single
static Pac-Man only. The zoo's attack statics — ``pacman_mobile``,
extra ``pacman_nodes``, scheduled edge cuts — and every non-uniform
``walk_variant`` are gated OFF this kernel by
``core.simulator.round_impl_decision`` (the ref fused round, which
shares the jnp failure helpers, still fuses the attack statics; walk
variants always take the stage sequence). Extending the kernel to the
zoo attacks rides the real-TPU validation item in ROADMAP item 3.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import estimator as est
from repro.core import protocol as prt
from repro.core import walkers as wlk
from repro.kernels.platform import (
    best_round_update_impl,
    default_interpret,
    pad_node_axis,
)

DEFAULT_BLOCK_NODES = 8
NEVER = est.NEVER


def random_round_inputs(key, n, C, B, W, t=70, p_active=0.8):
    """A plausible mid-trajectory observation round honoring the input
    contract (integer-valued count histograms, ``r``/``valid``/``upd``
    derived exactly as the simulator derives them) — the shared fixture
    for the bitwise oracle tests, the benchmark grid and the CI smoke
    tripwire. Returns ``(last_seen, hist, total, pos, track, r, valid,
    upd, t)``, i.e. ``round_update``'s argument tuple."""
    ks = jax.random.split(key, 5)
    ls = jax.random.randint(ks[0], (n, C), -1, t, dtype=jnp.int32)
    hist = jnp.floor(jax.random.uniform(ks[1], (n, B)) * 3).astype(jnp.int16)
    total = hist.sum(1, dtype=jnp.int32)
    pos = jax.random.randint(ks[2], (W,), 0, n, dtype=jnp.int32)
    track = jax.random.randint(ks[3], (W,), 0, C, dtype=jnp.int32)
    active = jax.random.uniform(ks[4], (W,)) < p_active
    t = jnp.int32(t)
    prev = ls[pos, track]
    r = t - prev
    valid = active & (prev != NEVER) & (r >= 1)
    upd = jnp.where(active, t, NEVER)
    return ls, hist, total, pos, track, r, valid, upd, t


def round_update_ref(last_seen, hist, total, pos, track, r, valid, upd, t):
    """The unfused reference sequence (and the Pallas kernel's bitwise
    oracle): ``record_returns`` -> ``last_seen`` scatter-max ->
    ``node_sums_compare``. Returns ``(last_seen, hist, total, sums)``."""
    rts = est.record_returns(est.ReturnTimeState(hist, total), pos, r, valid)
    ls = last_seen.at[pos, track].max(upd, mode="drop")
    sums = est.node_sums_compare(ls, rts.hist, rts.total, t)
    return ls, rts.hist, rts.total, sums


def _round_kernel(
    t_ref, pos_ref, track_ref, rbin_ref, w_ref, upd_ref,
    ls_ref, hist_ref, tot_ref,
    ls_out, hist_out, tot_out, sums_out,
):
    t = t_ref[0, 0]
    pos = pos_ref[0, :]  # (W,) node visited by each walk slot
    track = track_ref[0, :]  # (W,) column each walk writes
    rbin = rbin_ref[0, :]  # (W,) histogram bin of the observed return
    w = w_ref[0, :]  # (W,) 0/1 observation weight
    upd = upd_ref[0, :]  # (W,) last-seen update value (NEVER if inactive)
    ls = ls_ref[...]  # (bn, C) int32
    hist = hist_ref[...]  # (bn, B) int16 counts (or f32 on the bench arm)
    tot = tot_ref[...]  # (bn, 1) int32 counts (or f32 on the bench arm)
    bn, C = ls.shape
    B = hist.shape[1]
    W = pos.shape[0]

    base = pl.program_id(0) * bn
    rows = jax.lax.broadcasted_iota(jnp.int32, (bn, W), 0) + base
    hit = rows == pos[None, :]  # (bn, W): walk j visits row i of this tile

    # 1. return-time scatter as a one-hot contraction: the f32 matmul
    #    accumulates exact small integers (counts are step-bounded, far
    #    below 2**24), so the cast back to the carry dtype is lossless
    #    and the result is bitwise the reference scatter-adds
    bin_iota = jax.lax.broadcasted_iota(jnp.int32, (W, B), 1)
    ev = jnp.where(bin_iota == rbin[:, None], w[:, None], 0.0)  # (W, B)
    hist = hist + jnp.dot(hit.astype(jnp.float32), ev).astype(hist.dtype)
    tot = tot + jnp.sum(
        jnp.where(hit, w[None, :], 0.0), axis=1, keepdims=True
    ).astype(tot.dtype)

    # 2. last-seen scatter-max at (pos[j], track[j]) <- upd[j]
    col_iota = jax.lax.broadcasted_iota(jnp.int32, (W, C), 1)
    m = jnp.where(col_iota == track[:, None], upd[:, None], NEVER)  # (W, C)
    upd_rows = jnp.max(
        jnp.where(hit[:, :, None], m[None, :, :], NEVER), axis=1
    )  # (bn, C)
    ls = jnp.maximum(ls, upd_rows)

    # 3. theta sums on the updated tile: the shared compare-accumulate
    #    core (estimator.survival_node_sums_rows), VMEM-resident
    ls_out[...] = ls
    hist_out[...] = hist
    tot_out[...] = tot
    sums_out[...] = est.survival_node_sums_rows(ls, hist, tot[:, 0], t)[:, None]


@functools.partial(jax.jit, static_argnames=("block_nodes", "interpret"))
def round_update_pallas(
    last_seen: jax.Array,  # (n, C) int32
    hist: jax.Array,  # (n, B) int16 counts (f32 bench arm also supported)
    total: jax.Array,  # (n,) int32 counts (f32 bench arm also supported)
    pos: jax.Array,  # (W,) int32
    track: jax.Array,  # (W,) int32
    r: jax.Array,  # (W,) int32 observed return times (t - prev)
    valid: jax.Array,  # (W,) bool
    upd: jax.Array,  # (W,) int32 last-seen update (NEVER if inactive)
    t: jax.Array,  # scalar int32
    *,
    block_nodes: int = DEFAULT_BLOCK_NODES,
    interpret: bool | None = None,
):
    """One fused observation round over node tiles; see module docstring.

    Returns ``(last_seen, hist, total, sums)`` with the round's walk
    events applied and ``sums[i] = sum_c S_i(t - last_seen[i, c])``.
    ``n`` need not divide the tile: the node axis is padded with masked
    "no data" rows (sliced off again) that no walk can hit. NB the
    pad+slice happens per call, so a non-tile-multiple ``n`` inside a
    scanned trajectory pays one extra copy of the observation state per
    round — pick ``n`` (or ``block_nodes``) tile-aligned on the hot
    path, or carry pre-padded state (ROADMAP follow-up).
    """
    n, C = last_seen.shape
    B = hist.shape[1]
    W = pos.shape[0]
    if interpret is None:
        interpret = default_interpret()
    bn = min(block_nodes, n)
    last_seen, hist, total, pad = pad_node_axis(bn, last_seen, hist, total)
    npad = n + pad
    rbin = jnp.clip(r, 1, B) - 1  # record_returns' bin rule
    w = valid.astype(jnp.float32)
    t_arr = jnp.asarray(t, jnp.int32).reshape(1, 1)
    walk_spec = pl.BlockSpec((1, W), lambda i: (0, 0))  # broadcast to tiles
    ls_o, hist_o, tot_o, sums_o = pl.pallas_call(
        _round_kernel,
        grid=(npad // bn,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),  # t (broadcast)
            walk_spec,  # pos
            walk_spec,  # track
            walk_spec,  # rbin
            walk_spec,  # w
            walk_spec,  # upd
            pl.BlockSpec((bn, C), lambda i: (i, 0)),  # last_seen tile
            pl.BlockSpec((bn, B), lambda i: (i, 0)),  # hist tile
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),  # total tile
        ],
        out_specs=[
            pl.BlockSpec((bn, C), lambda i: (i, 0)),
            pl.BlockSpec((bn, B), lambda i: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad, C), last_seen.dtype),
            jax.ShapeDtypeStruct((npad, B), hist.dtype),
            jax.ShapeDtypeStruct((npad, 1), total.dtype),
            jax.ShapeDtypeStruct((npad, 1), jnp.float32),
        ],
        interpret=interpret,
    )(
        t_arr,
        pos[None, :],
        track[None, :],
        rbin[None, :],
        w[None, :],
        upd[None, :],
        last_seen,
        hist,
        total[:, None],
    )
    return ls_o[:n], hist_o[:n], tot_o[:n, 0], sums_o[:n, 0]


def round_update(
    last_seen, hist, total, pos, track, r, valid, upd, t,
    *, impl: str | None = None,
):
    """Backend-dispatched fused round: ``impl=None`` resolves through
    ``kernels.platform.best_round_update_impl`` ('pallas' on TPU, 'ref'
    on CPU/GPU). Both implementations are bitwise-interchangeable."""
    if impl is None:
        impl = best_round_update_impl()
    if impl == "pallas":
        return round_update_pallas(
            last_seen, hist, total, pos, track, r, valid, upd, t
        )
    if impl == "ref":
        return round_update_ref(
            last_seen, hist, total, pos, track, r, valid, upd, t
        )
    raise ValueError(f"unknown round impl {impl!r}; use 'pallas' or 'ref'")


# ---------------------------------------------------------------------------
# Whole-round kernel: topology + hop + failures + observations + decisions
# ---------------------------------------------------------------------------


def _whole_round_kernel(
    decafork_plus,
    # broadcast scalars
    params_f_ref,  # (1, 8) f32: p_fail, p_nfail, p_lfail, p_nrec, p_lrec,
    #                            eps, eps2, fork_prob (start-gates folded in)
    params_i_ref,  # (1, 4) i32: t, byz_kill_node, pacman_node, enabled
    # walk-level inputs (broadcast to every tile)
    pos_ref, track_ref, act_ref,  # (1, W) i32 / i32 / bool
    u_move_ref, u_pfail_ref, u_fork_ref, u_term_ref,  # (1, W) f32
    deg_ref,  # (1, W) i32 degrees at the walks' pre-hop nodes
    nbrw_ref, eupw_ref, efw_ref, erw_ref,  # (W, D) walk-row adjacency/masks
    uburst_ref,  # (K', W) f32 per-burst score uniforms
    bsz_ref,  # (1, K') i32 effective burst sizes (0 when not firing)
    # node-level inputs
    nodeup_ref, unfail_ref, unrec_ref, sched_ref,  # (1, N) full node axis
    eup_ref, ef_ref, er_ref,  # (bn, D) edge tiles: mask + symmetrized u's
    ls_ref, hist_ref, tot_ref,  # (bn, C) i32 / (bn, B) i16 / (bn, 1) i32
    # outputs
    ls_out, hist_out, tot_out,  # updated observation tiles
    eup_out,  # (bn, D) updated edge tile
    nodeup_out,  # (1, N) updated node mask (constant block)
    pos_out, act_out,  # (1, W) post-hop / post-failure walk state
    theta_out,  # (1, W) f32 theta-hat accumulator -> final theta
    chosen_out, fork_out, term_out,  # (1, W) bool decision masks
):
    """Two-phase whole-round pass; grid = (2, num_tiles), phase-major.

    Phase 0 advances the topology per tile and, in its first step, runs
    the walk epilogue (resident kills, masked rank-select hop, walk-level
    failures) on the full walk vectors, publishing ``pos_out``/``act_out``
    for phase 1 to read. Phase 1 applies the observation update to each
    tile (the PR-4 fused pipeline) and accumulates per-walk theta sums
    into ``theta_out``; its last step computes the fork/terminate masks.
    Output blocks with constant index maps persist across grid steps
    (the standard Pallas accumulation idiom), which is what carries the
    walk state and theta accumulator between phases. Tile-mapped outputs
    are written in BOTH phases (topology recomputed, observation tiles
    passed through in phase 0) so no revisited block holds stale data.
    """
    ph = pl.program_id(0)
    i = pl.program_id(1)
    pf = params_f_ref[0, :]
    pint = params_i_ref[0, :]
    t = pint[0]
    byz_node = pint[1]
    pac_node = pint[2]
    enabled = pint[3] > 0
    p_fail, p_nfail, p_lfail = pf[0], pf[1], pf[2]
    p_nrec, p_lrec, eps, eps2, p_fork = pf[3], pf[4], pf[5], pf[6], pf[7]

    # -- edge-tile topology update, recomputed in both phases so every
    #    mapped output block is written on every grid step
    eup = eup_ref[...]
    fail = ef_ref[...] < p_lfail
    rec = er_ref[...] < p_lrec
    eup_out[...] = jnp.where(eup, ~fail, rec)

    # the full updated node mask (cheap (N,) elementwise; the epilogue
    # needs it for kills and for BOTH hop endpoints)
    node_up = nodeup_ref[0, :]
    crash = unfail_ref[0, :] < p_nfail
    recov = unrec_ref[0, :] < p_nrec
    sched = sched_ref[0, :]
    node_new = jnp.where(node_up, ~(crash | sched), recov & ~sched)

    @pl.when(ph == 0)
    def _pass_through_obs():
        ls_out[...] = ls_ref[...]
        hist_out[...] = hist_ref[...]
        tot_out[...] = tot_ref[...]

    @pl.when((ph == 0) & (i == 0))
    def _walk_epilogue():
        nodeup_out[...] = node_new[None, :]
        pos = pos_ref[0, :]
        active = act_ref[0, :]
        # resident kills at the pre-hop positions
        active = active & node_new[pos]
        # masked rank-select hop over the walks' own adjacency rows
        nbr = nbrw_ref[...]
        fail_w = efw_ref[...] < p_lfail
        rec_w = erw_ref[...] < p_lrec
        eup_new_w = jnp.where(eupw_ref[...], ~fail_w, rec_w)
        deg = deg_ref[0, :]
        within = (
            jax.lax.broadcasted_iota(jnp.int32, nbr.shape, 1) < deg[:, None]
        )
        avail = within & eup_new_w & node_new[pos][:, None] & node_new[nbr]
        adeg, sel = wlk.select_available_edge(
            avail, u_move_ref[0, :], jnp.int32
        )
        nxt = jnp.take_along_axis(nbr, sel[:, None], axis=1)[:, 0]
        pos = jnp.where(active & (adeg > 0), nxt, pos)
        # walk-level threat models: probabilistic, bursts, Byz, Pac-Man
        active = active & ~(u_pfail_ref[0, :] < p_fail)
        for b in range(uburst_ref.shape[0]):
            score = jnp.where(active, uburst_ref[b, :], jnp.inf)
            rank = jnp.sum(score[:, None] > score[None, :], axis=1)
            active = active & ~(rank < bsz_ref[0, b])
        active = active & ~(pos == byz_node)  # -1 sentinels never match
        active = active & ~(pos == pac_node)
        pos_out[...] = pos[None, :]
        act_out[...] = active[None, :]

    @pl.when(ph == 1)
    def _observe_and_decide():
        pos = pos_out[0, :]
        active = act_out[0, :]
        track = track_ref[0, :]
        ls = ls_ref[...]
        hist = hist_ref[...]
        tot = tot_ref[...]
        bn, C = ls.shape
        B = hist.shape[1]
        W = pos.shape[0]
        base = i * bn
        rows = jax.lax.broadcasted_iota(jnp.int32, (bn, W), 0) + base
        hit = rows == pos[None, :]
        # prev = last_seen[pos, track]: a walk's row lives in exactly one
        # tile, so the masked max over this tile IS the gather for the
        # walks that land here (others see NEVER -> no contribution)
        ls_track = jnp.take(ls, track, axis=1)  # (bn, W)
        prev = jnp.max(jnp.where(hit, ls_track, NEVER), axis=0)
        r = t - prev
        valid = active & (prev != NEVER) & (r >= 1)
        rbin = jnp.clip(r, 1, B) - 1
        w8 = valid.astype(jnp.float32)
        bin_iota = jax.lax.broadcasted_iota(jnp.int32, (W, B), 1)
        ev = jnp.where(bin_iota == rbin[:, None], w8[:, None], 0.0)
        hist = hist + jnp.dot(hit.astype(jnp.float32), ev).astype(hist.dtype)
        tot = tot + jnp.sum(
            jnp.where(hit, w8[None, :], 0.0), axis=1, keepdims=True
        ).astype(tot.dtype)
        upd = jnp.where(active, t, NEVER)
        col_iota = jax.lax.broadcasted_iota(jnp.int32, (W, C), 1)
        m = jnp.where(col_iota == track[:, None], upd[:, None], NEVER)
        upd_rows = jnp.max(
            jnp.where(hit[:, :, None], m[None, :, :], NEVER), axis=1
        )
        ls = jnp.maximum(ls, upd_rows)
        ls_out[...] = ls
        hist_out[...] = hist
        tot_out[...] = tot
        # per-walk theta contribution from this tile's node sums
        sums = est.survival_node_sums_rows(ls, hist, tot[:, 0], t)
        contrib = jnp.sum(jnp.where(hit, sums[:, None], 0.0), axis=0)
        acc = jnp.where(i == 0, contrib, theta_out[0, :] + contrib)
        theta_out[...] = acc[None, :]

        @pl.when(i == pl.num_programs(1) - 1)
        def _decide():
            theta = acc - 0.5  # theta_hat_from_node_sums
            theta_out[...] = theta[None, :]
            chosen = prt.choose_walks_pairwise(pos, active)
            fork = (
                chosen & (theta < eps) & (u_fork_ref[0, :] < p_fork) & enabled
            )
            if decafork_plus:
                term = (
                    chosen
                    & (theta > eps2)
                    & (u_term_ref[0, :] < p_fork)
                    & enabled
                )
                term = term & ~fork
            else:
                term = jnp.zeros_like(fork)
            chosen_out[...] = chosen[None, :]
            fork_out[...] = fork[None, :]
            term_out[...] = term[None, :]


@functools.partial(
    jax.jit,
    static_argnames=("decafork_plus", "block_nodes", "interpret"),
)
def whole_round_pallas(
    last_seen: jax.Array,  # (n, C) int32
    hist: jax.Array,  # (n, B) int16 counts
    total: jax.Array,  # (n,) int32 counts
    node_up: jax.Array,  # (n,) bool live-node mask (pre-round)
    edge_up: jax.Array,  # (n, D) bool live-edge mask (pre-round)
    pos: jax.Array,  # (W,) int32 pre-hop positions
    track: jax.Array,  # (W,) int32
    active: jax.Array,  # (W,) bool pre-round liveness
    neighbors_rows: jax.Array,  # (W, D) = neighbors[pos]
    degrees_rows: jax.Array,  # (W,) = degrees[pos]
    edge_up_rows: jax.Array,  # (W, D) = edge_up[pos]
    e_fail_rows: jax.Array,  # (W, D) symmetrized link-fail uniforms at pos
    e_rec_rows: jax.Array,  # (W, D) symmetrized link-recovery uniforms
    u_move: jax.Array,  # (W,) hop uniforms
    u_pfail: jax.Array,  # (W,) probabilistic-failure uniforms
    u_fork: jax.Array,  # (W,) fork-decision uniforms
    u_term: jax.Array,  # (W,) terminate-decision uniforms
    u_burst: jax.Array,  # (K', W) per-burst score uniforms
    burst_sizes_eff: jax.Array,  # (K',) i32, 0 where the burst is not firing
    u_nfail: jax.Array,  # (n,) node crash uniforms
    u_nrec: jax.Array,  # (n,) node recovery uniforms
    sched_down: jax.Array,  # (n,) bool scheduled-crash mask for this step
    e_fail: jax.Array,  # (n, D) symmetrized link-fail uniforms, full table
    e_rec: jax.Array,  # (n, D) symmetrized link-recovery uniforms
    params_f: jax.Array,  # (1, 8) f32 — see _whole_round_kernel
    params_i: jax.Array,  # (1, 4) i32 — see _whole_round_kernel
    *,
    decafork_plus: bool = False,
    block_nodes: int = DEFAULT_BLOCK_NODES,
    interpret: bool | None = None,
):
    """One whole simulator round as a single node-tiled Pallas pass.

    Every random draw is made by the caller (from the exact PRNG streams
    the unfused sequence consumes) and enters as data, so the kernel is
    deterministic and bitwise-testable against the literal unfused round.
    Start-gates are folded into effective rates/sentinels by the caller:
    a rate of -1 never fires (uniforms live in [0, 1)), a node id of -1
    never matches. Returns

      ``(last_seen, hist, total, node_up, edge_up, pos, active, theta,
      chosen, fork, term)``

    — the updated observation state, the stepped topology masks, the
    post-hop post-failure walk state, per-walk theta-hat, and the
    decision masks for ``execute_forks`` / ``execute_terminations``
    (which stay outside: they are walk-sized and shared with every other
    path). ``n`` need not divide the tile; the node axis is padded with
    masked rows no walk can reach and sliced off the outputs.
    """
    n, C = last_seen.shape
    B = hist.shape[1]
    W = pos.shape[0]
    D = edge_up.shape[1]
    K = u_burst.shape[0]
    if interpret is None:
        interpret = default_interpret()
    bn = min(block_nodes, n)
    last_seen, hist, total, pad = pad_node_axis(bn, last_seen, hist, total)
    if pad:
        node_up = jnp.concatenate([node_up, jnp.zeros((pad,), bool)])
        edge_up = jnp.concatenate([edge_up, jnp.zeros((pad, D), bool)])
        u_nfail = jnp.concatenate([u_nfail, jnp.ones((pad,), u_nfail.dtype)])
        u_nrec = jnp.concatenate([u_nrec, jnp.ones((pad,), u_nrec.dtype)])
        sched_down = jnp.concatenate([sched_down, jnp.zeros((pad,), bool)])
        # pad edge-uniform rows with 1.0: never fails, never recovers
        e_fail = jnp.concatenate(
            [e_fail, jnp.ones((pad, D), e_fail.dtype)]
        )
        e_rec = jnp.concatenate([e_rec, jnp.ones((pad, D), e_rec.dtype)])
    npad = n + pad
    walk_spec = pl.BlockSpec((1, W), lambda p, i: (0, 0))
    wd_spec = pl.BlockSpec((W, D), lambda p, i: (0, 0))
    node_full_spec = pl.BlockSpec((1, npad), lambda p, i: (0, 0))
    edge_tile_spec = pl.BlockSpec((bn, D), lambda p, i: (i, 0))
    outs = pl.pallas_call(
        functools.partial(_whole_round_kernel, decafork_plus),
        grid=(2, npad // bn),
        in_specs=[
            pl.BlockSpec((1, 8), lambda p, i: (0, 0)),  # params_f
            pl.BlockSpec((1, 4), lambda p, i: (0, 0)),  # params_i
            walk_spec,  # pos
            walk_spec,  # track
            walk_spec,  # active
            walk_spec,  # u_move
            walk_spec,  # u_pfail
            walk_spec,  # u_fork
            walk_spec,  # u_term
            walk_spec,  # degrees_rows
            wd_spec,  # neighbors_rows
            wd_spec,  # edge_up_rows
            wd_spec,  # e_fail_rows
            wd_spec,  # e_rec_rows
            pl.BlockSpec((K, W), lambda p, i: (0, 0)),  # u_burst
            pl.BlockSpec((1, K), lambda p, i: (0, 0)),  # burst_sizes_eff
            node_full_spec,  # node_up
            node_full_spec,  # u_nfail
            node_full_spec,  # u_nrec
            node_full_spec,  # sched_down
            edge_tile_spec,  # edge_up tile
            edge_tile_spec,  # e_fail tile
            edge_tile_spec,  # e_rec tile
            pl.BlockSpec((bn, C), lambda p, i: (i, 0)),  # last_seen tile
            pl.BlockSpec((bn, B), lambda p, i: (i, 0)),  # hist tile
            pl.BlockSpec((bn, 1), lambda p, i: (i, 0)),  # total tile
        ],
        out_specs=[
            pl.BlockSpec((bn, C), lambda p, i: (i, 0)),  # last_seen
            pl.BlockSpec((bn, B), lambda p, i: (i, 0)),  # hist
            pl.BlockSpec((bn, 1), lambda p, i: (i, 0)),  # total
            edge_tile_spec,  # edge_up
            node_full_spec,  # node_up
            walk_spec,  # pos
            walk_spec,  # active
            walk_spec,  # theta
            walk_spec,  # chosen
            walk_spec,  # fork
            walk_spec,  # term
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad, C), last_seen.dtype),
            jax.ShapeDtypeStruct((npad, B), hist.dtype),
            jax.ShapeDtypeStruct((npad, 1), total.dtype),
            jax.ShapeDtypeStruct((npad, D), jnp.bool_),
            jax.ShapeDtypeStruct((1, npad), jnp.bool_),
            jax.ShapeDtypeStruct((1, W), pos.dtype),
            jax.ShapeDtypeStruct((1, W), jnp.bool_),
            jax.ShapeDtypeStruct((1, W), jnp.float32),
            jax.ShapeDtypeStruct((1, W), jnp.bool_),
            jax.ShapeDtypeStruct((1, W), jnp.bool_),
            jax.ShapeDtypeStruct((1, W), jnp.bool_),
        ],
        interpret=interpret,
    )(
        params_f,
        params_i,
        pos[None, :],
        track[None, :],
        active[None, :],
        u_move[None, :],
        u_pfail[None, :],
        u_fork[None, :],
        u_term[None, :],
        degrees_rows.astype(jnp.int32)[None, :],
        neighbors_rows,
        edge_up_rows,
        e_fail_rows.astype(jnp.float32),
        e_rec_rows.astype(jnp.float32),
        u_burst,
        burst_sizes_eff[None, :],
        node_up[None, :],
        u_nfail[None, :],
        u_nrec[None, :],
        sched_down[None, :],
        edge_up,
        e_fail,
        e_rec,
        last_seen,
        hist,
        total[:, None],
    )
    (ls_o, hist_o, tot_o, eup_o, nup_o, pos_o, act_o, theta_o,
     chosen_o, fork_o, term_o) = outs
    return (
        ls_o[:n],
        hist_o[:n],
        tot_o[:n, 0],
        nup_o[0, :n],
        eup_o[:n],
        pos_o[0],
        act_o[0],
        theta_o[0],
        chosen_o[0],
        fork_o[0],
        term_o[0],
    )
