"""Pallas TPU kernels for the system's compute hot loops:

  round_update — the fused per-round observation pass (scatter + max-
                 update + theta sums), ``estimator_impl="fused"``
  theta_survival — the standalone DECAFORK estimator sweep
  flash_attention — payload attention (causal + sliding-window, GQA)
  ssd_scan — Mamba-2 intra-chunk SSD block

Each kernel has a pure-jnp oracle (``ref.py``, or the unfused reference
sequence in ``round_update.round_update_ref``) and interpret-mode sweeps
in tests/ — ``round_update`` is held to *bitwise* oracle equality.
"""
from repro.kernels.ops import attention_pallas, ssd_pallas, theta_sums_pallas
from repro.kernels.round_update import (
    round_update,
    round_update_pallas,
    round_update_ref,
)

__all__ = [
    "attention_pallas",
    "ssd_pallas",
    "theta_sums_pallas",
    "round_update",
    "round_update_pallas",
    "round_update_ref",
]
