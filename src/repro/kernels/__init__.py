"""Pallas TPU kernels for the system's compute hot loops:

  round_update — the fused per-round observation pass (scatter + max-
                 update + theta sums), ``estimator_impl="fused"``, and
                 the whole-round kernel (``whole_round_pallas``: topology
                 + hop + failures + observation + decisions in ONE pass),
                 ``round_impl="fused"`` on TPU
  theta_survival — the standalone DECAFORK estimator sweep
  flash_attention — payload attention (causal + sliding-window, GQA)
  ssd_scan — Mamba-2 intra-chunk SSD block

Each kernel has a pure-jnp oracle (``ref.py``, or the unfused reference
sequence in ``round_update.round_update_ref`` / the literal unfused
round ``round_impl="unfused"``) and interpret-mode sweeps in tests/ —
``round_update`` and ``whole_round_pallas`` are held to *bitwise* oracle
equality. Implementation resolution (explicit config > ``"auto"`` >
``REPRO_*_IMPL`` env > backend default) lives in ``kernels.platform``.
"""
from repro.kernels.ops import attention_pallas, ssd_pallas, theta_sums_pallas
from repro.kernels.round_update import (
    round_update,
    round_update_pallas,
    round_update_ref,
    whole_round_pallas,
)

__all__ = [
    "attention_pallas",
    "ssd_pallas",
    "theta_sums_pallas",
    "round_update",
    "round_update_pallas",
    "round_update_ref",
    "whole_round_pallas",
]
