"""Pallas TPU kernels for the system's three compute hot loops:

  theta_survival — the DECAFORK estimator sweep (the paper's hot-spot)
  flash_attention — payload attention (causal + sliding-window, GQA)
  ssd_scan — Mamba-2 intra-chunk SSD block

Each kernel has a pure-jnp oracle in ref.py and interpret-mode allclose
sweeps in tests/.
"""
from repro.kernels.ops import attention_pallas, ssd_pallas, theta_sums_pallas

__all__ = ["attention_pallas", "ssd_pallas", "theta_sums_pallas"]
