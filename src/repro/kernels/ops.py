"""Jitted public wrappers around the Pallas kernels.

These adapt model-layer layouts to kernel layouts and provide the
drop-in replacements the model code selects via ``cfg.use_pallas``:

  attention_pallas(q, k, v, window)   <-> layers.blocked_causal_attention
  theta_sums_pallas(...)              <-> kernels.ref.theta_sums_ref
  ssd_pallas(x, dt, a, b, c, chunk)   <-> ssm.ssd_chunked

The round kernels (``round_update``, ``whole_round_pallas``) live in
``kernels.round_update`` and are already jitted wrappers themselves; the
simulator reaches them through its ``estimator_impl`` / ``round_impl``
resolution (``kernels.platform.best_*``, honoring the
``REPRO_ESTIMATOR_IMPL`` / ``REPRO_ROUND_IMPL`` env overrides) rather
than through this module.

``interpret`` defaults are platform-aware everywhere (wrappers AND the
underlying kernels): emulated on CPU, compiled on TPU — see
``kernels.platform.default_interpret``. Pass an explicit bool to override.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.kernels.platform import default_interpret as _default_interpret
from repro.kernels.ssd_scan import ssd_intra_chunk
from repro.kernels.theta_survival import theta_sums


def attention_pallas(q, k, v, window: int = 0, interpret: bool | None = None):
    """q: (B, S, H, D); k/v: (B, S, KV, D) — model layout."""
    if interpret is None:
        interpret = _default_interpret()
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention(qt, kt, vt, window=window, interpret=interpret)
    return jnp.swapaxes(out, 1, 2)


def theta_sums_pallas(last_seen, hist, total, t, interpret: bool | None = None):
    if interpret is None:
        interpret = _default_interpret()
    return theta_sums(last_seen, hist, total, t, interpret=interpret)


def ssd_pallas(x, dt, a, b_in, c_in, chunk: int = 128, interpret: bool | None = None):
    """Drop-in for repro.models.ssm.ssd_chunked (returns y, final_state)."""
    if interpret is None:
        interpret = _default_interpret()
    B, L, H, P = x.shape
    N = b_in.shape[-1]
    if L % chunk:
        raise ValueError("L must divide the chunk size")
    nc = L // chunk
    da = (dt * a).reshape(B, nc, chunk, H)
    da_cs = jnp.cumsum(da, axis=2)
    xdt = (x * dt[..., None]).reshape(B, nc, chunk, H, P)
    bc = b_in.reshape(B, nc, chunk, N)
    cc = c_in.reshape(B, nc, chunk, N)

    y_intra, states = ssd_intra_chunk(xdt, da_cs, bc, cc, interpret=interpret)

    # inter-chunk recurrence (log-depth, jnp)
    gs = jnp.exp(da_cs[:, :, -1])  # (B, nc, H)

    def combine(left, right):
        g1, s1 = left
        g2, s2 = right
        return g1 * g2, s1 * g2[..., None, None] + s2

    g_run, s_run = jax.lax.associative_scan(combine, (gs, states), axis=1)
    s_prev = jnp.concatenate([jnp.zeros_like(s_run[:, :1]), s_run[:, :-1]], axis=1)
    in_decay = jnp.exp(da_cs)  # (B, nc, Q, H)
    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", cc, in_decay, s_prev)
    y = (y_intra + y_inter).reshape(B, L, H, P).astype(x.dtype)
    return y, s_run[:, -1]
