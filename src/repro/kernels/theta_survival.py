"""Pallas TPU kernel for the DECAFORK estimator sweep (the paper's only
dense compute hot-spot).

Every protocol round each visited node evaluates
    sum_c S_i(t - last_seen[i, c])
over its walk-tracking columns. At production scale (n ~ 10^5 nodes per
shard, W walk slots, B histogram bins) this is an O(n * W * B) sweep.

TPU adaptation (DESIGN.md §3): a GPU implementation would gather
``cum[i, r_c]`` per (node, column) — scattered random access. TPUs hate
gathers, so we restate the gather as a *compare-and-accumulate*:

    cum_i(r) = sum_b hist[i,b] * [r > b]
 => sum_c cum_i(r_c) = sum_b hist[i,b] * #{c : r_c > b}

i.e. build per-node bin counts with a broadcasted compare against an iota
over bins (pure VPU work on VMEM tiles), then contract counts against the
histogram — a dense reduction the VPU/MXU pipeline streams at full tilt.
No gather survives.

Block layout: grid over node tiles; each program holds
  last_seen (bn, W) int32 | hist (bn, B) f32 | total (bn, 1) f32
in VMEM. The (bn, W, B) compare intermediate sizes VMEM: bn=8, W=64,
B=1024 -> 2 MiB f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.estimator import survival_node_sums_rows
from repro.kernels.platform import default_interpret, pad_node_axis


DEFAULT_BLOCK_NODES = 8


def _theta_kernel(t_ref, ls_ref, hist_ref, tot_ref, out_ref):
    t = t_ref[0, 0]
    ls = ls_ref[...]  # (bn, W) int32
    hist = hist_ref[...]  # (bn, B) f32
    tot = tot_ref[...]  # (bn, 1) f32
    # the (bn, W, B) compare intermediate stays VMEM-resident; the math
    # itself is the shared estimator.survival_node_sums_rows core
    out_ref[...] = survival_node_sums_rows(ls, hist, tot[:, 0], t)[:, None]


@functools.partial(jax.jit, static_argnames=("block_nodes", "interpret"))
def theta_sums(
    last_seen: jax.Array,  # (n, W) int32
    hist: jax.Array,  # (n, B) f32
    total: jax.Array,  # (n,) f32
    t: jax.Array,  # scalar int32
    *,
    block_nodes: int = DEFAULT_BLOCK_NODES,
    interpret: bool | None = None,
) -> jax.Array:
    """sum_c S_i(t - last_seen[i,c]) for every node i; (n,) f32.

    ``interpret=None`` resolves platform-aware: emulated on CPU, compiled
    on TPU (``kernels.platform.default_interpret``).
    """
    if interpret is None:
        interpret = default_interpret()
    n, W = last_seen.shape
    B = hist.shape[1]
    bn = min(block_nodes, n)
    last_seen, hist, total, pad = pad_node_axis(bn, last_seen, hist, total)
    grid = ((n + pad) // bn,)
    t_arr = jnp.asarray(t, jnp.int32).reshape(1, 1)
    out = pl.pallas_call(
        _theta_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),  # t (broadcast)
            pl.BlockSpec((bn, W), lambda i: (i, 0)),  # last_seen tile
            pl.BlockSpec((bn, B), lambda i: (i, 0)),  # hist tile
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),  # total tile
        ],
        out_specs=pl.BlockSpec((bn, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n + pad, 1), jnp.float32),
        interpret=interpret,
    )(t_arr, last_seen, hist, total[:, None])
    return out[:n, 0]
