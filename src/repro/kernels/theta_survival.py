"""Pallas TPU kernel for the DECAFORK estimator sweep (the paper's only
dense compute hot-spot).

Every protocol round each visited node evaluates
    sum_c S_i(t - last_seen[i, c])
over its walk-tracking columns. At production scale (n ~ 10^5 nodes per
shard, W walk slots, B histogram bins) this is an O(n * W * B) sweep.

TPU adaptation (DESIGN.md §3): a GPU implementation would gather
``cum[i, r_c]`` per (node, column) — scattered random access. TPUs hate
gathers, so we restate the gather as a *compare-and-accumulate*:

    cum_i(r) = sum_b hist[i,b] * [r > b]
 => sum_c cum_i(r_c) = sum_b hist[i,b] * #{c : r_c > b}

i.e. build per-node bin counts with a broadcasted compare against an iota
over bins (pure VPU work on VMEM tiles), then contract counts against the
histogram — a dense reduction the VPU/MXU pipeline streams at full tilt.
No gather survives.

Block layout: grid over node tiles; each program holds
  last_seen (bn, W) int32 | hist (bn, B) f32 | total (bn, 1) f32
in VMEM. The (bn, W, B) compare intermediate sizes VMEM: bn=8, W=64,
B=1024 -> 2 MiB f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.platform import default_interpret


DEFAULT_BLOCK_NODES = 8


def _theta_kernel(t_ref, ls_ref, hist_ref, tot_ref, out_ref):
    t = t_ref[0, 0]
    ls = ls_ref[...]  # (bn, W) int32
    hist = hist_ref[...]  # (bn, B) f32
    tot = tot_ref[...]  # (bn, 1) f32
    bn, W = ls.shape
    B = hist.shape[1]

    valid = ls >= 0
    r = jnp.where(valid, t - ls, 0)  # (bn, W)
    bidx = jax.lax.broadcasted_iota(jnp.int32, (bn, W, B), 2)
    over = (r[:, :, None] > bidx) & valid[:, :, None]  # (bn, W, B)
    cnt = jnp.sum(over.astype(jnp.float32), axis=1)  # (bn, B)
    mass = jnp.sum(cnt * hist, axis=1, keepdims=True)  # (bn, 1)
    n_valid = jnp.sum(valid.astype(jnp.float32), axis=1, keepdims=True)
    tot_safe = jnp.maximum(tot, 1.0)
    s = n_valid - mass / tot_safe
    s = jnp.where(tot > 0, s, n_valid)
    out_ref[...] = s


@functools.partial(jax.jit, static_argnames=("block_nodes", "interpret"))
def theta_sums(
    last_seen: jax.Array,  # (n, W) int32
    hist: jax.Array,  # (n, B) f32
    total: jax.Array,  # (n,) f32
    t: jax.Array,  # scalar int32
    *,
    block_nodes: int = DEFAULT_BLOCK_NODES,
    interpret: bool | None = None,
) -> jax.Array:
    """sum_c S_i(t - last_seen[i,c]) for every node i; (n,) f32.

    ``interpret=None`` resolves platform-aware: emulated on CPU, compiled
    on TPU (``kernels.platform.default_interpret``).
    """
    if interpret is None:
        interpret = default_interpret()
    n, W = last_seen.shape
    B = hist.shape[1]
    bn = min(block_nodes, n)
    if n % bn:
        raise ValueError(f"n={n} must be a multiple of block_nodes={bn}")
    grid = (n // bn,)
    t_arr = jnp.asarray(t, jnp.int32).reshape(1, 1)
    out = pl.pallas_call(
        _theta_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),  # t (broadcast)
            pl.BlockSpec((bn, W), lambda i: (i, 0)),  # last_seen tile
            pl.BlockSpec((bn, B), lambda i: (i, 0)),  # hist tile
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),  # total tile
        ],
        out_specs=pl.BlockSpec((bn, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=interpret,
    )(t_arr, last_seen, hist, total[:, None])
    return out[:, 0]
