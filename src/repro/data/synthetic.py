"""Synthetic data pipeline.

Two roles:
  1. `random_batch_like` — dtype/shape-correct random batches for smoke
     tests and throughput benchmarks (any architecture family);
  2. a *learnable* task for the end-to-end decentralized-training example:
     sequences from a fixed random first-order Markov chain over the
     vocabulary. Its per-token CE optimum is the chain's conditional
     entropy, so training progress is measurable against a known floor.
     Each graph node owns an (optionally non-iid) shard: node i samples
     with a node-specific starting distribution, and in the "hetero"
     setting a node-specific temperature perturbation of the chain —
     the paper's "local data of the visited node".
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class SyntheticTask(NamedTuple):
    logits: jax.Array  # (V, V) unnormalized row transition logits
    entropy: float  # conditional entropy of the chain (nats/token)


def make_markov_task(
    vocab: int, key=None, temperature: float = 2.0, rank: int = 16
) -> SyntheticTask:
    """Low-rank chain: logits = U V^T (rank << vocab), so the transition
    structure is learnable from ~rank * vocab observations instead of
    vocab^2 — a few hundred small batches suffice to approach the floor."""
    if key is None:
        key = jax.random.key(1234)
    k1, k2 = jax.random.split(key)
    u = jax.random.normal(k1, (vocab, rank))
    v = jax.random.normal(k2, (rank, vocab))
    g = u @ v / jnp.sqrt(rank) * temperature
    probs = jax.nn.softmax(g, axis=-1)
    # stationary distribution via power iteration
    pi = jnp.full((vocab,), 1.0 / vocab)
    for _ in range(64):
        pi = pi @ probs
    h_cond = -jnp.sum(pi[:, None] * probs * jnp.log(probs + 1e-12))
    return SyntheticTask(logits=g, entropy=float(h_cond))


def sample_batch(task: SyntheticTask, key, batch: int, seq: int, node_id: int = 0):
    """Tokens + next-token labels from the chain; deterministic per
    (key, node_id) — node_id selects the node's data shard."""
    key = jax.random.fold_in(key, node_id)
    k0, kseq = jax.random.split(key)
    vocab = task.logits.shape[0]
    start = jax.random.randint(k0, (batch,), 0, vocab)

    def step(tok, k):
        nxt = jax.random.categorical(k, task.logits[tok])
        return nxt, nxt

    keys = jax.random.split(kseq, seq)
    _, toks = jax.lax.scan(step, start, keys)
    toks = jnp.moveaxis(toks, 0, 1)  # (batch, seq)
    full = jnp.concatenate([start[:, None], toks], axis=1)
    return {"tokens": full[:, :-1].astype(jnp.int32), "labels": full[:, 1:].astype(jnp.int32)}


def node_batches(task: SyntheticTask, key, n_nodes: int, batch: int, seq: int):
    """(n_nodes, batch, seq) batches — one shard per graph node."""
    fn = lambda nid: sample_batch(task, key, batch, seq, nid)
    out = jax.vmap(lambda nid: fn(nid))(jnp.arange(n_nodes))
    return out


def random_batch_like(spec, key=None):
    """Materialize a random batch matching a ShapeDtypeStruct dict."""
    if key is None:
        key = jax.random.key(0)
    out = {}
    for i, (name, s) in enumerate(sorted(spec.items())):
        k = jax.random.fold_in(key, i)
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jax.random.randint(k, s.shape, 0, 64, dtype=s.dtype)
        else:
            out[name] = jax.random.normal(k, s.shape, dtype=s.dtype)
    return out
