from repro.data.synthetic import (
    SyntheticTask,
    make_markov_task,
    sample_batch,
    node_batches,
    random_batch_like,
)

__all__ = [
    "SyntheticTask",
    "make_markov_task",
    "sample_batch",
    "node_batches",
    "random_batch_like",
]
