from repro.checkpoint.checkpoint import (
    CheckpointMismatchError,
    load_pytree,
    save_pytree,
    save_walk_snapshot,
)

__all__ = [
    "CheckpointMismatchError",
    "save_pytree",
    "load_pytree",
    "save_walk_snapshot",
]
