from repro.checkpoint.checkpoint import save_pytree, load_pytree, save_walk_snapshot

__all__ = ["save_pytree", "load_pytree", "save_walk_snapshot"]
