"""Minimal dependency-free pytree checkpointing (npz + path-keyed leaves).

A forked walk *is* a live checkpoint copy — the same serialization is used
to snapshot a walk's model replica so a restarted node can re-enter the
system (``save_walk_snapshot``).
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np

from repro.utils.tree import tree_flatten_with_paths


def _atomic_write(path: str, write_fn) -> None:
    """Write via a same-directory temp file + ``os.replace``.

    A crash (or raised exception) mid-write leaves at worst an orphaned
    ``*.tmp-*`` file — the previous snapshot at ``path`` stays intact,
    and readers never observe a half-written file.
    """
    tmp = f"{path}.tmp-{os.getpid()}"
    try:
        with open(tmp, "wb") as f:
            write_fn(f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def save_pytree(path: str, tree: Any, metadata: dict | None = None) -> None:
    flat = tree_flatten_with_paths(tree)
    arrays = {}
    for p, leaf in flat:
        a = np.asarray(leaf)
        if a.dtype.name == "bfloat16":  # npz can't serialize ml_dtypes
            a = a.astype(np.float32)
        arrays[p] = a
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    # np.savez appends ".npz" to bare string paths; match that name, but
    # stage both files through a temp + os.replace so a crash mid-write
    # never shadows the previous good snapshot with a corrupt one.
    npz_path = path if path.endswith(".npz") else path + ".npz"
    _atomic_write(npz_path, lambda f: np.savez(f, **arrays))
    if metadata is not None:
        blob = json.dumps(metadata, indent=2, default=str).encode()
        _atomic_write(path + ".meta.json", lambda f: f.write(blob))


def load_pytree(path: str, like: Any) -> Any:
    """Restore into the structure of `like` (shape/dtype checked)."""
    with np.load(path if path.endswith(".npz") else path + ".npz") as data:
        flat = tree_flatten_with_paths(like)
        leaves = []
        for p, ref in flat:
            if p not in data:
                raise KeyError(f"checkpoint missing leaf {p!r}")
            arr = data[p]
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(f"shape mismatch for {p}: {arr.shape} vs {ref.shape}")
            leaves.append(arr.astype(ref.dtype))
    treedef = jax.tree.structure(like)
    return jax.tree.unflatten(treedef, leaves)


def save_walk_snapshot(path: str, replica_params: Any, walk_slot: int, step: int) -> None:
    snap = jax.tree.map(lambda x: x[walk_slot], replica_params)
    save_pytree(path, snap, metadata={"walk_slot": walk_slot, "step": step})
