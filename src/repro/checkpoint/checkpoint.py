"""Minimal dependency-free pytree checkpointing (npz + path-keyed leaves).

A forked walk *is* a live checkpoint copy — the same serialization is used
to snapshot a walk's model replica so a restarted node can re-enter the
system (``save_walk_snapshot``), and the durable-execution layer
(``repro.api.plan`` segment snapshots, ``repro.api.store``) rides the
same two functions.

Writes are atomic (same-directory temp + fsync + ``os.replace``); loads
are *checked*: every leaf must match the ``like`` template's path, shape
AND dtype, or :class:`CheckpointMismatchError` names every offender — a
stale snapshot with a drifted schema must never silently reinterpret
arrays. The one sanctioned dtype mismatch is the bfloat16 round-trip:
npz cannot hold ml_dtypes, so bf16 leaves are stored as float32 (exact —
f32 is a superset) and cast back on load (exact — the values are bf16
representable).
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np

from repro.utils.faults import SimulatedKill, fault_point
from repro.utils.tree import tree_flatten_with_paths

__all__ = [
    "CheckpointMismatchError",
    "save_pytree",
    "load_pytree",
    "save_walk_snapshot",
]


def _is_prng_key(leaf: Any) -> bool:
    """Typed PRNG key arrays (``jax.random.key``) need an explicit
    encoding: npz holds their raw ``key_data`` (uint32), and a key-typed
    ``like`` leaf wraps it back — exactly, the data IS the key."""
    dtype = getattr(leaf, "dtype", None)
    return dtype is not None and jax.dtypes.issubdtype(
        dtype, jax.dtypes.prng_key
    )


def _wrap_key(arr: np.ndarray, ref: Any) -> jax.Array:
    try:
        return jax.random.wrap_key_data(
            jax.numpy.asarray(arr), impl=jax.random.key_impl(ref)
        )
    except (AttributeError, TypeError):  # older impl-spec surface
        return jax.random.wrap_key_data(jax.numpy.asarray(arr))


class CheckpointMismatchError(ValueError):
    """A snapshot's leaves disagree with the expected structure.

    Raised by :func:`load_pytree` when any stored leaf's shape or dtype
    differs from the ``like`` template — the error message lists every
    mismatching leaf path with the stored vs expected spec.
    """

    def __init__(self, path: str, mismatches: list):
        self.path = path
        self.mismatches = list(mismatches)
        lines = "\n  ".join(self.mismatches)
        super().__init__(
            f"checkpoint {path!r} does not match the expected structure "
            f"({len(self.mismatches)} leaf mismatch(es)):\n  {lines}"
        )


def _atomic_write(path: str, write_fn) -> None:
    """Write via a same-directory temp file + ``os.replace``.

    A crash (or raised exception) mid-write leaves at worst an orphaned
    ``*.tmp-*`` file — the previous snapshot at ``path`` stays intact,
    and readers never observe a half-written file.

    Fault site ``checkpoint.write`` fires before anything touches disk;
    a scheduled :class:`~repro.utils.faults.Torn` action makes this
    writer behave like its pre-atomic ancestor dying mid-write: the
    final path gets a truncated prefix of the payload, then the
    "process" dies (:class:`~repro.utils.faults.SimulatedKill`). Readers
    must survive that file.
    """
    torn = fault_point("checkpoint.write", tearable=True)
    tmp = f"{path}.tmp-{os.getpid()}"
    try:
        with open(tmp, "wb") as f:
            write_fn(f)
            f.flush()
            os.fsync(f.fileno())
        if torn is not None:
            with open(tmp, "rb") as f:
                prefix = f.read(torn.keep_bytes)
            with open(path, "wb") as f:  # deliberately non-atomic
                f.write(prefix)
            raise SimulatedKill("checkpoint.write")
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def save_pytree(path: str, tree: Any, metadata: dict | None = None) -> None:
    flat = tree_flatten_with_paths(tree)
    arrays = {}
    for p, leaf in flat:
        if _is_prng_key(leaf):
            leaf = jax.random.key_data(leaf)
        a = np.asarray(leaf)
        if a.dtype.name == "bfloat16":  # npz can't serialize ml_dtypes
            a = a.astype(np.float32)
        arrays[p] = a
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    # np.savez appends ".npz" to bare string paths; match that name, but
    # stage both files through a temp + os.replace so a crash mid-write
    # never shadows the previous good snapshot with a corrupt one.
    npz_path = path if path.endswith(".npz") else path + ".npz"
    _atomic_write(npz_path, lambda f: np.savez(f, **arrays))
    if metadata is not None:
        blob = json.dumps(metadata, indent=2, default=str).encode()
        _atomic_write(path + ".meta.json", lambda f: f.write(blob))


def load_pytree(path: str, like: Any) -> Any:
    """Restore into the structure of ``like``.

    Every leaf is validated against its template: a missing path raises
    ``KeyError``; any shape OR dtype drift raises
    :class:`CheckpointMismatchError` listing every mismatching leaf
    (bf16 templates accept the documented float32 npz encoding and are
    cast back exactly).
    """
    npz_path = path if path.endswith(".npz") else path + ".npz"
    with np.load(npz_path) as data:
        flat = tree_flatten_with_paths(like)
        leaves = []
        mismatches = []
        for p, ref in flat:
            if p not in data:
                raise KeyError(f"checkpoint missing leaf {p!r}")
            arr = data[p]
            is_key = _is_prng_key(ref)
            # a key-typed template validates against its raw key_data
            spec = jax.random.key_data(ref) if is_key else ref
            ref_dtype = np.dtype(spec.dtype)
            if tuple(arr.shape) != tuple(spec.shape):
                mismatches.append(
                    f"{p}: stored shape {tuple(arr.shape)} != expected "
                    f"{tuple(spec.shape)}"
                )
                continue
            if arr.dtype != ref_dtype and not (
                ref_dtype.name == "bfloat16" and arr.dtype == np.float32
            ):
                mismatches.append(
                    f"{p}: stored dtype {arr.dtype} != expected {ref_dtype}"
                )
                continue
            if is_key:
                leaves.append(_wrap_key(arr, ref))
            else:
                leaves.append(arr.astype(ref.dtype))
        if mismatches:
            raise CheckpointMismatchError(npz_path, mismatches)
    treedef = jax.tree.structure(like)
    return jax.tree.unflatten(treedef, leaves)


def save_walk_snapshot(path: str, replica_params: Any, walk_slot: int, step: int) -> None:
    snap = jax.tree.map(lambda x: x[walk_slot], replica_params)
    save_pytree(path, snap, metadata={"walk_slot": walk_slot, "step": step})
