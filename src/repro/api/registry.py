"""Named-experiment registry: config-dict-driven studies, by name.

A *registered experiment* is a builder function that turns plain keyword
arguments (the kind that live in a JSON/YAML config or an HTTP request)
into an :class:`~repro.api.Experiment`. Registration gives a study a
stable name, which is what makes it reproducible from outside the
process:

    from repro.api import registry, Experiment

    @registry.register("fig4-eps-grid")
    def _fig4(n=100, steps=4500, **kw):
        ...
        return Experiment(...)

    exp = Experiment.from_config({"experiment": "fig4-eps-grid", "n": 100})

``Experiment.from_config`` is the single entry point config-driven
callers (the :class:`~repro.api.service.ExperimentService`, CLIs,
notebooks) use: the ``"experiment"`` key selects the builder, every other
key is passed through as a keyword override.

The built-in ``"walks"`` builder covers the common case — a generated
graph + ``ProtocolConfig``/``FailureConfig`` field dicts + optional named
scenario rows — so simple studies need no custom builder at all.
"""
from __future__ import annotations

from typing import Callable, Dict

__all__ = ["register", "get", "names", "build"]

_REGISTRY: Dict[str, Callable] = {}


def register(name: str, builder: Callable | None = None):
    """Register ``builder`` under ``name``; usable as a decorator.

    Re-registering a name overwrites it (last definition wins, so
    notebooks can iterate on a builder without restarting).
    """

    def _register(fn: Callable):
        if not callable(fn):
            raise TypeError(f"experiment builder for {name!r} must be callable")
        _REGISTRY[str(name)] = fn
        return fn

    return _register(builder) if builder is not None else _register


def get(name: str) -> Callable:
    """The builder registered under ``name``; KeyError lists what exists."""
    try:
        return _REGISTRY[name]
    except KeyError:
        pass
    # optional subsystems register their builders on import; pull them in
    # lazily so ``repro.api`` never hard-depends on them at import time
    import importlib

    for mod in ("repro.zoo",):
        try:
            importlib.import_module(mod)
        except ImportError:  # pragma: no cover - subsystem absent
            continue
        if name in _REGISTRY:
            return _REGISTRY[name]
    raise KeyError(
        f"unknown experiment {name!r}; registered experiments: "
        f"{sorted(_REGISTRY)}"
    ) from None


def names() -> tuple:
    """Registered experiment names, sorted."""
    return tuple(sorted(_REGISTRY))


def build(name: str, /, **overrides):
    """Build the named experiment with keyword overrides applied."""
    exp = get(name)(**overrides)
    from repro.api.experiment import Experiment

    if not isinstance(exp, Experiment):
        raise TypeError(
            f"experiment builder {name!r} returned {type(exp).__name__}, "
            "expected an Experiment"
        )
    return exp


# ---------------------------------------------------------------------------
# built-in: the generic config-driven study
# ---------------------------------------------------------------------------


def _scenario_rows(scenarios):
    from repro.core.failures import FailureConfig
    from repro.core.protocol import ProtocolConfig
    from repro.sweep.scenario import Scenario

    rows = []
    for i, row in enumerate(scenarios):
        row = dict(row)
        rows.append(
            Scenario(
                name=str(row.pop("name", f"scenario{i}")),
                pcfg=ProtocolConfig(**row.pop("protocol", {})),
                fcfg=FailureConfig(**row.pop("failures", {})),
            )
        )
        if row:
            raise TypeError(
                f"scenario row {i} has unknown keys {sorted(row)}; expected "
                "name/protocol/failures"
            )
    return rows


@register("walks")
def _walks(
    *,
    graph: str = "regular",
    n: int = 64,
    graph_seed: int = 0,
    graph_kwargs: dict | None = None,
    steps: int = 500,
    protocol: dict | None = None,
    failures: dict | None = None,
    scenarios=None,
    outputs="scalars",
    placement="auto",
    name: str | None = None,
):
    """The generic study: a generated graph running the self-regulation
    protocol. ``protocol=``/``failures=`` are ``ProtocolConfig`` /
    ``FailureConfig`` field dicts; ``scenarios=`` rows are dicts of
    ``{"name", "protocol", "failures"}``."""
    from repro.api.experiment import Experiment
    from repro.core.failures import FailureConfig
    from repro.core.protocol import ProtocolConfig
    from repro.graphs.generators import make_graph

    g = make_graph(graph, int(n), int(graph_seed), **(graph_kwargs or {}))
    pcfg = None
    fcfg = None
    if protocol is not None or not scenarios:
        pcfg = ProtocolConfig(**(protocol or {}))
        fcfg = FailureConfig(**(failures or {}))
    elif failures is not None:
        raise TypeError("failures= given without protocol=")
    return Experiment(
        graph=g,
        protocol=pcfg,
        failures=fcfg,
        steps=int(steps),
        scenarios=_scenario_rows(scenarios) if scenarios else None,
        outputs=outputs,
        placement=placement,
        name=name,
    )
