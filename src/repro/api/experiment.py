"""The declarative Experiment spec: describe a study, then ``plan()`` it.

One object names everything a run of the paper's system needs — graph,
protocol, failures, payload, output selection, placement policy — and
every execution mode hangs off the compiled :class:`~repro.api.Plan` it
lowers to:

    from repro.api import Experiment

    exp = Experiment(graph=g, protocol=pcfg, failures=fcfg, steps=4500)
    final, outs = exp.run(key=0)            # one trajectory
    outs = exp.ensemble(seeds=50)           # the paper's seed ensembles
    res = exp.sweep(scenarios, seeds=50)    # mixed regimes, grouped,
                                            # one compile per structure

Comparative studies — multi-stream RW vs gossip, Pac-Man-attack regimes,
epsilon grids, topology churn — are a scenario-list swap on the same
Experiment, not a choice of runner: the Plan owns static-signature
grouping, the process-wide compile cache and the placement decision, so
every mode batches and caches identically. ``run``/``ensemble``/``sweep``
on the Experiment are conveniences for ``exp.plan().<mode>(...)``;
re-planning is cheap (compiled executables live in the process-wide
cache, keyed on static structure, never on the Experiment instance).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax

from repro.api.placement import Placement
from repro.api.plan import Plan
from repro.api.results import SweepResult
from repro.core.failures import FailureConfig
from repro.core.outputs import split_outputs
from repro.core.protocol import ProtocolConfig

__all__ = ["Experiment"]


@dataclasses.dataclass(frozen=True)
class Experiment:
    """A declarative experiment spec (see module docstring).

    Fields:
      graph       the static superset topology (``repro.graphs.Graph``);
      protocol    the base :class:`ProtocolConfig` — required for
                  ``run``/``ensemble``; optional when only sweeping;
      failures    the base :class:`FailureConfig` (defaults to the
                  failure-free config when a protocol is given);
      steps       trajectory length (static);
      scenarios   optional declared scenario rows (``Scenario`` /
                  ``(pcfg, fcfg)`` pairs / ``.pcfg``/``.fcfg`` objects)
                  — the default list ``sweep()`` runs;
      payload     optional :class:`~repro.core.payload.Payload` workload;
      outputs     what the trajectory scan records: ``None`` /
                  ``'scalars'`` / ``'full'`` / an ``OutputSpec`` / a
                  field-name sequence that may mix ``StepOutputs`` names
                  with the payload's own output fields (thinning BOTH
                  sides — see ``core.outputs.split_outputs``);
      placement   scenario-axis device placement policy
                  (:class:`Placement` or ``'auto'|'sharded'|'local'``);
      name        optional label (reports, repr).
    """

    graph: Any
    protocol: ProtocolConfig | None = None
    failures: FailureConfig | None = None
    steps: int | None = None
    scenarios: Sequence | None = None
    payload: Any = None
    outputs: Any = None
    placement: Placement | str | None = "auto"
    name: str | None = None

    def __post_init__(self):
        if self.steps is None:
            raise TypeError("Experiment needs steps= (trajectory length)")
        if self.failures is not None and self.protocol is None:
            raise TypeError("failures= given without protocol=")
        if self.protocol is None and not self.scenarios:
            raise TypeError(
                "Experiment needs a base scenario (protocol=/failures=) "
                "and/or scenarios=[...]"
            )
        if self.protocol is not None and self.failures is None:
            object.__setattr__(self, "failures", FailureConfig())
        object.__setattr__(
            self, "placement", Placement.resolve(self.placement)
        )
        if self.scenarios is not None:
            object.__setattr__(self, "scenarios", tuple(self.scenarios))
        object.__setattr__(self, "steps", int(self.steps))
        # resolve output selection once, eagerly: bad field names fail at
        # spec time, not at trace time
        spec, pspec = split_outputs(self.outputs, self.payload)
        object.__setattr__(self, "_spec", spec)
        object.__setattr__(self, "_pspec", pspec)

    # -- construction ------------------------------------------------------

    @classmethod
    def from_config(cls, config) -> "Experiment":
        """Build a registered experiment from a plain config mapping.

        ``config["experiment"]`` names a builder in
        :mod:`repro.api.registry`; every other key passes through as a
        keyword override. This is how config-driven callers (the
        ExperimentService, CLIs) reproduce a study by name.
        """
        from repro.api import registry

        cfg = dict(config)
        name = cfg.pop("experiment", None)
        if not name:
            raise ValueError(
                "config needs an 'experiment' key naming a registered "
                f"experiment; registered: {list(registry.names())}"
            )
        return registry.build(name, **cfg)

    # -- lowering ----------------------------------------------------------

    def plan(self) -> Plan:
        """Lower the spec to a compiled :class:`Plan` (cheap: executables
        come from the process-wide signature-keyed cache)."""
        return Plan(self)

    # -- conveniences (each delegates to a fresh Plan) ---------------------

    def run(self, key: jax.Array | int = 0):
        """One trajectory of the base scenario; see :meth:`Plan.run`."""
        return self.plan().run(key)

    def ensemble(self, seeds: int, base_key: jax.Array | int = 0):
        """vmap over seeds; see :meth:`Plan.ensemble`."""
        return self.plan().ensemble(seeds, base_key)

    def sweep(
        self,
        scenarios: Sequence | None = None,
        *,
        seeds: int,
        base_key: jax.Array | int = 0,
        store=None,
    ) -> SweepResult:
        """Mixed scenario list, one compile per static group; see
        :meth:`Plan.sweep` (``store=`` enables disk-backed persistence)."""
        return self.plan().sweep(
            scenarios, seeds=seeds, base_key=base_key, store=store
        )

    def __repr__(self):
        label = f" {self.name!r}" if self.name else ""
        parts = [f"n={getattr(self.graph, 'n', '?')}", f"steps={self.steps}"]
        if self.protocol is not None:
            parts.append(f"protocol={self.protocol.algorithm}")
        if self.scenarios:
            parts.append(f"scenarios={len(self.scenarios)}")
        if self.payload is not None:
            parts.append(f"payload={type(self.payload).__name__}")
        return f"Experiment{label}({', '.join(parts)})"
