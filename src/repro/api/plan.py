"""Compiled execution plans: the layer between an Experiment and XLA.

An :class:`~repro.api.Experiment` *describes* a study; ``plan()`` lowers
it into a :class:`Plan` that owns the three things the four legacy
runners used to split between themselves and their callers:

  1. **static-signature grouping** — which scenario rows can share one
     compiled program (``Plan.groups``; the orchestration that lived in
     ``sweep/engine.run_scenarios``);
  2. **the compile cache** — a process-wide table of jitted executables
     keyed on :func:`plan_signature`, so the same static structure never
     re-lowers across ``.run`` / ``.ensemble`` / ``.sweep`` calls, across
     re-planned Experiments, across figures (``cache_stats`` exposes the
     entry and XLA-compile counts the tests assert on);
  3. **the placement decision** — ``Placement`` applied to the stacked
     scenario leaves at exactly one point.

The executables are jitted wrappers over the three un-jitted cores in
``core/simulator.py`` (one trajectory / vmap over seeds / vmap over
(scenario, seed)); everything traces through the same ``_run_core``, so
``sweep(...)[i]`` == ``ensemble`` on scenario ``i`` == the single
``run``, bitwise, under the same base key.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.api.placement import Placement
from repro.api.results import SweepResult
from repro.core import simulator as sim
from repro.graphs.spectral import stationary_distribution
from repro.graphs.state import mirror_indices
from repro.utils.faults import fault_point

__all__ = [
    "Plan",
    "plan_signature",
    "cache_stats",
    "clear_cache",
]

_STATIC_ARGNAMES = ("steps", "n", "payload", "spec", "pspec")
_SEG_STATIC_ARGNAMES = ("seg_len",) + _STATIC_ARGNAMES
_CORES = {
    "run": sim._run_core,
    "ensemble": sim._run_ensemble_core,
    "sweep": sim._sweep_core,
    # durable-execution segment cores: carry -> (carry', recorded chunk)
    "seg_run": sim._seg_run_core,
    "seg_ensemble": sim._seg_ensemble_core,
    "seg_sweep": sim._seg_sweep_core,
}
_MODE_STATICS = {
    mode: (_SEG_STATIC_ARGNAMES if mode.startswith("seg_") else _STATIC_ARGNAMES)
    for mode in _CORES
}

# the process-wide compile cache: (mode, signature) -> jitted executable.
# One slot per static program structure; the executables themselves are
# shared per mode (_JITTED) — jax keys the underlying compilation cache
# on (static kwargs, avals), so distinct signatures compile distinct XLA
# programs through one wrapper, and re-running the same structure never
# re-lowers or recompiles.
_EXECUTABLES: dict = {}
_JITTED: dict = {}


def payload_key(payload):
    """The signature component identifying a payload's static program.

    A payload declaring a stable :meth:`~repro.core.payload.Payload.signature`
    contributes a value tuple — two structurally identical payload
    instances then share one cache slot (and, since ``Payload.__eq__``
    follows the same key, one compiled XLA program), and the tuple is
    serializable for cross-process result-store keys. A signature-less
    payload contributes the object itself (identity hashing, the
    pre-signature behavior).
    """
    if payload is None:
        return None
    key = getattr(payload, "_signature_key", lambda: None)()
    return payload if key is None else ("payload",) + key


def plan_signature(
    mode: str,
    n: int,
    max_deg: int,
    steps: int,
    pcfg,
    schedule_lens: Tuple[int, ...],
    payload,
    spec,
    pspec,
    fcfg_static: tuple = (),
) -> tuple:
    """Hashable static signature of one compiled program.

    Two runs share an executable iff their signatures match: program
    shape comes from the protocol's static fields (algorithm /
    estimator_impl / max_walks / rt_bins / walk_variant / ...), the
    pytree structure of ``fork_prob`` (None vs value), the padded
    failure-schedule lengths (bursts, node crashes, extra Pac-Man ids,
    edge cuts), the failure config's static aux fields
    (``pacman_mobile`` — it changes the scan carry), the payload's
    :func:`payload_key` (a stable config tuple when the payload declares
    ``signature()``, the identity-hashed object otherwise), the output
    specs and the graph/trajectory dimensions. Traced numeric leaves
    (eps grids, rates, schedules, topology knobs) deliberately do NOT
    appear — they batch and re-run without recompiling.
    """
    return (
        mode,
        n,
        max_deg,
        steps,
        pcfg.static_fields,
        pcfg.fork_prob is None,
        tuple(schedule_lens),
        payload_key(payload),
        spec,
        pspec,
        tuple(fcfg_static),
    )


def _lower(mode: str, signature: tuple):
    """Resolve the executable for one NEW (mode, signature) cache slot.

    Called exactly once per fresh signature — the module-level seam the
    compile-count tests monkeypatch. The returned wrapper is shared per
    mode: jax's own cache keys compiled programs on (static kwargs,
    avals), which the signature mirrors, so slot bookkeeping and program
    caching agree.
    """
    fn = _JITTED.get(mode)
    if fn is None:
        fn = _JITTED[mode] = jax.jit(
            _CORES[mode], static_argnames=_MODE_STATICS[mode]
        )
    return fn


def executable(mode: str, signature: tuple):
    """The process-wide cache lookup: one jitted executable per
    (mode, static-signature), built on first use."""
    key = (mode, signature)
    fn = _EXECUTABLES.get(key)
    if fn is None:
        fn = _EXECUTABLES[key] = _lower(mode, signature)
    return fn


def cache_stats() -> dict:
    """Observability for the compile cache: ``entries`` is the number of
    distinct (mode, signature) slots ever lowered; ``xla_compiles`` the
    total number of XLA programs actually compiled (one per distinct
    (signature, batch shape) — a structure recompiles only for a new
    aval shape, e.g. a different seed count); ``by_mode`` splits the
    compile count per execution mode (run / ensemble / sweep).
    """
    by_mode = {m: f._cache_size() for m, f in _JITTED.items()}
    return {
        "entries": len(_EXECUTABLES),
        "xla_compiles": sum(by_mode.values()),
        "by_mode": by_mode,
    }


def clear_cache() -> None:
    """Drop every cached executable (tests only — a cleared cache means
    every structure re-lowers and recompiles on next use)."""
    _EXECUTABLES.clear()
    _JITTED.clear()


def _as_key(key) -> jax.Array:
    return jax.random.key(key) if isinstance(key, int) else key


def _schedule_lens(fcfg) -> tuple:
    """The shape-bearing failure-schedule lengths, in signature order."""
    return (
        fcfg.n_bursts, fcfg.n_node_crashes, fcfg.n_pacman, fcfg.n_edge_cuts
    )


class Plan:
    """A compiled execution plan for one Experiment (see module docstring).

    Construct via ``Experiment.plan()``. Methods:

      ``run(key=0)``                     one trajectory of the base
                                         (protocol, failures) scenario;
      ``ensemble(seeds, base_key=0)``    vmap over seeds;
      ``sweep_stacked(scenarios=None, *, seeds, base_key=0)``
                                         ONE static-structure stack ->
                                         outputs with leading (S, seeds)
                                         axes in one compiled call;
      ``sweep(scenarios=None, *, seeds, base_key=0)``
                                         arbitrary mixed lists: grouped by
                                         static signature, one compiled
                                         call per group, per-scenario
                                         results in input order
                                         (:class:`SweepResult`).

    All four share the process-wide executable cache, so re-running any
    of them with the same static structure — new keys, new eps grids, new
    failure rates, a re-planned Experiment — never recompiles.
    """

    def __init__(self, experiment):
        from repro.sweep.scenario import as_pair

        self.experiment = experiment
        self.graph = experiment.graph
        self.steps = experiment.steps
        self.payload = experiment.payload
        self.placement = experiment.placement
        self.spec = experiment._spec
        self.pspec = experiment._pspec
        self.n = self.graph.n
        self.neighbors = jnp.asarray(self.graph.neighbors)
        self.degrees = jnp.asarray(self.graph.degrees)
        self.mirror = jnp.asarray(mirror_indices(self.graph))
        self.max_deg = int(self.neighbors.shape[1])
        self._pi_cache = None
        if experiment.protocol is not None:
            self._base = (experiment.protocol, experiment.failures)
            if self.payload is not None:
                self.payload.validate(experiment.protocol)
        else:
            self._base = None
        # eager static validation of declared scenario rows
        for s in experiment.scenarios or ():
            pcfg, _ = as_pair(s)
            if self.payload is not None:
                self.payload.validate(pcfg)

    # -- shared preparation ------------------------------------------------

    def _pi(self, pcfg):
        if not pcfg.analytic_survival:
            return None
        if self._pi_cache is None:
            self._pi_cache = jnp.asarray(
                stationary_distribution(self.graph), jnp.float32
            )
        return self._pi_cache

    def _signature(self, mode, pcfg, schedule_lens, fcfg=None):
        return plan_signature(
            mode, self.n, self.max_deg, self.steps, pcfg,
            schedule_lens, self.payload, self.spec, self.pspec,
            fcfg_static=() if fcfg is None else fcfg.static_fields,
        )

    def _require_base(self, what: str):
        if self._base is None:
            raise ValueError(
                f"Plan.{what} needs a base scenario: construct the "
                "Experiment with protocol=/failures= (or use .sweep on its "
                "scenarios)"
            )
        return self._base

    # -- execution ---------------------------------------------------------

    def run(self, key: jax.Array | int = 0):
        """One trajectory; returns ``(final SimState, RecordedOutputs)``
        (with a payload: ``((state, payload carry), (RecordedOutputs,
        payload outputs))``)."""
        pcfg, fcfg = self._require_base("run")
        sig = self._signature("run", pcfg, _schedule_lens(fcfg), fcfg)
        return executable("run", sig)(
            _as_key(key), self.neighbors, self.degrees, self.mirror,
            self._pi(pcfg), pcfg, fcfg,
            steps=self.steps, n=self.n, payload=self.payload,
            spec=self.spec, pspec=self.pspec,
        )

    def ensemble(self, seeds: int, base_key: jax.Array | int = 0):
        """vmap over seeds: outputs with a leading ``(seeds,)`` axis."""
        pcfg, fcfg = self._require_base("ensemble")
        keys = jax.random.split(_as_key(base_key), seeds)
        sig = self._signature("ensemble", pcfg, _schedule_lens(fcfg), fcfg)
        return executable("ensemble", sig)(
            keys, self.neighbors, self.degrees, self.mirror,
            self._pi(pcfg), pcfg, fcfg,
            steps=self.steps, n=self.n, payload=self.payload,
            spec=self.spec, pspec=self.pspec,
        )

    # -- durable segmented execution ---------------------------------------
    #
    # The segmented path splits one scan into ``ceil(steps/segment_steps)``
    # compiled chunks through the ``seg_*`` cores. Because every PRNG
    # stream folds the CARRIED step counter (never a scan index), the
    # chunked trajectory is bitwise the monolithic one — the golden
    # resume tests hold this invariant. With a store, each boundary
    # write-behinds a self-contained snapshot (carry + recorded-so-far)
    # under the run's content key, so a killed process resumes from the
    # deepest loadable snapshot regardless of the chunking it now uses.

    def _segment_store(self, store, sig, stacked_configs, seeds, base):
        from repro.api.store import ResultStore

        store = ResultStore.resolve(store)
        if store is None:
            return None, None
        skey = store.sweep_key(sig, self.graph, stacked_configs, seeds, base)
        return store, skey

    def _drive_segments(
        self, mode, sig, init_carry, cfg_args, segment_steps, time_axis,
        store, skey,
    ):
        """Run one segmented trajectory/ensemble/sweep to completion.

        ``init_carry`` is a thunk (only called when no resumable snapshot
        exists); ``cfg_args`` is ``(pi, pcfg(s), fcfg(s))``;
        ``time_axis`` is where recorded chunks concatenate (run: 0,
        ensemble: 1, sweep: 2). Snapshot writes are best-effort — a
        failing store degrades to lost progress, never a failed run —
        and fault site ``segment.boundary`` fires after every boundary.
        """
        segment_steps = int(segment_steps)
        if segment_steps < 1:
            raise ValueError(f"segment_steps must be >= 1, got {segment_steps}")
        steps = self.steps
        done, carry, recorded = 0, None, None
        if store is not None:
            found = store.latest_segment(skey, max_steps=steps)
            if found is not None:
                done, snap = found
                carry, recorded = snap["carry"], snap["recorded"]
        if carry is None:
            carry = init_carry()
        while done < steps:
            seg = min(segment_steps, steps - done)
            seg_sig = sig + (("seg_len", seg),)
            carry, chunk = executable(mode, seg_sig)(
                carry, self.neighbors, self.degrees, self.mirror, *cfg_args,
                seg_len=seg, steps=steps, n=self.n, payload=self.payload,
                spec=self.spec, pspec=self.pspec,
            )
            recorded = chunk if recorded is None else jax.tree_util.tree_map(
                lambda a, b: jnp.concatenate((a, b), axis=time_axis),
                recorded, chunk,
            )
            done += seg
            if store is not None and done < steps:
                try:
                    store.put_segment(
                        skey, done,
                        jax.block_until_ready(
                            {"carry": carry, "recorded": recorded}
                        ),
                        extra_meta={"mode": mode, "total_steps": steps},
                    )
                except Exception as exc:  # write-behind is best-effort
                    import warnings

                    warnings.warn(
                        f"segment write-behind failed at {done}/{steps} "
                        f"steps: {exc!r}"
                    )
            fault_point("segment.boundary")
        return carry, recorded

    def run_segmented(
        self, key: jax.Array | int = 0, *, segment_steps: int, store=None
    ):
        """:meth:`run`, executed in resumable segments — same return
        value, bitwise. ``store=`` enables boundary snapshots (and
        resume from them); on completion the snapshots are cleared."""
        pcfg, fcfg = self._require_base("run_segmented")
        base = _as_key(key)
        sig = self._signature("seg_run", pcfg, _schedule_lens(fcfg), fcfg)
        store, skey = self._segment_store(store, sig, (pcfg, fcfg), 1, base)
        carry, recorded = self._drive_segments(
            "seg_run", sig,
            lambda: sim._init_carry(
                base, self.neighbors, pcfg, fcfg, self.steps, self.n,
                self.payload,
            ),
            (self._pi(pcfg), pcfg, fcfg), segment_steps, 0, store, skey,
        )
        if store is not None:
            store.clear_segments(skey)
        final = sim._finalize_segmented(carry, self.n, pcfg, self.payload)
        return final, recorded

    def ensemble_segmented(
        self,
        seeds: int,
        base_key: jax.Array | int = 0,
        *,
        segment_steps: int,
        store=None,
    ):
        """:meth:`ensemble` in resumable segments — same outputs,
        bitwise (leading ``(seeds,)`` axis, time on axis 1)."""
        pcfg, fcfg = self._require_base("ensemble_segmented")
        base = _as_key(base_key)
        keys = jax.random.split(base, seeds)
        sig = self._signature("seg_ensemble", pcfg, _schedule_lens(fcfg), fcfg)
        store, skey = self._segment_store(
            store, sig, (pcfg, fcfg), seeds, base
        )
        _carry, recorded = self._drive_segments(
            "seg_ensemble", sig,
            lambda: sim._init_ensemble_carry(
                keys, self.neighbors, pcfg, fcfg, self.steps, self.n,
                self.payload,
            ),
            (self._pi(pcfg), pcfg, fcfg), segment_steps, 1, store, skey,
        )
        if store is not None:
            store.clear_segments(skey)
        return recorded

    def sweep_stacked(
        self,
        scenarios: Sequence | None = None,
        *,
        seeds: int,
        base_key: jax.Array | int = 0,
        store=None,
        segment_steps: int | None = None,
    ):
        """One static-structure scenario stack x seeds in ONE compiled
        call; outputs carry leading ``(S, seeds)`` axes.

        Every scenario uses the same per-seed keys ``ensemble`` derives
        from ``base_key``, so ``sweep_stacked(...)[i]`` is bitwise equal
        to ``ensemble`` on scenario ``i``. Scenarios must share one
        static signature (mixed lists: use :meth:`sweep`); the Plan's
        ``Placement`` decides scenario-axis device placement here.

        ``store=`` (None | ``'env'`` | path | ``ResultStore``) enables
        disk-backed result persistence: a store-warm call returns the
        cached pytree without tracing, compiling or executing anything —
        the content key covers the plan signature, the graph, every
        stacked scenario leaf, ``seeds`` and the base key material.

        ``segment_steps=`` switches to the durable segmented executor:
        the scan runs in resumable chunks (bitwise identical to the
        monolithic call), and with a store each boundary write-behinds a
        snapshot so a killed process resumes a half-finished sweep from
        disk. The final result lands under the SAME content key as the
        monolithic path — segmented and monolithic warm hits are
        interchangeable — and ``segment_steps`` itself never enters the
        store key (only the per-chunk compile signatures).
        """
        from repro.sweep.scenario import as_pair, stack_configs

        scenarios = self._scenarios(scenarios, "sweep_stacked")
        base = _as_key(base_key)
        pcfgs, fcfgs = stack_configs(scenarios)
        pcfg0 = as_pair(scenarios[0])[0]
        if self.payload is not None:
            self.payload.validate(pcfg0)
        # schedule lengths AFTER stacking: pad_bursts reconciled them
        lens = (
            int(jnp.shape(fcfgs.burst_times)[-1]),
            int(jnp.shape(fcfgs.node_crash_times)[-1]),
            int(jnp.shape(fcfgs.pacman_nodes)[-1]),
            int(jnp.shape(fcfgs.edge_cut_times)[-1]),
        )
        sig = self._signature("sweep", pcfg0, lens, fcfgs)

        from repro.api.store import ResultStore

        store = ResultStore.resolve(store)
        skey = None
        if store is not None:
            # key on the pre-placement stacked leaves: device placement
            # never changes the answer, so it must not change the key
            skey = store.sweep_key(sig, self.graph, (pcfgs, fcfgs), seeds, base)
            cached = store.get(skey)
            if cached is not None:
                return cached

        keys = jax.random.split(base, seeds)
        pcfgs, fcfgs = self.placement.place(pcfgs, fcfgs, len(scenarios))
        if segment_steps is None:
            result = executable("sweep", sig)(
                keys, self.neighbors, self.degrees, self.mirror,
                self._pi(pcfg0), pcfgs, fcfgs,
                steps=self.steps, n=self.n, payload=self.payload,
                spec=self.spec, pspec=self.pspec,
            )
        else:
            seg_sig = self._signature("seg_sweep", pcfg0, lens, fcfgs)
            _carry, result = self._drive_segments(
                "seg_sweep", seg_sig,
                lambda: sim._init_sweep_carry(
                    keys, self.neighbors, pcfgs, fcfgs, self.steps, self.n,
                    self.payload,
                ),
                (self._pi(pcfg0), pcfgs, fcfgs), segment_steps, 2,
                store, skey,
            )
        if store is not None:
            store.put(
                skey,
                jax.block_until_ready(result),
                extra_meta={"scenarios": len(scenarios), "seeds": int(seeds)},
            )
            if segment_steps is not None:
                store.clear_segments(skey)
        return result

    def sweep(
        self,
        scenarios: Sequence | None = None,
        *,
        seeds: int,
        base_key: jax.Array | int = 0,
        store=None,
        segment_steps: int | None = None,
    ) -> SweepResult:
        """Run a mixed scenario list: grouped by static signature, ONE
        compiled call per group, per-scenario results in input order.

        Each scenario's ``(seeds,)``-leading outputs are bitwise what
        ``ensemble`` would produce for it under the same ``base_key``;
        adding a new regime (failure schedule, topology churn, Pac-Man
        node, eps grid row) is appending a scenario row, not a new
        compilation unit. ``store=`` persists each group's stacked call
        (see :meth:`sweep_stacked`).
        """
        scenarios = self._scenarios(scenarios, "sweep")
        names = tuple(
            getattr(s, "name", f"scenario{i}") for i, s in enumerate(scenarios)
        )
        results = [None] * len(scenarios)
        payloads = [None] * len(scenarios) if self.payload is not None else None
        for _sig, idxs in self.groups(scenarios):
            stacked = self.sweep_stacked(
                [scenarios[i] for i in idxs], seeds=seeds, base_key=base_key,
                store=store, segment_steps=segment_steps,
            )
            if self.payload is not None:
                stacked, stacked_payload = stacked
            for j, i in enumerate(idxs):
                results[i] = jax.tree_util.tree_map(lambda x: x[j], stacked)
                if self.payload is not None:
                    payloads[i] = jax.tree_util.tree_map(
                        lambda x: x[j], stacked_payload
                    )
        return SweepResult(names=names, outputs=results, payloads=payloads)

    # -- introspection -----------------------------------------------------

    def round_decisions(self, scenarios: Sequence | None = None) -> list:
        """How each compile group executes its rounds — with the reason.

        Returns ``[(signature, indices, RoundDecision)]`` over the given
        (or the Experiment's) scenario list; for a base-only plan (no
        scenario rows) a single entry with ``signature=None`` and
        ``indices=[0]``. The :class:`~repro.core.simulator.RoundDecision`
        carries ``impl`` (``'fused'``/``'unfused'``), the fused backend,
        and the ``reason`` string — the observability hook for configs
        that silently fall back to the stage sequence (zoo walk variants,
        attack statics outside a kernel's support). The decision is made
        on the group's PADDED schedule widths, exactly as the compiled
        program sees them: a cut-free scenario co-batched with an
        edge-cut scenario shares its group's fallback.
        """
        from repro.core.failures import pad_bursts
        from repro.core.simulator import round_impl_decision
        from repro.sweep.scenario import as_pair

        if scenarios is None and not self.experiment.scenarios:
            pcfg, fcfg = self._require_base("round_decisions")
            return [(None, [0], round_impl_decision(pcfg, fcfg))]
        scenarios = self._scenarios(scenarios, "round_decisions")
        out = []
        for sig, idxs in self.groups(scenarios):
            pairs = [as_pair(scenarios[i]) for i in idxs]
            fcfgs = pad_bursts([f for _, f in pairs])
            out.append((sig, idxs, round_impl_decision(pairs[0][0], fcfgs[0])))
        return out

    def groups(self, scenarios: Sequence | None = None) -> list:
        """The static-signature grouping: ``[(signature, [indices])]``
        over the given (or the Experiment's) scenario list — which rows
        share one compiled program."""
        from repro.sweep.scenario import group_scenarios

        return group_scenarios(self._scenarios(scenarios, "groups"))

    def _scenarios(self, scenarios, what: str) -> list:
        scenarios = (
            self.experiment.scenarios if scenarios is None else scenarios
        )
        if not scenarios:
            raise ValueError(
                f"Plan.{what} needs scenarios: pass them to the call or "
                "construct the Experiment with scenarios=[...]"
            )
        return list(scenarios)

    def __repr__(self):
        base = "1 base scenario" if self._base else "no base scenario"
        ns = len(self.experiment.scenarios or ())
        return (
            f"Plan(n={self.n}, steps={self.steps}, {base}, "
            f"{ns} declared scenario(s), placement={self.placement.policy!r})"
        )
