"""Device-placement policy for the scenario axis.

:class:`Placement` replaces the legacy ``sharded=`` tri-state (and the
ad-hoc mesh probing that lived in ``sweep/engine.py``) with an explicit,
named policy object the :class:`~repro.api.Plan` owns:

  ``Placement.AUTO``     place the stacked scenario leaves across the
                         'data' axis of the local mesh when more than one
                         device is visible and the scenario count
                         divides; silently stay local otherwise —
                         correctness never depends on placement.
  ``Placement.SHARDED``  demand placement; raise when it cannot be
                         honored instead of silently running replicated.
  ``Placement.LOCAL``    never touch device placement.

Policies are tiny frozen values: pass one to ``Experiment(placement=...)``
(strings ``"auto"`` / ``"sharded"`` / ``"local"`` also accepted).
``Placement.from_sharded`` maps the legacy tri-state — ``None`` -> AUTO,
``True`` -> SHARDED, ``False`` -> LOCAL — with the same identity-based
validation (``0``/``1`` must not alias into the wrong policy).
"""
from __future__ import annotations

import dataclasses

import jax

__all__ = ["Placement"]

_POLICIES = ("auto", "sharded", "local")


@dataclasses.dataclass(frozen=True)
class Placement:
    """Scenario-axis device-placement policy (see module docstring)."""

    policy: str = "auto"

    def __post_init__(self):
        if self.policy not in _POLICIES:
            raise ValueError(
                f"unknown placement policy {self.policy!r}; use one of "
                f"{list(_POLICIES)}"
            )

    # -- construction ------------------------------------------------------

    @classmethod
    def resolve(cls, value) -> "Placement":
        """Normalize an ``Experiment(placement=...)`` argument."""
        if value is None:
            return cls.AUTO
        if isinstance(value, cls):
            return value
        if isinstance(value, str):
            cls(value)  # validate the name
            return {p.policy: p for p in (cls.AUTO, cls.SHARDED, cls.LOCAL)}[
                value
            ]
        raise TypeError(
            f"placement must be a Placement or one of {list(_POLICIES)}; "
            f"got {value!r}"
        )

    @classmethod
    def from_sharded(cls, sharded) -> "Placement":
        """Map the legacy ``sharded=`` tri-state to a policy.

        Identity, not equality: 0/1 must not alias False/True into the
        wrong placement path (0 == False but ``0 is not False`` would
        have fallen through to auto).
        """
        if sharded is None:
            return cls.AUTO
        if sharded is True:
            return cls.SHARDED
        if sharded is False:
            return cls.LOCAL
        raise TypeError(
            f"sharded must be True, False or None (auto); got {sharded!r}"
        )

    # -- the decision ------------------------------------------------------

    def place(self, pcfgs, fcfgs, n_scenarios: int):
        """Place stacked config leaves across the 'data' mesh axis per
        this policy; returns the (possibly device_put) config pytrees.
        """
        if self.policy == "local":
            return pcfgs, fcfgs
        explicit = self.policy == "sharded"
        if jax.device_count() == 1 and not explicit:
            return pcfgs, fcfgs
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.launch.mesh import data_axis_size, make_local_mesh

        mesh = make_local_mesh()
        if n_scenarios % data_axis_size(mesh) != 0:
            if explicit:
                raise ValueError(
                    f"placement='sharded' but {n_scenarios} scenarios do not "
                    f"divide the data axis ({data_axis_size(mesh)} devices); "
                    "pad the scenario list or use Placement.AUTO"
                )
            return pcfgs, fcfgs
        sharding = NamedSharding(mesh, P("data"))

        def put(x):
            return jax.device_put(x, sharding)

        return (
            jax.tree_util.tree_map(put, pcfgs),
            jax.tree_util.tree_map(put, fcfgs),
        )


Placement.AUTO = Placement("auto")
Placement.SHARDED = Placement("sharded")
Placement.LOCAL = Placement("local")
