"""Disk-backed result persistence for compiled Plans.

The compile cache (``repro.api.plan``) makes repeated studies free
*within* one process; this module makes them free *across* processes: a
:class:`ResultStore` caches the results of ``Plan.sweep_stacked`` calls
on disk, keyed by a **stable content hash** of everything that determines
the answer —

    (plan signature, graph adjacency, stacked scenario config leaves,
     seeds, base key)

— so a store-warm re-run in a fresh process returns bitwise-identical
pytrees without compiling (or executing) a single XLA program. Keys
require every signature component to be *stable* (primitives, tuples,
dataclasses of primitives): payload-carrying sweeps are storable exactly
when the payload declares :meth:`~repro.core.payload.Payload.signature`.

Serialization rides the ``repro.checkpoint`` machinery (npz + atomic
temp-file + ``os.replace`` writes, so a crash mid-write never corrupts a
previously stored result); the pytree *structure* — ``RecordedOutputs``
fields, payload namedtuples, nesting — is recorded as a JSON schema in
the sidecar ``.meta.json`` and rebuilt on load, leaf dtypes restored
exactly.

Point a store at a directory explicitly (``ResultStore(path)``), or set
the ``REPRO_RESULT_STORE`` environment variable and let
``ResultStore.from_env()`` / the :class:`~repro.api.service.ExperimentService`
default pick it up. Unreadable or half-missing entries are treated as
misses, never as errors.
"""
from __future__ import annotations

import dataclasses
import hashlib
import importlib
import json
import os
from typing import Any

import jax
import numpy as np

from repro.checkpoint import load_pytree, save_pytree
from repro.core.outputs import RecordedOutputs
from repro.utils.faults import fault_point

__all__ = ["ResultStore", "UnstableSignatureError", "canonical_token"]

ENV_VAR = "REPRO_RESULT_STORE"

_SCHEMA_VERSION = 1


class UnstableSignatureError(ValueError):
    """A plan-signature component has no stable cross-process encoding
    (typically a payload without :meth:`Payload.signature`)."""


# ---------------------------------------------------------------------------
# stable tokens: signature tuples -> canonical strings
# ---------------------------------------------------------------------------


def canonical_token(obj: Any) -> str:
    """Canonical string for a static-signature component.

    Accepts the primitives/tuples/dataclasses a :func:`plan_signature` is
    built from; anything else (an identity-hashed payload object, a
    callable) raises :class:`UnstableSignatureError` — the store must
    never key results on ``id()``.
    """
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return repr(obj)
    if isinstance(obj, (tuple, list)):
        inner = ",".join(canonical_token(x) for x in obj)
        return f"({inner})"
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        fields = ",".join(
            f"{f.name}={canonical_token(getattr(obj, f.name))}"
            for f in dataclasses.fields(obj)
        )
        return f"{type(obj).__qualname__}({fields})"
    raise UnstableSignatureError(
        f"signature component {obj!r} has no stable cross-process encoding; "
        "results carrying it cannot be persisted. For payloads, implement "
        "Payload.signature() (a stable static-config tuple) to enable the "
        "result store."
    )


def _hash_leaves(h, tree) -> None:
    """Fold a pytree's numeric leaves (dtype, shape, bytes) into a hash."""
    for leaf in jax.tree_util.tree_leaves(tree):
        a = np.ascontiguousarray(np.asarray(leaf))
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())


# ---------------------------------------------------------------------------
# structure schema: describe / rebuild result pytrees
# ---------------------------------------------------------------------------


def _describe(obj: Any, leaves: list) -> dict:
    """Flatten ``obj`` into ``leaves`` and return a JSON-able schema that
    :func:`_rebuild` inverts. Handles the result shapes Plans produce:
    ``RecordedOutputs``, namedtuples (payload outputs), tuples/lists/
    dicts, ``None``, and array leaves."""
    if obj is None:
        return {"kind": "none"}
    if isinstance(obj, RecordedOutputs):
        return {
            "kind": "recorded",
            "fields": list(obj._fields),
            "children": [_describe(v, leaves) for v in obj],
        }
    if isinstance(obj, tuple) and hasattr(obj, "_fields"):  # namedtuple
        cls = type(obj)
        return {
            "kind": "namedtuple",
            "cls": [cls.__module__, cls.__qualname__],
            "children": [_describe(v, leaves) for v in obj],
        }
    if isinstance(obj, (tuple, list)):
        return {
            "kind": "tuple" if isinstance(obj, tuple) else "list",
            "children": [_describe(v, leaves) for v in obj],
        }
    if isinstance(obj, dict):
        keys = sorted(obj)
        return {
            "kind": "dict",
            "keys": keys,
            "children": [_describe(obj[k], leaves) for k in keys],
        }
    if getattr(obj, "dtype", None) is not None and jax.dtypes.issubdtype(
        obj.dtype, jax.dtypes.prng_key
    ):
        # typed PRNG keys (SimState.key in segment snapshots): store the
        # raw key_data, re-wrap on rebuild — the data IS the key
        a = np.asarray(jax.random.key_data(obj))
        leaves.append(a)
        return {"kind": "prng_key", "dtype": str(a.dtype), "shape": list(a.shape)}
    a = np.asarray(obj)
    leaves.append(a)
    return {"kind": "leaf", "dtype": str(a.dtype), "shape": list(a.shape)}


def _np_dtype(name: str):
    try:
        return np.dtype(name)
    except TypeError:
        import jax.numpy as jnp  # ml_dtypes names (bfloat16, ...)

        return np.dtype(getattr(jnp, name))


def _rebuild(schema: dict, leaves) -> Any:
    kind = schema["kind"]
    if kind == "none":
        return None
    if kind == "leaf":
        return next(leaves)
    if kind == "prng_key":
        import jax.numpy as jnp

        return jax.random.wrap_key_data(jnp.asarray(next(leaves)))
    children = [_rebuild(c, leaves) for c in schema["children"]]
    if kind == "recorded":
        return RecordedOutputs(tuple(schema["fields"]), tuple(children))
    if kind == "namedtuple":
        module, qualname = schema["cls"]
        cls = importlib.import_module(module)
        for part in qualname.split("."):
            cls = getattr(cls, part)
        return cls(*children)
    if kind == "tuple":
        return tuple(children)
    if kind == "list":
        return children
    if kind == "dict":
        return dict(zip(schema["keys"], children))
    raise ValueError(f"unknown schema kind {kind!r}")


def _leaf_templates(schema: dict, out: list) -> None:
    """Shape/dtype templates in flatten order, for ``load_pytree``'s
    checked restore (dtypes restored exactly, including the bfloat16 ->
    float32 npz round-trip)."""
    kind = schema["kind"]
    if kind in ("leaf", "prng_key"):
        out.append(np.zeros(tuple(schema["shape"]), _np_dtype(schema["dtype"])))
    elif kind != "none":
        for c in schema.get("children", ()):
            _leaf_templates(c, out)


# ---------------------------------------------------------------------------
# the store
# ---------------------------------------------------------------------------


class ResultStore:
    """Content-addressed, disk-backed Plan result cache (module docstring).

    Layout: ``<root>/<key[:2]>/<key>.npz`` (the leaves, written
    atomically) + ``<key>.meta.json`` (structure schema + provenance).
    ``hits`` / ``misses`` / ``puts`` count this instance's traffic.
    """

    def __init__(self, root: str):
        self.root = os.path.abspath(os.fspath(root))
        self.hits = 0
        self.misses = 0
        self.puts = 0

    # -- construction ------------------------------------------------------

    @classmethod
    def from_env(cls) -> "ResultStore | None":
        """The store named by ``$REPRO_RESULT_STORE``, or None if unset."""
        root = os.environ.get(ENV_VAR, "").strip()
        return cls(root) if root else None

    @classmethod
    def resolve(cls, store) -> "ResultStore | None":
        """Normalize a ``store=`` argument: None stays None, ``"env"``
        reads :data:`ENV_VAR`, a path string opens that directory, a
        ResultStore passes through."""
        if store is None or isinstance(store, cls):
            return store
        if store == "env":
            return cls.from_env()
        if isinstance(store, (str, os.PathLike)):
            return cls(store)
        raise TypeError(
            f"store must be None, 'env', a directory path or a ResultStore; "
            f"got {store!r}"
        )

    # -- keys --------------------------------------------------------------

    def sweep_key(
        self, signature: tuple, graph, stacked_configs, seeds: int, key
    ) -> str:
        """The content hash of one ``sweep_stacked`` call: stable plan
        signature + graph adjacency + stacked scenario leaves + seed
        count + base PRNG key material."""
        h = hashlib.sha256()
        h.update(b"repro-sweep-v1\x00")
        h.update(canonical_token(signature).encode())
        _hash_leaves(h, (np.asarray(graph.neighbors), np.asarray(graph.degrees)))
        _hash_leaves(h, stacked_configs)
        h.update(f"seeds={int(seeds)}".encode())
        h.update(np.asarray(jax.random.key_data(key)).tobytes())
        return h.hexdigest()

    def _paths(self, key: str) -> tuple:
        base = os.path.join(self.root, key[:2], key)
        return base, base + ".npz", base + ".meta.json"

    def __contains__(self, key: str) -> bool:
        _, npz, meta = self._paths(key)
        return os.path.exists(npz) and os.path.exists(meta)

    # -- IO ----------------------------------------------------------------

    def get(self, key: str):
        """The stored result pytree for ``key``, or None on a miss.
        Corrupt/partial entries (e.g. from a dead writer on a pre-atomic
        checkpoint layer) count as misses — and so does ANY read failure
        (fault site ``store.get``): a flaky store must degrade to
        recomputation, never take the caller down."""
        base, npz, meta_path = self._paths(key)
        try:
            fault_point("store.get")
            with open(meta_path) as f:
                meta = json.load(f)
            schema = meta["schema"]
            like: list = []
            _leaf_templates(schema, like)
            leaves = load_pytree(npz, like)
            result = _rebuild(schema, iter(leaves))
        except Exception:  # unreadable/corrupt/mismatched entry == miss
            self.misses += 1
            return None
        self.hits += 1
        return result

    def put(self, key: str, result: Any, extra_meta: dict | None = None):
        """Persist a result pytree under ``key`` (atomic: readers see the
        old entry or the new one, never a torn write). Fault site
        ``store.put`` fires before any IO."""
        fault_point("store.put")
        base, _npz, _meta = self._paths(key)
        leaves: list = []
        schema = _describe(result, leaves)
        meta = {"schema_version": _SCHEMA_VERSION, "key": key, "schema": schema}
        if extra_meta:
            meta.update(extra_meta)
        save_pytree(base, leaves, metadata=meta)
        self.puts += 1
        return key

    # -- segment snapshots (durable execution write-behind) ----------------
    #
    # A segmented run (``Plan.*_segmented`` / ``sweep_stacked(
    # segment_steps=...)``) persists, at each segment boundary, one
    # SELF-CONTAINED snapshot: the trajectory carry after ``steps_done``
    # rounds plus every recorded output so far. Snapshots are keyed by
    # the SAME content key as the final result and named by their step
    # count, so resume is segmentation-independent: a killed process
    # restarts from the deepest loadable snapshot whatever chunking it
    # now runs with. Older snapshots double as fallbacks for a torn
    # latest write; ``keep`` bounds how many stay on disk.

    def _segment_dir(self, key: str) -> str:
        return os.path.join(self.root, "segments", key[:2], key)

    def segment_steps_on_disk(self, key: str) -> list:
        """Step counts of the on-disk snapshots for ``key``, descending
        (no validation — :meth:`latest_segment` does the checked load)."""
        d = self._segment_dir(key)
        out = []
        try:
            for name in os.listdir(d):
                if name.startswith("seg_") and name.endswith(".npz"):
                    try:
                        out.append(int(name[4:-4]))
                    except ValueError:
                        continue
        except OSError:
            return []
        return sorted(set(out), reverse=True)

    def put_segment(
        self,
        key: str,
        steps_done: int,
        snapshot: Any,
        extra_meta: dict | None = None,
        keep: int = 2,
    ) -> None:
        """Write-behind one segment snapshot (atomic; fault site
        ``store.put``). Keeps the newest ``keep`` snapshots, pruning the
        rest — the previous one survives as the fallback for a torn
        latest write."""
        fault_point("store.put")
        base = os.path.join(self._segment_dir(key), f"seg_{steps_done:07d}")
        leaves: list = []
        schema = _describe(snapshot, leaves)
        meta = {
            "schema_version": _SCHEMA_VERSION,
            "key": key,
            "steps_done": int(steps_done),
            "schema": schema,
        }
        if extra_meta:
            meta.update(extra_meta)
        save_pytree(base, leaves, metadata=meta)
        self.puts += 1
        for stale in self.segment_steps_on_disk(key)[keep:]:
            self._drop_segment(key, stale)

    def latest_segment(self, key: str, max_steps: int | None = None):
        """The deepest loadable snapshot for ``key``:
        ``(steps_done, snapshot)``, or None. Corrupt/torn/mismatched
        snapshots are skipped (falling back to the next-older one), and
        any snapshot deeper than ``max_steps`` is ignored — a stale
        deeper run must not leak into a shorter one."""
        for steps_done in self.segment_steps_on_disk(key):
            if max_steps is not None and steps_done > max_steps:
                continue
            base = os.path.join(self._segment_dir(key), f"seg_{steps_done:07d}")
            try:
                fault_point("store.get")
                with open(base + ".meta.json") as f:
                    meta = json.load(f)
                schema = meta["schema"]
                like: list = []
                _leaf_templates(schema, like)
                leaves = load_pytree(base, like)
                snapshot = _rebuild(schema, iter(leaves))
            except Exception:  # torn/corrupt snapshot: fall back
                self.misses += 1
                continue
            self.hits += 1
            return steps_done, snapshot
        return None

    def clear_segments(self, key: str) -> None:
        """Drop every segment snapshot for ``key`` (the run completed —
        its final result owns the key now)."""
        for steps_done in self.segment_steps_on_disk(key):
            self._drop_segment(key, steps_done)

    def _drop_segment(self, key: str, steps_done: int) -> None:
        base = os.path.join(self._segment_dir(key), f"seg_{steps_done:07d}")
        for suffix in (".npz", ".meta.json"):
            try:
                os.remove(base + suffix)
            except OSError:
                pass

    def __repr__(self):
        return (
            f"ResultStore({self.root!r}, hits={self.hits}, "
            f"misses={self.misses}, puts={self.puts})"
        )
