"""The experiment service: coalescing submissions from many callers.

One process serving many studies wastes most of its time running
*compatible* work separately: two callers sweeping the same static
program structure (same algorithm / capacity / histogram resolution /
seeds / base key) each pay a full ``sweep_stacked`` dispatch even though
the compiled program could run both scenario lists as extra rows of ONE
stacked call. :class:`ExperimentService` closes that gap:

  * callers :meth:`~ExperimentService.submit` scenario lists and get a
    :class:`SubmissionFuture` back immediately;
  * pending requests are grouped by **coalescing key** —
    ``(group_key(scenario), seeds, base-key material)``, the same
    static-signature grouping ``Plan.sweep`` uses plus the batching
    axes — and each group executes as exactly one
    ``Plan.sweep_stacked`` call, however many callers contributed rows;
  * results stream back per group: a future over a mixed submission
    yields each scenario's outputs as soon as *its* group finishes
    (:meth:`SubmissionFuture.stream`), not when the whole sweep does;
  * every group call goes through the disk-backed
    :class:`~repro.api.store.ResultStore` (default: the directory named
    by ``$REPRO_RESULT_STORE``, if set), so repeated studies are free
    across processes too.

Coalescing is bitwise-invisible to callers: ``sweep_stacked`` gives every
scenario row the same per-seed keys ``ensemble`` would derive from
``base_key`` (the PR-1 invariant), so a scenario's results do not depend
on which strangers shared its batch. The coalescing key pins ``seeds``
and the base key precisely so that invariant applies.

Two execution modes: the default background worker thread (submissions
coalesce across a short ``linger`` window), or ``autostart=False`` +
explicit :meth:`~ExperimentService.flush` for deterministic batching —
everything submitted since the last flush coalesces maximally (this is
what the tests and benchmarks use).

**Resilience** (the durable-execution contract, chaos-tested through
``repro.utils.faults``): every group attempt passes fault site
``service.run_group``; retryable failures (:func:`default_retryable`)
retry with exponential backoff + jitter up to ``retries`` times; a group
that still fails with >1 member is *split* and its members re-run
individually, so one poisoned scenario fails only its own futures; a
per-submission ``timeout=`` bounds how long requests may wait before
their future fails with :class:`DeadlineExceededError`; and a (simulated)
kill unwinding the worker thread never strands callers — pending futures
are failed, and ``flush``/``result`` detect the dead worker and drain
inline. ``close()`` is deterministic: post-close ``submit`` raises
:class:`ServiceClosedError` immediately, and anything still queued at
close resolves (delivered by the final drain, or failed with
:class:`ServiceClosedError`) — futures never hang.
"""
from __future__ import annotations

import random
import threading
import time
from typing import Sequence

import jax
import numpy as np

from repro.api.results import SweepResult
from repro.api.store import ResultStore
from repro.utils.faults import TransientFault, fault_point

__all__ = [
    "ExperimentService",
    "SubmissionFuture",
    "ServiceClosedError",
    "DeadlineExceededError",
    "default_retryable",
]


class ServiceClosedError(RuntimeError):
    """``submit()`` on a closed service — it no longer accepts work."""


class DeadlineExceededError(TimeoutError):
    """A submission's deadline passed before its group (re)ran."""


def default_retryable(exc: BaseException) -> bool:
    """The default retry classification: transient injected faults and
    environmental IO/timeout errors retry; everything else — bad configs,
    shape errors, poisoned scenarios — fails fast (or splits)."""
    return isinstance(exc, (TransientFault, OSError, TimeoutError))


def _key_token(base_key) -> tuple:
    """Hashable coalescing token for a base PRNG key: equal keys — int
    seeds or key arrays — coalesce, distinct ones never do."""
    if isinstance(base_key, int):
        base_key = jax.random.key(base_key)
    return ("key", np.asarray(jax.random.key_data(base_key)).tobytes())


class SubmissionFuture:
    """One caller's pending sweep: resolves to a :class:`SweepResult`.

    Scenario outputs land per coalesced group — :meth:`stream` yields
    ``(name, outputs, payload_outputs)`` in completion order as each
    group's compiled call finishes; :meth:`result` blocks for the full
    :class:`SweepResult` (input order, exactly what ``Plan.sweep``
    returns). A failure in any group the submission touched raises from
    both.
    """

    def __init__(self, service, names: tuple, has_payload: bool):
        self._service = service
        self.names = names
        self._outputs = [None] * len(names)
        self._payloads = [None] * len(names) if has_payload else None
        self._cv = threading.Condition()
        self._completed: list = []  # indices, completion order
        self._remaining = len(names)
        self._error: BaseException | None = None

    # -- delivery (service side) ------------------------------------------

    def _deliver(self, index: int, outputs, payload_outputs) -> None:
        with self._cv:
            self._outputs[index] = outputs
            if self._payloads is not None:
                self._payloads[index] = payload_outputs
            self._completed.append(index)
            self._remaining -= 1
            self._cv.notify_all()

    def _fail(self, exc: BaseException) -> None:
        with self._cv:
            if self._error is None:
                self._error = exc
            self._remaining = 0
            self._cv.notify_all()

    # -- consumption (caller side) ----------------------------------------

    def done(self) -> bool:
        """True once every scenario resolved (or the submission failed)."""
        with self._cv:
            return self._remaining == 0

    def result(self, timeout: float | None = None) -> SweepResult:
        """Block for the full :class:`SweepResult` (scenarios in
        submission order); raises the group's error on failure."""
        self._service._ensure_progress()
        with self._cv:
            if not self._cv.wait_for(lambda: self._remaining == 0, timeout):
                raise TimeoutError(
                    f"submission incomplete after {timeout}s "
                    f"({len(self._completed)}/{len(self.names)} scenarios)"
                )
            if self._error is not None:
                raise self._error
            return SweepResult(
                names=self.names,
                outputs=list(self._outputs),
                payloads=(
                    None if self._payloads is None else list(self._payloads)
                ),
            )

    def stream(self, timeout: float | None = None):
        """Yield ``(name, outputs, payload_outputs)`` per scenario in
        completion order, as coalesced groups finish (payload slot is
        None for payload-free plans). ``timeout`` bounds each wait."""
        self._service._ensure_progress()
        served = 0
        while True:
            with self._cv:
                if not self._cv.wait_for(
                    lambda: served < len(self._completed)
                    or self._remaining == 0,
                    timeout,
                ):
                    raise TimeoutError(
                        f"no scenario completed within {timeout}s"
                    )
                if self._error is not None:
                    raise self._error
                batch = self._completed[served:]
                served += len(batch)
                drained = self._remaining == 0 and served == len(
                    self._completed
                )
            for i in batch:
                yield (
                    self.names[i],
                    self._outputs[i],
                    None if self._payloads is None else self._payloads[i],
                )
            if drained:
                return


class _Request:
    """One scenario row of one submission, tagged for delivery."""

    __slots__ = (
        "future", "index", "scenario", "seeds", "base_key", "key", "deadline",
    )

    def __init__(self, future, index, scenario, seeds, base_key, key, deadline):
        self.future = future
        self.index = index
        self.scenario = scenario
        self.seeds = seeds
        self.base_key = base_key
        self.key = key  # the coalescing key
        self.deadline = deadline  # monotonic seconds, or None


class ExperimentService:
    """Coalescing submission queue over one compiled Plan (see module
    docstring).

    Parameters:
      experiment  the :class:`Experiment` (or pre-lowered ``Plan``) every
                  submission runs against;
      store       result persistence: ``'env'`` (default — honor
                  ``$REPRO_RESULT_STORE`` when set), None (off), a
                  directory path, or a :class:`ResultStore`;
      autostart   start the background worker thread (False: batches run
                  only on explicit :meth:`flush` — deterministic, used by
                  tests/benchmarks);
      linger      seconds the worker waits after a wake-up before
                  draining, so concurrent submitters land in one batch;
      retries     re-attempts per group on a retryable failure (see
                  ``retryable``) before splitting/failing;
      backoff     base seconds of the exponential retry backoff (each
                  retry waits ``backoff * 2**k``, +25% jitter);
      retryable   predicate ``exc -> bool`` classifying retryable
                  failures (default :func:`default_retryable`);
      segment_steps  when set, every group runs through the durable
                  segmented executor (``sweep_stacked(segment_steps=)``):
                  with a store, a killed process resumes half-finished
                  sweeps from their boundary snapshots.

    ``stats`` counts traffic: ``submissions`` / ``scenarios`` in,
    ``batches`` compiled calls out, ``coalesced`` scenarios that rode a
    batch with >1 submission contributing, ``retries`` re-attempts,
    ``splits`` degraded groups re-run member-by-member.
    """

    def __init__(
        self,
        experiment,
        *,
        store="env",
        autostart: bool = True,
        linger: float = 0.002,
        retries: int = 2,
        backoff: float = 0.05,
        retryable=None,
        segment_steps: int | None = None,
    ):
        from repro.api.plan import Plan

        self.plan = (
            experiment if isinstance(experiment, Plan) else experiment.plan()
        )
        self.store = ResultStore.resolve(store)
        self.linger = float(linger)
        self.retries = int(retries)
        self.backoff = float(backoff)
        self.retryable = default_retryable if retryable is None else retryable
        self.segment_steps = segment_steps
        self.stats = {
            "submissions": 0,
            "scenarios": 0,
            "batches": 0,
            "coalesced": 0,
            "retries": 0,
            "splits": 0,
        }
        self._lock = threading.Lock()
        self._wake = threading.Condition(self._lock)
        self._queue: list = []
        self._inflight = 0
        self._closed = False
        self._worker = None
        self._worker_error: BaseException | None = None
        if autostart:
            self._worker = threading.Thread(
                target=self._worker_loop,
                name="ExperimentService",
                daemon=True,
            )
            self._worker.start()

    # -- submission --------------------------------------------------------

    def submit(
        self,
        scenarios: Sequence,
        *,
        seeds: int,
        base_key=0,
        timeout: float | None = None,
    ) -> SubmissionFuture:
        """Enqueue a scenario list; returns immediately with a
        :class:`SubmissionFuture`. Scenarios coalesce with every pending
        request sharing ``(static structure, seeds, base_key)``.
        ``timeout=`` sets a deadline: requests whose group has not (re)run
        by then fail their future with :class:`DeadlineExceededError`."""
        from repro.sweep.scenario import group_key

        scenarios = list(scenarios)
        if not scenarios:
            raise ValueError("submit() needs at least one scenario")
        names = tuple(
            getattr(s, "name", f"scenario{i}") for i, s in enumerate(scenarios)
        )
        dupes = sorted({n for n in names if names.count(n) > 1})
        if dupes:
            raise ValueError(f"duplicate scenario names in submission: {dupes}")
        seeds = int(seeds)
        ktok = _key_token(base_key)
        deadline = None if timeout is None else time.monotonic() + timeout
        future = SubmissionFuture(
            self, names, has_payload=self.plan.payload is not None
        )
        reqs = [
            _Request(
                future, i, s, seeds, base_key, (group_key(s), seeds, ktok),
                deadline,
            )
            for i, s in enumerate(scenarios)
        ]
        with self._lock:
            if self._closed:
                raise ServiceClosedError("ExperimentService is closed")
            self._queue.extend(reqs)
            self.stats["submissions"] += 1
            self.stats["scenarios"] += len(reqs)
            self._wake.notify_all()
        return future

    def run(self, scenarios: Sequence, *, seeds: int, base_key=0) -> SweepResult:
        """Submit and block for the result (one-caller convenience)."""
        return self.submit(scenarios, seeds=seeds, base_key=base_key).result()

    # -- execution ---------------------------------------------------------

    def flush(self, timeout: float | None = None) -> None:
        """Run everything pending and block until the queue is empty and
        no batch is in flight. With ``autostart=False`` (or a worker that
        died) this drains inline, so every submission since the last
        flush coalesces maximally — a dead worker never strands work."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            if self._worker_alive() is None:
                self._drain()
            with self._lock:
                remaining = (
                    None if deadline is None
                    else max(0.0, deadline - time.monotonic())
                )
                if not self._wake.wait_for(
                    lambda: (not self._queue and self._inflight == 0)
                    or self._worker_error is not None,
                    remaining,
                ):
                    raise TimeoutError(f"queue not drained within {timeout}s")
                if not self._queue and self._inflight == 0:
                    return
            # the worker died mid-stream: loop around and take over inline

    def close(self, timeout: float | None = None) -> None:
        """Drain pending work, then stop the worker. Idempotent; further
        ``submit`` calls raise :class:`ServiceClosedError`. Deterministic
        teardown: every future submitted before close resolves — rows the
        final drain delivered succeed, anything left (a drain killed
        mid-way, a worker that never ran) fails with
        :class:`ServiceClosedError` — no caller hangs."""
        with self._lock:
            self._closed = True
            self._wake.notify_all()
        worker, self._worker = self._worker, None
        if worker is not None and worker.is_alive():
            worker.join(timeout)
        try:
            self._drain()  # autostart=False (or a dead worker): inline
        finally:
            with self._lock:
                leftovers, self._queue = self._queue, []
            if leftovers:
                exc = ServiceClosedError("ExperimentService is closed")
                for fut in {id(r.future): r.future for r in leftovers}.values():
                    fut._fail(exc)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    def _worker_alive(self):
        """The live worker thread, or None (not started / joined / died)."""
        worker = self._worker
        if worker is None or not worker.is_alive():
            return None
        return worker

    def _ensure_progress(self) -> None:
        """Guard futures against deadlock: blocking on a result while no
        live worker exists runs the pending batch inline."""
        if self._worker_alive() is None:
            self._drain()

    def _worker_loop(self) -> None:
        try:
            while True:
                with self._lock:
                    self._wake.wait_for(lambda: self._queue or self._closed)
                    if self._closed and not self._queue:
                        return
                if self.linger:
                    time.sleep(self.linger)  # let concurrent submitters land
                self._drain()
        except BaseException as exc:
            # the worker "process" died (e.g. a SimulatedKill). Record it
            # and wake waiters: flush()/result() detect the dead thread
            # and drain inline, so no caller hangs on a killed worker.
            with self._lock:
                self._worker_error = exc
                self._wake.notify_all()

    def _drain(self) -> None:
        """Pop the whole queue, group by coalescing key, run each group
        as ONE ``sweep_stacked`` call, deliver rows to their futures."""
        with self._lock:
            batch, self._queue = self._queue, []
            self._inflight += 1
        try:
            groups: dict = {}
            order = []
            for req in batch:
                if req.key not in groups:
                    groups[req.key] = []
                    order.append(req.key)
                groups[req.key].append(req)
            for key in order:
                self._run_group(groups[key])
        finally:
            with self._lock:
                self._inflight -= 1
                self._wake.notify_all()

    def _expire(self, reqs: list) -> list:
        """Fail requests whose deadline passed; return the live rest."""
        now = time.monotonic()
        live = []
        for r in reqs:
            if r.deadline is not None and now > r.deadline:
                r.future._fail(
                    DeadlineExceededError(
                        f"submission deadline exceeded before scenario "
                        f"{getattr(r.scenario, 'name', r.index)!r} ran"
                    )
                )
            else:
                live.append(r)
        return live

    def _fail_group(self, reqs: list, exc: BaseException) -> None:
        for fut in {id(r.future): r.future for r in reqs}.values():
            fut._fail(exc)

    def _run_group(self, reqs: list, _split: bool = True) -> None:
        """Run one coalesced group with the full resilience ladder:
        deadline check -> attempt (fault site ``service.run_group``) ->
        exponential-backoff retries for retryable failures -> split a
        still-failing multi-member group and re-run members individually
        (one poisoned scenario fails only its own futures) -> clean
        per-future error delivery. A (simulated) kill fails the touching
        futures and re-raises — it unwinds the worker like the real thing.
        """
        has_payload = self.plan.payload is not None
        reqs = self._expire(reqs)
        if not reqs:
            return
        attempt = 0
        while True:
            try:
                fault_point("service.run_group")
                stacked = self.plan.sweep_stacked(
                    [r.scenario for r in reqs],
                    seeds=reqs[0].seeds,
                    base_key=reqs[0].base_key,
                    store=self.store,
                    segment_steps=self.segment_steps,
                )
                break
            except Exception as exc:
                if attempt < self.retries and self.retryable(exc):
                    attempt += 1
                    self.stats["retries"] += 1
                    delay = self.backoff * (2 ** (attempt - 1))
                    if delay > 0:
                        time.sleep(delay * (1.0 + 0.25 * random.random()))
                    reqs = self._expire(reqs)
                    if not reqs:
                        return
                    continue
                if _split and len(reqs) > 1:
                    # graceful degradation: the group is poisoned but the
                    # culprit is unknown — re-run members individually so
                    # only the culprit's futures fail
                    self.stats["splits"] += 1
                    for req in reqs:
                        self._run_group([req], _split=False)
                    return
                self._fail_group(reqs, exc)
                return
            except BaseException as exc:
                self._fail_group(reqs, exc)  # no caller may hang on a kill
                raise
        stacked_payload = None
        if has_payload:
            stacked, stacked_payload = stacked
        self.stats["batches"] += 1
        if len({id(r.future) for r in reqs}) > 1:
            self.stats["coalesced"] += len(reqs)
        for j, req in enumerate(reqs):
            outputs = jax.tree_util.tree_map(lambda x: x[j], stacked)
            payload_out = (
                jax.tree_util.tree_map(lambda x: x[j], stacked_payload)
                if has_payload
                else None
            )
            req.future._deliver(req.index, outputs, payload_out)

    def __repr__(self):
        s = self.stats
        return (
            f"ExperimentService({self.plan!r}, store={self.store!r}, "
            f"submissions={s['submissions']}, scenarios={s['scenarios']}, "
            f"batches={s['batches']})"
        )
