"""Result containers for the declarative Experiment API.

:class:`SweepResult` (moved here from ``sweep/engine.py`` — the legacy
module keeps an import alias) holds per-scenario outputs of a mixed
scenario sweep, input order preserved across static-structure groups.
"""
from __future__ import annotations

__all__ = ["SweepResult"]


class SweepResult:
    """Per-scenario outputs, input order preserved.

    Behaves as a container of scenarios: ``len`` is the scenario count,
    iteration yields per-scenario outputs (leading ``(seeds,)`` axis),
    and indexing accepts either a position or a scenario name. When the
    sweep carried a payload, ``payloads`` is the parallel list of
    per-scenario payload outputs (``payload(name_or_index)`` to look one
    up); otherwise it is ``None``.

    Name lookups are mapping-like: an unknown name raises ``KeyError``
    listing the available names (never the bare ``ValueError`` of
    ``tuple.index``), and duplicate scenario names are rejected at
    construction — a silently first-match duplicate lookup is a wrong
    answer waiting to happen.
    """

    def __init__(self, names: tuple, outputs: list, payloads: list | None = None):
        self.names = tuple(names)
        dupes = sorted({n for n in self.names if self.names.count(n) > 1})
        if dupes:
            raise ValueError(
                f"duplicate scenario name(s) {dupes!r}: every scenario in a "
                "sweep needs a unique name, or name lookups would silently "
                "resolve to the first match"
            )
        self.outputs = list(outputs)
        if len(self.outputs) != len(self.names):
            raise ValueError(
                f"{len(self.names)} names but {len(self.outputs)} outputs"
            )
        self.payloads = list(payloads) if payloads is not None else None

    def _index(self, i) -> int:
        if isinstance(i, str):
            try:
                return self.names.index(i)
            except ValueError:
                raise KeyError(
                    f"unknown scenario name {i!r}; available scenarios: "
                    f"{list(self.names)}"
                ) from None
        return i

    def __getitem__(self, i):
        return self.outputs[self._index(i)]

    def payload(self, i):
        """Per-scenario payload outputs by position or scenario name."""
        if self.payloads is None:
            raise KeyError(
                "this sweep ran without a payload, so there are no payload "
                "outputs; attach payload= to the Experiment to record them"
            )
        return self.payloads[self._index(i)]

    def __len__(self):
        return len(self.outputs)

    def __iter__(self):
        return iter(self.outputs)

    def items(self):
        return list(zip(self.names, self.outputs))

    def __repr__(self):
        return f"SweepResult({len(self.outputs)} scenarios: {list(self.names)!r})"
