"""The declarative Experiment API: spec -> compiled Plan -> results.

ONE public surface over the paper's trajectory core, replacing the four
divergent runners (``run_simulation`` / ``run_ensemble`` / ``run_sweep``
/ ``run_scenarios`` — now deprecation shims over this package):

    from repro.api import Experiment, Placement

    exp = Experiment(graph=g, protocol=pcfg, failures=fcfg, steps=4500,
                     payload=None, outputs=None, placement="auto")
    final, outs = exp.run(key=0)              # one trajectory
    outs = exp.ensemble(seeds=50)             # seed ensemble (vmap)
    res = exp.sweep(scenarios, seeds=50)      # mixed regimes, grouped by
                                              # static signature, ONE
                                              # compile per group

``Experiment.plan()`` exposes the lowered :class:`Plan` — the object that
owns static-signature grouping, the process-wide compile cache
(``repro.api.plan.cache_stats``) and the :class:`Placement` decision —
for callers that want to introspect grouping or amortize many calls over
one plan explicitly.

Serving many studies rides the same Plan: an
:class:`ExperimentService` coalesces concurrent submissions into one
compiled call per compatible group (futures stream per-group results),
a :class:`ResultStore` persists sweep results on disk keyed by stable
content hash (``store='env'`` honors ``$REPRO_RESULT_STORE``), and the
:mod:`~repro.api.registry` names config-dict-driven experiments
(``Experiment.from_config``).
"""
from repro.api import registry
from repro.api.experiment import Experiment
from repro.api.placement import Placement
from repro.api.plan import Plan, cache_stats, plan_signature
from repro.api.results import SweepResult
from repro.api.service import ExperimentService, SubmissionFuture
from repro.api.store import ResultStore

__all__ = [
    "Experiment",
    "ExperimentService",
    "Placement",
    "Plan",
    "ResultStore",
    "SubmissionFuture",
    "SweepResult",
    "cache_stats",
    "plan_signature",
    "registry",
]
