"""Computable theoretical guarantees (Sections IV and V).

Implements, under Assumption 1 (exponential return rate lambda_r, arrival
rate lambda_a):

  - Lemma 1:   CDF of a forked/terminated walk's survival estimate
               S(t - L_{i,k}(t)), the building block of everything else;
  - Cor. 1:    its closed-form mean (cross-checked numerically in tests);
  - Lemma 2:   E[theta_hat(t)] for a mixture of long-active, terminated
               and forked walks;
  - Lemma 3:   Var of the forked-walk estimate — we evaluate mean/variance
               *numerically* from the Lemma-1 CDF (robust against the very
               long closed form in the paper; tests verify Cor. 1 agrees);
  - Lemma 4/5: Bennett upper bounds on forking / termination probability.
               NOTE: the paper prints h((E-eps)^2 / sigma^2); the standard
               Bennett inequality their proof invokes uses h(tau / sigma^2)
               with tau = E - eps and unit-bounded summands. We implement
               the standard form and flag the discrepancy.
  - Thm. 2:    worst-case reaction-time bound after D failures / R forks;
  - Thm. 3 /   no-failure growth bound and its inversion (time until the
    Cor. 2     population exceeds z with probability delta);
  - Cor. 3:    linear-complexity overshoot recursion after a burst.

All numpy/float64 — these are design/validation-time quantities.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence, Tuple

import numpy as np

from repro.core.irwin_hall import irwin_hall_cdf, scaled_irwin_hall_cdf


@dataclasses.dataclass(frozen=True)
class Rates:
    """Assumption 1 rates: R_i ~ exp(lambda_r), H_{i,j} ~ exp(lambda_a)."""

    lambda_r: float
    lambda_a: float


# ---------------------------------------------------------------------------
# Lemma 1: CDF of the survival estimate of a forked(/terminated) walk
# ---------------------------------------------------------------------------


def fork_estimate_cdf(x, t: float, t_f: float, t_d: float, rates: Rates):
    """F_{theta_hat_{Tf,Td}(t)}(x) per Lemma 1.

    Walk forked at t_f < t, terminated at t_d (pass t_d = t for a walk
    that is still active).
    """
    lr, la = rates.lambda_r, rates.lambda_a
    x = np.asarray(x, dtype=np.float64)
    t_d = min(t_d, t)
    hi = math.exp(-lr * (t - t_d))  # largest observable value
    lo = math.exp(-lr * (t - t_f))  # smallest observable value
    atom = math.exp(-la * (t_d - t_f))  # P(fork never arrived before t_d)
    x_safe = np.where(x > 0, x, 1.0)  # mid is only used for x >= lo > 0
    mid = (
        x / hi * (1.0 - math.exp(-la * (t - t_f)) * np.power(x_safe, -la / lr))
        + atom
    )
    out = np.where(x >= hi, 1.0, np.where(x < lo, atom, np.clip(mid, 0.0, 1.0)))
    return out


def fork_estimate_mean_closed(t: float, t_f: float, t_d: float, rates: Rates) -> float:
    """Corollary 1 closed form."""
    lr, la = rates.lambda_r, rates.lambda_a
    t_d = min(t_d, t)
    ratio = 1.0 / (2.0 - la / lr)
    term1 = math.exp(-la * (t_d - t_f)) * math.exp(-lr * (t - t_d)) * (ratio - 1.0)
    term2 = math.exp(-lr * (t - t_d)) / 2.0
    term3 = (
        math.exp(-2.0 * lr * (t - t_f))
        * math.exp(lr * (t - t_d))
        * (0.5 - ratio)
    )
    return term1 + term2 + term3


def fork_estimate_moments(
    t: float, t_f: float, t_d: float, rates: Rates, grid: int = 20000
) -> Tuple[float, float]:
    """(mean, variance) by numerical integration of the Lemma-1 CDF.

    E[X] = int (1-F) dx and E[X^2] = int 2x (1-F) dx over the support
    [0, e^{-lr (t-Td)}] — robust substitute for the Lemma-3 closed form.
    """
    lr = rates.lambda_r
    t_d_eff = min(t_d, t)
    hi = math.exp(-lr * (t - t_d_eff))
    xs = np.linspace(0.0, hi, grid)
    sf = 1.0 - fork_estimate_cdf(xs, t, t_f, t_d, rates)
    mean = float(np.trapezoid(sf, xs))
    ex2 = float(np.trapezoid(2.0 * xs * sf, xs))
    return mean, max(ex2 - mean * mean, 0.0)


# ---------------------------------------------------------------------------
# Lemma 2: mean of theta_hat for a population history
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PopulationHistory:
    """|A_t| long-active walks, terminations at (time,count), forks ditto."""

    n_active: int
    terminations: Tuple[Tuple[float, int], ...] = ()  # (T_d, count)
    forks: Tuple[Tuple[float, int], ...] = ()  # (T_f, count)


def theta_mean(t: float, hist: PopulationHistory, rates: Rates) -> float:
    """Lemma 2 (the visiting walk is one of the long-active ones)."""
    lr, la = rates.lambda_r, rates.lambda_a
    ratio = 1.0 / (2.0 - la / lr)
    m = 0.5 + (hist.n_active - 1) / 2.0
    for t_d, cnt in hist.terminations:
        m += cnt * math.exp(-lr * (t - t_d)) / 2.0
    for t_f, cnt in hist.forks:
        m += cnt * (
            0.5
            + math.exp(-la * (t - t_f)) * (ratio - 1.0)
            + math.exp(-2.0 * lr * (t - t_f)) * (0.5 - ratio)
        )
    return m


def theta_variance(t: float, hist: PopulationHistory, rates: Rates) -> float:
    """sigma^2(t) as used by Lemmas 4/5 (numerical fork variances)."""
    lr = rates.lambda_r
    v = (hist.n_active - 1) / 12.0
    for t_d, cnt in hist.terminations:
        v += cnt * math.exp(-2.0 * lr * (t - t_d)) / 12.0
    for t_f, cnt in hist.forks:
        _, var = fork_estimate_moments(t, t_f, t, rates)
        v += cnt * var
    return v


# ---------------------------------------------------------------------------
# Lemmas 4 & 5: Bennett bounds on fork / termination probability
# ---------------------------------------------------------------------------


def _bennett_h(zeta: float) -> float:
    return (1.0 + zeta) * math.log1p(zeta) - zeta


def fork_probability_bound(
    t: float, hist: PopulationHistory, rates: Rates, eps: float, p: float
) -> float:
    """Lemma 4: for E[theta] > eps, p_fork <= p exp(-sigma^2 h(tau/sigma^2))."""
    m = theta_mean(t, hist, rates)
    tau = m - eps
    if tau <= 0:
        return p  # estimator mean already below threshold: no guarantee
    s2 = max(theta_variance(t, hist, rates), 1e-12)
    return p * math.exp(-s2 * _bennett_h(tau / s2))


def termination_probability_bound(
    t: float, hist: PopulationHistory, rates: Rates, eps2: float, p: float
) -> float:
    """Lemma 5: for E[theta] < eps2, p_term <= p exp(-sigma^2 h(tau/sigma^2))."""
    m = theta_mean(t, hist, rates)
    tau = eps2 - m
    if tau <= 0:
        return p
    s2 = max(theta_variance(t, hist, rates), 1e-12)
    return p * math.exp(-s2 * _bennett_h(tau / s2))


# ---------------------------------------------------------------------------
# Theorem 2: reaction time to the failure of D walks
# ---------------------------------------------------------------------------


def reaction_time_bound(
    d_failed: int,
    r_forked: int,
    k_remaining: int,
    t_d: float,
    eps: float,
    p: float,
    rates: Rates,
    delta: float = 0.05,
    horizon: int = 20000,
    eps_prime_grid: int = 24,
) -> float:
    """Smallest T - t_d such that >= 1 fork happened by T w.p. >= 1-delta.

    delta_{D-R}(T) <= prod_{t=Td}^T [1 - p F_{Sig_{K+R-1}}(eps')
                      F_{Sig_{D-R}}((eps - eps' - 1/2) e^{lr (t-Td)})],
    optimized over the free split eps' in (0, eps - 1/2).
    """
    lr = rates.lambda_r
    d_eff = d_failed - r_forked
    k_eff = k_remaining + r_forked
    if d_eff <= 0:
        return 0.0
    best = math.inf
    for frac in np.linspace(0.05, 0.95, eps_prime_grid):
        eps_p = frac * (eps - 0.5)
        if eps_p <= 0:
            continue
        live_cdf = float(irwin_hall_cdf(eps_p, max(k_eff - 1, 0)))
        if live_cdf <= 0:
            continue
        log_surv = 0.0
        found = None
        for step in range(1, horizon):
            support = math.exp(-lr * step)
            dead_cdf = float(
                scaled_irwin_hall_cdf(eps - eps_p - 0.5, d_eff, support)
            )
            q = 1.0 - p * live_cdf * dead_cdf
            log_surv += math.log(max(q, 1e-300))
            if math.exp(log_surv) <= delta:
                found = step
                break
        if found is not None and found < best:
            best = found
    return best


def multi_fork_reaction_bound(
    d_failed: int,
    k_remaining: int,
    r_target: int,
    t_d: float,
    eps: float,
    p: float,
    rates: Rates,
    delta_total: float = 0.05,
) -> float:
    """Time until >= R' forks, summing Thm. 2 per fork with delta split."""
    per = delta_total / max(r_target, 1)
    total = 0.0
    for r in range(r_target):
        total += reaction_time_bound(
            d_failed, r, k_remaining, t_d, eps, p, rates, delta=per
        )
    return total


# ---------------------------------------------------------------------------
# Theorem 3 / Corollary 2: growth without failures
# ---------------------------------------------------------------------------


def fork_rate_upper(nu: int, eps: float, p: float) -> float:
    """p_nu^+ = nu * p * F_{Sigma_{nu-1}}(eps - 1/2)."""
    return float(nu * p * irwin_hall_cdf(eps - 0.5, max(nu - 1, 0)))


def growth_bound_delta(
    z_max: int, z0: int, horizon: float, n_nodes: int, eps: float, p: float, rates: Rates
) -> float:
    """Thm. 3: P(Z_T > z_max) <= delta for a failure-free run of length T."""
    la = rates.lambda_a
    cum_t = 0.0
    delta = 0.0
    m = z0
    for nu in range(z0, z_max):
        p_nu = max(fork_rate_upper(nu, eps, p), 1e-300)
        t_nu1 = math.log(la * n_nodes / p_nu) / la if la * n_nodes > p_nu else 0.0
        if cum_t + t_nu1 >= horizon:
            m = nu
            break
        cum_t += t_nu1
        delta += n_nodes * math.exp(-la * t_nu1) + t_nu1 * p_nu
        m = nu + 1
    t_m2 = max(horizon - cum_t, 0.0)
    delta += fork_rate_upper(m, eps, p) * t_m2
    return min(delta, 1.0)


def time_until_growth(
    z_max: int, z0: int, n_nodes: int, eps: float, p: float, rates: Rates, delta: float
) -> float:
    """Cor. 2: largest T with P(Z_T > z_max) <= delta (bisection on Thm. 3)."""
    lo, hi = 0.0, 1.0
    while growth_bound_delta(z_max, z0, hi, n_nodes, eps, p, rates) < delta and hi < 1e12:
        hi *= 2.0
    for _ in range(80):
        mid = 0.5 * (lo + hi)
        if growth_bound_delta(z_max, z0, mid, n_nodes, eps, p, rates) < delta:
            lo = mid
        else:
            hi = mid
    return lo


# ---------------------------------------------------------------------------
# Theorem 4: exact (exponential) overshoot bound via the binary threshold tree
# ---------------------------------------------------------------------------


def _binom_tail_above(z: int, kappa: int, p_fork: float) -> float:
    """P(Z' > kappa | Z = z): forks ~ Binomial(z, p_fork), Z' = z + forks."""
    if kappa >= 2 * z:
        return 0.0
    if kappa < z:
        return 1.0
    tail = 0.0
    for k in range(kappa - z + 1, z + 1):
        tail += math.comb(z, k) * p_fork**k * (1 - p_fork) ** (z - k)
    return min(tail, 1.0)


def overshoot_exact_bound(
    z_after_failure: int,
    d_failed: int,
    t_d: float,
    horizon: int,
    eps: float,
    p: float,
    rates: Rates,
    kappa_factor: float = 1.5,
) -> float:
    """Theorem 4: upper bound on E[Z_{t0 + horizon}] after a burst.

    Walks the binary threshold tree: at each step the population either
    stays below the threshold kappa (assumed w.p. <= 1, Z pinned at kappa
    — the paper's bound) or exceeds it (probability upper-bounded by the
    Bennett/binomial tail, Z pinned at the worst case 2Z). Thresholds
    kappa_{1,a} = ceil(kappa_factor * Z) satisfy the paper's constraints
    kappa_{a,1} > kappa_a and kappa_{a,0} <= 2 kappa_a for factor in
    (1, 2]. Exponential in `horizon` — use for horizon <= ~12 (the
    linear-complexity Cor. 3 covers long horizons).
    """
    if not (1.0 < kappa_factor <= 2.0):
        raise ValueError("kappa_factor must be in (1, 2]")
    if horizon < 1:
        return float(z_after_failure)
    if horizon > 16:
        raise ValueError("exponential bound: use overshoot_recursion beyond 16")

    total = 0.0
    # each tree path: (weight, z_current, fork_history tuple)
    paths = [(1.0, z_after_failure, ())]
    for step in range(1, horizon):
        t = t_d + step
        new_paths = []
        for w, z, forks in paths:
            hist = PopulationHistory(
                n_active=z_after_failure,
                terminations=((t_d, d_failed),),
                forks=forks,
            )
            pf = fork_probability_bound(t, hist, rates, eps, p)
            kappa = min(int(math.ceil(kappa_factor * z)), 2 * z)
            if kappa <= z:
                kappa = z + 1
            p_over = _binom_tail_above(z, kappa, pf)
            # branch a=0: Z <= kappa (prob bounded by 1), pin at kappa
            f0 = forks + (((t, kappa - z),) if kappa > z else ())
            new_paths.append((w, kappa, f0))
            # branch a=1: Z > kappa, worst case 2Z
            if p_over > 0 and w * p_over > 1e-12:
                f1 = forks + (((t, z),) if z > 0 else ())
                new_paths.append((w * p_over, 2 * z, f1))
        paths = new_paths
    # leaf expectation: E[Z_{t0+x} | path] <= Z + Z * p_fork(H)
    for w, z, forks in paths:
        hist = PopulationHistory(
            n_active=z_after_failure,
            terminations=((t_d, d_failed),),
            forks=forks,
        )
        pf = fork_probability_bound(t_d + horizon, hist, rates, eps, p)
        total += w * (z + z * pf)
    return total


# ---------------------------------------------------------------------------
# Corollary 3: linear-complexity overshoot recursion
# ---------------------------------------------------------------------------


def overshoot_recursion(
    z_after_failure: int,
    d_failed: int,
    t_d: float,
    steps: int,
    eps: float,
    p: float,
    rates: Rates,
    use_ceiling: bool = True,
) -> np.ndarray:
    """E-bar[Z_{t'}] for t' = T_d+1 .. T_d+steps (Cor. 3).

    The history starts with Z_{T_d} long-active walks and D walks dead at
    T_d; each step appends the expected forks as fork events. With
    ``use_ceiling`` (the paper's literal statement) the bound grows by at
    least 1 per step — the paper itself notes this non-convergence; the
    ceiling-free variant (use_ceiling=False) is the informative
    short-horizon overshoot estimate.
    """
    zs = [float(z_after_failure)]
    forks: list[Tuple[float, int]] = []
    out = np.zeros(steps, dtype=np.float64)
    for i in range(steps):
        t = t_d + 1.0 + i
        hist = PopulationHistory(
            n_active=z_after_failure,
            terminations=((t_d, d_failed),),
            forks=tuple(forks),
        )
        pf = fork_probability_bound(t, hist, rates, eps, p)
        z_prev = math.ceil(zs[-1]) if use_ceiling else zs[-1]
        z_new = z_prev + z_prev * pf
        new_forks = (math.ceil(z_new) if use_ceiling else round(z_new)) - (
            math.ceil(zs[-1]) if use_ceiling else round(zs[-1])
        )
        if new_forks > 0:
            forks.append((t, new_forks))
        zs.append(z_new)
        out[i] = z_new
    return out
