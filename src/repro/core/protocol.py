"""Decision rules: DECAFORK, DECAFORK+ and the MISSINGPERSON baseline.

All rules are pure functions of (estimates, thresholds, PRNG key) returning
boolean event masks; the simulator executes the resulting forks and
terminations via the slot machinery in ``walkers.py``. Rules fire only for
"chosen" walks — per paper footnote 6, a node visited by several walks
runs the procedure for exactly one of them (we pick the lowest slot index).

``ProtocolConfig`` is a registered jax pytree split into
  - *traced data leaves* — the numeric knobs (``z0``, ``eps``, ``eps2``,
    ``eps_mp``, ``fork_prob``, ``protocol_start``, quantiles): jax values
    that vmap/batch across scenarios without recompiling;
  - *static aux fields* — everything that determines program shape or
    branching (``algorithm``, ``max_walks``, ``rt_bins``,
    ``estimator_impl``, ``auto_eps``, ``analytic_survival``,
    ``theta_bin_width``): two configs differing here have different pytree
    structures and therefore different compiled programs.

This split is what lets the sweep engine (``repro.sweep``) run a whole
epsilon grid / failure-regime stack as ONE jit-compiled call.
"""
from __future__ import annotations

import dataclasses
import numbers

import jax
import jax.numpy as jnp

from repro.core.estimator import NEVER
from repro.core.failures import _canonical_leaf

ALGORITHMS = ("none", "missingperson", "decafork", "decafork+")

# numeric, jax-traceable knobs (pytree data leaves, batchable under vmap)
_PROTOCOL_DATA = (
    "z0",
    "eps",
    "eps2",
    "eps_mp",
    "fork_prob",
    "protocol_start",
    "eps_quantile",
    "eps2_quantile",
    "auto_min_samples",
    "p_jump",
    "bias_p",
    "bias_q",
)
# shape/branch-determining fields (pytree aux data, static under jit)
_PROTOCOL_META = (
    "algorithm",
    "max_walks",
    "rt_bins",
    "analytic_survival",
    "estimator_impl",
    "auto_eps",
    "theta_bin_width",
    "round_impl",
    "walk_variant",
    "bloom_bits",
)

ROUND_IMPLS = ("auto", "fused", "unfused")

# movement strategies (repro.zoo.variants implements the non-uniform ones):
#   'uniform' — the paper's walk, a uniform available neighbor (default;
#       compiles the identical pre-zoo program);
#   'jump'    — w.p. p_jump teleport to a uniform up-node (Liu et al.,
#       random walks with jumps — escapes partitions and slow mixing);
#   'biased'  — node2vec-style p/q second-order walk (needs the walk's
#       previous position, carried as a WalkState column);
#   'bloom'   — self-avoiding walk with a per-walk Bloom-filter history
#       (fixed bloom_bits bit array; forked with the slot).
WALK_VARIANTS = ("uniform", "jump", "biased", "bloom")


@dataclasses.dataclass(frozen=True, eq=False)
class ProtocolConfig:
    """Protocol parameters; see module docstring for the static/traced split."""

    algorithm: str = "decafork"
    z0: int | jax.Array = 10  # target number of walks Z_0
    max_walks: int = 40  # walk slot capacity W (>= z0), static
    eps: float | jax.Array = 2.0  # forking threshold (theta_hat < eps)
    eps2: float | jax.Array = 5.75  # termination threshold, DECAFORK+
    eps_mp: float | jax.Array = 300.0  # MISSINGPERSON timeout
    fork_prob: float | jax.Array | None = None  # p; defaults to 1/z0
    rt_bins: int = 1024  # return-time histogram resolution, static
    protocol_start: int | jax.Array = 0  # no decisions before this step
    analytic_survival: bool = False  # footnote 5: geometric survival from pi
    # 'gather' (row-restricted cumsum+gather) | 'compare' (dense compare-
    # accumulate) | 'pallas' (theta_survival kernel) | 'fused' (one
    # round_update pass: scatter+max+sums) | 'auto' (best per backend)
    estimator_impl: str = "gather"
    # ---- beyond-paper: self-calibrating thresholds ----------------------
    # The paper hand-tunes eps per graph (Fig. 4 uses eps in {1.85,2,2.1})
    # and its Irwin-Hall rule ignores the inspection-paradox bias
    # (EXPERIMENTS.md "Estimator bias"). With auto_eps every node records
    # its own theta-hat distribution during the warmup phase and sets its
    # fork/terminate thresholds as LOCAL quantiles of that distribution —
    # decentralized (Rule 1), bias-inclusive, and graph-agnostic.
    auto_eps: bool = False
    eps_quantile: float | jax.Array = 0.05  # fork below this warmup quantile
    eps2_quantile: float | jax.Array = 0.995  # terminate above this quantile
    theta_bin_width: float = 0.25  # histogram bin width, static (shapes)
    auto_min_samples: int | jax.Array = 50  # below: fall back to eps/eps2
    # 'fused' (whole-round single pass: hop + topology + failures +
    # decisions in one dispatch) | 'unfused' (the literal per-stage
    # sequence — the bitwise oracle) | 'auto' (best per backend,
    # REPRO_ROUND_IMPL env override honored). Static (program shape).
    round_impl: str = "auto"
    # ---- zoo walk variants (repro.zoo): movement strategy ---------------
    walk_variant: str = "uniform"  # see WALK_VARIANTS; static (program)
    p_jump: float | jax.Array = 0.0  # 'jump': teleport prob per step
    bias_p: float | jax.Array = 1.0  # 'biased': return parameter p
    bias_q: float | jax.Array = 1.0  # 'biased': in-out parameter q
    bloom_bits: int = 64  # 'bloom': per-walk filter width, static

    def __post_init__(self):
        if self.algorithm not in ALGORITHMS:
            raise ValueError(f"unknown algorithm {self.algorithm!r}")
        if self.round_impl not in ROUND_IMPLS:
            raise ValueError(
                f"unknown round_impl {self.round_impl!r}; "
                f"expected one of {ROUND_IMPLS}"
            )
        if self.walk_variant not in WALK_VARIANTS:
            raise ValueError(
                f"unknown walk_variant {self.walk_variant!r}; "
                f"expected one of {WALK_VARIANTS}"
            )
        # traced z0 values defer this check to the caller (sweep stacks
        # validate statically before batching)
        if isinstance(self.z0, numbers.Integral) and self.max_walks < self.z0:
            raise ValueError("max_walks must be >= z0")

    @property
    def p(self):
        return self.fork_prob if self.fork_prob is not None else 1.0 / self.z0

    @property
    def static_fields(self) -> tuple:
        """The hashable program-shape signature of this config."""
        return tuple(getattr(self, f) for f in _PROTOCOL_META)

    # value-based eq/hash over all fields (concrete array leaves fold to
    # tuples; traced configs raise, as any tracer-hash must)
    def _canonical(self) -> tuple:
        return tuple(
            _canonical_leaf(getattr(self, f))
            for f in _PROTOCOL_DATA + _PROTOCOL_META
        )

    def __eq__(self, other):
        if not isinstance(other, ProtocolConfig):
            return NotImplemented
        return self._canonical() == other._canonical()

    def __hash__(self):
        return hash(self._canonical())


def _protocol_flatten(cfg: ProtocolConfig):
    data = tuple(getattr(cfg, f) for f in _PROTOCOL_DATA)
    aux = tuple(getattr(cfg, f) for f in _PROTOCOL_META)
    return data, aux


def _protocol_unflatten(aux, children) -> ProtocolConfig:
    # bypass __init__/__post_init__: jax may unflatten with placeholder
    # leaves (tracers, avals, bare object()), which must round-trip as-is
    cfg = object.__new__(ProtocolConfig)
    for f, v in zip(_PROTOCOL_DATA, children):
        object.__setattr__(cfg, f, v)
    for f, v in zip(_PROTOCOL_META, aux):
        object.__setattr__(cfg, f, v)
    return cfg


jax.tree_util.register_pytree_node(
    ProtocolConfig, _protocol_flatten, _protocol_unflatten
)


def choose_walks(pos: jax.Array, active: jax.Array, n_nodes: int) -> jax.Array:
    """Footnote 6: per node, select the single lowest-index visiting walk.

    Returns (W,) bool mask of walks that run the protocol this step.
    """
    W = pos.shape[0]
    slots = jnp.arange(W, dtype=jnp.int32)
    cand = jnp.where(active, slots, W)
    best = jnp.full((n_nodes,), W, jnp.int32).at[pos].min(cand, mode="drop")
    return active & (best[pos] == slots)


def choose_walks_pairwise(pos: jax.Array, active: jax.Array) -> jax.Array:
    """``choose_walks`` without the (n,)-sized scatter: each walk takes the
    min candidate slot over the walks sharing its node, via a (W, W)
    compare. Bitwise-identical — for an active walk, the set minimized
    over is exactly the candidates scattered to its node (inactive
    co-located walks contribute the same sentinel W either way) — but
    every array is walk-sized, which is what the fused whole-round path
    needs (W*W tiny; no n-sized intermediate, no scatter).
    """
    W = pos.shape[0]
    slots = jnp.arange(W, dtype=jnp.int32)
    cand = jnp.where(active, slots, W)
    same = pos[:, None] == pos[None, :]
    best = jnp.min(jnp.where(same, cand[None, :], W), axis=1)
    return active & (best == slots)


def decafork_decisions(
    theta: jax.Array,  # (W,) theta-hat per walk
    chosen: jax.Array,  # (W,) bool
    key: jax.Array,
    cfg: ProtocolConfig,
    enabled: jax.Array,  # scalar bool: t >= protocol_start
    eps: jax.Array | float | None = None,  # per-walk override (auto_eps)
    eps2: jax.Array | float | None = None,
):
    """DECAFORK fork mask (and DECAFORK+ termination mask)."""
    eps = cfg.eps if eps is None else eps
    eps2 = cfg.eps2 if eps2 is None else eps2
    k_fork, k_term = jax.random.split(key)
    u_fork = jax.random.uniform(k_fork, theta.shape)
    fork = chosen & (theta < eps) & (u_fork < cfg.p) & enabled
    if cfg.algorithm == "decafork+":
        u_term = jax.random.uniform(k_term, theta.shape)
        term = chosen & (theta > eps2) & (u_term < cfg.p) & enabled
        # eps < eps2 makes these disjoint, but guard anyway
        term = term & ~fork
    else:
        term = jnp.zeros_like(fork)
    return fork, term


def theta_quantile_thresholds(
    theta_hist: jax.Array,  # (n, TB) per-node warmup theta-hat histogram
    pos: jax.Array,  # (W,) node per walk
    cfg: ProtocolConfig,
):
    """Per-walk (eps, eps2) from the visiting node's own theta-hat
    distribution (auto_eps mode). Nodes with too few warmup samples fall
    back to the configured global thresholds."""
    rows = theta_hist[pos]  # (W, TB)
    total = jnp.sum(rows, axis=1, keepdims=True)
    cdf = jnp.cumsum(rows, axis=1) / jnp.maximum(total, 1.0)
    TB = rows.shape[1]
    centers = (jnp.arange(TB, dtype=jnp.float32) + 0.5) * cfg.theta_bin_width

    def quantile(q):
        ok = cdf >= q
        idx = jnp.argmax(ok, axis=1)  # first bin reaching the quantile
        return centers[idx]

    eps_local = quantile(cfg.eps_quantile)
    eps2_local = quantile(cfg.eps2_quantile)
    have = total[:, 0] >= cfg.auto_min_samples
    eps = jnp.where(have, eps_local, cfg.eps)
    eps2 = jnp.where(have, eps2_local, cfg.eps2)
    return eps, eps2


def missingperson_decisions(
    last_seen: jax.Array,  # (n, C) int32
    pos: jax.Array,  # (W,)
    track: jax.Array,  # (W,)
    chosen: jax.Array,  # (W,)
    t: jax.Array,
    key: jax.Array,
    cfg: ProtocolConfig,
    enabled: jax.Array,
) -> jax.Array:
    """MISSINGPERSON: (W, C) mask of replacement-fork events.

    Event (k, l) means: the node visited by walk k deems initial id l
    missing (unseen for > eps_mp) and forks a duplicate of k carrying
    identifier l "in replacement of RW l". Columns are the full track
    space C (= W); only the initial-id columns l < z0 can fire, expressed
    as a mask so that ``z0`` stays a traced (batchable) value.
    """
    W = pos.shape[0]
    C = last_seen.shape[1]
    ls = last_seen[pos]  # (W, C)
    stale = (t - ls) > cfg.eps_mp
    ids = jnp.arange(C, dtype=jnp.int32)[None, :]
    is_initial = ids < cfg.z0
    not_self = ids != track[:, None]
    u = jax.random.uniform(key, (W, C))
    return (
        chosen[:, None] & stale & is_initial & not_self & (u < cfg.p) & enabled
    )
