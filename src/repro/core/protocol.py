"""Decision rules: DECAFORK, DECAFORK+ and the MISSINGPERSON baseline.

All rules are pure functions of (estimates, thresholds, PRNG key) returning
boolean event masks; the simulator executes the resulting forks and
terminations via the slot machinery in ``walkers.py``. Rules fire only for
"chosen" walks — per paper footnote 6, a node visited by several walks
runs the procedure for exactly one of them (we pick the lowest slot index).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.estimator import NEVER

ALGORITHMS = ("none", "missingperson", "decafork", "decafork+")


@dataclasses.dataclass(frozen=True)
class ProtocolConfig:
    """Static protocol parameters (hashable -> usable as a jit static arg)."""

    algorithm: str = "decafork"
    z0: int = 10  # target number of walks Z_0
    max_walks: int = 40  # walk slot capacity W (>= z0)
    eps: float = 2.0  # forking threshold (theta_hat < eps)
    eps2: float = 5.75  # termination threshold (theta_hat > eps2), DECAFORK+
    eps_mp: float = 300.0  # MISSINGPERSON timeout
    fork_prob: float | None = None  # p; defaults to 1/z0
    rt_bins: int = 1024  # return-time histogram resolution
    protocol_start: int = 0  # no fork/terminate decisions before this step
    analytic_survival: bool = False  # footnote 5: geometric survival from pi
    estimator_impl: str = "gather"  # 'gather' | 'compare' | 'pallas'
    # ---- beyond-paper: self-calibrating thresholds ----------------------
    # The paper hand-tunes eps per graph (Fig. 4 uses eps in {1.85,2,2.1})
    # and its Irwin-Hall rule ignores the inspection-paradox bias
    # (EXPERIMENTS.md "Estimator bias"). With auto_eps every node records
    # its own theta-hat distribution during the warmup phase and sets its
    # fork/terminate thresholds as LOCAL quantiles of that distribution —
    # decentralized (Rule 1), bias-inclusive, and graph-agnostic.
    auto_eps: bool = False
    eps_quantile: float = 0.05  # fork below this warmup quantile
    eps2_quantile: float = 0.995  # terminate above this warmup quantile
    theta_bin_width: float = 0.25
    auto_min_samples: int = 50  # fall back to eps/eps2 below this count

    def __post_init__(self):
        if self.algorithm not in ALGORITHMS:
            raise ValueError(f"unknown algorithm {self.algorithm!r}")
        if self.max_walks < self.z0:
            raise ValueError("max_walks must be >= z0")

    @property
    def p(self) -> float:
        return self.fork_prob if self.fork_prob is not None else 1.0 / self.z0


def choose_walks(pos: jax.Array, active: jax.Array, n_nodes: int) -> jax.Array:
    """Footnote 6: per node, select the single lowest-index visiting walk.

    Returns (W,) bool mask of walks that run the protocol this step.
    """
    W = pos.shape[0]
    slots = jnp.arange(W, dtype=jnp.int32)
    cand = jnp.where(active, slots, W)
    best = jnp.full((n_nodes,), W, jnp.int32).at[pos].min(cand, mode="drop")
    return active & (best[pos] == slots)


def decafork_decisions(
    theta: jax.Array,  # (W,) theta-hat per walk
    chosen: jax.Array,  # (W,) bool
    key: jax.Array,
    cfg: ProtocolConfig,
    enabled: jax.Array,  # scalar bool: t >= protocol_start
    eps: jax.Array | float | None = None,  # per-walk override (auto_eps)
    eps2: jax.Array | float | None = None,
):
    """DECAFORK fork mask (and DECAFORK+ termination mask)."""
    eps = cfg.eps if eps is None else eps
    eps2 = cfg.eps2 if eps2 is None else eps2
    k_fork, k_term = jax.random.split(key)
    u_fork = jax.random.uniform(k_fork, theta.shape)
    fork = chosen & (theta < eps) & (u_fork < cfg.p) & enabled
    if cfg.algorithm == "decafork+":
        u_term = jax.random.uniform(k_term, theta.shape)
        term = chosen & (theta > eps2) & (u_term < cfg.p) & enabled
        # eps < eps2 makes these disjoint, but guard anyway
        term = term & ~fork
    else:
        term = jnp.zeros_like(fork)
    return fork, term


def theta_quantile_thresholds(
    theta_hist: jax.Array,  # (n, TB) per-node warmup theta-hat histogram
    pos: jax.Array,  # (W,) node per walk
    cfg: ProtocolConfig,
):
    """Per-walk (eps, eps2) from the visiting node's own theta-hat
    distribution (auto_eps mode). Nodes with too few warmup samples fall
    back to the configured global thresholds."""
    rows = theta_hist[pos]  # (W, TB)
    total = jnp.sum(rows, axis=1, keepdims=True)
    cdf = jnp.cumsum(rows, axis=1) / jnp.maximum(total, 1.0)
    TB = rows.shape[1]
    centers = (jnp.arange(TB, dtype=jnp.float32) + 0.5) * cfg.theta_bin_width
    big = jnp.float32(1e9)

    def quantile(q):
        ok = cdf >= q
        idx = jnp.argmax(ok, axis=1)  # first bin reaching the quantile
        return centers[idx]

    eps_local = quantile(cfg.eps_quantile)
    eps2_local = quantile(cfg.eps2_quantile)
    have = total[:, 0] >= cfg.auto_min_samples
    eps = jnp.where(have, eps_local, cfg.eps)
    eps2 = jnp.where(have, eps2_local, cfg.eps2)
    del big
    return eps, eps2


def missingperson_decisions(
    last_seen: jax.Array,  # (n, C) int32
    pos: jax.Array,  # (W,)
    track: jax.Array,  # (W,)
    chosen: jax.Array,  # (W,)
    t: jax.Array,
    key: jax.Array,
    cfg: ProtocolConfig,
    enabled: jax.Array,
) -> jax.Array:
    """MISSINGPERSON: (W, Z0) mask of replacement-fork events.

    Event (k, l) means: the node visited by walk k deems initial id l
    missing (unseen for > eps_mp) and forks a duplicate of k carrying
    identifier l "in replacement of RW l".
    """
    W = pos.shape[0]
    z0 = cfg.z0
    ls = last_seen[pos, :z0]  # (W, z0)
    stale = (t - ls) > cfg.eps_mp
    ids = jnp.arange(z0)[None, :]
    not_self = ids != track[:, None]
    u = jax.random.uniform(key, (W, z0))
    return chosen[:, None] & stale & not_self & (u < cfg.p) & enabled
