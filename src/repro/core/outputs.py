"""Trajectory output selection: which ``StepOutputs`` fields the scan stacks.

Every simulator round produces a full :class:`StepOutputs` *inside* the
compiled trajectory — that part is free. What is NOT free is stacking a
field over ``steps`` (x seeds x scenarios) in the scan's output buffers:
the per-walk fields (``fork_parent``, ``terminated``) are ``(W,)`` wide,
so recording them costs O(W) more HBM traffic per round than the five
scalar diagnostics, for every trajectory of every sweep.

An :class:`OutputSpec` names the fields a run materializes. The default
is scalars-only; attaching a payload auto-selects the full set (payload
hooks consume the per-walk fields, and their post-hoc replay — e.g. the
``bench_payload`` dispatch-loop arm — needs them recorded). Pass
``outputs=`` to any runner to override either way.

Recorded trajectories come back as a :class:`RecordedOutputs` — a
namedtuple-like, pytree-registered view over exactly the selected fields.
Asking it for a field the spec dropped raises an ``AttributeError`` that
says how to get it back, instead of silently returning stale data.

The spec is static under ``jax.jit`` (hashable, equality by field set):
two runs differing only in their OutputSpec are different compiled
programs, which is the point — the thinned program never allocates the
dropped stacks at all.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Sequence, Tuple

import jax


class StepOutputs(NamedTuple):
    """Everything one synchronous round can report (see simulator.py)."""

    z: jax.Array  # live walk count after the step
    forks: jax.Array  # forks executed this step
    terms: jax.Array  # deliberate terminations this step
    failures: jax.Array  # walks lost to the threat model this step
    theta_mean: jax.Array  # mean theta-hat over chosen walks (diagnostic)
    fork_parent: jax.Array  # (W,) parent slot of a walk forked into s, else -1
    terminated: jax.Array  # (W,) walks deliberately terminated this step


ALL_FIELDS: Tuple[str, ...] = StepOutputs._fields
SCALAR_FIELDS: Tuple[str, ...] = ("z", "forks", "terms", "failures", "theta_mean")
PER_WALK_FIELDS: Tuple[str, ...] = ("fork_parent", "terminated")


@dataclasses.dataclass(frozen=True)
class OutputSpec:
    """The set of ``StepOutputs`` fields a run records (static under jit).

    Field order is canonicalized to ``StepOutputs`` order, so two specs
    naming the same set are equal (and hit the same compiled program)
    regardless of how they were written.
    """

    fields: Tuple[str, ...] = SCALAR_FIELDS

    def __post_init__(self):
        wanted = tuple(self.fields)
        unknown = [f for f in wanted if f not in ALL_FIELDS]
        if unknown:
            raise ValueError(
                f"unknown StepOutputs field(s) {unknown!r}; valid fields are "
                f"{list(ALL_FIELDS)}"
            )
        if not wanted:
            raise ValueError("OutputSpec needs at least one field")
        canonical = tuple(f for f in ALL_FIELDS if f in set(wanted))
        object.__setattr__(self, "fields", canonical)

    def select(self, out: StepOutputs) -> "RecordedOutputs":
        """The thinned per-round view the scan actually stacks."""
        return RecordedOutputs(
            self.fields, tuple(getattr(out, f) for f in self.fields)
        )


SCALARS = OutputSpec(SCALAR_FIELDS)
FULL = OutputSpec(ALL_FIELDS)


@dataclasses.dataclass(frozen=True)
class PayloadOutputSpec:
    """The payload-output fields a run stacks (static under jit).

    Payload outputs are arbitrary per-round pytrees; when they are
    namedtuple-like (``_fields``, e.g. ``RwSgdOutputs``) a spec selects
    which fields the trajectory scan records — the same thinning
    ``OutputSpec`` does for ``StepOutputs``, so an ``RwSgdPayload`` sweep
    can drop the per-slot ``(W,)`` loss telemetry it never reads and its
    ``(S, seeds, steps, W)`` stack is never allocated. ``None`` in place
    of a spec records the payload's full output pytree untouched (the
    legacy behavior, bitwise AND structurally).

    Selection preserves the payload's own field order; the thinned view
    comes back as a :class:`RecordedOutputs`.

    Exactness: thinning never changes what is *computed* — the per-round
    jaxpr is identical, only the scan's stacked outputs shrink. It does
    produce a different XLA program, and dropping a float stack lets the
    backend re-fuse a reduction that feeds a retained field (e.g. the
    ``(W,)`` loss sum inside ``mean_loss``), so retained *float* fields
    can differ from the full run in the final ulp; integer fields are
    exact. (``StepOutputs`` thinning has the same caveat in principle;
    its golden tests pin that the current fields stay bitwise.)
    """

    fields: Tuple[str, ...]

    def __post_init__(self):
        wanted = tuple(self.fields)
        if not wanted:
            raise ValueError("PayloadOutputSpec needs at least one field")
        if len(set(wanted)) != len(wanted):
            object.__setattr__(self, "fields", tuple(dict.fromkeys(wanted)))

    def select(self, pout: Any) -> "RecordedOutputs":
        """The thinned per-round view the scan stacks (trace-time)."""
        have = getattr(pout, "_fields", None)
        if have is None:
            raise TypeError(
                "payload outputs are not field-addressable (no ._fields); "
                "emit a NamedTuple-like outputs pytree to use payload-output "
                f"thinning, or drop the payload field selection {self.fields!r}"
            )
        missing = [f for f in self.fields if f not in have]
        if missing:
            raise ValueError(
                f"payload outputs have no field(s) {missing!r}; this payload "
                f"emits {tuple(have)!r}"
            )
        keep = tuple(f for f in have if f in set(self.fields))
        return RecordedOutputs(keep, tuple(getattr(pout, f) for f in keep))


def resolve_spec(outputs: Any, payload: Any) -> OutputSpec:
    """Resolve a runner's ``outputs=`` argument to a concrete OutputSpec.

    ``None`` means auto: scalars-only for a payload-free run, the full
    field set when a payload is attached (its hooks mirror the per-walk
    fork/terminate events, so recording them costs nothing extra to
    debuggability and keeps replay tooling working).
    """
    if outputs is None:
        return FULL if payload is not None else SCALARS
    if isinstance(outputs, OutputSpec):
        return outputs
    if isinstance(outputs, str):
        named = {"scalars": SCALARS, "full": FULL}
        if outputs in named:
            return named[outputs]
        raise ValueError(
            f"unknown outputs shorthand {outputs!r}; use 'scalars', 'full', "
            "an OutputSpec, or a tuple of StepOutputs field names"
        )
    if isinstance(outputs, Sequence):
        return OutputSpec(tuple(outputs))
    raise TypeError(
        f"outputs must be None, 'scalars', 'full', an OutputSpec or a "
        f"sequence of field names; got {outputs!r}"
    )


def split_outputs(outputs: Any, payload: Any):
    """Resolve ``outputs=`` to ``(OutputSpec, PayloadOutputSpec | None)``.

    The one knob selects BOTH what the simulator records and what the
    payload records: a field-name sequence may freely mix ``StepOutputs``
    names with the payload's own output fields
    (``payload.output_fields()``) — e.g. ``("z", "mean_loss")`` stacks
    one scalar trajectory and one scalar loss curve, dropping the
    per-walk stacks on both sides. A name appearing in both sets resolves
    to the ``StepOutputs`` field.

    Rules:
      * ``None`` / ``'scalars'`` / ``'full'`` / an ``OutputSpec`` — the
        legacy resolution for the simulator fields; the payload records
        its full output pytree (``None`` payload spec);
      * a sequence naming only StepOutputs fields — ditto (legacy
        behavior of ``outputs=(...,)``);
      * a sequence naming any payload fields — those become the
        ``PayloadOutputSpec``; the StepOutputs names (or scalars-only if
        none are given — an explicitly thinned run does not want the
        auto-enabled per-walk stacks) become the ``OutputSpec``.
    """
    if outputs is None or isinstance(outputs, (str, OutputSpec)):
        return resolve_spec(outputs, payload), None
    if isinstance(outputs, PayloadOutputSpec):
        if payload is None:
            raise ValueError(
                "a PayloadOutputSpec was given but no payload is attached"
            )
        return resolve_spec(None, payload), outputs
    if not isinstance(outputs, Sequence):
        return resolve_spec(outputs, payload), None  # canonical TypeError
    names = tuple(outputs)
    step = tuple(f for f in names if f in ALL_FIELDS)
    rest = tuple(f for f in names if f not in ALL_FIELDS)
    if not rest:
        return OutputSpec(step), None
    declared = tuple(payload.output_fields()) if payload is not None else ()
    unknown = [f for f in rest if f not in declared]
    if unknown:
        raise ValueError(
            f"unknown output field(s) {unknown!r}: not StepOutputs fields "
            f"({list(ALL_FIELDS)}) and not payload output fields "
            f"({list(declared)})"
        )
    spec = OutputSpec(step) if step else SCALARS
    return spec, PayloadOutputSpec(rest)


class RecordedOutputs:
    """Namedtuple-like view over the fields an OutputSpec recorded.

    Supports attribute access (``outs.z``), iteration/len/indexing and
    ``_fields`` (so code written against the old ``StepOutputs`` tuple
    keeps working), plus dict-style ``_asdict``. Accessing a known
    ``StepOutputs`` field that the spec dropped raises immediately with
    the fix, instead of an opaque ``None``.
    """

    __slots__ = ("_fields", "_values")

    def __init__(self, fields: Tuple[str, ...], values: Tuple[Any, ...]):
        if len(fields) != len(values):
            raise ValueError("fields/values length mismatch")
        object.__setattr__(self, "_fields", tuple(fields))
        object.__setattr__(self, "_values", tuple(values))

    def __getattr__(self, name):
        fields = object.__getattribute__(self, "_fields")
        if name in fields:
            values = object.__getattribute__(self, "_values")
            return values[fields.index(name)]
        if name in ALL_FIELDS:
            raise AttributeError(
                f"StepOutputs field {name!r} was not recorded: this run's "
                f"OutputSpec is {fields!r}. Re-run with outputs='full' (or an "
                f"OutputSpec including {name!r}) to record it."
            )
        raise AttributeError(name)

    def __setattr__(self, name, value):
        raise AttributeError("RecordedOutputs is immutable")

    def __reduce__(self):
        # pickle/deepcopy support: reconstruct through __init__ (plain
        # slot restoration would trip the immutability guard)
        return (RecordedOutputs, (self._fields, self._values))

    def __iter__(self):
        return iter(self._values)

    def __len__(self):
        return len(self._values)

    def __getitem__(self, i):
        if isinstance(i, str):
            return getattr(self, i)
        return self._values[i]

    def _asdict(self) -> dict:
        return dict(zip(self._fields, self._values))

    def __repr__(self):
        body = ", ".join(
            f"{f}={v!r}" for f, v in zip(self._fields, self._values)
        )
        return f"RecordedOutputs({body})"


def _recorded_flatten(ro: RecordedOutputs):
    return ro._values, ro._fields


def _recorded_unflatten(fields, values) -> RecordedOutputs:
    return RecordedOutputs(tuple(fields), tuple(values))


jax.tree_util.register_pytree_node(
    RecordedOutputs, _recorded_flatten, _recorded_unflatten
)
