"""Pluggable walk-payload API: the computational task the walks execute.

The paper's random walks are not an end in themselves — they *carry a
workload* (decentralized RW-SGD learning, Section I). This module defines
the seam between the self-regulation control plane (``core.simulator``)
and that workload: a :class:`Payload` owns an arbitrary pytree *carry*
that is threaded through the simulator's ``lax.scan`` alongside
``SimState``, with three hooks called once per synchronous round, in
order (mirroring the protocol's own terminate-then-fork slot lifecycle —
a slot freed this round is immediately reallocatable, so a terminated
*and* re-forked slot must be cleared before the fresh copy lands):

  ``on_terminate(carry, terminated)``
      Slots deliberately terminated this round (DECAFORK+). The default
      keeps their state in place — a later re-fork overwrites the slot
      wholesale (see ``optim.rw_sgd.fork_replica``), so clearing is only
      needed for payloads whose freed-slot state must not linger.
  ``on_fork(carry, fork_parent)``
      Walk ``fork_parent[s]`` (>= 0) was duplicated into slot ``s`` this
      round; copy slot state parent -> child (DECAFORK's "identical
      copy"). ``fork_parent`` is the per-slot parent map emitted by
      ``walkers.execute_forks`` (slot allocation itself happens there,
      via ``walkers.allocate_fork_slots``); payloads only mirror it.
  ``on_visit(carry, walks, t, key)``
      The per-round local step: ``walks.pos[s]`` is the node slot ``s``
      sits on *after* this round's hop, ``walks.active[s]`` whether the
      slot is a live walk. Returns ``(carry, outputs)``; the per-round
      ``outputs`` pytree is stacked over time by the scan (this is the
      ``payload_outputs`` every ``run_*`` entry point returns).

``init(key) -> carry`` builds the initial carry; it runs *inside* the
compiled program, so under ``run_ensemble``/``run_sweep`` every
(scenario, seed) trajectory gets its own independently-keyed payload
state, exactly like the walk system itself.

Contract with the control plane: payload keys are folded from dedicated
stream tags (``PAYLOAD_INIT_TAG``, ``PAYLOAD_STREAM``) that the simulator
never uses, so attaching any payload — or none — leaves every simulator
random stream, and therefore every ``StepOutputs`` trajectory, bitwise
unchanged. ``payload=None`` skips the hooks entirely at trace time and is
the exact pre-payload program.

Payload objects are *static* under ``jax.jit``. By default they hash by
identity — construct one instance and reuse it across calls, or every
fresh instance recompiles. A payload that implements
:meth:`Payload.signature` (a stable tuple of its static configuration)
upgrades to *structural* identity: two instances with equal signatures
compare equal, share one compile-cache slot and one compiled program
(``repro.api.plan``), and gain a stable cross-process key for the
disk-backed result store (``repro.api.store``). Anything traced belongs
in the carry; anything structural (model definition, optimizer,
capacity) belongs on the object AND in the signature — a signature that
omits a knob which changes the computation will silently share compiled
programs between payloads that should differ.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax

# dedicated PRNG stream tags; the simulator uses fold_in_time tags 0..5
PAYLOAD_INIT_TAG = 0x70AD  # folds the run key into the payload init key
PAYLOAD_STREAM = 6  # per-round on_visit key stream


class Payload:
    """Base payload: empty carry, every hook a no-op.

    Subclass and override what you need; the base class is itself a valid
    payload (useful for asserting the control plane is payload-invariant).
    See ``optim.rw_sgd.RwSgdPayload`` for the flagship implementation and
    this module's docstring for hook semantics and ordering.
    """

    def validate(self, pcfg) -> None:
        """Static compatibility check against the ProtocolConfig; called
        once per ``run_*`` entry point, outside the trace. Raise on
        mismatch (e.g. slot-capacity disagreement)."""

    def signature(self) -> Tuple | None:
        """Stable static-config tuple identifying this payload's program.

        Return a hashable tuple of everything structural — model config,
        optimizer hyperparameters, task identity, capacities — built only
        from primitives/tuples/dataclasses so it serializes stably across
        processes. Two payloads with equal signatures are treated as THE
        SAME program: they share a compile-cache slot, a compiled XLA
        program, and a result-store key. The default ``None`` keeps
        identity semantics (no structural sharing; disk-backed result
        persistence unavailable for runs carrying this payload).
        """
        return None

    def _signature_key(self) -> Tuple | None:
        """Type-qualified stable identity, or None for identity hashing."""
        sig = self.signature()
        if sig is None:
            return None
        return (type(self).__module__, type(self).__qualname__, sig)

    # structural eq/hash when a signature is declared; identity otherwise
    def __eq__(self, other):
        key = self._signature_key()
        if key is None or not isinstance(other, Payload):
            return self is other
        return key == other._signature_key()

    def __hash__(self):
        key = self._signature_key()
        return object.__hash__(self) if key is None else hash(key)

    def output_fields(self) -> Tuple[str, ...]:
        """Names of the per-round output fields this payload emits (the
        ``_fields`` of the pytree ``on_visit`` returns). Used by the
        ``outputs=`` payload-output thinning (``core.outputs``); return
        ``()`` (the default) when the payload emits no addressable
        fields — thinning is then unavailable and the full output pytree
        is recorded."""
        return ()

    def init(self, key: jax.Array) -> Any:
        """Build the initial carry pytree (traced; per-trajectory key)."""
        return ()

    def on_fork(self, carry: Any, fork_parent: jax.Array) -> Any:
        """Mirror this round's slot duplications: ``fork_parent[s]`` is the
        parent slot copied into ``s``, or -1 where no fork landed."""
        return carry

    def on_visit(
        self, carry: Any, walks, t: jax.Array, key: jax.Array
    ) -> Tuple[Any, Any]:
        """Per-round local step at the visited nodes; returns
        ``(new_carry, outputs)`` — outputs are stacked over rounds."""
        return carry, ()

    def on_terminate(self, carry: Any, terminated: jax.Array) -> Any:
        """React to deliberate terminations (boolean per-slot mask)."""
        return carry


def payload_init_key(key: jax.Array) -> jax.Array:
    """The carry-init key derived from a trajectory's run key."""
    return jax.random.fold_in(key, PAYLOAD_INIT_TAG)
