"""Walk-slot state machine: movement, forking, termination.

Fixed-shape formulation of a dynamic population: the system owns
``max_walks`` slots; a slot is a walk iff ``active[slot]``. Forking
allocates a free slot (events beyond capacity are dropped — a documented
truncation of the paper's unbounded walk population); termination frees
the slot. ``track[slot]`` names the column of the per-node ``last_seen``
table the walk writes to: for DECAFORK each slot owns its own column
(fresh identity per fork, cleared on slot reuse); for MISSINGPERSON the
track is the *initial id* in [Z_0] being replaced, so replacements share
the identity of the walk they replace — exactly the paper's semantics.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.estimator import NEVER


class WalkState(NamedTuple):
    pos: jax.Array  # (W,) int32 current node
    active: jax.Array  # (W,) bool
    track: jax.Array  # (W,) int32 last_seen column owned by this walk
    # ---- zoo walk-variant memory (None unless the variant needs it; a
    # None field is an empty pytree subtree, so the default program and
    # its scan carry are structurally unchanged) -------------------------
    prev: jax.Array | None = None  # (W,) int32 previous node ('biased')
    bloom: jax.Array | None = None  # (W, bloom_bits) bool history ('bloom')


def init_walks(z0: int, max_walks: int, n_nodes: int, key: jax.Array) -> WalkState:
    """Start Z_0 walks at uniformly random nodes (footnote 4 variant)."""
    pos0 = jax.random.randint(key, (max_walks,), 0, n_nodes, dtype=jnp.int32)
    slots = jnp.arange(max_walks, dtype=jnp.int32)
    return WalkState(pos=pos0, active=slots < z0, track=slots)


def select_available_edge(row_mask: jax.Array, u: jax.Array, count_dtype):
    """Rank-select one available incident-edge slot per row, branch-free.

    ``row_mask`` is (W, D) availability over each walk's incident-edge
    slots, ``u`` the (W,) uniforms. Returns ``(adeg, sel)``: the count of
    available edges per row (``count_dtype``, == degree when the mask is
    full) and the selected slot index — the ``idx``-th available slot
    with ``idx = min(floor(u * adeg), adeg - 1)``. When every mask is
    full the available slots are exactly ``[0, degree)`` in order, so
    rank == slot index and the selection is bitwise the unmasked
    ``min(floor(u * degree), degree - 1)``. ``sel`` is garbage where
    ``adeg == 0`` (callers hold position there). Shared by the
    single-host hop (``move_walks``) and the shard_map'd distributed
    step, which must sample identically to stay in parity.
    """
    adeg = jnp.sum(row_mask, axis=1, dtype=count_dtype)
    idx = jnp.minimum((u * adeg).astype(jnp.int32), adeg - 1)
    # rank available slots per row; select the idx-th one
    rank = jnp.cumsum(row_mask, axis=1) - 1
    sel = jnp.argmax((rank == idx[:, None]) & row_mask, axis=1)
    return adeg, sel


def move_walks(
    ws: WalkState,
    neighbors: jax.Array,
    degrees: jax.Array,
    key: jax.Array,
    avail: jax.Array | None = None,
) -> WalkState:
    """One synchronous hop: each active walk moves to a uniform *available*
    neighbor.

    ``avail`` is the (n, max_deg) traversability mask from
    ``graphs.state.availability`` (None == everything up). Sampling is
    branch-free over masked slots (``select_available_edge``): draw
    u ~ U[0,1), scale by the count of available incident edges, and take
    the edge of that rank — bitwise the unmasked
    ``neighbors[pos, min(floor(u * degree), degree - 1)]`` when every
    mask is full. A walk whose node has no available incident edge
    (stranded on an isolated node) holds position.
    """
    W = ws.pos.shape[0]
    D = neighbors.shape[1]
    u = jax.random.uniform(key, (W,))
    if avail is None:
        row_mask = jnp.arange(D, dtype=degrees.dtype)[None, :] < degrees[ws.pos, None]
    else:
        row_mask = avail[ws.pos]  # (W, D)
    adeg, sel = select_available_edge(row_mask, u, degrees.dtype)
    nxt = neighbors[ws.pos, sel]
    can_move = ws.active & (adeg > 0)
    return ws._replace(pos=jnp.where(can_move, nxt, ws.pos))


def move_walks_rows(
    ws: WalkState,
    neighbors_rows: jax.Array,  # (W, D) = neighbors[ws.pos]
    u: jax.Array,  # (W,) pre-drawn hop uniforms
    avail_rows: jax.Array,  # (W, D) availability at each walk's node
    count_dtype,
) -> jax.Array:
    """Row-restricted hop: ``move_walks`` on pre-gathered walk rows.

    Takes the (W, D) adjacency and availability rows of the walks' own
    nodes (instead of gathering from the (n, D) tables internally) plus
    pre-drawn uniforms, and returns the new ``pos``. Bitwise-identical
    to ``move_walks`` with ``avail`` built from the same masks: the
    rank-select and the hold-position rule act row-locally, and
    ``take_along_axis`` on the gathered rows reads the very same
    entries as ``neighbors[pos, sel]``. This is the fused whole-round
    hop — everything it needs is (W, D)-shaped and VMEM-friendly.
    """
    adeg, sel = select_available_edge(avail_rows, u, count_dtype)
    nxt = jnp.take_along_axis(neighbors_rows, sel[:, None], axis=1)[:, 0]
    can_move = ws.active & (adeg > 0)
    return jnp.where(can_move, nxt, ws.pos)


def execute_terminations(ws: WalkState, term: jax.Array) -> WalkState:
    return ws._replace(active=ws.active & ~term)


def allocate_fork_slots(active: jax.Array, ev_mask: jax.Array):
    """Match fork events to free walk slots (capacity-capped, drop overflow).

    Ranks the free slots and the requested events, then pairs the r-th
    event with the r-th free slot. Returns ``(safe_slot, ev_ok, ev_slot)``:
    ``ev_ok`` marks events that got a slot, ``ev_slot`` is the slot each
    surviving event lands in (garbage where ``~ev_ok``), and ``safe_slot``
    is ``ev_slot`` with dropped events redirected to the out-of-range index
    ``W`` so callers can scatter with ``mode="drop"``. Shared by the
    single-host path (``execute_forks``) and the shard_map'd distributed
    step, which must allocate identically to stay replicated.
    """
    W = active.shape[0]
    slots = jnp.arange(W, dtype=jnp.int32)
    free = ~active
    n_free = jnp.sum(free)
    free_rank = jnp.cumsum(free) - 1  # rank of each slot among free ones
    ev_rank = jnp.cumsum(ev_mask) - 1  # rank of each event
    ev_ok = ev_mask & (ev_rank < n_free)
    rank_to_slot = (
        jnp.zeros((W,), jnp.int32)
        .at[jnp.where(free, free_rank, W)]
        .set(slots, mode="drop")
    )
    ev_slot = rank_to_slot[jnp.clip(ev_rank, 0, W - 1)]  # valid where ev_ok
    safe_slot = jnp.where(ev_ok, ev_slot, W)  # W = drop
    return safe_slot, ev_ok, ev_slot


def execute_grid_forks(
    ws: WalkState,
    last_seen: jax.Array,  # (n, C)
    ev: jax.Array,  # (W, C) bool event grid: (parent walk, identity)
    t: jax.Array,
):
    """MISSINGPERSON-shaped fork grid: event ``(k, l)`` forks a duplicate
    of walk ``k`` carrying identity ``l`` (replacing missing walk ``l``).

    The flat per-event origin/track/parent indices are *derived* from the
    event's grid coordinates (row = parent, column = track, origin =
    parent's node) instead of materializing three broadcast ``(W*C,)``
    index arrays at every call site.
    """
    W, C = ev.shape
    e = jnp.arange(W * C, dtype=jnp.int32)
    parent = e // C
    track = e % C
    return execute_forks(
        ws, last_seen, ev.reshape(-1), ws.pos[parent], track, t, parent
    )


def execute_forks(
    ws: WalkState,
    last_seen: jax.Array,  # (n, C)
    ev_mask: jax.Array,  # (E,) bool fork events
    ev_origin: jax.Array,  # (E,) int32 node the fork leaves from
    ev_track: jax.Array | None,  # (E,) int32 identity, or None -> own slot
    t: jax.Array,
    ev_parent: jax.Array | None = None,  # (E,) parent walk slot per event
):
    """Allocate free slots to fork events (capacity-capped, drop overflow).

    Returns (new WalkState, new last_seen, n_forks_executed, fork_parent)
    where fork_parent[s] is the parent slot of a walk forked into slot s
    this call (-1 otherwise) — the hook the learning layer uses to
    duplicate the parent's model replica (DECAFORK's "identical copy").
    """
    W = ws.pos.shape[0]
    slots = jnp.arange(W, dtype=jnp.int32)
    safe_slot, ev_ok, ev_slot = allocate_fork_slots(ws.active, ev_mask)

    if ev_parent is None:
        ev_parent = jnp.arange(ev_mask.shape[0], dtype=jnp.int32)
    fork_parent = (
        jnp.full((W,), -1, jnp.int32).at[safe_slot].set(ev_parent, mode="drop")
    )
    active = ws.active.at[safe_slot].set(True, mode="drop")
    pos = ws.pos.at[safe_slot].set(ev_origin, mode="drop")
    if ev_track is None:
        # DECAFORK: fresh identity = the slot itself; clear the stale column
        track = ws.track.at[safe_slot].set(ev_slot, mode="drop")
        fresh = jnp.zeros((W,), bool).at[safe_slot].set(True, mode="drop")
        col_origin = jnp.zeros((W,), jnp.int32).at[safe_slot].set(ev_origin, mode="drop")
        last_seen = jnp.where(fresh[None, :], NEVER, last_seen)
        # the forking node has, by construction, just seen the new walk
        last_seen = last_seen.at[col_origin, slots].add(
            jnp.where(fresh, t - NEVER, 0).astype(last_seen.dtype)
        )
    else:
        # MISSINGPERSON: replacement carries the missing walk's identity
        track = ws.track.at[safe_slot].set(ev_track, mode="drop")
    # zoo variant memory forks with the slot: the child duplicates the
    # parent's previous-node column and Bloom history
    prev = ws.prev
    if prev is not None:
        prev = prev.at[safe_slot].set(prev[ev_parent], mode="drop")
    bloom = ws.bloom
    if bloom is not None:
        bloom = bloom.at[safe_slot].set(bloom[ev_parent], mode="drop")
    return (
        WalkState(pos=pos, active=active, track=track, prev=prev, bloom=bloom),
        last_seen,
        jnp.sum(ev_ok),
        fork_parent,
    )
