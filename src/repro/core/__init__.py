"""The paper's primary contribution: self-regulating random walks.

DECAFORK / DECAFORK+ / MISSINGPERSON protocols, the return-time estimator,
the jitted multi-walk simulator, the node-sharded distributed step, and the
Section IV/V theory (Irwin-Hall threshold design + computable bounds).
"""
from repro.core.protocol import ProtocolConfig, ALGORITHMS
from repro.core.failures import FailureConfig
from repro.core.outputs import (
    FULL,
    SCALARS,
    OutputSpec,
    RecordedOutputs,
    StepOutputs,
)
from repro.core.payload import Payload
from repro.core.simulator import (
    run_simulation,
    run_ensemble,
    reaction_time,
    max_overshoot,
    survived,
    SimState,
)
from repro.core.irwin_hall import (
    irwin_hall_cdf,
    scaled_irwin_hall_cdf,
    design_eps,
    design_eps2,
    false_fork_probability,
    false_termination_probability,
)

__all__ = [
    "ProtocolConfig",
    "ALGORITHMS",
    "FailureConfig",
    "FULL",
    "SCALARS",
    "OutputSpec",
    "RecordedOutputs",
    "Payload",
    "run_simulation",
    "run_ensemble",
    "reaction_time",
    "max_overshoot",
    "survived",
    "SimState",
    "StepOutputs",
    "irwin_hall_cdf",
    "scaled_irwin_hall_cdf",
    "design_eps",
    "design_eps2",
    "false_fork_probability",
    "false_termination_probability",
]
