"""Node-sharded distributed protocol step (shard_map).

The paper's system is decentralized: every node acts on *local* state only
(Rule 1). This maps naturally onto SPMD: we shard the per-node protocol
state — ``last_seen`` rows, return-time histograms — across a 1-D device
axis (or a flattened ('pod','data') pair for the multi-pod mesh), while the
O(Z) walk descriptors (positions, active flags, tracks) stay replicated.

Per round each device:
  1. computes next hops for the walks currently sitting on *its* nodes
     (it owns their neighbor lists, and the live-topology masks for its
     rows) and contributes them to a psum — the SPMD analogue of "the
     holding node forwards the token". Movement samples over *available*
     incident edges (``GraphState`` semantics: down nodes/links are
     unreachable, a stranded walk holds position, a crashed node kills
     its residents), matching the single-device ``walkers.move_walks``
     path bit-for-bit on a 1-device mesh;
  2. records return-time samples / last-seen updates for its own rows;
  3. evaluates theta-hat and the fork/terminate rule for walks choosing
     its nodes, and contributes decision masks to a psum — decisions are
     node-local, exactly Rule 1; the psum is the message exchange.

Only two collectives per round (both over the O(max_walks) walk axis), so
collective bytes are independent of graph size — the protocol scales to
arbitrarily large node counts. This is the paper technique as a
first-class distributed feature; ``launch/dryrun.py`` lowers it for the
production meshes alongside the payload train steps.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import estimator as est
from repro.core import protocol as prt
from repro.core import walkers as wlk
from repro.core.walkers import WalkState
from repro.graphs.state import availability_rows
from repro.utils.compat import shard_map
from repro.utils.prng import fold_in_time


class ShardedProtocolState(NamedTuple):
    """Walk state replicated; node tables sharded on their first axis."""

    t: jax.Array
    pos: jax.Array  # (W,) replicated
    active: jax.Array  # (W,) replicated
    track: jax.Array  # (W,) replicated
    last_seen: jax.Array  # (n, W) node-sharded
    hist: jax.Array  # (n, B) node-sharded
    total: jax.Array  # (n,) node-sharded
    key: jax.Array  # replicated


def make_sharded_step(
    mesh: Mesh,
    node_axes: Sequence[str],
    n_nodes: int,
    pcfg: prt.ProtocolConfig,
):
    """Build the shard_map'd protocol round for `mesh` with nodes sharded
    over `node_axes` (e.g. ('data',) or ('pod', 'data')).

    The step takes the live-topology masks as trailing arguments:
    ``node_up`` (n,) bool replicated — availability needs the liveness of
    *neighbor* nodes, which live on other shards, so the cheap O(n)-bool
    vector stays replicated rather than adding a gather collective — and
    ``edge_up`` (n, max_deg) bool node-sharded alongside ``neighbors``.
    Pass all-True masks for a static topology (bitwise the unmasked hop).
    """

    axes = tuple(node_axes)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    if n_nodes % n_shards:
        raise ValueError(f"n_nodes={n_nodes} must divide over {n_shards} shards")
    n_local = n_nodes // n_shards

    node_spec = P(axes)
    rep = P()
    in_specs = (
        rep,  # t
        rep,  # pos
        rep,  # active
        rep,  # track
        node_spec,  # last_seen
        node_spec,  # hist
        P(axes),  # total
        rep,  # key
        node_spec,  # neighbors
        P(axes),  # degrees
        rep,  # node_up — replicated: availability needs neighbor liveness
        node_spec,  # edge_up
    )
    out_specs = (rep, rep, rep, rep, node_spec, node_spec, P(axes), rep, rep)

    def _shard_offset():
        off = jnp.int32(0)
        for a in axes:
            off = off * mesh.shape[a] + jax.lax.axis_index(a)
        return off * n_local

    def step(
        t, pos, active, track, last_seen, hist, total, key, neighbors, degrees,
        node_up, edge_up,
    ):
        W = pos.shape[0]
        lo = _shard_offset()
        # a down node kills its resident walks (kill_resident_walks parity;
        # node_up is replicated, so this needs no collective)
        active = active & node_up[pos]
        local = active & (pos >= lo) & (pos < lo + n_local)
        lpos = jnp.clip(pos - lo, 0, n_local - 1)

        # --- 1. movement: owner shard proposes the next hop over the
        # currently *available* incident edges (the same shared
        # rank-select as walkers.move_walks — bitwise-identical sampling
        # is what keeps the two paths in parity); a stranded walk
        # proposes its own position.
        k_move = fold_in_time(key, t, 0)
        u = jax.random.uniform(k_move, (W,))
        up_local = jax.lax.dynamic_slice_in_dim(node_up, lo, n_local)
        avail = availability_rows(edge_up, up_local, node_up, neighbors, degrees)
        row_mask = avail[lpos]  # (W, D)
        adeg, sel = wlk.select_available_edge(row_mask, u, degrees.dtype)
        nxt_local = neighbors[lpos, sel]
        proposal = jnp.where(local, jnp.where(adeg > 0, nxt_local, pos), 0)
        new_pos = jax.lax.psum(proposal, axes)
        pos = jnp.where(active, new_pos, pos)

        # --- 2. observations on local rows -------------------------------
        local = active & (pos >= lo) & (pos < lo + n_local)
        lpos = jnp.clip(pos - lo, 0, n_local - 1)
        prev = last_seen[lpos, track]
        r = t - prev
        valid = local & (prev != est.NEVER) & (r >= 1)
        bins = hist.shape[1]
        b = jnp.clip(r, 1, bins) - 1
        w = valid.astype(jnp.float32)
        hist = hist.at[lpos, b].add(jnp.where(local, w, 0.0), mode="drop")
        total = total.at[lpos].add(jnp.where(local, w, 0.0), mode="drop")
        upd = jnp.where(local, t, est.NEVER)
        last_seen = last_seen.at[lpos, track].max(upd, mode="drop")

        # --- 3. node-local estimates + decisions -------------------------
        slots = jnp.arange(W, dtype=jnp.int32)
        cand = jnp.where(local, slots, W)
        best = jnp.full((n_local,), W, jnp.int32).at[lpos].min(
            jnp.where(local, cand, W), mode="drop"
        )
        chosen = local & (best[lpos] == slots)

        cum = jnp.concatenate(
            [jnp.zeros_like(hist[:, :1]), jnp.cumsum(hist, axis=1)], axis=1
        )
        ls_rows = last_seen[lpos]  # (W, C)
        elapsed = t - ls_rows
        nodes_b = jnp.broadcast_to(lpos[:, None], ls_rows.shape)
        s = est.survival_eval(cum, total, nodes_b, elapsed)
        cols = jnp.arange(ls_rows.shape[1])[None, :]
        mask = (ls_rows != est.NEVER) & (cols != track[:, None])
        theta = 0.5 + jnp.sum(jnp.where(mask, s, 0.0), axis=1)

        enabled = t >= pcfg.protocol_start
        k_dec = fold_in_time(key, t, 4)
        fork_local, term_local = prt.decafork_decisions(
            theta, chosen, k_dec, pcfg, enabled
        )
        # --- decision exchange: disjoint masks -> psum ---------------------
        fork = jax.lax.psum(fork_local.astype(jnp.int32), axes) > 0
        term = jax.lax.psum(term_local.astype(jnp.int32), axes) > 0

        # --- 4. execute (replicated, deterministic) ------------------------
        active = active & ~term
        ev_origin = pos  # forked walk starts where its parent sits
        safe_slot, ev_ok, ev_slot = wlk.allocate_fork_slots(active, fork)
        active = active.at[safe_slot].set(True, mode="drop")
        pos = pos.at[safe_slot].set(ev_origin, mode="drop")
        track = track.at[safe_slot].set(ev_slot, mode="drop")
        # clear the reused local column + mark the fork origin if local
        fresh = jnp.zeros((W,), bool).at[safe_slot].set(ev_ok, mode="drop")
        col_origin = jnp.zeros((W,), jnp.int32).at[safe_slot].set(
            jnp.clip(ev_origin - lo, 0, n_local - 1), mode="drop"
        )
        origin_is_local = jnp.zeros((W,), bool).at[safe_slot].set(
            ev_ok & (ev_origin >= lo) & (ev_origin < lo + n_local), mode="drop"
        )
        last_seen = jnp.where(fresh[None, :], est.NEVER, last_seen)
        last_seen = last_seen.at[col_origin, slots].add(
            jnp.where(origin_is_local & fresh, t - est.NEVER, 0).astype(
                last_seen.dtype
            )
        )

        z = jnp.sum(active)
        return t + 1, pos, active, track, last_seen, hist, total, key, z

    return shard_map(step, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_vma=False)
