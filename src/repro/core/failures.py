"""Threat models (Section II): burst, probabilistic and Byzantine failures.

The protocol makes no assumption about failures; these models exist to
*challenge* it, mirroring the paper's evaluation:
  1) burst: D walks fail simultaneously at scheduled times (Figs. 1, 4-6);
  2) probabilistic: each walk independently dies w.p. p_f per step (Fig. 2);
  3) Byzantine: one node follows a 2-state Markov chain and, while in the
     Byz state, deterministically terminates every incoming walk (Fig. 3).

``FailureConfig`` is a registered jax pytree whose fields are all *traced
numeric leaves*: rates, times and node ids are jax-traceable values, so
many failure regimes batch under ``jax.vmap`` and share one compiled
program (the sweep engine, ``repro.sweep``). Only the number of scheduled
bursts is shape-determining — configs with different burst counts have
different pytree structures (pad with ``pad_bursts`` to co-batch them).
Every model below is branch-free on traced values: a disabled mechanism
(rate 0, node -1, no bursts) is a numeric no-op on the same program.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _static_len(x) -> int:
    """Length of a bursts field (tuple or (K,) array/tracer), shape-static."""
    return 0 if x is None else len(x)


def _canonical_leaf(v):
    """Hashable stand-in for a config leaf (concrete arrays -> tuples)."""
    if isinstance(v, (jax.Array, np.ndarray)):
        return tuple(np.asarray(v).reshape(-1).tolist())
    return v


@dataclasses.dataclass(frozen=True, eq=False)
class FailureConfig:
    """All-leaf failure parameters (see module docstring).

    ``burst_times``/``burst_sizes`` accept tuples (converted to (K,) int32
    arrays) or arrays; a burst time of -1 never fires, which is how padded
    scenario stacks encode "fewer bursts than the widest scenario".
    """

    burst_times: Tuple[int, ...] | jax.Array = ()
    burst_sizes: Tuple[int, ...] | jax.Array = ()
    p_fail: float | jax.Array = 0.0
    p_fail_start: int | jax.Array = 0  # probabilistic failures begin here
    byzantine_node: int | jax.Array = -1  # -1 disables
    p_byz: float | jax.Array = 0.0  # state-flip probability per step
    byz_start: bool | jax.Array = True  # start in the Byz state
    byz_start_time: int | jax.Array = 0  # node honest before this step

    def __post_init__(self):
        if _static_len(self.burst_times) != _static_len(self.burst_sizes):
            raise ValueError("burst_times and burst_sizes must align")
        for f in ("burst_times", "burst_sizes"):
            v = getattr(self, f)
            if isinstance(v, (tuple, list)):
                object.__setattr__(
                    self, f, jnp.asarray(v, jnp.int32).reshape((len(v),))
                )

    @property
    def n_bursts(self) -> int:
        """Static burst-slot count (the only shape-bearing field)."""
        return _static_len(self.burst_times)

    # value-based eq/hash: the generated dataclass versions would raise on
    # the (K,) burst arrays; concrete configs stay usable in sets/dicts
    # (traced configs raise, as any tracer-hash must)
    def _canonical(self) -> tuple:
        return tuple(_canonical_leaf(getattr(self, f)) for f in _FAILURE_LEAVES)

    def __eq__(self, other):
        if not isinstance(other, FailureConfig):
            return NotImplemented
        return self._canonical() == other._canonical()

    def __hash__(self):
        return hash(self._canonical())


_FAILURE_LEAVES = tuple(f.name for f in dataclasses.fields(FailureConfig))


def _failure_flatten(cfg: FailureConfig):
    return tuple(getattr(cfg, f) for f in _FAILURE_LEAVES), None


def _failure_unflatten(_aux, children) -> FailureConfig:
    # bypass __init__/__post_init__: jax may unflatten with placeholder
    # leaves (tracers, avals, bare object()), which must round-trip as-is
    cfg = object.__new__(FailureConfig)
    for f, v in zip(_FAILURE_LEAVES, children):
        object.__setattr__(cfg, f, v)
    return cfg


jax.tree_util.register_pytree_node(
    FailureConfig, _failure_flatten, _failure_unflatten
)


def apply_probabilistic_failures(
    active: jax.Array, t: jax.Array, cfg: FailureConfig, key: jax.Array
) -> jax.Array:
    # always draws (p_fail = 0 kills nobody) so the program is rate-agnostic;
    # the draw consumes a dedicated key, so trajectories with p_fail = 0
    # are bitwise those of a config without probabilistic failures.
    die = (jax.random.uniform(key, active.shape) < cfg.p_fail) & (
        t >= cfg.p_fail_start
    )
    return active & ~die


def apply_burst_failures(
    active: jax.Array, t: jax.Array, cfg: FailureConfig, key: jax.Array
) -> jax.Array:
    """Kill `size` uniformly random active walks at each scheduled time."""
    for i in range(cfg.n_bursts):
        bt = cfg.burst_times[i]
        bs = cfg.burst_sizes[i]
        k = jax.random.fold_in(key, i)
        score = jax.random.uniform(k, active.shape)
        score = jnp.where(active, score, jnp.inf)
        # rank among active walks by random score
        rank = jnp.sum(score[:, None] > score[None, :], axis=1)
        kill = active & (rank < bs) & (t == bt)
        active = active & ~kill
    return active


def step_byzantine(
    active: jax.Array,
    pos: jax.Array,
    t: jax.Array,
    byz_state: jax.Array,  # scalar bool (True = Byz / terminating)
    cfg: FailureConfig,
    key: jax.Array,
):
    """Advance the 2-state chain and kill walks sitting on the Byz node.

    The node behaves honestly before ``byz_start_time`` — the paper's
    standing assumption that walks circulate failure-free long enough to
    build return-time statistics before the first failure event. A
    ``byzantine_node`` of -1 disarms the chain entirely (no node index
    matches, no flips) without changing the compiled program.
    """
    armed = (t >= cfg.byz_start_time) & (cfg.byzantine_node >= 0)
    flip = (jax.random.uniform(key, ()) < cfg.p_byz) & armed
    byz_state = jnp.logical_xor(byz_state, flip)
    kill = active & byz_state & armed & (pos == cfg.byzantine_node)
    return active & ~kill, byz_state


def pad_bursts(cfgs):
    """Pad a list of FailureConfigs to a common burst count.

    Padding entries use time -1 / size 0, which never fire; the returned
    configs share one pytree structure and therefore stack into a single
    scenario batch.
    """
    k_max = max((c.n_bursts for c in cfgs), default=0)

    def _pad(c: FailureConfig) -> FailureConfig:
        k = c.n_bursts
        if k == k_max:
            return c
        pad_t = jnp.full((k_max - k,), -1, jnp.int32)
        pad_s = jnp.zeros((k_max - k,), jnp.int32)
        return dataclasses.replace(
            c,
            burst_times=jnp.concatenate([jnp.asarray(c.burst_times, jnp.int32), pad_t]),
            burst_sizes=jnp.concatenate([jnp.asarray(c.burst_sizes, jnp.int32), pad_s]),
        )

    return [_pad(c) for c in cfgs]
