"""Threat models (Section II): burst, probabilistic and Byzantine failures.

The protocol makes no assumption about failures; these models exist to
*challenge* it, mirroring the paper's evaluation:
  1) burst: D walks fail simultaneously at scheduled times (Figs. 1, 4-6);
  2) probabilistic: each walk independently dies w.p. p_f per step (Fig. 2);
  3) Byzantine: one node follows a 2-state Markov chain and, while in the
     Byz state, deterministically terminates every incoming walk (Fig. 3).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class FailureConfig:
    burst_times: Tuple[int, ...] = ()
    burst_sizes: Tuple[int, ...] = ()
    p_fail: float = 0.0
    p_fail_start: int = 0  # probabilistic failures begin at this step
    byzantine_node: int = -1  # -1 disables
    p_byz: float = 0.0  # state-flip probability per step
    byz_start: bool = True  # start in the Byz (terminating) state
    byz_start_time: int = 0  # node behaves honestly before this step

    def __post_init__(self):
        if len(self.burst_times) != len(self.burst_sizes):
            raise ValueError("burst_times and burst_sizes must align")


def apply_probabilistic_failures(
    active: jax.Array, t: jax.Array, cfg: FailureConfig, key: jax.Array
) -> jax.Array:
    if cfg.p_fail <= 0.0:
        return active
    die = (jax.random.uniform(key, active.shape) < cfg.p_fail) & (
        t >= cfg.p_fail_start
    )
    return active & ~die


def apply_burst_failures(
    active: jax.Array, t: jax.Array, cfg: FailureConfig, key: jax.Array
) -> jax.Array:
    """Kill `size` uniformly random active walks at each scheduled time."""
    for i, (bt, bs) in enumerate(zip(cfg.burst_times, cfg.burst_sizes)):
        k = jax.random.fold_in(key, i)
        score = jax.random.uniform(k, active.shape)
        score = jnp.where(active, score, jnp.inf)
        # rank among active walks by random score
        rank = jnp.sum(score[:, None] > score[None, :], axis=1)
        kill = active & (rank < bs) & (t == bt)
        active = active & ~kill
    return active


def step_byzantine(
    active: jax.Array,
    pos: jax.Array,
    t: jax.Array,
    byz_state: jax.Array,  # scalar bool (True = Byz / terminating)
    cfg: FailureConfig,
    key: jax.Array,
):
    """Advance the 2-state chain and kill walks sitting on the Byz node.

    The node behaves honestly before ``byz_start_time`` — the paper's
    standing assumption that walks circulate failure-free long enough to
    build return-time statistics before the first failure event.
    """
    if cfg.byzantine_node < 0:
        return active, byz_state
    armed = t >= cfg.byz_start_time
    flip = (jax.random.uniform(key, ()) < cfg.p_byz) & armed
    byz_state = jnp.logical_xor(byz_state, flip)
    kill = active & byz_state & armed & (pos == cfg.byzantine_node)
    return active & ~kill, byz_state
