"""Threat models (Section II): walk-level and topology-level failures.

The protocol makes no assumption about failures; these models exist to
*challenge* it, mirroring the paper's evaluation plus the dynamic-topology
regimes of the related work (Pac-Man attack, arXiv:2508.05663; multi-stream
regimes, arXiv:2504.09792):
  1) burst: D walks fail simultaneously at scheduled times (Figs. 1, 4-6);
  2) probabilistic: each walk independently dies w.p. p_f per step (Fig. 2);
  3) Byzantine: one node follows a 2-state Markov chain and, while in the
     Byz state, deterministically terminates every incoming walk (Fig. 3);
  4) node crashes: scheduled (``node_crash_times``/``node_crash_ids``) or
     i.i.d. (``p_node_fail``) — a crashed node kills its resident walks,
     drops out of the topology, and recovers w.p. ``p_node_recover``;
  5) link failures: each undirected edge independently fails w.p.
     ``p_link_fail`` per step and recovers w.p. ``p_link_recover``;
  6) Pac-Man: one adversarial node silently absorbs every visiting walk
     (unlike the Byzantine chain it never flips back to honesty);
  7) zoo attacks (``repro.zoo``): *multiple* simultaneous Pac-Man nodes
     (``pacman_nodes``, a shape-bearing id array), a *mobile* Pac-Man
     whose position hops each round (``pacman_mobile`` — the hopping
     position is traced scan state, see ``step_mobile_pacman``), and
     scheduled *partition cuts* (``edge_cut_times``/``edge_cut_thresholds``
     — at the scheduled step every edge crossing the node-id threshold
     goes down at once, splitting the graph into two components).

Models 4-7 act on :class:`repro.graphs.state.GraphState`, the live
topology masks carried through the simulator's scan (``step_topology``),
or on positions carried alongside it; 1-3 act directly on walk liveness.

``FailureConfig`` is a registered jax pytree whose fields are almost all
*traced numeric leaves*: rates, times and node ids are jax-traceable
values, so many failure regimes batch under ``jax.vmap`` and share one
compiled program (the sweep engine, ``repro.sweep``). Shape-determining
exceptions: the number of scheduled bursts / node crashes / Pac-Man ids /
edge cuts (configs with different schedule lengths have different pytree
structures — pad with ``pad_bursts`` to co-batch them) and the single
static aux field ``pacman_mobile`` (it decides whether the simulator
carries Pac-Man positions in its scan state, i.e. program structure).
Every model below is branch-free on traced values: a disabled mechanism
(rate 0, node -1, no schedule entries) is a numeric no-op on the same
program.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _static_len(x) -> int:
    """Length of a bursts field (tuple or (K,) array/tracer), shape-static."""
    return 0 if x is None else len(x)


def _canonical_leaf(v):
    """Hashable stand-in for a config leaf (concrete arrays -> tuples)."""
    if isinstance(v, (jax.Array, np.ndarray)):
        return tuple(np.asarray(v).reshape(-1).tolist())
    return v


@dataclasses.dataclass(frozen=True, eq=False)
class FailureConfig:
    """All-leaf failure parameters (see module docstring).

    ``burst_times``/``burst_sizes`` accept tuples (converted to (K,) int32
    arrays) or arrays; a burst time of -1 never fires, which is how padded
    scenario stacks encode "fewer bursts than the widest scenario".
    """

    burst_times: Tuple[int, ...] | jax.Array = ()
    burst_sizes: Tuple[int, ...] | jax.Array = ()
    p_fail: float | jax.Array = 0.0
    p_fail_start: int | jax.Array = 0  # probabilistic failures begin here
    byzantine_node: int | jax.Array = -1  # -1 disables
    p_byz: float | jax.Array = 0.0  # state-flip probability per step
    byz_start: bool | jax.Array = True  # start in the Byz state
    byz_start_time: int | jax.Array = 0  # node honest before this step
    # ---- topology-level failures (act on GraphState) --------------------
    node_crash_times: Tuple[int, ...] | jax.Array = ()  # scheduled crashes
    node_crash_ids: Tuple[int, ...] | jax.Array = ()  # node per crash (-1 off)
    p_node_fail: float | jax.Array = 0.0  # i.i.d. per-node crash rate
    p_node_recover: float | jax.Array = 0.0  # per-step recovery of down nodes
    node_fail_start: int | jax.Array = 0  # i.i.d. node crashes begin here
    p_link_fail: float | jax.Array = 0.0  # i.i.d. per-(undirected-)edge rate
    p_link_recover: float | jax.Array = 0.0  # per-step recovery of down links
    link_fail_start: int | jax.Array = 0  # i.i.d. link failures begin here
    pacman_node: int | jax.Array = -1  # silently absorbs visitors (-1 off)
    pacman_start_time: int | jax.Array = 0  # node honest before this step
    # ---- zoo attacks (repro.zoo): multi / mobile Pac-Man, partition cuts
    pacman_nodes: Tuple[int, ...] | jax.Array = ()  # extra Pac-Men (-1 off)
    pacman_hop_prob: float | jax.Array = 1.0  # mobile: hop rate per step
    edge_cut_times: Tuple[int, ...] | jax.Array = ()  # scheduled cuts (-1 off)
    edge_cut_thresholds: Tuple[int, ...] | jax.Array = ()  # node-id boundary
    # STATIC aux field (program structure, not a traced leaf): when True
    # every armed Pac-Man position becomes scan state hopping each round
    pacman_mobile: bool = False

    def __post_init__(self):
        if _static_len(self.burst_times) != _static_len(self.burst_sizes):
            raise ValueError("burst_times and burst_sizes must align")
        if _static_len(self.node_crash_times) != _static_len(self.node_crash_ids):
            raise ValueError("node_crash_times and node_crash_ids must align")
        if _static_len(self.edge_cut_times) != _static_len(self.edge_cut_thresholds):
            raise ValueError("edge_cut_times and edge_cut_thresholds must align")
        for f in (
            "burst_times", "burst_sizes", "node_crash_times", "node_crash_ids",
            "pacman_nodes", "edge_cut_times", "edge_cut_thresholds",
        ):
            v = getattr(self, f)
            if isinstance(v, (tuple, list)):
                object.__setattr__(
                    self, f, jnp.asarray(v, jnp.int32).reshape((len(v),))
                )

    @property
    def n_bursts(self) -> int:
        """Static burst-slot count (shape-bearing)."""
        return _static_len(self.burst_times)

    @property
    def n_node_crashes(self) -> int:
        """Static scheduled-crash count (shape-bearing)."""
        return _static_len(self.node_crash_times)

    @property
    def n_pacman(self) -> int:
        """Static extra-Pac-Man slot count (shape-bearing)."""
        return _static_len(self.pacman_nodes)

    @property
    def n_edge_cuts(self) -> int:
        """Static scheduled-edge-cut count (shape-bearing)."""
        return _static_len(self.edge_cut_times)

    @property
    def static_fields(self) -> tuple:
        """The hashable program-shape signature of this config (the aux
        part only; shape-bearing schedule lengths are reconciled by
        ``pad_bursts`` and tracked separately by the sweep grouping)."""
        return tuple(getattr(self, f) for f in _FAILURE_META)

    # value-based eq/hash: the generated dataclass versions would raise on
    # the (K,) burst arrays; concrete configs stay usable in sets/dicts
    # (traced configs raise, as any tracer-hash must)
    def _canonical(self) -> tuple:
        return tuple(
            _canonical_leaf(getattr(self, f))
            for f in _FAILURE_DATA + _FAILURE_META
        )

    def __eq__(self, other):
        if not isinstance(other, FailureConfig):
            return NotImplemented
        return self._canonical() == other._canonical()

    def __hash__(self):
        return hash(self._canonical())


# static aux fields (program structure, hashed into compile-group keys);
# everything else is a traced (vmap-batchable) data leaf
_FAILURE_META = ("pacman_mobile",)
_FAILURE_DATA = tuple(
    f.name
    for f in dataclasses.fields(FailureConfig)
    if f.name not in _FAILURE_META
)


def _failure_flatten(cfg: FailureConfig):
    data = tuple(getattr(cfg, f) for f in _FAILURE_DATA)
    aux = tuple(getattr(cfg, f) for f in _FAILURE_META)
    return data, aux


def _failure_unflatten(aux, children) -> FailureConfig:
    # bypass __init__/__post_init__: jax may unflatten with placeholder
    # leaves (tracers, avals, bare object()), which must round-trip as-is
    cfg = object.__new__(FailureConfig)
    for f, v in zip(_FAILURE_DATA, children):
        object.__setattr__(cfg, f, v)
    for f, v in zip(_FAILURE_META, aux):
        object.__setattr__(cfg, f, v)
    return cfg


jax.tree_util.register_pytree_node(
    FailureConfig, _failure_flatten, _failure_unflatten
)


def apply_probabilistic_failures(
    active: jax.Array, t: jax.Array, cfg: FailureConfig, key: jax.Array
) -> jax.Array:
    # always draws (p_fail = 0 kills nobody) so the program is rate-agnostic;
    # the draw consumes a dedicated key, so trajectories with p_fail = 0
    # are bitwise those of a config without probabilistic failures.
    die = (jax.random.uniform(key, active.shape) < cfg.p_fail) & (
        t >= cfg.p_fail_start
    )
    return active & ~die


def apply_burst_failures(
    active: jax.Array, t: jax.Array, cfg: FailureConfig, key: jax.Array
) -> jax.Array:
    """Kill `size` uniformly random active walks at each scheduled time."""
    for i in range(cfg.n_bursts):
        bt = cfg.burst_times[i]
        bs = cfg.burst_sizes[i]
        k = jax.random.fold_in(key, i)
        score = jax.random.uniform(k, active.shape)
        score = jnp.where(active, score, jnp.inf)
        # rank among active walks by random score
        rank = jnp.sum(score[:, None] > score[None, :], axis=1)
        kill = active & (rank < bs) & (t == bt)
        active = active & ~kill
    return active


def step_byzantine(
    active: jax.Array,
    pos: jax.Array,
    t: jax.Array,
    byz_state: jax.Array,  # scalar bool (True = Byz / terminating)
    cfg: FailureConfig,
    key: jax.Array,
):
    """Advance the 2-state chain and kill walks sitting on the Byz node.

    The node behaves honestly before ``byz_start_time`` — the paper's
    standing assumption that walks circulate failure-free long enough to
    build return-time statistics before the first failure event. A
    ``byzantine_node`` of -1 disarms the chain entirely (no node index
    matches, no flips) without changing the compiled program.
    """
    armed = (t >= cfg.byz_start_time) & (cfg.byzantine_node >= 0)
    flip = (jax.random.uniform(key, ()) < cfg.p_byz) & armed
    byz_state = jnp.logical_xor(byz_state, flip)
    kill = active & byz_state & armed & (pos == cfg.byzantine_node)
    return active & ~kill, byz_state


def topology_uniforms(
    key: jax.Array, neighbors: jax.Array, mirror: jax.Array
):
    """Draw and symmetrize one step's topology uniforms.

    Returns ``(u_nfail, u_nrec, e_fail, e_rec)`` — the node crash /
    recovery uniforms and the already-mirror-symmetrized link fail /
    recovery uniforms (one canonical draw per undirected edge, living at
    the lower endpoint, reflected to the partner slot via ``mirror``).
    Split out of :func:`step_topology` so the fused whole-round path can
    pre-draw the exact same streams outside its kernel; composing it
    with :func:`apply_topology` IS ``step_topology``, bit for bit.
    """
    n, D = neighbors.shape
    k_nfail, k_nrec, k_lfail, k_lrec = jax.random.split(key, 4)
    u_nfail = jax.random.uniform(k_nfail, (n,))
    u_nrec = jax.random.uniform(k_nrec, (n,))
    u_fail = jax.random.uniform(k_lfail, (n, D))
    u_rec = jax.random.uniform(k_lrec, (n, D))
    ids = jnp.arange(n, dtype=jnp.int32)
    lower = ids[:, None] < neighbors  # this slot holds the canonical draw
    e_fail = jnp.where(lower, u_fail, u_fail[neighbors, mirror])
    e_rec = jnp.where(lower, u_rec, u_rec[neighbors, mirror])
    return u_nfail, u_nrec, e_fail, e_rec


def scheduled_crash_mask(
    n: int, t: jax.Array, cfg: FailureConfig
) -> jax.Array:
    """(n,) bool — nodes downed by a schedule entry firing at step ``t``
    (time -1 / id -1 never fire — the padding encoding)."""
    sched_down = jnp.zeros((n,), bool)
    ids = jnp.arange(n, dtype=jnp.int32)
    for i in range(cfg.n_node_crashes):
        fire = (t == cfg.node_crash_times[i]) & (cfg.node_crash_ids[i] >= 0)
        sched_down = sched_down | ((ids == cfg.node_crash_ids[i]) & fire)
    return sched_down


def apply_topology(
    gs,
    t: jax.Array,
    cfg: FailureConfig,
    sched_down: jax.Array,  # (n,) bool from scheduled_crash_mask
    u_nfail: jax.Array,  # (n,) node crash uniforms
    u_nrec: jax.Array,  # (n,) node recovery uniforms
    e_fail: jax.Array,  # (n, D) symmetrized link-fail uniforms
    e_rec: jax.Array,  # (n, D) symmetrized link-recovery uniforms
    cut_down: jax.Array | None = None,  # (n, D) from edge_cut_mask
):
    """Pure mask update given pre-drawn uniforms (see ``step_topology``).

    ``cut_down`` (when configs schedule edge cuts) forces those edge
    slots down this step and blocks their recovery draw; None keeps the
    pre-zoo program unchanged.
    """
    from repro.graphs.state import GraphState

    crash = (u_nfail < cfg.p_node_fail) & (t >= cfg.node_fail_start)
    recover = u_nrec < cfg.p_node_recover
    node_up = jnp.where(
        gs.node_up, ~(crash | sched_down), recover & ~sched_down
    )
    fail = (e_fail < cfg.p_link_fail) & (t >= cfg.link_fail_start)
    rec = e_rec < cfg.p_link_recover
    if cut_down is None:
        edge_up = jnp.where(gs.edge_up, ~fail, rec)
    else:
        edge_up = jnp.where(gs.edge_up, ~(fail | cut_down), rec & ~cut_down)
    return GraphState(node_up=node_up, edge_up=edge_up)


def step_topology(
    gs,
    t: jax.Array,
    cfg: FailureConfig,
    key: jax.Array,
    neighbors: jax.Array,
    mirror: jax.Array,
):
    """Advance the live topology one step (see ``graphs.state.GraphState``).

    Scheduled crashes fire when ``t == node_crash_times[i]`` and down node
    ``node_crash_ids[i]``; i.i.d. crashes down each up node w.p.
    ``p_node_fail`` once ``t >= node_fail_start``; down nodes recover w.p.
    ``p_node_recover`` (never on the step a schedule entry downs them).
    Each undirected edge fails w.p. ``p_link_fail`` and recovers w.p.
    ``p_link_recover`` — one uniform per undirected edge, shared between
    the two directed slots via the precomputed ``mirror`` involution, so
    availability stays symmetric. All draws consume dedicated keys, so a
    config with every topology knob disabled leaves ``gs`` untouched AND
    leaves every other random stream bitwise unchanged.

    Composition of :func:`topology_uniforms` (the draws) and
    :func:`apply_topology` (the branch-free mask update); the fused
    whole-round path calls the two halves separately.
    """
    n = neighbors.shape[0]
    u_nfail, u_nrec, e_fail, e_rec = topology_uniforms(key, neighbors, mirror)
    sched_down = scheduled_crash_mask(n, t, cfg)
    cut_down = edge_cut_mask(neighbors, t, cfg) if cfg.n_edge_cuts else None
    return apply_topology(
        gs, t, cfg, sched_down, u_nfail, u_nrec, e_fail, e_rec,
        cut_down=cut_down,
    )


def kill_resident_walks(
    active: jax.Array, pos: jax.Array, node_up: jax.Array
) -> jax.Array:
    """A node crash takes its resident walks down with it."""
    return active & node_up[pos]


def initial_pacman_positions(cfg: FailureConfig) -> jax.Array:
    """(1+K,) int32 — the primary ``pacman_node`` followed by the extra
    ``pacman_nodes``. These are the initial positions a ``pacman_mobile``
    run carries through the scan (``step_mobile_pacman`` advances them);
    -1 entries are disarmed and never move or absorb."""
    head = jnp.asarray(cfg.pacman_node, jnp.int32).reshape((1,))
    if cfg.n_pacman == 0:
        return head
    extra = jnp.asarray(cfg.pacman_nodes, jnp.int32).reshape((-1,))
    return jnp.concatenate([head, extra])


def apply_pacman(
    active: jax.Array,
    pos: jax.Array,
    t: jax.Array,
    cfg: FailureConfig,
    pac_pos: jax.Array | None = None,
) -> jax.Array:
    """Pac-Man (arXiv:2508.05663): the adversarial node silently absorbs
    every walk that steps onto it — deterministically, with no recovery
    phase (contrast ``step_byzantine``'s 2-state chain). ``pacman_node``
    of -1 disarms it as a numeric no-op on the same compiled program.

    Zoo extensions: with extra ``pacman_nodes`` configured, every armed
    position absorbs simultaneously; a mobile run passes the carried
    ``pac_pos`` positions instead of the config's static ones.
    """
    if pac_pos is None and cfg.n_pacman == 0:
        # singleton static path — the pre-zoo program, bit for bit
        armed = (t >= cfg.pacman_start_time) & (cfg.pacman_node >= 0)
        kill = active & armed & (pos == cfg.pacman_node)
        return active & ~kill
    pac = initial_pacman_positions(cfg) if pac_pos is None else pac_pos
    hit = ((pos[:, None] == pac[None, :]) & (pac[None, :] >= 0)).any(axis=1)
    kill = active & (t >= cfg.pacman_start_time) & hit
    return active & ~kill


def step_mobile_pacman(
    pac_pos: jax.Array,  # (P,) int32 current Pac-Man positions (-1 off)
    t: jax.Array,
    cfg: FailureConfig,
    key: jax.Array,
    neighbors: jax.Array,
    degrees: jax.Array,
    avail: jax.Array | None = None,
) -> jax.Array:
    """Hop each armed Pac-Man to a uniform *available* neighbor w.p.
    ``pacman_hop_prob`` per step (mobile Pac-Man, after Chen et al.'s
    moving-adversary regime).

    Samples with the same rank-select primitive as walk movement
    (``select_available_edge``) over the live availability mask, so a
    mobile Pac-Man respects downed links exactly like a walk does. Hops
    begin at ``pacman_start_time`` — before that (and wherever the
    position is -1 or the node has no live incident edge) it holds.
    Draws consume a dedicated key, never perturbing other streams.
    """
    from repro.core.walkers import select_available_edge

    P = pac_pos.shape[0]
    n, D = neighbors.shape
    k_hop, k_gate = jax.random.split(key)
    u = jax.random.uniform(k_hop, (P,))
    gate = jax.random.uniform(k_gate, (P,)) < cfg.pacman_hop_prob
    safe = jnp.clip(pac_pos, 0, n - 1)  # -1 rows gather garbage, masked below
    if avail is None:
        row_mask = (
            jnp.arange(D, dtype=degrees.dtype)[None, :] < degrees[safe, None]
        )
    else:
        row_mask = avail[safe]
    adeg, sel = select_available_edge(row_mask, u, degrees.dtype)
    nxt = neighbors[safe, sel]
    can_move = (
        gate & (pac_pos >= 0) & (adeg > 0) & (t >= cfg.pacman_start_time)
    )
    return jnp.where(can_move, nxt, pac_pos)


def edge_cut_mask(
    neighbors: jax.Array, t: jax.Array, cfg: FailureConfig
) -> jax.Array:
    """(n, D) bool — directed edge slots severed by a scheduled cut at ``t``.

    At ``edge_cut_times[i]`` every edge whose endpoints straddle
    ``edge_cut_thresholds[i]`` (node id < thr vs >= thr) goes down at
    once, partitioning the graph along the id boundary — the correlated
    failure regime that motivates the jump-walk defense. Time -1 /
    threshold -1 never fire (the padding encoding). The mask is symmetric
    by construction (``u < thr != v < thr`` is symmetric in u, v). Cut
    edges stay down unless ``p_link_recover`` later revives them.
    """
    n, D = neighbors.shape
    ids = jnp.arange(n, dtype=jnp.int32)
    down = jnp.zeros((n, D), bool)
    for i in range(cfg.n_edge_cuts):
        thr = cfg.edge_cut_thresholds[i]
        fire = (t == cfg.edge_cut_times[i]) & (thr >= 0)
        cross = (ids[:, None] < thr) != (neighbors < thr)
        down = down | (cross & fire)
    return down


def pad_bursts(cfgs):
    """Pad a list of FailureConfigs to common schedule lengths.

    Covers every shape-bearing schedule — walk bursts, scheduled node
    crashes, extra Pac-Man ids, and scheduled edge cuts. Padding entries
    use time/id/threshold -1 (never fires); the returned configs share
    one pytree structure and therefore stack into a single scenario
    batch.
    """
    kb_max = max((c.n_bursts for c in cfgs), default=0)
    kc_max = max((c.n_node_crashes for c in cfgs), default=0)
    kp_max = max((c.n_pacman for c in cfgs), default=0)
    ke_max = max((c.n_edge_cuts for c in cfgs), default=0)

    def _pad_field(v, k, k_max, fill):
        if k == k_max:
            return jnp.asarray(v, jnp.int32) if k else v
        pad = jnp.full((k_max - k,), fill, jnp.int32)
        return jnp.concatenate([jnp.asarray(v, jnp.int32).reshape((k,)), pad])

    def _pad(c: FailureConfig) -> FailureConfig:
        if (
            c.n_bursts == kb_max
            and c.n_node_crashes == kc_max
            and c.n_pacman == kp_max
            and c.n_edge_cuts == ke_max
        ):
            return c
        return dataclasses.replace(
            c,
            burst_times=_pad_field(c.burst_times, c.n_bursts, kb_max, -1),
            burst_sizes=_pad_field(c.burst_sizes, c.n_bursts, kb_max, 0),
            node_crash_times=_pad_field(
                c.node_crash_times, c.n_node_crashes, kc_max, -1
            ),
            node_crash_ids=_pad_field(
                c.node_crash_ids, c.n_node_crashes, kc_max, -1
            ),
            pacman_nodes=_pad_field(c.pacman_nodes, c.n_pacman, kp_max, -1),
            edge_cut_times=_pad_field(
                c.edge_cut_times, c.n_edge_cuts, ke_max, -1
            ),
            edge_cut_thresholds=_pad_field(
                c.edge_cut_thresholds, c.n_edge_cuts, ke_max, -1
            ),
        )

    return [_pad(c) for c in cfgs]
