"""Irwin-Hall distribution and threshold design (Propositions 3 & 4).

Under the probability integral transform, each long-active walk contributes
a U(0,1) term to theta-hat, so for K active walks the probabilistic part of
theta-hat is Irwin-Hall distributed with K-1 summands (Prop. 3). A burst of
D terminated walks contributes a *scaled* Irwin-Hall: uniforms supported on
[0, e^{-lambda_r (t - T_d)}] (Prop. 4).

The closed form
    F_{Sigma_K}(s) = 1/K! * sum_{tau=0}^{floor(s)} (-1)^tau C(K,tau) (s-tau)^K
is numerically delicate for large K (catastrophic cancellation), so we
evaluate it with exact integer binomials in float64 for K <= 25 and fall
back to a grid-convolution CDF beyond; tests cross-check both.

Pure numpy (float64) on purpose: this is *design-time* math used to pick
(eps, eps2), not part of the jitted simulation path.
"""
from __future__ import annotations

import math

import numpy as np


def irwin_hall_cdf(s, k: int):
    """CDF of the sum of k iid U(0,1) at point(s) s.

    The closed form suffers catastrophic cancellation for large k (the
    alternating binomial terms reach ~1e+20 by k ~ 20 and the result
    loses monotonicity — found by the hypothesis property suite), so we
    switch to the grid convolution beyond k = 15.
    """
    if k == 0:
        return (np.asarray(s, dtype=np.float64) >= 0).astype(np.float64)
    if k <= 15:
        return _irwin_hall_cdf_closed(s, k)
    return _irwin_hall_cdf_grid(s, k)


def _irwin_hall_cdf_closed(s, k: int):
    s = np.asarray(s, dtype=np.float64)
    out = np.zeros_like(s)
    flat = s.ravel()
    res = np.empty_like(flat)
    for idx, x in enumerate(flat):
        if x <= 0:
            res[idx] = 0.0
        elif x >= k:
            res[idx] = 1.0
        else:
            acc = 0.0
            for tau in range(int(math.floor(x)) + 1):
                acc += ((-1) ** tau) * math.comb(k, tau) * (x - tau) ** k
            res[idx] = acc / math.factorial(k)
    out = res.reshape(s.shape)
    return np.clip(out, 0.0, 1.0)


def _irwin_hall_cdf_grid(s, k: int, grid_points_per_unit: int = 512):
    """CDF via repeated FFT-free convolution of the uniform density."""
    s = np.asarray(s, dtype=np.float64)
    h = 1.0 / grid_points_per_unit
    # density of U(0,1) sampled on the grid
    base = np.ones(grid_points_per_unit, dtype=np.float64) * h
    dens = base.copy()
    for _ in range(k - 1):
        dens = np.convolve(dens, base) / h * h  # keep mass normalized
    # dens now has support on [0, k); build CDF. Each uniform's cell mass
    # sits at its center (i + 1/2) h, so the k-fold sum's cell j is
    # centered at (j + k/2) h — align xs accordingly (without this the
    # CDF is systematically shifted by k h / 2).
    cdf = np.concatenate([[0.0], np.cumsum(dens)])
    cdf = cdf / cdf[-1]
    xs = (np.arange(len(cdf)) + 0.5 * k - 0.5) * h
    return np.interp(s, xs, cdf, left=0.0, right=1.0)


def scaled_irwin_hall_cdf(s, k: int, support: float):
    """Prop. 4: sum of k iid U(0, support) — F(s) = F_IH(s / support)."""
    if support <= 0:
        return (np.asarray(s, dtype=np.float64) >= 0).astype(np.float64)
    return irwin_hall_cdf(np.asarray(s, dtype=np.float64) / support, k)


def _invert_monotone(f, lo: float, hi: float, target: float, iters: int = 80):
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if f(mid) < target:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def design_eps(z0: int, delta: float = 1e-3) -> float:
    """Pick the forking threshold eps (Section III-B).

    Choose eps such that Pr(theta_hat <= eps | Z_0 active walks)
    = F_{Sigma_{Z0-1}}(eps - 1/2) = delta, i.e. a false fork (with Z_0
    healthy walks) is a delta-probability event per node visit.
    """
    if z0 < 2:
        return 0.5 + delta
    k = z0 - 1
    x = _invert_monotone(lambda v: irwin_hall_cdf(v, k), 0.0, float(k), delta)
    return float(x + 0.5)


def design_eps2(z0: int, delta: float = 1e-3) -> float:
    """Pick the termination threshold eps_2 (Section III-C).

    Choose eps_2 such that Pr(theta_hat >= eps_2 | Z_0 active walks)
    = 1 - F_{Sigma_{Z0-1}}(eps_2 - 1/2) = delta.
    """
    if z0 < 2:
        return 0.5 + 1.0
    k = z0 - 1
    x = _invert_monotone(lambda v: irwin_hall_cdf(v, k), 0.0, float(k), 1.0 - delta)
    return float(x + 0.5)


def false_fork_probability(z0: int, eps: float, p: float | None = None) -> float:
    """p_fork = p * F_{Sigma_{Z0-1}}(eps - 1/2) with Z_0 healthy walks."""
    if p is None:
        p = 1.0 / z0
    return float(p * irwin_hall_cdf(eps - 0.5, z0 - 1))


def false_termination_probability(z0: int, eps2: float, p: float | None = None) -> float:
    if p is None:
        p = 1.0 / z0
    return float(p * (1.0 - irwin_hall_cdf(eps2 - 0.5, z0 - 1)))
