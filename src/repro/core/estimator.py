"""Return-time estimation and the theta-hat walk-count estimator (Eq. 1).

This is the heart of the paper: every node i maintains
  - ``last_seen[i, c]``: last time step at which walk (track) c visited i
    (-1 if never seen) — the random variable L_{i,c}(t);
  - ``hist[i, b]``: empirical histogram of observed return times R_i
    (bin b holds counts of return time b+1, the final bin clamps the tail).

From the histogram each node derives the empirical survival function
  S_i(r) = Pr(R_i > r) = 1 - F_hat_{R_i}(r)
and estimates the number of live walks as (Eq. 1)
  theta_hat_i(t) = 1/2 + sum_{c != k, seen} S_i(t - last_seen[i, c]).

Everything here is functional and jit/vmap-friendly: histograms are dense
(n, B) int16 count arrays (exact — per-node-per-bin counts are bounded by
the step budget, far below 32767; totals are int32 since W*steps can
exceed int16), survival evaluation is a gather into the exclusive
cumulative sum (widened to float32 at the read), and theta-hat is a
masked (W, C) reduction.

The fused whole-round path carries ``CumulativeReturnState`` instead: the
(n, B+1) cumulative count table updated incrementally by scatter-adding
step rows, which removes the per-round cumsum from the hot loop entirely
(XLA CPU lowers ``cumsum`` to a quadratic reduce-window — it dominated
the PR-4 round). Integer counts make the two carries exact transforms of
each other: ``hist = diff(cum)``, ``total = cum[:, -1]``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

NEVER = -1  # sentinel for "walk never seen at this node"


class ReturnTimeState(NamedTuple):
    """Per-node empirical return-time statistics.

    Counts are exact integers: per-bin counts fit int16 (bounded by the
    step budget — a node observes at most ``steps`` samples overall, let
    alone per bin), totals are int32 (W * steps can exceed 32767). All
    reads widen to float32, where every count is exactly representable
    (far below 2**24), so the narrow carry is bitwise-neutral downstream.
    """

    hist: jax.Array  # (n, B) int16 counts; bin b <-> return time b+1
    total: jax.Array  # (n,) int32 total sample count


def init_return_time_state(n: int, bins: int) -> ReturnTimeState:
    return ReturnTimeState(
        hist=jnp.zeros((n, bins), jnp.int16),
        total=jnp.zeros((n,), jnp.int32),
    )


def record_returns(
    state: ReturnTimeState,
    nodes: jax.Array,  # (W,) int32 node visited by each walk
    r: jax.Array,  # (W,) int32 observed return times (t - last_seen)
    valid: jax.Array,  # (W,) bool — active walk with a prior visit record
) -> ReturnTimeState:
    """Scatter-add observed return-time samples into per-node histograms.

    Dtype-polymorphic (follows ``state``): the benchmark grid keeps a
    float32 arm alive for measurement, the simulator carries int16/int32.
    """
    bins = state.hist.shape[1]
    b = jnp.clip(r, 1, bins) - 1
    hist = state.hist.at[nodes, b].add(
        valid.astype(state.hist.dtype), mode="drop"
    )
    total = state.total.at[nodes].add(
        valid.astype(state.total.dtype), mode="drop"
    )
    return ReturnTimeState(hist=hist, total=total)


def survival_cumulative(state: ReturnTimeState) -> jax.Array:
    """(n, B+1) table C with C[i, r] = #samples <= r (C[i, 0] = 0)."""
    csum = jnp.cumsum(state.hist.astype(jnp.float32), axis=1)
    return jnp.concatenate([jnp.zeros_like(csum[:, :1]), csum], axis=1)


def survival_eval(
    cum: jax.Array,  # (n, B+1) from survival_cumulative
    total: jax.Array,  # (n,)
    nodes: jax.Array,  # (...,) int32
    r: jax.Array,  # (...,) int32 elapsed times
) -> jax.Array:
    """Empirical S_i(r) = 1 - F_hat(r), elementwise over broadcasted args.

    Conventions: S(r <= 0) = 1; nodes with no samples yet return 1
    (optimistic prior — a walk is presumed alive absent any evidence).
    """
    bins = cum.shape[1] - 1
    r_cl = jnp.clip(r, 0, bins)
    tot = total[nodes].astype(jnp.float32)
    seen_mass = cum[nodes, r_cl]
    s = 1.0 - seen_mass / jnp.maximum(tot, 1.0)
    s = jnp.where(tot > 0, s, 1.0)
    return jnp.where(r <= 0, 1.0, s)


def analytic_survival_eval(
    pi: jax.Array,  # (n,) stationary distribution (geometric rate q_i = pi_i)
    nodes: jax.Array,
    r: jax.Array,
) -> jax.Array:
    """Analytic geometric survival S_i(r) = (1 - pi_i)^r (footnote 5)."""
    q = pi[nodes]
    s = jnp.exp(jnp.log1p(-q) * r.astype(jnp.float32))
    return jnp.where(r <= 0, 1.0, s)


def theta_hat(
    last_seen: jax.Array,  # (n, C) int32
    cum: jax.Array,  # (n, B+1)
    total: jax.Array,  # (n,)
    t: jax.Array,  # scalar int32 current time
    pos: jax.Array,  # (W,) node of each visiting walk
    track: jax.Array,  # (W,) column owned by each walk
    *,
    pi: jax.Array | None = None,  # if set, use analytic survival instead
) -> jax.Array:
    """Eq. (1): theta_hat for every walk slot's current node, vectorized.

    Returns (W,) theta values; caller masks by which walks were "chosen"
    by their node. The visiting walk's own column is excluded (it
    contributes the deterministic 1/2 offset).
    """
    W = pos.shape[0]
    C = last_seen.shape[1]
    ls = last_seen[pos]  # (W, C)
    elapsed = t - ls  # (W, C)
    nodes_b = jnp.broadcast_to(pos[:, None], (W, C))
    if pi is not None:
        s = analytic_survival_eval(pi, nodes_b, elapsed)
    else:
        s = survival_eval(cum, total, nodes_b, elapsed)
    cols = jnp.arange(C)[None, :]
    mask = (ls != NEVER) & (cols != track[:, None])
    return 0.5 + jnp.sum(jnp.where(mask, s, 0.0), axis=1)


def theta_hat_rows(
    last_seen: jax.Array,  # (n, C) int32
    hist: jax.Array,  # (n, B)
    total: jax.Array,  # (n,)
    t: jax.Array,  # scalar int32 current time
    pos: jax.Array,  # (W,) node of each visiting walk
    track: jax.Array,  # (W,) column owned by each walk
    *,
    pi: jax.Array | None = None,  # if set, use analytic survival instead
    max_elapsed: int | None = None,  # static upper bound on t (see below)
) -> jax.Array:
    """Row-restricted Eq. (1): gather the <= W visited rows FIRST, then
    run the cumsum + survival lookup on those rows only.

    Bitwise-identical to ``theta_hat(last_seen, survival_cumulative(rts),
    ...)`` — per-row cumsums and the elementwise survival evaluation do
    not depend on the other rows — but the per-round work drops from
    O(n*B) (full cumulative table every round) to O(W*B): proportional
    to the walks actually observing, not the graph. This is the default
    ``estimator_impl="gather"`` hot path.

    ``max_elapsed`` (static) is an upper bound on ``t`` over the whole
    run (the simulator passes its ``steps``): no elapsed time — and so
    no cumulative-table lookup index — can exceed it, so the per-row
    cumsum is trimmed to ``min(B, max_elapsed)`` bins. Prefix sums at
    the surviving indices do not involve the trimmed tail, so the
    result stays bitwise identical while a short run over a
    high-resolution histogram (steps < rt_bins) skips the dead tail's
    work entirely.
    """
    W = pos.shape[0]
    C = last_seen.shape[1]
    ls = last_seen[pos]  # (W, C)
    elapsed = t - ls  # (W, C)
    if pi is not None:
        nodes_b = jnp.broadcast_to(pos[:, None], (W, C))
        s = analytic_survival_eval(pi, nodes_b, elapsed)
    else:
        bins = hist.shape[1]
        if max_elapsed is not None:
            bins = min(bins, max(int(max_elapsed), 1))
        csum = jnp.cumsum(
            hist[pos][:, :bins].astype(jnp.float32), axis=1
        )  # visited rows only
        cum = jnp.concatenate([jnp.zeros_like(csum[:, :1]), csum], axis=1)
        r_cl = jnp.clip(elapsed, 0, bins)
        tot = jnp.broadcast_to(
            total[pos].astype(jnp.float32)[:, None], (W, C)
        )
        seen_mass = jnp.take_along_axis(cum, r_cl, axis=1)
        s = 1.0 - seen_mass / jnp.maximum(tot, 1.0)
        s = jnp.where(tot > 0, s, 1.0)
        s = jnp.where(elapsed <= 0, 1.0, s)
    cols = jnp.arange(C)[None, :]
    mask = (ls != NEVER) & (cols != track[:, None])
    return 0.5 + jnp.sum(jnp.where(mask, s, 0.0), axis=1)


def survival_node_sums_rows(
    last_seen: jax.Array,  # (R, C) — any row block (full table or a tile)
    hist: jax.Array,  # (R, B)
    total: jax.Array,  # (R,)
    t: jax.Array,
) -> jax.Array:
    """The compare-accumulate survival core: sum_c S_i(t - L_{i,c}) per
    row, no gather — cum_i(r) = sum_b hist[i,b] [r > b].

    This is THE single source of the formula: ``node_sums_compare`` calls
    it on the full node table, and the Pallas kernels
    (``kernels/theta_survival.py``, ``kernels/round_update.py``) call it
    on their VMEM-resident node tiles — one implementation, so the
    survival conventions (optimistic no-sample prior, S(r<=0)=1 via the
    r=0 clamp) can never drift between the jnp oracle and the kernels.
    Plain jnp on arrays; traceable inside and outside kernel bodies.
    """
    R, C = last_seen.shape
    B = hist.shape[1]
    hist_f = hist.astype(jnp.float32)  # exact: integer counts < 2**24
    total_f = total.astype(jnp.float32)
    valid = last_seen != NEVER
    r = jnp.where(valid, t - last_seen, 0)  # (R, C)
    bidx = jax.lax.broadcasted_iota(jnp.int32, (R, C, B), 2)
    over = (r[:, :, None] > bidx) & valid[:, :, None]
    cnt = jnp.sum(over.astype(jnp.float32), axis=1)  # (R, B)
    mass = jnp.sum(cnt * hist_f, axis=1)
    n_valid = jnp.sum(valid.astype(jnp.float32), axis=1)
    s = n_valid - mass / jnp.maximum(total_f, 1.0)
    return jnp.where(total_f > 0, s, n_valid)


def node_sums_compare(
    last_seen: jax.Array,  # (n, C)
    hist: jax.Array,  # (n, B)
    total: jax.Array,  # (n,)
    t: jax.Array,
) -> jax.Array:
    """sum_c S_i(t - L_{i,c}) per node via the TPU compare-accumulate
    formulation (``survival_node_sums_rows`` on the full table); exists
    in pure jnp both as the kernel oracle and as a measurable CPU/XLA
    variant."""
    return survival_node_sums_rows(last_seen, hist, total, t)


def theta_hat_from_node_sums(node_sums: jax.Array, pos: jax.Array) -> jax.Array:
    """theta for a visiting walk = node_sum - 1 (own fresh column, S=1)
    + 1/2 (deterministic self term) = node_sum - 1/2.

    Valid only AFTER last_seen[pos, track] was updated to t.
    """
    return node_sums[pos] - 0.5


# --- incremental cumulative carry (fused whole-round hot path) -----------


class CumulativeReturnState(NamedTuple):
    """Per-node cumulative return-time counts, carried incrementally.

    ``cum[i, r] = #samples at node i with return time <= r`` for
    r in 0..C (so ``cum[:, 0] == 0`` and ``cum[:, -1]`` is the total
    sample count: every sample's clamped bin ``clip(r, 1, B) - 1`` lies
    below ``C = min(B, steps)`` because observed return times never
    exceed the step budget). This is exactly the table
    ``theta_hat_rows`` rebuilds from the histogram with a per-round
    cumsum; carrying it directly turns each observation into a
    scatter-add of (W, C+1) 0/1 step rows and removes the cumsum —
    XLA CPU's quadratic reduce-window — from the round entirely.
    int32 throughout: the last column is total-bounded (W * steps).
    """

    cum: jax.Array  # (n, C+1) int32 cumulative counts


def init_cumulative_state(n: int, bins: int) -> CumulativeReturnState:
    """``bins`` here is the TRIMMED bin count C = min(rt_bins, steps)."""
    return CumulativeReturnState(cum=jnp.zeros((n, bins + 1), jnp.int32))


def record_returns_cumulative(
    state: CumulativeReturnState,
    nodes: jax.Array,  # (W,) int32 node visited by each walk
    r: jax.Array,  # (W,) int32 observed return times (t - last_seen)
    valid: jax.Array,  # (W,) bool — active walk with a prior visit record
    bins: int,  # the FULL histogram bin count B (clamp target)
) -> CumulativeReturnState:
    """Scatter-add the step rows ``[col > b]`` — the cumulative image of
    ``record_returns``'s one-hot at bin ``b = clip(r, 1, B) - 1``.

    Exact-integer equivalent of ``record_returns`` on the cumulative
    table: ``diff(cum)`` after this update equals ``hist`` after that
    one, bin for bin.
    """
    b = jnp.clip(r, 1, bins) - 1  # (W,) same clamp as record_returns
    cols = jnp.arange(state.cum.shape[1], dtype=b.dtype)[None, :]
    rows = ((cols > b[:, None]) & valid[:, None]).astype(state.cum.dtype)
    return CumulativeReturnState(
        cum=state.cum.at[nodes].add(rows, mode="drop")
    )


def cumulative_to_return_time(
    state: CumulativeReturnState, bins: int
) -> ReturnTimeState:
    """Exact inverse transform: ``hist = diff(cum)`` (zero-padded back to
    the full ``bins``), ``total = cum[:, -1]``. Bitwise the histogram
    ``record_returns`` would have accumulated from the same samples."""
    cum = state.cum
    hist = (cum[:, 1:] - cum[:, :-1]).astype(jnp.int16)
    c = hist.shape[1]
    if c < bins:
        hist = jnp.pad(hist, ((0, 0), (0, bins - c)))
    return ReturnTimeState(hist=hist, total=cum[:, -1])


def theta_hat_cumulative(
    last_seen: jax.Array,  # (n, C) int32
    state: CumulativeReturnState,
    t: jax.Array,  # scalar int32 current time
    pos: jax.Array,  # (W,) node of each visiting walk
    track: jax.Array,  # (W,) column owned by each walk
    *,
    pi: jax.Array | None = None,  # if set, use analytic survival instead
) -> jax.Array:
    """Eq. (1) read directly off the carried cumulative table.

    Bitwise-identical to ``theta_hat_rows(..., max_elapsed=steps)`` when
    the carry was trimmed to ``min(B, steps)`` bins: the gathered int32
    prefix counts cast exactly to the float32 values the per-round
    cumsum would produce (all counts < 2**24), and the survival tail is
    the same expression. No cumsum anywhere — the dominant cost of the
    gather-family round is gone.
    """
    W = pos.shape[0]
    C = last_seen.shape[1]
    ls = last_seen[pos]  # (W, C)
    elapsed = t - ls  # (W, C)
    if pi is not None:
        nodes_b = jnp.broadcast_to(pos[:, None], (W, C))
        s = analytic_survival_eval(pi, nodes_b, elapsed)
    else:
        cum = state.cum[pos]  # (W, bins+1) int32 — visited rows only
        bins = cum.shape[1] - 1
        r_cl = jnp.clip(elapsed, 0, bins)
        seen_mass = jnp.take_along_axis(cum, r_cl, axis=1).astype(
            jnp.float32
        )
        tot = jnp.broadcast_to(
            cum[:, -1:].astype(jnp.float32), (W, C)
        )
        s = 1.0 - seen_mass / jnp.maximum(tot, 1.0)
        s = jnp.where(tot > 0, s, 1.0)
        s = jnp.where(elapsed <= 0, 1.0, s)
    cols = jnp.arange(C)[None, :]
    mask = (ls != NEVER) & (cols != track[:, None])
    return 0.5 + jnp.sum(jnp.where(mask, s, 0.0), axis=1)
