"""Fully-jitted multi-walk simulator (the paper's evaluation engine).

One synchronous round (time t -> t+1):
  1. every live walk hops to a uniform random neighbor;
  2. failures strike (probabilistic, burst, Byzantine — Section II);
  3. each node visited by >= 1 surviving walk "chooses one" (footnote 6),
     records return-time samples for *all* visitors, updates last-seen;
  4. the chosen walk's node computes theta-hat (Eq. 1) and runs the
     protocol: DECAFORK fork / DECAFORK+ fork-or-terminate /
     MISSINGPERSON timeout replacement;
  5. forks/terminations execute through the slot machinery.

The whole trajectory runs under one ``lax.scan``; vmap over PRNG keys gives
the 50-seed ensembles of the paper's figures in a single compiled call.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import estimator as est
from repro.core import failures as flr
from repro.core import protocol as prt
from repro.core import walkers as wlk
from repro.graphs.generators import Graph
from repro.graphs.spectral import stationary_distribution
from repro.utils.prng import fold_in_time


class SimState(NamedTuple):
    t: jax.Array  # scalar int32
    walks: wlk.WalkState
    last_seen: jax.Array  # (n, W) int32
    rts: est.ReturnTimeState
    byz_state: jax.Array  # scalar bool
    key: jax.Array
    theta_hist: jax.Array  # (n, TB) warmup theta-hat histogram (auto_eps)


class StepOutputs(NamedTuple):
    z: jax.Array  # live walk count after the step
    forks: jax.Array  # forks executed this step
    terms: jax.Array  # deliberate terminations this step
    failures: jax.Array  # walks lost to the threat model this step
    theta_mean: jax.Array  # mean theta-hat over chosen walks (diagnostic)
    fork_parent: jax.Array  # (W,) parent slot of a walk forked into s, else -1
    terminated: jax.Array  # (W,) walks deliberately terminated this step


def init_state(n: int, pcfg: prt.ProtocolConfig, fcfg: flr.FailureConfig, key: jax.Array) -> SimState:
    W = pcfg.max_walks
    k_init, k_run = jax.random.split(key)
    walks = wlk.init_walks(pcfg.z0, W, n, k_init)
    if pcfg.algorithm == "missingperson":
        # paper: L_{i,l}(0) = 0 for all initial ids at every node
        last_seen = jnp.where(
            jnp.arange(W)[None, :] < pcfg.z0,
            jnp.zeros((n, W), jnp.int32),
            est.NEVER,
        )
    else:
        last_seen = jnp.full((n, W), est.NEVER, jnp.int32)
        # the starting node of each initial walk has seen it at t=0
        last_seen = last_seen.at[walks.pos, jnp.arange(W)].max(
            jnp.where(walks.active, 0, est.NEVER)
        )
    tb = _theta_bins(pcfg)
    return SimState(
        t=jnp.int32(0),
        walks=walks,
        last_seen=last_seen,
        rts=est.init_return_time_state(n, pcfg.rt_bins),
        byz_state=jnp.asarray(fcfg.byz_start),
        key=k_run,
        theta_hist=jnp.zeros((n, tb), jnp.float32),
    )


def _theta_bins(pcfg: prt.ProtocolConfig) -> int:
    # theta-hat <= 0.5 + (slots - 1); one extra bin absorbs the tail
    return int((pcfg.max_walks + 1) / pcfg.theta_bin_width) + 1


def protocol_step(
    state: SimState,
    pcfg: prt.ProtocolConfig,
    fcfg: flr.FailureConfig,
    neighbors: jax.Array,
    degrees: jax.Array,
    pi: jax.Array | None,
):
    """One synchronous round; returns (next state, per-step outputs)."""
    t = state.t
    key = state.key
    k_move = fold_in_time(key, t, 0)
    k_pfail = fold_in_time(key, t, 1)
    k_burst = fold_in_time(key, t, 2)
    k_byz = fold_in_time(key, t, 3)
    k_dec = fold_in_time(key, t, 4)

    ws = state.walks
    n_before = jnp.sum(ws.active)

    # 1. movement
    ws = wlk.move_walks(ws, neighbors, degrees, k_move)

    # 2. threat models
    active = flr.apply_probabilistic_failures(ws.active, t, fcfg, k_pfail)
    active = flr.apply_burst_failures(active, t, fcfg, k_burst)
    active, byz_state = flr.step_byzantine(
        active, ws.pos, t, state.byz_state, fcfg, k_byz
    )
    ws = ws._replace(active=active)
    n_failed = n_before - jnp.sum(active)

    # 3. observations: return samples + last-seen updates for ALL visitors
    last_seen = state.last_seen
    prev = last_seen[ws.pos, ws.track]  # (W,)
    r = t - prev
    valid = ws.active & (prev != est.NEVER) & (r >= 1)
    rts = est.record_returns(state.rts, ws.pos, r, valid)
    upd = jnp.where(ws.active, t, est.NEVER)
    last_seen = last_seen.at[ws.pos, ws.track].max(upd, mode="drop")

    # 4. estimation + decisions for chosen walks
    chosen = prt.choose_walks(ws.pos, ws.active, degrees.shape[0])
    enabled = t >= pcfg.protocol_start
    theta_hist = state.theta_hist
    if pcfg.algorithm in ("decafork", "decafork+"):
        if pcfg.estimator_impl == "gather" or pi is not None:
            cum = est.survival_cumulative(rts)
            theta = est.theta_hat(
                last_seen, cum, rts.total, t, ws.pos, ws.track, pi=pi
            )
        elif pcfg.estimator_impl == "compare":
            sums = est.node_sums_compare(last_seen, rts.hist, rts.total, t)
            theta = est.theta_hat_from_node_sums(sums, ws.pos)
        elif pcfg.estimator_impl == "pallas":
            from repro.kernels import theta_sums_pallas

            sums = theta_sums_pallas(last_seen, rts.hist, rts.total, t)
            theta = est.theta_hat_from_node_sums(sums, ws.pos)
        else:
            raise ValueError(pcfg.estimator_impl)
        # beyond-paper: per-node self-calibrated thresholds (auto_eps)
        if pcfg.auto_eps:
            warmup = ~enabled
            b = jnp.clip(
                (theta / pcfg.theta_bin_width).astype(jnp.int32),
                0,
                theta_hist.shape[1] - 1,
            )
            w = (chosen & warmup).astype(jnp.float32)
            theta_hist = theta_hist.at[ws.pos, b].add(w, mode="drop")
            eps_w, eps2_w = prt.theta_quantile_thresholds(theta_hist, ws.pos, pcfg)
            fork_mask, term_mask = prt.decafork_decisions(
                theta, chosen, k_dec, pcfg, enabled, eps=eps_w, eps2=eps2_w
            )
        else:
            fork_mask, term_mask = prt.decafork_decisions(
                theta, chosen, k_dec, pcfg, enabled
            )
        ws = wlk.execute_terminations(ws, term_mask)
        n_terms = jnp.sum(term_mask)
        ws, last_seen, n_forks, fork_parent = wlk.execute_forks(
            ws, last_seen, fork_mask, ws.pos, None, t
        )
        theta_mean = jnp.sum(jnp.where(chosen, theta, 0.0)) / jnp.maximum(
            jnp.sum(chosen), 1
        )
    elif pcfg.algorithm == "missingperson":
        ev = prt.missingperson_decisions(
            last_seen, ws.pos, ws.track, chosen, t, k_dec, pcfg, enabled
        )  # (W, z0)
        W, z0 = ev.shape
        ev_mask = ev.reshape(-1)
        ev_origin = jnp.broadcast_to(ws.pos[:, None], (W, z0)).reshape(-1)
        ev_track = jnp.broadcast_to(
            jnp.arange(z0, dtype=jnp.int32)[None, :], (W, z0)
        ).reshape(-1)
        ev_parent = jnp.broadcast_to(
            jnp.arange(W, dtype=jnp.int32)[:, None], (W, z0)
        ).reshape(-1)
        ws, last_seen, n_forks, fork_parent = wlk.execute_forks(
            ws, last_seen, ev_mask, ev_origin, ev_track, t, ev_parent
        )
        n_terms = jnp.int32(0)
        term_mask = jnp.zeros((W,), bool)
        theta_mean = jnp.float32(0.0)
    else:  # 'none': plain multi-RW system without self-regulation
        n_forks = jnp.int32(0)
        n_terms = jnp.int32(0)
        theta_mean = jnp.float32(0.0)
        fork_parent = jnp.full((ws.pos.shape[0],), -1, jnp.int32)
        term_mask = jnp.zeros_like(ws.active)

    new_state = SimState(
        t=t + 1,
        walks=ws,
        last_seen=last_seen,
        rts=rts,
        byz_state=byz_state,
        key=key,
        theta_hist=theta_hist,
    )
    out = StepOutputs(
        z=jnp.sum(ws.active),
        forks=n_forks,
        terms=n_terms,
        failures=n_failed,
        theta_mean=theta_mean,
        fork_parent=fork_parent,
        terminated=term_mask,
    )
    return new_state, out


@functools.partial(jax.jit, static_argnames=("pcfg", "fcfg", "steps", "n"))
def _run(key, neighbors, degrees, pi, pcfg, fcfg, steps, n):
    state = init_state(n, pcfg, fcfg, key)

    def body(s, _):
        return protocol_step(s, pcfg, fcfg, neighbors, degrees, pi)

    return jax.lax.scan(body, state, None, length=steps)


def _graph_arrays(graph: Graph, pcfg: prt.ProtocolConfig):
    neighbors = jnp.asarray(graph.neighbors)
    degrees = jnp.asarray(graph.degrees)
    pi = (
        jnp.asarray(stationary_distribution(graph), jnp.float32)
        if pcfg.analytic_survival
        else None
    )
    return neighbors, degrees, pi


def run_simulation(
    graph: Graph,
    pcfg: prt.ProtocolConfig,
    fcfg: flr.FailureConfig,
    steps: int,
    key: jax.Array | int = 0,
):
    """Run one trajectory; returns (final SimState, StepOutputs over time)."""
    if isinstance(key, int):
        key = jax.random.key(key)
    neighbors, degrees, pi = _graph_arrays(graph, pcfg)
    return _run(key, neighbors, degrees, pi, pcfg, fcfg, steps, graph.n)


def run_ensemble(
    graph: Graph,
    pcfg: prt.ProtocolConfig,
    fcfg: flr.FailureConfig,
    steps: int,
    seeds: int,
    base_key: jax.Array | int = 0,
):
    """vmap over seeds: StepOutputs with leading (seeds,) axis."""
    if isinstance(base_key, int):
        base_key = jax.random.key(base_key)
    keys = jax.random.split(base_key, seeds)
    neighbors, degrees, pi = _graph_arrays(graph, pcfg)

    @jax.jit
    def fn(ks):
        return jax.vmap(
            lambda k: _run(k, neighbors, degrees, pi, pcfg, fcfg, steps, graph.n)[1]
        )(ks)

    return fn(keys)


# ---------------------------------------------------------------------------
# Trajectory metrics (used by benchmarks and integration tests)
# ---------------------------------------------------------------------------


def reaction_time(z, z0: int, failure_time: int) -> int:
    """Steps from `failure_time` until Z_t first returns to >= z0 (-1: never)."""
    import numpy as np

    z = np.asarray(z)
    post = z[failure_time:]
    hits = np.nonzero(post >= z0)[0]
    return int(hits[0]) if hits.size else -1


def max_overshoot(z, z0: int) -> int:
    import numpy as np

    return int(np.max(np.asarray(z)) - z0)


def survived(z) -> bool:
    """Resilience objective: at least one walk alive at all times."""
    import numpy as np

    return bool((np.asarray(z) > 0).all())
