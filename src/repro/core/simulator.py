"""Fully-jitted multi-walk simulator (the paper's evaluation engine).

One synchronous round (time t -> t+1):
  1. the topology evolves (``GraphState``: scheduled/i.i.d. node crashes,
     i.i.d. link failures, stochastic recoveries); a crashing node kills
     the walks resident on it;
  2. every surviving walk hops to a uniform random *available* neighbor
     (down nodes/links are unreachable; a stranded walk holds position);
  3. walk-level failures strike (probabilistic, burst, Byzantine —
     Section II; Pac-Man absorption);
  4. each node visited by >= 1 surviving walk "chooses one" (footnote 6),
     records return-time samples for *all* visitors, updates last-seen;
  5. the chosen walk's node computes theta-hat (Eq. 1) and runs the
     protocol: DECAFORK fork / DECAFORK+ fork-or-terminate /
     MISSINGPERSON timeout replacement;
  6. forks/terminations execute through the slot machinery.

The whole trajectory runs under one ``lax.scan``; the live topology is
part of the scan carry, so downed nodes/links persist and recover across
steps. Configs are pytrees with *traced numeric leaves* (see
``protocol.py`` / ``failures.py``) — the topology knobs included — so one
trajectory core batches outward over seeds (vmap) and over (scenario,
seed) stacks, provided the scenarios share static structure (same
algorithm, estimator_impl, max_walks, rt_bins, burst + node-crash
schedule lengths).

This module is the *backend*: the un-jitted cores (``_run_core`` /
``_run_ensemble_core`` / ``_sweep_core``) that ``repro.api.Plan``
compiles through its process-wide signature-keyed executable cache. The
public, declarative surface is ``repro.api.Experiment`` (spec ->
``plan()`` -> results); the four historical runners
(``run_simulation`` / ``run_ensemble`` / ``run_sweep`` and
``repro.sweep.run_scenarios``) remain as deprecation shims that build
the equivalent Experiment, so they stay bitwise-equal to the new path.

Every core accepts a ``payload`` (``core.payload.Payload``): the
computational task the walks carry (flagship: RW-SGD learning via
``optim.rw_sgd.RwSgdPayload``). The payload's carry pytree rides the same
``lax.scan`` — its hooks run inside the compiled trajectory, so learning
curves batch across seeds and scenarios exactly like ``Z_t`` curves, and
the runners additionally return the stacked per-round payload outputs.
``payload=None`` (the default) traces the hook-free program and is
bitwise identical to the pre-payload engine; payload PRNG streams are
disjoint from the simulator's, so even an attached payload leaves every
``StepOutputs`` trajectory bitwise unchanged.

Output selection is static (``core.outputs``): an ``OutputSpec`` picks
which ``StepOutputs`` fields the trajectory scan stacks over time —
scalars-only by default (the per-walk ``(W,)`` fields are auto-recorded
only when a payload is attached) — and a ``PayloadOutputSpec`` does the
same for the payload's per-round outputs, so dropped ``(..., steps, W)``
buffers are never allocated on either side.

The static ``Graph`` stays a trace-time constant (the superset topology);
``GraphState`` only masks it, so scenario rows vary *which parts are up
when* without recompilation. With every topology knob disabled the masks
stay full and each round is bitwise the static-graph round. On the fused
estimator path the observation state (``last_seen``, return-time
histograms) is carried pre-padded to the round kernel's node tile
(``observation_rows``) and sliced back once per run — bitwise-identical
to the per-round pad+slice it replaces.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import estimator as est
from repro.core import failures as flr
from repro.core import protocol as prt
from repro.core import walkers as wlk
from repro.core.outputs import SCALARS, StepOutputs
from repro.core.payload import PAYLOAD_STREAM, payload_init_key
from repro.graphs.generators import Graph
from repro.graphs.spectral import stationary_distribution
from repro.graphs.state import GraphState, availability, init_graph_state, mirror_indices
from repro.utils.prng import fold_in_time


class SimState(NamedTuple):
    t: jax.Array  # scalar int32
    walks: wlk.WalkState
    last_seen: jax.Array  # (n, W) int32
    rts: est.ReturnTimeState
    byz_state: jax.Array  # scalar bool
    key: jax.Array
    theta_hist: jax.Array  # (n, TB) warmup theta-hat histogram (auto_eps)
    graph: GraphState  # live topology masks (node_up, edge_up)


def init_state(
    n: int,
    max_deg: int,
    pcfg: prt.ProtocolConfig,
    fcfg: flr.FailureConfig,
    key: jax.Array,
    n_obs: int | None = None,
) -> SimState:
    """Initial simulator state; ``n_obs`` (>= n, default n) is the row
    count of the observation-state arrays (``last_seen``, return-time
    histograms). The fused estimator path carries them PRE-padded to the
    node tile (``observation_rows``) so the per-round pad+slice inside
    the scan disappears; pad rows are masked "no data" rows no walk can
    hit, so every real row is bitwise what the unpadded run computes."""
    n_obs = n if n_obs is None else n_obs
    W = pcfg.max_walks
    k_init, k_run = jax.random.split(key)
    walks = wlk.init_walks(pcfg.z0, W, n, k_init)
    if pcfg.algorithm == "missingperson":
        if n_obs != n:
            raise ValueError("missingperson does not pad observation state")
        # paper: L_{i,l}(0) = 0 for all initial ids at every node
        last_seen = jnp.where(
            jnp.arange(W)[None, :] < pcfg.z0,
            jnp.zeros((n, W), jnp.int32),
            est.NEVER,
        )
    else:
        last_seen = jnp.full((n_obs, W), est.NEVER, jnp.int32)
        # the starting node of each initial walk has seen it at t=0
        last_seen = last_seen.at[walks.pos, jnp.arange(W)].max(
            jnp.where(walks.active, 0, est.NEVER)
        )
    tb = _theta_bins(pcfg)
    return SimState(
        t=jnp.int32(0),
        walks=walks,
        last_seen=last_seen,
        rts=est.init_return_time_state(n_obs, pcfg.rt_bins),
        byz_state=jnp.asarray(fcfg.byz_start),
        key=k_run,
        theta_hist=jnp.zeros((n, tb), jnp.float32),
        graph=init_graph_state(n, max_deg),
    )


def resolved_estimator_impl(pcfg: prt.ProtocolConfig) -> str:
    """``estimator_impl`` with ``'auto'`` resolved for the current
    backend (trace-time; fused on TPU, gather elsewhere)."""
    impl = pcfg.estimator_impl
    if impl == "auto":
        # function-level import: the kernels package (and with it
        # jax.experimental.pallas) loads only when a round actually asks
        from repro.kernels.platform import best_estimator_impl

        impl = best_estimator_impl()
    return impl


def _will_fuse(pcfg: prt.ProtocolConfig) -> bool:
    """Whether the trajectory will take the fused observation path —
    THE fuse predicate (``protocol_step`` consumes it directly, adding
    only its caller-supplied ``pi is None`` guard)."""
    return (
        resolved_estimator_impl(pcfg) == "fused"
        and pcfg.algorithm in ("decafork", "decafork+")
        and not pcfg.analytic_survival
    )


def observation_rows(n: int, pcfg: prt.ProtocolConfig) -> int:
    """Static row count of the observation-state arrays for a run.

    On the fused path the node axis is padded up to the round kernel's
    tile ONCE here, instead of pad+slice every round inside the scan (one
    observation-state copy per round saved whenever ``n`` is not
    tile-aligned); everywhere else it is just ``n``.
    """
    if not _will_fuse(pcfg):
        return n
    from repro.kernels.round_update import DEFAULT_BLOCK_NODES

    bn = min(DEFAULT_BLOCK_NODES, n)
    return n + (-n) % bn


def _theta_bins(pcfg: prt.ProtocolConfig) -> int:
    # theta-hat <= 0.5 + (slots - 1); one extra bin absorbs the tail
    return int((pcfg.max_walks + 1) / pcfg.theta_bin_width) + 1


def protocol_step(
    state: SimState,
    pcfg: prt.ProtocolConfig,
    fcfg: flr.FailureConfig,
    neighbors: jax.Array,
    degrees: jax.Array,
    mirror: jax.Array,
    pi: jax.Array | None,
    *,
    max_elapsed: int | None = None,
):
    """One synchronous round; returns (next state, per-step outputs).

    ``max_elapsed`` (static) is an optional upper bound on ``t`` over the
    whole run — the trajectory scan passes its ``steps`` — letting the
    estimator trim the dead tail of the cumulative return-time table
    (bitwise-identical results; see ``estimator.theta_hat_rows``).
    """
    t = state.t
    key = state.key
    k_move = fold_in_time(key, t, 0)
    k_pfail = fold_in_time(key, t, 1)
    k_burst = fold_in_time(key, t, 2)
    k_byz = fold_in_time(key, t, 3)
    k_dec = fold_in_time(key, t, 4)
    k_topo = fold_in_time(key, t, 5)

    ws = state.walks
    n_before = jnp.sum(ws.active)

    # 1. topology evolves; a crashing node kills its resident walks
    gs = flr.step_topology(state.graph, t, fcfg, k_topo, neighbors, mirror)
    ws = ws._replace(
        active=flr.kill_resident_walks(ws.active, ws.pos, gs.node_up)
    )

    # 2. movement over the currently-available edges
    ws = wlk.move_walks(
        ws, neighbors, degrees, k_move, availability(gs, neighbors, degrees)
    )

    # 3. walk-level threat models
    active = flr.apply_probabilistic_failures(ws.active, t, fcfg, k_pfail)
    active = flr.apply_burst_failures(active, t, fcfg, k_burst)
    active, byz_state = flr.step_byzantine(
        active, ws.pos, t, state.byz_state, fcfg, k_byz
    )
    active = flr.apply_pacman(active, ws.pos, t, fcfg)
    ws = ws._replace(active=active)
    n_failed = n_before - jnp.sum(active)

    # 4. observations: return samples + last-seen updates for ALL visitors
    impl = resolved_estimator_impl(pcfg)
    last_seen = state.last_seen
    prev = last_seen[ws.pos, ws.track]  # (W,)
    r = t - prev
    valid = ws.active & (prev != est.NEVER) & (r >= 1)
    upd = jnp.where(ws.active, t, est.NEVER)
    node_sums = None
    # `pi is None` guards direct callers that pass an analytic-survival
    # table independently of pcfg; the padding decision (_will_fuse,
    # observation_rows) must stay a superset-consistent view of this.
    fuse = _will_fuse(pcfg) and pi is None
    if fuse:
        # one fused pass: scatter + max-update + node theta-sums
        # (kernels/round_update.py; Pallas tiles on TPU, jnp elsewhere)
        from repro.kernels.round_update import round_update

        last_seen, hist, tot, node_sums = round_update(
            last_seen, state.rts.hist, state.rts.total,
            ws.pos, ws.track, r, valid, upd, t,
        )
        rts = est.ReturnTimeState(hist=hist, total=tot)
    else:
        rts = est.record_returns(state.rts, ws.pos, r, valid)
        last_seen = last_seen.at[ws.pos, ws.track].max(upd, mode="drop")

    # 5. estimation + decisions for chosen walks
    chosen = prt.choose_walks(ws.pos, ws.active, degrees.shape[0])
    enabled = t >= pcfg.protocol_start
    theta_hist = state.theta_hist
    if pcfg.algorithm in ("decafork", "decafork+"):
        if fuse:
            theta = est.theta_hat_from_node_sums(node_sums, ws.pos)
        elif impl == "gather" or pi is not None:
            theta = est.theta_hat_rows(
                last_seen, rts.hist, rts.total, t, ws.pos, ws.track, pi=pi,
                max_elapsed=max_elapsed,
            )
        elif impl == "compare":
            sums = est.node_sums_compare(last_seen, rts.hist, rts.total, t)
            theta = est.theta_hat_from_node_sums(sums, ws.pos)
        elif impl == "pallas":
            from repro.kernels import theta_sums_pallas

            sums = theta_sums_pallas(last_seen, rts.hist, rts.total, t)
            theta = est.theta_hat_from_node_sums(sums, ws.pos)
        else:
            raise ValueError(impl)
        # beyond-paper: per-node self-calibrated thresholds (auto_eps)
        if pcfg.auto_eps:
            warmup = ~enabled
            b = jnp.clip(
                (theta / pcfg.theta_bin_width).astype(jnp.int32),
                0,
                theta_hist.shape[1] - 1,
            )
            w = (chosen & warmup).astype(jnp.float32)
            theta_hist = theta_hist.at[ws.pos, b].add(w, mode="drop")
            eps_w, eps2_w = prt.theta_quantile_thresholds(theta_hist, ws.pos, pcfg)
            fork_mask, term_mask = prt.decafork_decisions(
                theta, chosen, k_dec, pcfg, enabled, eps=eps_w, eps2=eps2_w
            )
        else:
            fork_mask, term_mask = prt.decafork_decisions(
                theta, chosen, k_dec, pcfg, enabled
            )
        ws = wlk.execute_terminations(ws, term_mask)
        n_terms = jnp.sum(term_mask)
        ws, last_seen, n_forks, fork_parent = wlk.execute_forks(
            ws, last_seen, fork_mask, ws.pos, None, t
        )
        theta_mean = jnp.sum(jnp.where(chosen, theta, 0.0)) / jnp.maximum(
            jnp.sum(chosen), 1
        )
    elif pcfg.algorithm == "missingperson":
        ev = prt.missingperson_decisions(
            last_seen, ws.pos, ws.track, chosen, t, k_dec, pcfg, enabled
        )  # (W, C) — only initial-id columns (< z0) can fire
        ws, last_seen, n_forks, fork_parent = wlk.execute_grid_forks(
            ws, last_seen, ev, t
        )
        n_terms = jnp.int32(0)
        term_mask = jnp.zeros((ev.shape[0],), bool)
        theta_mean = jnp.float32(0.0)
    else:  # 'none': plain multi-RW system without self-regulation
        n_forks = jnp.int32(0)
        n_terms = jnp.int32(0)
        theta_mean = jnp.float32(0.0)
        fork_parent = jnp.full((ws.pos.shape[0],), -1, jnp.int32)
        term_mask = jnp.zeros_like(ws.active)

    new_state = SimState(
        t=t + 1,
        walks=ws,
        last_seen=last_seen,
        rts=rts,
        byz_state=byz_state,
        key=key,
        theta_hist=theta_hist,
        graph=gs,
    )
    out = StepOutputs(
        z=jnp.sum(ws.active),
        forks=n_forks,
        terms=n_terms,
        failures=n_failed,
        theta_mean=theta_mean,
        fork_parent=fork_parent,
        terminated=term_mask,
    )
    return new_state, out


def _strip_obs_pad(state: SimState, n: int) -> SimState:
    """Slice the pre-padded observation rows back to the graph's ``n``
    (one slice per *run*, vs one pad+slice per round without carrying
    padded state); a no-op when the run never padded."""
    if state.last_seen.shape[0] == n:
        return state
    return state._replace(
        last_seen=state.last_seen[:n],
        rts=est.ReturnTimeState(
            hist=state.rts.hist[:n], total=state.rts.total[:n]
        ),
    )


def _run_core(
    key, neighbors, degrees, mirror, pi, pcfg, fcfg, steps, n,
    payload=None, spec=SCALARS, pspec=None,
):
    """Un-jitted single-trajectory scan; every batching wrapper traces
    through this one function so ensemble/sweep results are bitwise equal
    to the single-run path. This is the ONE backend ``repro.api.Plan``
    compiles — the jitted executables live in the Plan's process-wide
    cache, keyed on the static signature.

    ``spec`` (an ``OutputSpec``, static) selects which ``StepOutputs``
    fields the scan stacks over time: the full per-round StepOutputs is
    free *inside* the round, but every recorded field costs a
    ``(steps, ...)`` output buffer — O(W) extra HBM traffic per round for
    the per-walk fields — so the thinned view is the default and the
    dropped stacks are never allocated at all. ``pspec`` (a
    ``PayloadOutputSpec`` or None, static) does the same for the payload's
    per-round outputs; ``None`` records the payload's full output pytree
    untouched.

    On the fused estimator path the observation state is carried
    PRE-padded to the round kernel's node tile (``observation_rows``) and
    sliced back once after the scan — bitwise-identical to padding every
    round, without the per-round state copy.

    With ``payload=None`` this is exactly the payload-free program (same
    scan carry, same jaxpr). With a payload, the carry becomes
    ``(SimState, payload_carry)`` and each round runs the hook sequence
    ``on_terminate -> on_fork -> on_visit`` after the protocol round,
    mirroring the protocol's own order (``execute_terminations`` frees
    slots *before* ``execute_forks`` reallocates them, so a slot can be
    terminated and re-forked in one round — clearing must not clobber the
    fresh copy); the forked walk trains at its origin node the very round
    it is created, on a copy of its parent's pre-round replica. Returns
    ``((final SimState, final carry), (RecordedOutputs, payload_outputs))``.
    """
    n_obs = observation_rows(n, pcfg)
    state = init_state(n, neighbors.shape[1], pcfg, fcfg, key, n_obs=n_obs)

    if payload is None:

        def body(s, _):
            s2, out = protocol_step(
                s, pcfg, fcfg, neighbors, degrees, mirror, pi,
                max_elapsed=steps,
            )
            return s2, spec.select(out)

        final, recorded = jax.lax.scan(body, state, None, length=steps)
        return _strip_obs_pad(final, n), recorded

    pcarry = payload.init(payload_init_key(key))

    def body(carry, _):
        s, pc = carry
        t = s.t  # pre-round step counter, matching the simulator's streams
        k_visit = fold_in_time(s.key, t, PAYLOAD_STREAM)
        s2, out = protocol_step(
            s, pcfg, fcfg, neighbors, degrees, mirror, pi, max_elapsed=steps
        )
        pc = payload.on_terminate(pc, out.terminated)
        pc = payload.on_fork(pc, out.fork_parent)
        pc, pout = payload.on_visit(pc, s2.walks, t, k_visit)
        if pspec is not None:
            pout = pspec.select(pout)
        return (s2, pc), (spec.select(out), pout)

    (final, pcarry), recorded = jax.lax.scan(
        body, (state, pcarry), None, length=steps
    )
    return (_strip_obs_pad(final, n), pcarry), recorded


# deliberately NO input donation on any entry point: the trajectory
# outputs never alias the (tiny) key/config inputs, and donating a
# caller-owned key would break the standard same-key-different-config
# comparison on accelerators. The memory win that matters — reusing the
# scan carry (last_seen/hist/topology state) in place every round — is
# already done by XLA inside the compiled program.


def _run_ensemble_core(
    keys, neighbors, degrees, mirror, pi, pcfg, fcfg, steps, n,
    payload=None, spec=SCALARS, pspec=None,
):
    """(seeds,) keys -> RecordedOutputs with leading (seeds,) axis (a
    (RecordedOutputs, payload_outputs) pair when a payload is attached)."""
    return jax.vmap(
        lambda k: _run_core(
            k, neighbors, degrees, mirror, pi, pcfg, fcfg, steps, n,
            payload, spec, pspec,
        )[1]
    )(keys)


def _sweep_core(
    keys, neighbors, degrees, mirror, pi, pcfgs, fcfgs, steps, n,
    payload=None, spec=SCALARS, pspec=None,
):
    """Stacked configs (leaves with leading (S,) axis) + (seeds,) keys ->
    RecordedOutputs with leading (S, seeds) axes, all in one XLA program
    (a (RecordedOutputs, payload_outputs) pair when a payload is
    attached)."""

    def one_scenario(pcfg, fcfg):
        return jax.vmap(
            lambda k: _run_core(
                k, neighbors, degrees, mirror, pi, pcfg, fcfg, steps, n,
                payload, spec, pspec,
            )[1]
        )(keys)

    return jax.vmap(one_scenario)(pcfgs, fcfgs)


def _graph_arrays(graph: Graph, pcfg: prt.ProtocolConfig):
    """The trace-time graph constants one run needs (benchmark baselines
    drive the cores directly through this; the Plan prepares the same
    arrays once per plan instead of once per call)."""
    neighbors = jnp.asarray(graph.neighbors)
    degrees = jnp.asarray(graph.degrees)
    mirror = jnp.asarray(mirror_indices(graph))
    pi = (
        jnp.asarray(stationary_distribution(graph), jnp.float32)
        if pcfg.analytic_survival
        else None
    )
    return neighbors, degrees, mirror, pi


# ---------------------------------------------------------------------------
# Legacy runner shims (deprecated; use repro.api.Experiment)
# ---------------------------------------------------------------------------
#
# The four historical entry points survive as THIN shims over the
# declarative API — they build the equivalent Experiment, lower it to a
# Plan and run it, so they are bitwise-equal to the new path by
# construction (and golden-tested as such). No in-repo code may call
# them; the test lanes promote APIDeprecationWarning to an error.


def run_simulation(
    graph: Graph,
    pcfg: prt.ProtocolConfig,
    fcfg: flr.FailureConfig,
    steps: int,
    key: jax.Array | int = 0,
    *,
    payload=None,
    outputs=None,
):
    """DEPRECATED shim: one trajectory.

    Use ``repro.api.Experiment(graph=..., protocol=pcfg, failures=fcfg,
    steps=steps, ...).run(key)`` — same return value, same bits.
    """
    from repro.api import Experiment
    from repro.utils.deprecation import warn_legacy_runner

    warn_legacy_runner(
        "repro.core.run_simulation", "Experiment(...).run(key)"
    )
    return Experiment(
        graph=graph, protocol=pcfg, failures=fcfg, steps=steps,
        payload=payload, outputs=outputs,
    ).run(key)


def run_ensemble(
    graph: Graph,
    pcfg: prt.ProtocolConfig,
    fcfg: flr.FailureConfig,
    steps: int,
    seeds: int,
    base_key: jax.Array | int = 0,
    *,
    payload=None,
    outputs=None,
):
    """DEPRECATED shim: vmap over seeds.

    Use ``repro.api.Experiment(graph=..., protocol=pcfg, failures=fcfg,
    steps=steps, ...).ensemble(seeds, base_key)``.
    """
    from repro.api import Experiment
    from repro.utils.deprecation import warn_legacy_runner

    warn_legacy_runner(
        "repro.core.run_ensemble", "Experiment(...).ensemble(seeds)"
    )
    return Experiment(
        graph=graph, protocol=pcfg, failures=fcfg, steps=steps,
        payload=payload, outputs=outputs,
    ).ensemble(seeds, base_key)


def run_sweep(
    graph: Graph,
    scenarios: Sequence[Tuple[prt.ProtocolConfig, flr.FailureConfig]],
    steps: int,
    seeds: int,
    base_key: jax.Array | int = 0,
    *,
    sharded: bool | None = None,
    payload=None,
    outputs=None,
):
    """DEPRECATED shim: one static-structure scenario stack x seeds,
    stacked outputs with leading (S, seeds) axes.

    Use ``repro.api.Experiment(graph=..., scenarios=..., steps=...,
    placement=...).plan().sweep_stacked(seeds=seeds, base_key=...)``
    (the ``sharded`` tri-state maps to ``Placement.from_sharded``).
    """
    from repro.api import Experiment, Placement
    from repro.utils.deprecation import warn_legacy_runner

    warn_legacy_runner(
        "repro.core.simulator.run_sweep",
        "Experiment(...).plan().sweep_stacked(seeds=...)",
    )
    return Experiment(
        graph=graph, scenarios=scenarios, steps=steps, payload=payload,
        outputs=outputs, placement=Placement.from_sharded(sharded),
    ).plan().sweep_stacked(seeds=seeds, base_key=base_key)


# ---------------------------------------------------------------------------
# Trajectory metrics (used by benchmarks and integration tests)
# ---------------------------------------------------------------------------


def reaction_time(z, z0: int, failure_time: int) -> int:
    """Steps from `failure_time` until Z_t first returns to >= z0 (-1: never)."""
    import numpy as np

    z = np.asarray(z)
    post = z[failure_time:]
    hits = np.nonzero(post >= z0)[0]
    return int(hits[0]) if hits.size else -1


def max_overshoot(z, z0: int) -> int:
    import numpy as np

    return int(np.max(np.asarray(z)) - z0)


def survived(z) -> bool:
    """Resilience objective: at least one walk alive at all times."""
    import numpy as np

    return bool((np.asarray(z) > 0).all())
