"""Fully-jitted multi-walk simulator (the paper's evaluation engine).

One synchronous round (time t -> t+1):
  1. the topology evolves (``GraphState``: scheduled/i.i.d. node crashes,
     i.i.d. link failures, stochastic recoveries); a crashing node kills
     the walks resident on it;
  2. every surviving walk hops to a uniform random *available* neighbor
     (down nodes/links are unreachable; a stranded walk holds position);
  3. walk-level failures strike (probabilistic, burst, Byzantine —
     Section II; Pac-Man absorption);
  4. each node visited by >= 1 surviving walk "chooses one" (footnote 6),
     records return-time samples for *all* visitors, updates last-seen;
  5. the chosen walk's node computes theta-hat (Eq. 1) and runs the
     protocol: DECAFORK fork / DECAFORK+ fork-or-terminate /
     MISSINGPERSON timeout replacement;
  6. forks/terminations execute through the slot machinery.

The whole trajectory runs under one ``lax.scan``; the live topology is
part of the scan carry, so downed nodes/links persist and recover across
steps. Configs are pytrees with *traced numeric leaves* (see
``protocol.py`` / ``failures.py``) — the topology knobs included — so one
trajectory core batches outward over seeds (vmap) and over (scenario,
seed) stacks, provided the scenarios share static structure (same
algorithm, estimator_impl, max_walks, rt_bins, burst + node-crash
schedule lengths).

This module is the *backend*: the un-jitted cores (``_run_core`` /
``_run_ensemble_core`` / ``_sweep_core``) that ``repro.api.Plan``
compiles through its process-wide signature-keyed executable cache. The
public, declarative surface is ``repro.api.Experiment`` (spec ->
``plan()`` -> results); the four historical runners
(``run_simulation`` / ``run_ensemble`` / ``run_sweep`` and
``repro.sweep.run_scenarios``) remain as deprecation shims that build
the equivalent Experiment, so they stay bitwise-equal to the new path.

Every core accepts a ``payload`` (``core.payload.Payload``): the
computational task the walks carry (flagship: RW-SGD learning via
``optim.rw_sgd.RwSgdPayload``). The payload's carry pytree rides the same
``lax.scan`` — its hooks run inside the compiled trajectory, so learning
curves batch across seeds and scenarios exactly like ``Z_t`` curves, and
the runners additionally return the stacked per-round payload outputs.
``payload=None`` (the default) traces the hook-free program and is
bitwise identical to the pre-payload engine; payload PRNG streams are
disjoint from the simulator's, so even an attached payload leaves every
``StepOutputs`` trajectory bitwise unchanged.

Output selection is static (``core.outputs``): an ``OutputSpec`` picks
which ``StepOutputs`` fields the trajectory scan stacks over time —
scalars-only by default (the per-walk ``(W,)`` fields are auto-recorded
only when a payload is attached) — and a ``PayloadOutputSpec`` does the
same for the payload's per-round outputs, so dropped ``(..., steps, W)``
buffers are never allocated on either side.

The static ``Graph`` stays a trace-time constant (the superset topology);
``GraphState`` only masks it, so scenario rows vary *which parts are up
when* without recompilation. With every topology knob disabled the masks
stay full and each round is bitwise the static-graph round. On the fused
estimator path the observation state (``last_seen``, return-time
histograms) is carried pre-padded to the round kernel's node tile
(``observation_rows``) and sliced back once per run — bitwise-identical
to the per-round pad+slice it replaces.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import estimator as est
from repro.core import failures as flr
from repro.core import protocol as prt
from repro.core import walkers as wlk
from repro.core.outputs import SCALARS, StepOutputs
from repro.core.payload import PAYLOAD_STREAM, payload_init_key
from repro.graphs.generators import Graph
from repro.graphs.spectral import stationary_distribution
from repro.graphs.state import (
    GraphState,
    availability,
    availability_rows,
    init_graph_state,
    mirror_indices,
)
from repro.utils.prng import fold_in_time


class SimState(NamedTuple):
    t: jax.Array  # scalar int32
    walks: wlk.WalkState
    last_seen: jax.Array  # (n, W) int32
    # ReturnTimeState (histogram carry) on the unfused / kernel paths,
    # CumulativeReturnState (incremental CDF carry) on the fused-ref
    # whole-round path — decided statically by the config (_will_fuse_round)
    rts: est.ReturnTimeState | est.CumulativeReturnState
    byz_state: jax.Array  # scalar bool
    key: jax.Array
    theta_hist: jax.Array  # (n, TB) warmup theta-hat histogram (auto_eps)
    graph: GraphState  # live topology masks (node_up, edge_up)
    # (1+K,) mobile Pac-Man positions when fcfg.pacman_mobile (a static
    # field, so the carry structure is a trace-time constant); None — an
    # empty pytree subtree — otherwise, leaving the default program's
    # scan carry structurally unchanged
    pacman_pos: jax.Array | None = None


def init_state(
    n: int,
    max_deg: int,
    pcfg: prt.ProtocolConfig,
    fcfg: flr.FailureConfig,
    key: jax.Array,
    n_obs: int | None = None,
    steps: int | None = None,
) -> SimState:
    """Initial simulator state; ``n_obs`` (>= n, default n) is the row
    count of the observation-state arrays (``last_seen``, return-time
    histograms). The fused estimator path carries them PRE-padded to the
    node tile (``observation_rows``) so the per-round pad+slice inside
    the scan disappears; pad rows are masked "no data" rows no walk can
    hit, so every real row is bitwise what the unpadded run computes.

    ``steps`` (static, optional) is the run's step budget: on the
    fused-ref whole-round path the return-time carry is the cumulative
    table trimmed to ``min(rt_bins, steps)`` bins (the same trim
    ``theta_hat_rows`` applies through ``max_elapsed`` — bitwise-neutral,
    see its docstring); without it the carry keeps all ``rt_bins``."""
    n_obs = n if n_obs is None else n_obs
    W = pcfg.max_walks
    k_init, k_run = jax.random.split(key)
    walks = wlk.init_walks(pcfg.z0, W, n, k_init)
    if pcfg.walk_variant != "uniform":
        # function-level import: the zoo package loads only when a
        # non-default variant actually runs (no import cycle either way)
        from repro.zoo.variants import init_variant_state

        walks = init_variant_state(walks, pcfg)
    if pcfg.algorithm == "missingperson":
        if n_obs != n:
            raise ValueError("missingperson does not pad observation state")
        # paper: L_{i,l}(0) = 0 for all initial ids at every node
        last_seen = jnp.where(
            jnp.arange(W)[None, :] < pcfg.z0,
            jnp.zeros((n, W), jnp.int32),
            est.NEVER,
        )
    else:
        last_seen = jnp.full((n_obs, W), est.NEVER, jnp.int32)
        # the starting node of each initial walk has seen it at t=0
        last_seen = last_seen.at[walks.pos, jnp.arange(W)].max(
            jnp.where(walks.active, 0, est.NEVER)
        )
    tb = _theta_bins(pcfg)
    if _will_fuse_round(pcfg, fcfg) and _fused_round_backend() == "ref":
        cbins = pcfg.rt_bins if steps is None else min(
            pcfg.rt_bins, max(int(steps), 1)
        )
        rts = est.init_cumulative_state(n_obs, cbins)
    else:
        rts = est.init_return_time_state(n_obs, pcfg.rt_bins)
    return SimState(
        t=jnp.int32(0),
        walks=walks,
        last_seen=last_seen,
        rts=rts,
        byz_state=jnp.asarray(fcfg.byz_start),
        key=k_run,
        theta_hist=jnp.zeros((n, tb), jnp.float32),
        graph=init_graph_state(n, max_deg),
        pacman_pos=(
            flr.initial_pacman_positions(fcfg) if fcfg.pacman_mobile else None
        ),
    )


def resolved_estimator_impl(pcfg: prt.ProtocolConfig) -> str:
    """``estimator_impl`` with ``'auto'`` resolved for the current
    backend (trace-time; fused on TPU, gather elsewhere)."""
    impl = pcfg.estimator_impl
    if impl == "auto":
        # function-level import: the kernels package (and with it
        # jax.experimental.pallas) loads only when a round actually asks
        from repro.kernels.platform import best_estimator_impl

        impl = best_estimator_impl()
    return impl


def _will_fuse(pcfg: prt.ProtocolConfig) -> bool:
    """Whether the trajectory will take the fused observation path —
    THE fuse predicate (``protocol_step`` consumes it directly, adding
    only its caller-supplied ``pi is None`` guard)."""
    return (
        resolved_estimator_impl(pcfg) == "fused"
        and pcfg.algorithm in ("decafork", "decafork+")
        and not pcfg.analytic_survival
    )


def resolved_round_impl(pcfg: prt.ProtocolConfig) -> str:
    """``round_impl`` with ``'auto'`` resolved for the current backend
    (trace-time; honors the ``REPRO_ROUND_IMPL`` env override)."""
    impl = pcfg.round_impl
    if impl == "auto":
        from repro.kernels.platform import best_round_impl

        impl = best_round_impl()
    return impl


def _fused_round_backend() -> str:
    from repro.kernels.platform import fused_round_backend

    return fused_round_backend()


class RoundDecision(NamedTuple):
    """Trace-time record of how one scenario's round will execute.

    ``impl`` is ``'fused'`` or ``'unfused'``; ``backend`` names the fused
    round flavor (``'ref'``/``'pallas'``) when fused, else None; and
    ``reason`` says WHY — which gate sent an intended-fused config back
    to the stage sequence. ``Plan.round_decisions()`` surfaces this per
    compile group, so a silently-degraded config is one call away from
    explaining itself.
    """

    impl: str
    backend: str | None
    reason: str

    @property
    def fused(self) -> bool:
        return self.impl == "fused"


def round_impl_decision(
    pcfg: prt.ProtocolConfig, fcfg: flr.FailureConfig | None = None
) -> RoundDecision:
    """Resolve how a (protocol, failure) config pair executes its rounds —
    THE whole-round fuse predicate, with the fallback reason attached.
    ``init_state`` (carry representation) and ``protocol_step``
    (dispatch) both consume it, so the carry and the step function agree
    by construction for every caller.

    Gated to the configurations the fused round reproduces bitwise:
    DECAFORK/DECAFORK+ with empirical survival and fixed thresholds, on
    the estimator family the backend's fused round computes — the
    gather family for the ref (incremental-CDF) round, the node-sum
    family (compare/pallas/fused) for the whole-round Pallas kernel.
    Zoo configs narrow this further: non-uniform walk variants always
    take the stage sequence, and the Pallas whole-round kernel (unlike
    the ref round, which shares the jnp failure helpers) does not fuse
    multi/mobile Pac-Man or scheduled edge cuts. Everything else keeps
    the literal unfused sequence, which doubles as the fused path's
    golden oracle (``round_impl="unfused"``).

    ``fcfg=None`` means "no zoo attack statics" (the pre-zoo call shape).
    """

    def unfused(reason: str) -> RoundDecision:
        return RoundDecision("unfused", None, reason)

    impl = resolved_round_impl(pcfg)
    if impl != "fused":
        return unfused(f"round_impl resolved to {impl!r}")
    if pcfg.algorithm not in ("decafork", "decafork+"):
        return unfused(f"algorithm {pcfg.algorithm!r} has no fused round")
    if pcfg.analytic_survival:
        return unfused("analytic_survival only runs the stage sequence")
    if pcfg.auto_eps:
        return unfused("auto_eps thresholds only run the stage sequence")
    eimpl = resolved_estimator_impl(pcfg)
    backend = _fused_round_backend()
    if backend == "pallas":
        if eimpl not in ("compare", "pallas", "fused"):
            return unfused(
                f"estimator_impl {eimpl!r} is outside the pallas fused "
                "round's node-sum family"
            )
    elif eimpl != "gather":
        return unfused(
            f"estimator_impl {eimpl!r} is outside the ref fused round's "
            "gather family"
        )
    if pcfg.walk_variant != "uniform":
        return unfused(
            f"walk_variant {pcfg.walk_variant!r} has no fused round"
        )
    if fcfg is not None and backend == "pallas":
        if fcfg.pacman_mobile:
            return unfused(
                "mobile Pac-Man is not in the pallas whole-round kernel"
            )
        if fcfg.n_pacman:
            return unfused(
                "multiple Pac-Man nodes are not in the pallas whole-round "
                "kernel"
            )
        if fcfg.n_edge_cuts:
            return unfused(
                "scheduled edge cuts are not in the pallas whole-round "
                "kernel"
            )
    return RoundDecision(
        "fused", backend, f"all stages supported by the {backend} fused round"
    )


def _will_fuse_round(
    pcfg: prt.ProtocolConfig, fcfg: flr.FailureConfig | None = None
) -> bool:
    """Boolean view of :func:`round_impl_decision` (see its docstring)."""
    return round_impl_decision(pcfg, fcfg).fused


def observation_rows(
    n: int,
    pcfg: prt.ProtocolConfig,
    fcfg: flr.FailureConfig | None = None,
) -> int:
    """Static row count of the observation-state arrays for a run.

    On the fused paths (observation-fused estimator, or the whole-round
    Pallas kernel) the node axis is padded up to the round kernel's
    tile ONCE here, instead of pad+slice every round inside the scan (one
    observation-state copy per round saved whenever ``n`` is not
    tile-aligned); everywhere else it is just ``n``.
    """
    pad_for_kernel = _will_fuse(pcfg) or (
        _will_fuse_round(pcfg, fcfg) and _fused_round_backend() == "pallas"
    )
    if not pad_for_kernel:
        return n
    from repro.kernels.round_update import DEFAULT_BLOCK_NODES

    bn = min(DEFAULT_BLOCK_NODES, n)
    return n + (-n) % bn


def _theta_bins(pcfg: prt.ProtocolConfig) -> int:
    # theta-hat <= 0.5 + (slots - 1); one extra bin absorbs the tail
    return int((pcfg.max_walks + 1) / pcfg.theta_bin_width) + 1


def protocol_step(
    state: SimState,
    pcfg: prt.ProtocolConfig,
    fcfg: flr.FailureConfig,
    neighbors: jax.Array,
    degrees: jax.Array,
    mirror: jax.Array,
    pi: jax.Array | None,
    *,
    max_elapsed: int | None = None,
):
    """One synchronous round; returns (next state, per-step outputs).

    ``max_elapsed`` (static) is an optional upper bound on ``t`` over the
    whole run — the trajectory scan passes its ``steps`` — letting the
    estimator trim the dead tail of the cumulative return-time table
    (bitwise-identical results; see ``estimator.theta_hat_rows``).

    When ``_will_fuse_round(pcfg)`` holds, the round dispatches to the
    fused whole-round implementation (``_protocol_step_fused``) — bitwise
    the sequence below, verified by the whole-round golden tests. This
    function body IS the unfused oracle (``round_impl="unfused"``).
    """
    if _will_fuse_round(pcfg, fcfg):
        if pi is not None:
            raise ValueError(
                "the fused whole-round path does not take an analytic-"
                "survival table; pass round_impl='unfused' (or a config "
                "with analytic_survival=True, which never fuses)"
            )
        return _protocol_step_fused(state, pcfg, fcfg, neighbors, degrees, mirror)
    t = state.t
    key = state.key
    k_move = fold_in_time(key, t, 0)
    k_pfail = fold_in_time(key, t, 1)
    k_burst = fold_in_time(key, t, 2)
    k_byz = fold_in_time(key, t, 3)
    k_dec = fold_in_time(key, t, 4)
    k_topo = fold_in_time(key, t, 5)

    ws = state.walks
    n_before = jnp.sum(ws.active)

    # 1. topology evolves; a crashing node kills its resident walks
    gs = flr.step_topology(state.graph, t, fcfg, k_topo, neighbors, mirror)
    ws = ws._replace(
        active=flr.kill_resident_walks(ws.active, ws.pos, gs.node_up)
    )

    # 1b. a mobile Pac-Man hops over the same live topology the walks see
    # (dedicated stream tag 6 + 1: never perturbs the walk/decision draws)
    pac_pos = state.pacman_pos
    if fcfg.pacman_mobile:
        k_pac = fold_in_time(key, t, 7)
        pac_pos = flr.step_mobile_pacman(
            pac_pos, t, fcfg, k_pac, neighbors, degrees,
            availability(gs, neighbors, degrees),
        )

    # 2. movement over the currently-available edges; non-uniform zoo
    # variants (jump / biased / bloom) are whole other static programs
    if pcfg.walk_variant == "uniform":
        ws = wlk.move_walks(
            ws, neighbors, degrees, k_move, availability(gs, neighbors, degrees)
        )
    else:
        from repro.zoo.variants import move_variant

        ws = move_variant(
            ws, pcfg, neighbors, degrees, k_move,
            availability(gs, neighbors, degrees), gs.node_up,
        )

    # 3. walk-level threat models
    active = flr.apply_probabilistic_failures(ws.active, t, fcfg, k_pfail)
    active = flr.apply_burst_failures(active, t, fcfg, k_burst)
    active, byz_state = flr.step_byzantine(
        active, ws.pos, t, state.byz_state, fcfg, k_byz
    )
    active = flr.apply_pacman(active, ws.pos, t, fcfg, pac_pos)
    ws = ws._replace(active=active)
    n_failed = n_before - jnp.sum(active)

    # 4. observations: return samples + last-seen updates for ALL visitors
    impl = resolved_estimator_impl(pcfg)
    last_seen = state.last_seen
    prev = last_seen[ws.pos, ws.track]  # (W,)
    r = t - prev
    valid = ws.active & (prev != est.NEVER) & (r >= 1)
    upd = jnp.where(ws.active, t, est.NEVER)
    node_sums = None
    # `pi is None` guards direct callers that pass an analytic-survival
    # table independently of pcfg; the padding decision (_will_fuse,
    # observation_rows) must stay a superset-consistent view of this.
    fuse = _will_fuse(pcfg) and pi is None
    if fuse:
        # one fused pass: scatter + max-update + node theta-sums
        # (kernels/round_update.py; Pallas tiles on TPU, jnp elsewhere)
        from repro.kernels.round_update import round_update

        last_seen, hist, tot, node_sums = round_update(
            last_seen, state.rts.hist, state.rts.total,
            ws.pos, ws.track, r, valid, upd, t,
        )
        rts = est.ReturnTimeState(hist=hist, total=tot)
    else:
        rts = est.record_returns(state.rts, ws.pos, r, valid)
        last_seen = last_seen.at[ws.pos, ws.track].max(upd, mode="drop")

    # 5. estimation + decisions for chosen walks
    chosen = prt.choose_walks(ws.pos, ws.active, degrees.shape[0])
    enabled = t >= pcfg.protocol_start
    theta_hist = state.theta_hist
    if pcfg.algorithm in ("decafork", "decafork+"):
        if fuse:
            theta = est.theta_hat_from_node_sums(node_sums, ws.pos)
        elif impl == "gather" or pi is not None:
            theta = est.theta_hat_rows(
                last_seen, rts.hist, rts.total, t, ws.pos, ws.track, pi=pi,
                max_elapsed=max_elapsed,
            )
        elif impl == "compare":
            sums = est.node_sums_compare(last_seen, rts.hist, rts.total, t)
            theta = est.theta_hat_from_node_sums(sums, ws.pos)
        elif impl == "pallas":
            from repro.kernels import theta_sums_pallas

            sums = theta_sums_pallas(last_seen, rts.hist, rts.total, t)
            theta = est.theta_hat_from_node_sums(sums, ws.pos)
        else:
            raise ValueError(impl)
        # beyond-paper: per-node self-calibrated thresholds (auto_eps)
        if pcfg.auto_eps:
            warmup = ~enabled
            b = jnp.clip(
                (theta / pcfg.theta_bin_width).astype(jnp.int32),
                0,
                theta_hist.shape[1] - 1,
            )
            w = (chosen & warmup).astype(jnp.float32)
            theta_hist = theta_hist.at[ws.pos, b].add(w, mode="drop")
            eps_w, eps2_w = prt.theta_quantile_thresholds(theta_hist, ws.pos, pcfg)
            fork_mask, term_mask = prt.decafork_decisions(
                theta, chosen, k_dec, pcfg, enabled, eps=eps_w, eps2=eps2_w
            )
        else:
            fork_mask, term_mask = prt.decafork_decisions(
                theta, chosen, k_dec, pcfg, enabled
            )
        ws = wlk.execute_terminations(ws, term_mask)
        n_terms = jnp.sum(term_mask)
        ws, last_seen, n_forks, fork_parent = wlk.execute_forks(
            ws, last_seen, fork_mask, ws.pos, None, t
        )
        theta_mean = jnp.sum(jnp.where(chosen, theta, 0.0)) / jnp.maximum(
            jnp.sum(chosen), 1
        )
    elif pcfg.algorithm == "missingperson":
        ev = prt.missingperson_decisions(
            last_seen, ws.pos, ws.track, chosen, t, k_dec, pcfg, enabled
        )  # (W, C) — only initial-id columns (< z0) can fire
        ws, last_seen, n_forks, fork_parent = wlk.execute_grid_forks(
            ws, last_seen, ev, t
        )
        n_terms = jnp.int32(0)
        term_mask = jnp.zeros((ev.shape[0],), bool)
        theta_mean = jnp.float32(0.0)
    else:  # 'none': plain multi-RW system without self-regulation
        n_forks = jnp.int32(0)
        n_terms = jnp.int32(0)
        theta_mean = jnp.float32(0.0)
        fork_parent = jnp.full((ws.pos.shape[0],), -1, jnp.int32)
        term_mask = jnp.zeros_like(ws.active)

    new_state = SimState(
        t=t + 1,
        walks=ws,
        last_seen=last_seen,
        rts=rts,
        byz_state=byz_state,
        key=key,
        theta_hist=theta_hist,
        graph=gs,
        pacman_pos=pac_pos,
    )
    out = StepOutputs(
        z=jnp.sum(ws.active),
        forks=n_forks,
        terms=n_terms,
        failures=n_failed,
        theta_mean=theta_mean,
        fork_parent=fork_parent,
        terminated=term_mask,
    )
    return new_state, out


def _protocol_step_fused(
    state: SimState,
    pcfg: prt.ProtocolConfig,
    fcfg: flr.FailureConfig,
    neighbors: jax.Array,
    degrees: jax.Array,
    mirror: jax.Array,
):
    """The fused whole-round implementation behind ``round_impl="fused"``.

    Bitwise-identical to the unfused sequence in ``protocol_step`` (its
    golden oracle) by construction: every PRNG stream is derived with the
    exact same key folds, the failure/topology helpers are the same
    functions, and each restructured stage is an exact-arithmetic
    transform of its unfused counterpart —

      * movement is row-restricted (``move_walks_rows`` over
        ``availability_rows`` at the walks' own rows) — the rank-select
        acts row-locally, so gathering first changes nothing;
      * "choose one walk per node" is the (W, W) pairwise minimum
        (``choose_walks_pairwise``) instead of an (n,)-scatter;
      * on the ref backend (CPU/GPU) the return-time statistics are the
        incrementally-carried cumulative table
        (``CumulativeReturnState``): observation is a scatter-add of 0/1
        step rows and theta reads prefix counts straight off the carry
        (``theta_hat_cumulative``) — no per-round cumsum, which XLA CPU
        lowers to a quadratic reduce-window and which dominated the
        PR-4 round;
      * on TPU the whole round (hop + topology + failures + observation
        + decisions) is one node-tiled Pallas pass
        (``kernels.round_update.whole_round_pallas``) with all uniforms
        pre-drawn from the same streams.

    Fork/terminate execution (slot machinery) stays outside in both
    branches — it is walk-sized and shared with every other path.
    """
    t = state.t
    key = state.key
    k_move = fold_in_time(key, t, 0)
    k_pfail = fold_in_time(key, t, 1)
    k_burst = fold_in_time(key, t, 2)
    k_byz = fold_in_time(key, t, 3)
    k_dec = fold_in_time(key, t, 4)
    k_topo = fold_in_time(key, t, 5)

    ws = state.walks
    W = ws.pos.shape[0]
    n = degrees.shape[0]
    n_before = jnp.sum(ws.active)
    enabled = t >= pcfg.protocol_start
    pac_pos = state.pacman_pos

    if _fused_round_backend() == "ref":
        # 1. topology evolves; a crashing node kills its resident walks
        # (step_topology already applies any scheduled edge cuts)
        gs = flr.step_topology(state.graph, t, fcfg, k_topo, neighbors, mirror)
        ws = ws._replace(
            active=flr.kill_resident_walks(ws.active, ws.pos, gs.node_up)
        )

        # 1b. mobile Pac-Man hop — same helper, same dedicated stream as
        # the unfused sequence, so the positions stay its exact bits
        if fcfg.pacman_mobile:
            k_pac = fold_in_time(key, t, 7)
            pac_pos = flr.step_mobile_pacman(
                pac_pos, t, fcfg, k_pac, neighbors, degrees,
                availability(gs, neighbors, degrees),
            )

        # 2. movement, row-restricted to the walks' own adjacency rows
        u_move = jax.random.uniform(k_move, (W,))
        avail_rows = availability_rows(
            gs.edge_up[ws.pos], gs.node_up[ws.pos], gs.node_up,
            neighbors[ws.pos], degrees[ws.pos],
        )
        ws = ws._replace(
            pos=wlk.move_walks_rows(
                ws, neighbors[ws.pos], u_move, avail_rows, degrees.dtype
            )
        )

        # 3. walk-level threat models (same helpers, same keys)
        active = flr.apply_probabilistic_failures(ws.active, t, fcfg, k_pfail)
        active = flr.apply_burst_failures(active, t, fcfg, k_burst)
        active, byz_state = flr.step_byzantine(
            active, ws.pos, t, state.byz_state, fcfg, k_byz
        )
        active = flr.apply_pacman(active, ws.pos, t, fcfg, pac_pos)
        ws = ws._replace(active=active)
        n_failed = n_before - jnp.sum(active)

        # 4. observations on the incremental cumulative carry
        last_seen = state.last_seen
        prev = last_seen[ws.pos, ws.track]
        r = t - prev
        valid = ws.active & (prev != est.NEVER) & (r >= 1)
        upd = jnp.where(ws.active, t, est.NEVER)
        rts = est.record_returns_cumulative(
            state.rts, ws.pos, r, valid, pcfg.rt_bins
        )
        last_seen = last_seen.at[ws.pos, ws.track].max(upd, mode="drop")

        # 5. estimation + decisions; no cumsum anywhere
        chosen = prt.choose_walks_pairwise(ws.pos, ws.active)
        theta = est.theta_hat_cumulative(
            last_seen, rts, t, ws.pos, ws.track
        )
        fork_mask, term_mask = prt.decafork_decisions(
            theta, chosen, k_dec, pcfg, enabled
        )
    else:
        # TPU: one whole-round Pallas pass; pre-draw every uniform from
        # the exact streams the unfused sequence consumes
        from repro.kernels.round_update import whole_round_pallas

        n_obs = state.last_seen.shape[0]
        K = fcfg.n_bursts
        u_move = jax.random.uniform(k_move, (W,))
        u_pfail = jax.random.uniform(k_pfail, (W,))
        if K:
            u_burst = jnp.stack(
                [
                    jax.random.uniform(jax.random.fold_in(k_burst, i), (W,))
                    for i in range(K)
                ]
            )
            burst_sizes_eff = jnp.stack(
                [
                    jnp.where(t == fcfg.burst_times[i], fcfg.burst_sizes[i], 0)
                    for i in range(K)
                ]
            ).astype(jnp.int32)
        else:
            u_burst = jnp.ones((1, W), jnp.float32)
            burst_sizes_eff = jnp.zeros((1,), jnp.int32)
        k_fork, k_term = jax.random.split(k_dec)
        u_fork = jax.random.uniform(k_fork, (W,))
        u_term = jax.random.uniform(k_term, (W,))
        u_nfail, u_nrec, e_fail, e_rec = flr.topology_uniforms(
            k_topo, neighbors, mirror
        )
        sched_down = flr.scheduled_crash_mask(n, t, fcfg)

        # Byzantine chain advances outside (one scalar draw); the kernel
        # only needs "which node kills this round" (-1: none)
        byz_armed = (t >= fcfg.byz_start_time) & (fcfg.byzantine_node >= 0)
        flip = (jax.random.uniform(k_byz, ()) < fcfg.p_byz) & byz_armed
        byz_state = jnp.logical_xor(state.byz_state, flip)
        byz_kill_node = jnp.where(
            byz_state & byz_armed, fcfg.byzantine_node, -1
        ).astype(jnp.int32)
        pac_armed = (t >= fcfg.pacman_start_time) & (fcfg.pacman_node >= 0)
        pac_node = jnp.where(pac_armed, fcfg.pacman_node, -1).astype(jnp.int32)

        # start-gated rates fold the gate into the threshold (u in [0,1)
        # is never < -1, so "not started" == rate -1)
        p_fail_eff = jnp.where(t >= fcfg.p_fail_start, fcfg.p_fail, -1.0)
        p_nf_eff = jnp.where(t >= fcfg.node_fail_start, fcfg.p_node_fail, -1.0)
        p_lf_eff = jnp.where(t >= fcfg.link_fail_start, fcfg.p_link_fail, -1.0)

        def _pad_nodes(x, fill):
            pad = n_obs - x.shape[0]
            if pad == 0:
                return x
            return jnp.concatenate(
                [x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)]
            )

        # pad rows stay down forever: node_up False, recovery uniform 1.0
        outs = whole_round_pallas(
            state.last_seen, state.rts.hist, state.rts.total,
            _pad_nodes(state.graph.node_up, False),
            _pad_nodes(state.graph.edge_up, False),
            ws.pos, ws.track, ws.active,
            neighbors[ws.pos], degrees[ws.pos],
            state.graph.edge_up[ws.pos], e_fail[ws.pos], e_rec[ws.pos],
            u_move, u_pfail, u_fork, u_term,
            u_burst, burst_sizes_eff,
            _pad_nodes(u_nfail, 1.0), _pad_nodes(u_nrec, 1.0),
            _pad_nodes(sched_down, False),
            _pad_nodes(e_fail, 1.0), _pad_nodes(e_rec, 1.0),
            params_f=jnp.stack(
                [
                    jnp.asarray(p_fail_eff, jnp.float32),
                    jnp.asarray(p_nf_eff, jnp.float32),
                    jnp.asarray(p_lf_eff, jnp.float32),
                    jnp.asarray(fcfg.p_node_recover, jnp.float32),
                    jnp.asarray(fcfg.p_link_recover, jnp.float32),
                    jnp.asarray(pcfg.eps, jnp.float32),
                    jnp.asarray(pcfg.eps2, jnp.float32),
                    jnp.asarray(pcfg.p, jnp.float32),
                ]
            )[None, :],
            params_i=jnp.stack(
                [
                    jnp.asarray(t, jnp.int32),
                    byz_kill_node,
                    pac_node,
                    enabled.astype(jnp.int32),
                ]
            )[None, :],
            decafork_plus=pcfg.algorithm == "decafork+",
        )
        (last_seen, hist, tot, node_up_new, edge_up_new,
         pos_new, act_new, theta, chosen, fork_mask, term_mask) = outs
        gs = GraphState(node_up=node_up_new[:n], edge_up=edge_up_new[:n])
        ws = ws._replace(pos=pos_new, active=act_new)
        rts = est.ReturnTimeState(hist=hist, total=tot)
        n_failed = n_before - jnp.sum(act_new)

    # forks/terminations execute through the shared slot machinery
    ws = wlk.execute_terminations(ws, term_mask)
    n_terms = jnp.sum(term_mask)
    ws, last_seen, n_forks, fork_parent = wlk.execute_forks(
        ws, last_seen, fork_mask, ws.pos, None, t
    )
    theta_mean = jnp.sum(jnp.where(chosen, theta, 0.0)) / jnp.maximum(
        jnp.sum(chosen), 1
    )

    new_state = SimState(
        t=t + 1,
        walks=ws,
        last_seen=last_seen,
        rts=rts,
        byz_state=byz_state,
        key=key,
        theta_hist=state.theta_hist,
        graph=gs,
        pacman_pos=pac_pos,
    )
    out = StepOutputs(
        z=jnp.sum(ws.active),
        forks=n_forks,
        terms=n_terms,
        failures=n_failed,
        theta_mean=theta_mean,
        fork_parent=fork_parent,
        terminated=term_mask,
    )
    return new_state, out


def _strip_obs_pad(state: SimState, n: int, pcfg: prt.ProtocolConfig) -> SimState:
    """Final-state normalization: slice the pre-padded observation rows
    back to the graph's ``n`` (one slice per *run*, vs one pad+slice per
    round without carrying padded state) and convert a cumulative
    whole-round carry back to the public ``ReturnTimeState`` (exact
    integer transform — see ``estimator.cumulative_to_return_time``), so
    every consumer of a final state sees one representation."""
    rts = state.rts
    if isinstance(rts, est.CumulativeReturnState):
        rts = est.cumulative_to_return_time(rts, pcfg.rt_bins)
        state = state._replace(rts=rts)
    if state.last_seen.shape[0] == n:
        return state
    return state._replace(
        last_seen=state.last_seen[:n],
        rts=est.ReturnTimeState(
            hist=state.rts.hist[:n], total=state.rts.total[:n]
        ),
    )


def _init_carry(key, neighbors, pcfg, fcfg, steps, n, payload=None):
    """The trajectory's step-0 carry: ``(SimState, payload carry | None)``.

    This is the SAME initialization ``_run_core`` performs (same key
    splits, same observation-row padding, same cumulative-carry trim on
    ``steps`` — the TOTAL step budget, never a segment length), factored
    out so the segmented execution path starts from bitwise the state
    the monolithic scan starts from.
    """
    n_obs = observation_rows(n, pcfg, fcfg)
    state = init_state(
        n, neighbors.shape[1], pcfg, fcfg, key, n_obs=n_obs, steps=steps
    )
    pcarry = payload.init(payload_init_key(key)) if payload is not None else None
    return (state, pcarry)


def _scan_chunk(
    carry, neighbors, degrees, mirror, pi, pcfg, fcfg, length, steps,
    payload=None, spec=SCALARS, pspec=None,
):
    """Advance a trajectory carry by ``length`` rounds — THE scan body.

    ``_run_core`` calls this once with ``length == steps``; the segment
    cores call it per segment. Both trace the identical per-round body
    (``protocol_step`` + payload hooks), and every PRNG stream folds the
    carried step counter ``state.t`` — never the loop index — so where
    the scan is *split* cannot change a single drawn bit. ``steps`` (the
    total budget) feeds ``max_elapsed`` so the estimator's bin trim is a
    whole-run constant.

    With ``payload=None`` the scan carry is the bare ``SimState``
    (exactly the pre-segmentation program); with a payload it is
    ``(SimState, payload_carry)`` and each round runs the hook sequence
    ``on_terminate -> on_fork -> on_visit`` after the protocol round,
    mirroring the protocol's own order (``execute_terminations`` frees
    slots *before* ``execute_forks`` reallocates them, so a slot can be
    terminated and re-forked in one round — clearing must not clobber the
    fresh copy); the forked walk trains at its origin node the very round
    it is created, on a copy of its parent's pre-round replica.
    """
    state, pcarry = carry

    if payload is None:

        def body(s, _):
            s2, out = protocol_step(
                s, pcfg, fcfg, neighbors, degrees, mirror, pi,
                max_elapsed=steps,
            )
            return s2, spec.select(out)

        final, recorded = jax.lax.scan(body, state, None, length=length)
        return (final, None), recorded

    def body(c, _):
        s, pc = c
        t = s.t  # pre-round step counter, matching the simulator's streams
        k_visit = fold_in_time(s.key, t, PAYLOAD_STREAM)
        s2, out = protocol_step(
            s, pcfg, fcfg, neighbors, degrees, mirror, pi, max_elapsed=steps
        )
        pc = payload.on_terminate(pc, out.terminated)
        pc = payload.on_fork(pc, out.fork_parent)
        pc, pout = payload.on_visit(pc, s2.walks, t, k_visit)
        if pspec is not None:
            pout = pspec.select(pout)
        return (s2, pc), (spec.select(out), pout)

    (final, pcarry), recorded = jax.lax.scan(
        body, (state, pcarry), None, length=length
    )
    return (final, pcarry), recorded


def _run_core(
    key, neighbors, degrees, mirror, pi, pcfg, fcfg, steps, n,
    payload=None, spec=SCALARS, pspec=None,
):
    """Un-jitted single-trajectory scan; every batching wrapper traces
    through this one function so ensemble/sweep results are bitwise equal
    to the single-run path. This is the ONE backend ``repro.api.Plan``
    compiles — the jitted executables live in the Plan's process-wide
    cache, keyed on the static signature.

    ``spec`` (an ``OutputSpec``, static) selects which ``StepOutputs``
    fields the scan stacks over time: the full per-round StepOutputs is
    free *inside* the round, but every recorded field costs a
    ``(steps, ...)`` output buffer — O(W) extra HBM traffic per round for
    the per-walk fields — so the thinned view is the default and the
    dropped stacks are never allocated at all. ``pspec`` (a
    ``PayloadOutputSpec`` or None, static) does the same for the payload's
    per-round outputs; ``None`` records the payload's full output pytree
    untouched.

    On the fused estimator path the observation state is carried
    PRE-padded to the round kernel's node tile (``observation_rows``) and
    sliced back once after the scan — bitwise-identical to padding every
    round, without the per-round state copy.

    The body is :func:`_scan_chunk` with ``length == steps``; the
    durable-execution path (``Plan.*_segmented`` over ``_seg_run_core``)
    runs the same chunks with checkpoint boundaries in between, so the
    two are bitwise-equal by construction (and golden-tested as such).
    Returns ``(final SimState, RecordedOutputs)`` — with a payload,
    ``((final SimState, final carry), (RecordedOutputs, payload_outputs))``.
    """
    carry = _init_carry(key, neighbors, pcfg, fcfg, steps, n, payload)
    (final, pcarry), recorded = _scan_chunk(
        carry, neighbors, degrees, mirror, pi, pcfg, fcfg, steps, steps,
        payload, spec, pspec,
    )
    final = _strip_obs_pad(final, n, pcfg)
    if payload is None:
        return final, recorded
    return (final, pcarry), recorded


# deliberately NO input donation on any entry point: the trajectory
# outputs never alias the (tiny) key/config inputs, and donating a
# caller-owned key would break the standard same-key-different-config
# comparison on accelerators. The memory win that matters — reusing the
# scan carry (last_seen/hist/topology state) in place every round — is
# already done by XLA inside the compiled program.


def _run_ensemble_core(
    keys, neighbors, degrees, mirror, pi, pcfg, fcfg, steps, n,
    payload=None, spec=SCALARS, pspec=None,
):
    """(seeds,) keys -> RecordedOutputs with leading (seeds,) axis (a
    (RecordedOutputs, payload_outputs) pair when a payload is attached)."""
    return jax.vmap(
        lambda k: _run_core(
            k, neighbors, degrees, mirror, pi, pcfg, fcfg, steps, n,
            payload, spec, pspec,
        )[1]
    )(keys)


def _sweep_core(
    keys, neighbors, degrees, mirror, pi, pcfgs, fcfgs, steps, n,
    payload=None, spec=SCALARS, pspec=None,
):
    """Stacked configs (leaves with leading (S,) axis) + (seeds,) keys ->
    RecordedOutputs with leading (S, seeds) axes, all in one XLA program
    (a (RecordedOutputs, payload_outputs) pair when a payload is
    attached)."""

    def one_scenario(pcfg, fcfg):
        return jax.vmap(
            lambda k: _run_core(
                k, neighbors, degrees, mirror, pi, pcfg, fcfg, steps, n,
                payload, spec, pspec,
            )[1]
        )(keys)

    return jax.vmap(one_scenario)(pcfgs, fcfgs)


# ---------------------------------------------------------------------------
# Segmented (durable) execution cores
# ---------------------------------------------------------------------------
#
# A segmented run is the monolithic scan split at host-visible
# boundaries: the carry ``(SimState, payload_carry)`` — the int16
# histogram / cumulative return carry, zoo columns (``prev``/``bloom``),
# mobile Pac-Man positions, live topology masks, payload replicas, all
# of it — crosses each boundary as a plain pytree the host can
# ``checkpoint.save_pytree`` and reload. Because every PRNG stream folds
# the carried step counter (never a loop index), and because each
# segment traces the identical ``_scan_chunk`` body, interrupting at any
# boundary and resuming from the snapshot is BITWISE the uninterrupted
# run (``tests/test_resume.py`` proves it per algorithm x attack). The
# drivers that thread snapshots through these cores live in
# ``repro.api.plan`` (``Plan.run_segmented`` / ``ensemble_segmented`` /
# ``sweep_stacked(segment_steps=...)``).


def _seg_run_core(
    carry, neighbors, degrees, mirror, pi, pcfg, fcfg, seg_len, steps, n,
    payload=None, spec=SCALARS, pspec=None,
):
    """One segment of one trajectory: carry -> (carry', recorded chunk).

    ``seg_len`` (static) is this segment's round count; ``steps`` stays
    the TOTAL budget (it feeds the estimator's bin trim, a whole-run
    constant). ``n`` only shapes the static signature — the final
    ``_strip_obs_pad`` happens once, host-side, after the last segment.
    """
    del n  # signature parity with _run_core; padding strips at the end
    return _scan_chunk(
        carry, neighbors, degrees, mirror, pi, pcfg, fcfg, seg_len, steps,
        payload, spec, pspec,
    )


def _seg_ensemble_core(
    carry, neighbors, degrees, mirror, pi, pcfg, fcfg, seg_len, steps, n,
    payload=None, spec=SCALARS, pspec=None,
):
    """One segment of a seed ensemble (carry leaves lead with (seeds,))."""
    return jax.vmap(
        lambda c: _seg_run_core(
            c, neighbors, degrees, mirror, pi, pcfg, fcfg, seg_len, steps, n,
            payload, spec, pspec,
        )
    )(carry)


def _seg_sweep_core(
    carry, neighbors, degrees, mirror, pi, pcfgs, fcfgs, seg_len, steps, n,
    payload=None, spec=SCALARS, pspec=None,
):
    """One segment of a stacked sweep (carry leaves lead with (S, seeds))."""

    def one_scenario(c, pcfg, fcfg):
        return jax.vmap(
            lambda cc: _seg_run_core(
                cc, neighbors, degrees, mirror, pi, pcfg, fcfg, seg_len,
                steps, n, payload, spec, pspec,
            )
        )(c)

    return jax.vmap(one_scenario)(carry, pcfgs, fcfgs)


def _init_ensemble_carry(keys, neighbors, pcfg, fcfg, steps, n, payload=None):
    """Step-0 carries for a seed ensemble: leaves lead with (seeds,)."""
    return jax.vmap(
        lambda k: _init_carry(k, neighbors, pcfg, fcfg, steps, n, payload)
    )(keys)


def _init_sweep_carry(keys, neighbors, pcfgs, fcfgs, steps, n, payload=None):
    """Step-0 carries for a stacked sweep: leaves lead with (S, seeds)."""

    def one_scenario(pcfg, fcfg):
        return jax.vmap(
            lambda k: _init_carry(k, neighbors, pcfg, fcfg, steps, n, payload)
        )(keys)

    return jax.vmap(one_scenario)(pcfgs, fcfgs)


def _finalize_segmented(carry, n, pcfg, payload=None):
    """Host-side final-state normalization after the last segment — the
    exact ``_strip_obs_pad`` the monolithic core applies inside jit."""
    state, pcarry = carry
    state = _strip_obs_pad(state, n, pcfg)
    if payload is None:
        return state
    return (state, pcarry)


def _graph_arrays(graph: Graph, pcfg: prt.ProtocolConfig):
    """The trace-time graph constants one run needs (benchmark baselines
    drive the cores directly through this; the Plan prepares the same
    arrays once per plan instead of once per call)."""
    neighbors = jnp.asarray(graph.neighbors)
    degrees = jnp.asarray(graph.degrees)
    mirror = jnp.asarray(mirror_indices(graph))
    pi = (
        jnp.asarray(stationary_distribution(graph), jnp.float32)
        if pcfg.analytic_survival
        else None
    )
    return neighbors, degrees, mirror, pi


# ---------------------------------------------------------------------------
# Legacy runner shims (deprecated; use repro.api.Experiment)
# ---------------------------------------------------------------------------
#
# The four historical entry points survive as THIN shims over the
# declarative API — they build the equivalent Experiment, lower it to a
# Plan and run it, so they are bitwise-equal to the new path by
# construction (and golden-tested as such). No in-repo code may call
# them; the test lanes promote APIDeprecationWarning to an error.


def run_simulation(
    graph: Graph,
    pcfg: prt.ProtocolConfig,
    fcfg: flr.FailureConfig,
    steps: int,
    key: jax.Array | int = 0,
    *,
    payload=None,
    outputs=None,
):
    """DEPRECATED shim: one trajectory.

    Use ``repro.api.Experiment(graph=..., protocol=pcfg, failures=fcfg,
    steps=steps, ...).run(key)`` — same return value, same bits.
    """
    from repro.api import Experiment
    from repro.utils.deprecation import warn_legacy_runner

    warn_legacy_runner(
        "repro.core.run_simulation", "Experiment(...).run(key)"
    )
    return Experiment(
        graph=graph, protocol=pcfg, failures=fcfg, steps=steps,
        payload=payload, outputs=outputs,
    ).run(key)


def run_ensemble(
    graph: Graph,
    pcfg: prt.ProtocolConfig,
    fcfg: flr.FailureConfig,
    steps: int,
    seeds: int,
    base_key: jax.Array | int = 0,
    *,
    payload=None,
    outputs=None,
):
    """DEPRECATED shim: vmap over seeds.

    Use ``repro.api.Experiment(graph=..., protocol=pcfg, failures=fcfg,
    steps=steps, ...).ensemble(seeds, base_key)``.
    """
    from repro.api import Experiment
    from repro.utils.deprecation import warn_legacy_runner

    warn_legacy_runner(
        "repro.core.run_ensemble", "Experiment(...).ensemble(seeds)"
    )
    return Experiment(
        graph=graph, protocol=pcfg, failures=fcfg, steps=steps,
        payload=payload, outputs=outputs,
    ).ensemble(seeds, base_key)


def run_sweep(
    graph: Graph,
    scenarios: Sequence[Tuple[prt.ProtocolConfig, flr.FailureConfig]],
    steps: int,
    seeds: int,
    base_key: jax.Array | int = 0,
    *,
    sharded: bool | None = None,
    payload=None,
    outputs=None,
):
    """DEPRECATED shim: one static-structure scenario stack x seeds,
    stacked outputs with leading (S, seeds) axes.

    Use ``repro.api.Experiment(graph=..., scenarios=..., steps=...,
    placement=...).plan().sweep_stacked(seeds=seeds, base_key=...)``
    (the ``sharded`` tri-state maps to ``Placement.from_sharded``).
    """
    from repro.api import Experiment, Placement
    from repro.utils.deprecation import warn_legacy_runner

    warn_legacy_runner(
        "repro.core.simulator.run_sweep",
        "Experiment(...).plan().sweep_stacked(seeds=...)",
    )
    return Experiment(
        graph=graph, scenarios=scenarios, steps=steps, payload=payload,
        outputs=outputs, placement=Placement.from_sharded(sharded),
    ).plan().sweep_stacked(seeds=seeds, base_key=base_key)


# ---------------------------------------------------------------------------
# Trajectory metrics (used by benchmarks and integration tests)
# ---------------------------------------------------------------------------


def reaction_time(z, z0: int, failure_time: int) -> int:
    """Steps from `failure_time` until Z_t first returns to >= z0 (-1: never)."""
    import numpy as np

    z = np.asarray(z)
    post = z[failure_time:]
    hits = np.nonzero(post >= z0)[0]
    return int(hits[0]) if hits.size else -1


def max_overshoot(z, z0: int) -> int:
    import numpy as np

    return int(np.max(np.asarray(z)) - z0)


def survived(z) -> bool:
    """Resilience objective: at least one walk alive at all times."""
    import numpy as np

    return bool((np.asarray(z) > 0).all())
