"""Beyond-paper: self-calibrating thresholds vs the paper's hand-tuned eps.

The paper tunes eps per graph size (Fig. 4: eps in {1.85, 2.0, 2.1}) and
per family. ``auto_eps`` replaces the Irwin-Hall design rule with local
per-node quantiles of the warmup theta-hat distribution — decentralized,
inspection-paradox-bias-inclusive, zero tuning. This benchmark runs the
Fig. 4 / Fig. 6 sweeps with ONE global quantile setting and compares
against the per-graph-tuned DECAFORK."""
from benchmarks.common import burst_failures, pcfg_for, run_case, save_result
from repro.graphs import make_graph

SWEEP = [
    ("regular", 50, dict(degree=8)),
    ("regular", 100, dict(degree=8)),
    ("regular", 200, dict(degree=8)),
    ("power_law", 100, dict(m=4)),
    ("erdos_renyi", 100, {}),
]

TUNED_EPS = {("regular", 50): 1.85, ("regular", 100): 2.0, ("regular", 200): 2.1,
             ("power_law", 100): 1.9, ("erdos_renyi", 100): 1.9}


def run(verbose: bool = True):
    rows = []
    for fam, n, kw in SWEEP:
        g = make_graph(fam, n, seed=0, **kw)
        tuned = run_case(
            f"auto_eps/tuned/{fam}-{n}", g,
            pcfg_for("decafork", eps=TUNED_EPS[(fam, n)]),
            burst_failures(),
        )
        # self-calibration needs ~100+ theta-hat samples per node: give the
        # warmup ~1200 steps (the paper's own init-phase assumption, made
        # quantitative — EXPERIMENTS.md §Beyond-paper)
        auto = run_case(
            f"auto_eps/auto/{fam}-{n}", g,
            pcfg_for("decafork+", auto_eps=True, protocol_start=1200),
            burst_failures(),
        )
        for res in (tuned, auto):
            rows.append({"name": res.name, "us_per_call": res.us_per_call,
                         **res.metrics()})
            if verbose:
                print(res.csv_row())
    save_result("auto_eps", rows)
    return rows


if __name__ == "__main__":
    run()
