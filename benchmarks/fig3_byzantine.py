"""Fig. 3: a Byzantine node (2-state Markov chain) kills incoming walks.

Paper claims: DECAFORK with the burst-tuned eps fails; only DECAFORK+
copes with both the Byz phase and the sudden No-Byz phase (no runaway
overshoot when the node turns honest).

The two DECAFORK eps variants are one batched group (eps is a traced
scenario leaf); DECAFORK+ compiles separately.
"""
from benchmarks.common import (
    PROTO_START, default_graph, run_sweep_cases, save_result, scenario,
)
from repro.core import FailureConfig


def run(verbose: bool = True):
    g = default_graph()
    fcfg = FailureConfig(
        byzantine_node=0, p_byz=0.001, byz_start_time=PROTO_START + 1000,
    )
    scenarios = [
        scenario("fig3/decafork", "decafork", fcfg),
        scenario("fig3/decafork/eps=2.5", "decafork", fcfg, eps=2.5),
        scenario("fig3/decafork+", "decafork+", fcfg),
    ]
    rows = []
    for res in run_sweep_cases(g, scenarios):
        rows.append({"name": res.name, "us_per_call": res.us_per_call,
                     **res.metrics()})
        if verbose:
            print(res.csv_row())
    save_result("fig3_byzantine", rows)
    return rows


if __name__ == "__main__":
    run()
