"""Fig. 3: a Byzantine node (2-state Markov chain) kills incoming walks.

Paper claims: DECAFORK with the burst-tuned eps fails; only DECAFORK+
copes with both the Byz phase and the sudden No-Byz phase (no runaway
overshoot when the node turns honest)."""
from benchmarks.common import (
    PROTO_START, default_graph, pcfg_for, run_case, save_result,
)
from repro.core import FailureConfig


def run(verbose: bool = True):
    g = default_graph()
    fcfg = FailureConfig(
        byzantine_node=0, p_byz=0.001, byz_start_time=PROTO_START + 1000,
    )
    rows = []
    for alg, kw in (("decafork", {}), ("decafork", dict(eps=2.5)),
                    ("decafork+", {})):
        label = f"fig3/{alg}" + (f"/eps={kw['eps']}" if kw else "")
        res = run_case(label, g, pcfg_for(alg, **kw), fcfg)
        rows.append({"name": res.name, "us_per_call": res.us_per_call,
                     **res.metrics()})
        if verbose:
            print(res.csv_row())
    save_result("fig3_byzantine", rows)
    return rows


if __name__ == "__main__":
    run()
