"""Fig. 6: stability across graph families (the survival function is
estimated per node, so no distributional assumption is needed).

Families as in the paper: random regular, complete, Erdos-Renyi,
power-law; eps mildly tuned per family as the paper tunes per graph."""
from benchmarks.common import (
    burst_failures, pcfg_for, run_case, save_result,
)
from repro.graphs import make_graph

FAMILIES = [
    ("regular", dict(degree=8), 2.0),
    ("complete", {}, 2.0),
    ("erdos_renyi", {}, 1.9),
    ("power_law", dict(m=4), 1.9),
]


def run(verbose: bool = True):
    rows = []
    for fam, kw, eps in FAMILIES:
        g = make_graph(fam, 100, seed=0, **kw)
        res = run_case(
            f"fig6/{fam}", g, pcfg_for("decafork", eps=eps), burst_failures()
        )
        rows.append({"name": res.name, "us_per_call": res.us_per_call,
                     **res.metrics()})
        if verbose:
            print(res.csv_row())
    save_result("fig6_graphs", rows)
    return rows


if __name__ == "__main__":
    run()
