"""Theory-vs-simulation: Thm. 2 reaction bound and Cor. 3 overshoot.

Checks that the worst-case analytical bounds hold over the measured
ensembles (bounds must upper-bound the observed quantities)."""
import numpy as np

from benchmarks.common import (
    BURSTS, Z0, burst_failures, default_graph, pcfg_for, run_case, save_result,
)
from repro.core.theory import Rates, overshoot_recursion, reaction_time_bound
from repro.graphs import arrival_rate_estimate, return_rate_estimate


def run(verbose: bool = True):
    g = default_graph()
    rates = Rates(
        lambda_r=float(return_rate_estimate(g).mean()),
        lambda_a=float(arrival_rate_estimate(g)),
    )
    res = run_case("theory/decafork", g, pcfg_for("decafork"), burst_failures())
    m = res.metrics()
    # Thm. 2: time until the FIRST fork after D=5 failures (K=5 remain)
    t_bound = reaction_time_bound(
        d_failed=5, r_forked=0, k_remaining=Z0 - 5, t_d=0.0,
        eps=2.0, p=1.0 / Z0, rates=rates, delta=0.05,
    )
    observed_react = m["reaction_median"][0]
    # Cor. 3: overshoot 500 steps after the burst
    oc = overshoot_recursion(
        z_after_failure=Z0 - 5, d_failed=5, t_d=0.0, steps=500,
        eps=2.0, p=1.0 / Z0, rates=rates,
    )
    rows = [{
        "name": "theory/thm2_vs_sim",
        "us_per_call": res.us_per_call,
        "thm2_first_fork_bound": float(t_bound),
        "observed_full_recovery_median": float(observed_react),
        "cor3_z_bound_at_500": float(oc[-1]),
        "observed_max_z": m["max_z"],
    }]
    if verbose:
        print(
            f"theory/thm2,{res.us_per_call:.2f},"
            f"bound_first_fork={t_bound:.0f}|observed_recovery={observed_react:.0f}"
            f"|cor3_bound500={oc[-1]:.1f}|observed_maxZ={m['max_z']}"
        )
    save_result("theory_bounds", rows)
    return rows


if __name__ == "__main__":
    run()
