"""Fig. 5: the eps trade-off — reaction time vs undesired forks.

Paper claim: larger eps -> faster reaction but more walks beyond Z_0;
smaller eps risks failure after the second burst."""
from benchmarks.common import (
    burst_failures, default_graph, pcfg_for, run_case, save_result,
)


def run(verbose: bool = True):
    g = default_graph()
    rows = []
    for eps in (1.8, 2.0, 2.25, 2.5):
        res = run_case(
            f"fig5/eps={eps}", g, pcfg_for("decafork", eps=eps), burst_failures()
        )
        rows.append({"name": res.name, "us_per_call": res.us_per_call,
                     **res.metrics()})
        if verbose:
            print(res.csv_row())
    save_result("fig5_epsilon", rows)
    return rows


if __name__ == "__main__":
    run()
