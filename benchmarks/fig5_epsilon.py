"""Fig. 5: the eps trade-off — reaction time vs undesired forks.

Paper claim: larger eps -> faster reaction but more walks beyond Z_0;
smaller eps risks failure after the second burst.

The canonical sweep-engine showcase: the whole eps grid is one scenario
batch — ONE compiled program, one device dispatch for every curve
(``benchmarks/bench_sweep.py`` measures the speedup on this exact shape).
"""
from benchmarks.common import (
    burst_failures, default_graph, run_sweep_cases, save_result, scenario,
)

EPS_GRID = (1.8, 2.0, 2.25, 2.5)


def run(verbose: bool = True):
    g = default_graph()
    fcfg = burst_failures()
    scenarios = [
        scenario(f"fig5/eps={eps}", "decafork", fcfg, eps=eps)
        for eps in EPS_GRID
    ]
    rows = []
    for res in run_sweep_cases(g, scenarios):
        rows.append({"name": res.name, "us_per_call": res.us_per_call,
                     **res.metrics()})
        if verbose:
            print(res.csv_row())
    save_result("fig5_epsilon", rows)
    return rows


if __name__ == "__main__":
    run()
