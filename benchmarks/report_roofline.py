"""Aggregate experiments/dryrun/*.json into the EXPERIMENTS.md tables.

Usage:
  PYTHONPATH=src python -m benchmarks.report_roofline            # print
  PYTHONPATH=src python -m benchmarks.report_roofline --markdown # tables
"""
from __future__ import annotations

import argparse
import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")

HBM_PER_CHIP = 16 * 2**30  # v5e: 16 GiB


def load_records(dry_dir: str = DRYRUN_DIR):
    recs = []
    for p in sorted(glob.glob(os.path.join(dry_dir, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def fmt_bytes(b: float) -> str:
    return f"{b / 2**30:.1f}"


def fmt_s(x: float) -> str:
    if x >= 0.1:
        return f"{x:.2f}"
    return f"{x * 1e3:.2f}m"


def roofline_table(recs, mesh="pod256", tag_filter=""):
    lines = [
        "| arch | shape | compute s | memory s | collective s | bottleneck | "
        "GiB/dev | fits | useful |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh or not r.get("ok") or "roofline" not in r:
            continue
        if r["arch"].startswith("protocol"):
            continue
        rl = r["roofline"]
        mem = r["memory"]["total_bytes"]
        fits = "Y" if mem <= HBM_PER_CHIP else f"N ({mem / HBM_PER_CHIP:.0f}x)"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(rl['compute_s'])} | "
            f"{fmt_s(rl['memory_s'])} | {fmt_s(rl['collective_s'])} | "
            f"**{rl['bottleneck']}** | {fmt_bytes(mem)} | {fits} | "
            f"{rl['useful_ratio']:.2f} |"
        )
    return "\n".join(lines)


def compile_table(recs, mesh="pod512"):
    lines = [
        "| arch | shape | ok | compile s | GiB/dev |",
        "|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        mem = r.get("memory", {}).get("total_bytes", 0)
        lines.append(
            f"| {r['arch']} | {r.get('shape','-')} | "
            f"{'ok' if r.get('ok') else 'FAIL: ' + r.get('error','')[:60]} | "
            f"{r.get('compile_seconds','-')} | {fmt_bytes(mem)} |"
        )
    return "\n".join(lines)


def summary(recs):
    by_mesh = {}
    for r in recs:
        key = r.get("mesh", "?")
        by_mesh.setdefault(key, [0, 0])
        by_mesh[key][0] += 1
        by_mesh[key][1] += 1 if r.get("ok") else 0
    return {m: f"{ok}/{n} ok" for m, (n, ok) in by_mesh.items()}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--dir", default=DRYRUN_DIR)
    args = ap.parse_args()
    recs = load_records(args.dir)
    print("# summary:", summary(recs))
    print("\n## Roofline (single pod, 16x16 = 256 chips)\n")
    print(roofline_table(recs, "pod256"))
    print("\n## Multi-pod compile proof (2x16x16 = 512 chips)\n")
    print(compile_table(recs, "pod512"))


if __name__ == "__main__":
    main()
