"""Shared benchmark machinery for the paper-figure reproductions.

Every figure is a *scenario sweep*: its curves are (protocol, failure)
regimes run over a seed ensemble. ``run_sweep_cases`` hands the whole
curve set to one declarative ``repro.api.Experiment`` — one compiled XLA
program and one device dispatch per static-structure group instead of one
per curve — and reports wall time per simulated (scenario x step x seed)
plus the paper's qualitative metrics: stability (mean |Z_t - Z_0|),
reaction time to each burst, max overshoot, and survival rate.
``run_case`` remains for genuinely unbatchable cases (per-graph sweeps).

Reduced mode (default, CI-friendly): 4500 steps, 8 seeds, bursts at
1500/3000. Paper mode (BENCH_FULL=1): 9000 steps, 50 seeds, bursts at
2000/6000 as in Figs. 1-3.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

from repro.api import Experiment
from repro.core import FailureConfig, ProtocolConfig
from repro.graphs import make_graph
from repro.sweep import Scenario

FULL = os.environ.get("BENCH_FULL", "0") == "1"

# canonical synchronous-rounds parameters (EXPERIMENTS.md "Thresholds")
Z0 = 10
EPS_DECAFORK = 2.0
EPS_DFKP = 3.0
EPS2_DFKP = 7.57  # design_eps2(10, 1e-3)
EPS_MP = 400.0
MAX_WALKS = 64

STEPS = 9000 if FULL else 4500
SEEDS = 50 if FULL else 8
BURSTS = (2000, 6000) if FULL else (1500, 3000)
BURST_SIZES = (5, 6)
PROTO_START = 1000 if FULL else 800

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def pcfg_for(alg: str, **overrides) -> ProtocolConfig:
    base = dict(z0=Z0, max_walks=MAX_WALKS, protocol_start=PROTO_START, rt_bins=1024)
    if alg == "decafork":
        base.update(eps=EPS_DECAFORK)
    elif alg == "decafork+":
        base.update(eps=EPS_DFKP, eps2=EPS2_DFKP)
    elif alg == "missingperson":
        base.update(eps_mp=EPS_MP)
    base.update(overrides)
    return ProtocolConfig(algorithm=alg, **base)


def burst_failures(**overrides) -> FailureConfig:
    base = dict(burst_times=BURSTS, burst_sizes=BURST_SIZES)
    base.update(overrides)
    return FailureConfig(**base)


@dataclasses.dataclass
class EnsembleResult:
    name: str
    z: np.ndarray  # (seeds, T)
    us_per_call: float  # wall microseconds per (step x seed)
    forks: int
    terms: int

    def metrics(self, z0: int = Z0, bursts=BURSTS) -> dict:
        z = self.z
        post = z[:, PROTO_START:]
        m = {
            "mean_z": float(post.mean()),
            "mean_abs_dev": float(np.abs(post - z0).mean()),
            "max_z": int(z.max()),
            "min_z_post": int(post.min()),
            "survival_rate": float((z > 0).all(1).mean()),
        }
        reacts = []
        for bt in bursts:
            per_seed = []
            for s in range(z.shape[0]):
                hits = np.nonzero(z[s, bt + 1 :] >= z0)[0]
                per_seed.append(int(hits[0]) if hits.size else STEPS)
            reacts.append(float(np.median(per_seed)))
        m["reaction_median"] = reacts
        m["overshoot"] = int(z.max() - z0)
        return m

    def csv_row(self) -> str:
        m = self.metrics()
        derived = (
            f"meanZ={m['mean_z']:.1f}|dev={m['mean_abs_dev']:.2f}"
            f"|react={'/'.join(str(int(r)) for r in m['reaction_median'])}"
            f"|overshoot={m['overshoot']}|surv={m['survival_rate']:.2f}"
        )
        return f"{self.name},{self.us_per_call:.2f},{derived}"


def run_case(
    name: str,
    graph,
    pcfg: ProtocolConfig,
    fcfg: FailureConfig,
    steps: int = None,
    seeds: int = None,
) -> EnsembleResult:
    steps = steps or STEPS
    seeds = seeds or SEEDS
    t0 = time.time()
    outs = Experiment(
        graph=graph, protocol=pcfg, failures=fcfg, steps=steps
    ).ensemble(seeds)
    z = np.asarray(outs.z)
    wall = time.time() - t0
    return EnsembleResult(
        name=name,
        z=z,
        us_per_call=wall * 1e6 / (steps * seeds),
        forks=int(np.asarray(outs.forks).sum()),
        terms=int(np.asarray(outs.terms).sum()),
    )


def run_sweep_cases(
    graph,
    scenarios: list,
    steps: int = None,
    seeds: int = None,
) -> list:
    """Run a figure's whole curve set through the batched sweep engine.

    One compiled call per static-structure group (same algorithm /
    estimator / capacity); ``us_per_call`` is the amortized wall time per
    (scenario x step x seed) over the entire sweep — directly comparable
    to the per-curve ``run_case`` number it replaces.
    """
    steps = steps or STEPS
    seeds = seeds or SEEDS
    t0 = time.time()
    res = Experiment(graph=graph, scenarios=scenarios, steps=steps).sweep(
        seeds=seeds
    )
    zs = [np.asarray(o.z) for o in res.outputs]  # blocks until done
    wall = time.time() - t0
    us = wall * 1e6 / (steps * seeds * len(scenarios))
    return [
        EnsembleResult(
            name=name,
            z=z,
            us_per_call=us,
            forks=int(np.asarray(o.forks).sum()),
            terms=int(np.asarray(o.terms).sum()),
        )
        for name, z, o in zip(res.names, zs, res.outputs)
    ]


def scenario(name: str, alg: str, fcfg: FailureConfig, **overrides) -> Scenario:
    """Figure-curve shorthand: named scenario from the canonical configs."""
    return Scenario(name, pcfg_for(alg, **overrides), fcfg)


def default_graph(n: int = 100, seed: int = 0):
    return make_graph("regular", n, seed=seed, degree=8)


def machine_metadata() -> dict:
    """The environment block stamped into every results/*.json: numbers
    from different machines / jax builds / backends are not comparable,
    and a result file that doesn't say where it came from is a trap."""
    import platform as _platform

    import jax

    dev = jax.devices()[0]
    return {
        "machine": _platform.node(),
        "platform": _platform.platform(),
        "cpu_count": os.cpu_count(),
        "python": _platform.python_version(),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": getattr(dev, "device_kind", str(dev)),
        "device_count": jax.device_count(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
    }


def save_result(bench: str, rows: list, extra: dict | None = None) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    payload = {"bench": bench, "full": FULL, "rows": rows,
               "meta": machine_metadata()}
    if extra:
        payload.update(extra)
    with open(os.path.join(RESULTS_DIR, f"{bench}.json"), "w") as f:
        json.dump(payload, f, indent=2, default=float)
