"""Fig. 4: consistency across graph sizes n in {50, 100, 200}.

Paper claim: DECAFORK recovers on all sizes; smaller graphs react faster
(return-time support is tighter).

Graph size changes array shapes, so each n is its own sweep call; the
per-n eps tuning rides the traced scenario axis (a future multi-eps grid
per n would batch for free).
"""
from benchmarks.common import (
    burst_failures, run_sweep_cases, save_result, scenario,
)
from repro.graphs import make_graph

# eps tuned per n as in the paper (eps in {1.85, 2, 2.1})
EPS_BY_N = {50: 1.85, 100: 2.0, 200: 2.1}


def run(verbose: bool = True):
    rows = []
    for n, eps in EPS_BY_N.items():
        g = make_graph("regular", n, seed=0, degree=8)
        for res in run_sweep_cases(
            g, [scenario(f"fig4/n={n}", "decafork", burst_failures(), eps=eps)]
        ):
            rows.append({"name": res.name, "us_per_call": res.us_per_call,
                         **res.metrics()})
            if verbose:
                print(res.csv_row())
    save_result("fig4_nodes", rows)
    return rows


if __name__ == "__main__":
    run()
