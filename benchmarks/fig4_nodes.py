"""Fig. 4: consistency across graph sizes n in {50, 100, 200}.

Paper claim: DECAFORK recovers on all sizes; smaller graphs react faster
(return-time support is tighter)."""
from benchmarks.common import (
    burst_failures, pcfg_for, run_case, save_result,
)
from repro.graphs import make_graph

# eps tuned per n as in the paper (eps in {1.85, 2, 2.1})
EPS_BY_N = {50: 1.85, 100: 2.0, 200: 2.1}


def run(verbose: bool = True):
    rows = []
    for n, eps in EPS_BY_N.items():
        g = make_graph("regular", n, seed=0, degree=8)
        res = run_case(
            f"fig4/n={n}", g, pcfg_for("decafork", eps=eps), burst_failures()
        )
        rows.append({"name": res.name, "us_per_call": res.us_per_call,
                     **res.metrics()})
        if verbose:
            print(res.csv_row())
    save_result("fig4_nodes", rows)
    return rows


if __name__ == "__main__":
    run()
