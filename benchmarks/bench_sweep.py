"""BENCH: batched scenario sweep vs the per-scenario baseline.

Workload: a fig5-style epsilon grid (8 scenarios x 4 seeds reduced;
16 x 8 with BENCH_FULL=1) on the canonical figure configuration.

Three engines over the identical workload:
  - ``sweep``     : ONE jit-compiled call for the whole grid
                    (``repro.sweep`` — this PR's engine);
  - ``loop_seed`` : the seed repo's engine — configs were jit-static, so
                    every scenario meant a fresh trace + XLA compile + its
                    own device dispatch (reproduced with a fresh jit
                    wrapper per scenario);
  - ``loop_warm`` : post-refactor per-scenario loop — traced config
                    leaves share one program, but still one dispatch per
                    scenario (isolates compile amortization from batching).

Emits BENCH json (us per scenario-step-seed + end-to-end speedups) via
``save_result``. The acceptance bar is sweep >= 2x over loop_seed
end-to-end; loop_warm shows how much of that batching alone buys.
"""
from __future__ import annotations

import functools
import time

import jax
import numpy as np

from benchmarks.common import (
    FULL, burst_failures, default_graph, pcfg_for, save_result,
)
from repro.core import run_ensemble
from repro.core import simulator as sim
from repro.core.simulator import run_sweep

STEPS = 2000 if FULL else 600
SEEDS = 8 if FULL else 4
N_EPS = 16 if FULL else 8


def _scenarios():
    fcfg = burst_failures(burst_times=(STEPS // 3, 2 * STEPS // 3))
    grid = np.linspace(1.7, 2.6, N_EPS)
    return [
        (pcfg_for("decafork", eps=float(e), protocol_start=STEPS // 4), fcfg)
        for e in grid
    ]


def bench_sweep(graph, scenarios):
    t0 = time.time()
    out = run_sweep(graph, scenarios, steps=STEPS, seeds=SEEDS, base_key=0)
    z = np.asarray(out.z)
    return time.time() - t0, z


def bench_loop_seed_style(graph, scenarios):
    """The pre-sweep engine: one trace+compile+dispatch per scenario.

    A fresh jit wrapper per scenario reproduces the seed behavior, where
    configs were static jit arguments and every eps value was its own
    compilation unit.
    """
    neighbors, degrees, mirror, pi = sim._graph_arrays(graph, scenarios[0][0])
    keys = jax.random.split(jax.random.key(0), SEEDS)
    t0 = time.time()
    zs = []
    for pcfg, fcfg in scenarios:
        fn = jax.jit(
            functools.partial(sim._run_ensemble_core, steps=STEPS, n=graph.n)
        )
        out = fn(keys, neighbors, degrees, mirror, pi, pcfg, fcfg)
        zs.append(np.asarray(out.z))
    return time.time() - t0, np.stack(zs)


def bench_loop_warm(graph, scenarios):
    """Per-scenario loop on the refactored engine (shared program)."""
    t0 = time.time()
    zs = [
        np.asarray(
            run_ensemble(graph, pcfg, fcfg, steps=STEPS, seeds=SEEDS, base_key=0).z
        )
        for pcfg, fcfg in scenarios
    ]
    return time.time() - t0, np.stack(zs)


def run(verbose: bool = True):
    graph = default_graph()
    scenarios = _scenarios()
    denom = len(scenarios) * STEPS * SEEDS

    t_sweep, z_sweep = bench_sweep(graph, scenarios)
    t_seed, z_seed = bench_loop_seed_style(graph, scenarios)
    t_warm, z_warm = bench_loop_warm(graph, scenarios)

    # all three engines must agree bitwise (same keys, same program math)
    assert (z_sweep == z_seed).all() and (z_sweep == z_warm).all()

    rows = [
        {"name": "bench_sweep/sweep", "wall_s": t_sweep,
         "us_per_call": t_sweep * 1e6 / denom},
        {"name": "bench_sweep/loop_seed_style", "wall_s": t_seed,
         "us_per_call": t_seed * 1e6 / denom},
        {"name": "bench_sweep/loop_warm", "wall_s": t_warm,
         "us_per_call": t_warm * 1e6 / denom},
    ]
    extra = {
        "scenarios": len(scenarios),
        "steps": STEPS,
        "seeds": SEEDS,
        "speedup_vs_seed_loop": t_seed / t_sweep,
        "speedup_vs_warm_loop": t_warm / t_sweep,
    }
    save_result("bench_sweep", rows, extra)
    if verbose:
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.2f},wall={r['wall_s']:.2f}s")
        print(
            f"BENCH bench_sweep speedup_vs_seed_loop="
            f"{extra['speedup_vs_seed_loop']:.2f}x "
            f"speedup_vs_warm_loop={extra['speedup_vs_warm_loop']:.2f}x "
            f"({len(scenarios)} scenarios x {SEEDS} seeds x {STEPS} steps)"
        )
    return rows


if __name__ == "__main__":
    run()
