"""BENCH: batched scenario sweep vs the per-scenario baseline.

Workload: a fig5-style epsilon grid (8 scenarios x 4 seeds reduced;
16 x 8 with BENCH_FULL=1) on the canonical figure configuration — the
PR-1 workload, unchanged across PRs so the numbers are comparable.
``benchmarks.bench_round`` reuses this exact workload (same
``_scenarios`` / STEPS / SEEDS) for its fused-vs-unfused whole-round
comparison, and now owns the round-level estimator microbench that
used to live here.

Three engines over the identical workload:
  - ``sweep``     : ONE jit-compiled call for the whole grid
                    (``repro.sweep``);
  - ``loop_seed`` : the seed repo's engine — configs were jit-static, so
                    every scenario meant a fresh trace + XLA compile + its
                    own device dispatch (reproduced with a fresh jit
                    wrapper per scenario);
  - ``loop_warm`` : per-scenario loop on the refactored engine — traced
                    config leaves share one program, but still one
                    dispatch per scenario.

``sweep`` and ``loop_warm`` are each measured twice: ``cold`` (first
call, includes compile — the end-to-end number a user sees) and
``steady`` (minimum over ``REPEATS`` fully-cached re-runs — the engine's
throughput once programs are cached; the min damps scheduler noise on
shared hosts). The headline ratios:

  - ``speedup_vs_seed_loop``  = seed cold / sweep cold (PR-1 definition);
  - ``speedup_vs_warm_loop``  = warm-loop steady / sweep steady — both
    arms fully warm, the honest batched-vs-dispatched throughput ratio
    (PR-1 reported cold sweep vs warm-ish loop = 0.65x; that mixed
    number is kept as ``speedup_vs_warm_loop_cold``).
"""
from __future__ import annotations

import functools
import time

import jax
import numpy as np

from benchmarks.common import (
    FULL, burst_failures, default_graph, pcfg_for, save_result,
)
from repro.api import Experiment
from repro.core import simulator as sim

STEPS = 2000 if FULL else 600
SEEDS = 8 if FULL else 4
N_EPS = 16 if FULL else 8
REPEATS = 2  # steady-state = min over this many cached re-runs


def _scenarios():
    fcfg = burst_failures(burst_times=(STEPS // 3, 2 * STEPS // 3))
    grid = np.linspace(1.7, 2.6, N_EPS)
    return [
        (pcfg_for("decafork", eps=float(e), protocol_start=STEPS // 4), fcfg)
        for e in grid
    ]


def bench_sweep(graph, scenarios):
    t0 = time.time()
    out = Experiment(graph=graph, scenarios=scenarios, steps=STEPS)\
        .plan().sweep_stacked(seeds=SEEDS, base_key=0)
    z = np.asarray(out.z)
    return time.time() - t0, z


def bench_loop_seed_style(graph, scenarios):
    """The pre-sweep engine: one trace+compile+dispatch per scenario.

    A fresh jit wrapper per scenario reproduces the seed behavior, where
    configs were static jit arguments and every eps value was its own
    compilation unit.
    """
    neighbors, degrees, mirror, pi = sim._graph_arrays(graph, scenarios[0][0])
    keys = jax.random.split(jax.random.key(0), SEEDS)
    t0 = time.time()
    zs = []
    for pcfg, fcfg in scenarios:
        fn = jax.jit(
            functools.partial(sim._run_ensemble_core, steps=STEPS, n=graph.n)
        )
        out = fn(keys, neighbors, degrees, mirror, pi, pcfg, fcfg)
        zs.append(np.asarray(out.z))
    return time.time() - t0, np.stack(zs)


def bench_loop_warm(graph, scenarios):
    """Per-scenario loop on the refactored engine (shared program)."""
    t0 = time.time()
    zs = [
        np.asarray(
            Experiment(graph=graph, protocol=pcfg, failures=fcfg, steps=STEPS)
            .ensemble(SEEDS, base_key=0).z
        )
        for pcfg, fcfg in scenarios
    ]
    return time.time() - t0, np.stack(zs)


def _steady(fn, *args):
    best, z = None, None
    for _ in range(REPEATS):
        t, z = fn(*args)
        best = t if best is None else min(best, t)
    return best, z


def run(verbose: bool = True):
    graph = default_graph()
    scenarios = _scenarios()
    denom = len(scenarios) * STEPS * SEEDS

    t_sweep_cold, z_sweep = bench_sweep(graph, scenarios)
    t_sweep, _ = _steady(bench_sweep, graph, scenarios)
    t_seed, z_seed = bench_loop_seed_style(graph, scenarios)
    t_warm_cold, z_warm = bench_loop_warm(graph, scenarios)
    t_warm, _ = _steady(bench_loop_warm, graph, scenarios)

    # all three engines must agree bitwise (same keys, same program math)
    assert (z_sweep == z_seed).all() and (z_sweep == z_warm).all()

    rows = [
        {"name": "bench_sweep/sweep", "wall_s": t_sweep_cold,
         "us_per_call": t_sweep_cold * 1e6 / denom},
        {"name": "bench_sweep/sweep_steady", "wall_s": t_sweep,
         "us_per_call": t_sweep * 1e6 / denom},
        {"name": "bench_sweep/loop_seed_style", "wall_s": t_seed,
         "us_per_call": t_seed * 1e6 / denom},
        {"name": "bench_sweep/loop_warm", "wall_s": t_warm_cold,
         "us_per_call": t_warm_cold * 1e6 / denom},
        {"name": "bench_sweep/loop_warm_steady", "wall_s": t_warm,
         "us_per_call": t_warm * 1e6 / denom},
    ]
    extra = {
        "scenarios": len(scenarios),
        "steps": STEPS,
        "seeds": SEEDS,
        "repeats": REPEATS,
        "speedup_vs_seed_loop": t_seed / t_sweep_cold,
        "speedup_vs_warm_loop": t_warm / t_sweep,
        "speedup_vs_warm_loop_cold": t_warm_cold / t_sweep_cold,
    }
    save_result("bench_sweep", rows, extra)
    if verbose:
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.2f},wall={r['wall_s']:.2f}s")
        print(
            f"BENCH bench_sweep speedup_vs_seed_loop="
            f"{extra['speedup_vs_seed_loop']:.2f}x "
            f"speedup_vs_warm_loop={extra['speedup_vs_warm_loop']:.2f}x "
            f"(cold {extra['speedup_vs_warm_loop_cold']:.2f}x; "
            f"{len(scenarios)} scenarios x {SEEDS} seeds x {STEPS} steps)"
        )
    return rows


if __name__ == "__main__":
    run()

