"""Fig. 2: continuous probabilistic failures p_f on top of bursts.

Paper claims: DECAFORK recovers from bursts but cannot hold Z_0 under
continuous failures; DECAFORK+ stays stable across p_f values.

The p_f grid is a traced scenario axis: both p_f values of an algorithm
share one compiled program and run in one batched call.
"""
from benchmarks.common import (
    PROTO_START, burst_failures, default_graph, run_sweep_cases, save_result,
    scenario,
)


def run(verbose: bool = True):
    g = default_graph()
    scenarios = [
        scenario(f"fig2/{alg}/pf={pf}", alg,
                 burst_failures(p_fail=pf, p_fail_start=PROTO_START))
        for pf in (0.001, 0.0002)
        for alg in ("decafork", "decafork+")
    ]
    rows = []
    for res in run_sweep_cases(g, scenarios):
        rows.append({"name": res.name, "us_per_call": res.us_per_call,
                     **res.metrics()})
        if verbose:
            print(res.csv_row())
    save_result("fig2_probabilistic", rows)
    return rows


if __name__ == "__main__":
    run()
