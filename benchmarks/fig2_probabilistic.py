"""Fig. 2: continuous probabilistic failures p_f on top of bursts.

Paper claims: DECAFORK recovers from bursts but cannot hold Z_0 under
continuous failures; DECAFORK+ stays stable across p_f values."""
from benchmarks.common import (
    PROTO_START, burst_failures, default_graph, pcfg_for, run_case, save_result,
)


def run(verbose: bool = True):
    g = default_graph()
    rows = []
    for pf in (0.001, 0.0002):
        fcfg = burst_failures(p_fail=pf, p_fail_start=PROTO_START)
        for alg in ("decafork", "decafork+"):
            res = run_case(f"fig2/{alg}/pf={pf}", g, pcfg_for(alg), fcfg)
            rows.append({"name": res.name, "us_per_call": res.us_per_call,
                         **res.metrics()})
            if verbose:
                print(res.csv_row())
    save_result("fig2_probabilistic", rows)
    return rows


if __name__ == "__main__":
    run()
