"""Fig. 7 (beyond-paper): dynamic-topology failure regimes.

The paper's opening premise — "RWs can fail due to node or link failures"
— exercised at the topology level, which the GraphState layer makes a
traced scenario axis:

  * node crashes: a scheduled crash downs a node (killing its resident
    walks) mid-run, with slow stochastic recovery; plus an i.i.d.
    crash/recover churn regime;
  * link failures: i.i.d. per-edge failure/recovery — the graph thins and
    re-heals continuously, stranding walks on degraded neighborhoods;
  * Pac-Man (arXiv:2508.05663): one adversarial node silently absorbs
    every visiting walk, with no honest phase to learn from.

All regimes share the DECAFORK/DECAFORK+ static structure, so each
algorithm's whole row set runs as ONE compiled sweep call (the per-group
compile guarantee of ``repro.sweep``); the 'none' baseline shows each
threat is fatal without self-regulation.
"""
from benchmarks.common import (
    PROTO_START, STEPS, default_graph, run_sweep_cases, save_result, scenario,
)
from repro.core import FailureConfig

CRASH_AT = PROTO_START + (STEPS - PROTO_START) // 3


def topology_failures() -> list:
    """(tag, FailureConfig) rows for the three topology threat models."""
    return [
        ("crash", FailureConfig(
            node_crash_times=(CRASH_AT,), node_crash_ids=(0,),
            p_node_recover=0.002,
        )),
        # schedule-free rows co-batch with "crash" via pad_bursts
        ("churn", FailureConfig(
            p_node_fail=5e-5, p_node_recover=0.01,
            node_fail_start=PROTO_START,
        )),
        ("links", FailureConfig(
            p_link_fail=2e-4, p_link_recover=0.02,
            link_fail_start=PROTO_START,
        )),
        ("pacman", FailureConfig(
            pacman_node=0, pacman_start_time=CRASH_AT,
        )),
    ]


def run(verbose: bool = True):
    g = default_graph()
    scenarios = []
    for alg in ("decafork", "decafork+", "none"):
        for tag, fcfg in topology_failures():
            scenarios.append(scenario(f"fig7/{alg}/{tag}", alg, fcfg))
    rows = []
    for res in run_sweep_cases(g, scenarios):
        rows.append({"name": res.name, "us_per_call": res.us_per_call,
                     **res.metrics()})
        if verbose:
            print(res.csv_row())
    save_result("fig7_topology", rows)
    return rows


if __name__ == "__main__":
    run()
