"""Fig. 8 (beyond-paper): LEARNING under failure, as a batched sweep.

The paper's point is that the walks execute a computational task —
decentralized RW-SGD learning — so the figure that matters is not just
Z_t-under-failure but *loss*-under-failure. Related work compares RW
learning against failure regimes directly (Gholami & Seferoglu, "A Tale
of Two Learning Algorithms"; Chen et al., "Random Walk Learning and the
Pac-Man Attack"); with the payload API this is an ordinary scenario
sweep: one ``RwSgdPayload`` rides ``Experiment.sweep``, every (protocol x
failure regime x seed) trajectory trains its own replica set inside the
compiled scan, and the loss curves come back batched.

Grid: {decafork, decafork+, none} x {burst, Pac-Man absorption, node
churn} — one compiled call per protocol (static-structure group), every
failure regime a traced scenario row inside it. The 'none' rows show
what failure does to unregulated RW-SGD: walks die, replicas stop
training, the loss curve flatlines; the DECAFORK rows keep learning.

Emits ``results/fig8_learning.json``: per-scenario loss/Z curves
(downsampled), pre/post-failure loss means, live-replica counts, and the
compile-count bookkeeping (one XLA program per protocol group).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import FULL, save_result
from repro.api import Experiment, cache_stats
from repro.configs import get_smoke_config
from repro.core import FailureConfig
from repro.data import make_markov_task
from repro.graphs import random_regular_graph
from repro.models.model import Model
from repro.optim import RwSgdPayload, adamw
from repro.sweep import Scenario
from repro.core.protocol import ProtocolConfig

STEPS = 900 if FULL else 300
SEEDS = 4 if FULL else 2
PROTO_START = STEPS // 3
FAIL_AT = STEPS // 2
Z0, MAX_WALKS = 5, 12
ALGS = ("decafork", "decafork+", "none")


def _pcfg(alg: str) -> ProtocolConfig:
    return ProtocolConfig(
        algorithm=alg, z0=Z0, max_walks=MAX_WALKS, eps=1.6, eps2=8.0,
        protocol_start=PROTO_START, rt_bins=256,
    )


def failure_regimes() -> list:
    """(tag, FailureConfig) rows — the >= 3 failure axes of the figure."""
    return [
        ("burst", FailureConfig(burst_times=(FAIL_AT,), burst_sizes=(3,))),
        ("pacman", FailureConfig(pacman_node=0, pacman_start_time=FAIL_AT)),
        ("churn", FailureConfig(
            p_node_fail=1e-3, p_node_recover=0.05, node_fail_start=FAIL_AT,
        )),
    ]


def build_payload() -> RwSgdPayload:
    cfg = get_smoke_config(
        "paper_rwsgd", num_layers=1, d_model=64, d_ff=128, vocab_size=256,
        num_heads=2, num_kv_heads=2,
    )
    model = Model(cfg)
    task = make_markov_task(cfg.vocab_size, rank=4, temperature=2.5)
    return RwSgdPayload(
        model, adamw(5e-3), task, max_walks=MAX_WALKS,
        local_batch=2, seq_len=16,
    )


def _downsample(curve: np.ndarray, points: int = 100) -> list:
    idx = np.linspace(0, curve.shape[0] - 1, min(points, curve.shape[0]))
    out = curve[idx.astype(int)]
    # JSON-safe: rounds where no replica trained are null, not a number
    return [None if np.isnan(v) else float(v) for v in out]


def _masked_mean(x: np.ndarray):
    """Mean over finite entries; None when every entry is masked."""
    x = x[np.isfinite(x)]
    return float(x.mean()) if x.size else None


def run(verbose: bool = True):
    g = random_regular_graph(48, 6, seed=0)
    payload = build_payload()
    scenarios = [
        Scenario(f"fig8/{alg}/{tag}", _pcfg(alg), fcfg)
        for alg in ALGS
        for tag, fcfg in failure_regimes()
    ]
    compiles_before = cache_stats()["xla_compiles"]
    res = Experiment(
        graph=g, scenarios=scenarios, steps=STEPS, payload=payload
    ).sweep(seeds=SEEDS)
    compiles = cache_stats()["xla_compiles"] - compiles_before

    rows = []
    for name in res.names:
        out = res[name]
        learn = res.payload(name)
        z = np.asarray(out.z)  # (seeds, T)
        trained = np.asarray(learn.trained)  # (seeds, T)
        # a round where no replica trained has no loss (the 0.0 is a
        # placeholder) — a fully-absorbed population must read as a dead
        # curve, not as a perfect learner
        loss = np.where(trained > 0, np.asarray(learn.mean_loss), np.nan)
        live = np.sum(np.isfinite(loss), axis=0)  # seeds with a loss at t
        mean_curve = np.where(
            live > 0, np.nansum(loss, axis=0) / np.maximum(live, 1), np.nan
        )
        rows.append({
            "name": name,
            "loss_curve": _downsample(mean_curve),
            "z_curve": _downsample(z.mean(0)),
            "loss_pre_failure": _masked_mean(loss[:, max(FAIL_AT - 50, 0):FAIL_AT]),
            "loss_final": _masked_mean(loss[:, -50:]),
            "trained_final": float(trained[:, -1].mean()),
            "survival_rate": float((z > 0).all(1).mean()),
        })
        if verbose:
            r = rows[-1]
            fmt = lambda v: "dead" if v is None else f"{v:.3f}"
            print(f"{name},loss {fmt(r['loss_pre_failure'])}->{fmt(r['loss_final'])},"
                  f"replicas@end={r['trained_final']:.1f},"
                  f"surv={r['survival_rate']:.2f}")
    extra = {
        "steps": STEPS, "seeds": SEEDS, "fail_at": FAIL_AT,
        "entropy_floor": payload.task.entropy,
        "compiled_programs": compiles,
        "protocol_groups": len(ALGS),
    }
    assert compiles <= len(ALGS), (compiles, len(ALGS))
    save_result("fig8_learning", rows, extra)
    if verbose:
        print(f"# fig8: {len(scenarios)} scenarios in {compiles} compiled "
              f"programs ({len(ALGS)} protocol groups)")
    return rows


if __name__ == "__main__":
    run()
