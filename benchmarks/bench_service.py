"""BENCH: the experiment service — coalescing callers vs sequential sweeps.

Workload: K callers (6 reduced; 8 with BENCH_FULL=1), each holding its
own slice of one epsilon grid — identical static structure, same
seeds/base key, exactly the "many users, compatible studies" regime the
service exists for.

Arms (identical total work — CALLERS x PER_CALLER scenarios x SEEDS):
  - ``sequential`` : each caller runs a private ``Plan.sweep`` — one
                     device dispatch per caller (the pre-service story);
  - ``service``    : all K submissions coalesce into ONE ``sweep_stacked``
                     batch through ``ExperimentService`` (asserted:
                     stats show exactly one compiled batch);
  - ``store_warm`` : the same batch answered from a warm ResultStore —
                     no trace, no compile, no execution; disk read +
                     schema rebuild only. Reported as ms per hit (the
                     cross-process repeat-study latency).

Both timed arms run fully warm (programs cached; steady = min over
REPEATS) so the ratio is dispatch overhead, not compile amortization.
"""
from __future__ import annotations

import tempfile
import time

import jax
import numpy as np

from benchmarks.common import (
    FULL, burst_failures, default_graph, pcfg_for, save_result,
)
from repro.api import Experiment, ExperimentService, ResultStore
from repro.sweep import Scenario

STEPS = 2000 if FULL else 600
SEEDS = 8 if FULL else 4
CALLERS = 8 if FULL else 6
PER_CALLER = 3
BASE_KEY = 11
REPEATS = 3


def _caller_scenarios() -> list:
    fcfg = burst_failures(burst_times=(STEPS // 3, 2 * STEPS // 3))
    grid = np.linspace(1.7, 2.6, CALLERS * PER_CALLER)
    return [
        [
            Scenario(
                f"c{c}/eps={e:.3f}",
                pcfg_for("decafork", eps=float(e), protocol_start=STEPS // 4),
                fcfg,
            )
            for e in grid[c * PER_CALLER : (c + 1) * PER_CALLER]
        ]
        for c in range(CALLERS)
    ]


def _block(results) -> None:
    for res in results:
        jax.block_until_ready(res.outputs)


def run() -> None:
    callers = _caller_scenarios()
    all_rows = [r for rows in callers for r in rows]
    plan = Experiment(
        graph=default_graph(), steps=STEPS, scenarios=all_rows
    ).plan()

    def sequential():
        out = [
            plan.sweep(rows, seeds=SEEDS, base_key=BASE_KEY)
            for rows in callers
        ]
        _block(out)
        return out

    def service():
        with ExperimentService(plan, store=None, autostart=False) as svc:
            futs = [
                svc.submit(rows, seeds=SEEDS, base_key=BASE_KEY)
                for rows in callers
            ]
            svc.flush()
            out = [f.result() for f in futs]
            _block(out)
            assert svc.stats["batches"] == 1, svc.stats  # fully coalesced
        return out

    def timed(fn) -> float:
        t0 = time.time()
        fn()
        return time.time() - t0

    sequential()  # warm the compile cache for both arms
    service()
    t_seq = min(timed(sequential) for _ in range(REPEATS))
    t_svc = min(timed(service) for _ in range(REPEATS))

    # warm-store hit: the repeat-study path (fresh processes see this too)
    with tempfile.TemporaryDirectory() as d:
        store = ResultStore(d)
        plan.sweep_stacked(all_rows, seeds=SEEDS, base_key=BASE_KEY, store=store)
        t_hit = min(
            timed(
                lambda: plan.sweep_stacked(
                    all_rows, seeds=SEEDS, base_key=BASE_KEY, store=store
                )
            )
            for _ in range(max(REPEATS, 3))
        )
        assert store.hits >= 3 and store.misses == 1

    total = STEPS * SEEDS * len(all_rows)
    speedup = t_seq / t_svc
    rows = [
        f"service_coalesced,{t_svc * 1e6 / total:.3f},"
        f"callers={CALLERS}|batches=1|speedup_vs_sequential={speedup:.2f}x",
        f"sequential_sweeps,{t_seq * 1e6 / total:.3f},dispatches={CALLERS}",
        f"store_warm_hit,{t_hit * 1e6 / total:.3f},"
        f"hit_ms={t_hit * 1e3:.1f}|vs_service={t_svc / max(t_hit, 1e-9):.1f}x",
    ]
    for r in rows:
        print(r)
    save_result(
        "bench_service",
        rows,
        extra={
            "callers": CALLERS,
            "per_caller": PER_CALLER,
            "steps": STEPS,
            "seeds": SEEDS,
            "sequential_s": t_seq,
            "service_s": t_svc,
            "store_hit_s": t_hit,
            "speedup_vs_sequential": speedup,
        },
    )


if __name__ == "__main__":
    run()
