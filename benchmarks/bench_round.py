"""BENCH: the simulator round — whole-round fusion and the estimator
microbench (results/bench_round.json).

Two measurements:

``run_whole_round`` — the headline: the PR-4 per-stage round
(``round_impl="unfused"``: topology step, hop, failure stack,
observation scatter, estimator, decisions as separate XLA stages — in
particular a per-round ``cumsum`` over the return-time histogram, which
XLA CPU lowers to a quadratic reduce-window) versus the fused whole
round (``round_impl="fused"``: row-restricted hop, pairwise choose, and
the incrementally-carried cumulative return-time table on CPU; the
single-pass Pallas kernel on TPU). Both arms run the bench_sweep
workload — the fig5-style epsilon grid (8 scenarios x 4 seeds x 600
steps reduced) on the canonical n=100 8-regular graph — through the
same batched sweep engine, both warm (steady = min over cached re-runs
after the cold compile), and must agree bitwise on every recorded
output before any number is reported.

``run_round`` — the PR-4 microbench, unchanged grid: ONE fused
observation round (scatter + last-seen update + theta) per
``estimator_impl`` (gather / compare / fused; plus the interpret-mode
Pallas kernels off-TPU for completeness) across an (n, W, B) grid.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import FULL, default_graph, save_result
from benchmarks.bench_sweep import STEPS, SEEDS, _scenarios
from repro.api import Experiment

REPEATS = 3  # steady-state = min over this many fully-cached re-runs


def _sweep_arm(graph, scenarios, round_impl):
    """The bench_sweep workload with every scenario pinned to one
    round_impl; returns (wall seconds, recorded outputs)."""
    pinned = [
        (dataclasses.replace(p, round_impl=round_impl), f)
        for p, f in scenarios
    ]
    t0 = time.time()
    out = Experiment(graph=graph, scenarios=pinned, steps=STEPS)\
        .plan().sweep_stacked(seeds=SEEDS, base_key=0)
    jax.block_until_ready(out)
    return time.time() - t0, out


def run_whole_round(verbose: bool = True):
    """Fused whole round vs the per-stage sequence, both arms warm."""
    graph = default_graph()
    scenarios = _scenarios()
    denom = len(scenarios) * STEPS * SEEDS
    rows, outs, steady = [], {}, {}
    for impl in ("unfused", "fused"):
        t_cold, out = _sweep_arm(graph, scenarios, impl)
        best = None
        for _ in range(REPEATS):
            t, out = _sweep_arm(graph, scenarios, impl)
            best = t if best is None else min(best, t)
        outs[impl], steady[impl] = out, best
        rows += [
            {"name": f"bench_round/whole_{impl}_cold", "wall_s": t_cold,
             "us_per_call": t_cold * 1e6 / denom},
            {"name": f"bench_round/whole_{impl}_steady", "wall_s": best,
             "us_per_call": best * 1e6 / denom},
        ]
        if verbose:
            print(
                f"bench_round/whole_{impl},{best * 1e6 / denom:.2f},"
                f"cold={t_cold:.2f}s|steady={best:.2f}s"
            )
    # the fused round must be bitwise the unfused sequence — no number
    # is worth reporting if the arms computed different trajectories
    for name, a, b in zip(
        outs["fused"]._fields, outs["fused"], outs["unfused"]
    ):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=f"fused vs unfused: {name}"
        )
    extra = {
        "scenarios": len(scenarios),
        "steps": STEPS,
        "seeds": SEEDS,
        "repeats": REPEATS,
        "speedup_fused_vs_unfused": steady["unfused"] / steady["fused"],
    }
    if verbose:
        print(
            f"BENCH bench_round whole-round speedup_fused_vs_unfused="
            f"{extra['speedup_fused_vs_unfused']:.2f}x "
            f"({len(scenarios)} scenarios x {SEEDS} seeds x {STEPS} steps)"
        )
    return rows, extra


# ---------------------------------------------------------------------------
# round-level estimator microbench (the PR-4 grid)
# ---------------------------------------------------------------------------

ROUND_GRID = (
    [(100, 64, 1024), (1000, 64, 1024), (4096, 128, 1024), (16384, 128, 512)]
    if FULL
    else [(100, 64, 1024), (1000, 64, 1024), (4096, 128, 512)]
)
ROUND_ITERS = 30 if FULL else 10
# interpret-mode Pallas (the off-TPU fallback) is an emulation, orders of
# magnitude off its compiled speed — only meaningful to time on TPU or at
# tiny shapes; keep it to the smallest grid point elsewhere
PALLAS_MAX_N = 10**9 if jax.default_backend() == "tpu" else 128


def _round_inputs(key, n, W, B):
    from repro.kernels.round_update import random_round_inputs

    return random_round_inputs(key, n, W, B, W, t=500)


def _round_impls():
    """Jitted one-round pipelines per estimator_impl: scatter + last-seen
    update + theta for the visiting walks (what one scan step pays)."""
    from repro.core import estimator as est
    from repro.kernels import round_update_pallas, round_update_ref
    from repro.kernels import theta_sums_pallas

    def scatter(ls, hist, total, pos, track, r, valid, upd):
        rts = est.record_returns(est.ReturnTimeState(hist, total), pos, r, valid)
        ls = ls.at[pos, track].max(upd, mode="drop")
        return ls, rts

    @jax.jit
    def gather(ls, hist, total, pos, track, r, valid, upd, t):
        ls, rts = scatter(ls, hist, total, pos, track, r, valid, upd)
        theta = est.theta_hat_rows(ls, rts.hist, rts.total, t, pos, track)
        return ls, rts.hist, rts.total, theta

    @jax.jit
    def compare(ls, hist, total, pos, track, r, valid, upd, t):
        ls, rts = scatter(ls, hist, total, pos, track, r, valid, upd)
        sums = est.node_sums_compare(ls, rts.hist, rts.total, t)
        return ls, rts.hist, rts.total, est.theta_hat_from_node_sums(sums, pos)

    @jax.jit
    def fused(ls, hist, total, pos, track, r, valid, upd, t):
        ls, hist, total, sums = round_update_ref(
            ls, hist, total, pos, track, r, valid, upd, t
        )
        return ls, hist, total, est.theta_hat_from_node_sums(sums, pos)

    @jax.jit
    def pallas_fused(ls, hist, total, pos, track, r, valid, upd, t):
        ls, hist, total, sums = round_update_pallas(
            ls, hist, total, pos, track, r, valid, upd, t
        )
        return ls, hist, total, est.theta_hat_from_node_sums(sums, pos)

    @jax.jit
    def pallas_theta(ls, hist, total, pos, track, r, valid, upd, t):
        ls, rts = scatter(ls, hist, total, pos, track, r, valid, upd)
        sums = theta_sums_pallas(ls, rts.hist, rts.total, t)
        return ls, rts.hist, rts.total, est.theta_hat_from_node_sums(sums, pos)

    return {
        "gather": gather,
        "compare": compare,
        "fused": fused,
        "pallas_fused": pallas_fused,
        "pallas_theta": pallas_theta,
    }


def run_round(verbose: bool = True):
    impls = _round_impls()
    rows = []
    key = jax.random.key(0)
    for n, W, B in ROUND_GRID:
        args = _round_inputs(jax.random.fold_in(key, n), n, W, B)
        thetas = {}
        for name, fn in impls.items():
            if name.startswith("pallas") and n > PALLAS_MAX_N:
                continue
            out = fn(*args)  # compile + correctness probe
            thetas[name] = np.asarray(out[3])
            jax.block_until_ready(out)
            t0 = time.time()
            for _ in range(ROUND_ITERS):
                out = fn(*args)
            jax.block_until_ready(out)
            us = (time.time() - t0) * 1e6 / ROUND_ITERS
            rows.append(
                {"name": f"bench_round/{name}", "n": n, "W": W, "B": B,
                 "us_per_round": us}
            )
            if verbose:
                print(f"bench_round/{name},{us:.1f},n={n}|W={W}|B={B}")
        # the node-sum impls agree bitwise; gather differs only in float
        # association (same math, different reduction path) and is
        # comparable at active walks (node-sum theta assumes the walk's
        # own column was just stamped — exactly where the protocol reads)
        for a in ("fused", "pallas_fused", "pallas_theta"):
            if a in thetas:
                np.testing.assert_array_equal(thetas[a], thetas["compare"], a)
        act = np.asarray(args[7]) >= 0  # upd != NEVER <=> active slot
        np.testing.assert_allclose(
            thetas["gather"][act], thetas["compare"][act],
            rtol=1e-5, atol=1e-5,
        )
    return rows


def run(verbose: bool = True):
    whole_rows, extra = run_whole_round(verbose)
    micro_rows = run_round(verbose)
    extra = dict(extra, iters=ROUND_ITERS, backend=jax.default_backend())
    save_result("bench_round", whole_rows + micro_rows, extra)
    return whole_rows + micro_rows
