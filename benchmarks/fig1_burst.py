"""Fig. 1: burst failures — MISSINGPERSON vs DECAFORK vs DECAFORK+.

Paper claims reproduced: MISSINGPERSON over-reacts (overshoot well past
Z_0); DECAFORK reacts and stabilizes around Z_0; DECAFORK+ reacts
significantly faster (terminations allow a more aggressive eps).

All three curves go through the batched sweep engine in one call
(per-algorithm static groups compile separately; everything else batches).
"""
from benchmarks.common import (
    burst_failures, default_graph, run_sweep_cases, save_result, scenario,
)


def run(verbose: bool = True):
    g = default_graph()
    fcfg = burst_failures()
    scenarios = [
        scenario(f"fig1/{alg}", alg, fcfg)
        for alg in ("missingperson", "decafork", "decafork+")
    ]
    rows = []
    for res in run_sweep_cases(g, scenarios):
        rows.append({"name": res.name, "us_per_call": res.us_per_call,
                     **res.metrics(), "forks": res.forks, "terms": res.terms})
        if verbose:
            print(res.csv_row())
    save_result("fig1_burst", rows)
    return rows


if __name__ == "__main__":
    run()
