"""Fig. 1: burst failures — MISSINGPERSON vs DECAFORK vs DECAFORK+.

Paper claims reproduced: MISSINGPERSON over-reacts (overshoot well past
Z_0); DECAFORK reacts and stabilizes around Z_0; DECAFORK+ reacts
significantly faster (terminations allow a more aggressive eps)."""
from benchmarks.common import (
    burst_failures, default_graph, pcfg_for, run_case, save_result,
)


def run(verbose: bool = True):
    g = default_graph()
    fcfg = burst_failures()
    rows = []
    for alg in ("missingperson", "decafork", "decafork+"):
        res = run_case(f"fig1/{alg}", g, pcfg_for(alg), fcfg)
        rows.append({"name": res.name, "us_per_call": res.us_per_call,
                     **res.metrics(), "forks": res.forks, "terms": res.terms})
        if verbose:
            print(res.csv_row())
    save_result("fig1_burst", rows)
    return rows


if __name__ == "__main__":
    run()
