"""Estimator implementations microbenchmark (gather vs compare vs kernel).

The 'compare' formulation is the TPU-native restatement the Pallas kernel
uses; on CPU/XLA we measure both jnp paths (the Pallas kernel itself runs
in interpret mode here, so its wall-clock is not meaningful — its
correctness is covered by tests, its roofline by the dry-run)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result


def _bench(fn, args, iters=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def run(verbose: bool = True):
    from repro.core import estimator as est

    key = jax.random.key(0)
    rows = []
    for n, W, B in ((1024, 64, 512), (4096, 64, 1024)):
        ls = jax.random.randint(key, (n, W), -1, 500, dtype=jnp.int32)
        hist = (jax.random.uniform(jax.random.fold_in(key, 1), (n, B)) * 4).astype(
            jnp.float32
        )
        total = hist.sum(1)
        t = jnp.int32(600)

        @jax.jit
        def gather(ls, hist, total, t):
            cum = jnp.concatenate(
                [jnp.zeros_like(hist[:, :1]), jnp.cumsum(hist, axis=1)], axis=1
            )
            nodes = jnp.broadcast_to(jnp.arange(ls.shape[0])[:, None], ls.shape)
            s = est.survival_eval(cum, total, nodes, t - ls)
            return jnp.sum(jnp.where(ls >= 0, s, 0.0), axis=1)

        compare = jax.jit(est.node_sums_compare)
        us_g = _bench(gather, (ls, hist, total, t))
        us_c = _bench(compare, (ls, hist, total, t))
        a = gather(ls, hist, total, t)
        b = compare(ls, hist, total, t)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)
        for name, us in (("gather", us_g), ("compare", us_c)):
            row = f"kernel_theta/{name}/n={n}"
            rows.append({"name": row, "us_per_call": us, "n": n, "W": W, "B": B})
            if verbose:
                print(f"{row},{us:.1f},identical=True")
    save_result("kernel_theta", rows)
    return rows


if __name__ == "__main__":
    run()
