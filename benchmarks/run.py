"""Benchmark harness entry point — one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Reduced sizes by default;
set BENCH_FULL=1 for the paper-scale ensembles (50 seeds, 9000 steps).

  PYTHONPATH=src python -m benchmarks.run             # all figures
  PYTHONPATH=src python -m benchmarks.run fig1 fig3   # a subset
"""
from __future__ import annotations

import sys
import time

from benchmarks import (
    auto_eps,
    bench_payload,
    bench_sweep,
    fig1_burst,
    fig2_probabilistic,
    fig3_byzantine,
    fig4_nodes,
    fig5_epsilon,
    fig6_graphs,
    fig7_topology,
    fig8_learning,
    kernel_theta,
    theory_bounds,
)

BENCHES = {
    "fig1": fig1_burst.run,
    "fig2": fig2_probabilistic.run,
    "fig3": fig3_byzantine.run,
    "fig4": fig4_nodes.run,
    "fig5": fig5_epsilon.run,
    "fig6": fig6_graphs.run,
    "fig7": fig7_topology.run,
    "fig8": fig8_learning.run,
    "theory": theory_bounds.run,
    "kernel_theta": kernel_theta.run,
    "auto_eps": auto_eps.run,
    "sweep": bench_sweep.run,
    "payload": bench_payload.run,
}


def main() -> None:
    names = [a for a in sys.argv[1:] if not a.startswith("-")] or list(BENCHES)
    print("name,us_per_call,derived")
    t0 = time.time()
    for name in names:
        if name not in BENCHES:
            raise SystemExit(f"unknown benchmark {name!r}; have {list(BENCHES)}")
        BENCHES[name]()
    print(f"# total wall time: {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
