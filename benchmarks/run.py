"""Benchmark harness entry point — one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Reduced sizes by default;
set BENCH_FULL=1 for the paper-scale ensembles (50 seeds, 9000 steps).

  PYTHONPATH=src python -m benchmarks.run             # all figures
  PYTHONPATH=src python -m benchmarks.run fig1 fig3   # a subset
  PYTHONPATH=src python -m benchmarks.run --smoke     # seconds-fast CI lane

``--smoke`` runs no timings: it asserts the estimator implementations
(gather / compare / pallas / fused, jnp AND interpret-mode kernels) agree
on tiny shapes — the drift tripwire for every PR's fast CI lane.
"""
from __future__ import annotations

import sys
import time

from benchmarks import (
    auto_eps,
    bench_payload,
    bench_resume,
    bench_round,
    bench_service,
    bench_sweep,
    fig1_burst,
    fig2_probabilistic,
    fig3_byzantine,
    fig4_nodes,
    fig5_epsilon,
    fig6_graphs,
    fig7_topology,
    fig8_learning,
    fig9_zoo,
    kernel_theta,
    theory_bounds,
)

BENCHES = {
    "fig1": fig1_burst.run,
    "fig2": fig2_probabilistic.run,
    "fig3": fig3_byzantine.run,
    "fig4": fig4_nodes.run,
    "fig5": fig5_epsilon.run,
    "fig6": fig6_graphs.run,
    "fig7": fig7_topology.run,
    "fig8": fig8_learning.run,
    "fig9": fig9_zoo.run,
    "theory": theory_bounds.run,
    "kernel_theta": kernel_theta.run,
    "auto_eps": auto_eps.run,
    "sweep": bench_sweep.run,
    "round": bench_round.run,
    "payload": bench_payload.run,
    "service": bench_service.run,
    "resume": bench_resume.run,
}


def smoke() -> None:
    """Estimator-impl + API agreement tripwire (tiny shapes, no timing).

    Asserts, in a few seconds:
      * one fused observation round (ref AND interpret-mode Pallas
        round_update, AND the theta_survival kernel) is bitwise the
        unfused gather/compare sequence, on a non-tile-multiple n;
      * a short simulation drives the same trajectory under every
        estimator_impl (gather vs compare/pallas/fused decisions may
        round differently in float, so trajectories are compared within
        the node-sum family and the gather family separately);
      * the whole-round fused path (``round_impl="fused"``) is bitwise
        the literal unfused stage sequence over a full churny
        trajectory — every recorded output, not just z;
      * the legacy runner shims (run_simulation / run_ensemble /
        run_sweep / run_scenarios) are bitwise the new Experiment API —
        the deprecation layer must never drift from the real path.
    """
    import dataclasses
    import warnings

    import jax
    import numpy as np

    from repro.api import Experiment
    from repro.core import FailureConfig, ProtocolConfig
    from repro.core import estimator as est
    from repro.graphs import random_regular_graph
    from repro.kernels import (
        round_update_pallas,
        round_update_ref,
        theta_sums_pallas,
    )
    from repro.kernels.round_update import random_round_inputs
    from repro.utils.deprecation import APIDeprecationWarning

    # --- one-round bitwise agreement on an odd n ------------------------
    args = random_round_inputs(jax.random.key(7), 13, 6, 32, 6)
    ls, hist, total, pos, track, r, valid, upd, t = args
    want = round_update_ref(*args)
    got = round_update_pallas(*args, interpret=True)
    for name, a, b in zip(("last_seen", "hist", "total", "sums"), want, got):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=f"round_update: {name}"
        )
    sums_kernel = theta_sums_pallas(want[0], want[1], want[2], t, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(sums_kernel), np.asarray(want[3]), err_msg="theta_sums"
    )
    cum = est.survival_cumulative(est.ReturnTimeState(want[1], want[2]))
    theta_g = est.theta_hat(want[0], cum, want[2], t, pos, track)
    theta_r = est.theta_hat_rows(want[0], want[1], want[2], t, pos, track)
    np.testing.assert_array_equal(
        np.asarray(theta_g), np.asarray(theta_r), err_msg="theta rows"
    )
    # node-sum theta assumes the walk's own column was just stamped with t,
    # which only holds for ACTIVE walks (exactly where the protocol reads it)
    act = np.asarray(upd) >= 0
    np.testing.assert_allclose(
        np.asarray(theta_g)[act],
        np.asarray(est.theta_hat_from_node_sums(want[3], pos))[act],
        rtol=1e-5, atol=1e-5, err_msg="gather vs node sums",
    )

    # --- trajectory agreement across estimator_impl ---------------------
    g = random_regular_graph(19, 4, seed=2)
    fcfg = FailureConfig(burst_times=(30,), burst_sizes=(2,))
    zs = {}
    for impl in ("gather", "compare", "pallas", "fused", "auto"):
        pcfg = ProtocolConfig(
            algorithm="decafork", z0=4, max_walks=8, eps=1.4,
            protocol_start=15, rt_bins=32, estimator_impl=impl,
        )
        _, o = Experiment(
            graph=g, protocol=pcfg, failures=fcfg, steps=60
        ).run(key=5)
        zs[impl] = np.asarray(o.z)
    for impl in ("pallas", "fused"):
        np.testing.assert_array_equal(
            zs[impl], zs["compare"], err_msg=f"{impl} vs compare trajectory"
        )
    # 'auto' must resolve to the backend's best impl's exact trajectory
    auto_family = "fused" if jax.default_backend() == "tpu" else "gather"
    np.testing.assert_array_equal(
        zs["auto"], zs[auto_family],
        err_msg=f"auto vs {auto_family} trajectory",
    )

    # --- whole-round fusion vs the unfused oracle ------------------------
    # the fused round must reproduce the literal stage sequence bitwise on
    # every recorded output, under node/link churn and a burst
    churn = FailureConfig(
        burst_times=(30,), burst_sizes=(2,),
        p_node_fail=0.02, p_node_recover=0.3, node_fail_start=10,
        p_link_fail=0.05, p_link_recover=0.4, link_fail_start=10,
    )
    outs = {}
    for rimpl in ("fused", "unfused"):
        pcfg = ProtocolConfig(
            algorithm="decafork+", z0=4, max_walks=8, eps=1.4, eps2=6.0,
            protocol_start=15, rt_bins=32, estimator_impl="gather",
            round_impl=rimpl,
        )
        _, outs[rimpl] = Experiment(
            graph=g, protocol=pcfg, failures=churn, steps=60,
            outputs="full",
        ).run(key=5)
    for name, a, b in zip(outs["fused"]._fields, outs["fused"],
                          outs["unfused"]):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"whole-round fused vs unfused: {name}",
        )

    # --- new API vs legacy-shim bitwise agreement ------------------------
    from repro.core import run_ensemble, run_simulation
    from repro.core.simulator import run_sweep
    from repro.sweep import Scenario, run_scenarios

    pcfg = ProtocolConfig(
        algorithm="decafork", z0=4, max_walks=8, eps=1.6,
        protocol_start=15, rt_bins=32,
    )
    pcfg2 = ProtocolConfig(
        algorithm="missingperson", z0=4, max_walks=8, eps_mp=20.0,
        protocol_start=15, rt_bins=32,
    )
    scen = [Scenario("dfk", pcfg, fcfg), Scenario("mp", pcfg2, fcfg)]
    exp = Experiment(graph=g, protocol=pcfg, failures=fcfg, steps=60,
                     outputs="full", scenarios=scen)
    plan = exp.plan()
    _, new_run = plan.run(key=5)
    new_ens = plan.ensemble(2, base_key=5)
    new_stack = plan.sweep_stacked([scen[0]], seeds=2, base_key=5)
    new_mixed = plan.sweep(seeds=2, base_key=5)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", APIDeprecationWarning)
        _, old_run = run_simulation(g, pcfg, fcfg, steps=60, key=5,
                                    outputs="full")
        old_ens = run_ensemble(g, pcfg, fcfg, steps=60, seeds=2, base_key=5,
                               outputs="full")
        old_stack = run_sweep(g, [scen[0]], steps=60, seeds=2, base_key=5,
                              outputs="full")
        old_mixed = run_scenarios(g, scen, steps=60, seeds=2, base_key=5,
                                  outputs="full")
    for label, a, b in (
        ("run_simulation", new_run, old_run),
        ("run_ensemble", new_ens, old_ens),
        ("run_sweep", new_stack, old_stack),
    ):
        for name, x, y in zip(a._fields, a, b):
            np.testing.assert_array_equal(
                np.asarray(x), np.asarray(y),
                err_msg=f"shim drift: {label}.{name}",
            )
    assert old_mixed.names == new_mixed.names
    for name in new_mixed.names:
        for f, x, y in zip(new_mixed[name]._fields, new_mixed[name],
                           old_mixed[name]):
            np.testing.assert_array_equal(
                np.asarray(x), np.asarray(y),
                err_msg=f"shim drift: run_scenarios[{name}].{f}",
            )

    # --- zoo default-variant bitwise tripwire ----------------------------
    # the zoo's neutral row — uniform defense, every zoo knob explicit at
    # its neutral value — must be bitwise the plain config: the variant
    # dispatch and the attack machinery cost the default program nothing
    from repro.zoo import defense, zoo_scenarios

    plain_p = ProtocolConfig(
        algorithm="decafork", z0=4, max_walks=8, eps=1.4,
        protocol_start=15, rt_bins=32,
    )
    zoo_p = dataclasses.replace(
        plain_p, **defense("uniform"),
        walk_variant="uniform", p_jump=0.0, bias_p=1.0, bias_q=1.0,
    )
    zoo_f = dataclasses.replace(
        churn, pacman_nodes=(), pacman_mobile=False,
        edge_cut_times=(), edge_cut_thresholds=(),
    )
    plain_out = Experiment(
        graph=g, protocol=plain_p, failures=churn, steps=60, outputs="full"
    ).ensemble(2, base_key=5)
    zoo_out = Experiment(
        graph=g, protocol=zoo_p, failures=zoo_f, steps=60, outputs="full"
    ).ensemble(2, base_key=5)
    for name, a, b in zip(zoo_out._fields, zoo_out, plain_out):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"zoo neutral row drift: {name}",
        )
    # and the grid helper stays wired: a defense|attack row is buildable
    # and carries the expected statics
    [row] = zoo_scenarios(["jump"], [("edge_cut", {"time": 30, "threshold": 9})],
                          base_protocol=plain_p)
    assert row.pcfg.walk_variant == "jump" and row.fcfg.n_edge_cuts == 1

    # --- service coalescing bitwise tripwire -----------------------------
    # two callers sharing one static structure coalesce into one batch,
    # and each caller's rows stay bitwise what a private sweep returns
    from repro.api import ExperimentService

    s_a = Scenario("svc_a", pcfg, fcfg)
    s_b = Scenario("svc_b", dataclasses.replace(pcfg, eps=1.9), fcfg)
    with ExperimentService(plan, store=None, autostart=False) as svc:
        fa = svc.submit([s_a], seeds=2, base_key=5)
        fb = svc.submit([s_b], seeds=2, base_key=5)
        svc.flush()
        assert svc.stats["batches"] == 1, svc.stats
        coalesced = {"svc_a": fa.result()["svc_a"], "svc_b": fb.result()["svc_b"]}
    seq = plan.sweep([s_a, s_b], seeds=2, base_key=5)
    for name in ("svc_a", "svc_b"):
        for f, x, y in zip(seq[name]._fields, seq[name], coalesced[name]):
            np.testing.assert_array_equal(
                np.asarray(x), np.asarray(y),
                err_msg=f"service coalescing drift: {name}.{f}",
            )

    # --- durable-execution bitwise tripwire ------------------------------
    # a segmented run killed at a boundary and resumed from its snapshot
    # must be bitwise the straight run — the ISSUE-9 invariant, in seconds
    import tempfile

    from repro.api.store import ResultStore
    from repro.utils.faults import FaultPlan, Kill, SimulatedKill

    straight = plan.sweep_stacked([scen[0]], seeds=2, base_key=5)
    with tempfile.TemporaryDirectory() as tmp:
        store = ResultStore(tmp)
        fp = FaultPlan().skip("segment.boundary", 1).at(
            "segment.boundary", Kill()
        )
        killed = False
        try:
            with fp.active():
                plan.sweep_stacked([scen[0]], seeds=2, base_key=5,
                                   store=store, segment_steps=20)
        except SimulatedKill:
            killed = True
        assert killed, "the boundary kill must fire"
        resumed = plan.sweep_stacked([scen[0]], seeds=2, base_key=5,
                                     store=store, segment_steps=20)
    for name, a, b in zip(straight._fields, straight, resumed):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"resume drift vs straight run: {name}",
        )

    print("SMOKE ok: estimator impls agree (round bitwise, trajectories); "
          "zoo neutral row bitwise == plain config; legacy shims bitwise == "
          "Experiment API; coalesced service == sequential sweep bitwise; "
          "kill-and-resume bitwise == straight run")


def main() -> None:
    if "--smoke" in sys.argv[1:]:
        t0 = time.time()
        smoke()
        print(f"# smoke wall time: {time.time() - t0:.1f}s", file=sys.stderr)
        return
    names = [a for a in sys.argv[1:] if not a.startswith("-")] or list(BENCHES)
    print("name,us_per_call,derived")
    t0 = time.time()
    for name in names:
        if name not in BENCHES:
            raise SystemExit(f"unknown benchmark {name!r}; have {list(BENCHES)}")
        BENCHES[name]()
    print(f"# total wall time: {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
