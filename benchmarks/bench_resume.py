"""BENCH: durable segmented execution overhead vs segment length.

Workload: the ``bench_sweep`` epsilon grid, unchanged (8 scenarios x 4
seeds x 600 steps reduced; 16 x 8 x 2000 with BENCH_FULL=1), so the
monolithic arm here is directly comparable to ``bench_sweep/sweep``.

Arms, all over the identical workload:
  - ``monolithic``     : one ``sweep_stacked`` call (the baseline);
  - ``seg<k>``         : ``segment_steps=k``, NO store — pure
                         chunking overhead (extra dispatches + host-side
                         chunk concatenation);
  - ``seg<k>_store``   : ``segment_steps=k`` with a throwaway on-disk
                         ResultStore — adds the boundary snapshot
                         write-behind, i.e. the full durability cost.

Each arm is measured ``cold`` (first call, includes compiles of every
distinct chunk length) and ``steady`` (min over REPEATS cached re-runs;
the store arm clears both snapshots and the final result between runs so
it re-executes rather than warm-hitting). Before ANY number is reported,
every arm's ``z`` trajectory must be bitwise the monolithic one — a
durability layer that changes results is not measured, it is broken.
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import FULL, default_graph, save_result
from benchmarks.bench_sweep import SEEDS, STEPS, _scenarios
from repro.api import Experiment
from repro.api.store import ResultStore

REPEATS = 2
SEGMENTS = (STEPS, STEPS // 4, 50)  # 1 chunk, 4 chunks, many chunks


def _plan(graph, scenarios):
    return Experiment(graph=graph, scenarios=scenarios, steps=STEPS).plan()


def _time(fn):
    t0 = time.time()
    out = fn()
    return time.time() - t0, np.asarray(out.z)


def _steady(fn):
    best, z = None, None
    for _ in range(REPEATS):
        t, z = _time(fn)
        best = t if best is None else min(best, t)
    return best, z


def run(verbose: bool = True):
    graph = default_graph()
    scenarios = _scenarios()
    plan = _plan(graph, scenarios)
    denom = len(scenarios) * STEPS * SEEDS
    rows, gates = [], []

    def emit(name, cold, steady, z):
        gates.append((name, z))
        rows.append({"name": f"bench_resume/{name}", "wall_s": cold,
                     "us_per_call": cold * 1e6 / denom})
        rows.append({"name": f"bench_resume/{name}_steady", "wall_s": steady,
                     "us_per_call": steady * 1e6 / denom})

    t_cold, z_ref = _time(lambda: plan.sweep_stacked(seeds=SEEDS, base_key=0))
    t_steady, _ = _steady(lambda: plan.sweep_stacked(seeds=SEEDS, base_key=0))
    emit("monolithic", t_cold, t_steady, z_ref)

    for seg in SEGMENTS:
        arm = lambda: plan.sweep_stacked(  # noqa: E731
            seeds=SEEDS, base_key=0, segment_steps=seg
        )
        t_cold, z = _time(arm)
        t_steady, _ = _steady(arm)
        emit(f"seg{seg}", t_cold, t_steady, z)

    tmp = tempfile.mkdtemp(prefix="bench_resume_store_")
    try:
        for seg in SEGMENTS:
            store = ResultStore(tmp)

            def arm(seg=seg, store=store):
                # drop prior state so the run re-executes (write-behind
                # cost, not warm-hit cost, is what this arm measures)
                shutil.rmtree(store.root, ignore_errors=True)
                return plan.sweep_stacked(
                    seeds=SEEDS, base_key=0, segment_steps=seg, store=store
                )

            t_cold, z = _time(arm)
            t_steady, _ = _steady(arm)
            emit(f"seg{seg}_store", t_cold, t_steady, z)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    # the bitwise gate: no number leaves this bench unless every arm
    # reproduced the monolithic trajectories exactly
    for name, z in gates[1:]:
        assert np.array_equal(z_ref, z), f"{name} diverged from monolithic"

    mono_steady = rows[1]["wall_s"]
    extra = {
        "scenarios": len(scenarios), "steps": STEPS, "seeds": SEEDS,
        "segment_lengths": list(SEGMENTS), "repeats": REPEATS,
        "full": FULL, "bitwise_gate": "passed",
        "overhead_steady": {
            r["name"].split("/", 1)[1].removesuffix("_steady"):
                r["wall_s"] / mono_steady
            for r in rows
            if r["name"].endswith("_steady")
        },
    }
    save_result("bench_resume", rows, extra)
    if verbose:
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.2f},wall={r['wall_s']:.2f}s")
        ratios = ", ".join(
            f"{k}={v:.2f}x" for k, v in extra["overhead_steady"].items()
        )
        print(f"BENCH bench_resume steady overhead vs monolithic: {ratios}")
    return rows


if __name__ == "__main__":
    run()
