"""BENCH: fused in-scan RW-SGD payload vs the per-hop Python loop.

Workload: the end-to-end decentralized-training example — DECAFORK walks
carrying model replicas over a regular graph, one local SGD step per hop,
a mid-run burst failure — at the example's smoke-model size, identical
configs and seeds in both arms:

  - ``fused``  : ``Experiment(..., payload=RwSgdPayload(...)).run()`` —
                 protocol round, replica forking, batch sampling and the
                 vmapped train step all inside ONE ``lax.scan`` / ONE
                 device dispatch for the whole trajectory;
  - ``per_hop``: the pre-payload engine (the old
                 ``examples/decentralized_training.py`` loop): a jitted
                 ``protocol_step`` per hop, a host round-trip to inspect
                 ``fork_parent``, a ``fork_replica`` dispatch when forks
                 fired, then a jitted batch-sample + train dispatch —
                 3-4 dispatches and one device->host sync per hop.

Each arm runs twice: ``cold`` includes compilation (the end-to-end
number a user sees), ``warm`` re-runs with everything cached (isolates
dispatch/sync overhead from compile amortization). Emits BENCH json
(``results/bench_payload.json``) with wall clocks and speedup ratios,
``bench_sweep.json``-style.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import FULL, save_result
from repro.configs import get_smoke_config
from repro.core.failures import FailureConfig
from repro.core.protocol import ProtocolConfig
from repro.api import Experiment
from repro.core.simulator import init_state, protocol_step
from repro.data import make_markov_task, sample_batch
from repro.graphs import random_regular_graph
from repro.graphs.state import mirror_indices
from repro.models.model import Model
from repro.optim import RwSgdPayload, adamw, fork_replica, init_replicas
from repro.optim.rw_sgd import replica_train_step

STEPS = 1000 if FULL else 200
N, DEG, Z0, W = 32, 8, 4, 8
BURST_AT = STEPS // 2
PROTO_START = STEPS // 4
LOCAL_BATCH, SEQ = 2, 32
SEED = 0


def _setup():
    g = random_regular_graph(N, DEG, seed=0)
    pcfg = ProtocolConfig(
        algorithm="decafork", z0=Z0, max_walks=W, eps=1.2,
        protocol_start=PROTO_START, rt_bins=512,
    )
    fcfg = FailureConfig(burst_times=(BURST_AT,), burst_sizes=(3,))
    cfg = get_smoke_config("paper_rwsgd")
    model = Model(cfg)
    task = make_markov_task(cfg.vocab_size)
    opt = adamw(3e-3)
    return g, pcfg, fcfg, model, task, opt


def bench_fused(g, pcfg, fcfg, payload):
    t0 = time.time()
    (_, _), (outs, learn) = Experiment(
        graph=g, protocol=pcfg, failures=fcfg, steps=STEPS, payload=payload
    ).run(key=SEED)
    jax.block_until_ready(learn.mean_loss)
    return time.time() - t0, np.asarray(outs.z), np.asarray(learn.mean_loss)


def bench_per_hop(g, pcfg, fcfg, model, task, opt):
    """The old example's engine, verbatim structure: per-hop dispatches."""
    neighbors = jnp.asarray(g.neighbors)
    degrees = jnp.asarray(g.degrees)
    mirror = jnp.asarray(mirror_indices(g))
    key = jax.random.key(SEED)
    rs = init_replicas(model.init, opt.init, key, max_walks=W)
    train = jax.jit(replica_train_step(model.loss, opt))
    step_fn = jax.jit(
        lambda s: protocol_step(s, pcfg, fcfg, neighbors, degrees, mirror, None)
    )

    @jax.jit
    def node_batches_for(pos, kb):
        return jax.vmap(
            lambda nid: sample_batch(task, kb, LOCAL_BATCH, SEQ, nid)
        )(pos)

    t0 = time.time()
    state = init_state(g.n, g.max_degree, pcfg, fcfg, key)
    slots = jnp.arange(W)
    zs, losses = [], []
    for t in range(STEPS):
        state, out = step_fn(state)
        parents = out.fork_parent
        if np.asarray(parents >= 0).any():  # host sync every hop
            rs = fork_replica(rs, jnp.maximum(parents, 0), slots, parents >= 0)
        kb = jax.random.fold_in(key, 10_000 + t)
        batches = node_batches_for(state.walks.pos, kb)
        rs, step_losses = train(rs, batches, state.walks.active)
        z = int(out.z)
        zs.append(z)
        losses.append(float(step_losses.sum() / max(z, 1)))
    return time.time() - t0, np.asarray(zs), np.asarray(losses)


def run(verbose: bool = True):
    g, pcfg, fcfg, model, task, opt = _setup()
    payload = RwSgdPayload(
        model, opt, task, max_walks=W, local_batch=LOCAL_BATCH, seq_len=SEQ
    )

    t_fused_cold, z_f, loss_f = bench_fused(g, pcfg, fcfg, payload)
    t_fused_warm, _, _ = bench_fused(g, pcfg, fcfg, payload)
    t_hop_cold, z_h, loss_h = bench_per_hop(g, pcfg, fcfg, model, task, opt)
    t_hop_warm, _, _ = bench_per_hop(g, pcfg, fcfg, model, task, opt)

    # same control plane in both arms (payload streams are disjoint from
    # the simulator's): identical Z_t trajectories; both arms learn
    assert (z_f == z_h).all(), "control plane diverged between arms"
    assert loss_f[-20:].mean() < loss_f[:20].mean()
    assert loss_h[-20:].mean() < loss_h[:20].mean()

    rows = [
        {"name": "bench_payload/fused_cold", "wall_s": t_fused_cold,
         "us_per_step": t_fused_cold * 1e6 / STEPS},
        {"name": "bench_payload/fused_warm", "wall_s": t_fused_warm,
         "us_per_step": t_fused_warm * 1e6 / STEPS},
        {"name": "bench_payload/per_hop_cold", "wall_s": t_hop_cold,
         "us_per_step": t_hop_cold * 1e6 / STEPS},
        {"name": "bench_payload/per_hop_warm", "wall_s": t_hop_warm,
         "us_per_step": t_hop_warm * 1e6 / STEPS},
    ]
    extra = {
        "steps": STEPS, "nodes": N, "z0": Z0, "max_walks": W,
        "speedup_cold": t_hop_cold / t_fused_cold,
        "speedup_warm": t_hop_warm / t_fused_warm,
        "final_loss_fused": float(loss_f[-20:].mean()),
        "final_loss_per_hop": float(loss_h[-20:].mean()),
    }
    save_result("bench_payload", rows, extra)
    if verbose:
        for r in rows:
            print(f"{r['name']},{r['us_per_step']:.1f},wall={r['wall_s']:.2f}s")
        print(
            f"BENCH bench_payload speedup_cold={extra['speedup_cold']:.2f}x "
            f"speedup_warm={extra['speedup_warm']:.2f}x "
            f"({STEPS} steps, {W} replica slots)"
        )
    return rows


if __name__ == "__main__":
    run()
