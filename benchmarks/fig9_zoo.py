"""Fig. 9 (beyond-paper): the zoo — attacks x defenses cross-product.

The resilience story as a grid: every walk-variant *defense* in
``repro.zoo.variants`` against every adversary in ``repro.zoo.attacks``,
on the two-community graph whose id boundary is exactly what the
``edge_cut`` attack severs. The whole grid is declared through the
registered ``"zoo"`` experiment builder (``Experiment.from_config``) and
runs through one Plan: the sweep engine compiles ONE program per static
group (walk variant x attack statics x schedule widths), and
``Plan.round_decisions`` records how each group executes its rounds —
fused or stage-sequence fallback, with the reason — so the result file
documents not just the numbers but the programs that produced them.

Qualitative expectations the grid exhibits:

  * ``none``          — every defense holds Z near Z0 (sanity row);
  * ``mobile_pacman`` — a hopping absorber bleeds walks everywhere; the
    self-regulation (forking) has to outpace it;
  * ``multi_pacman``  — one absorber per community doubles the drain;
  * ``edge_cut``      — the partition strands walks; ``jump`` teleports
    across the cut while ``uniform`` cannot re-mix.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (
    EPS2_DFKP, EPS_DFKP, FULL, MAX_WALKS, Z0, save_result,
)
from repro.api import Experiment

N = 64
STEPS = 4500 if FULL else 1200
SEEDS = 16 if FULL else 4
PROTO_START = 1000 if FULL else 200
ATTACK_AT = PROTO_START + (STEPS - PROTO_START) // 3
HALF = N // 2

DEFENSES = ("uniform", "jump", "biased", "bloom")
ATTACKS = (
    ("none", {}),
    ("mobile_pacman", {"node": 0, "hop_prob": 0.5, "start": ATTACK_AT}),
    ("multi_pacman", {"nodes": (0, HALF), "start": ATTACK_AT}),
    ("edge_cut", {"time": ATTACK_AT, "threshold": HALF}),
)


def experiment() -> Experiment:
    """The grid as one declarative, registry-named experiment."""
    return Experiment.from_config({
        "experiment": "zoo",
        "n": N,
        "graph_seed": 0,
        "graph_kwargs": {"k_bridges": 2},
        "steps": STEPS,
        "protocol": {
            "algorithm": "decafork+", "z0": Z0, "eps": EPS_DFKP,
            "eps2": EPS2_DFKP, "max_walks": MAX_WALKS,
            "protocol_start": PROTO_START, "rt_bins": 1024,
        },
        "defenses": DEFENSES,
        "attacks": ATTACKS,
        "name": "fig9_zoo",
    })


def run(verbose: bool = True):
    exp = experiment()
    plan = exp.plan()
    names = [s.name for s in exp.scenarios]
    groups = plan.groups()
    decisions = [
        {
            "scenarios": [names[i] for i in idxs],
            "impl": dec.impl,
            "backend": dec.backend,
            "reason": dec.reason,
        }
        for _sig, idxs, dec in plan.round_decisions()
    ]

    t0 = time.time()
    res = plan.sweep(seeds=SEEDS)
    zs = [np.asarray(o.z) for o in res.outputs]  # blocks until done
    wall = time.time() - t0
    us = wall * 1e6 / (STEPS * SEEDS * len(names))

    rows = []
    for name, z, o in zip(res.names, zs, res.outputs):
        post = z[:, PROTO_START:]
        row = {
            "name": f"fig9/{name}",
            "us_per_call": us,
            "mean_z_post": float(post.mean()),
            "mean_abs_dev": float(np.abs(post - Z0).mean()),
            "min_z_post": int(post.min()),
            "max_z": int(z.max()),
            "survival_rate": float((z > 0).all(1).mean()),
            "forks": int(np.asarray(o.forks).sum()),
            "terms": int(np.asarray(o.terms).sum()),
        }
        rows.append(row)
        if verbose:
            print(
                f"fig9/{name},{us:.2f},"
                f"meanZ={row['mean_z_post']:.1f}|dev={row['mean_abs_dev']:.2f}"
                f"|minZ={row['min_z_post']}|surv={row['survival_rate']:.2f}"
            )
    save_result(
        "fig9_zoo",
        rows,
        extra={
            "grid": {
                "defenses": list(DEFENSES),
                "attacks": [a for a, _ in ATTACKS],
                "n": N, "steps": STEPS, "seeds": SEEDS,
                "graph": "community", "attack_at": ATTACK_AT,
            },
            "compile_groups": len(groups),
            "round_decisions": decisions,
        },
    )
    return rows


if __name__ == "__main__":
    run()
